#!/usr/bin/env bash
# Budget gate over the consolidated BENCH_*.json headlines: fails when any
# benchmark named in perf_budgets.json runs slower than its ceiling by more
# than the configured tolerance (the ">20% regression" gate). Files or
# budget entries with no counterpart are skipped — the budgets track the
# headline benches, not an inventory — so the gate degrades gracefully when
# only a subset of bench targets ran.
#
#   scripts/bench.sh && scripts/bench_check.sh
set -euo pipefail
cd "$(dirname "$0")/.."

python3 - <<'PY'
import glob
import json
import sys

budgets = json.load(open("perf_budgets.json"))
ceilings = budgets["budgets_ns"]
tol = budgets.get("tolerance", 1.2)
seen = 0
failures = []
for path in sorted(glob.glob("BENCH_*.json")):
    data = json.load(open(path))
    for r in data.get("results", []):
        name = r.get("name")
        if name not in ceilings:
            continue
        seen += 1
        limit = ceilings[name] * tol
        med = float(r["median_ns"])
        status = "ok" if med <= limit else "FAIL"
        # headroom: how many times under the gate the median sits (<1 =
        # over budget) — watch this shrink before it ever fails
        headroom = limit / med if med > 0 else float("inf")
        print(
            f"[bench_check] {status:4} {name:<44} "
            f"median {med:>14.1f} ns  ceiling {ceilings[name]:.0f} x {tol}"
            f"  headroom {headroom:6.1f}x"
        )
        if med > limit:
            failures.append(name)
if seen == 0:
    print(
        "[bench_check] no budgeted benchmarks found in BENCH_*.json — "
        "nothing to gate"
    )
if failures:
    print(
        f"[bench_check] {len(failures)} benchmark(s) over budget: "
        + ", ".join(failures)
    )
    sys.exit(1)
print(f"[bench_check] {seen} budgeted benchmark(s) within ceiling")
PY
