#!/usr/bin/env bash
# Budget gate over the consolidated BENCH_*.json headlines: fails when any
# benchmark named in perf_budgets.json runs slower than its ceiling by more
# than the configured tolerance (the ">20% regression" gate). Files or
# budget entries with no counterpart are skipped — the budgets track the
# headline benches, not an inventory — so the gate degrades gracefully when
# only a subset of bench targets ran.
#
#   scripts/bench.sh && scripts/bench_check.sh
#
# `--record` re-baselines instead of gating: every budgeted benchmark that
# has a measured median in BENCH_*.json gets its ceiling rewritten to that
# median (rounded up to two significant figures so the checked-in numbers
# stay readable); entries without a fresh measurement keep their old
# ceiling, and the note + tolerance fields pass through untouched.
#
#   scripts/bench.sh && scripts/bench_check.sh --record
set -euo pipefail
cd "$(dirname "$0")/.."

MODE="check"
if [ "${1:-}" = "--record" ]; then
  MODE="record"
elif [ -n "${1:-}" ]; then
  echo "usage: scripts/bench_check.sh [--record]" >&2
  exit 2
fi
export BENCH_CHECK_MODE="$MODE"

python3 - <<'PY'
import glob
import json
import math
import sys
import os

budgets = json.load(open("perf_budgets.json"))
ceilings = budgets["budgets_ns"]
tol = budgets.get("tolerance", 1.2)

# measured medians per budgeted name; a name measured by more than one
# BENCH file keeps its slowest median (the conservative baseline)
measured = {}
for path in sorted(glob.glob("BENCH_*.json")):
    data = json.load(open(path))
    for r in data.get("results", []):
        name = r.get("name")
        if name not in ceilings:
            continue
        med = float(r["median_ns"])
        measured[name] = max(med, measured.get(name, 0.0))

if os.environ.get("BENCH_CHECK_MODE") == "record":
    recorded = 0
    for name, med in sorted(measured.items()):
        if med <= 0:
            continue
        # round UP to 2 significant figures: a readable ceiling that never
        # undercuts the measurement it came from
        exp = math.floor(math.log10(med))
        quantum = 10 ** max(exp - 1, 0)
        ceiling = int(math.ceil(med / quantum) * quantum)
        print(
            f"[bench_check] record {name:<44} "
            f"median {med:>14.1f} ns  ceiling {ceilings[name]} -> {ceiling}"
        )
        ceilings[name] = ceiling
        recorded += 1
    kept = len(ceilings) - recorded
    # note + tolerance (and any future fields) pass through untouched
    with open("perf_budgets.json", "w") as f:
        json.dump(budgets, f, indent=2)
        f.write("\n")
    print(
        f"[bench_check] recorded {recorded} ceiling(s) from measured "
        f"medians ({kept} kept — no fresh measurement)"
    )
    sys.exit(0)

seen = 0
failures = []
for name in sorted(measured):
    med = measured[name]
    seen += 1
    limit = ceilings[name] * tol
    status = "ok" if med <= limit else "FAIL"
    # headroom: how many times under the gate the median sits (<1 =
    # over budget) — watch this shrink before it ever fails
    headroom = limit / med if med > 0 else float("inf")
    print(
        f"[bench_check] {status:4} {name:<44} "
        f"median {med:>14.1f} ns  ceiling {ceilings[name]:.0f} x {tol}"
        f"  headroom {headroom:6.1f}x"
    )
    if med > limit:
        failures.append(name)
if seen == 0:
    print(
        "[bench_check] no budgeted benchmarks found in BENCH_*.json — "
        "nothing to gate"
    )
if failures:
    print(
        f"[bench_check] {len(failures)} benchmark(s) over budget: "
        + ", ".join(failures)
    )
    sys.exit(1)
print(f"[bench_check] {seen} budgeted benchmark(s) within ceiling")
PY
