#!/usr/bin/env bash
# Run the bench_* targets and consolidate machine-readable perf trajectories
# at the repo root so future PRs have something to compare against:
#   BENCH_pipeline.json — compress / deco / timesim / runtime / pipeline
#   BENCH_fabric.json   — fabric sync_arrival + fabric-clock overhead vs
#                         single-link at n in {4, 16, 32}
#   BENCH_elastic.json  — membership-aware clock tick + aggregation
#                         bookkeeping with churn vs the static-fabric
#                         baseline at n in {4, 16, 32}
#   BENCH_topo.json     — two-tier topology clock tick vs flat at
#                         n in {4, 16, 32} x regions in {2, 4}
#   BENCH_trace.json    — exact prefix-integral transfer_end vs the old
#                         10 ms Euler stepper on {Sine, OU, Markov,
#                         Windowed-OU} x {0.1 s, 3 s, 30 s}, plus the
#                         serial-vs-pooled exp hetero --fast sweep cell
#   BENCH_bond.json     — water-filling Bond::schedule at k in {2, 4} and
#                         the bonded clock tick vs single-path at
#                         n in {4, 16, 32} x k in {2, 4}
#   BENCH_scale.json    — shared-timeline-class clock tick at
#                         n in {1k, 10k, 100k} vs the O(n) singleton
#                         reference engine at {1k, 10k} (the per-tick cost
#                         of the class engine must stay flat in n)
#   BENCH_obs.json      — clock hot-loop tick with tracing disabled
#                         (NullSink) vs fully traced at n in {16, 1k}
#                         (the null series must stay inside the untraced
#                         tick envelope — the zero-overhead contract)
#   BENCH_audit.json    — clock hot-loop tick bare vs with the O(1)
#                         streaming plan-audit fold at n in {16, 1k}
#                         (the fold series must stay inside the untraced
#                         tick envelope)
#   BENCH_lossy.json    — clock hot-loop tick with per-worker message
#                         loss (i.i.d. / bursty retransmission pricing)
#                         and a binding deadline cut vs the lossless
#                         baseline at n in {4, 16}
#
# scripts/bench_check.sh gates the BENCH_*.json headlines against the
# checked-in perf_budgets.json ceilings.
#
#   scripts/bench.sh                # fast mode (default; CI-sized)
#   DECO_BENCH_FAST=0 scripts/bench.sh   # full measurement windows
set -euo pipefail
cd "$(dirname "$0")/.."

: "${DECO_BENCH_FAST:=1}"
if [ "$DECO_BENCH_FAST" = "0" ]; then
  unset DECO_BENCH_FAST
else
  export DECO_BENCH_FAST
fi

jsonl="$(mktemp)"
fab_jsonl="$(mktemp)"
ela_jsonl="$(mktemp)"
topo_jsonl="$(mktemp)"
trace_jsonl="$(mktemp)"
bond_jsonl="$(mktemp)"
scale_jsonl="$(mktemp)"
obs_jsonl="$(mktemp)"
audit_jsonl="$(mktemp)"
lossy_jsonl="$(mktemp)"
trap 'rm -f "$jsonl" "$fab_jsonl" "$ela_jsonl" "$topo_jsonl" "$trace_jsonl" "$bond_jsonl" "$scale_jsonl" "$obs_jsonl" "$audit_jsonl" "$lossy_jsonl"' EXIT

consolidate() {
  # consolidate <jsonl> <out.json>
  {
    echo '{'
    echo '  "generated_by": "scripts/bench.sh",'
    echo "  \"host_parallelism\": $(nproc 2>/dev/null || echo 1),"
    echo '  "results": ['
    awk 'NR > 1 { print prev "," } { prev = "    " $0 } END { if (NR > 0) print prev }' "$1"
    echo '  ]'
    echo '}'
  } > "$2"
  echo "wrote $2 ($(grep -c '"name"' "$2") results)"
}

export DECO_BENCH_JSON="$jsonl"
for target in bench_compress bench_deco bench_timesim bench_runtime bench_pipeline; do
  echo "### cargo bench --bench $target"
  cargo bench --bench "$target"
done
consolidate "$jsonl" BENCH_pipeline.json

echo "### cargo bench --bench bench_fabric"
DECO_BENCH_JSON="$fab_jsonl" cargo bench --bench bench_fabric
consolidate "$fab_jsonl" BENCH_fabric.json

echo "### cargo bench --bench bench_elastic"
DECO_BENCH_JSON="$ela_jsonl" cargo bench --bench bench_elastic
consolidate "$ela_jsonl" BENCH_elastic.json

echo "### cargo bench --bench bench_topo"
DECO_BENCH_JSON="$topo_jsonl" cargo bench --bench bench_topo
consolidate "$topo_jsonl" BENCH_topo.json

echo "### cargo bench --bench bench_trace"
DECO_BENCH_JSON="$trace_jsonl" cargo bench --bench bench_trace
consolidate "$trace_jsonl" BENCH_trace.json

echo "### cargo bench --bench bench_bond"
DECO_BENCH_JSON="$bond_jsonl" cargo bench --bench bench_bond
consolidate "$bond_jsonl" BENCH_bond.json

echo "### cargo bench --bench bench_scale"
DECO_BENCH_JSON="$scale_jsonl" cargo bench --bench bench_scale
consolidate "$scale_jsonl" BENCH_scale.json

echo "### cargo bench --bench bench_obs"
DECO_BENCH_JSON="$obs_jsonl" cargo bench --bench bench_obs
consolidate "$obs_jsonl" BENCH_obs.json

echo "### cargo bench --bench bench_audit"
DECO_BENCH_JSON="$audit_jsonl" cargo bench --bench bench_audit
consolidate "$audit_jsonl" BENCH_audit.json

echo "### cargo bench --bench bench_lossy"
DECO_BENCH_JSON="$lossy_jsonl" cargo bench --bench bench_lossy
consolidate "$lossy_jsonl" BENCH_lossy.json
