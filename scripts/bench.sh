#!/usr/bin/env bash
# Run the five bench_* targets and consolidate one machine-readable
# BENCH_pipeline.json at the repo root (ns/iter + bytes/s per shape) so
# future PRs have a perf trajectory to compare against.
#
#   scripts/bench.sh                # fast mode (default; CI-sized)
#   DECO_BENCH_FAST=0 scripts/bench.sh   # full measurement windows
set -euo pipefail
cd "$(dirname "$0")/.."

: "${DECO_BENCH_FAST:=1}"
if [ "$DECO_BENCH_FAST" = "0" ]; then
  unset DECO_BENCH_FAST
else
  export DECO_BENCH_FAST
fi

jsonl="$(mktemp)"
trap 'rm -f "$jsonl"' EXIT
export DECO_BENCH_JSON="$jsonl"

for target in bench_compress bench_deco bench_timesim bench_runtime bench_pipeline; do
  echo "### cargo bench --bench $target"
  cargo bench --bench "$target"
done

{
  echo '{'
  echo '  "generated_by": "scripts/bench.sh",'
  echo "  \"host_parallelism\": $(nproc 2>/dev/null || echo 1),"
  echo '  "results": ['
  awk 'NR > 1 { print prev "," } { prev = "    " $0 } END { if (NR > 0) print prev }' "$jsonl"
  echo '  ]'
  echo '}'
} > BENCH_pipeline.json

echo "wrote BENCH_pipeline.json ($(grep -c '"name"' BENCH_pipeline.json) results)"
