//! Degradation-condition tests (Remark 2): the DD-EF-SGD pipeline with
//! (δ=1, τ=0) must reproduce plain D-SGD state-for-state; (δ=1, τ>0) is
//! DD-SGD; (δ<1, τ=0) is D-EF-SGD — checked against hand-rolled reference
//! loops on the quadratic oracle.

use deco::compress::{ErrorFeedback, Identity, TopK};
use deco::config::{ExperimentConfig, NetworkConfig, StopConfig};
use deco::coordinator::TrainLoop;
use deco::netsim::TraceKind;
use deco::optim::{GradOracle, Quadratic};
use deco::strategy::StrategyKind;
use deco::util::Rng;
use std::collections::VecDeque;

fn oracle() -> Quadratic {
    Quadratic::new(128, 3, 1.0, 0.2, 0.4, 0.3, 77)
}

fn net() -> NetworkConfig {
    NetworkConfig::homogeneous(TraceKind::Constant { bps: 1e8 }, 0.1)
}

fn cfg(strategy: StrategyKind, iters: usize) -> ExperimentConfig {
    ExperimentConfig {
        task: "quadratic".into(),
        workers: 3,
        gamma: 0.05,
        strategy,
        network: net(),
        stop: StopConfig {
            max_iters: iters,
            loss_target: None,
            max_virtual_time: None,
        },
        seed: 77,
        t_comp: Some(0.05),
        s_g_bits: Some(128.0 * 32.0),
        log_every: iters, // only final record
        block_topk: false,
        clip_norm: None,
        churn: deco::elastic::ChurnSpec::None,
        drain: deco::elastic::DrainPolicy::Drop,
    }
}

/// Reference DD-EF-SGD with explicit state, mirroring the paper's Algo 2.
fn reference_run(delta: f64, tau: usize, iters: usize) -> Vec<f32> {
    let oracle = oracle();
    let n = oracle.workers();
    let dim = oracle.dim();
    let mut x = oracle.init();
    let mut g = vec![0.0f32; dim];
    let mut efs: Vec<ErrorFeedback> =
        (0..n).map(|_| ErrorFeedback::new(dim)).collect();
    let mut queues: Vec<VecDeque<Vec<f32>>> =
        (0..n).map(|_| VecDeque::new()).collect();
    // NOTE: must mirror WorkerState's RNG derivation for bit-equality with
    // randomized compressors; Identity/TopK are deterministic so any rng
    // works here.
    let mut rng = Rng::new(1);
    for t in 1..=iters {
        for w in 0..n {
            oracle.grad(w, t, &x, &mut g);
            queues[w].push_back(g.clone());
        }
        let mut agg = vec![0.0f32; dim];
        let mut any = false;
        // match the pipeline's aggregation arithmetic exactly:
        // `agg += (1/n) * v` (scale-then-multiply, not divide)
        let scale = 1.0 / n as f32;
        for w in 0..n {
            if queues[w].len() > tau {
                let mut old = queues[w].pop_front().unwrap();
                if delta >= 1.0 {
                    efs[w].step(&mut old, &Identity, &mut rng);
                } else {
                    efs[w].step(&mut old, &TopK::new(delta), &mut rng);
                }
                for (a, v) in agg.iter_mut().zip(&old) {
                    *a += scale * *v;
                }
                any = true;
            }
        }
        if any {
            for (xi, ai) in x.iter_mut().zip(&agg) {
                *xi -= 0.05 * ai;
            }
        }
    }
    x
}

fn pipeline_run(strategy: StrategyKind, iters: usize) -> Vec<f32> {
    let c = cfg(strategy, iters);
    let params = c.train_params(128);
    let mut tl =
        TrainLoop::new(oracle(), c.strategy.build(), c.network.link(), params);
    tl.run("quad");
    tl.model().to_vec()
}

#[test]
fn dsgd_degradation_state_for_state() {
    let got = pipeline_run(StrategyKind::DSgd, 40);
    let want = reference_run(1.0, 0, 40);
    assert_eq!(got, want, "D-SGD (δ=1, τ=0) trajectory mismatch");
}

#[test]
fn ddsgd_degradation_state_for_state() {
    let got = pipeline_run(StrategyKind::DdSgd { tau: 3 }, 40);
    let want = reference_run(1.0, 3, 40);
    assert_eq!(got, want, "DD-SGD (δ=1, τ=3) trajectory mismatch");
}

#[test]
fn defsgd_degradation_state_for_state() {
    let got = pipeline_run(StrategyKind::DEfSgd { delta: 0.1 }, 40);
    let want = reference_run(0.1, 0, 40);
    assert_eq!(got, want, "D-EF-SGD (δ=0.1, τ=0) trajectory mismatch");
}

#[test]
fn delayed_pipeline_takes_tau_extra_iters() {
    // DD variants apply nothing for the first τ iterations: after exactly
    // τ+1 iterations, x must have moved once
    for tau in [0usize, 2, 5] {
        let got = pipeline_run(StrategyKind::DdSgd { tau }, tau + 1);
        let init = oracle().init();
        assert_ne!(got, init, "tau={tau}: no update after {} iters", tau + 1);
        if tau > 0 {
            let frozen = pipeline_run(StrategyKind::DdSgd { tau }, tau);
            assert_eq!(frozen, init, "tau={tau}: updated too early");
        }
    }
}
