//! Plan-audit integration tests (DESIGN.md §Observability → Audit):
//!
//! * tiling — on real traced runs, plan windows exactly tile
//!   `[first_replan, makespan]`: bitwise-contiguous boundaries, realized
//!   time summing to the clock's total (±1e-9 relative);
//! * exactness — a constant-trace DeCo run has ≈0 plan bias and ≈0
//!   hindsight-oracle regret (the closed form is exact there, and the
//!   noiseless monitor estimates are perfectly calibrated);
//! * sensitivity — an OU-trace run shows nonzero bias and positive
//!   cumulative regret (the instantaneous estimate is wrong about the
//!   window it governs);
//! * equivalence — the O(1) streaming fold matches the buffered audit
//!   bit-for-bit, and the audit-annotated Perfetto export is
//!   byte-identical across pool sizes.

use deco::coordinator::{TrainLoop, TrainParams};
use deco::deco::DecoInput;
use deco::metrics::sink::BufferSink;
use deco::metrics::RunResult;
use deco::netsim::{BandwidthTrace, Fabric, TraceKind};
use deco::obs::{
    audit_events, perfetto_audit_string, BufferTracer, PlanAudit, TraceEvent,
    TraceSink,
};
use deco::optim::Quadratic;
use deco::strategy::StrategyKind;
use deco::topo::Topology;

const S_G: f64 = 1e8;
const T_COMP: f64 = 0.2;

fn params(max_iters: usize) -> TrainParams {
    TrainParams {
        gamma: 0.005,
        max_iters,
        log_every: 10,
        t_comp_override: Some(T_COMP),
        s_g_override: Some(S_G),
        fallback: DecoInput { s_g: S_G, a: 2e7, b: 0.2, t_comp: T_COMP },
        seed: 11,
        ..Default::default()
    }
}

fn quad() -> Quadratic {
    Quadratic::new(256, 4, 1.0, 0.2, 0.3, 0.3, 11)
}

fn constant_fabric() -> Fabric {
    Fabric::homogeneous(4, BandwidthTrace::constant(2e7), 0.2)
}

fn ou_fabric() -> Fabric {
    Fabric::homogeneous(
        4,
        BandwidthTrace::new(TraceKind::Ou {
            mean_bps: 2e7,
            sigma_bps: 8e6,
            theta: 0.2,
            seed: 3,
        }),
        0.2,
    )
}

/// Traced DeCo run; returns the consumed loop (for its ground-truth
/// fabric), the result, and the event buffer.
fn run_traced(
    fabric: Fabric,
    update_every: usize,
    iters: usize,
    threads: usize,
) -> (TrainLoop<Quadratic>, RunResult, Vec<TraceEvent>) {
    let mut p = params(iters);
    p.threads = Some(threads);
    let mut tl = TrainLoop::try_with_topology(
        quad(),
        StrategyKind::DecoSgd { update_every }.build(),
        fabric,
        Topology::Flat,
        p,
    )
    .unwrap();
    let mut sink = BufferSink::new();
    let mut tracer = BufferTracer::new();
    let mut res = tl.run_traced("audit", &mut sink, &mut tracer).unwrap();
    res.records = sink.into_records();
    (tl, res, tracer.into_events())
}

/// Property: plan windows tile `[first_replan, makespan]` — boundaries
/// are bitwise-contiguous and realized time sums to the clock's total.
fn assert_windows_tile(events: &[TraceEvent], res: &RunResult) {
    let audit = PlanAudit::buffered(events);
    let ws = audit.windows();
    assert!(ws.len() >= 2, "need several plan windows, got {}", ws.len());
    for pair in ws.windows(2) {
        assert_eq!(
            pair[0].t_end.to_bits(),
            pair[1].t_start.to_bits(),
            "windows {} and {} must share a boundary bitwise",
            pair[0].index,
            pair[1].index
        );
    }
    let s = audit.summary();
    assert_eq!(s.first_t, 0.0, "the first re-plan fires at t=0");
    assert!(
        (s.last_t - res.total_time).abs() <= 1e-9 * res.total_time,
        "last window closes at {} vs makespan {}",
        s.last_t,
        res.total_time
    );
    let realized_sum: f64 = ws.iter().map(|w| w.t_end - w.t_start).sum();
    let span = s.last_t - s.first_t;
    assert!(
        (realized_sum - span).abs() <= 1e-9 * span,
        "realized sum {realized_sum} vs audited span {span}"
    );
    assert!(
        (s.real_time - span).abs() <= 1e-9 * span,
        "summary real_time {} vs audited span {span}",
        s.real_time
    );
    let iters: usize = ws.iter().map(|w| w.iters).sum();
    assert_eq!(iters, res.total_iters, "every tick belongs to one window");
}

#[test]
fn windows_tile_the_run_on_constant_and_ou_traces() {
    let (_, res, events) = run_traced(constant_fabric(), 20, 60, 1);
    assert_windows_tile(&events, &res);
    let (_, res, events) = run_traced(ou_fabric(), 15, 90, 1);
    assert_windows_tile(&events, &res);
}

#[test]
fn constant_trace_has_near_zero_bias_and_regret() {
    let (tl, _, events) = run_traced(constant_fabric(), 20, 60, 1);
    let report = audit_events(&events, tl.fabric());
    let s = &report.summary;
    assert!(s.windows >= 2);
    // steady-state windows are exact: the solver's closed form equals
    // the realized round bit-for-bit once the pipeline is filled
    for w in &report.windows[1..] {
        assert!(
            w.bias().abs() <= 1e-6 * w.realized(),
            "window {} bias {} on a constant trace",
            w.index,
            w.bias()
        );
    }
    // only window 0 carries the pipeline-fill transient (b + tx once)
    assert!(
        s.bias().abs() <= 0.05 * s.mean_realized(),
        "run-level bias {} vs realized {}",
        s.bias(),
        s.mean_realized()
    );
    // the executed plan IS the hindsight oracle here
    assert!(
        report.regret.cumulative >= -1e-6,
        "regret can't be meaningfully negative: {}",
        report.regret.cumulative
    );
    assert!(
        report.regret.cumulative <= 0.05 * s.real_time,
        "cumulative regret {} vs realized {}",
        report.regret.cumulative,
        s.real_time
    );
    // noiseless estimates on a constant trace are perfectly calibrated
    // homogeneous noiseless workers share one timeline class, hence one
    // estimator slot — the calibration reports at class granularity
    let cal = &report.calibration;
    assert!(cal.all.samples > 0, "calibration needs estimator snapshots");
    assert_eq!(cal.links.len(), 1, "one row per estimator slot");
    for row in cal.links.iter().chain(std::iter::once(&cal.all)) {
        assert!(
            row.bias.abs() <= 1e-6 * row.mean_true,
            "link {} bias {}",
            row.worker,
            row.bias
        );
        assert_eq!(row.coverage, 1.0);
        assert_eq!(row.band_coverage, 1.0);
        assert!(row.lat_bias.abs() <= 1e-9);
    }
}

#[test]
fn ou_trace_shows_bias_and_positive_regret() {
    let (tl, _, events) = run_traced(ou_fabric(), 15, 90, 1);
    let report = audit_events(&events, tl.fabric());
    let s = &report.summary;
    assert!(s.windows >= 3);
    assert!(
        s.bias().abs() > 1e-6,
        "an OU trace must show nonzero plan bias, got {}",
        s.bias()
    );
    assert!(s.rmse() > 0.0);
    assert!(
        report.regret.cumulative > 0.0,
        "hindsight regret must be positive under a moving trace, got {}",
        report.regret.cumulative
    );
    // the estimator is wrong about the window ahead — nonzero RMSE
    assert!(report.calibration.all.samples > 0);
    assert!(report.calibration.all.rmse > 0.0);
}

#[test]
fn streaming_fold_matches_buffered_audit_bitwise() {
    for (fabric, e, n) in
        [(constant_fabric(), 20, 60), (ou_fabric(), 15, 90)]
    {
        let (_, _, events) = run_traced(fabric, e, n, 1);
        let buffered = PlanAudit::buffered(&events);
        let mut streaming = PlanAudit::streaming();
        for ev in &events {
            streaming.record(ev);
        }
        streaming.finish();
        assert_eq!(streaming.summary(), buffered.summary());
        let (a, b) = (streaming.summary(), buffered.summary());
        for (x, y) in [
            (a.pred_time, b.pred_time),
            (a.real_time, b.real_time),
            (a.bias_sq_sum, b.bias_sq_sum),
            (a.worst_bias, b.worst_bias),
        ] {
            assert_eq!(x.to_bits(), y.to_bits(), "fold fields must be bitwise");
        }
    }
}

#[test]
fn audit_perfetto_export_is_identical_across_pool_sizes() {
    let (tl, _, serial) = run_traced(ou_fabric(), 15, 60, 1);
    let (_, _, pooled) = run_traced(ou_fabric(), 15, 60, 4);
    let a = perfetto_audit_string(&serial, tl.fabric());
    let b = perfetto_audit_string(&pooled, tl.fabric());
    assert!(!a.is_empty());
    assert_eq!(a, b, "audit trace bytes must not depend on the pool size");
    assert!(
        a.contains("round s/iter") && a.contains("bandwidth Mbps"),
        "audit export must carry the counter tracks"
    );
    // counter samples live on the control-plane process
    assert!(a.contains("\"ph\":\"C\""), "counter events must be present");
}

#[test]
fn audit_report_renderings_are_deterministic() {
    let (tl, _, events) = run_traced(ou_fabric(), 15, 60, 1);
    let x = audit_events(&events, tl.fabric());
    let y = audit_events(&events, tl.fabric());
    assert_eq!(x.csv(), y.csv());
    assert_eq!(x.table(), y.table());
    assert_eq!(x.json().to_string(), y.json().to_string());
    assert!(x.csv().starts_with("window,iter_first,iters,"));
    // one CSV row per window plus the header
    assert_eq!(x.csv().lines().count(), x.windows.len() + 1);
}
