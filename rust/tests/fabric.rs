//! Fabric-substrate regression tests (DESIGN.md §Network-Fabric):
//!
//! * determinism contract — a homogeneous fabric prices bit-identically to
//!   the pre-refactor single shared `Link`, across serial and pooled
//!   execution (final model, every record, virtual-clock totals), checked
//!   both against the compatibility constructor and against an inline
//!   replay of the legacy single-link Eq. 19 recurrence;
//! * the headline heterogeneity claim — under a straggler, DeCo planning
//!   on the monitored bottleneck (a, b) reaches the loss target sooner
//!   than the same controller planning on the mean link.

use deco::coordinator::{TrainLoop, TrainParams};
use deco::deco::solve::DecoInput;
use deco::metrics::RunResult;
use deco::netsim::{BandwidthTrace, Fabric, Link};
use deco::optim::{GradOracle, Quadratic};
use deco::strategy::{PlanBasis, StrategyKind};

const S_G: f64 = 1e8;
const T_COMP: f64 = 0.05;

fn params(max_iters: usize) -> TrainParams {
    TrainParams {
        gamma: 0.005,
        max_iters,
        log_every: 10,
        t_comp_override: Some(T_COMP),
        s_g_override: Some(S_G),
        fallback: DecoInput { s_g: S_G, a: 2e7, b: 0.2, t_comp: T_COMP },
        seed: 11,
        ..Default::default()
    }
}

fn quad(dim: usize) -> Quadratic {
    Quadratic::new(dim, 4, 1.0, 0.2, 0.3, 0.3, 11)
}

fn run_fabric(
    fabric: Fabric,
    kind: StrategyKind,
    mut p: TrainParams,
    dim: usize,
    threads: usize,
) -> (Vec<f32>, RunResult) {
    p.threads = Some(threads);
    let mut tl = TrainLoop::with_fabric(quad(dim), kind.build(), fabric, p);
    let res = tl.run("fabric");
    (tl.model().to_vec(), res)
}

/// The pre-refactor virtual clock: ONE shared link, the scalar Eq. 19
/// recurrence. Static (τ, δ) so the wire bits are constant.
fn legacy_single_link_total(
    link: &Link,
    t_comp: f64,
    tau: usize,
    bits: u64,
    iters: usize,
) -> f64 {
    let (mut ts_prev, mut tm_prev) = (0.0f64, 0.0f64);
    let mut tc: Vec<f64> = Vec::new();
    for k in 1..=iters {
        let tc_delayed = if k as i64 - 1 - tau as i64 >= 1 {
            tc[k - 2 - tau]
        } else {
            0.0
        };
        let ts = t_comp + tc_delayed.max(ts_prev);
        let start = tm_prev.max(ts);
        let tm = link.transfer_end(start, bits);
        ts_prev = ts;
        tm_prev = tm;
        tc.push(tm + link.latency());
    }
    *tc.last().unwrap()
}

#[test]
fn homogeneous_fabric_matches_legacy_recurrence_bitwise() {
    // static strategies => constant wire bits, so the legacy single-link
    // replay must reproduce the fabric clock's total time bit-for-bit
    let link = Link::new(BandwidthTrace::constant(2e7), 0.2);
    let cases: Vec<(StrategyKind, usize, u64)> = vec![
        // (strategy, tau, bits = (delta.min(1)*S_G) as u64)
        (StrategyKind::DEfSgd { delta: 0.1 }, 0, (0.1 * S_G) as u64),
        (StrategyKind::DdSgd { tau: 3 }, 3, S_G as u64),
        (StrategyKind::DSgd, 0, S_G as u64),
    ];
    for (kind, tau, bits) in cases {
        let iters = 60;
        let (_, res) = run_fabric(
            Fabric::homogeneous(4, BandwidthTrace::constant(2e7), 0.2),
            kind.clone(),
            params(iters),
            256,
            1,
        );
        assert_eq!(res.total_iters, iters, "{kind:?} stopped early");
        let legacy = legacy_single_link_total(&link, T_COMP, tau, bits, iters);
        assert_eq!(
            res.total_time.to_bits(),
            legacy.to_bits(),
            "{kind:?}: fabric clock {} != legacy single-link {legacy}",
            res.total_time
        );
    }
}

#[test]
fn homogeneous_fabric_equals_single_link_constructor() {
    // TrainLoop::new(link) (the compatibility path) and an explicitly built
    // homogeneous fabric must agree bit-for-bit — serial AND pooled
    // (dim 65_536 crosses both parallel-engine thresholds)
    let dim = 65_536;
    let p = TrainParams { max_iters: 30, ..params(30) };
    let kind = StrategyKind::DecoSgd { update_every: 10 };
    let link = Link::new(BandwidthTrace::constant(2e7), 0.2);
    let mut base = TrainLoop::new(
        quad(dim),
        kind.build(),
        link.clone(),
        TrainParams { threads: Some(1), ..p.clone() },
    );
    let base_res = base.run("fabric");
    let base_model = base.model().to_vec();
    assert!(base_res.final_loss().is_finite());
    for threads in [1usize, 4] {
        let (model, res) = run_fabric(
            Fabric::homogeneous(4, BandwidthTrace::constant(2e7), 0.2),
            kind.clone(),
            p.clone(),
            dim,
            threads,
        );
        assert_eq!(model, base_model, "model diverges at {threads} threads");
        assert_eq!(res.records, base_res.records, "{threads} threads");
        assert_eq!(
            res.total_time.to_bits(),
            base_res.total_time.to_bits(),
            "virtual clock diverges at {threads} threads"
        );
    }
}

#[test]
fn straggler_fabric_prices_slower_than_homogeneous() {
    let homo = run_fabric(
        Fabric::homogeneous(4, BandwidthTrace::constant(2e7), 0.2),
        StrategyKind::DEfSgd { delta: 0.1 },
        params(50),
        256,
        1,
    )
    .1;
    let strag = run_fabric(
        Fabric::with_straggler(4, BandwidthTrace::constant(2e7), 0.2, 0.25, 2.0),
        StrategyKind::DEfSgd { delta: 0.1 },
        params(50),
        256,
        1,
    )
    .1;
    assert_eq!(homo.total_iters, strag.total_iters);
    assert!(
        strag.total_time > homo.total_time,
        "straggler {} should cost more than homogeneous {}",
        strag.total_time,
        homo.total_time
    );
}

#[test]
fn bottleneck_planning_beats_mean_link_under_latency_straggler() {
    // latency-dominated straggler: same bandwidth everywhere, worker 0 at
    // 9x the latency. Both planners settle on delta = 1 (bandwidth is
    // plentiful for the 1 Mbit gradient), so the runs differ ONLY through
    // tau: the bottleneck planner covers the straggler's 0.9 s round trip
    // (tau = 5, bubble-free at T_comp), the mean-link planner plans for
    // 0.3 s (tau = 2) and stalls on the delayed aggregation every
    // iteration.
    let fabric = || {
        Fabric::with_straggler(4, BandwidthTrace::constant(1e8), 0.1, 1.0, 9.0)
    };
    let p = TrainParams {
        gamma: 0.005,
        max_iters: 2000,
        log_every: 25,
        t_comp_override: Some(0.2),
        s_g_override: Some(1e6),
        fallback: DecoInput { s_g: 1e6, a: 1e8, b: 0.1, t_comp: 0.2 },
        seed: 11,
        ..Default::default()
    };
    let kind = StrategyKind::DecoSgd { update_every: 10 };
    let oracle = quad(256);
    let target = 0.6 * oracle.loss(&oracle.init());
    let run = |plan: PlanBasis| {
        let mut tl = TrainLoop::with_fabric(
            quad(256),
            kind.build(),
            fabric(),
            TrainParams { plan, loss_target: Some(target), ..p.clone() },
        );
        tl.run("hetero")
    };
    let bot = run(PlanBasis::Bottleneck);
    let mean = run(PlanBasis::MeanLink);
    let tb = bot.time_to_loss(target).expect("bottleneck plan reaches");
    let tm = mean.time_to_loss(target).expect("mean plan reaches");
    assert!(
        tb < 0.95 * tm,
        "bottleneck-aware {tb:.1}s should clearly beat mean-link {tm:.1}s"
    );
}
