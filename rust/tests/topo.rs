//! Topology-subsystem regression tests (DESIGN.md §Topology):
//!
//! * determinism contract — a `Topology::Flat` run is bit-identical to the
//!   fabric-only path (serial AND pooled), and a two-tier run is
//!   bit-identical across pool sizes;
//! * two-tier pricing — the global sync arrival is gated by the slowest
//!   region partial, and hierarchical aggregation beats the flat
//!   shared-egress star on a scarce WAN;
//! * elastic composition — a departing aggregator triggers re-election +
//!   an epoch bump, and the run keeps converging;
//! * config validation — two-tier specs require a regions fabric and
//!   reject empty groups.

use deco::config::{
    FabricSpec, NetworkConfig, RegionSpec, TopologySpec,
};
use deco::coordinator::{TrainLoop, TrainParams};
use deco::deco::DecoInput;
use deco::elastic::{ChurnEvent, ChurnSpec, TimedEvent};
use deco::metrics::RunResult;
use deco::netsim::{BandwidthTrace, Fabric, TraceKind};
use deco::optim::Quadratic;
use deco::strategy::StrategyKind;
use deco::topo::{RegionTopo, Topology};

const S_G: f64 = 1e8;
const T_COMP: f64 = 0.2;

fn params(max_iters: usize) -> TrainParams {
    TrainParams {
        gamma: 0.005,
        max_iters,
        log_every: 10,
        t_comp_override: Some(T_COMP),
        s_g_override: Some(S_G),
        fallback: DecoInput { s_g: S_G, a: 2e7, b: 0.2, t_comp: T_COMP },
        seed: 11,
        ..Default::default()
    }
}

fn quad(dim: usize) -> Quadratic {
    Quadratic::new(dim, 4, 1.0, 0.2, 0.3, 0.3, 11)
}

fn lan_fabric() -> Fabric {
    Fabric::homogeneous(4, BandwidthTrace::constant(1e9), 0.005)
}

fn two_tier() -> Topology {
    Topology::TwoTier {
        regions: vec![
            RegionTopo::new(vec![0, 1], 0),
            RegionTopo::new(vec![2, 3], 2),
        ],
        wan: Fabric::homogeneous(2, BandwidthTrace::constant(2e7), 0.3),
    }
}

fn run_topo(
    fabric: Fabric,
    topo: Topology,
    kind: StrategyKind,
    mut p: TrainParams,
    dim: usize,
    threads: usize,
) -> (Vec<f32>, RunResult) {
    p.threads = Some(threads);
    let mut tl =
        TrainLoop::try_with_topology(quad(dim), kind.build(), fabric, topo, p)
            .unwrap();
    let res = tl.run("topo");
    (tl.model().to_vec(), res)
}

fn assert_bit_identical(a: &(Vec<f32>, RunResult), b: &(Vec<f32>, RunResult)) {
    assert_eq!(a.0.len(), b.0.len());
    for (i, (xa, xb)) in a.0.iter().zip(&b.0).enumerate() {
        assert_eq!(xa.to_bits(), xb.to_bits(), "model diverges at {i}");
    }
    assert_eq!(a.1.total_iters, b.1.total_iters);
    assert_eq!(a.1.total_time.to_bits(), b.1.total_time.to_bits());
    assert_eq!(a.1.records.len(), b.1.records.len());
    for (ra, rb) in a.1.records.iter().zip(&b.1.records) {
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "iter {}", ra.iter);
        assert_eq!(ra.time.to_bits(), rb.time.to_bits(), "iter {}", ra.iter);
        assert_eq!(ra.regions, rb.regions, "iter {}", ra.iter);
    }
}

/// The heterogeneous fabric the flat bit-identity contract runs on: a
/// straggler defeats the clock's uniform fast path so the general loop is
/// what's being compared.
fn straggler_fabric() -> Fabric {
    Fabric::with_straggler(4, BandwidthTrace::constant(1e8), 0.1, 0.5, 2.0)
}

#[test]
fn flat_topology_is_bit_identical_to_fabric_path() {
    for threads in [1usize, 4] {
        let mut p = params(600);
        p.threads = Some(threads);
        let mut fabric_only = TrainLoop::try_with_fabric(
            quad(1024),
            StrategyKind::DecoSgd { update_every: 20 }.build(),
            straggler_fabric(),
            p.clone(),
        )
        .unwrap();
        let res_fabric = fabric_only.run("topo");
        let topo = run_topo(
            straggler_fabric(),
            Topology::Flat,
            StrategyKind::DecoSgd { update_every: 20 },
            p,
            1024,
            threads,
        );
        assert_bit_identical(
            &(fabric_only.model().to_vec(), res_fabric),
            &topo,
        );
        // flat records carry no region columns
        assert!(topo.1.records.iter().all(|r| r.regions.is_empty()));
    }
}

#[test]
fn two_tier_run_is_bit_identical_across_pool_sizes() {
    let serial = run_topo(
        lan_fabric(),
        two_tier(),
        StrategyKind::DecoTwoTier { update_every: 20 },
        params(600),
        4096,
        1,
    );
    let pooled = run_topo(
        lan_fabric(),
        two_tier(),
        StrategyKind::DecoTwoTier { update_every: 20 },
        params(600),
        4096,
        4,
    );
    assert_bit_identical(&serial, &pooled);
    // and every record carries both regions' columns
    assert!(serial.1.records.iter().all(|r| r.regions.len() == 2));
}

#[test]
fn tier_blind_strategies_run_two_tier_with_uncompressed_wan() {
    // a legacy strategy on a two-tier topology ships uncompressed partials:
    // the run must still complete, converge, and log wan_delta = 1
    let (_, res) = run_topo(
        lan_fabric(),
        two_tier(),
        StrategyKind::DSgd,
        params(400),
        512,
        1,
    );
    assert_eq!(res.total_iters, 400);
    assert!(res.records.iter().all(|r| r.wan_delta == 1.0));
    let l0 = {
        let q = quad(512);
        let x = deco::optim::GradOracle::init(&q);
        deco::optim::GradOracle::loss(&q, &x)
    };
    assert!(res.final_loss() < l0, "{l0} -> {}", res.final_loss());
}

#[test]
fn departing_aggregator_reelects_and_bumps_epoch() {
    // worker 0 (region 0's aggregator) leaves at t=30 s: region 0 must
    // hand the role to worker 1, bump the membership epoch, and keep
    // pricing; the rejoin at t=90 s keeps worker 1 in the role (the
    // incumbent is still active — re-election only fires when the
    // aggregator itself is gone, so roles stay stable across rejoins)
    let spec = ChurnSpec::Scripted {
        events: vec![
            TimedEvent { t: 30.0, event: ChurnEvent::Leave { worker: 0 } },
            TimedEvent { t: 90.0, event: ChurnEvent::Rejoin { worker: 0 } },
        ],
    };
    let mut p = params(1500);
    p.churn = spec;
    p.threads = Some(1);
    let mut tl = TrainLoop::try_with_topology(
        quad(512),
        StrategyKind::DecoTwoTier { update_every: 400 }.build(),
        lan_fabric(),
        two_tier(),
        p,
    )
    .unwrap();
    assert_eq!(tl.clock().regions()[0].aggregator, 0);
    let res = tl.run("topo");
    assert_eq!(res.total_iters, 1500);
    // the role moved to worker 1 and stayed there; the epoch counted
    // leave, re-election, and rejoin
    assert_eq!(tl.clock().regions()[0].aggregator, 1);
    assert_eq!(tl.membership().epoch(), 3);
    // region 0 kept pricing throughout (its sync never froze at 0 while
    // worker 1 carried the region alone)
    assert!(res.records.iter().all(|r| r.regions[0].sync > 0.0));
}

#[test]
fn draining_region_empties_then_prices_inactive() {
    // drain × topology composition: both members of region 0 leave under
    // DrainPolicy::Drain while holding in-flight gradients (τ = 2). Their
    // flushes must keep flowing through a *present* aggregator (if the
    // incumbent fully departs first, the role falls back to a draining
    // member), and once the region is empty it prices as inactive —
    // frozen WAN timeline, sync 0 — while region 1 keeps running.
    let spec = ChurnSpec::Scripted {
        events: vec![
            TimedEvent { t: 30.0, event: ChurnEvent::Leave { worker: 1 } },
            TimedEvent { t: 36.0, event: ChurnEvent::Leave { worker: 0 } },
        ],
    };
    let mut p = params(100);
    p.churn = spec;
    p.drain = deco::elastic::DrainPolicy::Drain;
    p.log_every = 5;
    p.threads = Some(1);
    let mut tl = TrainLoop::try_with_topology(
        quad(256),
        StrategyKind::DdSgd { tau: 2 }.build(),
        lan_fabric(),
        two_tier(),
        p,
    )
    .unwrap();
    let res = tl.run("topo");
    assert_eq!(res.total_iters, 100, "run survives the region emptying");
    // both leaves (and any drain completions / re-elections) moved the
    // epoch at least twice
    assert!(tl.membership().epoch() >= 2);
    let last = res.records.last().unwrap();
    assert_eq!(last.regions[0].sync, 0.0, "empty region prices inactive");
    assert!(last.regions[1].sync > 0.0, "region 1 keeps running");
    // region 0's WAN traffic froze once it emptied
    let prev = &res.records[res.records.len() - 2];
    assert_eq!(prev.regions[0].wan_bits, last.regions[0].wan_bits);
    assert!(last.regions[1].wan_bits > prev.regions[1].wan_bits);
}

#[test]
fn two_tier_beats_flat_star_on_scarce_wan() {
    // integration form of the exp topo headline at one sweep point
    let flat = deco::exp::topo::run_one(
        2,
        0.1,
        deco::exp::topo::TopoArm::FlatDeco,
        4,
        512,
        6000,
    )
    .unwrap();
    let two = deco::exp::topo::run_one(
        2,
        0.1,
        deco::exp::topo::TopoArm::TwoTierDeco,
        4,
        512,
        6000,
    )
    .unwrap();
    let tf = flat.time_to_loss(0.18).expect("flat reaches");
    let tt = two.time_to_loss(0.18).expect("two-tier reaches");
    assert!(tt < tf, "two-tier {tt:.1}s !< flat {tf:.1}s");
}

#[test]
fn topo_sweep_is_deterministic() {
    let (csv_a, _) = deco::exp::topo::sweep(0.02, 4, 128).unwrap();
    let (csv_b, _) = deco::exp::topo::sweep(0.02, 4, 128).unwrap();
    assert_eq!(csv_a, csv_b, "byte-identical CSV across sweeps");
}

#[test]
fn invalid_topologies_error_not_panic() {
    // two-tier spec over a non-regions fabric
    let mut net = NetworkConfig::homogeneous(
        TraceKind::Constant { bps: 1e8 },
        0.1,
    );
    net.topology = TopologySpec::TwoTier {
        wan_trace: TraceKind::Constant { bps: 2e7 },
        wan_latency_s: 0.3,
        region_wan: Vec::new(),
    };
    let fabric = net.build_fabric(4).unwrap();
    assert!(net.build_topology(4, &fabric).is_err());

    // empty regions group is rejected before election can panic
    net.fabric = FabricSpec::Regions {
        groups: vec![
            RegionSpec {
                workers: 0,
                trace: TraceKind::Constant { bps: 1e8 },
                latency_s: 0.05,
            },
            RegionSpec {
                workers: 4,
                trace: TraceKind::Constant { bps: 1e8 },
                latency_s: 0.05,
            },
        ],
    };
    assert!(net.build_fabric(4).is_err());

    // a topology that doesn't partition the workers errors at construction
    let bad = Topology::TwoTier {
        regions: vec![RegionTopo::new(vec![0, 1], 0)],
        wan: Fabric::homogeneous(1, BandwidthTrace::constant(2e7), 0.3),
    };
    assert!(TrainLoop::try_with_topology(
        quad(64),
        StrategyKind::DSgd.build(),
        lan_fabric(),
        bad,
        params(10),
    )
    .is_err());
}
