//! Elastic-subsystem regression tests (DESIGN.md §Elasticity):
//!
//! * determinism contract — a `ChurnSpec::none()` run is bit-identical to
//!   a fabric-only run (serial AND pooled), and still matches the
//!   pre-fabric scalar Eq. 19 replay on a homogeneous fabric; a fixed seed
//!   compiles the identical event timeline and produces byte-identical
//!   `results/churn.csv` content across two sweeps;
//! * membership pricing — a departed straggler stops gating the virtual
//!   clock, a rejoin warm-resumes it;
//! * drain-vs-drop policy — `Drain` flushes the in-flight delayed
//!   gradients into the model, `Drop` freezes them, both deterministically.

use deco::coordinator::{TrainLoop, TrainParams};
use deco::deco::solve::DecoInput;
use deco::elastic::{ChurnEvent, ChurnSpec, DrainPolicy, TimedEvent};
use deco::metrics::RunResult;
use deco::netsim::{BandwidthTrace, Fabric, Link};
use deco::optim::Quadratic;
use deco::strategy::StrategyKind;

const S_G: f64 = 1e8;
const T_COMP: f64 = 0.05;

fn params(max_iters: usize) -> TrainParams {
    TrainParams {
        gamma: 0.005,
        max_iters,
        log_every: 10,
        t_comp_override: Some(T_COMP),
        s_g_override: Some(S_G),
        fallback: DecoInput { s_g: S_G, a: 2e7, b: 0.2, t_comp: T_COMP },
        seed: 11,
        ..Default::default()
    }
}

fn quad(dim: usize) -> Quadratic {
    Quadratic::new(dim, 4, 1.0, 0.2, 0.3, 0.3, 11)
}

fn run_churn(
    fabric: Fabric,
    kind: StrategyKind,
    mut p: TrainParams,
    dim: usize,
    threads: usize,
) -> (Vec<f32>, RunResult) {
    p.threads = Some(threads);
    let mut tl = TrainLoop::with_fabric(quad(dim), kind.build(), fabric, p);
    let res = tl.run("elastic");
    (tl.model().to_vec(), res)
}

fn leave(t: f64, worker: usize) -> TimedEvent {
    TimedEvent { t, event: ChurnEvent::Leave { worker } }
}

fn rejoin(t: f64, worker: usize) -> TimedEvent {
    TimedEvent { t, event: ChurnEvent::Rejoin { worker } }
}

#[test]
fn churn_none_is_bit_identical_to_fabric_only_run() {
    // dim 65_536 crosses both parallel-engine thresholds, DeCo exercises
    // dynamic (τ, δ): the elastic machinery with an empty timeline must
    // not perturb one bit, at any pool size
    let dim = 65_536;
    let kind = StrategyKind::DecoSgd { update_every: 10 };
    let fabric =
        || Fabric::homogeneous(4, BandwidthTrace::constant(2e7), 0.2);
    let base = run_churn(fabric(), kind.clone(), params(30), dim, 1);
    for threads in [1usize, 4] {
        let p = TrainParams { churn: ChurnSpec::none(), ..params(30) };
        let (model, res) = run_churn(fabric(), kind.clone(), p, dim, threads);
        assert_eq!(model, base.0, "model diverges at {threads} threads");
        assert_eq!(res.records, base.1.records, "{threads} threads");
        assert_eq!(
            res.total_time.to_bits(),
            base.1.total_time.to_bits(),
            "virtual clock diverges at {threads} threads"
        );
    }
}

/// The pre-fabric virtual clock: ONE shared link, the scalar Eq. 19
/// recurrence (static (τ, δ) so wire bits are constant).
fn legacy_single_link_total(
    link: &Link,
    t_comp: f64,
    tau: usize,
    bits: u64,
    iters: usize,
) -> f64 {
    let (mut ts_prev, mut tm_prev) = (0.0f64, 0.0f64);
    let mut tc: Vec<f64> = Vec::new();
    for k in 1..=iters {
        let tc_delayed = if k as i64 - 1 - tau as i64 >= 1 {
            tc[k - 2 - tau]
        } else {
            0.0
        };
        let ts = t_comp + tc_delayed.max(ts_prev);
        let start = tm_prev.max(ts);
        let tm = link.transfer_end(start, bits);
        ts_prev = ts;
        tm_prev = tm;
        tc.push(tm + link.latency());
    }
    *tc.last().unwrap()
}

#[test]
fn churn_none_matches_legacy_single_link_recurrence() {
    let link = Link::new(BandwidthTrace::constant(2e7), 0.2);
    let iters = 60;
    let p = TrainParams { churn: ChurnSpec::none(), ..params(iters) };
    let (_, res) = run_churn(
        Fabric::homogeneous(4, BandwidthTrace::constant(2e7), 0.2),
        StrategyKind::DEfSgd { delta: 0.1 },
        p,
        256,
        1,
    );
    assert_eq!(res.total_iters, iters);
    let legacy =
        legacy_single_link_total(&link, T_COMP, 0, (0.1 * S_G) as u64, iters);
    assert_eq!(
        res.total_time.to_bits(),
        legacy.to_bits(),
        "elastic-but-empty pricing {} != legacy single-link {legacy}",
        res.total_time
    );
}

#[test]
fn fixed_seed_compiles_identical_timelines() {
    let spec = |seed| ChurnSpec::Random {
        leave_rate_per_100s: 3.0,
        mean_down_s: 20.0,
        outage_rate_per_100s: 2.0,
        outage_s: 10.0,
        horizon_s: 400.0,
        seed,
    };
    let a = spec(7).compile(4).unwrap();
    let b = spec(7).compile(4).unwrap();
    assert_eq!(a, b, "fixed seed ⇒ identical event timeline");
    assert!(!a.is_empty());
    assert_ne!(a, spec(8).compile(4).unwrap());
}

#[test]
fn churn_sweep_csv_is_deterministic() {
    // two full sweeps (same seed) must produce byte-identical CSV — what
    // `repro exp churn` writes to results/churn.csv
    let (csv1, rows1) = deco::exp::churn::sweep(0.25, 4, 256, 7).unwrap();
    let (csv2, rows2) = deco::exp::churn::sweep(0.25, 4, 256, 7).unwrap();
    assert_eq!(csv1, csv2, "sweep CSV must be deterministic in the seed");
    assert_eq!(rows1, rows2);
    assert!(csv1.starts_with("scenario,cycle_s,outage_s,strategy,"));
    // 6 scenarios × 3 arms + header
    assert_eq!(csv1.lines().count(), 1 + 6 * 3);
}

#[test]
fn straggler_departure_speeds_the_clock_and_rejoin_slows_it() {
    // D-SGD (static plan, constant bits) on a straggler fabric: worker 0
    // (quarter bandwidth) gates every aggregation at ~20 s/iteration.
    // Departed forever ⇒ healthy pace; a leave/rejoin cycle lands between.
    let fabric = || {
        Fabric::with_straggler(4, BandwidthTrace::constant(2e7), 0.2, 0.25, 2.0)
    };
    let iters = 100;
    let run = |spec: ChurnSpec| {
        let p = TrainParams { churn: spec, ..params(iters) };
        run_churn(fabric(), StrategyKind::DSgd, p, 256, 1)
    };
    let (_, none) = run(ChurnSpec::none());
    let (_, gone) = run(ChurnSpec::Scripted { events: vec![leave(30.0, 0)] });
    let (_, cycle) = run(ChurnSpec::Scripted {
        events: vec![leave(30.0, 0), rejoin(300.0, 0)],
    });
    assert_eq!(none.total_iters, iters);
    assert_eq!(gone.total_iters, iters);
    assert!(
        gone.total_time < 0.5 * none.total_time,
        "departed straggler must stop gating: {} vs {}",
        gone.total_time,
        none.total_time
    );
    assert!(
        cycle.total_time > gone.total_time
            && cycle.total_time < none.total_time,
        "leave+rejoin lands between: {} / {} / {}",
        gone.total_time,
        cycle.total_time,
        none.total_time
    );
}

#[test]
fn membership_state_reflects_the_schedule() {
    let fabric =
        Fabric::homogeneous(4, BandwidthTrace::constant(2e7), 0.2);
    let p = TrainParams {
        churn: ChurnSpec::Scripted {
            events: vec![leave(2.0, 1), leave(4.0, 3), rejoin(8.0, 1)],
        },
        ..params(60)
    };
    let mut tl =
        TrainLoop::with_fabric(quad(256), StrategyKind::DSgd.build(), fabric, p);
    let res = tl.run("elastic");
    assert!(res.final_loss().is_finite());
    let m = tl.membership();
    assert!(m.is_active(1), "worker 1 rejoined");
    assert!(!m.is_active(3), "worker 3 stayed departed");
    assert_eq!(m.active_count(), 3);
    assert_eq!(m.epoch(), 3, "three membership events fired");
}

#[test]
fn drain_flushes_in_flight_gradients_drop_freezes_them() {
    // DGA at τ=3 keeps 3 gradients in flight; worker 0 leaves mid-run.
    // Drain applies those gradients (different final model than Drop),
    // and each policy is deterministic run-to-run.
    let fabric =
        || Fabric::homogeneous(4, BandwidthTrace::constant(2e7), 0.2);
    let kind = StrategyKind::DdSgd { tau: 3 };
    let run = |policy: DrainPolicy| {
        let p = TrainParams {
            churn: ChurnSpec::Scripted { events: vec![leave(100.0, 0)] },
            drain: policy,
            ..params(80)
        };
        run_churn(fabric(), kind.clone(), p, 256, 1)
    };
    let (drop1, _) = run(DrainPolicy::Drop);
    let (drop2, _) = run(DrainPolicy::Drop);
    let (drain1, drain_res) = run(DrainPolicy::Drain);
    let (drain2, _) = run(DrainPolicy::Drain);
    assert_eq!(drop1, drop2, "Drop is deterministic");
    assert_eq!(drain1, drain2, "Drain is deterministic");
    assert_ne!(
        drop1, drain1,
        "the flushed in-flight gradients must reach the model"
    );
    assert!(drain_res.final_loss().is_finite());
}
