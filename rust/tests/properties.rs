//! Property-based tests on coordinator/compression/timing invariants,
//! driven by the in-tree `util::check::forall` harness (seeded, replayable).

use deco::compress::{
    k_for_delta, BlockTopK, Compressor, ErrorFeedback, RandK, SparseVec, TopK,
};
use deco::coordinator::{TrainLoop, TrainParams, VirtualClock, WorkerState};
use deco::deco::solve::{delta_star, solve, tau_range, DecoInput};
use deco::metrics::sink::CsvSink;
use deco::netsim::{
    BandwidthTrace, Bond, DegradeWindow, Fabric, Link, LossBurstWindow,
    LossProcess, TraceKind,
};
use deco::optim::Quadratic;
use deco::strategy::StrategyKind;
use deco::timesim::{t_avg_closed_form, EventSim, PipelineParams};
use deco::util::check::{forall, Gen};
use deco::util::Rng;

fn gen_delta(g: &mut Gen) -> f64 {
    // log-uniform in [0.003, 1.0]
    (10f64).powf(g.f64(-2.5, 0.0))
}

#[test]
fn prop_topk_keeps_k_largest() {
    forall("topk_keeps_k_largest", 200, |g| {
        let n = g.size(1, 3000);
        let delta = gen_delta(g);
        let orig = g.normal_vec(n, 1.0);
        let mut a = orig.clone();
        let comp = TopK::new(delta);
        let mut rng = Rng::new(g.seed);
        let kept = comp.compress(&mut a, &mut rng);
        let k = k_for_delta(delta, n);
        if kept != k {
            return Err(format!("kept {kept} != k {k} (n={n})"));
        }
        let kept_min = a
            .iter()
            .filter(|&&x| x != 0.0)
            .map(|x| x.abs())
            .fold(f32::INFINITY, f32::min);
        let dropped_max = orig
            .iter()
            .zip(&a)
            .filter(|(_, &v)| v == 0.0)
            .map(|(o, _)| o.abs())
            .fold(0.0f32, f32::max);
        if k < n && kept_min < dropped_max {
            return Err(format!("kept_min {kept_min} < dropped {dropped_max}"));
        }
        Ok(())
    });
}

#[test]
fn prop_ef_invariant_all_compressors() {
    forall("ef_invariant", 120, |g| {
        let blocks = g.size(1, 4);
        let n = blocks * deco::BLOCK;
        let delta = gen_delta(g);
        let mut ef = ErrorFeedback::new(n);
        let mut rng = Rng::new(g.seed ^ 1);
        let comps: Vec<Box<dyn Compressor>> = vec![
            Box::new(TopK::new(delta)),
            Box::new(BlockTopK::new(delta)),
            Box::new(RandK::new(delta)),
        ];
        let comp = &comps[g.size(0, 2)];
        for _ in 0..4 {
            let grad = g.normal_vec(n, 2.0);
            let e_old = ef.error().to_vec();
            let mut buf = grad.clone();
            ef.step(&mut buf, comp.as_ref(), &mut rng);
            for i in 0..n {
                let lhs = buf[i] + ef.error()[i];
                let rhs = grad[i] + e_old[i];
                if lhs != rhs {
                    return Err(format!(
                        "EF invariant broken at {i}: {lhs} != {rhs} ({})",
                        comp.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_roundtrip() {
    forall("sparse_roundtrip", 200, |g| {
        let n = g.size(0, 5000);
        let mut a = g.normal_vec(n, 1.0);
        // random sparsity pattern
        for v in a.iter_mut() {
            if g.bool() {
                *v = 0.0;
            }
        }
        let sv = SparseVec::encode(&a);
        if sv.decode() != a {
            return Err("decode != original".into());
        }
        if sv.nnz() != a.iter().filter(|&&x| x != 0.0).count() {
            return Err("nnz mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_reduction_bit_identical() {
    // the leader's sharded sparse reduction (fixed worker order per shard)
    // must match the serial reduction bit-for-bit for ANY shard geometry:
    // empty shards, all mass in one shard, dim not divisible by the count,
    // more shards than dims
    forall("sharded_reduction", 120, |g| {
        let dim = g.size(1, 4000);
        let nw = g.size(1, 6);
        let msgs: Vec<SparseVec> = (0..nw)
            .map(|w| {
                let mut a = g.normal_vec(dim, 1.0);
                match w % 3 {
                    // dense-ish message
                    0 => {}
                    // random sparsity (can be fully empty)
                    1 => a.iter_mut().for_each(|v| {
                        if g.bool() {
                            *v = 0.0;
                        }
                    }),
                    // all mass in one narrow stripe → most shards empty
                    _ => {
                        let lo = g.size(0, dim - 1);
                        let hi = (lo + g.size(1, 16)).min(dim);
                        for (i, v) in a.iter_mut().enumerate() {
                            if i < lo || i >= hi {
                                *v = 0.0;
                            }
                        }
                    }
                }
                SparseVec::encode(&a)
            })
            .collect();
        let scale = 1.0 / nw as f32;
        let mut serial = vec![0.0f32; dim];
        for sv in &msgs {
            sv.add_into_scaled(&mut serial, scale);
        }
        let shards = g.size(1, 12); // may exceed dim
        let chunk = dim.div_ceil(shards);
        let mut sharded = vec![0.0f32; dim];
        for (i, out) in sharded.chunks_mut(chunk).enumerate() {
            for sv in &msgs {
                sv.add_shard_into_scaled((i * chunk) as u32, out, scale);
            }
        }
        for j in 0..dim {
            if serial[j].to_bits() != sharded[j].to_bits() {
                return Err(format!(
                    "bit mismatch at {j}: {} vs {} (dim={dim} nw={nw} \
                     shards={shards})",
                    serial[j], sharded[j]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_worker_staleness_exact() {
    // whatever constant τ, the gradient applied at iteration t was computed
    // at t − τ
    forall("worker_staleness", 60, |g| {
        let tau = g.size(0, 7);
        let dim = 8;
        let mut w = WorkerState::new(0, dim, g.seed);
        let comp = deco::compress::Identity;
        for t in 0..30usize {
            w.grad_buffer().iter_mut().for_each(|v| *v = t as f32);
            w.push_gradient();
            if let Some((sv, _)) = w.pop_compress(tau, &comp) {
                let stamped = sv.decode()[0] as usize;
                if stamped != t - tau {
                    return Err(format!(
                        "tau={tau}: applied {stamped} at t={t}"
                    ));
                }
            } else if t >= tau {
                return Err(format!("tau={tau}: no pop at t={t}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_delay_queue_transients() {
    // the queue discipline documented in coordinator/worker.rs, checked
    // against an explicit model under a randomly shifting τ schedule:
    // each step pushes one gradient; a message pops iff the queue is
    // deeper than τ, and at most ONE extra gradient drains per step when
    // τ dropped below the realized depth (its mass folds into EF).
    forall("delay_queue_transients", 80, |g| {
        let dim = 4;
        let mut w = WorkerState::new(0, dim, g.seed);
        let comp = deco::compress::Identity;
        let mut tau = g.size(0, 6);
        let mut model_len = 0usize;
        for step in 0..120usize {
            if g.size(0, 9) == 0 {
                tau = g.size(0, 6); // shift τ mid-run, DeCo-style
            }
            w.grad_buffer().iter_mut().for_each(|v| *v = step as f32);
            w.push_gradient();
            model_len += 1;
            let emitted = w.pop_compress(tau, &comp).is_some();
            let want_emit = model_len > tau;
            if want_emit {
                model_len -= 1; // the message pop
                if model_len > tau {
                    model_len -= 1; // the one-per-step extra drain
                }
            }
            if emitted != want_emit {
                return Err(format!(
                    "step {step}: emitted={emitted}, want {want_emit} \
                     (tau={tau})"
                ));
            }
            if w.queue_len() != model_len {
                return Err(format!(
                    "step {step}: queue {} != model {model_len} (tau={tau})",
                    w.queue_len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tau_shift_transient_lengths() {
    // the two prose transients, exactly: a τ increase by Δ stretches the
    // pipeline for exactly Δ silent steps; a decrease by Δ drains exactly
    // one extra gradient per step for Δ steps, emitting every step
    forall("tau_shift_transients", 60, |g| {
        let dim = 2;
        let comp = deco::compress::Identity;
        let tau_a = g.size(0, 5);
        let delta_up = g.size(1, 4);
        let tau_b = tau_a + delta_up;
        let mut w = WorkerState::new(0, dim, g.seed ^ 5);
        // reach steady state at tau_a (queue depth == tau_a)
        for t in 0..(tau_a + 8) {
            w.grad_buffer().iter_mut().for_each(|v| *v = t as f32);
            w.push_gradient();
            w.pop_compress(tau_a, &comp);
        }
        if w.queue_len() != tau_a {
            return Err(format!(
                "steady depth {} != tau_a {tau_a}",
                w.queue_len()
            ));
        }
        // increase to tau_b: exactly delta_up silent steps, then emission
        let mut silent = 0usize;
        for step in 0..(delta_up + 3) {
            w.grad_buffer().iter_mut().for_each(|v| *v = step as f32);
            w.push_gradient();
            match w.pop_compress(tau_b, &comp) {
                None => {
                    if step >= delta_up {
                        return Err(format!(
                            "still silent at step {step}, want resume at \
                             {delta_up}"
                        ));
                    }
                    silent += 1;
                }
                Some(_) => {
                    if step < delta_up {
                        return Err(format!(
                            "emitted at step {step} < stretch {delta_up}"
                        ));
                    }
                }
            }
        }
        if silent != delta_up {
            return Err(format!("{silent} silent steps, want {delta_up}"));
        }
        if w.queue_len() != tau_b {
            return Err(format!("depth {} != tau_b {tau_b}", w.queue_len()));
        }
        // decrease back to tau_a: one extra drain per step, every step
        // emits, depth sheds exactly one per step
        for i in 0..delta_up {
            w.grad_buffer().iter_mut().for_each(|v| *v = i as f32);
            w.push_gradient();
            if w.pop_compress(tau_a, &comp).is_none() {
                return Err(format!("no emission during drain step {i}"));
            }
            let want = tau_b - 1 - i;
            if w.queue_len() != want {
                return Err(format!(
                    "drain step {i}: depth {} != {want}",
                    w.queue_len()
                ));
            }
        }
        if w.queue_len() != tau_a {
            return Err(format!(
                "post-drain depth {} != tau_a {tau_a}",
                w.queue_len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_fabric_sync_arrival_dominates_links() {
    // sync_arrival == max over per-link arrivals, >= every link, and at
    // n = 1 it degenerates to that link's arrival exactly
    forall("fabric_sync_arrival", 120, |g| {
        let n = g.size(1, 6);
        let links: Vec<Link> = (0..n)
            .map(|_| {
                let lat = g.f64(0.0, 1.0);
                let trace = if g.bool() {
                    BandwidthTrace::constant(g.f64(1e6, 1e9))
                } else {
                    BandwidthTrace::new(TraceKind::Sine {
                        mean_bps: g.f64(1e7, 5e8),
                        amp_bps: g.f64(0.0, 9e6),
                        period_s: g.f64(0.5, 20.0),
                    })
                };
                Link::new(trace, lat)
            })
            .collect();
        let start = g.f64(0.0, 50.0);
        let bits = g.size(0, 200_000_000) as u64;
        let per_link: Vec<f64> =
            links.iter().map(|l| l.arrival(start, bits)).collect();
        let fabric = Fabric::new(links);
        let sync = fabric.sync_arrival(start, bits);
        for (i, &a) in per_link.iter().enumerate() {
            if sync < a {
                return Err(format!(
                    "sync {sync} < link {i} arrival {a} (n={n})"
                ));
            }
        }
        let max = per_link.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        if sync.to_bits() != max.to_bits() {
            return Err(format!("sync {sync} != max arrival {max}"));
        }
        if n == 1 && sync.to_bits() != per_link[0].to_bits() {
            return Err("n=1 sync must equal the single arrival".into());
        }
        Ok(())
    });
}

#[test]
fn prop_two_tier_global_sync_dominates_region_syncs() {
    // on a two-tier topology the global sync arrival is gated by the
    // slowest region partial: TC_k >= wan_tc_r >= sync_r >= TS_k for every
    // active region r, and TC_k == max_r wan_tc_r exactly
    use deco::topo::{RegionTopo, Topology};
    forall("two_tier_sync_dominates", 80, |g| {
        let regions = g.size(1, 4);
        let mut next = 0usize;
        let mut topo_regions = Vec::with_capacity(regions);
        let mut links = Vec::new();
        for _ in 0..regions {
            let m = g.size(1, 4);
            let ids: Vec<usize> = (next..next + m).collect();
            next += m;
            for _ in 0..m {
                links.push(Link::new(
                    BandwidthTrace::constant(g.f64(1e7, 1e9)),
                    g.f64(0.0, 0.1),
                ));
            }
            topo_regions.push(RegionTopo {
                // election order is irrelevant to the invariant: pick any
                aggregator: ids[0],
                members: ids.into(),
            });
        }
        let wan = Fabric::new(
            (0..regions)
                .map(|_| {
                    Link::new(
                        BandwidthTrace::constant(g.f64(1e6, 1e8)),
                        g.f64(0.0, 1.0),
                    )
                })
                .collect(),
        );
        let mut clock = VirtualClock::with_topology(
            Fabric::new(links),
            Topology::TwoTier { regions: topo_regions, wan },
        )
        .map_err(|e| e.to_string())?;
        let iters = g.size(3, 40);
        for k in 0..iters {
            let tau = g.size(0, 4);
            let lan_bits = g.size(0, 50_000_000) as u64;
            let wan_bits = g.size(0, 50_000_000) as u64;
            let t = clock.tick_topo(
                g.f64(0.01, 0.5),
                tau,
                lan_bits,
                wan_bits,
                None,
            );
            let mut max_wan = f64::NEG_INFINITY;
            for (r, rt) in clock.region_ticks().iter().enumerate() {
                if !rt.active {
                    return Err(format!("region {r} inactive without mask"));
                }
                if rt.sync < t.ts {
                    return Err(format!(
                        "k={k} region {r}: sync {} < TS {}",
                        rt.sync, t.ts
                    ));
                }
                if rt.wan_tc < rt.sync {
                    return Err(format!(
                        "k={k} region {r}: wan arrival {} < sync {}",
                        rt.wan_tc, rt.sync
                    ));
                }
                if t.tc < rt.sync {
                    return Err(format!(
                        "k={k} region {r}: global sync {} < region sync {}",
                        t.tc, rt.sync
                    ));
                }
                max_wan = max_wan.max(rt.wan_tc);
            }
            if t.tc.to_bits() != max_wan.to_bits() {
                return Err(format!(
                    "k={k}: global {} != max region wan arrival {max_wan}",
                    t.tc
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_clock_matches_event_sim() {
    // incremental VirtualClock == batch EventSim for any constant params
    forall("clock_vs_eventsim", 60, |g| {
        let p = PipelineParams {
            a: g.f64(1e6, 1e9),
            b: g.f64(0.0, 1.0),
            delta: gen_delta(g),
            tau: g.size(0, 6),
            t_comp: g.f64(0.01, 1.0),
            s_g: g.f64(1e6, 5e9),
        };
        let iters = g.size(5, 300);
        let mut clock = VirtualClock::single_link(Link::new(
            BandwidthTrace::constant(p.a),
            p.b,
        ));
        let bits = (p.delta * p.s_g) as u64;
        for _ in 0..iters {
            clock.tick(p.t_comp, p.tau, bits);
        }
        let sim = EventSim::run(&p, iters);
        let (a, b) = (clock.now(), sim.total_time());
        if (a - b).abs() > 1e-6 * b.max(1.0) {
            return Err(format!("clock {a} != sim {b} ({p:?})"));
        }
        Ok(())
    });
}

#[test]
fn prop_theorem3_closed_form_converges() {
    forall("thm3_convergence", 40, |g| {
        let p = PipelineParams {
            a: g.f64(1e6, 1e9),
            b: g.f64(0.0, 1.0),
            delta: gen_delta(g),
            tau: g.size(0, 8),
            t_comp: g.f64(0.01, 1.0),
            s_g: g.f64(1e6, 5e9),
        };
        let sim = EventSim::run(&p, 4000);
        let model = t_avg_closed_form(&p);
        let rel = (sim.t_avg() - model).abs() / model;
        if rel > 0.05 {
            return Err(format!("rel err {rel} for {p:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_deco_output_feasible_and_optimal() {
    forall("deco_feasible", 120, |g| {
        let inp = DecoInput {
            s_g: g.f64(1e7, 5e9),
            a: g.f64(1e6, 1e9),
            b: g.f64(0.001, 2.0),
            t_comp: g.f64(0.01, 1.0),
        };
        let out = solve(&inp);
        if !(out.delta > 0.0 && out.delta <= 1.0) {
            return Err(format!("delta {} out of range", out.delta));
        }
        // bubble-free: T_avg at the chosen point equals T_comp (when the
        // solver stayed in the feasible range)
        let (lo, hi) = tau_range(&inp);
        if out.tau >= lo && out.tau <= hi {
            let p = PipelineParams {
                a: inp.a,
                b: inp.b,
                delta: out.delta,
                tau: out.tau,
                t_comp: inp.t_comp,
                s_g: inp.s_g,
            };
            let t = t_avg_closed_form(&p);
            if (t - inp.t_comp).abs() / inp.t_comp > 1e-6 {
                return Err(format!("not bubble-free: T_avg {t}"));
            }
            // no feasible τ in range does strictly better
            for tau in lo..=hi {
                if let Some(d) = delta_star(&inp, tau) {
                    let lp = deco::deco::phi::log_phi(d, tau);
                    if lp < out.log_phi - 1e-9 {
                        return Err(format!(
                            "suboptimal: tau={tau} beats chosen {}",
                            out.tau
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_arbitrary_runresults() {
    use deco::metrics::{Record, RegionRecord, RunResult};
    forall("metrics_json_roundtrip", 50, |g| {
        let n = g.size(0, 20);
        // every record of a run must carry the same region count — the
        // writers hard-error otherwise, so generate it per run
        let regions = if g.bool() { g.size(1, 4) } else { 0 };
        let mut records = Vec::with_capacity(n);
        for i in 0..n {
            records.push(Record {
                iter: i,
                time: g.f64(0.0, 1e4),
                loss: g.f64(-10.0, 10.0),
                train_loss: g.f64(-10.0, 10.0),
                tau: g.size(0, 9),
                delta: g.f64(0.001, 1.0),
                grad_norm: g.f64(0.0, 100.0),
                bandwidth: g.f64(0.0, 1e9),
                wan_delta: g.f64(0.001, 1.0),
                regions: (0..regions)
                    .map(|_| RegionRecord {
                        sync: g.f64(0.0, 1e4),
                        wan_bits: g.size(0, 1_000_000_000) as u64,
                    })
                    .collect(),
            });
        }
        let res = RunResult {
            method: format!("m{}", g.size(0, 9)),
            task: "t".into(),
            workers: g.size(1, 32),
            records,
            total_time: g.f64(0.0, 1e5),
            total_iters: n,
        };
        let j = res.to_json();
        let parsed = deco::util::Json::parse(&j.to_string_pretty())
            .map_err(|e| e.to_string())?;
        let records = parsed.get("records").unwrap().as_arr().unwrap();
        if records.len() != n {
            return Err("record count".into());
        }
        Ok(())
    });
}

// ---- exact prefix-integral transfer engine (DESIGN.md §Perf) ----

/// The integration step the pre-engine `Link::transfer_end` used (the
/// frozen oracle itself lives in `BandwidthTrace::euler_end_reference`).
const INT_DT: f64 = 0.01;

/// A varying-bandwidth trace of any base kind (no wrappers).
fn gen_varying_trace(g: &mut Gen) -> BandwidthTrace {
    let kind = match g.size(0, 3) {
        0 => TraceKind::Sine {
            mean_bps: g.f64(5e7, 2e8),
            amp_bps: g.f64(0.0, 4e7),
            period_s: g.f64(0.5, 20.0),
        },
        1 => TraceKind::Ou {
            mean_bps: g.f64(5e7, 2e8),
            sigma_bps: g.f64(1e6, 3e7),
            theta: g.f64(0.1, 1.0),
            seed: g.rng.next_u64(),
        },
        2 => TraceKind::Markov {
            levels_bps: vec![
                g.f64(1e7, 5e7),
                g.f64(5e7, 1e8),
                g.f64(1e8, 3e8),
            ],
            dwell_s: g.f64(0.5, 5.0),
            seed: g.rng.next_u64(),
        },
        _ => {
            let n = g.size(2, 12);
            let mut t = 0.0;
            let mut times = Vec::with_capacity(n);
            let mut vals = Vec::with_capacity(n);
            for _ in 0..n {
                times.push(t);
                vals.push(g.f64(2e7, 2e8));
                t += g.f64(0.5, 10.0);
            }
            TraceKind::Samples { times_s: times, bps: vals }
        }
    };
    BandwidthTrace::new(kind)
}

#[test]
fn prop_transfer_end_inverts_cum_bits() {
    // `end_of_transfer` is the exact inverse of the cumulative integral:
    // B(end) − B(start) == bits (ulp-scale tolerance), and it is monotone
    // in both the start time and the payload — on every base kind,
    // through Scaled wrappers and floor-clamped degrade windows
    forall("transfer_end_inverts_cum_bits", 60, |g| {
        let mut trace = gen_varying_trace(g);
        if g.bool() {
            trace = trace.scaled(g.f64(0.2, 1.0));
        }
        if g.bool() {
            let s = g.f64(0.0, 50.0);
            let frac = [0.0, 0.25, 0.5][g.size(0, 2)];
            trace = trace.windowed(vec![DegradeWindow {
                start_s: s,
                end_s: s + g.f64(0.5, 20.0),
                frac,
            }]);
        }
        let start = g.f64(0.0, 300.0);
        let bits = g.f64(1e4, 3e9);
        let end = trace.end_of_transfer(start, bits);
        if end < start {
            return Err(format!("end {end} precedes start {start}"));
        }
        let got = trace.bits_over(start, end);
        let tol = bits * 1e-6 + 1.0;
        if (got - bits).abs() > tol {
            return Err(format!(
                "B(end)-B(start)={got} != bits={bits} (tol {tol})"
            ));
        }
        // monotone in bits
        let end2 = trace.end_of_transfer(start, bits * g.f64(1.0, 3.0) + 10.0);
        if end2 < end - 1e-6 {
            return Err(format!("more bits ended earlier: {end2} < {end}"));
        }
        // monotone in start
        let start2 = start + g.f64(0.0, 5.0);
        let end3 = trace.end_of_transfer(start2, bits);
        if end3 < end - 1e-6 {
            return Err(format!("later start ended earlier: {end3} < {end}"));
        }
        Ok(())
    });
}

#[test]
fn prop_exact_end_matches_euler_within_step_error() {
    // the exact inversion agrees with the old 10 ms Euler integrator up to
    // the Euler scheme's own per-step error: each step mis-prices at most
    // the rate swing within it, so the accumulated bits slack is bounded
    // by Σ|Δa|·dt (plus one full step at the boundary), measured here
    // directly from the trace
    forall("exact_end_matches_euler", 30, |g| {
        let trace = gen_varying_trace(g);
        let start = g.f64(0.0, 100.0);
        let secs_target = g.f64(0.1, 30.0);
        let bits = trace.mean_over(start, start + secs_target) * secs_target;
        let exact = trace.end_of_transfer(start, bits);
        let euler = trace.euler_end_reference(start, bits);
        let horizon = exact.max(euler);
        let steps = ((horizon - start) / INT_DT).ceil() as usize + 2;
        let mut swing = 0.0;
        let mut amax = trace.at(start);
        let mut amin = amax;
        let mut prev = amax;
        for i in 1..=steps {
            let a = trace.at(start + i as f64 * INT_DT);
            swing += (a - prev).abs() * INT_DT;
            amax = amax.max(a);
            amin = amin.min(a);
            prev = a;
        }
        let tol_bits = 2.0 * swing + 2.0 * amax * INT_DT;
        let tol_secs = 1.5 * (tol_bits / (0.9 * amin) + 2.0 * INT_DT);
        if (exact - euler).abs() > tol_secs {
            return Err(format!(
                "exact {exact} vs euler {euler}: |Δ| > tol {tol_secs}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_mean_over_degenerate_interval_is_at() {
    // t1 <= t0 must report the instantaneous rate, not a negative/zero
    // quotient (the old 200-point sampler summed a negative dt)
    forall("mean_over_degenerate", 40, |g| {
        let trace = gen_varying_trace(g);
        let t0 = g.f64(0.0, 200.0);
        for t1 in [t0, t0 - g.f64(0.0, 10.0)] {
            let got = trace.mean_over(t0, t1);
            let want = trace.at(t0);
            if got.to_bits() != want.to_bits() {
                return Err(format!(
                    "mean_over({t0}, {t1}) = {got} != at(t0) = {want}"
                ));
            }
        }
        // and a proper interval is the exact prefix difference
        let t1 = t0 + g.f64(0.1, 20.0);
        let mean = trace.mean_over(t0, t1);
        let bits = trace.bits_over(t0, t1);
        let rel = (mean * (t1 - t0) - bits).abs() / bits.max(1.0);
        if rel > 1e-12 {
            return Err(format!("mean·dt != bits_over (rel {rel})"));
        }
        Ok(())
    });
}

// ---- bonded multi-path transport (DESIGN.md §Bonding) ----

/// A k-path bond over varying traces with per-path random latencies.
fn gen_bond(g: &mut Gen, k: usize) -> Bond {
    let paths = (0..k)
        .map(|_| Link::new(gen_varying_trace(g), g.f64(0.01, 0.5)))
        .collect();
    Bond::new(paths)
}

#[test]
fn prop_bonded_arrival_bracketed_and_bits_conserved() {
    // the water-filling arrival can never precede the earliest possible
    // share (start + min latency) and never trails the best single path
    // alone (the bisection's hi bracket); the per-path split must sum to
    // the payload at full f64 resolution
    forall("bonded_arrival_and_conservation", 60, |g| {
        let k = g.size(2, 4);
        let bond = gen_bond(g, k);
        let start = g.f64(0.0, 100.0);
        let bits = g.f64(1e4, 2e9) as u64;
        let sched = bond.schedule(&vec![start; k], bits);
        let lo = start + bond.min_latency();
        if sched.arrival < lo - 1e-9 {
            return Err(format!(
                "arrival {} precedes start+min_latency {lo}",
                sched.arrival
            ));
        }
        let best_single = (0..k)
            .map(|p| bond.path(p).arrival(start, bits))
            .fold(f64::INFINITY, f64::min);
        if sched.arrival > best_single + 1e-9 {
            return Err(format!(
                "bonded arrival {} worse than best single path \
                 {best_single}",
                sched.arrival
            ));
        }
        let total: f64 = sched.bits.iter().sum();
        let tol = 1e-6 * bits as f64 + 1.0;
        if (total - bits as f64).abs() > tol {
            return Err(format!(
                "shares sum to {total}, payload {bits} (tol {tol})"
            ));
        }
        for p in 0..k {
            // no share lands after the common arrival, none starts early
            let land = sched.tx_end[p] + bond.path(p).latency();
            if land > sched.arrival + 1e-9 {
                return Err(format!(
                    "path {p} lands at {land} after arrival {}",
                    sched.arrival
                ));
            }
            if sched.tx_end[p] < start - 1e-9 {
                return Err(format!(
                    "path {p} tx_end {} precedes start {start}",
                    sched.tx_end[p]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_equal_latency_bond_beats_every_single_path_tx() {
    // with equal latencies the common-arrival split is also the earliest
    // common *transmission* end, so the bonded transfer_end can't trail
    // any one path carrying the whole payload alone
    forall("bonded_tx_end_dominates", 40, |g| {
        let k = g.size(2, 3);
        let lat = g.f64(0.01, 0.5);
        let paths: Vec<Link> = (0..k)
            .map(|_| Link::new(gen_varying_trace(g), lat))
            .collect();
        let bond = Bond::new(paths.clone());
        let start = g.f64(0.0, 50.0);
        let bits = g.f64(1e5, 1e9) as u64;
        let bonded = bond.transfer_end(start, bits);
        let best = paths
            .iter()
            .map(|p| p.transfer_end(start, bits))
            .fold(f64::INFINITY, f64::min);
        if bonded > best + 1e-6 {
            return Err(format!(
                "bonded transfer_end {bonded} > best single {best}"
            ));
        }
        Ok(())
    });
}

/// A random link prototype for the class-engine comparison: any varying
/// trace, optionally degraded over a window, with a random latency.
fn gen_scan_link(g: &mut Gen) -> Link {
    let mut trace = gen_varying_trace(g);
    if g.bool() {
        let s = g.f64(0.0, 30.0);
        trace = trace.windowed(vec![DegradeWindow {
            start_s: s,
            end_s: s + g.f64(0.5, 20.0),
            frac: [0.0, 0.25, 0.5][g.size(0, 2)],
        }]);
    }
    Link::new(trace, g.f64(0.0, 0.3))
}

/// Flip a random worker, but never empty the mask (the clock asserts a
/// non-empty active set).
fn flip_one_keeping_nonempty(g: &mut Gen, mask: &mut [bool]) {
    let w = g.size(0, mask.len() - 1);
    mask[w] = !mask[w];
    if mask.iter().all(|&m| !m) {
        mask[w] = true;
    }
}

#[test]
fn prop_class_engine_matches_reference_scan() {
    // the shared-timeline-class engine (tournament tree, DESIGN.md §Perf)
    // must be *bit*-identical — every tick report and every per-worker
    // view — to the O(n)-per-tick singleton reference scan (the pre-SoA
    // recurrence), under random link mixes, degrade windows, a bonded
    // worker, and random churn masks at n ∈ {3, 64, 1024}
    forall("class_engine_vs_reference_scan", 30, |g| {
        let n = [3usize, 64, 1024][g.size(0, 2)];
        let nproto = g.size(1, 3);
        let protos: Vec<Link> =
            (0..nproto).map(|_| gen_scan_link(g)).collect();
        let links: Vec<Link> = (0..n)
            .map(|_| protos[g.size(0, nproto - 1)].clone())
            .collect();
        let mut fabric = Fabric::new(links);
        if g.bool() {
            fabric.set_bond(0, gen_bond(g, 2));
        }
        let mut shared = VirtualClock::new(fabric.clone());
        let mut reference =
            VirtualClock::new(fabric).with_reference_scan();

        let mut mask = vec![true; n];
        let ticks = g.size(5, 25);
        for k in 1..=ticks {
            if g.bool() {
                flip_one_keeping_nonempty(g, &mut mask);
            }
            // alternate the mask with full-membership ticks so rejoin
            // paths (None after Some) get exercised too
            let active = if g.bool() { Some(&mask[..]) } else { None };
            let t_comp = g.f64(0.01, 0.5);
            let tau = g.size(0, 4);
            let bits = g.size(0, 20_000_000) as u64;
            let a = shared.tick_members(t_comp, tau, bits, active);
            let b = reference.tick_members(t_comp, tau, bits, active);
            for (name, x, y) in [
                ("ts", a.ts, b.ts),
                ("tm", a.tm, b.tm),
                ("tc", a.tc, b.tc),
                ("tx", a.tx_secs, b.tx_secs),
            ] {
                if x.to_bits() != y.to_bits() {
                    return Err(format!(
                        "k={k} n={n}: {name} diverged ({x} vs {y})"
                    ));
                }
            }
        }
        if shared.timeline_classes() > reference.timeline_classes() {
            return Err(format!(
                "sharing tracks {} classes, reference only {}",
                shared.timeline_classes(),
                reference.timeline_classes()
            ));
        }
        let sw = shared.worker_ticks().to_vec();
        let st = shared.tx_totals().to_vec();
        let rw = reference.worker_ticks().to_vec();
        let rt = reference.tx_totals().to_vec();
        for w in 0..n {
            if sw[w].tm.to_bits() != rw[w].tm.to_bits()
                || sw[w].tc.to_bits() != rw[w].tc.to_bits()
                || sw[w].tx_secs.to_bits() != rw[w].tx_secs.to_bits()
            {
                return Err(format!("worker {w} last-tick view diverged"));
            }
            if st[w].to_bits() != rt[w].to_bits() {
                return Err(format!(
                    "worker {w} tx total diverged ({} vs {})",
                    st[w], rt[w]
                ));
            }
        }
        let (sp, rp) = (shared.path_ticks(0), reference.path_ticks(0));
        if sp.len() != rp.len() {
            return Err(format!(
                "bond path views diverged ({} vs {} paths)",
                sp.len(),
                rp.len()
            ));
        }
        for (p, (x, y)) in sp.iter().zip(rp).enumerate() {
            if x.tm.to_bits() != y.tm.to_bits()
                || x.bits.to_bits() != y.bits.to_bits()
                || x.tx_secs.to_bits() != y.tx_secs.to_bits()
            {
                return Err(format!("bond path {p} diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_two_tier_class_engine_matches_reference_scan() {
    // same incremental-vs-reference contract on the two-tier clock:
    // random regions, churn masks, and aggregator re-elections applied to
    // both engines must keep every tick, region view, and accumulator
    // bit-identical
    use deco::topo::{RegionTopo, Topology};
    forall("two_tier_class_engine_vs_reference", 30, |g| {
        let regions = g.size(1, 4);
        let mut links = Vec::new();
        let mut topo_regions = Vec::new();
        let mut next = 0usize;
        for _ in 0..regions {
            let m = g.size(1, 4);
            let ids: Vec<usize> = (next..next + m).collect();
            next += m;
            for _ in 0..m {
                links.push(Link::new(
                    BandwidthTrace::constant(g.f64(1e7, 1e9)),
                    g.f64(0.0, 0.1),
                ));
            }
            topo_regions.push(RegionTopo {
                aggregator: ids[0],
                members: ids.into(),
            });
        }
        let n = next;
        let wan = Fabric::new(
            (0..regions)
                .map(|_| {
                    Link::new(
                        BandwidthTrace::constant(g.f64(1e6, 1e8)),
                        g.f64(0.0, 1.0),
                    )
                })
                .collect(),
        );
        let topo = Topology::TwoTier { regions: topo_regions, wan };
        let fabric = Fabric::new(links);
        let mut shared =
            VirtualClock::with_topology(fabric.clone(), topo.clone())
                .map_err(|e| e.to_string())?;
        let mut reference = VirtualClock::with_topology(fabric, topo)
            .map_err(|e| e.to_string())?
            .with_reference_scan();

        let mut mask = vec![true; n];
        let iters = g.size(3, 30);
        for k in 0..iters {
            if g.bool() {
                flip_one_keeping_nonempty(g, &mut mask);
            }
            if g.bool() {
                let r = g.size(0, regions - 1);
                let a = shared.reelect_aggregator(r, &mask);
                let b = reference.reelect_aggregator(r, &mask);
                if a != b {
                    return Err(format!(
                        "k={k}: re-election disagreed ({a} vs {b})"
                    ));
                }
            }
            let active = if g.bool() { Some(&mask[..]) } else { None };
            let t_comp = g.f64(0.01, 0.5);
            let tau = g.size(0, 4);
            let lan_bits = g.size(0, 50_000_000) as u64;
            let wan_bits = g.size(0, 50_000_000) as u64;
            let a =
                shared.tick_topo(t_comp, tau, lan_bits, wan_bits, active);
            let b =
                reference.tick_topo(t_comp, tau, lan_bits, wan_bits, active);
            if a.ts.to_bits() != b.ts.to_bits()
                || a.tc.to_bits() != b.tc.to_bits()
            {
                return Err(format!(
                    "k={k}: global tick diverged ({} vs {})",
                    a.tc, b.tc
                ));
            }
            let srt = shared.region_ticks();
            let rrt = reference.region_ticks();
            for (r, (x, y)) in srt.iter().zip(rrt).enumerate() {
                if x.active != y.active
                    || x.sync.to_bits() != y.sync.to_bits()
                    || x.wan_tc.to_bits() != y.wan_tc.to_bits()
                {
                    return Err(format!(
                        "k={k} region {r} diverged \
                         (sync {} vs {}, wan_tc {} vs {})",
                        x.sync, y.sync, x.wan_tc, y.wan_tc
                    ));
                }
            }
        }
        let swan = shared.wan_tx_totals().to_vec();
        let rwan = reference.wan_tx_totals();
        for (r, (x, y)) in swan.iter().zip(rwan).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("region {r} wan tx total diverged"));
            }
        }
        let st = shared.tx_totals().to_vec();
        let rt = reference.tx_totals().to_vec();
        for w in 0..n {
            if st[w].to_bits() != rt[w].to_bits() {
                return Err(format!("worker {w} tx total diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_path_degrade_never_speeds_the_bond() {
    // baking a degrade window into one path lowers that path's cumulative
    // integral pointwise, so the earliest covering time — the bonded
    // arrival — can only move later (monotone failover)
    forall("bonded_degrade_monotone", 40, |g| {
        let k = g.size(2, 3);
        let bond = gen_bond(g, k);
        let p = g.size(0, k - 1);
        let s = g.f64(0.0, 30.0);
        let frac = [0.0, 0.25, 0.5][g.size(0, 2)];
        let degraded = bond.with_path_windows(
            p,
            vec![DegradeWindow {
                start_s: s,
                end_s: s + g.f64(1.0, 40.0),
                frac,
            }],
        );
        let start = g.f64(0.0, 40.0);
        let bits = g.f64(1e4, 5e8) as u64;
        let healthy = bond.arrival(start, bits);
        let slowed = degraded.arrival(start, bits);
        if slowed < healthy - 1e-6 {
            return Err(format!(
                "degrading path {p} sped the bond: {slowed} < {healthy}"
            ));
        }
        Ok(())
    });
}

// ---- lossy transport + deadline-bounded aggregation (DESIGN.md
// §Robustness) ----

/// A random seeded loss process: i.i.d. or bursty Gilbert–Elliott, with a
/// random retransmission timeout.
fn gen_loss(g: &mut Gen) -> LossProcess {
    let seed = g.rng.next_u64();
    let p = if g.bool() {
        LossProcess::iid(g.f64(0.05, 0.7), seed)
    } else {
        LossProcess::gilbert_elliott(
            g.f64(0.0, 0.1),
            g.f64(0.5, 0.95),
            g.f64(0.05, 0.5),
            g.f64(0.5, 10.0),
            seed,
        )
    };
    p.with_rto(g.f64(0.05, 0.5))
}

#[test]
fn prop_retransmission_never_prices_earlier() {
    // lost attempts only ever push the arrival later: a first-attempt
    // success is bit-identical to the lossless transfer, any
    // retransmission lands at or after it (transfer_end is monotone in
    // its start) and books positive retransmit time, and empty payloads
    // cannot be lost — on single links and bonds alike
    forall("retransmission_never_earlier", 80, |g| {
        let link = gen_scan_link(g);
        let loss = gen_loss(g);
        let worker = g.size(0, 7) as u32;
        let msg = g.rng.next_u64() % 1000;
        let start = g.f64(0.0, 100.0);
        let bits = g.size(1, 500_000_000) as u64;
        let base = link.transfer_end(start, bits);
        let out = loss.price(&link, worker, msg, start, bits);
        if out.attempts < 1 || out.attempts > 12 {
            return Err(format!("attempts {} out of range", out.attempts));
        }
        if out.attempts == 1 {
            if out.tm.to_bits() != base.to_bits() || out.retx_secs != 0.0 {
                return Err(format!(
                    "first-attempt success must price losslessly \
                     ({} vs {base}, retx {})",
                    out.tm, out.retx_secs
                ));
            }
        } else {
            if out.tm < base - 1e-6 {
                return Err(format!(
                    "retransmitted arrival {} precedes lossless {base} \
                     ({} attempts)",
                    out.tm, out.attempts
                ));
            }
            if out.retx_secs <= 0.0 {
                return Err(format!(
                    "{} attempts booked retx {}",
                    out.attempts, out.retx_secs
                ));
            }
        }
        let zero = loss.price(&link, worker, msg, start, 0);
        if zero.attempts != 1 || zero.retx_secs != 0.0 {
            return Err("bits=0 messages cannot be lost".into());
        }
        // same contract through the bonded water-filling scheduler
        let bond = gen_bond(g, 2);
        let starts = vec![start; 2];
        let clean = bond.schedule(&starts, bits);
        let (sched, attempts, retx) =
            loss.price_bonded(&bond, worker, msg, &starts, bits);
        if attempts == 1 {
            if sched.arrival.to_bits() != clean.arrival.to_bits()
                || retx != 0.0
            {
                return Err(
                    "bonded first-attempt success must price losslessly"
                        .into(),
                );
            }
        } else if sched.arrival < clean.arrival - 1e-6 {
            return Err(format!(
                "bonded retransmitted arrival {} precedes lossless {}",
                sched.arrival, clean.arrival
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_rate_zero_loss_and_slack_deadline_are_identity() {
    // the two robustness knobs at their neutral settings must be
    // structural no-ops: a rate-0 loss process (even one carrying rate-0
    // burst windows) and a deadline too slack to ever bind leave every
    // tick bit-identical to the plain clock — on the shared-class engine
    // and the reference scan alike, under random churn masks
    forall("rate_zero_and_slack_deadline_identity", 30, |g| {
        let n = [3usize, 16][g.size(0, 1)];
        let nproto = g.size(1, 2);
        let protos: Vec<Link> =
            (0..nproto).map(|_| gen_scan_link(g)).collect();
        let links: Vec<Link> = (0..n)
            .map(|_| protos[g.size(0, nproto - 1)].clone())
            .collect();
        let mut fabric = Fabric::new(links);
        if g.bool() {
            fabric.set_bond(0, gen_bond(g, 2));
        }
        let mut variant_fabric = fabric.clone();
        let s = g.f64(0.0, 20.0);
        variant_fabric.set_loss(
            g.size(0, n - 1),
            LossProcess::iid(0.0, g.rng.next_u64()).with_bursts(vec![
                LossBurstWindow {
                    start_s: s,
                    end_s: s + g.f64(0.5, 10.0),
                    rate: 0.0,
                },
            ]),
        );
        if variant_fabric.has_loss() {
            return Err("rate-0 loss must be dropped structurally".into());
        }
        let mut plain = VirtualClock::new(fabric.clone());
        let mut variant = VirtualClock::new(variant_fabric.clone());
        let mut plain_ref =
            VirtualClock::new(fabric).with_reference_scan();
        let mut variant_ref =
            VirtualClock::new(variant_fabric).with_reference_scan();
        variant.set_deadline(Some(1e12));
        variant_ref.set_deadline(Some(1e12));
        let mut mask = vec![true; n];
        let ticks = g.size(5, 20);
        for k in 1..=ticks {
            if g.bool() {
                flip_one_keeping_nonempty(g, &mut mask);
            }
            let active = if g.bool() { Some(&mask[..]) } else { None };
            let t_comp = g.f64(0.01, 0.5);
            let tau = g.size(0, 4);
            let bits = g.size(0, 20_000_000) as u64;
            let a = plain.tick_members(t_comp, tau, bits, active);
            let others = [
                variant.tick_members(t_comp, tau, bits, active),
                plain_ref.tick_members(t_comp, tau, bits, active),
                variant_ref.tick_members(t_comp, tau, bits, active),
            ];
            for (i, b) in others.iter().enumerate() {
                if a.ts.to_bits() != b.ts.to_bits()
                    || a.tm.to_bits() != b.tm.to_bits()
                    || a.tc.to_bits() != b.tc.to_bits()
                    || a.tx_secs.to_bits() != b.tx_secs.to_bits()
                    || b.retx_secs != 0.0
                {
                    return Err(format!(
                        "k={k} n={n}: clock {i} diverged from plain"
                    ));
                }
            }
            if !variant.late_workers().is_empty() {
                return Err(format!(
                    "k={k}: slack deadline marked workers late"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lossy_deadline_clock_matches_reference_scan() {
    // the shared-timeline engine and the O(n) reference scan must stay
    // bit-identical under genuine message loss (lossy workers price as
    // singleton classes keyed on worker id and message id) and a binding
    // aggregation deadline — every tick report, late set, and per-worker
    // retransmit view
    forall("lossy_deadline_vs_reference_scan", 25, |g| {
        let n = [3usize, 16][g.size(0, 1)];
        let proto = gen_scan_link(g);
        let links: Vec<Link> = (0..n).map(|_| proto.clone()).collect();
        let mut fabric = Fabric::new(links);
        for _ in 0..g.size(1, 3) {
            fabric.set_loss(g.size(0, n - 1), gen_loss(g));
        }
        if g.bool() {
            fabric.set_bond(n - 1, gen_bond(g, 2));
        }
        let mut shared = VirtualClock::new(fabric.clone());
        let mut reference = VirtualClock::new(fabric).with_reference_scan();
        let deadline = if g.bool() { Some(g.f64(0.05, 2.0)) } else { None };
        shared.set_deadline(deadline);
        reference.set_deadline(deadline);
        let mut mask = vec![true; n];
        let ticks = g.size(5, 25);
        for k in 1..=ticks {
            if g.bool() {
                flip_one_keeping_nonempty(g, &mut mask);
            }
            let active = if g.bool() { Some(&mask[..]) } else { None };
            let t_comp = g.f64(0.01, 0.5);
            let tau = g.size(0, 4);
            let bits = g.size(0, 20_000_000) as u64;
            let a = shared.tick_members(t_comp, tau, bits, active);
            let b = reference.tick_members(t_comp, tau, bits, active);
            for (name, x, y) in [
                ("ts", a.ts, b.ts),
                ("tm", a.tm, b.tm),
                ("tc", a.tc, b.tc),
                ("tx", a.tx_secs, b.tx_secs),
                ("retx", a.retx_secs, b.retx_secs),
            ] {
                if x.to_bits() != y.to_bits() {
                    return Err(format!(
                        "k={k} n={n}: {name} diverged ({x} vs {y})"
                    ));
                }
            }
            if shared.late_workers() != reference.late_workers() {
                return Err(format!(
                    "k={k}: late sets diverged ({:?} vs {:?})",
                    shared.late_workers(),
                    reference.late_workers()
                ));
            }
        }
        let sw = shared.worker_ticks();
        let rw = reference.worker_ticks();
        for w in 0..n {
            if sw[w].tc.to_bits() != rw[w].tc.to_bits()
                || sw[w].retx_secs.to_bits() != rw[w].retx_secs.to_bits()
                || sw[w].attempts != rw[w].attempts
            {
                return Err(format!("worker {w} lossy view diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lossy_deadline_train_serial_equals_pooled() {
    // a full lossy + deadline-bounded DeCo training run must be
    // bit-identical at every worker-pool size (t_comp pinned): the
    // sharded reduction, late-gradient absorption, and attempt-count
    // monitoring are all deterministic in worker order, never in thread
    // schedule
    forall("lossy_deadline_serial_vs_pooled", 8, |g| {
        let workers = g.size(2, 4);
        let dim = 4096 + g.size(0, 512);
        let mut fabric =
            Fabric::homogeneous(workers, BandwidthTrace::constant(1e8), 0.05);
        fabric.set_loss(0, gen_loss(g));
        let kind = StrategyKind::DecoLossy {
            update_every: g.size(1, 10),
            quantile: 0.9,
        };
        let p = TrainParams {
            gamma: 0.005,
            max_iters: g.size(40, 100),
            log_every: g.size(1, 5),
            t_comp_override: Some(0.05),
            s_g_override: Some(1e8),
            fallback: DecoInput { s_g: 1e8, a: 2e7, b: 0.2, t_comp: 0.05 },
            seed: g.seed,
            threads: Some(1),
            ..Default::default()
        };
        let seed = g.seed;
        let quad =
            || Quadratic::new(dim, workers, 1.0, 0.2, 0.3, 0.3, seed);
        let mut serial_tl = TrainLoop::with_fabric(
            quad(),
            kind.build(),
            fabric.clone(),
            p.clone(),
        );
        let serial = serial_tl.run("prop");
        let pooled_p = TrainParams { threads: Some(3), ..p };
        let mut pooled_tl =
            TrainLoop::with_fabric(quad(), kind.build(), fabric, pooled_p);
        let pooled = pooled_tl.run("prop");
        if serial.total_iters != pooled.total_iters
            || serial.total_time.to_bits() != pooled.total_time.to_bits()
        {
            return Err(format!(
                "totals diverged: {} iters / {}s vs {} iters / {}s",
                serial.total_iters,
                serial.total_time,
                pooled.total_iters,
                pooled.total_time
            ));
        }
        if serial.records.len() != pooled.records.len() {
            return Err("record counts diverged".into());
        }
        for (i, (a, b)) in
            serial.records.iter().zip(&pooled.records).enumerate()
        {
            if a.time.to_bits() != b.time.to_bits()
                || a.loss.to_bits() != b.loss.to_bits()
                || a.tau != b.tau
                || a.delta.to_bits() != b.delta.to_bits()
            {
                return Err(format!("record {i} diverged across pools"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_streamed_csv_matches_buffered_run() {
    // `TrainLoop::run_streamed(CsvSink)` (DESIGN.md §Perf) must emit the
    // exact bytes the buffered `run()` + `to_csv()` path does, and the
    // incremental `RunFolds` must be bit-identical to the buffered
    // summary scans (time-to-target interpolation included) — for any
    // strategy, fabric shape, and logging cadence
    forall("streamed_csv_vs_buffered_run", 12, |g| {
        let dim = g.size(8, 32);
        let workers = g.size(2, 4);
        let kind = match g.size(0, 3) {
            0 => StrategyKind::DSgd,
            1 => StrategyKind::DEfSgd { delta: gen_delta(g) },
            2 => StrategyKind::DdSgd { tau: g.size(0, 3) },
            _ => StrategyKind::DecoSgd { update_every: g.size(1, 20) },
        };
        let p = TrainParams {
            gamma: 0.005,
            max_iters: g.size(30, 120),
            log_every: g.size(1, 10),
            t_comp_override: Some(0.05),
            s_g_override: Some(1e8),
            fallback: DecoInput { s_g: 1e8, a: 2e7, b: 0.2, t_comp: 0.05 },
            seed: g.seed,
            threads: Some(1),
            ..Default::default()
        };
        let fabric = if g.bool() {
            Fabric::homogeneous(workers, BandwidthTrace::constant(1e8), 0.05)
        } else {
            Fabric::with_straggler(
                workers,
                BandwidthTrace::constant(1e8),
                0.05,
                0.5,
                2.0,
            )
        };
        let seed = g.seed;
        let quad =
            || Quadratic::new(dim, workers, 1.0, 0.2, 0.3, 0.3, seed);

        let mut buffered_tl = TrainLoop::with_fabric(
            quad(),
            kind.build(),
            fabric.clone(),
            p.clone(),
        );
        let buffered = buffered_tl.run("prop");
        if buffered.records.is_empty() {
            return Err("buffered run logged no records".into());
        }
        let first = buffered.records[0].loss;
        let best = buffered.best_loss();
        // one easy, one mid-run, one exactly-at-best, one unreachable
        let targets = [
            best + 0.75 * (first - best),
            best + 0.25 * (first - best),
            best,
            best - 1.0,
        ];

        let mut sink = CsvSink::new(Vec::new(), &targets);
        let mut streamed_tl =
            TrainLoop::with_fabric(quad(), kind.build(), fabric, p);
        let streamed = streamed_tl
            .run_streamed("prop", &mut sink)
            .map_err(|e| e.to_string())?;
        let (bytes, folds) = sink.finish().map_err(|e| e.to_string())?;

        if !streamed.records.is_empty() {
            return Err("run_streamed must not buffer records".into());
        }
        if streamed.total_iters != buffered.total_iters
            || streamed.total_time.to_bits() != buffered.total_time.to_bits()
        {
            return Err(format!(
                "run totals diverged: {} iters / {}s vs {} iters / {}s",
                streamed.total_iters,
                streamed.total_time,
                buffered.total_iters,
                buffered.total_time
            ));
        }
        if bytes != buffered.to_csv().into_bytes() {
            return Err("streamed CSV bytes != buffered to_csv".into());
        }
        for (i, &t) in targets.iter().enumerate() {
            let (bt, ft) = (buffered.time_to_loss(t), folds.time_to(i));
            match (bt, ft) {
                (None, None) => {}
                (Some(b), Some(f)) if b.to_bits() == f.to_bits() => {}
                other => {
                    return Err(format!(
                        "target {t}: fold {other:?} != buffered scan"
                    ));
                }
            }
            if buffered.iters_to_loss(t) != folds.iters_to(i) {
                return Err(format!("target {t}: iters-to diverged"));
            }
        }
        if folds.final_loss().to_bits() != buffered.final_loss().to_bits()
            || folds.best_loss().to_bits() != buffered.best_loss().to_bits()
            || folds.records() != buffered.records.len()
        {
            return Err("fold summary diverged from the buffered run".into());
        }
        Ok(())
    });
}
