//! Bonded-transport regression tests (DESIGN.md §Bonding):
//!
//! * determinism contract — a k=1 bond prices bit-identically to the plain
//!   single-link fabric (serial AND pooled: the bond code path adds no
//!   float reorderings), and two `exp bonded` sweeps with the same seed
//!   produce byte-identical `results/bonded.csv` content;
//! * failover semantics — a worker-level outage on a bonded worker hits
//!   every path (all-paths-out ⇒ the floor trickle, not a hang), while a
//!   path-scoped outage leaves the surviving path carrying the bits.

use deco::coordinator::{TrainLoop, TrainParams};
use deco::deco::solve::DecoInput;
use deco::elastic::{ChurnEvent, ChurnSpec, TimedEvent};
use deco::metrics::RunResult;
use deco::netsim::{BandwidthTrace, Bond, Fabric, Link};
use deco::optim::Quadratic;
use deco::strategy::StrategyKind;

const S_G: f64 = 1e8;
const T_COMP: f64 = 0.05;

fn params(max_iters: usize) -> TrainParams {
    TrainParams {
        gamma: 0.005,
        max_iters,
        log_every: 10,
        t_comp_override: Some(T_COMP),
        s_g_override: Some(S_G),
        fallback: DecoInput { s_g: S_G, a: 2e7, b: 0.2, t_comp: T_COMP },
        seed: 11,
        ..Default::default()
    }
}

fn quad(dim: usize) -> Quadratic {
    Quadratic::new(dim, 4, 1.0, 0.2, 0.3, 0.3, 11)
}

fn run_bond(
    fabric: Fabric,
    kind: StrategyKind,
    mut p: TrainParams,
    dim: usize,
    threads: usize,
) -> (Vec<f32>, RunResult) {
    p.threads = Some(threads);
    let mut tl = TrainLoop::with_fabric(quad(dim), kind.build(), fabric, p);
    let res = tl.run("bond");
    (tl.model().to_vec(), res)
}

#[test]
fn k1_bond_is_bit_identical_to_the_plain_fabric() {
    // dim 65_536 crosses the parallel-engine thresholds, DeCo exercises
    // dynamic (τ, δ): wrapping every worker's link in a one-path bond must
    // not perturb one bit, at any pool size
    let dim = 65_536;
    let kind = StrategyKind::DecoSgd { update_every: 10 };
    let plain = || Fabric::homogeneous(4, BandwidthTrace::constant(2e7), 0.2);
    let bonded = || {
        let mut f = plain();
        for i in 0..4 {
            let link = Link::new(BandwidthTrace::constant(2e7), 0.2);
            f.set_bond(i, Bond::single(link));
        }
        f
    };
    let base = run_bond(plain(), kind.clone(), params(30), dim, 1);
    for threads in [1usize, 4] {
        let (model, res) =
            run_bond(bonded(), kind.clone(), params(30), dim, threads);
        assert_eq!(model, base.0, "model diverges at {threads} threads");
        assert_eq!(res.records, base.1.records, "{threads} threads");
        assert_eq!(
            res.total_time.to_bits(),
            base.1.total_time.to_bits(),
            "virtual clock diverges at {threads} threads"
        );
    }
}

#[test]
fn bonded_sweep_csv_is_deterministic() {
    // two full sweeps (same seed) must produce byte-identical CSV — what
    // `repro exp bonded` writes to results/bonded.csv
    let (csv1, rows1) = deco::exp::bonded::sweep(0.25, 4, 256, 7).unwrap();
    let (csv2, rows2) = deco::exp::bonded::sweep(0.25, 4, 256, 7).unwrap();
    assert_eq!(csv1, csv2, "sweep CSV must be deterministic in the seed");
    assert_eq!(rows1, rows2);
    assert!(csv1.starts_with("scenario,outage_s,strategy,"));
    // 2 scenarios × 4 arms + header
    assert_eq!(csv1.lines().count(), 1 + 2 * 4);
}

#[test]
fn worker_level_outage_on_a_bond_means_all_paths() {
    // D-SGD (static plan, constant bits) with worker 0 dual-homed on two
    // fat paths. A path-0 outage leaves path 1 carrying the run at nearly
    // full pace; a worker-level LinkOutage of the same length blanks BOTH
    // paths to the 1 kbps floor and must cost roughly the whole window.
    let fabric = || {
        let mut f =
            Fabric::homogeneous(4, BandwidthTrace::constant(2e7), 0.2);
        f.set_bond(
            0,
            Bond::new(vec![
                Link::new(BandwidthTrace::constant(2e7), 0.2),
                Link::new(BandwidthTrace::constant(2e7), 0.2),
            ]),
        );
        f
    };
    let iters = 100;
    let run = |event: ChurnEvent| {
        let p = TrainParams {
            churn: ChurnSpec::Scripted {
                events: vec![TimedEvent { t: 30.0, event }],
            },
            ..params(iters)
        };
        run_bond(fabric(), StrategyKind::DSgd, p, 256, 1)
    };
    let (_, calm) = {
        let p = TrainParams { churn: ChurnSpec::none(), ..params(iters) };
        run_bond(fabric(), StrategyKind::DSgd, p, 256, 1)
    };
    let (_, path0) = run(ChurnEvent::PathOutage {
        worker: 0,
        path: 0,
        secs: 40.0,
    });
    let (_, whole) = run(ChurnEvent::LinkOutage { worker: 0, secs: 40.0 });
    assert_eq!(calm.total_iters, iters);
    assert_eq!(path0.total_iters, iters);
    assert_eq!(whole.total_iters, iters);
    assert!(
        path0.total_time < calm.total_time + 0.5 * 40.0,
        "one surviving path must absorb most of the outage: {} vs calm {}",
        path0.total_time,
        calm.total_time
    );
    assert!(
        whole.total_time > calm.total_time + 0.8 * 40.0,
        "a worker-level outage must stall all paths: {} vs calm {}",
        whole.total_time,
        calm.total_time
    );
    assert!(
        whole.total_time > path0.total_time,
        "all-paths-out costs strictly more than one-path-out"
    );
}

#[test]
fn out_of_range_path_indices_error_at_compile_time() {
    // the compile-time guard: a path-scoped event naming a path the bonded
    // worker doesn't have (or any path on a single-path worker) is a clear
    // error from ChurnSpec::compile_for, not a mid-run panic
    let spec = ChurnSpec::Scripted {
        events: vec![TimedEvent {
            t: 5.0,
            event: ChurnEvent::PathOutage { worker: 0, path: 2, secs: 10.0 },
        }],
    };
    let e = spec.compile_for(4, &[2, 1, 1, 1]).unwrap_err().to_string();
    assert!(e.contains("path 2"), "{e}");
    assert!(e.contains("2 path(s)"), "{e}");
    assert!(spec.compile(4).is_err(), "single-path workers have no path 2");
    let ok = ChurnSpec::Scripted {
        events: vec![TimedEvent {
            t: 5.0,
            event: ChurnEvent::PathOutage { worker: 0, path: 1, secs: 10.0 },
        }],
    };
    assert!(ok.compile_for(4, &[2, 1, 1, 1]).is_ok());
}
