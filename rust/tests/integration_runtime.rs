//! Cross-layer integration tests: rust hot path vs the AOT-lowered L1/L2
//! artifacts through PJRT. These are the tests that prove the three
//! implementations of the compression spec (jnp oracle, Pallas kernel, rust
//! BlockTopK) and the flat-parameter model convention actually agree.
//!
//! All tests skip (pass vacuously, with a note) when `artifacts/` is absent
//! so `cargo test` works before `make artifacts`.

use deco::compress::{BlockTopK, Compressor};
use deco::runtime::client::BatchInput;
use deco::runtime::{Manifest, Runtime};
use deco::util::{Json, Rng, SplitMix64};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn golden_compress_cross_language() {
    // python/tests/test_aot.py writes golden_compress.json from the SAME
    // SplitMix64 stream; rust must reproduce delta/e_new bit-for-bit.
    let Some(dir) = artifacts_dir() else { return };
    let path = dir.join("golden_compress.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!("skipping: golden fixture not written yet (run pytest)");
        return;
    };
    let g = Json::parse(&text).expect("golden json");
    let n = g.req_usize("n").unwrap();
    let k = g.req_usize("k").unwrap();
    let block = g.req_usize("block").unwrap();
    assert_eq!(block, deco::BLOCK);

    let mut gv = vec![0.0f32; n];
    let mut ev = vec![0.0f32; n];
    SplitMix64::new(g.req_f64("seed_g").unwrap() as u64).fill_f32_sym(&mut gv);
    SplitMix64::new(g.req_f64("seed_e").unwrap() as u64).fill_f32_sym(&mut ev);

    // fused EF step with blockwise top-k, same as the pallas kernel
    let mut a: Vec<f32> = gv.iter().zip(&ev).map(|(x, y)| x + y).collect();
    let stash = a.clone();
    let comp = BlockTopK::with_block(k as f64 / block as f64, block);
    let mut rng = Rng::new(0);
    let kept = comp.compress(&mut a, &mut rng);
    let e_new: Vec<f32> = stash.iter().zip(&a).map(|(s, d)| s - d).collect();

    assert_eq!(kept, g.req_usize("delta_nnz").unwrap());
    let delta_sum: f64 = a.iter().map(|&x| x as f64).sum();
    let enew_sum: f64 = e_new.iter().map(|&x| x as f64).sum();
    assert!(
        (delta_sum - g.req_f64("delta_sum").unwrap()).abs() < 1e-6,
        "delta_sum {delta_sum} vs {}",
        g.req_f64("delta_sum").unwrap()
    );
    assert!((enew_sum - g.req_f64("enew_sum").unwrap()).abs() < 1e-6);
    // head-by-head exact equality
    for (i, jv) in g.get("delta_head").unwrap().as_arr().unwrap().iter().enumerate() {
        assert_eq!(a[i], jv.as_f64().unwrap() as f32, "delta[{i}]");
    }
    for (i, jv) in g.get("enew_head").unwrap().as_arr().unwrap().iter().enumerate() {
        assert_eq!(e_new[i], jv.as_f64().unwrap() as f32, "e_new[{i}]");
    }
}

#[test]
fn pallas_compress_matches_rust_bitwise() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime");
    for (delta, name) in rt.manifest.compress_palette() {
        let exec = rt.compress_exec(&name).expect("compress exec");
        let mut rng = Rng::new(42 + (delta * 1000.0) as u64);
        let g: Vec<f32> = (0..exec.dim).map(|_| rng.normal_f32()).collect();
        let e: Vec<f32> = (0..exec.dim).map(|_| rng.normal_f32() * 0.3).collect();
        let (delta_vec, e_new) = exec.run(&g, &e).expect("pallas run");

        // rust twin
        let mut a: Vec<f32> = g.iter().zip(&e).map(|(x, y)| x + y).collect();
        let stash = a.clone();
        let comp = BlockTopK::new(delta);
        assert_eq!(comp.k_per_block(), exec.k_per_block);
        comp.compress(&mut a, &mut rng);
        let e_rust: Vec<f32> =
            stash.iter().zip(&a).map(|(s, d)| s - d).collect();

        assert_eq!(delta_vec, a, "delta mismatch at palette {delta}");
        assert_eq!(e_new, e_rust, "e_new mismatch at palette {delta}");
    }
}

#[test]
fn pallas_apply_matches_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime");
    let apply = rt.apply_exec().expect("apply exec");
    let mut rng = Rng::new(7);
    let x: Vec<f32> = (0..apply.dim).map(|_| rng.normal_f32()).collect();
    let u: Vec<f32> = (0..apply.dim).map(|_| rng.normal_f32()).collect();
    let lr = 0.07f32;
    let out = apply.run(&x, &u, lr).expect("apply run");
    for i in 0..apply.dim {
        let expect = x[i] - lr * u[i];
        assert!(
            (out[i] - expect).abs() <= expect.abs() * 1e-6 + 1e-7,
            "i={i}: {} vs {expect}",
            out[i]
        );
    }
}

#[test]
fn grad_module_trains_all_models() {
    // plain SGD on every AOT'd model must reduce its loss — proving the
    // (params, x, y) -> (loss, grad) convention works for every entry.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime");
    let manifest = Manifest::load(&dir).unwrap();
    for (name, m) in &manifest.models {
        if m.param_count > 1_000_000 {
            continue; // keep the test fast; big variants covered by examples
        }
        let exec = rt.grad_exec(name).expect("grad exec");
        let mut params = m.init_flat(5);
        let mut grad = vec![0.0f32; m.param_count];
        let mut rng = Rng::new(9);
        let xlen: usize = m.x_shape.iter().product();
        let ylen: usize = m.y_shape.iter().product();
        let classes = m
            .meta
            .get("classes")
            .and_then(|v| v.as_u64())
            .unwrap_or_else(|| {
                m.meta.get("vocab").and_then(|v| v.as_u64()).unwrap_or(10)
            }) as usize;
        let xf: Vec<f32> = (0..xlen).map(|_| rng.normal_f32()).collect();
        let xi: Vec<i32> =
            (0..xlen).map(|_| rng.below(classes) as i32).collect();
        let y: Vec<i32> =
            (0..ylen).map(|_| rng.below(classes) as i32).collect();
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for step in 0..12 {
            let x = if m.x_dtype == "f32" {
                BatchInput::F32(&xf)
            } else {
                BatchInput::I32(&xi)
            };
            let loss = exec.run(&params, x, &y, &mut grad).expect("run");
            if step == 0 {
                first = loss;
            }
            last = loss;
            for (p, g) in params.iter_mut().zip(&grad) {
                *p -= 0.1 * g;
            }
        }
        assert!(
            last < first,
            "{name}: loss did not decrease ({first} -> {last})"
        );
        // pad gradient must stay zero
        if let Some(pad) = m.tensors.iter().find(|t| t.name == "_pad") {
            assert!(
                grad[pad.offset..pad.offset + pad.size]
                    .iter()
                    .all(|&v| v == 0.0),
                "{name}: pad gradient non-zero"
            );
        }
    }
}

#[test]
fn pjrt_oracle_end_to_end_deco_run() {
    // 30 iterations of DeCo-SGD on the CNN through the full coordinator:
    // loss must drop and the controller must have chosen a (τ, δ).
    let Some(dir) = artifacts_dir() else { return };
    std::env::set_var("DECO_ARTIFACTS", dir.to_str().unwrap());
    let cfg = deco::config::ExperimentConfig {
        task: "cnn_fmnist".into(),
        workers: 2,
        gamma: 0.15,
        strategy: deco::strategy::StrategyKind::DecoSgd { update_every: 5 },
        network: deco::config::wan_network(1e8, 0.2, 3),
        stop: deco::config::StopConfig {
            max_iters: 30,
            loss_target: None,
            max_virtual_time: None,
        },
        seed: 2,
        t_comp: Some(0.04),
        s_g_bits: Some(208_000.0 * 32.0),
        log_every: 5,
        block_topk: true, // exercise the kernel-identical path end to end
        clip_norm: Some(5.0),
        churn: deco::elastic::ChurnSpec::None,
        drain: deco::elastic::DrainPolicy::Drop,
    };
    let mut env = deco::exp::ExpEnv::new();
    env.verbose = false;
    let res = env.run(&cfg).expect("run");
    assert_eq!(res.workers, 2);
    assert!(res.records.len() >= 5);
    let first = res.records.first().unwrap().loss;
    let last = res.records.last().unwrap().loss;
    assert!(last < first, "loss {first} -> {last}");
    let r = res.records.last().unwrap();
    assert!(r.delta > 0.0 && r.delta <= 1.0);
    assert!(r.tau >= 1, "WAN latency must force tau >= 1");
}
