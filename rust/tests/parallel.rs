//! Parallel-execution determinism (DESIGN.md §Parallel-Execution): the
//! worker pool must change *where* work runs, never *what* is computed.
//! A run with pool size 1 and the same run with a multi-thread pool must
//! agree bit-for-bit — final model, every record, and the virtual clock —
//! across strategies that exercise dynamic (τ, δ), the sharded aggregation
//! path (dim ≥ the shard threshold), and both compressor families.

use deco::config::{wan_network, ExperimentConfig, NetworkConfig, StopConfig};
use deco::coordinator::TrainLoop;
use deco::metrics::RunResult;
use deco::optim::Quadratic;
use deco::strategy::StrategyKind;

fn cfg(strategy: StrategyKind, block_topk: bool) -> ExperimentConfig {
    ExperimentConfig {
        task: "quadratic".into(),
        workers: 4,
        gamma: 0.01,
        strategy,
        network: wan_network(1e8, 0.2, 5),
        stop: StopConfig {
            max_iters: 40,
            loss_target: None,
            max_virtual_time: None,
        },
        seed: 13,
        t_comp: Some(0.05),
        s_g_bits: Some(124e6 * 32.0),
        log_every: 5,
        block_topk,
        clip_norm: Some(5.0),
        churn: deco::elastic::ChurnSpec::None,
        drain: deco::elastic::DrainPolicy::Drop,
    }
}

/// dim 65_536 crosses the sharded-aggregation threshold AND the parallel
/// worker-phase threshold, so a multi-thread pool exercises both engines.
fn run(c: &ExperimentConfig, threads: usize) -> (Vec<f32>, RunResult) {
    let dim = 65_536;
    let oracle = Quadratic::new(dim, c.workers, 0.5, 0.1, 0.3, 0.2, c.seed);
    let mut params = c.train_params(dim);
    params.threads = Some(threads);
    let mut tl =
        TrainLoop::new(oracle, c.strategy.build(), c.network.link(), params);
    assert_eq!(tl.threads(), threads.max(1));
    let res = tl.run("det");
    (tl.model().to_vec(), res)
}

fn assert_identical(c: &ExperimentConfig, label: &str) {
    let (x1, r1) = run(c, 1);
    assert!(!r1.records.is_empty(), "{label}: no records");
    assert!(r1.final_loss().is_finite(), "{label}: diverged");
    for threads in [2usize, 4, 7] {
        let (xt, rt) = run(c, threads);
        assert_eq!(x1, xt, "{label}: model diverges at {threads} threads");
        assert_eq!(
            r1.records, rt.records,
            "{label}: records diverge at {threads} threads"
        );
        assert_eq!(
            r1.total_time.to_bits(),
            rt.total_time.to_bits(),
            "{label}: virtual clock diverges at {threads} threads"
        );
        assert_eq!(r1.total_iters, rt.total_iters, "{label}: iter count");
    }
}

#[test]
fn deco_dynamic_tau_delta_bit_identical() {
    assert_identical(
        &cfg(StrategyKind::DecoSgd { update_every: 10 }, false),
        "deco-sgd/topk",
    );
}

#[test]
fn fixed_compression_bit_identical_blockwise() {
    assert_identical(
        &cfg(StrategyKind::DEfSgd { delta: 0.05 }, true),
        "d-ef-sgd/block_topk",
    );
}

#[test]
fn dense_identity_path_bit_identical() {
    // δ = 1 (Identity wire): exercises the dense-message sharding edge
    assert_identical(&cfg(StrategyKind::DdSgd { tau: 2 }, false), "dga/dense");
}

#[test]
fn sweep_parallelism_matches_serial_runs() {
    // the runner-level sweep (runs-on-threads) must equal one-by-one runs
    use deco::exp::{ExpEnv, TaskSpec};
    let mut env = ExpEnv::new();
    env.verbose = false;
    let task = TaskSpec::quadratic();
    let net: NetworkConfig = wan_network(1e8, 0.2, 3);
    let swept = env.sweep_strategies(&task, 4, &net, 0.05).unwrap();
    assert_eq!(swept.len(), 5);
    for (label, res) in &swept {
        let kind = StrategyKind::paper_baselines()
            .into_iter()
            .find(|k| k.label() == *label)
            .unwrap();
        let one = env.run(&task.config(4, kind, net.clone(), 0.05)).unwrap();
        assert_eq!(
            one.records, res.records,
            "{label}: sweep-parallel run differs from direct run"
        );
        assert_eq!(one.total_time.to_bits(), res.total_time.to_bits());
    }
}
