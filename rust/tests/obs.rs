//! Observability-layer integration tests (DESIGN.md §Observability):
//!
//! * span tiling — on real flat and two-tier runs, every worker's phase
//!   spans are non-overlapping, contiguous, within the tick, and sum to
//!   the tick's arrival delta (±1e-9 relative);
//! * transparency — a `NullSink` run is bit-identical to a traced run's
//!   training output (model bits, records, virtual times);
//! * determinism — the Perfetto export is byte-identical across reruns
//!   and pool sizes, and the stall attribution accounts for the whole
//!   makespan.

use deco::coordinator::{TrainLoop, TrainParams};
use deco::deco::DecoInput;
use deco::metrics::sink::BufferSink;
use deco::metrics::RunResult;
use deco::netsim::{BandwidthTrace, Fabric};
use deco::obs::{perfetto_string, Attribution, BufferTracer, TraceEvent};
use deco::optim::Quadratic;
use deco::strategy::StrategyKind;
use deco::topo::{RegionTopo, Topology};

const S_G: f64 = 1e8;
const T_COMP: f64 = 0.2;

fn params(max_iters: usize) -> TrainParams {
    TrainParams {
        gamma: 0.005,
        max_iters,
        log_every: 10,
        t_comp_override: Some(T_COMP),
        s_g_override: Some(S_G),
        fallback: DecoInput { s_g: S_G, a: 2e7, b: 0.2, t_comp: T_COMP },
        seed: 11,
        ..Default::default()
    }
}

fn quad() -> Quadratic {
    Quadratic::new(256, 4, 1.0, 0.2, 0.3, 0.3, 11)
}

fn flat_fabric() -> Fabric {
    Fabric::homogeneous(4, BandwidthTrace::constant(2e7), 0.2)
}

fn two_tier() -> (Fabric, Topology) {
    let fabric = Fabric::homogeneous(4, BandwidthTrace::constant(1e9), 0.005);
    let topo = Topology::TwoTier {
        regions: vec![
            RegionTopo::new(vec![0, 1], 0),
            RegionTopo::new(vec![2, 3], 2),
        ],
        wan: Fabric::homogeneous(2, BandwidthTrace::constant(2e7), 0.3),
    };
    (fabric, topo)
}

fn run_traced(
    fabric: Fabric,
    topo: Topology,
    kind: StrategyKind,
    threads: usize,
) -> (Vec<f32>, RunResult, Vec<TraceEvent>) {
    let mut p = params(60);
    p.threads = Some(threads);
    let mut tl =
        TrainLoop::try_with_topology(quad(), kind.build(), fabric, topo, p)
            .unwrap();
    let mut sink = BufferSink::new();
    let mut tracer = BufferTracer::new();
    let mut res = tl.run_traced("obs", &mut sink, &mut tracer).unwrap();
    res.records = sink.into_records();
    (tl.model().to_vec(), res, tracer.into_events())
}

/// Every worker's five spans tile [ts − t_comp, tc] exactly: contiguous,
/// non-overlapping, monotone, and their durations sum to the arrival
/// delta within 1e-9 relative.
fn assert_spans_tile(events: &[TraceEvent]) {
    let mut ticks = 0usize;
    for ev in events {
        let TraceEvent::Tick(tt) = ev else { continue };
        ticks += 1;
        let lo = tt.ts - tt.t_comp;
        let delta = tt.tc - lo;
        for wt in &tt.workers {
            let spans = &wt.spans;
            assert_eq!(
                spans[0].t0.to_bits(),
                lo.to_bits(),
                "iter {} worker {}: first span must start at compute",
                tt.iter,
                wt.worker
            );
            for i in 1..spans.len() {
                assert_eq!(
                    spans[i].t0.to_bits(),
                    spans[i - 1].t1.to_bits(),
                    "iter {} worker {}: span {i} not contiguous",
                    tt.iter,
                    wt.worker
                );
            }
            for s in spans {
                assert!(s.t1 >= s.t0, "negative span at iter {}", tt.iter);
            }
            assert_eq!(
                spans[4].t1.to_bits(),
                tt.tc.to_bits(),
                "iter {} worker {}: last span must end at the arrival",
                tt.iter,
                wt.worker
            );
            let sum: f64 = spans.iter().map(|s| s.dur()).sum();
            assert!(
                (sum - delta).abs() <= 1e-9 * delta.max(1.0),
                "iter {} worker {}: spans sum {sum} vs delta {delta}",
                tt.iter,
                wt.worker
            );
        }
    }
    assert!(ticks > 0, "the trace must contain tick events");
}

#[test]
fn flat_run_spans_tile_the_tick() {
    let (_, _, events) = run_traced(
        flat_fabric(),
        Topology::Flat,
        StrategyKind::DecoSgd { update_every: 20 },
        1,
    );
    assert_spans_tile(&events);
}

#[test]
fn two_tier_run_spans_tile_the_tick() {
    let (fabric, topo) = two_tier();
    let (_, _, events) = run_traced(
        fabric,
        topo,
        StrategyKind::DecoTwoTier { update_every: 20 },
        1,
    );
    assert_spans_tile(&events);
    // region tracks exist on a two-tier run
    let has_regions = events.iter().any(|ev| {
        matches!(ev, TraceEvent::Tick(tt) if !tt.regions.is_empty())
    });
    assert!(has_regions, "two-tier ticks must carry region traces");
}

#[test]
fn tracing_is_transparent_to_training() {
    let kind = StrategyKind::DecoSgd { update_every: 20 };
    let mut p = params(60);
    p.threads = Some(1);
    let mut tl = TrainLoop::try_with_topology(
        quad(),
        kind.clone().build(),
        flat_fabric(),
        Topology::Flat,
        p,
    )
    .unwrap();
    let plain = tl.run("obs");
    let model = tl.model().to_vec();

    let (tmodel, traced, _) =
        run_traced(flat_fabric(), Topology::Flat, kind, 1);
    assert_eq!(model.len(), tmodel.len());
    for (i, (a, b)) in model.iter().zip(&tmodel).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "model diverges at {i}");
    }
    assert_eq!(plain.total_iters, traced.total_iters);
    assert_eq!(plain.total_time.to_bits(), traced.total_time.to_bits());
    assert_eq!(plain.records.len(), traced.records.len());
    for (ra, rb) in plain.records.iter().zip(&traced.records) {
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "iter {}", ra.iter);
        assert_eq!(ra.time.to_bits(), rb.time.to_bits(), "iter {}", ra.iter);
    }
}

#[test]
fn perfetto_export_is_deterministic_across_pool_sizes() {
    let kind = StrategyKind::DecoSgd { update_every: 20 };
    let (_, _, serial) =
        run_traced(flat_fabric(), Topology::Flat, kind.clone(), 1);
    let (_, _, pooled) = run_traced(flat_fabric(), Topology::Flat, kind, 4);
    let a = perfetto_string(&serial);
    let b = perfetto_string(&pooled);
    assert!(!a.is_empty());
    assert_eq!(a, b, "trace bytes must not depend on the pool size");
    // the trace carries the re-plan decision log
    let replans = serial
        .iter()
        .filter(|ev| matches!(ev, TraceEvent::Replan { .. }))
        .count();
    assert!(replans > 0, "DeCo runs must log re-plan decisions");
}

#[test]
fn attribution_accounts_for_the_whole_run() {
    for (fabric, topo, kind) in [
        (
            flat_fabric(),
            Topology::Flat,
            StrategyKind::DecoSgd { update_every: 20 },
        ),
        {
            let (f, t) = two_tier();
            (f, t, StrategyKind::DecoTwoTier { update_every: 20 })
        },
    ] {
        let (_, res, events) = run_traced(fabric, topo, kind, 1);
        let mut attr = Attribution::new();
        for ev in &events {
            if let TraceEvent::Tick(tt) = ev {
                attr.record_tick(tt);
            }
        }
        assert!(attr.makespan() > 0.0);
        assert!(
            (attr.makespan() - res.total_time).abs()
                <= 1e-9 * res.total_time,
            "makespan {} vs run virtual time {}",
            attr.makespan(),
            res.total_time
        );
        let gap = (attr.attributed() - attr.makespan()).abs();
        assert!(
            gap <= 1e-6 * attr.makespan(),
            "attribution lost {gap}s of {}s",
            attr.makespan()
        );
        let f = attr.straggler_fraction()
            + attr.transfer_fraction()
            + attr.compute_fraction();
        assert!((f - 1.0).abs() < 1e-9, "fractions sum to {f}");
    }
}
