//! Run metrics: per-iteration records, time-to-target extraction (the
//! paper's headline quantity), and CSV/JSON writers for the experiment
//! generators.
//!
//! Two consumption styles share one row format: [`RunResult`] buffers every
//! [`Record`] (the small-run / analysis path) while [`sink::CsvSink`]
//! streams rows to disk with O(1) memory and folds the summary statistics
//! incrementally (the 100k-worker path — DESIGN.md §Perf). Both emit rows
//! through [`csv_header`]/[`csv_row`], so the streamed file is
//! byte-identical to `RunResult::to_csv`.

pub mod sink;

use std::io::Write;
use std::path::Path;

/// One region's entry in a logged step of a two-tier run (DESIGN.md
/// §Topology).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RegionRecord {
    /// absolute virtual time this region's partial was ready (0.0 while
    /// the region had no active member)
    pub sync: f64,
    /// cumulative bits shipped across this region's WAN link so far
    pub wan_bits: u64,
}

/// One logged training step.
#[derive(Clone, Debug, PartialEq)]
pub struct Record {
    pub iter: usize,
    /// virtual wall-clock (s) when this iteration's update *arrived*
    pub time: f64,
    /// full (deterministic) global loss from the oracle's evaluation pass
    pub loss: f64,
    /// average per-worker *training* loss of this iteration's minibatches —
    /// already computed by the gradient pass, and the signal the
    /// between-boundary divergence guard watches
    pub train_loss: f64,
    pub tau: usize,
    pub delta: f64,
    pub grad_norm: f64,
    /// instantaneous bandwidth estimate when logged (bits/s, 0 if unknown)
    pub bandwidth: f64,
    /// WAN-tier compression ratio (1.0 on flat runs / tier-blind plans)
    pub wan_delta: f64,
    /// per-region sync time + WAN bits (empty on flat runs). Every record
    /// of a run must carry the same region count — the CSV/JSON writers
    /// enforce it as a hard error.
    pub regions: Vec<RegionRecord>,
}

/// A completed training run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RunResult {
    pub method: String,
    pub task: String,
    pub workers: usize,
    pub records: Vec<Record>,
    /// total virtual time at the last executed iteration
    pub total_time: f64,
    pub total_iters: usize,
}

impl RunResult {
    /// First virtual time at which the loss reaches `target` (≤), linearly
    /// interpolated between the straddling records. `None` if never reached.
    pub fn time_to_loss(&self, target: f64) -> Option<f64> {
        let mut prev: Option<&Record> = None;
        for r in &self.records {
            if r.loss <= target {
                return Some(match prev {
                    Some(p) if p.loss > r.loss => {
                        let w = (p.loss - target) / (p.loss - r.loss);
                        p.time + w * (r.time - p.time)
                    }
                    _ => r.time,
                });
            }
            prev = Some(r);
        }
        None
    }

    /// First iteration index reaching the loss target.
    pub fn iters_to_loss(&self, target: f64) -> Option<usize> {
        self.records.iter().find(|r| r.loss <= target).map(|r| r.iter)
    }

    /// Perplexity convenience for LM tasks: time to `exp(loss) <= ppl`.
    pub fn time_to_ppl(&self, ppl: f64) -> Option<f64> {
        self.time_to_loss(ppl.ln())
    }

    pub fn final_loss(&self) -> f64 {
        self.records.last().map(|r| r.loss).unwrap_or(f64::NAN)
    }

    /// Best (minimum) loss seen.
    pub fn best_loss(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.loss)
            .fold(f64::INFINITY, f64::min)
    }

    /// Region count carried by this run's records. Hard error (panic) when
    /// records disagree — a mismatched row would silently misalign every
    /// column to its right, so the writers refuse to emit it.
    fn region_columns(&self) -> usize {
        let n = self.records.first().map_or(0, |r| r.regions.len());
        for r in &self.records {
            assert_eq!(
                r.regions.len(),
                n,
                "record at iter {} carries {} region entries but this run's \
                 header has {n}: refusing to write misaligned CSV/JSON",
                r.iter,
                r.regions.len()
            );
        }
        n
    }

    pub fn to_csv(&self) -> String {
        let nregions = self.region_columns();
        let mut s = csv_header(nregions);
        s.push('\n');
        for r in &self.records {
            s.push_str(&csv_row(r, nregions));
            s.push('\n');
        }
        s
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        ensure_parent(path)?;
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }

    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        self.region_columns(); // same hard error as the CSV writer
        Json::obj(vec![
            ("method", Json::str(&self.method)),
            ("task", Json::str(&self.task)),
            ("workers", Json::num(self.workers as f64)),
            ("total_time", Json::num(self.total_time)),
            ("total_iters", Json::num(self.total_iters as f64)),
            (
                "records",
                Json::arr(self.records.iter().map(|r| {
                    let mut pairs = vec![
                        ("iter", Json::num(r.iter as f64)),
                        ("time", Json::num(r.time)),
                        ("loss", Json::num(r.loss)),
                        ("train_loss", Json::num(r.train_loss)),
                        ("tau", Json::num(r.tau as f64)),
                        ("delta", Json::num(r.delta)),
                        ("grad_norm", Json::num(r.grad_norm)),
                        ("bandwidth", Json::num(r.bandwidth)),
                    ];
                    if !r.regions.is_empty() {
                        pairs.push(("wan_delta", Json::num(r.wan_delta)));
                        pairs.push((
                            "regions",
                            Json::arr(r.regions.iter().map(|reg| {
                                Json::obj(vec![
                                    ("sync", Json::num(reg.sync)),
                                    (
                                        "wan_bits",
                                        Json::num(reg.wan_bits as f64),
                                    ),
                                ])
                            })),
                        ));
                    }
                    Json::obj(pairs)
                })),
            ),
        ])
    }

    pub fn write_json(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        ensure_parent(path)?;
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_json().to_string_pretty().as_bytes())
    }
}

/// The CSV header line (no trailing newline) for a run whose records carry
/// `nregions` region entries. The single source of the column layout —
/// shared by [`RunResult::to_csv`] and the streaming [`sink::CsvSink`].
pub fn csv_header(nregions: usize) -> String {
    let mut header = vec![
        "iter".to_string(),
        "time".into(),
        "loss".into(),
        "train_loss".into(),
        "tau".into(),
        "delta".into(),
        "grad_norm".into(),
        "bandwidth".into(),
    ];
    if nregions > 0 {
        header.push("wan_delta".into());
        for r in 0..nregions {
            header.push(format!("region{r}_sync"));
            header.push(format!("region{r}_wan_bits"));
        }
    }
    header.join(",")
}

/// One CSV row (no trailing newline) under an `nregions`-column header.
/// Panics on a region-count mismatch — a misaligned row would silently
/// shift every column to its right.
pub fn csv_row(r: &Record, nregions: usize) -> String {
    let mut cells = vec![
        r.iter.to_string(),
        format!("{:.6}", r.time),
        format!("{:.6}", r.loss),
        format!("{:.6}", r.train_loss),
        r.tau.to_string(),
        format!("{:.4}", r.delta),
        format!("{:.6}", r.grad_norm),
        format!("{:.0}", r.bandwidth),
    ];
    if nregions > 0 {
        cells.push(format!("{:.4}", r.wan_delta));
        assert_eq!(
            r.regions.len(),
            nregions,
            "record at iter {} carries {} region entries but this run's \
             header has {nregions}: refusing to write misaligned CSV/JSON",
            r.iter,
            r.regions.len()
        );
        for reg in &r.regions {
            cells.push(format!("{:.6}", reg.sync));
            cells.push(reg.wan_bits.to_string());
        }
    }
    cells.join(",")
}

/// Create the parent directory of `path` if it doesn't exist yet, so
/// `repro train --out results/nested/x.csv` works on a fresh checkout.
fn ensure_parent(path: &Path) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    Ok(())
}

/// Pretty-print a table of (method, value) rows — the experiment CLIs all
/// report through this.
pub fn format_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:>w$}"))
            .collect::<Vec<_>>()
            .join("  ")
    };
    out.push_str(&fmt_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(iter: usize, time: f64, loss: f64) -> Record {
        Record {
            iter,
            time,
            loss,
            train_loss: loss,
            tau: 0,
            delta: 1.0,
            grad_norm: 0.0,
            bandwidth: 0.0,
            wan_delta: 1.0,
            regions: Vec::new(),
        }
    }

    #[test]
    fn time_to_loss_interpolates() {
        let run = RunResult {
            records: vec![rec(0, 0.0, 10.0), rec(10, 1.0, 6.0), rec(20, 2.0, 2.0)],
            ..Default::default()
        };
        // target 4.0 is halfway between 6.0@1s and 2.0@2s
        let t = run.time_to_loss(4.0).unwrap();
        assert!((t - 1.5).abs() < 1e-12, "t={t}");
        assert_eq!(run.time_to_loss(10.0), Some(0.0));
        assert_eq!(run.time_to_loss(1.0), None);
        assert_eq!(run.iters_to_loss(6.0), Some(10));
    }

    #[test]
    fn ppl_is_exp_loss() {
        let run = RunResult {
            records: vec![rec(0, 0.0, 4.0), rec(1, 1.0, 3.0)],
            ..Default::default()
        };
        assert_eq!(run.time_to_ppl(3.0f64.exp()), Some(1.0));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let run = RunResult {
            method: "deco".into(),
            records: vec![rec(1, 0.5, 2.0)],
            ..Default::default()
        };
        let csv = run.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("iter,time,loss"));
    }

    #[test]
    fn two_tier_csv_emits_per_region_columns() {
        let mut r1 = rec(1, 0.5, 2.0);
        r1.wan_delta = 0.02;
        r1.regions = vec![
            RegionRecord { sync: 0.12, wan_bits: 1_000_000 },
            RegionRecord { sync: 0.11, wan_bits: 1_000_000 },
        ];
        let mut r2 = rec(2, 1.0, 1.5);
        r2.wan_delta = 0.02;
        r2.regions = vec![
            RegionRecord { sync: 0.62, wan_bits: 2_000_000 },
            RegionRecord { sync: 0.61, wan_bits: 2_000_000 },
        ];
        let run = RunResult {
            method: "deco-2tier".into(),
            records: vec![r1, r2],
            ..Default::default()
        };
        let csv = run.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(header.ends_with(
            "wan_delta,region0_sync,region0_wan_bits,region1_sync,\
             region1_wan_bits"
        ));
        for line in csv.lines() {
            assert_eq!(
                line.split(',').count(),
                header.split(',').count(),
                "self-describing: every row matches the header"
            );
        }
        assert!(csv.contains("2000000"));
        // JSON carries the same per-region data
        let json = run.to_json().to_string_pretty();
        assert!(json.contains("\"regions\"") && json.contains("\"sync\""));
    }

    #[test]
    #[should_panic(expected = "misaligned")]
    fn region_count_mismatch_is_a_hard_error() {
        let mut r1 = rec(1, 0.5, 2.0);
        r1.regions = vec![RegionRecord { sync: 0.1, wan_bits: 10 }];
        let r2 = rec(2, 1.0, 1.5); // no regions: header/row mismatch
        let run = RunResult {
            records: vec![r1, r2],
            ..Default::default()
        };
        let _ = run.to_csv();
    }

    #[test]
    fn writers_create_missing_parent_dirs() {
        let run = RunResult {
            method: "deco".into(),
            records: vec![rec(1, 0.5, 2.0)],
            ..Default::default()
        };
        let base = std::env::temp_dir().join(format!(
            "deco_metrics_test_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let csv_path = base.join("nested/deeper/run.csv");
        run.write_csv(&csv_path).expect("csv into fresh nested dir");
        let json_path = base.join("other/run.json");
        run.write_json(&json_path).expect("json into fresh nested dir");
        assert!(csv_path.exists() && json_path.exists());
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn table_formatting_aligns() {
        let t = format_table(
            &["method", "time"],
            &[
                vec!["dsgd".into(), "100.0".into()],
                vec!["deco-sgd".into(), "19.7".into()],
            ],
        );
        assert!(t.contains("deco-sgd"));
        assert_eq!(t.lines().count(), 4);
    }
}
