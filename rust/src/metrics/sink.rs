//! Streaming metrics sinks (DESIGN.md §Perf).
//!
//! Buffering every [`Record`] of a 100k-worker sweep cell is the memory
//! bottleneck long before the clock is the time bottleneck, so the
//! training loop can hand each record to a [`MetricsSink`] the moment it
//! is logged instead of growing a `Vec`. [`CsvSink`] writes rows through
//! the same [`csv_header`]/[`csv_row`] helpers `RunResult::to_csv` uses —
//! the streamed file is **byte-identical** to the buffered one (a
//! regression test in `tests/properties.rs` holds the two side by side) —
//! while [`RunFolds`] folds the summary statistics (time-to-target,
//! final/best loss) incrementally with the exact interpolation arithmetic
//! of [`RunResult::time_to_loss`]. [`BufferSink`] is the compatibility
//! adapter: it just collects, and `TrainLoop::run` is
//! `run_streamed(BufferSink)`.

use std::io::Write;

use super::{csv_header, csv_row, Record, RunResult};

/// A consumer of training records, fed one record per log boundary in
/// iteration order.
pub trait MetricsSink {
    fn record(&mut self, rec: &Record) -> anyhow::Result<()>;
}

/// The buffering sink: collects records for a [`RunResult`] — the
/// historical behaviour, fine for analysis-sized runs.
#[derive(Debug, Default)]
pub struct BufferSink {
    records: Vec<Record>,
}

impl BufferSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_records(self) -> Vec<Record> {
        self.records
    }
}

impl MetricsSink for BufferSink {
    fn record(&mut self, rec: &Record) -> anyhow::Result<()> {
        self.records.push(rec.clone());
        Ok(())
    }
}

/// Incremental folds over a record stream: everything the experiment
/// tables need from a run, without retaining the run. The interpolation
/// is bit-for-bit [`RunResult::time_to_loss`]'s —
/// `prop_streamed_csv_matches_buffered_run` in `tests/properties.rs`
/// pins the equivalence.
#[derive(Clone, Debug)]
pub struct RunFolds {
    /// loss targets being watched, in the caller's order
    targets: Vec<f64>,
    time_to: Vec<Option<f64>>,
    iters_to: Vec<Option<usize>>,
    /// (time, loss) of the previous record — the straddle for the
    /// interpolated crossing
    prev: Option<(f64, f64)>,
    final_loss: f64,
    best_loss: f64,
    records: usize,
}

impl RunFolds {
    pub fn new(targets: &[f64]) -> Self {
        Self {
            targets: targets.to_vec(),
            time_to: vec![None; targets.len()],
            iters_to: vec![None; targets.len()],
            prev: None,
            final_loss: f64::NAN,
            best_loss: f64::INFINITY,
            records: 0,
        }
    }

    pub fn observe(&mut self, rec: &Record) {
        for (i, &target) in self.targets.iter().enumerate() {
            if self.time_to[i].is_some() || rec.loss > target {
                continue;
            }
            // first record at or under the target: interpolate the
            // crossing against the straddling predecessor, exactly like
            // the buffered scan (which guards against non-decreasing loss)
            self.time_to[i] = Some(match self.prev {
                Some((pt, pl)) if pl > rec.loss => {
                    let w = (pl - target) / (pl - rec.loss);
                    pt + w * (rec.time - pt)
                }
                _ => rec.time,
            });
            self.iters_to[i] = Some(rec.iter);
        }
        self.prev = Some((rec.time, rec.loss));
        self.final_loss = rec.loss;
        self.best_loss = self.best_loss.min(rec.loss);
        self.records += 1;
    }

    /// The loss targets being watched.
    pub fn targets(&self) -> &[f64] {
        &self.targets
    }

    /// First virtual time reaching target `i` (interpolated), if ever.
    pub fn time_to(&self, i: usize) -> Option<f64> {
        self.time_to[i]
    }

    /// First logged iteration reaching target `i`, if ever.
    pub fn iters_to(&self, i: usize) -> Option<usize> {
        self.iters_to[i]
    }

    /// Loss of the last record ([`f64::NAN`] before any).
    pub fn final_loss(&self) -> f64 {
        self.final_loss
    }

    /// Minimum loss seen ([`f64::INFINITY`] before any).
    pub fn best_loss(&self) -> f64 {
        self.best_loss
    }

    /// Records observed.
    pub fn records(&self) -> usize {
        self.records
    }
}

/// Bounded-memory CSV writer: the header is emitted lazily from the first
/// record's region count, every later record must match it (the streaming
/// form of `RunResult::region_columns`' hard error), and a [`RunFolds`]
/// rides along so the summary statistics survive without the rows.
#[derive(Debug)]
pub struct CsvSink<W: Write> {
    out: W,
    /// region-column count, fixed by the first record
    nregions: Option<usize>,
    folds: RunFolds,
}

impl<W: Write> CsvSink<W> {
    pub fn new(out: W, targets: &[f64]) -> Self {
        Self { out, nregions: None, folds: RunFolds::new(targets) }
    }

    /// The incremental summary folds (readable mid-stream).
    pub fn folds(&self) -> &RunFolds {
        &self.folds
    }

    /// Flush and hand back the writer plus the folded summary.
    pub fn finish(mut self) -> anyhow::Result<(W, RunFolds)> {
        self.out.flush()?;
        Ok((self.out, self.folds))
    }
}

impl<W: Write> MetricsSink for CsvSink<W> {
    fn record(&mut self, rec: &Record) -> anyhow::Result<()> {
        let nregions = match self.nregions {
            Some(n) => n,
            None => {
                let n = rec.regions.len();
                self.out.write_all(csv_header(n).as_bytes())?;
                self.out.write_all(b"\n")?;
                self.nregions = Some(n);
                n
            }
        };
        if rec.regions.len() != nregions {
            anyhow::bail!(
                "record at iter {} carries {} region entries but this \
                 stream's header has {nregions}: refusing to write \
                 misaligned CSV",
                rec.iter,
                rec.regions.len()
            );
        }
        self.out.write_all(csv_row(rec, nregions).as_bytes())?;
        self.out.write_all(b"\n")?;
        self.folds.observe(rec);
        Ok(())
    }
}

/// Folds-only sink for runs whose rows nobody reads (capacity probes,
/// resume fingerprint checks): O(1) memory, no I/O.
#[derive(Debug)]
pub struct FoldSink {
    folds: RunFolds,
}

impl FoldSink {
    pub fn new(targets: &[f64]) -> Self {
        Self { folds: RunFolds::new(targets) }
    }

    pub fn folds(&self) -> &RunFolds {
        &self.folds
    }

    pub fn into_folds(self) -> RunFolds {
        self.folds
    }
}

impl MetricsSink for FoldSink {
    fn record(&mut self, rec: &Record) -> anyhow::Result<()> {
        self.folds.observe(rec);
        Ok(())
    }
}

/// Fold an already-buffered [`RunResult`] — the bridge for comparing the
/// streamed statistics against the buffered scans.
pub fn fold_run(run: &RunResult, targets: &[f64]) -> RunFolds {
    let mut folds = RunFolds::new(targets);
    for rec in &run.records {
        folds.observe(rec);
    }
    folds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(iter: usize, time: f64, loss: f64) -> Record {
        Record {
            iter,
            time,
            loss,
            train_loss: loss,
            tau: 0,
            delta: 1.0,
            grad_norm: 0.0,
            bandwidth: 0.0,
            wan_delta: 1.0,
            regions: Vec::new(),
        }
    }

    #[test]
    fn folds_match_the_buffered_scans() {
        let run = RunResult {
            records: vec![
                rec(0, 0.0, 10.0),
                rec(10, 1.0, 6.0),
                rec(20, 2.0, 2.0),
                rec(30, 3.0, 2.5), // non-monotone tail
            ],
            ..Default::default()
        };
        let targets = [10.0, 4.0, 2.2, 1.0];
        let folds = fold_run(&run, &targets);
        for (i, &t) in targets.iter().enumerate() {
            let bt = run.time_to_loss(t);
            let ft = folds.time_to(i);
            match (bt, ft) {
                (None, None) => {}
                (Some(b), Some(f)) => {
                    assert_eq!(b.to_bits(), f.to_bits(), "target {t}")
                }
                other => panic!("target {t}: {other:?}"),
            }
            assert_eq!(run.iters_to_loss(t), folds.iters_to(i));
        }
        assert_eq!(folds.final_loss().to_bits(), run.final_loss().to_bits());
        assert_eq!(folds.best_loss().to_bits(), run.best_loss().to_bits());
        assert_eq!(folds.records(), run.records.len());
    }

    #[test]
    fn csv_sink_streams_byte_identical_rows() {
        let records =
            vec![rec(1, 0.5, 2.0), rec(2, 1.0, 1.5), rec(3, 1.5, 1.2)];
        let run = RunResult {
            records: records.clone(),
            ..Default::default()
        };
        let mut sink = CsvSink::new(Vec::new(), &[1.4]);
        for r in &records {
            sink.record(r).unwrap();
        }
        let (bytes, folds) = sink.finish().unwrap();
        assert_eq!(bytes, run.to_csv().into_bytes());
        assert_eq!(
            folds.time_to(0).unwrap().to_bits(),
            run.time_to_loss(1.4).unwrap().to_bits()
        );
    }

    #[test]
    fn csv_sink_rejects_region_count_drift() {
        let mut sink = CsvSink::new(Vec::new(), &[]);
        sink.record(&rec(1, 0.5, 2.0)).unwrap();
        let mut bad = rec(2, 1.0, 1.5);
        bad.regions =
            vec![super::super::RegionRecord { sync: 0.1, wan_bits: 10 }];
        let err = sink.record(&bad).unwrap_err();
        assert!(err.to_string().contains("misaligned"), "{err}");
    }

    #[test]
    fn buffer_sink_collects_in_order() {
        let mut sink = BufferSink::new();
        for r in [rec(1, 0.5, 2.0), rec(2, 1.0, 1.5)] {
            sink.record(&r).unwrap();
        }
        let recs = sink.into_records();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].iter, 1);
        assert_eq!(recs[1].iter, 2);
    }
}
