//! Synthetic datasets — stand-ins for FashionMNIST / CIFAR-10 / ImageNet /
//! Wikitext (see DESIGN.md §Hardware-Adaptation for why the substitution
//! preserves the paper's claims: the evaluation compares *time-to-target
//! between methods*, which depends on gradient dynamics and network state,
//! not on the specific corpus).
//!
//! Both generators are deterministic functions of (seed, index) and shard
//! across `n` workers by interleaving, so every experiment is reproducible
//! and worker shards are disjoint (the paper's data-parallel setting).

pub mod image;
pub mod text;

pub use image::{ImageBatch, SyntheticImages};
pub use text::{LmBatch, SyntheticCorpus};

/// A worker's view of a dataset: batch `t` for worker `i` must be
/// deterministic so re-runs and baselines see identical data streams.
pub trait Sharded {
    type Batch;
    fn batch(&self, worker: usize, iter: usize) -> Self::Batch;
}
