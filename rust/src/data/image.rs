//! Synthetic image classification — Gaussian class prototypes + noise.
//!
//! Class `c` has a fixed prototype image `P_c` (seeded); a sample is
//! `x = P_c + noise_scale * n` with iid Gaussian pixels. This yields a task
//! that is genuinely learnable (a CNN reaches high accuracy) but not
//! trivially linear (noise_scale controls difficulty / gradient noise σ —
//! the knob the theory experiments sweep).
//!
//! Heterogeneity (the paper's ζ): with `dirichlet_alpha < inf`, workers draw
//! classes from skewed distributions — worker i over-represents class
//! (i mod classes) — producing the non-IID gradients of the FL regime.

use super::Sharded;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct ImageBatch {
    /// NHWC f32 pixels
    pub x: Vec<f32>,
    /// class ids
    pub y: Vec<i32>,
    pub shape: (usize, usize, usize, usize),
}

#[derive(Clone, Debug)]
pub struct SyntheticImages {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub classes: usize,
    pub batch: usize,
    pub noise_scale: f32,
    /// 0 = IID across workers; larger skews each worker's class mix
    pub skew: f32,
    seed: u64,
    prototypes: Vec<f32>, // classes × H × W × C
}

impl SyntheticImages {
    pub fn new(
        height: usize,
        width: usize,
        channels: usize,
        classes: usize,
        batch: usize,
        seed: u64,
    ) -> Self {
        let hw = height * width * channels;
        let mut rng = Rng::new(seed ^ 0xDA7A);
        let mut prototypes = vec![0.0f32; classes * hw];
        rng.fill_normal_f32(&mut prototypes, 1.0);
        Self {
            height,
            width,
            channels,
            classes,
            batch,
            noise_scale: 0.7,
            skew: 0.0,
            seed,
            prototypes,
        }
    }

    pub fn with_noise(mut self, noise: f32) -> Self {
        self.noise_scale = noise;
        self
    }

    pub fn with_skew(mut self, skew: f32) -> Self {
        self.skew = skew;
        self
    }

    fn pixel_count(&self) -> usize {
        self.height * self.width * self.channels
    }

    fn sample_class(&self, rng: &mut Rng, worker: usize) -> usize {
        if self.skew <= 0.0 {
            return rng.below(self.classes);
        }
        // worker's favorite class gets probability boosted by `skew`
        let fav = worker % self.classes;
        let p_fav = (1.0 + self.skew as f64) / (self.classes as f64 + self.skew as f64);
        if rng.next_f64() < p_fav {
            fav
        } else {
            rng.below(self.classes)
        }
    }
}

impl Sharded for SyntheticImages {
    type Batch = ImageBatch;

    fn batch(&self, worker: usize, iter: usize) -> ImageBatch {
        let hw = self.pixel_count();
        let mut rng = Rng::new(
            self.seed
                ^ (worker as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (iter as u64).wrapping_mul(0xD1B54A32D192ED03),
        );
        let mut x = vec![0.0f32; self.batch * hw];
        let mut y = vec![0i32; self.batch];
        for bi in 0..self.batch {
            let c = self.sample_class(&mut rng, worker);
            y[bi] = c as i32;
            let proto = &self.prototypes[c * hw..(c + 1) * hw];
            let dst = &mut x[bi * hw..(bi + 1) * hw];
            for (d, p) in dst.iter_mut().zip(proto) {
                *d = p + self.noise_scale * rng.normal_f32();
            }
        }
        ImageBatch {
            x,
            y,
            shape: (self.batch, self.height, self.width, self.channels),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> SyntheticImages {
        SyntheticImages::new(8, 8, 1, 10, 16, 42)
    }

    #[test]
    fn deterministic_batches() {
        let d = ds();
        let b1 = d.batch(0, 5);
        let b2 = d.batch(0, 5);
        assert_eq!(b1.x, b2.x);
        assert_eq!(b1.y, b2.y);
    }

    #[test]
    fn workers_get_disjoint_streams() {
        let d = ds();
        assert_ne!(d.batch(0, 0).x, d.batch(1, 0).x);
        assert_ne!(d.batch(0, 0).x, d.batch(0, 1).x);
    }

    #[test]
    fn shapes_and_labels_valid() {
        let d = ds();
        let b = d.batch(2, 3);
        assert_eq!(b.x.len(), 16 * 8 * 8);
        assert_eq!(b.y.len(), 16);
        assert!(b.y.iter().all(|&c| (0..10).contains(&c)));
    }

    #[test]
    fn classes_separable() {
        // samples of the same class are closer to their prototype than to
        // other prototypes on average (the task is learnable)
        let d = ds().with_noise(0.3);
        let b = d.batch(0, 0);
        let hw = 64;
        for bi in 0..b.y.len() {
            let c = b.y[bi] as usize;
            let xi = &b.x[bi * hw..(bi + 1) * hw];
            let own: f32 = xi
                .iter()
                .zip(&d.prototypes[c * hw..(c + 1) * hw])
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            let other_c = (c + 1) % 10;
            let other: f32 = xi
                .iter()
                .zip(&d.prototypes[other_c * hw..(other_c + 1) * hw])
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            assert!(own < other, "sample {bi} closer to wrong prototype");
        }
    }

    #[test]
    fn skew_biases_label_distribution() {
        let d = ds().with_skew(8.0);
        let mut count_fav = 0;
        let mut total = 0;
        for it in 0..50 {
            let b = d.batch(3, it); // favorite class = 3
            count_fav += b.y.iter().filter(|&&c| c == 3).count();
            total += b.y.len();
        }
        let frac = count_fav as f64 / total as f64;
        assert!(frac > 0.3, "frac={frac} (IID would be 0.1)");
    }
}
