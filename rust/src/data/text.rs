//! Synthetic language-modeling corpus — the Wikitext stand-in.
//!
//! A seeded order-1 Markov chain over the vocabulary with Zipf-distributed
//! marginals and sparse, peaked transition rows. The resulting stream has
//! (a) non-uniform unigram stats, (b) strong local structure a causal LM can
//! learn (perplexity drops well below vocab), (c) enough entropy that loss
//! does not collapse to zero — the properties that matter for reproducing
//! time-to-perplexity comparisons between optimizers.

use super::Sharded;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct LmBatch {
    /// input tokens, B × T
    pub x: Vec<i32>,
    /// next-token targets, B × T
    pub y: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

#[derive(Clone, Debug)]
pub struct SyntheticCorpus {
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    seed: u64,
    /// per-token successor table: `branch` candidates per token
    successors: Vec<u32>,
    branch: usize,
    /// Zipf sampling alias table (cheap: cdf + binary search)
    zipf_cdf: Vec<f64>,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seq: usize, batch: usize, seed: u64) -> Self {
        let branch = 4;
        let mut rng = Rng::new(seed ^ 0x7E87);
        let successors: Vec<u32> = (0..vocab * branch)
            .map(|_| rng.below(vocab) as u32)
            .collect();
        // Zipf(1.1) cdf over the vocab
        let mut weights: Vec<f64> = (1..=vocab)
            .map(|r| 1.0 / (r as f64).powf(1.1))
            .collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        for w in weights.iter_mut() {
            acc += *w / total;
            *w = acc;
        }
        Self { vocab, seq, batch, seed, successors, branch, zipf_cdf: weights }
    }

    fn zipf(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        self.zipf_cdf.partition_point(|&c| c < u).min(self.vocab - 1)
    }

    /// Generate `len + 1` tokens of the chain (inputs + final target).
    fn gen_stream(&self, rng: &mut Rng, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(len + 1);
        let mut tok = self.zipf(rng);
        out.push(tok as i32);
        for _ in 0..len {
            // 85%: follow the peaked successor table; 15%: resample (noise)
            tok = if rng.next_f64() < 0.85 {
                let j = rng.below(self.branch);
                self.successors[tok * self.branch + j] as usize
            } else {
                self.zipf(rng)
            };
            out.push(tok as i32);
        }
        out
    }
}

impl Sharded for SyntheticCorpus {
    type Batch = LmBatch;

    fn batch(&self, worker: usize, iter: usize) -> LmBatch {
        let mut rng = Rng::new(
            self.seed
                ^ (worker as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (iter as u64).wrapping_mul(0xD1B54A32D192ED03),
        );
        let mut x = Vec::with_capacity(self.batch * self.seq);
        let mut y = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let stream = self.gen_stream(&mut rng, self.seq);
            x.extend_from_slice(&stream[..self.seq]);
            y.extend_from_slice(&stream[1..=self.seq]);
        }
        LmBatch { x, y, batch: self.batch, seq: self.seq }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> SyntheticCorpus {
        SyntheticCorpus::new(512, 64, 8, 7)
    }

    #[test]
    fn deterministic_and_sharded() {
        let c = corpus();
        assert_eq!(c.batch(1, 2).x, c.batch(1, 2).x);
        assert_ne!(c.batch(0, 0).x, c.batch(1, 0).x);
        assert_ne!(c.batch(0, 0).x, c.batch(0, 1).x);
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let c = corpus();
        let b = c.batch(0, 0);
        for s in 0..b.batch {
            let xrow = &b.x[s * b.seq..(s + 1) * b.seq];
            let yrow = &b.y[s * b.seq..(s + 1) * b.seq];
            assert_eq!(&xrow[1..], &yrow[..b.seq - 1]);
        }
    }

    #[test]
    fn tokens_in_vocab() {
        let c = corpus();
        let b = c.batch(3, 9);
        assert!(b.x.iter().all(|&t| (0..512).contains(&t)));
        assert!(b.y.iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn zipf_marginals_are_skewed() {
        let c = corpus();
        let mut counts = vec![0usize; 512];
        for it in 0..40 {
            for &t in &c.batch(0, it).x {
                counts[t as usize] += 1;
            }
        }
        let total: usize = counts.iter().sum();
        let top16: usize = {
            let mut sorted = counts.clone();
            sorted.sort_unstable_by(|a, b| b.cmp(a));
            sorted[..16].iter().sum()
        };
        // top 16 of 512 tokens should carry far more than 16/512 = 3% mass
        // (the Markov mixing flattens the raw Zipf marginals somewhat)
        assert!(
            top16 as f64 / total as f64 > 0.12,
            "top16 share = {}",
            top16 as f64 / total as f64
        );
    }

    #[test]
    fn chain_is_predictable() {
        // bigram structure: successors of a token concentrate on `branch`
        // candidates, so the conditional entropy is far below uniform
        let c = corpus();
        let mut follow: std::collections::HashMap<i32, Vec<i32>> =
            std::collections::HashMap::new();
        for it in 0..50 {
            let b = c.batch(0, it);
            for s in 0..b.batch {
                let xrow = &b.x[s * b.seq..(s + 1) * b.seq];
                for w in xrow.windows(2) {
                    follow.entry(w[0]).or_default().push(w[1]);
                }
            }
        }
        // for the most frequent context, the top successor should dominate
        let (_, succs) = follow
            .iter()
            .max_by_key(|(_, v)| v.len())
            .expect("nonempty");
        let mut counts: std::collections::HashMap<i32, usize> =
            std::collections::HashMap::new();
        for &s in succs {
            *counts.entry(s).or_default() += 1;
        }
        let max = counts.values().max().unwrap();
        let frac = *max as f64 / succs.len() as f64;
        assert!(frac > 0.1, "top successor share {frac} too uniform");
    }
}
