//! Strongly-convex quadratic testbed with explicit (L, µ, σ, ζ) knobs —
//! the workload for validating Theorems 1/2 and the φ experiments.
//!
//! Worker i's local loss: `f_i(x) = ½ (x − c_i)ᵀ A (x − c_i)` with diagonal
//! `A` whose spectrum spans [µ, L]. Centers `c_i` are spread with radius
//! controlled by `zeta` (data heterogeneity: `∇f_i(x*) ≠ 0`), and the
//! stochastic oracle adds iid `N(0, σ²/d)` per coordinate so
//! `E‖ξ‖² = σ²` — exactly Assumption 3.

use super::{worker_rng, GradOracle};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct Quadratic {
    dim: usize,
    workers: usize,
    /// diagonal of A, spectrum in [mu, l]
    diag: Vec<f32>,
    /// per-worker centers, workers × dim
    centers: Vec<f32>,
    /// global optimum = mean of centers (A shared across workers)
    global_center: Vec<f32>,
    sigma: f64,
    seed: u64,
}

impl Quadratic {
    pub fn new(
        dim: usize,
        workers: usize,
        l: f64,
        mu: f64,
        sigma: f64,
        zeta: f64,
        seed: u64,
    ) -> Self {
        assert!(l >= mu && mu > 0.0);
        let mut rng = Rng::new(seed ^ 0x0A11);
        // log-spaced spectrum in [mu, L]
        let diag: Vec<f32> = (0..dim)
            .map(|i| {
                let t = if dim == 1 { 0.0 } else { i as f64 / (dim - 1) as f64 };
                (mu * (l / mu).powf(t)) as f32
            })
            .collect();
        // centers: c_i = zeta_dir_i * r, where r calibrates E||∇f_i(x*)||² ≈ ζ²
        let mut centers = vec![0.0f32; workers * dim];
        if zeta > 0.0 && workers > 1 {
            for w in 0..workers {
                let mut dir = vec![0.0f32; dim];
                rng.fill_normal_f32(&mut dir, 1.0);
                let norm: f64 = dir.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
                // ∇f_i(x̄) = A (x̄ - c_i); with unit direction scaled so that
                // ||A c_i|| ≈ ζ — use the mean eigenvalue for calibration
                let mean_eig: f64 =
                    diag.iter().map(|&d| d as f64).sum::<f64>() / dim as f64;
                let r = zeta / mean_eig;
                for (c, d) in centers[w * dim..(w + 1) * dim]
                    .iter_mut()
                    .zip(&dir)
                {
                    *c = (*d as f64 / norm * r) as f32;
                }
            }
            // recentre so the mean is 0 (global optimum at origin shift)
            for j in 0..dim {
                let mean: f32 = (0..workers)
                    .map(|w| centers[w * dim + j])
                    .sum::<f32>()
                    / workers as f32;
                for w in 0..workers {
                    centers[w * dim + j] -= mean;
                }
            }
        }
        let global_center = vec![0.0f32; dim];
        Self { dim, workers, diag, centers, global_center, sigma, seed }
    }

    /// Condition number L/µ.
    pub fn l(&self) -> f64 {
        *self.diag.last().unwrap() as f64
    }

    pub fn mu(&self) -> f64 {
        self.diag[0] as f64
    }

    /// Optimal global loss value (= heterogeneity penalty at the optimum).
    pub fn f_star(&self) -> f64 {
        // f(x*) with x* = global_center (mean of centers = 0 by recentring)
        self.loss_det(&self.global_center)
    }

    fn loss_det(&self, x: &[f32]) -> f64 {
        let mut total = 0.0f64;
        for w in 0..self.workers {
            let c = &self.centers[w * self.dim..(w + 1) * self.dim];
            for j in 0..self.dim {
                let d = (x[j] - c[j]) as f64;
                total += 0.5 * self.diag[j] as f64 * d * d;
            }
        }
        total / self.workers as f64
    }
}

impl GradOracle for Quadratic {
    fn dim(&self) -> usize {
        self.dim
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn grad(&self, worker: usize, iter: usize, x: &[f32], out: &mut [f32]) -> f64 {
        let mut rng = worker_rng(self.seed, worker, iter);
        let c = &self.centers[worker * self.dim..(worker + 1) * self.dim];
        let noise_per_coord = (self.sigma / (self.dim as f64).sqrt()) as f32;
        let mut loss = 0.0f64;
        for j in 0..self.dim {
            let d = x[j] - c[j];
            loss += 0.5 * self.diag[j] as f64 * (d as f64) * (d as f64);
            out[j] = self.diag[j] * d + noise_per_coord * rng.normal_f32();
        }
        loss
    }

    fn loss(&self, x: &[f32]) -> f64 {
        self.loss_det(x)
    }

    fn init(&self) -> Vec<f32> {
        // start at distance ~1 from the optimum in every coordinate
        let mut rng = Rng::new(self.seed ^ 0x1217);
        let mut x = vec![0.0f32; self.dim];
        rng.fill_normal_f32(&mut x, (1.0 / (self.dim as f64).sqrt()) as f32);
        for v in x.iter_mut() {
            *v += 1.0 / (self.dim as f32).sqrt();
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_noise_variance_matches_sigma() {
        let q = Quadratic::new(64, 4, 1.0, 1.0, 2.0, 0.0, 5);
        let x = vec![0.0f32; 64];
        let mut g = vec![0.0f32; 64];
        let mut acc = 0.0f64;
        let trials = 2000;
        for t in 0..trials {
            q.grad(0, t, &x, &mut g);
            // true grad at 0 with c=0 is 0, so ||g||² == ||ξ||²
            acc += g.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
        }
        let mean = acc / trials as f64;
        assert!((mean - 4.0).abs() < 0.3, "E||ξ||²={mean}, want σ²=4");
    }

    #[test]
    fn heterogeneity_spreads_worker_gradients() {
        let q = Quadratic::new(32, 8, 2.0, 0.5, 0.0, 3.0, 6);
        let x = vec![0.0f32; 32];
        let mut g = vec![0.0f32; 32];
        let mut norms = Vec::new();
        for w in 0..8 {
            q.grad(w, 0, &x, &mut g);
            norms.push(crate::util::stats::l2_norm(&g));
        }
        // σ=0, so any gradient norm at the global optimum is pure ζ
        assert!(norms.iter().any(|&n| n > 0.1), "no heterogeneity: {norms:?}");
        // and the average gradient should be ~0 (centers recentred)
        let mut avg = vec![0.0f32; 32];
        for w in 0..8 {
            q.grad(w, 0, &x, &mut g);
            for (a, v) in avg.iter_mut().zip(&g) {
                *a += v / 8.0;
            }
        }
        assert!(crate::util::stats::l2_norm(&avg) < 1e-3);
    }

    #[test]
    fn gd_converges_at_condition_rate() {
        let q = Quadratic::new(16, 2, 4.0, 1.0, 0.0, 0.0, 7);
        let mut x = q.init();
        let mut g = vec![0.0f32; 16];
        let gamma = 1.0 / q.l() as f32 / 2.0;
        let l0 = q.loss(&x);
        for t in 0..200 {
            let mut avg = vec![0.0f32; 16];
            for w in 0..2 {
                q.grad(w, t, &x, &mut avg.clone());
                q.grad(w, t, &x, &mut g);
                for (a, v) in avg.iter_mut().zip(&g) {
                    *a += v / 2.0;
                }
            }
            for (xi, gi) in x.iter_mut().zip(&avg) {
                *xi -= gamma * gi;
            }
        }
        assert!(q.loss(&x) < 1e-6 * l0.max(1.0), "loss={}", q.loss(&x));
    }

    #[test]
    fn spectrum_spans_mu_to_l() {
        let q = Quadratic::new(100, 2, 10.0, 0.1, 0.0, 0.0, 8);
        assert!((q.mu() - 0.1).abs() < 1e-6);
        assert!((q.l() - 10.0).abs() < 1e-5);
    }
}
