//! Regularized logistic regression on synthetic separable-ish data — a
//! convex-but-not-quadratic testbed (sanity check that theory results are
//! not quadratic artifacts).

use super::{worker_rng, GradOracle};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct Logistic {
    dim: usize,
    workers: usize,
    /// per-worker datasets: features (n_local × dim) and ±1 labels
    feats: Vec<Vec<f32>>,
    labels: Vec<Vec<f32>>,
    batch: usize,
    reg: f64,
    seed: u64,
}

impl Logistic {
    pub fn new(
        dim: usize,
        workers: usize,
        n_per_worker: usize,
        batch: usize,
        reg: f64,
        skew: f64,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed ^ 0x106);
        // ground-truth separator
        let mut wstar = vec![0.0f32; dim];
        rng.fill_normal_f32(&mut wstar, 1.0 / (dim as f32).sqrt());
        let mut feats = Vec::with_capacity(workers);
        let mut labels = Vec::with_capacity(workers);
        for w in 0..workers {
            let mut fx = vec![0.0f32; n_per_worker * dim];
            rng.fill_normal_f32(&mut fx, 1.0);
            // heterogeneity: shift each worker's feature cloud
            if skew > 0.0 {
                let shift = (w as f32 - (workers as f32 - 1.0) / 2.0)
                    * skew as f32
                    / workers as f32;
                for v in fx.iter_mut() {
                    *v += shift;
                }
            }
            let mut ly = Vec::with_capacity(n_per_worker);
            for i in 0..n_per_worker {
                let margin: f32 = fx[i * dim..(i + 1) * dim]
                    .iter()
                    .zip(&wstar)
                    .map(|(a, b)| a * b)
                    .sum();
                // 10% label noise keeps σ > 0
                let flip = rng.next_f64() < 0.1;
                ly.push(if (margin > 0.0) ^ flip { 1.0 } else { -1.0 });
            }
            feats.push(fx);
            labels.push(ly);
        }
        Self { dim, workers, feats, labels, batch, reg, seed }
    }

    fn grad_on(&self, worker: usize, rows: &[usize], x: &[f32], out: &mut [f32]) -> f64 {
        out.iter_mut().for_each(|v| *v = 0.0);
        let fx = &self.feats[worker];
        let ly = &self.labels[worker];
        let mut loss = 0.0f64;
        for &r in rows {
            let xi = &fx[r * self.dim..(r + 1) * self.dim];
            let margin: f32 = xi.iter().zip(x).map(|(a, b)| a * b).sum();
            let z = (ly[r] * margin) as f64;
            loss += (1.0 + (-z).exp()).ln();
            let s = (-ly[r] as f64) / (1.0 + z.exp());
            for (o, f) in out.iter_mut().zip(xi) {
                *o += (s as f32) * f;
            }
        }
        let nb = rows.len() as f32;
        for (o, xi) in out.iter_mut().zip(x) {
            *o = *o / nb + (self.reg as f32) * xi;
        }
        loss / rows.len() as f64
            + 0.5 * self.reg * x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
    }
}

impl GradOracle for Logistic {
    fn dim(&self) -> usize {
        self.dim
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn grad(&self, worker: usize, iter: usize, x: &[f32], out: &mut [f32]) -> f64 {
        let n = self.labels[worker].len();
        let mut rng = worker_rng(self.seed, worker, iter);
        let rows: Vec<usize> = (0..self.batch).map(|_| rng.below(n)).collect();
        self.grad_on(worker, &rows, x, out)
    }

    fn loss(&self, x: &[f32]) -> f64 {
        let mut buf = vec![0.0f32; self.dim];
        let mut total = 0.0f64;
        for w in 0..self.workers {
            let rows: Vec<usize> = (0..self.labels[w].len()).collect();
            total += self.grad_on(w, &rows, x, &mut buf);
        }
        total / self.workers as f64
    }

    fn init(&self) -> Vec<f32> {
        vec![0.0f32; self.dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_learns_the_separator() {
        let p = Logistic::new(20, 4, 200, 16, 1e-3, 0.0, 3);
        let mut x = p.init();
        let l0 = p.loss(&x);
        let mut g = vec![0.0f32; 20];
        for t in 0..300 {
            let mut avg = vec![0.0f32; 20];
            for w in 0..4 {
                p.grad(w, t, &x, &mut g);
                for (a, v) in avg.iter_mut().zip(&g) {
                    *a += v / 4.0;
                }
            }
            for (xi, gi) in x.iter_mut().zip(&avg) {
                *xi -= 0.5 * gi;
            }
        }
        let l1 = p.loss(&x);
        assert!(l1 < 0.6 * l0, "loss {l0} -> {l1}");
    }

    #[test]
    fn deterministic_minibatches() {
        let p = Logistic::new(10, 2, 50, 8, 0.0, 0.0, 4);
        let x = vec![0.1f32; 10];
        let mut g1 = vec![0.0f32; 10];
        let mut g2 = vec![0.0f32; 10];
        p.grad(1, 7, &x, &mut g1);
        p.grad(1, 7, &x, &mut g2);
        assert_eq!(g1, g2);
    }

    #[test]
    fn skew_creates_heterogeneity() {
        let p = Logistic::new(16, 4, 100, 100, 0.0, 4.0, 5);
        let x = vec![0.05f32; 16];
        let mut norms = Vec::new();
        let mut g = vec![0.0f32; 16];
        let mut grads: Vec<Vec<f32>> = Vec::new();
        for w in 0..4 {
            p.grad(w, 0, &x, &mut g);
            norms.push(crate::util::stats::l2_norm(&g));
            grads.push(g.clone());
        }
        // worker gradients must differ meaningfully
        let d01: f64 = grads[0]
            .iter()
            .zip(&grads[3])
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(d01 > 1e-3, "gradients identical despite skew");
    }
}
