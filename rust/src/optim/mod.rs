//! Analytic optimization testbeds + the gradient-oracle abstraction.
//!
//! [`GradOracle`] is the seam between the coordinator and the compute layer:
//! the PJRT-backed oracle (`runtime::PjrtOracle`) runs the real L2/L1 HLO
//! modules; the testbeds here ([`Quadratic`], [`Logistic`]) provide
//! closed-form gradients with controllable σ (gradient noise), ζ (worker
//! heterogeneity), L and µ — so the theory experiments (`exp phi`,
//! convergence-rate validation) run thousands of steps in milliseconds.

pub mod logistic;
pub mod quadratic;

pub use logistic::Logistic;
pub use quadratic::Quadratic;

use crate::util::Rng;

/// A distributed gradient oracle over a flat parameter vector.
///
/// `Send + Sync` with `&self` methods: the coordinator's worker phase calls
/// `grad` concurrently from the pool (one worker id per thread), and the
/// experiment sweeps move whole training loops onto pool threads. All
/// oracles here are deterministic functions of `(worker, iter, x)` with no
/// interior mutability, so sharing is free. An oracle over a handle that
/// is not thread-safe (e.g. real PJRT executables under the `pjrt`
/// feature, which are single-threaded-owned) must wrap it to satisfy the
/// bound — a `Mutex` around the executable is the straightforward route;
/// the coordinator already pins such runs to a serial pool so the lock
/// stays uncontended.
pub trait GradOracle: Send + Sync {
    /// Parameter dimension (padded to the compressor block size by callers
    /// that need it; testbeds can use any dim).
    fn dim(&self) -> usize;

    /// Number of workers.
    fn workers(&self) -> usize;

    /// Stochastic gradient of worker `i`'s local loss at `x` for iteration
    /// `iter`, written into `out`. Returns the local loss estimate. May be
    /// called concurrently for distinct workers.
    fn grad(&self, worker: usize, iter: usize, x: &[f32], out: &mut [f32]) -> f64;

    /// Full (deterministic) global loss — for metrics, not on the hot path.
    fn loss(&self, x: &[f32]) -> f64;

    /// A fresh parameter vector at the canonical init.
    fn init(&self) -> Vec<f32>;
}

/// Convenience wrapper for seeding per-worker noise streams.
pub(crate) fn worker_rng(seed: u64, worker: usize, iter: usize) -> Rng {
    Rng::new(
        seed ^ (worker as u64).wrapping_mul(0xA24BAED4963EE407)
            ^ (iter as u64).wrapping_mul(0x9FB21C651E98DF25),
    )
}
