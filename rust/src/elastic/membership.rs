//! The membership state machine the training loop prices and aggregates
//! over, plus the **epoch** counter event-triggered DeCo re-plans on.
//!
//! Per worker: `Active` (computing, transmitting) → `Draining` (departed
//! under [`super::DrainPolicy::Drain`]; still flushing its delay queue one
//! gradient per iteration) → `Departed` (fully absent; its `WorkerState`
//! and monitor estimators are retained for a warm rejoin) → `Active` again
//! on rejoin. Every transition — and every link outage/degrade window
//! boundary, via [`Membership::bump`] — advances the epoch, which is the
//! single signal `strategy::StrategyCtx` exposes for re-planning.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemberState {
    Active,
    Draining,
    Departed,
}

#[derive(Clone, Debug)]
pub struct Membership {
    state: Vec<MemberState>,
    epoch: u64,
}

impl Membership {
    /// All `n` workers active, epoch 0.
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        Self { state: vec![MemberState::Active; n], epoch: 0 }
    }

    /// Monotone change counter: bumped on every membership transition and
    /// every fault-window boundary. Strategies re-plan when it moves.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn n(&self) -> usize {
        self.state.len()
    }

    pub fn state(&self, worker: usize) -> MemberState {
        self.state[worker]
    }

    pub fn is_active(&self, worker: usize) -> bool {
        self.state[worker] == MemberState::Active
    }

    /// Workers currently computing gradients.
    pub fn active_count(&self) -> usize {
        self.state
            .iter()
            .filter(|&&s| s == MemberState::Active)
            .count()
    }

    /// Workers whose messages are being aggregated (active + draining) —
    /// the divisor of the leader's `γ/n_eff` average.
    pub fn member_count(&self) -> usize {
        self.state
            .iter()
            .filter(|&&s| s != MemberState::Departed)
            .count()
    }

    /// A worker departs. `drain = true` routes it through `Draining`
    /// (in-flight gradients flush first); `false` departs it immediately.
    pub fn leave(&mut self, worker: usize, drain: bool) {
        assert_eq!(
            self.state[worker],
            MemberState::Active,
            "leave of a non-active worker (timeline not validated?)"
        );
        self.state[worker] = if drain {
            MemberState::Draining
        } else {
            MemberState::Departed
        };
        self.epoch += 1;
    }

    /// A draining worker's queue emptied: it is now fully departed.
    pub fn finish_drain(&mut self, worker: usize) {
        assert_eq!(self.state[worker], MemberState::Draining);
        self.state[worker] = MemberState::Departed;
        self.epoch += 1;
    }

    /// A departed (or still-draining) worker resumes computing.
    pub fn rejoin(&mut self, worker: usize) {
        assert_ne!(
            self.state[worker],
            MemberState::Active,
            "rejoin of an active worker (timeline not validated?)"
        );
        self.state[worker] = MemberState::Active;
        self.epoch += 1;
    }

    /// Epoch bump without a membership transition — fault-window
    /// boundaries, where the effective `(a, b)` changes under the planner.
    pub fn bump(&mut self) {
        self.epoch += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transitions_and_counts() {
        let mut m = Membership::new(4);
        assert_eq!(m.epoch(), 0);
        assert_eq!(m.active_count(), 4);
        assert_eq!(m.member_count(), 4);

        m.leave(1, true);
        assert_eq!(m.state(1), MemberState::Draining);
        assert_eq!(m.active_count(), 3);
        assert_eq!(m.member_count(), 4, "draining still aggregates");
        assert_eq!(m.epoch(), 1);

        m.finish_drain(1);
        assert_eq!(m.state(1), MemberState::Departed);
        assert_eq!(m.member_count(), 3);
        assert_eq!(m.epoch(), 2);

        m.leave(0, false);
        assert_eq!(m.state(0), MemberState::Departed);
        assert_eq!(m.member_count(), 2);

        m.rejoin(1);
        assert!(m.is_active(1));
        assert_eq!(m.active_count(), 2);
        assert_eq!(m.epoch(), 4);

        m.bump();
        assert_eq!(m.epoch(), 5);
    }

    #[test]
    #[should_panic]
    fn leave_twice_panics() {
        let mut m = Membership::new(2);
        m.leave(0, false);
        m.leave(0, false);
    }
}
