//! Elastic membership & fault injection (DESIGN.md §Elasticity) — the
//! dynamic-membership layer over the per-worker [`crate::netsim::Fabric`].
//!
//! The paper (and PR 2's fabric) assume a fixed worker set and always-up
//! links; real cross-region deployments see preemptions, dropouts, and
//! transient link outages (cf. CrossPipe's cross-datacenter setting and the
//! delay-compensation line of work). This module makes that hostile
//! environment a first-class scenario family:
//!
//! * [`ChurnEvent`] — `Leave` / `Rejoin` / `LinkOutage` / `LinkDegrade`,
//!   plus the path-scoped `PathOutage` / `PathDegrade` for bonded workers
//!   (DESIGN.md §Bonding), stamped with virtual times into a
//!   [`ChurnTimeline`];
//! * [`ChurnSpec`] — the serde scenario layer (mirroring
//!   `config::FabricSpec`): `none`, `scripted` event lists, or seeded
//!   `random` churn compiled deterministically into a timeline;
//! * [`Membership`] — the active/draining/departed state machine the
//!   training loop prices and aggregates over, with a monotone **epoch**
//!   counter that event-triggered DeCo re-plans on;
//! * [`DrainPolicy`] — what happens to a departed worker's in-flight
//!   delayed gradients: `Drop` freezes them in the retained queue (the
//!   default — absence looks like a pipeline stall), `Drain` flushes them
//!   one per iteration before the worker fully departs.
//!
//! Determinism contract: [`ChurnSpec::None`] compiles to an empty timeline
//! and the training loop's elastic path degenerates bit-identically to a
//! fabric-only run (serial and pooled — `tests/elastic.rs`); a fixed seed
//! compiles to an identical event timeline every time.

pub mod event;
pub mod membership;
pub mod spec;

pub use event::{ChurnEvent, ChurnTimeline, TimedEvent};
pub use membership::{MemberState, Membership};
pub use spec::ChurnSpec;

/// What happens to a leaving worker's in-flight delayed gradients
/// (DESIGN.md §Elasticity). Either way its EF vector and delay queue are
/// retained, so a [`ChurnEvent::Rejoin`] resumes warm.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DrainPolicy {
    /// The queue freezes in place: no pops while departed, and the worker
    /// stops contributing the moment it leaves. On rejoin the backlog
    /// resumes as if the absence were a pipeline stall.
    #[default]
    Drop,
    /// The worker stops computing but keeps emitting its queued gradients,
    /// one per iteration, until the pipeline is empty — the in-flight
    /// messages complete delivery — and only then fully departs.
    Drain,
}
