//! `ChurnSpec` — the scenario layer for churn, mirroring
//! `config::FabricSpec`: a JSON-serializable description (see
//! `config::churn_to_json` / `churn_from_json`) compiled into a concrete
//! [`ChurnTimeline`] for one run.
//!
//! Compilation is **deterministic**: `Scripted` is sorted + validated
//! verbatim, and `Random` draws every arrival from per-worker RNG streams
//! derived from the spec seed, so a fixed seed yields the identical event
//! timeline on every compile (`tests/elastic.rs`).

use super::event::{ChurnEvent, ChurnTimeline, TimedEvent};
use crate::util::Rng;
use anyhow::{anyhow, Result};

#[derive(Clone, Debug, Default, PartialEq)]
pub enum ChurnSpec {
    /// No events: the run degenerates bit-identically to a fabric-only run.
    #[default]
    None,
    /// Explicit event list (scenario files, the `exp churn` arms).
    Scripted { events: Vec<TimedEvent> },
    /// Seeded random churn: per-worker Poisson leave/rejoin cycles and link
    /// outages over a horizon, compiled deterministically from the seed.
    /// Leaves that would empty the active set are dropped (with their
    /// paired rejoin) at compile time.
    Random {
        /// expected departures per worker per 100 s of virtual time
        leave_rate_per_100s: f64,
        /// mean downtime before a departed worker rejoins (s, exponential)
        mean_down_s: f64,
        /// expected link outages per worker per 100 s
        outage_rate_per_100s: f64,
        /// duration of each link outage (s)
        outage_s: f64,
        /// horizon over which events are generated (s)
        horizon_s: f64,
        seed: u64,
    },
}

impl ChurnSpec {
    /// The no-churn spec (the determinism-contract baseline).
    pub fn none() -> Self {
        Self::None
    }

    pub fn is_none(&self) -> bool {
        matches!(self, Self::None)
    }

    /// Compile into the validated, time-sorted timeline a run with `n`
    /// single-path workers executes. Bonded runs use
    /// [`Self::compile_for`] so path-scoped events are checked against the
    /// fabric's real path geometry.
    pub fn compile(&self, n: usize) -> Result<ChurnTimeline> {
        self.compile_for(n, &vec![1; n])
    }

    /// [`Self::compile`] against an explicit path geometry (`paths[w]` =
    /// worker `w`'s path count, from `Fabric::paths_per_worker`).
    pub fn compile_for(
        &self,
        n: usize,
        paths: &[usize],
    ) -> Result<ChurnTimeline> {
        match self {
            Self::None => Ok(ChurnTimeline::empty()),
            Self::Scripted { events } => {
                ChurnTimeline::validated_for(events.clone(), n, paths)
            }
            Self::Random {
                leave_rate_per_100s,
                mean_down_s,
                outage_rate_per_100s,
                outage_s,
                horizon_s,
                seed,
            } => {
                for (name, v) in [
                    ("leave_rate_per_100s", *leave_rate_per_100s),
                    ("mean_down_s", *mean_down_s),
                    ("outage_rate_per_100s", *outage_rate_per_100s),
                    ("outage_s", *outage_s),
                    ("horizon_s", *horizon_s),
                ] {
                    if !(v.is_finite() && v >= 0.0) {
                        return Err(anyhow!(
                            "random churn: {name} = {v} invalid"
                        ));
                    }
                }
                // a positive rate with a zero paired duration would
                // silently compile to NO events — reject the mislabeled
                // "churn" run instead
                if *leave_rate_per_100s > 0.0 && *mean_down_s <= 0.0 {
                    return Err(anyhow!(
                        "random churn: leave_rate_per_100s > 0 requires \
                         mean_down_s > 0"
                    ));
                }
                if *outage_rate_per_100s > 0.0 && *outage_s <= 0.0 {
                    return Err(anyhow!(
                        "random churn: outage_rate_per_100s > 0 requires \
                         outage_s > 0"
                    ));
                }
                Ok(compile_random(
                    n,
                    *leave_rate_per_100s,
                    *mean_down_s,
                    *outage_rate_per_100s,
                    *outage_s,
                    *horizon_s,
                    *seed,
                ))
            }
        }
    }
}

/// Per-worker RNG stream `salt` derived from the spec seed.
fn stream(seed: u64, worker: usize, salt: u64) -> Rng {
    Rng::new(
        seed ^ (worker as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)
            ^ salt.wrapping_mul(0xD1B54A32D192ED03),
    )
}

/// Exponential draw with mean 1 (clamped away from exact zero).
fn exp1(rng: &mut Rng) -> f64 {
    (-(1.0 - rng.next_f64()).ln()).max(1e-9)
}

fn compile_random(
    n: usize,
    leave_rate_per_100s: f64,
    mean_down_s: f64,
    outage_rate_per_100s: f64,
    outage_s: f64,
    horizon_s: f64,
    seed: u64,
) -> ChurnTimeline {
    let mut events = Vec::new();
    for w in 0..n {
        // leave/rejoin cycles: exponential up-time gaps, exponential
        // downtime with mean `mean_down_s`
        let leave_rate = leave_rate_per_100s / 100.0;
        if leave_rate > 0.0 && mean_down_s > 0.0 {
            let mut rng = stream(seed, w, 1);
            let mut t = exp1(&mut rng) / leave_rate;
            while t < horizon_s {
                let down = mean_down_s * exp1(&mut rng);
                events.push(TimedEvent {
                    t,
                    event: ChurnEvent::Leave { worker: w },
                });
                // the paired rejoin always exists (events past the run's
                // end simply never fire), so leaves/rejoins alternate
                events.push(TimedEvent {
                    t: t + down,
                    event: ChurnEvent::Rejoin { worker: w },
                });
                t = t + down + exp1(&mut rng) / leave_rate;
            }
        }
        // link outages: exponential gaps, fixed duration, non-overlapping
        let outage_rate = outage_rate_per_100s / 100.0;
        if outage_rate > 0.0 && outage_s > 0.0 {
            let mut rng = stream(seed, w, 2);
            let mut t = exp1(&mut rng) / outage_rate;
            while t < horizon_s {
                events.push(TimedEvent {
                    t,
                    event: ChurnEvent::LinkOutage { worker: w, secs: outage_s },
                });
                t += outage_s + exp1(&mut rng) / outage_rate;
            }
        }
    }
    // enforce the never-empty invariant: drop any leave that would empty
    // the active set, together with its paired rejoin
    let sorted = ChurnTimeline::new(events);
    let mut count = n;
    let mut skip_rejoin = vec![0usize; n];
    let mut kept = Vec::with_capacity(sorted.events().len());
    for ev in sorted.events() {
        match ev.event {
            ChurnEvent::Leave { worker } => {
                if count == 1 {
                    skip_rejoin[worker] += 1;
                    continue;
                }
                count -= 1;
                kept.push(ev.clone());
            }
            ChurnEvent::Rejoin { worker } => {
                if skip_rejoin[worker] > 0 {
                    skip_rejoin[worker] -= 1;
                    continue;
                }
                count += 1;
                kept.push(ev.clone());
            }
            _ => kept.push(ev.clone()),
        }
    }
    ChurnTimeline::validated(kept, n)
        .expect("random compilation preserves the membership invariants")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_spec(seed: u64) -> ChurnSpec {
        ChurnSpec::Random {
            leave_rate_per_100s: 3.0,
            mean_down_s: 20.0,
            outage_rate_per_100s: 2.0,
            outage_s: 10.0,
            horizon_s: 500.0,
            seed,
        }
    }

    #[test]
    fn none_compiles_empty() {
        assert!(ChurnSpec::none().compile(4).unwrap().is_empty());
        assert!(ChurnSpec::default().is_none());
    }

    #[test]
    fn random_is_deterministic_in_the_seed() {
        let a = random_spec(7).compile(4).unwrap();
        let b = random_spec(7).compile(4).unwrap();
        assert_eq!(a, b, "same seed must compile the same timeline");
        assert!(!a.is_empty(), "these rates should produce events");
        let c = random_spec(8).compile(4).unwrap();
        assert_ne!(a, c, "different seeds should differ");
    }

    #[test]
    fn random_never_empties_even_one_worker() {
        // n = 1: every leave would empty the set, so all must be dropped
        let tl = random_spec(3).compile(1).unwrap();
        assert!(tl
            .events()
            .iter()
            .all(|e| !matches!(e.event, ChurnEvent::Leave { .. })));
    }

    #[test]
    fn random_rejects_degenerate_params() {
        let bad = ChurnSpec::Random {
            leave_rate_per_100s: f64::NAN,
            mean_down_s: 10.0,
            outage_rate_per_100s: 0.0,
            outage_s: 0.0,
            horizon_s: 100.0,
            seed: 0,
        };
        assert!(bad.compile(4).is_err());
        // a positive rate with a zero paired duration would be a silent
        // no-op "churn" run — rejected, not compiled to nothing
        let silent_leaves = ChurnSpec::Random {
            leave_rate_per_100s: 4.0,
            mean_down_s: 0.0,
            outage_rate_per_100s: 0.0,
            outage_s: 0.0,
            horizon_s: 100.0,
            seed: 0,
        };
        assert!(silent_leaves.compile(4).is_err());
        let silent_outages = ChurnSpec::Random {
            leave_rate_per_100s: 0.0,
            mean_down_s: 0.0,
            outage_rate_per_100s: 2.0,
            outage_s: 0.0,
            horizon_s: 100.0,
            seed: 0,
        };
        assert!(silent_outages.compile(4).is_err());
    }

    #[test]
    fn scripted_validates() {
        let bad = ChurnSpec::Scripted {
            events: vec![TimedEvent {
                t: 1.0,
                event: ChurnEvent::Rejoin { worker: 0 },
            }],
        };
        assert!(bad.compile(4).is_err());
    }
}
