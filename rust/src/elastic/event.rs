//! Churn events and the compiled per-run timeline.
//!
//! A [`ChurnTimeline`] is the *realized* schedule one training run
//! executes: time-sorted [`TimedEvent`]s, validated against the worker
//! count (membership must never empty, leaves/rejoins must alternate).
//! Link-level events (`LinkOutage` / `LinkDegrade`) are baked into the
//! fabric as lazy [`DegradeWindow`]s *before* the run
//! ([`ChurnTimeline::bake_windows`]), so the virtual clock, the monitors,
//! and the fabric's bottleneck/mean views all price the same degraded
//! bandwidth without any per-tick bookkeeping; membership events
//! (`Leave` / `Rejoin`) are applied by the training loop as the virtual
//! clock passes their timestamps.
//!
//! On a bonded worker (DESIGN.md §Bonding), a worker-level link event
//! explicitly means **all paths** — the whole WAN attachment is down or
//! degraded — while the path-scoped `PathOutage` / `PathDegrade` events
//! hit one path and leave the water-filling scheduler to shift bits onto
//! the survivors. Path indices are validated against the fabric's path
//! geometry at compile time ([`ChurnTimeline::validated_for`]), so a
//! scenario naming a path the bond doesn't have fails with a clear error
//! instead of a mid-run panic.

use std::sync::Arc;

use crate::netsim::{DegradeWindow, Fabric, LossBurstWindow, LossProcess};
use anyhow::{anyhow, Result};

/// Seed base for burst-only loss processes minted by [`bake_windows`]
/// (workers that have scripted bursts but no configured loss process).
///
/// [`bake_windows`]: ChurnTimeline::bake_windows
const BURST_SEED: u64 = 0xB0B5_7B57;

/// One membership or link fault (times live on the [`TimedEvent`]).
#[derive(Clone, Debug, PartialEq)]
pub enum ChurnEvent {
    /// The worker departs (preemption / dropout). Its `WorkerState` is
    /// retained for a warm rejoin; its in-flight gradients follow the run's
    /// [`super::DrainPolicy`].
    Leave { worker: usize },
    /// A departed worker resumes with its retained EF vector, delay queue,
    /// and warm monitor estimators.
    Rejoin { worker: usize },
    /// The worker's link is down for `secs`: bandwidth pinned to the trace
    /// floor, so in-flight transfers stall until the window ends. On a
    /// bonded worker this means **every path** is down.
    LinkOutage { worker: usize, secs: f64 },
    /// The worker's link runs at `frac`× bandwidth for `secs`. On a bonded
    /// worker this degrades **every path**.
    LinkDegrade { worker: usize, frac: f64, secs: f64 },
    /// One path of a bonded worker is down for `secs`; the water-filling
    /// scheduler shifts its bits to the surviving paths.
    PathOutage { worker: usize, path: usize, secs: f64 },
    /// One path of a bonded worker runs at `frac`× bandwidth for `secs`.
    PathDegrade { worker: usize, path: usize, frac: f64, secs: f64 },
    /// The worker's WAN path drops messages at (at least) `rate` for
    /// `secs`: baked into the fabric's [`crate::netsim::LossProcess`] as a
    /// scripted burst window, so every attempt sent inside the window rides
    /// the timeout/backoff retransmission ladder.
    LossBurst { worker: usize, rate: f64, secs: f64 },
}

impl ChurnEvent {
    pub fn worker(&self) -> usize {
        match *self {
            Self::Leave { worker }
            | Self::Rejoin { worker }
            | Self::LinkOutage { worker, .. }
            | Self::LinkDegrade { worker, .. }
            | Self::PathOutage { worker, .. }
            | Self::PathDegrade { worker, .. }
            | Self::LossBurst { worker, .. } => worker,
        }
    }
}

/// An event stamped with the virtual time (s) at which it fires.
#[derive(Clone, Debug, PartialEq)]
pub struct TimedEvent {
    pub t: f64,
    pub event: ChurnEvent,
}

/// A compiled, time-sorted churn schedule for one run.
///
/// The event list is `Arc`-shared: sweeps clone one compiled timeline into
/// every cell, and the clone bumps a refcount instead of copying the
/// schedule (the PR-5 grid-sharing pattern applied to churn timelines).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChurnTimeline {
    /// sorted ascending by `t`; ties keep insertion order (stable sort)
    events: Arc<[TimedEvent]>,
}

impl ChurnTimeline {
    /// An empty timeline — the [`super::ChurnSpec::None`] realization.
    pub fn empty() -> Self {
        Self::default()
    }

    /// Sort (stably, by time) without validating. Use
    /// [`Self::validated`] for schedules from user configs.
    pub fn new(mut events: Vec<TimedEvent>) -> Self {
        events.sort_by(|a, b| a.t.total_cmp(&b.t));
        Self { events: events.into() }
    }

    /// Sort and validate against a run with `n` single-path workers: worker
    /// indices in range, finite non-negative times, positive durations,
    /// alternating leave/rejoin per worker, and — the invariant the whole
    /// coordinator leans on — the active set never empties. Path-scoped
    /// events may only name path 0 here; use [`Self::validated_for`] with
    /// the fabric's real path geometry for bonded runs.
    pub fn validated(events: Vec<TimedEvent>, n: usize) -> Result<Self> {
        Self::validated_for(events, n, &vec![1; n])
    }

    /// [`Self::validated`] against an explicit path geometry: `paths[w]`
    /// is worker `w`'s path count, and a path-scoped event naming a path
    /// index `>= paths[w]` is rejected here — at compile time, with a
    /// clear error — rather than panicking mid-run.
    pub fn validated_for(
        events: Vec<TimedEvent>,
        n: usize,
        paths: &[usize],
    ) -> Result<Self> {
        let tl = Self::new(events);
        tl.validate(n, paths)?;
        Ok(tl)
    }

    pub fn events(&self) -> &[TimedEvent] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    fn validate(&self, n: usize, paths: &[usize]) -> Result<()> {
        assert_eq!(paths.len(), n, "one path count per worker");
        let mut active = vec![true; n];
        let mut count = n;
        for ev in &self.events {
            let w = ev.event.worker();
            if w >= n {
                return Err(anyhow!(
                    "churn event names worker {w} but the run has {n}"
                ));
            }
            if !(ev.t.is_finite() && ev.t >= 0.0) {
                return Err(anyhow!("churn event time {} invalid", ev.t));
            }
            match ev.event {
                ChurnEvent::Leave { .. } => {
                    if !active[w] {
                        return Err(anyhow!(
                            "worker {w} leaves at t={} but is already \
                             departed",
                            ev.t
                        ));
                    }
                    if count == 1 {
                        return Err(anyhow!(
                            "churn schedule empties the active set at t={}",
                            ev.t
                        ));
                    }
                    active[w] = false;
                    count -= 1;
                }
                ChurnEvent::Rejoin { .. } => {
                    if active[w] {
                        return Err(anyhow!(
                            "worker {w} rejoins at t={} but is active",
                            ev.t
                        ));
                    }
                    active[w] = true;
                    count += 1;
                }
                ChurnEvent::LinkOutage { secs, .. } => {
                    if !(secs.is_finite() && secs > 0.0) {
                        return Err(anyhow!("outage duration {secs} invalid"));
                    }
                }
                ChurnEvent::LinkDegrade { frac, secs, .. } => {
                    if !(secs.is_finite() && secs > 0.0) {
                        return Err(anyhow!(
                            "degrade duration {secs} invalid"
                        ));
                    }
                    if !(frac.is_finite() && (0.0..=1.0).contains(&frac)) {
                        return Err(anyhow!("degrade frac {frac} invalid"));
                    }
                }
                ChurnEvent::PathOutage { path, secs, .. } => {
                    if path >= paths[w] {
                        return Err(anyhow!(
                            "churn event names path {path} on worker {w} \
                             but it has {} path(s)",
                            paths[w]
                        ));
                    }
                    if !(secs.is_finite() && secs > 0.0) {
                        return Err(anyhow!(
                            "path outage duration {secs} invalid"
                        ));
                    }
                }
                ChurnEvent::PathDegrade { path, frac, secs, .. } => {
                    if path >= paths[w] {
                        return Err(anyhow!(
                            "churn event names path {path} on worker {w} \
                             but it has {} path(s)",
                            paths[w]
                        ));
                    }
                    if !(secs.is_finite() && secs > 0.0) {
                        return Err(anyhow!(
                            "path degrade duration {secs} invalid"
                        ));
                    }
                    if !(frac.is_finite() && (0.0..=1.0).contains(&frac)) {
                        return Err(anyhow!(
                            "path degrade frac {frac} invalid"
                        ));
                    }
                }
                ChurnEvent::LossBurst { rate, secs, .. } => {
                    if !(secs.is_finite() && secs > 0.0) {
                        return Err(anyhow!(
                            "loss burst duration {secs} invalid"
                        ));
                    }
                    if !(rate.is_finite() && (0.0..=1.0).contains(&rate)) {
                        return Err(anyhow!("loss burst rate {rate} invalid"));
                    }
                }
            }
        }
        Ok(())
    }

    /// The degrade/outage windows this schedule puts on `worker`'s link
    /// (outages are `frac = 0` windows — the trace floor keeps the link
    /// integrable). On a bonded worker this is path 0's view; see
    /// [`Self::path_windows_for`].
    pub fn windows_for(&self, worker: usize) -> Vec<DegradeWindow> {
        self.path_windows_for(worker, 0)
    }

    /// The windows landing on path `path` of `worker`: every worker-level
    /// link event (the whole attachment is down, so **all** paths get the
    /// window) plus the path-scoped events naming exactly this path.
    pub fn path_windows_for(
        &self,
        worker: usize,
        path: usize,
    ) -> Vec<DegradeWindow> {
        self.events
            .iter()
            .filter_map(|ev| match ev.event {
                ChurnEvent::LinkOutage { worker: w, secs } if w == worker => {
                    Some(DegradeWindow {
                        start_s: ev.t,
                        end_s: ev.t + secs,
                        frac: 0.0,
                    })
                }
                ChurnEvent::LinkDegrade { worker: w, frac, secs }
                    if w == worker =>
                {
                    Some(DegradeWindow {
                        start_s: ev.t,
                        end_s: ev.t + secs,
                        frac,
                    })
                }
                ChurnEvent::PathOutage { worker: w, path: p, secs }
                    if w == worker && p == path =>
                {
                    Some(DegradeWindow {
                        start_s: ev.t,
                        end_s: ev.t + secs,
                        frac: 0.0,
                    })
                }
                ChurnEvent::PathDegrade { worker: w, path: p, frac, secs }
                    if w == worker && p == path =>
                {
                    Some(DegradeWindow {
                        start_s: ev.t,
                        end_s: ev.t + secs,
                        frac,
                    })
                }
                _ => None,
            })
            .collect()
    }

    /// The scripted loss-burst windows this schedule puts on `worker`'s
    /// loss process.
    pub fn loss_bursts_for(&self, worker: usize) -> Vec<LossBurstWindow> {
        self.events
            .iter()
            .filter_map(|ev| match ev.event {
                ChurnEvent::LossBurst { worker: w, rate, secs }
                    if w == worker =>
                {
                    Some(LossBurstWindow {
                        start_s: ev.t,
                        end_s: ev.t + secs,
                        rate,
                    })
                }
                _ => None,
            })
            .collect()
    }

    /// Bake every outage/degrade window into the fabric's links, so the
    /// clock's transfer integration, the per-link monitors, and the
    /// bottleneck/mean fabric views all see the same time-varying picture.
    /// Bonded workers get their windows baked per path, so a path-scoped
    /// fault shifts bits to the survivors while a worker-level fault takes
    /// the whole attachment down. Loss bursts attach to the worker's
    /// [`LossProcess`] (extending its window list, or seeding a burst-only
    /// process on an otherwise-lossless worker).
    pub fn bake_windows(&self, fabric: &mut Fabric) {
        for w in 0..fabric.workers() {
            if let Some(mut bond) = fabric.bond(w).cloned() {
                let mut touched = false;
                for p in 0..bond.k() {
                    let wins = self.path_windows_for(w, p);
                    if !wins.is_empty() {
                        bond = bond.with_path_windows(p, wins);
                        touched = true;
                    }
                }
                if touched {
                    fabric.set_bond(w, bond);
                }
            } else {
                let wins = self.windows_for(w);
                if !wins.is_empty() {
                    let link = fabric.link(w).with_windows(wins);
                    fabric.set_link(w, link);
                }
            }
            let mut bursts = self.loss_bursts_for(w);
            if !bursts.is_empty() {
                let base = fabric.loss(w).cloned().unwrap_or_else(|| {
                    // burst-only worker: a zero base whose burst draws are
                    // still seeded deterministically per worker
                    LossProcess::iid(0.0, BURST_SEED ^ ((w as u64) << 17))
                });
                bursts.extend_from_slice(base.bursts());
                // zero-rate bursts on a lossless base fall out at set_loss
                fabric.set_loss(w, base.with_bursts(bursts));
            }
        }
    }

    /// Times at which an outage/degrade window *closes* — the training loop
    /// bumps the membership epoch there too, so event-triggered DeCo
    /// re-plans when the fault clears, not just when it strikes.
    pub fn window_ends(&self) -> Vec<f64> {
        let mut ends: Vec<f64> = self
            .events
            .iter()
            .filter_map(|ev| match ev.event {
                ChurnEvent::LinkOutage { secs, .. }
                | ChurnEvent::LinkDegrade { secs, .. }
                | ChurnEvent::PathOutage { secs, .. }
                | ChurnEvent::PathDegrade { secs, .. }
                | ChurnEvent::LossBurst { secs, .. } => Some(ev.t + secs),
                _ => None,
            })
            .collect();
        ends.sort_by(f64::total_cmp);
        ends
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{BandwidthTrace, Link};

    fn leave(t: f64, worker: usize) -> TimedEvent {
        TimedEvent { t, event: ChurnEvent::Leave { worker } }
    }

    fn rejoin(t: f64, worker: usize) -> TimedEvent {
        TimedEvent { t, event: ChurnEvent::Rejoin { worker } }
    }

    #[test]
    fn sorts_by_time() {
        let tl = ChurnTimeline::new(vec![leave(5.0, 1), rejoin(2.0, 0)]);
        assert_eq!(tl.events()[0].t, 2.0);
        assert_eq!(tl.events()[1].t, 5.0);
    }

    #[test]
    fn validates_membership_transitions() {
        // double leave
        assert!(
            ChurnTimeline::validated(vec![leave(1.0, 0), leave(2.0, 0)], 4)
                .is_err()
        );
        // rejoin while active
        assert!(ChurnTimeline::validated(vec![rejoin(1.0, 2)], 4).is_err());
        // out-of-range worker
        assert!(ChurnTimeline::validated(vec![leave(1.0, 7)], 4).is_err());
        // emptying the active set
        assert!(ChurnTimeline::validated(
            vec![leave(1.0, 0), leave(2.0, 1)],
            2
        )
        .is_err());
        // a legal leave/rejoin cycle passes
        let ok = ChurnTimeline::validated(
            vec![leave(1.0, 0), rejoin(3.0, 0), leave(4.0, 0)],
            2,
        );
        assert!(ok.is_ok());
    }

    #[test]
    fn windows_extract_and_bake() {
        let tl = ChurnTimeline::validated(
            vec![
                TimedEvent {
                    t: 10.0,
                    event: ChurnEvent::LinkOutage { worker: 1, secs: 5.0 },
                },
                TimedEvent {
                    t: 30.0,
                    event: ChurnEvent::LinkDegrade {
                        worker: 1,
                        frac: 0.5,
                        secs: 10.0,
                    },
                },
            ],
            3,
        )
        .unwrap();
        let wins = tl.windows_for(1);
        assert_eq!(wins.len(), 2);
        assert_eq!(wins[0].end_s, 15.0);
        assert_eq!(wins[1].frac, 0.5);
        assert!(tl.windows_for(0).is_empty());
        assert_eq!(tl.window_ends(), vec![15.0, 40.0]);

        let mut fabric = Fabric::replicate(
            Link::new(BandwidthTrace::constant(1e8), 0.1),
            3,
        );
        tl.bake_windows(&mut fabric);
        // worker 1 collapses to the floor during the outage, halves during
        // the degrade, and is healthy otherwise; others are untouched
        assert_eq!(fabric.link(1).bandwidth_at(12.0), 1e3);
        assert_eq!(fabric.link(1).bandwidth_at(35.0), 5e7);
        assert_eq!(fabric.link(1).bandwidth_at(50.0), 1e8);
        assert_eq!(fabric.link(0).bandwidth_at(12.0), 1e8);
        assert!(fabric.link(0).trace().as_constant().is_some());
        assert!(fabric.link(1).trace().as_constant().is_none());
    }

    #[test]
    fn loss_bursts_validate_bake_and_merge() {
        let burst = |t: f64, worker: usize, rate: f64, secs: f64| TimedEvent {
            t,
            event: ChurnEvent::LossBurst { worker, rate, secs },
        };
        // degenerate params are rejected
        assert!(
            ChurnTimeline::validated(vec![burst(1.0, 0, 1.5, 5.0)], 2)
                .is_err()
        );
        assert!(
            ChurnTimeline::validated(vec![burst(1.0, 0, 0.5, 0.0)], 2)
                .is_err()
        );
        assert!(ChurnTimeline::validated(vec![burst(1.0, 3, 0.5, 5.0)], 2)
            .is_err());

        let tl = ChurnTimeline::validated(
            vec![burst(10.0, 1, 0.8, 5.0), burst(40.0, 1, 0.5, 2.0)],
            3,
        )
        .unwrap();
        assert_eq!(tl.loss_bursts_for(1).len(), 2);
        assert!(tl.loss_bursts_for(0).is_empty());
        // burst closes count as window ends (re-plan triggers)
        assert_eq!(tl.window_ends(), vec![15.0, 42.0]);

        // baking onto a lossless fabric mints a burst-only process: lossy
        // exactly inside the windows, lossless elsewhere
        let mut fabric = Fabric::replicate(
            Link::new(BandwidthTrace::constant(1e8), 0.1),
            3,
        );
        tl.bake_windows(&mut fabric);
        assert!(fabric.loss(0).is_none());
        let proc = fabric.loss(1).expect("burst-bearing worker is lossy");
        assert_eq!(proc.rate_at(1, 12.0), 0.8);
        assert_eq!(proc.rate_at(1, 41.0), 0.5);
        assert_eq!(proc.rate_at(1, 20.0), 0.0);

        // baking onto an already-lossy worker keeps its base process and
        // extends the window list
        let mut fabric2 = Fabric::replicate(
            Link::new(BandwidthTrace::constant(1e8), 0.1),
            3,
        );
        fabric2.set_loss(1, LossProcess::iid(0.1, 7));
        tl.bake_windows(&mut fabric2);
        let merged = fabric2.loss(1).unwrap();
        assert_eq!(merged.rate_at(1, 12.0), 0.8);
        assert_eq!(merged.rate_at(1, 20.0), 0.1);
        assert_eq!(merged.bursts().len(), 2);

        // an all-zero-rate burst on a lossless base is a structural no-op
        let zero = ChurnTimeline::validated(
            vec![burst(10.0, 1, 0.0, 5.0)],
            3,
        )
        .unwrap();
        let mut fabric3 = Fabric::replicate(
            Link::new(BandwidthTrace::constant(1e8), 0.1),
            3,
        );
        zero.bake_windows(&mut fabric3);
        assert!(fabric3.loss(1).is_none());
    }

    #[test]
    fn path_events_validate_against_the_path_geometry() {
        let path_outage = |t: f64, worker: usize, path: usize| TimedEvent {
            t,
            event: ChurnEvent::PathOutage { worker, path, secs: 5.0 },
        };
        // worker 0 has 2 paths, worker 1 has 1
        let paths = vec![2usize, 1];
        assert!(ChurnTimeline::validated_for(
            vec![path_outage(1.0, 0, 1)],
            2,
            &paths
        )
        .is_ok());
        // naming a path the bond doesn't have fails at compile time
        let err = ChurnTimeline::validated_for(
            vec![path_outage(1.0, 0, 2)],
            2,
            &paths,
        )
        .unwrap_err();
        assert!(err.to_string().contains("path 2"), "{err}");
        assert!(ChurnTimeline::validated_for(
            vec![path_outage(1.0, 1, 1)],
            2,
            &paths
        )
        .is_err());
        // the single-path entry point only admits path 0
        assert!(
            ChurnTimeline::validated(vec![path_outage(1.0, 0, 1)], 2).is_err()
        );
        assert!(
            ChurnTimeline::validated(vec![path_outage(1.0, 0, 0)], 2).is_ok()
        );
        // degenerate path-event params are rejected too
        let bad_frac = TimedEvent {
            t: 1.0,
            event: ChurnEvent::PathDegrade {
                worker: 0,
                path: 0,
                frac: 1.5,
                secs: 5.0,
            },
        };
        assert!(
            ChurnTimeline::validated_for(vec![bad_frac], 2, &paths).is_err()
        );
    }

    #[test]
    fn worker_level_events_hit_every_path_and_path_events_only_theirs() {
        use crate::netsim::Bond;
        let tl = ChurnTimeline::validated_for(
            vec![
                TimedEvent {
                    t: 10.0,
                    event: ChurnEvent::LinkOutage { worker: 0, secs: 5.0 },
                },
                TimedEvent {
                    t: 30.0,
                    event: ChurnEvent::PathDegrade {
                        worker: 0,
                        path: 1,
                        frac: 0.25,
                        secs: 10.0,
                    },
                },
            ],
            2,
            &[2, 1],
        )
        .unwrap();
        // the worker-level outage lands on both paths; the path-scoped
        // degrade only on path 1
        assert_eq!(tl.path_windows_for(0, 0).len(), 1);
        assert_eq!(tl.path_windows_for(0, 1).len(), 2);
        assert!(tl.path_windows_for(1, 0).is_empty());
        assert_eq!(tl.window_ends(), vec![15.0, 40.0]);

        let mut fabric = Fabric::replicate(
            Link::new(BandwidthTrace::constant(1e8), 0.1),
            2,
        );
        fabric.set_bond(
            0,
            Bond::new(vec![
                Link::new(BandwidthTrace::constant(1e8), 0.1),
                Link::new(BandwidthTrace::constant(4e7), 0.1),
            ]),
        );
        tl.bake_windows(&mut fabric);
        let bond = fabric.bond(0).unwrap();
        // during the worker-level outage both paths sit on the floor
        assert_eq!(bond.path(0).bandwidth_at(12.0), 1e3);
        assert_eq!(bond.path(1).bandwidth_at(12.0), 1e3);
        // during the path-scoped degrade only path 1 is hit
        assert_eq!(bond.path(0).bandwidth_at(35.0), 1e8);
        assert_eq!(bond.path(1).bandwidth_at(35.0), 1e7);
        // healthy otherwise; the unbonded worker is untouched
        assert_eq!(bond.path(1).bandwidth_at(50.0), 4e7);
        assert_eq!(fabric.link(1).bandwidth_at(12.0), 1e8);
    }
}
