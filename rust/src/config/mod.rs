//! Experiment configuration — JSON-serializable descriptions of a training
//! run (task, workers, strategy, network, stopping rules), plus the presets
//! mirroring the paper's settings. The `repro train --config x.json` path
//! and all `exp` generators build runs through this.
//!
//! (Config files are JSON rather than TOML because the build is fully
//! offline and the JSON codec is in-tree — see `util::json`.)

use crate::deco::DecoInput;
use crate::elastic::{ChurnEvent, ChurnSpec, DrainPolicy, TimedEvent};
use crate::netsim::{
    BandwidthTrace, Bond, DegradeWindow, Fabric, Link, LossKind, LossProcess,
    TraceKind,
};
use crate::strategy::StrategyKind;
use crate::topo::{elect, RegionTopo, Topology};
use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// model name from the manifest ("gpt_mini", "cnn_fmnist", ...) or
    /// "quadratic" / "logistic" for the analytic testbeds
    pub task: String,
    pub workers: usize,
    pub gamma: f32,
    pub strategy: StrategyKind,
    pub network: NetworkConfig,
    pub stop: StopConfig,
    pub seed: u64,
    /// pin compute time per iteration (s); None = measure wall time
    pub t_comp: Option<f64>,
    /// pin gradient size (bits); None = 32 × model params
    pub s_g_bits: Option<f64>,
    pub log_every: usize,
    /// use the blockwise (Pallas-identical) compressor
    pub block_topk: bool,
    /// per-worker global-norm gradient clipping (None = off)
    pub clip_norm: Option<f64>,
    /// churn scenario (elastic subsystem); `ChurnSpec::None` = static run
    pub churn: ChurnSpec,
    /// what happens to a leaving worker's in-flight gradients
    /// (serde: `"drop"` | `"drain"`, default drop)
    pub drain: DrainPolicy,
}

/// How the per-worker [`Fabric`] is derived from the base trace/latency —
/// the serde-friendly heterogeneity scenario layer (DESIGN.md
/// §Network-Fabric).
#[derive(Clone, Debug, Default, PartialEq)]
pub enum FabricSpec {
    /// every worker gets an identical copy of the base link (bit-identical
    /// to the former single shared link)
    #[default]
    Homogeneous,
    /// worker 0 gets `frac`× the base bandwidth (lazily scaled trace) and
    /// `mult`× the base latency
    Straggler { frac: f64, mult: f64 },
    /// explicit worker groups, each with its own trace kind and latency
    /// (multi-region topologies); group sizes must sum to the run's worker
    /// count
    Regions { groups: Vec<RegionSpec> },
}

#[derive(Clone, Debug, PartialEq)]
pub struct RegionSpec {
    pub workers: usize,
    pub trace: TraceKind,
    pub latency_s: f64,
}

/// One WAN path of a bonded worker (DESIGN.md §Bonding).
#[derive(Clone, Debug, PartialEq)]
pub struct PathSpec {
    pub trace: TraceKind,
    pub latency_s: f64,
}

/// A bonded multi-path attachment: `worker` sends over all of `paths` in
/// parallel via the water-filling scheduler, replacing whatever single
/// link the [`FabricSpec`] gave it. Legacy configs (no `bonds` key) build
/// exactly the single-link fabric they always did.
#[derive(Clone, Debug, PartialEq)]
pub struct BondSpec {
    pub worker: usize,
    pub paths: Vec<PathSpec>,
}

/// A lossy WAN attachment (DESIGN.md §Robustness): `worker`'s messages
/// are dropped per `kind` and retransmitted on an exponential backoff
/// with base timeout `rto_s`. Legacy configs (no `losses` key) build
/// exactly the lossless fabric they always did.
#[derive(Clone, Debug, PartialEq)]
pub struct LossSpec {
    pub worker: usize,
    pub kind: LossKind,
    pub seed: u64,
    /// retransmission timeout base (s); `None` = the netsim default
    pub rto_s: Option<f64>,
}

/// One region's own WAN link, overriding the shared two-tier WAN
/// trace/latency (DESIGN.md §Topology).
#[derive(Clone, Debug, PartialEq)]
pub struct RegionWanSpec {
    pub wan_trace: TraceKind,
    pub wan_latency_s: f64,
}

/// How the workers are wired into the aggregation tree — the serde
/// scenario layer over [`crate::topo::Topology`] (DESIGN.md §Topology).
#[derive(Clone, Debug, Default, PartialEq)]
pub enum TopologySpec {
    /// the historical star: every worker pushes straight to the leader
    #[default]
    Flat,
    /// two-tier aggregation over a [`FabricSpec::Regions`] fabric: each
    /// `regions` group becomes one region (contiguous worker block) with
    /// an elected aggregator, and each region crosses the WAN over its own
    /// link built from this shared trace/latency — or from its entry in
    /// `region_wan` when that list is non-empty (one spec per region, in
    /// group order; empty = every region shares the default)
    TwoTier {
        wan_trace: TraceKind,
        wan_latency_s: f64,
        region_wan: Vec<RegionWanSpec>,
    },
}

#[derive(Clone, Debug)]
pub struct NetworkConfig {
    pub trace: TraceKind,
    pub latency_s: f64,
    /// per-worker heterogeneity applied on top of the base trace/latency
    pub fabric: FabricSpec,
    /// aggregation-tree wiring (flat unless configured otherwise)
    pub topology: TopologySpec,
    /// bonded multi-path attachments applied after the fabric spec
    /// (DESIGN.md §Bonding); empty = every worker single-path, exactly the
    /// pre-bonding behavior
    pub bonds: Vec<BondSpec>,
    /// lossy WAN attachments applied last (DESIGN.md §Robustness); empty =
    /// every worker lossless, exactly the pre-loss behavior
    pub losses: Vec<LossSpec>,
}

impl NetworkConfig {
    /// Homogeneous network from a base trace + latency.
    pub fn homogeneous(trace: TraceKind, latency_s: f64) -> Self {
        Self {
            trace,
            latency_s,
            fabric: FabricSpec::Homogeneous,
            topology: TopologySpec::Flat,
            bonds: Vec::new(),
            losses: Vec::new(),
        }
    }

    /// The base link (region specs aside, the non-straggler link).
    pub fn link(&self) -> Link {
        Link::new(BandwidthTrace::new(self.trace.clone()), self.latency_s)
    }

    /// Realize the per-worker fabric for a run with `n` workers, then
    /// replace each bonded worker's link with its multi-path [`Bond`].
    pub fn build_fabric(&self, n: usize) -> Result<Fabric> {
        let mut fabric = match &self.fabric {
            FabricSpec::Homogeneous => Fabric::homogeneous(
                n,
                BandwidthTrace::new(self.trace.clone()),
                self.latency_s,
            ),
            FabricSpec::Straggler { frac, mult } => {
                if !(frac.is_finite() && mult.is_finite())
                    || *frac <= 0.0
                    || *mult <= 0.0
                {
                    return Err(anyhow!(
                        "straggler fabric needs finite frac > 0 and \
                         mult > 0 (got frac={frac}, mult={mult})"
                    ));
                }
                Fabric::with_straggler(
                    n,
                    BandwidthTrace::new(self.trace.clone()),
                    self.latency_s,
                    *frac,
                    *mult,
                )
            }
            FabricSpec::Regions { groups } => {
                if let Some(i) =
                    groups.iter().position(|g| g.workers == 0)
                {
                    // an empty group would slip through the sum check but
                    // leave a region with nobody to elect as aggregator
                    return Err(anyhow!(
                        "fabric regions group {i} has workers: 0"
                    ));
                }
                let total: usize = groups.iter().map(|g| g.workers).sum();
                if total != n {
                    return Err(anyhow!(
                        "fabric regions cover {total} workers but the run \
                         has {n}"
                    ));
                }
                let mut links = Vec::with_capacity(n);
                for g in groups {
                    for _ in 0..g.workers {
                        links.push(Link::new(
                            BandwidthTrace::new(g.trace.clone()),
                            g.latency_s,
                        ));
                    }
                }
                Fabric::new(links)
            }
        };
        for (bi, b) in self.bonds.iter().enumerate() {
            if b.worker >= n {
                return Err(anyhow!(
                    "bond {bi} names worker {} but the run has {n}",
                    b.worker
                ));
            }
            if self.bonds[..bi].iter().any(|o| o.worker == b.worker) {
                return Err(anyhow!(
                    "worker {} appears in more than one bond",
                    b.worker
                ));
            }
            if b.paths.is_empty() {
                return Err(anyhow!(
                    "bond {bi} (worker {}) has no paths",
                    b.worker
                ));
            }
            let mut links = Vec::with_capacity(b.paths.len());
            for (p, path) in b.paths.iter().enumerate() {
                if !(path.latency_s.is_finite() && path.latency_s >= 0.0) {
                    return Err(anyhow!(
                        "bond {bi} path {p} needs finite latency_s >= 0 \
                         (got {})",
                        path.latency_s
                    ));
                }
                links.push(Link::new(
                    BandwidthTrace::new(path.trace.clone()),
                    path.latency_s,
                ));
            }
            fabric.set_bond(b.worker, Bond::new(links));
        }
        for (li, l) in self.losses.iter().enumerate() {
            if l.worker >= n {
                return Err(anyhow!(
                    "loss spec {li} names worker {} but the run has {n}",
                    l.worker
                ));
            }
            if self.losses[..li].iter().any(|o| o.worker == l.worker) {
                return Err(anyhow!(
                    "worker {} appears in more than one loss spec",
                    l.worker
                ));
            }
            let in_unit = |v: f64| v.is_finite() && (0.0..=1.0).contains(&v);
            let mut proc = match l.kind {
                LossKind::Iid { p } => {
                    if !in_unit(p) {
                        return Err(anyhow!(
                            "loss spec {li} needs p in [0, 1] (got {p})"
                        ));
                    }
                    LossProcess::iid(p, l.seed)
                }
                LossKind::GilbertElliott { p_good, p_bad, pi_bad, dwell_s } => {
                    if !(in_unit(p_good) && in_unit(p_bad) && in_unit(pi_bad))
                    {
                        return Err(anyhow!(
                            "loss spec {li} needs p_good/p_bad/pi_bad in \
                             [0, 1] (got {p_good}/{p_bad}/{pi_bad})"
                        ));
                    }
                    if !(dwell_s.is_finite() && dwell_s > 0.0) {
                        return Err(anyhow!(
                            "loss spec {li} needs finite dwell_s > 0 \
                             (got {dwell_s})"
                        ));
                    }
                    LossProcess::gilbert_elliott(
                        p_good, p_bad, pi_bad, dwell_s, l.seed,
                    )
                }
            };
            if let Some(rto) = l.rto_s {
                if !(rto.is_finite() && rto > 0.0) {
                    return Err(anyhow!(
                        "loss spec {li} needs finite rto_s > 0 (got {rto})"
                    ));
                }
                proc = proc.with_rto(rto);
            }
            fabric.set_loss(l.worker, proc);
        }
        Ok(fabric)
    }

    /// Realize the aggregation-tree [`Topology`] for a run with `n`
    /// workers on `fabric` (the fabric built by [`Self::build_fabric`]).
    /// [`TopologySpec::Flat`] is always valid; [`TopologySpec::TwoTier`]
    /// requires a [`FabricSpec::Regions`] fabric — each group becomes one
    /// region (contiguous worker block) with its aggregator elected from
    /// the realized links ([`elect`] order), and the WAN fabric carries
    /// one link per region built from the shared WAN trace/latency.
    pub fn build_topology(
        &self,
        n: usize,
        fabric: &Fabric,
    ) -> Result<Topology> {
        let TopologySpec::TwoTier { wan_trace, wan_latency_s, region_wan } =
            &self.topology
        else {
            return Ok(Topology::Flat);
        };
        let FabricSpec::Regions { groups } = &self.fabric else {
            return Err(anyhow!(
                "a two-tier topology requires a 'regions' fabric spec \
                 (got {:?})",
                self.fabric
            ));
        };
        if !(wan_latency_s.is_finite() && *wan_latency_s >= 0.0) {
            return Err(anyhow!(
                "two-tier topology needs a finite wan_latency_s >= 0 \
                 (got {wan_latency_s})"
            ));
        }
        let mut regions = Vec::with_capacity(groups.len());
        let mut next = 0usize;
        for g in groups {
            let members: Vec<usize> = (next..next + g.workers).collect();
            next += g.workers;
            let aggregator = elect(fabric, &members);
            regions.push(RegionTopo::new(members, aggregator));
        }
        if next != n {
            return Err(anyhow!(
                "fabric regions cover {next} workers but the run has {n}"
            ));
        }
        let wan = if region_wan.is_empty() {
            Fabric::homogeneous(
                groups.len(),
                BandwidthTrace::new(wan_trace.clone()),
                *wan_latency_s,
            )
        } else {
            if region_wan.len() != groups.len() {
                return Err(anyhow!(
                    "region_wan lists {} links but the fabric has {} \
                     regions",
                    region_wan.len(),
                    groups.len()
                ));
            }
            let mut links = Vec::with_capacity(region_wan.len());
            for (r, rw) in region_wan.iter().enumerate() {
                if !(rw.wan_latency_s.is_finite() && rw.wan_latency_s >= 0.0)
                {
                    return Err(anyhow!(
                        "region_wan[{r}] needs finite wan_latency_s >= 0 \
                         (got {})",
                        rw.wan_latency_s
                    ));
                }
                links.push(Link::new(
                    BandwidthTrace::new(rw.wan_trace.clone()),
                    rw.wan_latency_s,
                ));
            }
            Fabric::new(links)
        };
        let topo = Topology::TwoTier { regions, wan };
        topo.validate(n)?;
        Ok(topo)
    }

    /// Nominal mean bandwidth (bits/s) of the base trace, for fallback
    /// priors.
    pub fn nominal_bps(&self) -> f64 {
        nominal_of(&self.trace)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("trace", trace_to_json(&self.trace)),
            ("latency_s", Json::num(self.latency_s)),
            ("fabric", fabric_to_json(&self.fabric)),
        ];
        if self.topology != TopologySpec::Flat {
            pairs.push(("topology", topology_to_json(&self.topology)));
        }
        if !self.bonds.is_empty() {
            pairs.push((
                "bonds",
                Json::arr(self.bonds.iter().map(|b| {
                    Json::obj(vec![
                        ("worker", Json::num(b.worker as f64)),
                        (
                            "paths",
                            Json::arr(b.paths.iter().map(|p| {
                                Json::obj(vec![
                                    ("trace", trace_to_json(&p.trace)),
                                    ("latency_s", Json::num(p.latency_s)),
                                ])
                            })),
                        ),
                    ])
                })),
            ));
        }
        if !self.losses.is_empty() {
            pairs.push((
                "losses",
                Json::arr(self.losses.iter().map(|l| {
                    let mut lp = vec![(
                        "worker",
                        Json::num(l.worker as f64),
                    )];
                    match l.kind {
                        LossKind::Iid { p } => {
                            lp.push(("kind", Json::str("iid")));
                            lp.push(("p", Json::num(p)));
                        }
                        LossKind::GilbertElliott {
                            p_good,
                            p_bad,
                            pi_bad,
                            dwell_s,
                        } => {
                            lp.push(("kind", Json::str("gilbert_elliott")));
                            lp.push(("p_good", Json::num(p_good)));
                            lp.push(("p_bad", Json::num(p_bad)));
                            lp.push(("pi_bad", Json::num(pi_bad)));
                            lp.push(("dwell_s", Json::num(dwell_s)));
                        }
                    }
                    // string, not number: see the churn Random seed note
                    lp.push(("seed", Json::str(l.seed.to_string())));
                    if let Some(rto) = l.rto_s {
                        lp.push(("rto_s", Json::num(rto)));
                    }
                    Json::obj(lp)
                })),
            ));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let bonds = match j.get("bonds") {
            None => Vec::new(),
            Some(v) => {
                let arr = v
                    .as_arr()
                    .ok_or_else(|| anyhow!("'bonds' not an array"))?;
                let mut bonds = Vec::with_capacity(arr.len());
                for b in arr {
                    let parr = b
                        .req("paths")
                        .map_err(err)?
                        .as_arr()
                        .ok_or_else(|| anyhow!("'paths' not an array"))?;
                    let mut paths = Vec::with_capacity(parr.len());
                    for p in parr {
                        paths.push(PathSpec {
                            trace: trace_from_json(
                                p.req("trace").map_err(err)?,
                            )?,
                            latency_s: p.req_f64("latency_s").map_err(err)?,
                        });
                    }
                    bonds.push(BondSpec {
                        worker: b.req_usize("worker").map_err(err)?,
                        paths,
                    });
                }
                bonds
            }
        };
        let losses = match j.get("losses") {
            None => Vec::new(),
            Some(v) => {
                let arr = v
                    .as_arr()
                    .ok_or_else(|| anyhow!("'losses' not an array"))?;
                let mut losses = Vec::with_capacity(arr.len());
                for l in arr {
                    let kind = match l.req_str("kind").map_err(err)? {
                        "iid" => LossKind::Iid {
                            p: l.req_f64("p").map_err(err)?,
                        },
                        "gilbert_elliott" => LossKind::GilbertElliott {
                            p_good: l.req_f64("p_good").map_err(err)?,
                            p_bad: l.req_f64("p_bad").map_err(err)?,
                            pi_bad: l.req_f64("pi_bad").map_err(err)?,
                            dwell_s: l.req_f64("dwell_s").map_err(err)?,
                        },
                        other => {
                            return Err(anyhow!(
                                "unknown loss kind '{other}'"
                            ))
                        }
                    };
                    losses.push(LossSpec {
                        worker: l.req_usize("worker").map_err(err)?,
                        kind,
                        seed: seed_from_json(l, "seed")?,
                        rto_s: match l.get("rto_s") {
                            None => None,
                            Some(v) => Some(v.as_f64().ok_or_else(|| {
                                anyhow!("'rto_s' must be a number")
                            })?),
                        },
                    });
                }
                losses
            }
        };
        Ok(Self {
            trace: trace_from_json(j.req("trace").map_err(err)?)?,
            latency_s: j.req_f64("latency_s").map_err(err)?,
            fabric: match j.get("fabric") {
                Some(f) => fabric_from_json(f)?,
                None => FabricSpec::Homogeneous,
            },
            topology: match j.get("topology") {
                Some(t) => topology_from_json(t)?,
                None => TopologySpec::Flat,
            },
            bonds,
            losses,
        })
    }
}

pub fn topology_to_json(t: &TopologySpec) -> Json {
    match t {
        TopologySpec::Flat => Json::obj(vec![("kind", Json::str("flat"))]),
        TopologySpec::TwoTier { wan_trace, wan_latency_s, region_wan } => {
            let mut pairs = vec![
                ("kind", Json::str("two_tier")),
                ("wan_trace", trace_to_json(wan_trace)),
                ("wan_latency_s", Json::num(*wan_latency_s)),
            ];
            if !region_wan.is_empty() {
                pairs.push((
                    "region_wan",
                    Json::arr(region_wan.iter().map(|rw| {
                        Json::obj(vec![
                            ("wan_trace", trace_to_json(&rw.wan_trace)),
                            ("wan_latency_s", Json::num(rw.wan_latency_s)),
                        ])
                    })),
                ));
            }
            Json::obj(pairs)
        }
    }
}

pub fn topology_from_json(j: &Json) -> Result<TopologySpec> {
    Ok(match j.req_str("kind").map_err(err)? {
        "flat" => TopologySpec::Flat,
        "two_tier" => {
            let region_wan = match j.get("region_wan") {
                None => Vec::new(),
                Some(v) => {
                    let arr = v.as_arr().ok_or_else(|| {
                        anyhow!("'region_wan' not an array")
                    })?;
                    let mut specs = Vec::with_capacity(arr.len());
                    for rw in arr {
                        specs.push(RegionWanSpec {
                            wan_trace: trace_from_json(
                                rw.req("wan_trace").map_err(err)?,
                            )?,
                            wan_latency_s: rw
                                .req_f64("wan_latency_s")
                                .map_err(err)?,
                        });
                    }
                    specs
                }
            };
            TopologySpec::TwoTier {
                wan_trace: trace_from_json(j.req("wan_trace").map_err(err)?)?,
                wan_latency_s: j.req_f64("wan_latency_s").map_err(err)?,
                region_wan,
            }
        }
        other => return Err(anyhow!("unknown topology kind '{other}'")),
    })
}

fn nominal_of(trace: &TraceKind) -> f64 {
    match trace {
        TraceKind::Constant { bps } => *bps,
        TraceKind::Sine { mean_bps, .. } => *mean_bps,
        TraceKind::Ou { mean_bps, .. } => *mean_bps,
        TraceKind::Markov { levels_bps, .. } => {
            levels_bps.iter().sum::<f64>() / levels_bps.len().max(1) as f64
        }
        TraceKind::Samples { bps, .. } => {
            bps.iter().sum::<f64>() / bps.len().max(1) as f64
        }
        TraceKind::Scaled { inner, frac } => frac * nominal_of(inner),
        // fault windows are transient: the nominal is the healthy rate
        TraceKind::Windowed { inner, .. } => nominal_of(inner),
    }
}

#[derive(Clone, Debug)]
pub struct StopConfig {
    pub max_iters: usize,
    pub loss_target: Option<f64>,
    pub max_virtual_time: Option<f64>,
}

fn err(msg: String) -> anyhow::Error {
    anyhow!(msg)
}

fn opt_num(j: &Json, key: &str) -> Option<f64> {
    j.get(key).and_then(|v| v.as_f64())
}

pub fn trace_to_json(t: &TraceKind) -> Json {
    match t {
        TraceKind::Constant { bps } => Json::obj(vec![
            ("kind", Json::str("constant")),
            ("bps", Json::num(*bps)),
        ]),
        TraceKind::Sine { mean_bps, amp_bps, period_s } => Json::obj(vec![
            ("kind", Json::str("sine")),
            ("mean_bps", Json::num(*mean_bps)),
            ("amp_bps", Json::num(*amp_bps)),
            ("period_s", Json::num(*period_s)),
        ]),
        TraceKind::Ou { mean_bps, sigma_bps, theta, seed } => Json::obj(vec![
            ("kind", Json::str("ou")),
            ("mean_bps", Json::num(*mean_bps)),
            ("sigma_bps", Json::num(*sigma_bps)),
            ("theta", Json::num(*theta)),
            ("seed", Json::num(*seed as f64)),
        ]),
        TraceKind::Markov { levels_bps, dwell_s, seed } => Json::obj(vec![
            ("kind", Json::str("markov")),
            (
                "levels_bps",
                Json::arr(levels_bps.iter().map(|&v| Json::num(v))),
            ),
            ("dwell_s", Json::num(*dwell_s)),
            ("seed", Json::num(*seed as f64)),
        ]),
        TraceKind::Samples { times_s, bps } => Json::obj(vec![
            ("kind", Json::str("samples")),
            ("times_s", Json::arr(times_s.iter().map(|&v| Json::num(v)))),
            ("bps", Json::arr(bps.iter().map(|&v| Json::num(v)))),
        ]),
        TraceKind::Scaled { inner, frac } => Json::obj(vec![
            ("kind", Json::str("scaled")),
            ("frac", Json::num(*frac)),
            ("inner", trace_to_json(inner)),
        ]),
        TraceKind::Windowed { inner, windows } => Json::obj(vec![
            ("kind", Json::str("windowed")),
            ("inner", trace_to_json(inner)),
            (
                "windows",
                Json::arr(windows.iter().map(|w| {
                    Json::obj(vec![
                        ("start_s", Json::num(w.start_s)),
                        ("end_s", Json::num(w.end_s)),
                        ("frac", Json::num(w.frac)),
                    ])
                })),
            ),
        ]),
    }
}

pub fn fabric_to_json(f: &FabricSpec) -> Json {
    match f {
        FabricSpec::Homogeneous => {
            Json::obj(vec![("kind", Json::str("homogeneous"))])
        }
        FabricSpec::Straggler { frac, mult } => Json::obj(vec![
            ("kind", Json::str("straggler")),
            ("frac", Json::num(*frac)),
            ("mult", Json::num(*mult)),
        ]),
        FabricSpec::Regions { groups } => Json::obj(vec![
            ("kind", Json::str("regions")),
            (
                "groups",
                Json::arr(groups.iter().map(|g| {
                    Json::obj(vec![
                        ("workers", Json::num(g.workers as f64)),
                        ("trace", trace_to_json(&g.trace)),
                        ("latency_s", Json::num(g.latency_s)),
                    ])
                })),
            ),
        ]),
    }
}

pub fn fabric_from_json(j: &Json) -> Result<FabricSpec> {
    Ok(match j.req_str("kind").map_err(err)? {
        "homogeneous" => FabricSpec::Homogeneous,
        "straggler" => FabricSpec::Straggler {
            frac: j.req_f64("frac").map_err(err)?,
            mult: j.req_f64("mult").map_err(err)?,
        },
        "regions" => {
            let arr = j
                .req("groups")
                .map_err(err)?
                .as_arr()
                .ok_or_else(|| anyhow!("'groups' not an array"))?;
            let mut groups = Vec::with_capacity(arr.len());
            for g in arr {
                groups.push(RegionSpec {
                    workers: g.req_usize("workers").map_err(err)?,
                    trace: trace_from_json(g.req("trace").map_err(err)?)?,
                    latency_s: g.req_f64("latency_s").map_err(err)?,
                });
            }
            FabricSpec::Regions { groups }
        }
        other => return Err(anyhow!("unknown fabric kind '{other}'")),
    })
}

pub fn trace_from_json(j: &Json) -> Result<TraceKind> {
    let kind = j.req_str("kind").map_err(err)?;
    let nums = |key: &str| -> Result<Vec<f64>> {
        Ok(j.req(key)
            .map_err(err)?
            .as_arr()
            .ok_or_else(|| anyhow!("'{key}' not an array"))?
            .iter()
            .filter_map(|v| v.as_f64())
            .collect())
    };
    Ok(match kind {
        "constant" => TraceKind::Constant { bps: j.req_f64("bps").map_err(err)? },
        "sine" => TraceKind::Sine {
            mean_bps: j.req_f64("mean_bps").map_err(err)?,
            amp_bps: j.req_f64("amp_bps").map_err(err)?,
            period_s: j.req_f64("period_s").map_err(err)?,
        },
        "ou" => TraceKind::Ou {
            mean_bps: j.req_f64("mean_bps").map_err(err)?,
            sigma_bps: j.req_f64("sigma_bps").map_err(err)?,
            theta: j.req_f64("theta").map_err(err)?,
            seed: j.req_f64("seed").map_err(err)? as u64,
        },
        "markov" => TraceKind::Markov {
            levels_bps: nums("levels_bps")?,
            dwell_s: j.req_f64("dwell_s").map_err(err)?,
            seed: j.req_f64("seed").map_err(err)? as u64,
        },
        "samples" => TraceKind::Samples { times_s: nums("times_s")?, bps: nums("bps")? },
        "scaled" => TraceKind::Scaled {
            inner: Box::new(trace_from_json(j.req("inner").map_err(err)?)?),
            frac: j.req_f64("frac").map_err(err)?,
        },
        "windowed" => {
            let arr = j
                .req("windows")
                .map_err(err)?
                .as_arr()
                .ok_or_else(|| anyhow!("'windows' not an array"))?;
            let mut windows = Vec::with_capacity(arr.len());
            for w in arr {
                windows.push(DegradeWindow {
                    start_s: w.req_f64("start_s").map_err(err)?,
                    end_s: w.req_f64("end_s").map_err(err)?,
                    frac: w.req_f64("frac").map_err(err)?,
                });
            }
            TraceKind::Windowed {
                inner: Box::new(trace_from_json(j.req("inner").map_err(err)?)?),
                windows,
            }
        }
        other => return Err(anyhow!("unknown trace kind '{other}'")),
    })
}

pub fn churn_to_json(c: &ChurnSpec) -> Json {
    match c {
        ChurnSpec::None => Json::obj(vec![("kind", Json::str("none"))]),
        ChurnSpec::Scripted { events } => Json::obj(vec![
            ("kind", Json::str("scripted")),
            (
                "events",
                Json::arr(events.iter().map(|ev| {
                    let mut pairs = vec![("t", Json::num(ev.t))];
                    match &ev.event {
                        ChurnEvent::Leave { worker } => {
                            pairs.push(("event", Json::str("leave")));
                            pairs.push(("worker", Json::num(*worker as f64)));
                        }
                        ChurnEvent::Rejoin { worker } => {
                            pairs.push(("event", Json::str("rejoin")));
                            pairs.push(("worker", Json::num(*worker as f64)));
                        }
                        ChurnEvent::LinkOutage { worker, secs } => {
                            pairs.push(("event", Json::str("link_outage")));
                            pairs.push(("worker", Json::num(*worker as f64)));
                            pairs.push(("secs", Json::num(*secs)));
                        }
                        ChurnEvent::LinkDegrade { worker, frac, secs } => {
                            pairs.push(("event", Json::str("link_degrade")));
                            pairs.push(("worker", Json::num(*worker as f64)));
                            pairs.push(("frac", Json::num(*frac)));
                            pairs.push(("secs", Json::num(*secs)));
                        }
                        ChurnEvent::PathOutage { worker, path, secs } => {
                            pairs.push(("event", Json::str("path_outage")));
                            pairs.push(("worker", Json::num(*worker as f64)));
                            pairs.push(("path", Json::num(*path as f64)));
                            pairs.push(("secs", Json::num(*secs)));
                        }
                        ChurnEvent::PathDegrade {
                            worker,
                            path,
                            frac,
                            secs,
                        } => {
                            pairs.push(("event", Json::str("path_degrade")));
                            pairs.push(("worker", Json::num(*worker as f64)));
                            pairs.push(("path", Json::num(*path as f64)));
                            pairs.push(("frac", Json::num(*frac)));
                            pairs.push(("secs", Json::num(*secs)));
                        }
                        ChurnEvent::LossBurst { worker, rate, secs } => {
                            pairs.push(("event", Json::str("loss_burst")));
                            pairs.push(("worker", Json::num(*worker as f64)));
                            pairs.push(("rate", Json::num(*rate)));
                            pairs.push(("secs", Json::num(*secs)));
                        }
                    }
                    Json::obj(pairs)
                })),
            ),
        ]),
        ChurnSpec::Random {
            leave_rate_per_100s,
            mean_down_s,
            outage_rate_per_100s,
            outage_s,
            horizon_s,
            seed,
        } => Json::obj(vec![
            ("kind", Json::str("random")),
            ("leave_rate_per_100s", Json::num(*leave_rate_per_100s)),
            ("mean_down_s", Json::num(*mean_down_s)),
            ("outage_rate_per_100s", Json::num(*outage_rate_per_100s)),
            ("outage_s", Json::num(*outage_s)),
            ("horizon_s", Json::num(*horizon_s)),
            // string, not number: a u64 seed above 2^53 would silently
            // round through f64 and compile a different timeline on reload
            ("seed", Json::str(seed.to_string())),
        ]),
    }
}

/// Parse a u64 seed that may be a JSON string (lossless, what we write) or
/// a number (hand-written configs; rejected when it can't round-trip).
fn seed_from_json(j: &Json, key: &str) -> Result<u64> {
    let v = j.req(key).map_err(err)?;
    if let Some(s) = v.as_str() {
        return s
            .parse()
            .map_err(|e| anyhow!("'{key}' = {s:?} is not a u64: {e}"));
    }
    let f = v
        .as_f64()
        .ok_or_else(|| anyhow!("'{key}' must be a u64 string or integer"))?;
    const EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
    if !(f.is_finite() && f >= 0.0 && f.fract() == 0.0 && f <= EXACT) {
        return Err(anyhow!(
            "'{key}' = {f} is not an exactly-representable u64; write it \
             as a string"
        ));
    }
    Ok(f as u64)
}

pub fn churn_from_json(j: &Json) -> Result<ChurnSpec> {
    Ok(match j.req_str("kind").map_err(err)? {
        "none" => ChurnSpec::None,
        "scripted" => {
            let arr = j
                .req("events")
                .map_err(err)?
                .as_arr()
                .ok_or_else(|| anyhow!("'events' not an array"))?;
            let mut events = Vec::with_capacity(arr.len());
            for e in arr {
                let t = e.req_f64("t").map_err(err)?;
                let worker = e.req_usize("worker").map_err(err)?;
                let event = match e.req_str("event").map_err(err)? {
                    "leave" => ChurnEvent::Leave { worker },
                    "rejoin" => ChurnEvent::Rejoin { worker },
                    "link_outage" => ChurnEvent::LinkOutage {
                        worker,
                        secs: e.req_f64("secs").map_err(err)?,
                    },
                    "link_degrade" => ChurnEvent::LinkDegrade {
                        worker,
                        frac: e.req_f64("frac").map_err(err)?,
                        secs: e.req_f64("secs").map_err(err)?,
                    },
                    "path_outage" => ChurnEvent::PathOutage {
                        worker,
                        path: e.req_usize("path").map_err(err)?,
                        secs: e.req_f64("secs").map_err(err)?,
                    },
                    "path_degrade" => ChurnEvent::PathDegrade {
                        worker,
                        path: e.req_usize("path").map_err(err)?,
                        frac: e.req_f64("frac").map_err(err)?,
                        secs: e.req_f64("secs").map_err(err)?,
                    },
                    "loss_burst" => ChurnEvent::LossBurst {
                        worker,
                        rate: e.req_f64("rate").map_err(err)?,
                        secs: e.req_f64("secs").map_err(err)?,
                    },
                    other => {
                        return Err(anyhow!("unknown churn event '{other}'"))
                    }
                };
                events.push(TimedEvent { t, event });
            }
            ChurnSpec::Scripted { events }
        }
        "random" => {
            let f = |key| j.req_f64(key).map_err(err);
            ChurnSpec::Random {
                leave_rate_per_100s: f("leave_rate_per_100s")?,
                mean_down_s: f("mean_down_s")?,
                outage_rate_per_100s: f("outage_rate_per_100s")?,
                outage_s: f("outage_s")?,
                horizon_s: f("horizon_s")?,
                seed: seed_from_json(j, "seed")?,
            }
        }
        other => return Err(anyhow!("unknown churn kind '{other}'")),
    })
}

pub fn strategy_to_json(s: &StrategyKind) -> Json {
    match s {
        StrategyKind::DSgd => Json::obj(vec![("kind", Json::str("d_sgd"))]),
        StrategyKind::DEfSgd { delta } => Json::obj(vec![
            ("kind", Json::str("d_ef_sgd")),
            ("delta", Json::num(*delta)),
        ]),
        StrategyKind::DdSgd { tau } => Json::obj(vec![
            ("kind", Json::str("dd_sgd")),
            ("tau", Json::num(*tau as f64)),
        ]),
        StrategyKind::Accordion { delta_low, delta_high } => Json::obj(vec![
            ("kind", Json::str("accordion")),
            ("delta_low", Json::num(*delta_low)),
            ("delta_high", Json::num(*delta_high)),
        ]),
        StrategyKind::CocktailSgd => {
            Json::obj(vec![("kind", Json::str("cocktail_sgd"))])
        }
        StrategyKind::DecoSgd { update_every } => Json::obj(vec![
            ("kind", Json::str("deco_sgd")),
            ("update_every", Json::num(*update_every as f64)),
        ]),
        StrategyKind::DecoEvent { update_every } => Json::obj(vec![
            ("kind", Json::str("deco_event")),
            ("update_every", Json::num(*update_every as f64)),
        ]),
        StrategyKind::DecoTwoTier { update_every } => Json::obj(vec![
            ("kind", Json::str("deco_two_tier")),
            ("update_every", Json::num(*update_every as f64)),
        ]),
        StrategyKind::DecoLossy { update_every, quantile } => Json::obj(vec![
            ("kind", Json::str("deco_lossy")),
            ("update_every", Json::num(*update_every as f64)),
            ("quantile", Json::num(*quantile)),
        ]),
    }
}

pub fn strategy_from_json(j: &Json) -> Result<StrategyKind> {
    Ok(match j.req_str("kind").map_err(err)? {
        "d_sgd" => StrategyKind::DSgd,
        "d_ef_sgd" => StrategyKind::DEfSgd {
            delta: j.req_f64("delta").map_err(err)?,
        },
        "dd_sgd" => StrategyKind::DdSgd {
            tau: j.req_usize("tau").map_err(err)?,
        },
        "accordion" => StrategyKind::Accordion {
            delta_low: j.req_f64("delta_low").map_err(err)?,
            delta_high: j.req_f64("delta_high").map_err(err)?,
        },
        "cocktail_sgd" => StrategyKind::CocktailSgd,
        "deco_sgd" => StrategyKind::DecoSgd {
            update_every: j.req_usize("update_every").map_err(err)?,
        },
        "deco_event" => StrategyKind::DecoEvent {
            update_every: j.req_usize("update_every").map_err(err)?,
        },
        "deco_lossy" => {
            let quantile = j.req_f64("quantile").map_err(err)?;
            if !(quantile.is_finite() && 0.0 < quantile && quantile < 1.0) {
                return Err(anyhow!(
                    "deco_lossy needs quantile in (0, 1) (got {quantile})"
                ));
            }
            StrategyKind::DecoLossy {
                update_every: j.req_usize("update_every").map_err(err)?,
                quantile,
            }
        }
        "deco_two_tier" => StrategyKind::DecoTwoTier {
            update_every: j.req_usize("update_every").map_err(err)?,
        },
        other => return Err(anyhow!("unknown strategy kind '{other}'")),
    })
}

impl ExperimentConfig {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("task", Json::str(&self.task)),
            ("workers", Json::num(self.workers as f64)),
            ("gamma", Json::num(self.gamma as f64)),
            ("strategy", strategy_to_json(&self.strategy)),
            ("network", self.network.to_json()),
            (
                "stop",
                Json::obj(vec![
                    ("max_iters", Json::num(self.stop.max_iters as f64)),
                    (
                        "loss_target",
                        self.stop
                            .loss_target
                            .map(Json::num)
                            .unwrap_or(Json::Null),
                    ),
                    (
                        "max_virtual_time",
                        self.stop
                            .max_virtual_time
                            .map(Json::num)
                            .unwrap_or(Json::Null),
                    ),
                ]),
            ),
            ("seed", Json::num(self.seed as f64)),
            ("log_every", Json::num(self.log_every as f64)),
            ("block_topk", Json::Bool(self.block_topk)),
        ];
        if let Some(t) = self.t_comp {
            pairs.push(("t_comp", Json::num(t)));
        }
        if let Some(s) = self.s_g_bits {
            pairs.push(("s_g_bits", Json::num(s)));
        }
        if let Some(c) = self.clip_norm {
            pairs.push(("clip_norm", Json::num(c)));
        }
        if !self.churn.is_none() {
            pairs.push(("churn", churn_to_json(&self.churn)));
        }
        if self.drain == DrainPolicy::Drain {
            pairs.push(("drain", Json::str("drain")));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let stop = j.req("stop").map_err(err)?;
        Ok(Self {
            task: j.req_str("task").map_err(err)?.to_string(),
            workers: j.req_usize("workers").map_err(err)?,
            gamma: j.req_f64("gamma").map_err(err)? as f32,
            strategy: strategy_from_json(j.req("strategy").map_err(err)?)?,
            network: NetworkConfig::from_json(j.req("network").map_err(err)?)?,
            stop: StopConfig {
                max_iters: stop.req_usize("max_iters").map_err(err)?,
                loss_target: opt_num(stop, "loss_target"),
                max_virtual_time: opt_num(stop, "max_virtual_time"),
            },
            seed: opt_num(j, "seed").unwrap_or(0.0) as u64,
            t_comp: opt_num(j, "t_comp"),
            s_g_bits: opt_num(j, "s_g_bits"),
            log_every: opt_num(j, "log_every").unwrap_or(10.0) as usize,
            block_topk: j.get("block_topk").and_then(|v| v.as_bool()).unwrap_or(false),
            clip_norm: opt_num(j, "clip_norm"),
            churn: match j.get("churn") {
                Some(c) => churn_from_json(c)?,
                None => ChurnSpec::None,
            },
            drain: match j.get("drain") {
                None => DrainPolicy::Drop,
                Some(v) => match v.as_str() {
                    Some("drop") => DrainPolicy::Drop,
                    Some("drain") => DrainPolicy::Drain,
                    Some(other) => {
                        return Err(anyhow!("unknown drain policy '{other}'"))
                    }
                    None => {
                        return Err(anyhow!(
                            "'drain' must be \"drop\" or \"drain\""
                        ))
                    }
                },
            },
        })
    }

    pub fn from_json_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("parsing experiment config: {e}"))?;
        Self::from_json(&j)
    }

    /// Fallback DeCo inputs before the monitor warms up.
    pub fn fallback(&self, s_g: f64, t_comp: f64) -> DecoInput {
        DecoInput {
            s_g,
            a: self.network.nominal_bps(),
            b: self.network.latency_s,
            t_comp,
        }
    }

    /// Translate into [`crate::coordinator::TrainParams`].
    pub fn train_params(&self, dim: usize) -> crate::coordinator::TrainParams {
        let s_g = self.s_g_bits.unwrap_or(dim as f64 * 32.0);
        let t_comp_prior = self.t_comp.unwrap_or(0.1);
        crate::coordinator::TrainParams {
            gamma: self.gamma,
            max_iters: self.stop.max_iters,
            log_every: self.log_every,
            loss_target: self.stop.loss_target,
            max_virtual_time: self.stop.max_virtual_time,
            t_comp_override: self.t_comp,
            s_g_override: Some(s_g),
            paper_wire: true,
            block_topk: self.block_topk,
            clip_norm: self.clip_norm,
            seed: self.seed,
            fallback: self.fallback(s_g, t_comp_prior),
            monitor_alpha: 0.3,
            plan: crate::strategy::PlanBasis::Bottleneck,
            threads: None,
            churn: self.churn.clone(),
            drain: self.drain,
        }
    }
}

/// Paper-style WAN preset: OU bandwidth around `mean_bps`, latency `b`.
pub fn wan_network(mean_bps: f64, latency_s: f64, seed: u64) -> NetworkConfig {
    NetworkConfig {
        trace: TraceKind::Ou {
            mean_bps,
            sigma_bps: 0.25 * mean_bps,
            theta: 0.2,
            seed,
        },
        latency_s,
        fabric: FabricSpec::Homogeneous,
        topology: TopologySpec::Flat,
        bonds: Vec::new(),
        losses: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ExperimentConfig {
        ExperimentConfig {
            task: "gpt_mini".into(),
            workers: 4,
            gamma: 0.1,
            strategy: StrategyKind::DecoSgd { update_every: 20 },
            network: wan_network(1e8, 0.2, 1),
            stop: StopConfig {
                max_iters: 100,
                loss_target: Some(3.0),
                max_virtual_time: None,
            },
            seed: 7,
            t_comp: Some(0.35),
            s_g_bits: Some(124e6 * 32.0),
            log_every: 10,
            block_topk: false,
            clip_norm: Some(2.0),
            churn: ChurnSpec::None,
            drain: DrainPolicy::Drop,
        }
    }

    #[test]
    fn json_roundtrip() {
        let c = sample();
        let text = c.to_json().to_string_pretty();
        let back = ExperimentConfig::from_json(
            &Json::parse(&text).unwrap(),
        )
        .unwrap();
        assert_eq!(back.task, c.task);
        assert_eq!(back.strategy, c.strategy);
        assert_eq!(back.network.latency_s, 0.2);
        assert_eq!(back.stop.loss_target, Some(3.0));
        assert_eq!(back.t_comp, Some(0.35));
        assert_eq!(back.seed, 7);
    }

    #[test]
    fn all_strategies_roundtrip() {
        for s in [
            StrategyKind::DSgd,
            StrategyKind::DEfSgd { delta: 0.1 },
            StrategyKind::DdSgd { tau: 3 },
            StrategyKind::Accordion { delta_low: 0.01, delta_high: 0.3 },
            StrategyKind::CocktailSgd,
            StrategyKind::DecoSgd { update_every: 5 },
            StrategyKind::DecoEvent { update_every: 7 },
            StrategyKind::DecoTwoTier { update_every: 9 },
            StrategyKind::DecoLossy { update_every: 11, quantile: 0.9 },
        ] {
            let j = strategy_to_json(&s);
            assert_eq!(strategy_from_json(&j).unwrap(), s);
        }
        // a quantile outside (0, 1) is rejected at parse time, before the
        // builder's assert could panic mid-run
        let bad = strategy_to_json(&StrategyKind::DecoLossy {
            update_every: 11,
            quantile: 1.0,
        });
        assert!(strategy_from_json(&bad).is_err());
    }

    #[test]
    fn churn_specs_roundtrip() {
        for c in [
            ChurnSpec::None,
            ChurnSpec::Scripted {
                events: vec![
                    TimedEvent {
                        t: 10.0,
                        event: ChurnEvent::Leave { worker: 0 },
                    },
                    TimedEvent {
                        t: 40.0,
                        event: ChurnEvent::Rejoin { worker: 0 },
                    },
                    TimedEvent {
                        t: 55.0,
                        event: ChurnEvent::LinkOutage { worker: 2, secs: 15.0 },
                    },
                    TimedEvent {
                        t: 90.0,
                        event: ChurnEvent::LinkDegrade {
                            worker: 1,
                            frac: 0.3,
                            secs: 20.0,
                        },
                    },
                    TimedEvent {
                        t: 110.0,
                        event: ChurnEvent::PathOutage {
                            worker: 2,
                            path: 1,
                            secs: 8.0,
                        },
                    },
                    TimedEvent {
                        t: 130.0,
                        event: ChurnEvent::PathDegrade {
                            worker: 2,
                            path: 0,
                            frac: 0.4,
                            secs: 12.0,
                        },
                    },
                    TimedEvent {
                        t: 150.0,
                        event: ChurnEvent::LossBurst {
                            worker: 1,
                            rate: 0.8,
                            secs: 25.0,
                        },
                    },
                ],
            },
            ChurnSpec::Random {
                leave_rate_per_100s: 2.0,
                mean_down_s: 30.0,
                outage_rate_per_100s: 1.0,
                outage_s: 12.0,
                horizon_s: 600.0,
                seed: 9,
            },
            // seeds above 2^53 must survive the round trip losslessly
            ChurnSpec::Random {
                leave_rate_per_100s: 2.0,
                mean_down_s: 30.0,
                outage_rate_per_100s: 0.0,
                outage_s: 0.0,
                horizon_s: 600.0,
                seed: (1u64 << 53) + 1,
            },
        ] {
            let j = churn_to_json(&c);
            let text = j.to_string_pretty();
            let back = churn_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, c);
        }
        // numeric seeds stay accepted for hand-written configs, but only
        // when they round-trip exactly; wrong-typed drain keys error
        let hand = Json::parse(
            "{\"kind\": \"random\", \"leave_rate_per_100s\": 1.0, \
             \"mean_down_s\": 10.0, \"outage_rate_per_100s\": 0.0, \
             \"outage_s\": 0.0, \"horizon_s\": 100.0, \"seed\": 42}",
        )
        .unwrap();
        assert!(matches!(
            churn_from_json(&hand).unwrap(),
            ChurnSpec::Random { seed: 42, .. }
        ));
        let lossy = Json::parse(
            "{\"kind\": \"random\", \"leave_rate_per_100s\": 1.0, \
             \"mean_down_s\": 10.0, \"outage_rate_per_100s\": 0.0, \
             \"outage_s\": 0.0, \"horizon_s\": 100.0, \"seed\": -1}",
        )
        .unwrap();
        assert!(churn_from_json(&lossy).is_err());
    }

    #[test]
    fn experiment_config_carries_churn_and_defaults_to_none() {
        let mut c = sample();
        c.churn = ChurnSpec::Random {
            leave_rate_per_100s: 1.0,
            mean_down_s: 10.0,
            outage_rate_per_100s: 0.5,
            outage_s: 5.0,
            horizon_s: 200.0,
            seed: 3,
        };
        c.drain = DrainPolicy::Drain;
        let text = c.to_json().to_string_pretty();
        let back =
            ExperimentConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.churn, c.churn);
        assert_eq!(back.drain, DrainPolicy::Drain);
        let tp = back.train_params(512);
        assert_eq!(tp.churn, c.churn);
        assert_eq!(tp.drain, DrainPolicy::Drain);
        // pre-elastic configs (no churn/drain keys) parse to the defaults
        let legacy = sample();
        let text = legacy.to_json().to_string_pretty();
        assert!(!text.contains("churn") && !text.contains("drain"));
        let parsed =
            ExperimentConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(parsed.churn.is_none());
        assert_eq!(parsed.drain, DrainPolicy::Drop);
        // unknown policies and wrong-typed keys error instead of silently
        // falling back to Drop
        let bad = Json::parse(
            &text.replacen('{', "{\"drain\": \"flush\",", 1),
        )
        .unwrap();
        assert!(ExperimentConfig::from_json(&bad).is_err());
        let bad_type =
            Json::parse(&text.replacen('{', "{\"drain\": true,", 1)).unwrap();
        assert!(ExperimentConfig::from_json(&bad_type).is_err());
    }

    #[test]
    fn windowed_trace_roundtrips() {
        let t = TraceKind::Windowed {
            inner: Box::new(TraceKind::Constant { bps: 1e8 }),
            windows: vec![
                DegradeWindow { start_s: 5.0, end_s: 10.0, frac: 0.0 },
                DegradeWindow { start_s: 20.0, end_s: 30.0, frac: 0.5 },
            ],
        };
        let j = trace_to_json(&t);
        let back =
            trace_from_json(&Json::parse(&j.to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(back, t);
        // a windowed constant still reports the inner nominal bandwidth
        let c = NetworkConfig::homogeneous(t, 0.1);
        assert_eq!(c.nominal_bps(), 1e8);
    }

    #[test]
    fn all_traces_roundtrip() {
        for t in [
            TraceKind::Constant { bps: 1e8 },
            TraceKind::Sine { mean_bps: 1e8, amp_bps: 1e7, period_s: 5.0 },
            TraceKind::Ou { mean_bps: 1e8, sigma_bps: 1e7, theta: 0.2, seed: 3 },
            TraceKind::Markov {
                levels_bps: vec![1e7, 1e8],
                dwell_s: 2.0,
                seed: 4,
            },
            TraceKind::Samples {
                times_s: vec![0.0, 1.0],
                bps: vec![1e8, 2e8],
            },
        ] {
            let j = trace_to_json(&t);
            assert_eq!(trace_from_json(&j).unwrap(), t);
        }
    }

    #[test]
    fn nominal_bandwidths() {
        assert_eq!(wan_network(1e8, 0.1, 0).nominal_bps(), 1e8);
        let c = NetworkConfig {
            trace: TraceKind::Markov {
                levels_bps: vec![1e8, 3e8],
                dwell_s: 1.0,
                seed: 0,
            },
            latency_s: 0.1,
            fabric: FabricSpec::Homogeneous,
            topology: TopologySpec::Flat,
            bonds: Vec::new(),
            losses: Vec::new(),
        };
        assert_eq!(c.nominal_bps(), 2e8);
        // scaled traces report the scaled nominal
        let s = NetworkConfig::homogeneous(
            TraceKind::Scaled {
                inner: Box::new(TraceKind::Constant { bps: 2e8 }),
                frac: 0.25,
            },
            0.1,
        );
        assert_eq!(s.nominal_bps(), 5e7);
    }

    #[test]
    fn fabric_specs_roundtrip() {
        for f in [
            FabricSpec::Homogeneous,
            FabricSpec::Straggler { frac: 0.25, mult: 2.0 },
            FabricSpec::Regions {
                groups: vec![
                    RegionSpec {
                        workers: 2,
                        trace: TraceKind::Constant { bps: 1e8 },
                        latency_s: 0.05,
                    },
                    RegionSpec {
                        workers: 2,
                        trace: TraceKind::Ou {
                            mean_bps: 5e7,
                            sigma_bps: 1e7,
                            theta: 0.2,
                            seed: 3,
                        },
                        latency_s: 0.4,
                    },
                ],
            },
        ] {
            let j = fabric_to_json(&f);
            let text = j.to_string_pretty();
            let back =
                fabric_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, f);
        }
    }

    #[test]
    fn network_config_fabric_roundtrips_and_defaults() {
        let mut c = wan_network(1e8, 0.2, 1);
        c.fabric = FabricSpec::Straggler { frac: 0.1, mult: 3.0 };
        let back = NetworkConfig::from_json(
            &Json::parse(&c.to_json().to_string_pretty()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.fabric, c.fabric);
        // configs written before the fabric layer default to homogeneous
        let legacy = Json::parse(
            "{\"trace\": {\"kind\": \"constant\", \"bps\": 1e8}, \
             \"latency_s\": 0.2}",
        )
        .unwrap();
        let parsed = NetworkConfig::from_json(&legacy).unwrap();
        assert_eq!(parsed.fabric, FabricSpec::Homogeneous);
    }

    #[test]
    fn build_fabric_realizes_specs() {
        let mut c = NetworkConfig::homogeneous(
            TraceKind::Constant { bps: 1e8 },
            0.1,
        );
        let f = c.build_fabric(4).unwrap();
        assert_eq!(f.workers(), 4);
        assert_eq!(f.bottleneck(0.0), (1e8, 0.1));

        c.fabric = FabricSpec::Straggler { frac: 0.5, mult: 2.0 };
        let f = c.build_fabric(4).unwrap();
        assert_eq!(f.bottleneck(0.0), (5e7, 0.2));

        c.fabric = FabricSpec::Regions {
            groups: vec![
                RegionSpec {
                    workers: 3,
                    trace: TraceKind::Constant { bps: 2e8 },
                    latency_s: 0.05,
                },
                RegionSpec {
                    workers: 1,
                    trace: TraceKind::Constant { bps: 2e7 },
                    latency_s: 0.3,
                },
            ],
        };
        let f = c.build_fabric(4).unwrap();
        assert_eq!(f.bottleneck(0.0), (2e7, 0.3));
        // group sizes must cover the worker count exactly
        assert!(c.build_fabric(5).is_err());

        // degenerate straggler values from user config error, not panic
        for (frac, mult) in
            [(0.0, 2.0), (-0.5, 1.0), (0.5, 0.0), (f64::NAN, 1.0)]
        {
            c.fabric = FabricSpec::Straggler { frac, mult };
            assert!(c.build_fabric(4).is_err(), "frac={frac} mult={mult}");
        }
    }

    #[test]
    fn regions_with_zero_workers_are_rejected() {
        // a zero-size group can pass the sum check while leaving a region
        // with nobody to elect as aggregator — it must error out up front
        let mut c = NetworkConfig::homogeneous(
            TraceKind::Constant { bps: 1e8 },
            0.1,
        );
        c.fabric = FabricSpec::Regions {
            groups: vec![
                RegionSpec {
                    workers: 4,
                    trace: TraceKind::Constant { bps: 1e8 },
                    latency_s: 0.05,
                },
                RegionSpec {
                    workers: 0,
                    trace: TraceKind::Constant { bps: 1e7 },
                    latency_s: 0.3,
                },
            ],
        };
        let e = c.build_fabric(4).unwrap_err().to_string();
        assert!(e.contains("workers: 0"), "{e}");
    }

    #[test]
    fn topology_spec_roundtrips_and_defaults_to_flat() {
        for t in [
            TopologySpec::Flat,
            TopologySpec::TwoTier {
                wan_trace: TraceKind::Constant { bps: 2e7 },
                wan_latency_s: 0.3,
                region_wan: Vec::new(),
            },
            TopologySpec::TwoTier {
                wan_trace: TraceKind::Constant { bps: 2e7 },
                wan_latency_s: 0.3,
                region_wan: vec![
                    RegionWanSpec {
                        wan_trace: TraceKind::Constant { bps: 4e7 },
                        wan_latency_s: 0.2,
                    },
                    RegionWanSpec {
                        wan_trace: TraceKind::Constant { bps: 1e7 },
                        wan_latency_s: 0.4,
                    },
                ],
            },
        ] {
            let j = topology_to_json(&t);
            let back = topology_from_json(
                &Json::parse(&j.to_string_pretty()).unwrap(),
            )
            .unwrap();
            assert_eq!(back, t);
        }
        // a flat topology is omitted from the JSON (legacy configs parse)
        let mut c = wan_network(1e8, 0.2, 1);
        assert!(!c.to_json().to_string_pretty().contains("topology"));
        c.topology = TopologySpec::TwoTier {
            wan_trace: TraceKind::Constant { bps: 2e7 },
            wan_latency_s: 0.3,
            region_wan: Vec::new(),
        };
        let back = NetworkConfig::from_json(
            &Json::parse(&c.to_json().to_string_pretty()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.topology, c.topology);
        let legacy = Json::parse(
            "{\"trace\": {\"kind\": \"constant\", \"bps\": 1e8}, \
             \"latency_s\": 0.2}",
        )
        .unwrap();
        let parsed = NetworkConfig::from_json(&legacy).unwrap();
        assert_eq!(parsed.topology, TopologySpec::Flat);
    }

    #[test]
    fn build_topology_realizes_two_tier_from_regions() {
        use crate::topo::Topology;
        let mut c = NetworkConfig::homogeneous(
            TraceKind::Constant { bps: 1e9 },
            0.005,
        );
        c.fabric = FabricSpec::Regions {
            groups: vec![
                RegionSpec {
                    workers: 2,
                    trace: TraceKind::Constant { bps: 1e9 },
                    latency_s: 0.005,
                },
                RegionSpec {
                    workers: 3,
                    trace: TraceKind::Constant { bps: 5e8 },
                    latency_s: 0.01,
                },
            ],
        };
        c.topology = TopologySpec::TwoTier {
            wan_trace: TraceKind::Constant { bps: 2e7 },
            wan_latency_s: 0.3,
            region_wan: Vec::new(),
        };
        let fabric = c.build_fabric(5).unwrap();
        let topo = c.build_topology(5, &fabric).unwrap();
        let Topology::TwoTier { regions, wan } = &topo else {
            panic!("expected two-tier")
        };
        assert_eq!(regions.len(), 2);
        assert_eq!(regions[0].members, vec![0, 1]);
        assert_eq!(regions[1].members, vec![2, 3, 4]);
        // identical links inside a group: election keeps the lowest index
        assert_eq!(regions[0].aggregator, 0);
        assert_eq!(regions[1].aggregator, 2);
        assert_eq!(wan.workers(), 2);
        assert_eq!(wan.bottleneck(0.0), (2e7, 0.3));

        // flat spec: always Ok(Flat), any fabric
        let flat = NetworkConfig::homogeneous(
            TraceKind::Constant { bps: 1e8 },
            0.1,
        );
        let f = flat.build_fabric(4).unwrap();
        assert!(matches!(
            flat.build_topology(4, &f).unwrap(),
            Topology::Flat
        ));

        // two-tier without a regions fabric errors
        let mut bad = flat.clone();
        bad.topology = c.topology.clone();
        let f = bad.build_fabric(4).unwrap();
        assert!(bad.build_topology(4, &f).is_err());

        // degenerate WAN latency errors
        c.topology = TopologySpec::TwoTier {
            wan_trace: TraceKind::Constant { bps: 2e7 },
            wan_latency_s: f64::NAN,
            region_wan: Vec::new(),
        };
        let f = c.build_fabric(5).unwrap();
        assert!(c.build_topology(5, &f).is_err());
    }

    #[test]
    fn bonds_roundtrip_and_default_to_empty() {
        let mut c = wan_network(1e8, 0.2, 1);
        // no bonds: the key is omitted and legacy configs parse to empty
        assert!(!c.to_json().to_string_pretty().contains("bonds"));
        c.bonds = vec![BondSpec {
            worker: 0,
            paths: vec![
                PathSpec {
                    trace: TraceKind::Constant { bps: 1e8 },
                    latency_s: 0.05,
                },
                PathSpec {
                    trace: TraceKind::Constant { bps: 2e7 },
                    latency_s: 0.25,
                },
            ],
        }];
        let back = NetworkConfig::from_json(
            &Json::parse(&c.to_json().to_string_pretty()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.bonds, c.bonds);
        let legacy = Json::parse(
            "{\"trace\": {\"kind\": \"constant\", \"bps\": 1e8}, \
             \"latency_s\": 0.2}",
        )
        .unwrap();
        assert!(NetworkConfig::from_json(&legacy).unwrap().bonds.is_empty());
    }

    #[test]
    fn losses_roundtrip_and_build_into_the_fabric() {
        let mut c = wan_network(1e8, 0.2, 1);
        // no losses: the key is omitted and legacy configs parse to empty
        assert!(!c.to_json().to_string_pretty().contains("losses"));
        let legacy = Json::parse(
            "{\"trace\": {\"kind\": \"constant\", \"bps\": 1e8}, \
             \"latency_s\": 0.2}",
        )
        .unwrap();
        assert!(NetworkConfig::from_json(&legacy).unwrap().losses.is_empty());

        c.losses = vec![
            LossSpec {
                worker: 0,
                kind: LossKind::Iid { p: 0.3 },
                seed: 42,
                rto_s: Some(0.1),
            },
            LossSpec {
                worker: 2,
                kind: LossKind::GilbertElliott {
                    p_good: 0.02,
                    p_bad: 0.9,
                    pi_bad: 0.25,
                    dwell_s: 20.0,
                },
                seed: u64::MAX, // string-seed path must stay lossless
                rto_s: None,
            },
        ];
        let back = NetworkConfig::from_json(
            &Json::parse(&c.to_json().to_string_pretty()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.losses, c.losses);

        let fabric = c.build_fabric(4).unwrap();
        let p0 = fabric.loss(0).expect("worker 0 lossy");
        assert_eq!(p0.rate_at(0, 5.0), 0.3);
        assert_eq!(p0.rto_s(), 0.1);
        assert!(fabric.loss(2).is_some());
        assert!(fabric.loss(1).is_none());
        // a p = 0 i.i.d. spec builds the lossless fabric (structural no-op)
        let mut zero = wan_network(1e8, 0.2, 1);
        zero.losses = vec![LossSpec {
            worker: 1,
            kind: LossKind::Iid { p: 0.0 },
            seed: 1,
            rto_s: None,
        }];
        assert!(zero.build_fabric(4).unwrap().loss(1).is_none());

        // invalid specs error instead of panicking
        for (worker, kind, rto_s) in [
            (9, LossKind::Iid { p: 0.3 }, None),
            (0, LossKind::Iid { p: 1.5 }, None),
            (0, LossKind::Iid { p: 0.3 }, Some(0.0)),
            (
                0,
                LossKind::GilbertElliott {
                    p_good: 0.02,
                    p_bad: 0.9,
                    pi_bad: 0.25,
                    dwell_s: 0.0,
                },
                None,
            ),
        ] {
            let mut bad = wan_network(1e8, 0.2, 1);
            bad.losses = vec![LossSpec { worker, kind, seed: 1, rto_s }];
            assert!(bad.build_fabric(4).is_err());
        }
        // duplicate worker
        let mut dup = wan_network(1e8, 0.2, 1);
        dup.losses = vec![
            LossSpec {
                worker: 0,
                kind: LossKind::Iid { p: 0.3 },
                seed: 1,
                rto_s: None,
            },
            LossSpec {
                worker: 0,
                kind: LossKind::Iid { p: 0.4 },
                seed: 2,
                rto_s: None,
            },
        ];
        let e = dup.build_fabric(4).unwrap_err().to_string();
        assert!(e.contains("more than one loss spec"), "{e}");
    }

    #[test]
    fn build_fabric_applies_and_validates_bonds() {
        let mut c = NetworkConfig::homogeneous(
            TraceKind::Constant { bps: 1e8 },
            0.1,
        );
        c.bonds = vec![BondSpec {
            worker: 1,
            paths: vec![
                PathSpec {
                    trace: TraceKind::Constant { bps: 1e8 },
                    latency_s: 0.05,
                },
                PathSpec {
                    trace: TraceKind::Constant { bps: 2e7 },
                    latency_s: 0.25,
                },
            ],
        }];
        let fabric = c.build_fabric(4).unwrap();
        assert_eq!(fabric.paths_per_worker(), vec![1, 2, 1, 1]);
        let bond = fabric.bond(1).unwrap();
        assert_eq!(bond.k(), 2);
        assert_eq!(bond.path(1).latency(), 0.25);
        assert!(fabric.bond(0).is_none());

        // out-of-range worker
        let mut bad = c.clone();
        bad.bonds[0].worker = 9;
        let e = bad.build_fabric(4).unwrap_err().to_string();
        assert!(e.contains("names worker 9"), "{e}");
        // duplicate worker
        let mut dup = c.clone();
        dup.bonds.push(dup.bonds[0].clone());
        let e = dup.build_fabric(4).unwrap_err().to_string();
        assert!(e.contains("more than one bond"), "{e}");
        // empty path list
        let mut empty = c.clone();
        empty.bonds[0].paths.clear();
        let e = empty.build_fabric(4).unwrap_err().to_string();
        assert!(e.contains("has no paths"), "{e}");
        // degenerate latency
        let mut nan = c.clone();
        nan.bonds[0].paths[0].latency_s = f64::NAN;
        assert!(nan.build_fabric(4).is_err());
    }

    #[test]
    fn region_wan_overrides_the_shared_wan_link() {
        use crate::topo::Topology;
        let mut c = NetworkConfig::homogeneous(
            TraceKind::Constant { bps: 1e9 },
            0.005,
        );
        c.fabric = FabricSpec::Regions {
            groups: vec![
                RegionSpec {
                    workers: 2,
                    trace: TraceKind::Constant { bps: 1e9 },
                    latency_s: 0.005,
                },
                RegionSpec {
                    workers: 2,
                    trace: TraceKind::Constant { bps: 5e8 },
                    latency_s: 0.01,
                },
            ],
        };
        c.topology = TopologySpec::TwoTier {
            wan_trace: TraceKind::Constant { bps: 2e7 },
            wan_latency_s: 0.3,
            region_wan: vec![
                RegionWanSpec {
                    wan_trace: TraceKind::Constant { bps: 8e7 },
                    wan_latency_s: 0.1,
                },
                RegionWanSpec {
                    wan_trace: TraceKind::Constant { bps: 1e7 },
                    wan_latency_s: 0.5,
                },
            ],
        };
        let fabric = c.build_fabric(4).unwrap();
        let topo = c.build_topology(4, &fabric).unwrap();
        let Topology::TwoTier { wan, .. } = &topo else {
            panic!("expected two-tier")
        };
        assert_eq!(wan.workers(), 2);
        assert_eq!(wan.link(0).bandwidth_at(0.0), 8e7);
        assert_eq!(wan.link(0).latency(), 0.1);
        assert_eq!(wan.link(1).bandwidth_at(0.0), 1e7);
        assert_eq!(wan.link(1).latency(), 0.5);

        // one spec per region, in group order — mismatch errors
        let TopologySpec::TwoTier { region_wan, .. } = &mut c.topology
        else {
            unreachable!()
        };
        region_wan.pop();
        let e = c.build_topology(4, &fabric).unwrap_err().to_string();
        assert!(e.contains("region_wan lists 1"), "{e}");
        // degenerate per-region latency errors
        let TopologySpec::TwoTier { region_wan, .. } = &mut c.topology
        else {
            unreachable!()
        };
        region_wan.push(RegionWanSpec {
            wan_trace: TraceKind::Constant { bps: 1e7 },
            wan_latency_s: f64::NAN,
        });
        assert!(c.build_topology(4, &fabric).is_err());
    }

    #[test]
    fn train_params_pass_through() {
        let c = sample();
        let tp = c.train_params(470_016);
        assert_eq!(tp.t_comp_override, Some(0.35));
        assert_eq!(tp.s_g_override, Some(124e6 * 32.0));
        assert_eq!(tp.loss_target, Some(3.0));
        assert_eq!(tp.fallback.b, 0.2);
    }
}
