//! DeCo-SGD — reproduction of *"DECo-SGD: Joint Optimization of Delay
//! Staleness and Gradient Compression Ratio for Distributed SGD"* (a.k.a.
//! *"Taming Latency and Bandwidth"*, CS.LG 2025) as a rust + JAX + Pallas
//! three-layer stack.
//!
//! Layer map (see DESIGN.md):
//! * **L3 (this crate)** — the distributed-training coordinator: worker
//!   pipeline with delayed aggregation, error-feedback Top-k compression on
//!   the gradient hot path, the DeCo adaptive controller, a WAN network
//!   simulator, the Theorem-3 timeline model, metrics, config and CLI.
//! * **L2/L1 (python, build-time only)** — JAX models (CNN / ViT / GPT) and
//!   Pallas kernels AOT-lowered to HLO text under `artifacts/`, loaded and
//!   executed here through the PJRT CPU client ([`runtime`]). Python never
//!   runs at training time.
//!
//! Entry points: the `repro` binary (experiment CLI), `examples/`, and the
//! public modules below.

pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod deco;
pub mod elastic;
pub mod exp;
pub mod metrics;
pub mod netsim;
pub mod obs;
pub mod optim;
pub mod runtime;
pub mod strategy;
pub mod timesim;
pub mod topo;
pub mod util;

/// Block size shared with the L1 Pallas kernel and the flat-parameter
/// padding convention (python/compile/params.py::BLOCK).
pub const BLOCK: usize = 1024;
