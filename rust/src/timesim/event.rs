//! Exact event recurrence of Eq. 19 — the ground truth the closed form
//! approximates, and (in trace-driven form) the virtual clock the training
//! loop runs on.
//!
//! ```text
//! TC_k     = TM_k + b
//! TS_{k+1} = T_comp + max{ TC_{k-τ}, TS_k }
//! TM_{k+1} = δ·S_g/a + max{ TM_k, TS_{k+1} }
//! ```
//! with `TS_0 = TM_0 = 0`, `TC_k = 0` for `k ≤ 0`. The indexing follows the
//! paper exactly (1-based `k`), so `T_avg = TC_t / t`.

use super::model::PipelineParams;
use crate::coordinator::VirtualClock;
use crate::netsim::{Fabric, Link};

/// Per-iteration timeline: computation end, transmission end, arrival.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IterTimes {
    pub ts: f64,
    pub tm: f64,
    pub tc: f64,
}

/// Constant-(a, b) recurrence simulator.
#[derive(Clone, Debug)]
pub struct EventSim {
    /// rows[k-1] holds iteration k (1-based per the paper)
    rows: Vec<IterTimes>,
}

impl EventSim {
    /// Run `iters` iterations of the recurrence with fixed parameters.
    pub fn run(p: &PipelineParams, iters: usize) -> Self {
        let mut rows: Vec<IterTimes> = Vec::with_capacity(iters);
        let tx = p.t_tx();
        for k in 1..=iters {
            let ts_prev = if k == 1 { 0.0 } else { rows[k - 2].ts };
            let tm_prev = if k == 1 { 0.0 } else { rows[k - 2].tm };
            // TC_{k-1-τ} (arrival of the gradient this step must wait for)
            let tc_delayed = if k as i64 - 1 - p.tau as i64 >= 1 {
                rows[k - 2 - p.tau].tc
            } else {
                0.0
            };
            let ts = p.t_comp + tc_delayed.max(ts_prev);
            let tm = tx + tm_prev.max(ts);
            let tc = tm + p.b;
            rows.push(IterTimes { ts, tm, tc });
        }
        Self { rows }
    }

    /// Trace-driven generalization on a per-worker [`Fabric`]: every worker
    /// transmits over its own link and iteration k's aggregation completes
    /// at the **slowest** worker's arrival. `bits(k)` gives the wire size of
    /// iteration k (so δ may vary per iteration — this is what DD-EF-SGD
    /// under DeCo does). Delegates to [`VirtualClock`] — the single Eq. 19
    /// implementation both the event simulator and the training loop share
    /// (DESIGN.md §Network-Fabric). The reported `tm` is the slowest
    /// worker's transmission end.
    pub fn run_on_fabric(
        fabric: Fabric,
        t_comp: impl Fn(usize) -> f64,
        tau: impl Fn(usize) -> usize,
        bits: impl Fn(usize) -> u64,
        iters: usize,
    ) -> Self {
        let mut clock = VirtualClock::new(fabric);
        let mut rows: Vec<IterTimes> = Vec::with_capacity(iters);
        for k in 1..=iters {
            let t = clock.tick(t_comp(k), tau(k), bits(k));
            rows.push(IterTimes { ts: t.ts, tm: t.tm, tc: t.tc });
        }
        Self { rows }
    }

    /// Single shared link: a 1-worker fabric (the pre-fabric behavior,
    /// bit-identical to it).
    pub fn run_on_link(
        link: &Link,
        t_comp: impl Fn(usize) -> f64,
        tau: impl Fn(usize) -> usize,
        bits: impl Fn(usize) -> u64,
        iters: usize,
    ) -> Self {
        Self::run_on_fabric(
            Fabric::new(vec![link.clone()]),
            t_comp,
            tau,
            bits,
            iters,
        )
    }

    pub fn rows(&self) -> &[IterTimes] {
        &self.rows
    }

    pub fn iters(&self) -> usize {
        self.rows.len()
    }

    /// `TC_t` of the final iteration (total elapsed time).
    pub fn total_time(&self) -> f64 {
        self.rows.last().map(|r| r.tc).unwrap_or(0.0)
    }

    /// Measured average iteration time `TC_t / t` (Theorem 3's quantity).
    pub fn t_avg(&self) -> f64 {
        self.total_time() / self.iters().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::BandwidthTrace;
    use crate::timesim::model::{approx_error_bound, t_avg_closed_form};

    fn p(a: f64, b: f64, delta: f64, tau: usize, t_comp: f64, s_g: f64) -> PipelineParams {
        PipelineParams { a, b, delta, tau, t_comp, s_g }
    }

    #[test]
    fn dsgd_serial_exact() {
        // τ=0, δ=1: each iteration is exactly T_comp + tx + b after the
        // previous arrival
        let pp = p(1e8, 0.1, 1.0, 0, 0.05, 1e7);
        let sim = EventSim::run(&pp, 100);
        let per_iter = 0.05 + 0.1 + 0.1;
        assert!((sim.total_time() - 100.0 * per_iter).abs() < 1e-9);
    }

    #[test]
    fn theorem3_bound_holds_all_regimes() {
        // sweep the four proof cases; |TC_t - t*T_avg'| <= bound
        let cases = [
            p(1e8, 0.1, 0.01, 8, 0.5, 1e8),  // case 1
            p(1e6, 0.05, 1.0, 20, 0.01, 1e8), // case 2
            p(1e8, 1.0, 0.05, 2, 0.3, 1e9),  // case 3-ish
            p(1e7, 0.5, 0.5, 1, 0.05, 1e8),  // case 4-ish
            p(5e7, 0.2, 0.1, 3, 0.1, 4e9),
        ];
        for pp in cases {
            let t = 3000;
            let sim = EventSim::run(&pp, t);
            let lhs = (sim.total_time() - t as f64 * t_avg_closed_form(&pp)).abs();
            let bound = approx_error_bound(&pp) + 1e-9;
            // the paper proves O(1) absolute deviation; allow 3x slack for
            // the pre-periodic transient
            assert!(
                lhs <= 3.0 * bound,
                "params {pp:?}: |TC_t - t*T'| = {lhs} > 3*{bound}"
            );
        }
    }

    #[test]
    fn t_avg_converges_to_closed_form() {
        let pp = p(1e8, 0.3, 0.1, 2, 0.1, 2e9);
        let closed = t_avg_closed_form(&pp);
        let sim = EventSim::run(&pp, 5000);
        assert!(
            (sim.t_avg() - closed).abs() / closed < 0.01,
            "sim={} closed={closed}",
            sim.t_avg()
        );
    }

    #[test]
    fn monotone_timeline() {
        let pp = p(1e7, 0.2, 0.2, 3, 0.05, 1e9);
        let sim = EventSim::run(&pp, 200);
        for w in sim.rows().windows(2) {
            assert!(w[1].ts >= w[0].ts);
            assert!(w[1].tm >= w[0].tm);
            assert!(w[1].tc >= w[0].tc);
        }
        for r in sim.rows() {
            assert!(r.tm >= r.ts);
            assert!(r.tc > r.tm);
        }
    }

    #[test]
    fn link_run_matches_constant_recurrence() {
        let pp = p(1e8, 0.15, 0.2, 2, 0.07, 1e9);
        let sim1 = EventSim::run(&pp, 500);
        let link = Link::new(BandwidthTrace::constant(pp.a), pp.b);
        let bits = (pp.delta * pp.s_g) as u64;
        let sim2 = EventSim::run_on_link(
            &link,
            |_| pp.t_comp,
            |_| pp.tau,
            |_| bits,
            500,
        );
        assert!(
            (sim1.total_time() - sim2.total_time()).abs() < 1e-6,
            "{} vs {}",
            sim1.total_time(),
            sim2.total_time()
        );
    }

    #[test]
    fn fabric_run_homogeneous_matches_link_run() {
        let link = Link::new(BandwidthTrace::constant(5e7), 0.2);
        let bits = |k: usize| 1_000_000 + (k as u64 % 5) * 300_000;
        let sim1 = EventSim::run_on_link(&link, |_| 0.05, |k| k % 3, bits, 300);
        let sim2 = EventSim::run_on_fabric(
            Fabric::replicate(link.clone(), 6),
            |_| 0.05,
            |k| k % 3,
            bits,
            300,
        );
        assert_eq!(sim1.iters(), sim2.iters());
        for (a, b) in sim1.rows().iter().zip(sim2.rows()) {
            assert_eq!(a.ts.to_bits(), b.ts.to_bits());
            assert_eq!(a.tm.to_bits(), b.tm.to_bits());
            assert_eq!(a.tc.to_bits(), b.tc.to_bits());
        }
    }

    #[test]
    fn straggler_fabric_never_faster() {
        let trace = BandwidthTrace::constant(1e8);
        let homo = EventSim::run_on_fabric(
            crate::netsim::Fabric::homogeneous(4, trace.clone(), 0.1),
            |_| 0.05,
            |_| 2,
            |_| 8_000_000,
            200,
        );
        let strag = EventSim::run_on_fabric(
            crate::netsim::Fabric::with_straggler(4, trace, 0.1, 0.25, 2.0),
            |_| 0.05,
            |_| 2,
            |_| 8_000_000,
            200,
        );
        for (h, s) in homo.rows().iter().zip(strag.rows()) {
            assert!(s.tc >= h.tc);
        }
        assert!(strag.total_time() > homo.total_time());
    }

    #[test]
    fn larger_tau_never_slower() {
        for tau in 0..6usize {
            let pp0 = p(2e7, 0.4, 0.3, tau, 0.05, 1e9);
            let pp1 = p(2e7, 0.4, 0.3, tau + 1, 0.05, 1e9);
            let t0 = EventSim::run(&pp0, 1000).total_time();
            let t1 = EventSim::run(&pp1, 1000).total_time();
            assert!(t1 <= t0 + 1e-6, "tau {tau}->{}: {t0} -> {t1}", tau + 1);
        }
    }
}

#[cfg(test)]
mod periodicity_tests {
    use super::*;
    use crate::timesim::model::{classify, Regime};

    /// Cases 3/4 of the Theorem 3 proof: when τ cannot hide the round trip,
    /// the sequence {TS_{k+1} − TS_k} becomes (τ+1)-periodic with period sum
    /// T_comp + b + δS_g/a.
    #[test]
    fn intermediate_delay_regime_is_tau_plus_1_periodic() {
        let cases = [
            // Case 3: T_comp > tx, τ·T_comp <= tx + b
            PipelineParams { a: 1e9, b: 1.0, delta: 0.2, tau: 2, t_comp: 0.4, s_g: 1e9 },
            // Case 4: T_comp < tx, τ·tx <= T_comp + b
            PipelineParams { a: 1e8, b: 1.0, delta: 0.5, tau: 1, t_comp: 0.1, s_g: 1e8 },
        ];
        for p in cases {
            assert_eq!(classify(&p), Regime::Periodic, "{p:?}");
            let sim = EventSim::run(&p, 400);
            let rows = sim.rows();
            let period = p.tau + 1;
            let expect = p.t_comp + p.b + p.t_tx();
            // skip the transient, then check TS_{k+(τ+1)} − TS_k == period sum
            for k in 50..(rows.len() - period) {
                let d = rows[k + period].ts - rows[k].ts;
                assert!(
                    (d - expect).abs() < 1e-9,
                    "{p:?}: TS diff {d} != {expect} at k={k}"
                );
            }
        }
    }

    /// Case 1: computation-dominated — TS_k == k·T_comp exactly after the
    /// proof's induction (for all k, from the start).
    #[test]
    fn computation_dominated_ts_is_linear() {
        let p = PipelineParams {
            a: 1e9, b: 0.05, delta: 0.01, tau: 4, t_comp: 0.5, s_g: 1e9,
        };
        assert_eq!(classify(&p), Regime::ComputationDominated);
        let sim = EventSim::run(&p, 200);
        for (i, r) in sim.rows().iter().enumerate() {
            let k = (i + 1) as f64;
            assert!(
                (r.ts - k * p.t_comp).abs() < 1e-9,
                "TS_{k} = {} != {}",
                r.ts,
                k * p.t_comp
            );
        }
    }

    /// Case 2: communication-dominated — steady-state TM spacing equals the
    /// transmission time.
    #[test]
    fn communication_dominated_tm_spacing_is_tx() {
        let p = PipelineParams {
            a: 1e7, b: 0.05, delta: 1.0, tau: 20, t_comp: 0.01, s_g: 1e8,
        };
        assert_eq!(classify(&p), Regime::CommunicationDominated);
        let sim = EventSim::run(&p, 300);
        let rows = sim.rows();
        let tx = p.t_tx();
        for k in 100..rows.len() - 1 {
            let d = rows[k + 1].tm - rows[k].tm;
            assert!((d - tx).abs() < 1e-9, "TM spacing {d} != {tx} at {k}");
        }
    }
}
