//! Theorem 3 closed form and the four-regime structure from its proof.
//!
//! `T_avg ≈ max{ (T_comp + b + δS_g/a)/(τ+1), δS_g/a, T_comp }` with error
//! `|TC_t − t·T_avg'| ≤ b + min{T_comp, δS_g/a}` — both sides are checked
//! against [`super::event::EventSim`] in tests and in `exp thm3`.



/// The (a, b, δ, τ, T_comp, S_g) tuple every timing formula consumes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PipelineParams {
    /// bandwidth, bits/s
    pub a: f64,
    /// end-to-end latency, s
    pub b: f64,
    /// compression ratio in (0, 1]
    pub delta: f64,
    /// delay staleness, iterations
    pub tau: usize,
    /// computation time per iteration, s
    pub t_comp: f64,
    /// gradient size, bits
    pub s_g: f64,
}

impl PipelineParams {
    /// Transmission time per iteration: `δ·S_g / a`.
    pub fn t_tx(&self) -> f64 {
        self.delta * self.s_g / self.a
    }
}

/// The four regimes in the Theorem 3 proof (B.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    /// Case 1: `T_comp > δS_g/a` and `τ·T_comp > δS_g/a + b` — fully hidden
    /// communication; the pipeline runs at compute speed.
    ComputationDominated,
    /// Case 2: `δS_g/a > T_comp` and `τ·δS_g/a > T_comp + b` — the link is
    /// saturated; iterations tick at the transmission rate.
    CommunicationDominated,
    /// Cases 3/4: τ too small to hide the round trip; the timeline is
    /// (τ+1)-periodic with period `T_comp + b + δS_g/a`.
    Periodic,
}

/// Classify per the proof's case split.
pub fn classify(p: &PipelineParams) -> Regime {
    let tx = p.t_tx();
    let tau = p.tau as f64;
    if p.t_comp > tx && tau * p.t_comp > tx + p.b {
        Regime::ComputationDominated
    } else if tx > p.t_comp && tau * tx > p.t_comp + p.b {
        Regime::CommunicationDominated
    } else {
        Regime::Periodic
    }
}

/// Theorem 3: the steady-state average iteration time.
pub fn t_avg_closed_form(p: &PipelineParams) -> f64 {
    let tx = p.t_tx();
    let pipelined = (p.t_comp + p.b + tx) / (p.tau as f64 + 1.0);
    pipelined.max(tx).max(p.t_comp)
}

/// Theorem 3's approximation-error bound on `|TC_t − t·T_avg'|`.
pub fn approx_error_bound(p: &PipelineParams) -> f64 {
    p.b + p.t_comp.min(p.t_tx())
}

/// Throughput efficiency (Fig. 1): ratio of compute-bound throughput to the
/// achieved throughput of plain D-SGD (τ=0, δ=1) at these network params.
pub fn dsgd_throughput_efficiency(a: f64, b: f64, t_comp: f64, s_g: f64) -> f64 {
    let p = PipelineParams { a, b, delta: 1.0, tau: 0, t_comp, s_g };
    t_comp / t_avg_closed_form(&p)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(a: f64, b: f64, delta: f64, tau: usize, t_comp: f64, s_g: f64) -> PipelineParams {
        PipelineParams { a, b, delta, tau, t_comp, s_g }
    }

    #[test]
    fn dsgd_serial_time() {
        // τ=0, δ=1: T_avg = T_comp + b + S_g/a (serial round trip)
        let pp = p(1e8, 0.1, 1.0, 0, 0.05, 1e8);
        let t = t_avg_closed_form(&pp);
        assert!((t - (0.05 + 0.1 + 1.0)).abs() < 1e-12);
        assert_eq!(classify(&pp), Regime::Periodic);
    }

    #[test]
    fn computation_dominated_hits_t_comp() {
        // big τ, tiny δ: pipeline hides everything
        let pp = p(1e8, 0.1, 0.01, 8, 0.5, 1e8);
        assert_eq!(classify(&pp), Regime::ComputationDominated);
        assert!((t_avg_closed_form(&pp) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn communication_dominated_hits_tx() {
        // τ large but link slow: T_avg == δS_g/a
        let pp = p(1e6, 0.05, 1.0, 20, 0.01, 1e8);
        assert_eq!(classify(&pp), Regime::CommunicationDominated);
        assert!((t_avg_closed_form(&pp) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn t_avg_monotone_in_delta_and_tau() {
        // increasing δ can only increase T_avg; increasing τ can only
        // decrease it
        let base = p(1e8, 0.2, 0.1, 2, 0.05, 1e9);
        let t0 = t_avg_closed_form(&base);
        let more_delta = p(1e8, 0.2, 0.5, 2, 0.05, 1e9);
        assert!(t_avg_closed_form(&more_delta) >= t0);
        let more_tau = p(1e8, 0.2, 0.1, 6, 0.05, 1e9);
        assert!(t_avg_closed_form(&more_tau) <= t0);
    }

    #[test]
    fn efficiency_degrades_with_latency_and_recovers_with_bandwidth() {
        let s_g = 124e6 * 32.0; // GPT-2 124M × f32 — the Fig. 1 setting
        // t_comp calibrated so the paper's "50% below 2 Gbps / above
        // 200 ms" contour lands where Fig. 1 reports it (their A40 step
        // time at GPT-2 batch-5 with grad accumulation; see exp::fig1)
        let t_comp = 2.0;
        let hi_bw = dsgd_throughput_efficiency(10e9, 0.01, t_comp, s_g);
        let lo_bw = dsgd_throughput_efficiency(1e9, 0.01, t_comp, s_g);
        let hi_lat = dsgd_throughput_efficiency(10e9, 1.0, t_comp, s_g);
        assert!(hi_bw > lo_bw);
        assert!(hi_bw > hi_lat);
        assert!(hi_bw <= 1.0 && lo_bw > 0.0);
        // paper: at ~2 Gbps + 200 ms efficiency ~50%
        let mid = dsgd_throughput_efficiency(2e9, 0.2, t_comp, s_g);
        assert!(mid < 0.65 && mid > 0.35, "mid={mid}");
    }
}
