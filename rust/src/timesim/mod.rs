//! Timeline model of DD-EF-SGD — Theorem 3 and its surroundings.
//!
//! * [`event`] — the exact Eq. 19 recurrence over `TS_k` (computation end),
//!   `TM_k` (transmission end) and `TC_k` (arrival) with constant (a, b),
//!   plus a trace-driven generalization used by the virtual training clock.
//! * [`model`] — the closed-form `T_avg` approximation, the four-regime
//!   classifier from the proof, and the throughput-efficiency map (Fig. 1).
//! * [`timeline`] — per-iteration segment renderer for Fig. 2.

pub mod event;
pub mod model;
pub mod timeline;

pub use event::{EventSim, IterTimes};
pub use model::{t_avg_closed_form, PipelineParams, Regime};
pub use timeline::{render_ascii, TimelineRow};
