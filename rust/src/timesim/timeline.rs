//! Fig. 2 — per-iteration running timelines of D-SGD and its variants.
//!
//! Produces both a structured row form (for CSV) and an ASCII rendering of
//! the compute / transmit / latency segments, one lane per iteration index,
//! matching the paper's figure qualitatively.

use super::event::EventSim;
use super::model::PipelineParams;


#[derive(Clone, Debug)]
pub struct TimelineRow {
    pub iter: usize,
    pub comp_start: f64,
    pub comp_end: f64,
    pub tx_start: f64,
    pub tx_end: f64,
    pub arrival: f64,
}

/// Extract segment rows from an event simulation.
pub fn rows(p: &PipelineParams, iters: usize) -> Vec<TimelineRow> {
    let sim = EventSim::run(p, iters);
    let tx = p.t_tx();
    sim.rows()
        .iter()
        .enumerate()
        .map(|(i, r)| TimelineRow {
            iter: i + 1,
            comp_start: r.ts - p.t_comp,
            comp_end: r.ts,
            tx_start: r.tm - tx,
            tx_end: r.tm,
            arrival: r.tc,
        })
        .collect()
}

/// ASCII rendering: one line per iteration, `#` = compute, `=` = transmit,
/// `.` = latency in flight.
pub fn render_ascii(p: &PipelineParams, iters: usize, width: usize) -> String {
    let rws = rows(p, iters);
    let horizon = rws.last().map(|r| r.arrival).unwrap_or(1.0);
    let scale = width as f64 / horizon;
    let mut out = String::new();
    for r in &rws {
        let mut line = vec![b' '; width + 1];
        let put = |line: &mut Vec<u8>, a: f64, b: f64, c: u8| {
            let i0 = (a * scale).round() as usize;
            let i1 = ((b * scale).round() as usize).min(width);
            for ch in line[i0.min(width)..i1].iter_mut() {
                *ch = c;
            }
        };
        put(&mut line, r.comp_start, r.comp_end, b'#');
        put(&mut line, r.tx_start, r.tx_end, b'=');
        put(&mut line, r.tx_end, r.arrival, b'.');
        out.push_str(&format!(
            "it{:>3} |{}|\n",
            r.iter,
            String::from_utf8(line).unwrap()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_consistent() {
        let p = PipelineParams {
            a: 1e8,
            b: 0.2,
            delta: 0.1,
            tau: 2,
            t_comp: 0.05,
            s_g: 1e9,
        };
        let rws = rows(&p, 12);
        assert_eq!(rws.len(), 12);
        for r in &rws {
            assert!(r.comp_end - r.comp_start - p.t_comp < 1e-12);
            assert!(r.tx_start >= r.comp_end - 1e-9 || r.iter == 1);
            assert!((r.arrival - r.tx_end - p.b).abs() < 1e-12);
        }
    }

    #[test]
    fn ascii_renders_nonempty() {
        let p = PipelineParams {
            a: 1e8,
            b: 0.1,
            delta: 1.0,
            tau: 0,
            t_comp: 0.1,
            s_g: 1e8,
        };
        let s = render_ascii(&p, 6, 80);
        assert_eq!(s.lines().count(), 6);
        assert!(s.contains('#'));
        assert!(s.contains('='));
        assert!(s.contains('.'));
    }
}
