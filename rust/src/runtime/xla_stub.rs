//! Offline stand-in for the external `xla` crate (PJRT bindings,
//! xla_extension 0.5.1). The vendored native toolchain is not part of the
//! default build, so `client.rs` aliases this module as `xla` unless the
//! `pjrt` feature is enabled; the API surface mirrors exactly what
//! `client.rs` uses, and every entry point fails with [`Unavailable`] so
//! `Runtime::load` returns a clean error and everything analytic —
//! quadratic/logistic oracles, the DeCo controller, the full simulator —
//! keeps working with zero native dependencies. Integration tests and
//! benches already skip when `artifacts/` is absent, so the stub never even
//! gets exercised there.

use std::path::Path;

/// The error every stub call returns.
#[derive(Clone, Copy, Debug)]
pub struct Unavailable;

impl std::fmt::Display for Unavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PJRT runtime unavailable: built without the `pjrt` feature \
             (offline xla stub)"
        )
    }
}

impl std::error::Error for Unavailable {}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Unavailable> {
        Err(Unavailable)
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Unavailable> {
        Err(Unavailable)
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Unavailable> {
        Err(Unavailable)
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Unavailable> {
        Err(Unavailable)
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(
        _path: impl AsRef<Path>,
    ) -> Result<Self, Unavailable> {
        Err(Unavailable)
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_v: &[T]) -> Self {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Unavailable> {
        Err(Unavailable)
    }

    pub fn to_tuple1(&self) -> Result<Literal, Unavailable> {
        Err(Unavailable)
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal), Unavailable> {
        Err(Unavailable)
    }

    pub fn copy_raw_to(&self, _out: &mut [f32]) -> Result<(), Unavailable> {
        Err(Unavailable)
    }

    pub fn get_first_element<T: Default>(&self) -> Result<T, Unavailable> {
        Err(Unavailable)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Unavailable> {
        Err(Unavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = format!("{err} / {err:?}");
        assert!(msg.contains("pjrt"));
    }

    #[test]
    fn literal_surface_compiles_and_fails() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_tuple2().is_err());
        assert!(lit.get_first_element::<f32>().is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
    }
}
