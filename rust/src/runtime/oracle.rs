//! `PjrtOracle` — the [`GradOracle`] implementation backed by the AOT HLO
//! gradient modules, wired to the synthetic data generators. This is what
//! the coordinator trains *real* models through.

use super::client::{BatchInput, GradExec};
use crate::data::{Sharded, SyntheticCorpus, SyntheticImages};
use crate::optim::GradOracle;
use crate::runtime::manifest::ModelEntry;

/// A model's data stream.
pub enum DataSource {
    Images(SyntheticImages),
    Corpus(SyntheticCorpus),
}

impl DataSource {
    /// Build the canonical source for a manifest model entry.
    pub fn for_model(m: &ModelEntry, seed: u64) -> Self {
        match m.task.as_str() {
            "image" => {
                let (h, w, c) = (m.x_shape[1], m.x_shape[2], m.x_shape[3]);
                let classes = m
                    .meta
                    .get("classes")
                    .and_then(|v| v.as_u64())
                    .unwrap_or(10) as usize;
                // hard setting: heavy pixel noise slows convergence to the
                // hundreds-of-iterations regime the paper's tasks live in
                // (their CNN trains for epochs over 60k images)
                DataSource::Images(
                    SyntheticImages::new(h, w, c, classes, m.batch, seed)
                        .with_noise(1.5),
                )
            }
            "lm" => {
                let vocab = m
                    .meta
                    .get("vocab")
                    .and_then(|v| v.as_u64())
                    .unwrap_or(512) as usize;
                let seq = m.x_shape[1];
                DataSource::Corpus(SyntheticCorpus::new(
                    vocab, seq, m.batch, seed,
                ))
            }
            other => panic!("unknown task kind {other}"),
        }
    }
}

pub struct PjrtOracle {
    exec: GradExec,
    data: DataSource,
    workers: usize,
    /// distinct eval batches averaged by `loss()` (drawn from a shard id
    /// past the training workers so they never overlap training data)
    eval_batches: usize,
}

impl PjrtOracle {
    pub fn new(exec: GradExec, workers: usize, seed: u64) -> Self {
        let data = DataSource::for_model(&exec.model, seed);
        Self { exec, data, workers, eval_batches: 4 }
    }

    pub fn with_eval_batches(mut self, n: usize) -> Self {
        self.eval_batches = n.max(1);
        self
    }

    pub fn model(&self) -> &ModelEntry {
        &self.exec.model
    }

    fn run_batch(
        &self,
        worker: usize,
        iter: usize,
        x: &[f32],
        out: &mut [f32],
    ) -> f64 {
        match &self.data {
            DataSource::Images(ds) => {
                let b = ds.batch(worker, iter);
                self.exec
                    .run(x, BatchInput::F32(&b.x), &b.y, out)
                    .expect("grad exec") as f64
            }
            DataSource::Corpus(ds) => {
                let b = ds.batch(worker, iter);
                self.exec
                    .run(x, BatchInput::I32(&b.x), &b.y, out)
                    .expect("grad exec") as f64
            }
        }
    }
}

impl GradOracle for PjrtOracle {
    fn dim(&self) -> usize {
        self.exec.model.param_count
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn grad(
        &self,
        worker: usize,
        iter: usize,
        x: &[f32],
        out: &mut [f32],
    ) -> f64 {
        self.run_batch(worker, iter, x, out)
    }

    fn loss(&self, x: &[f32]) -> f64 {
        // held-out estimate: shard id past the training workers
        let mut buf = vec![0.0f32; self.dim()];
        let mut acc = 0.0;
        for b in 0..self.eval_batches {
            acc += self.run_batch(self.workers + 1, 900_000 + b, x, &mut buf);
        }
        acc / self.eval_batches as f64
    }

    fn init(&self) -> Vec<f32> {
        self.exec.model.init_flat(0xD0C0)
    }
}
