//! PJRT client wrapper: compile-once executables for the grad / compress /
//! apply modules with typed, flat-buffer call interfaces.

use super::manifest::{Manifest, ModelEntry, ModuleEntry};
use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

// Default (offline) builds bind `xla::` to the in-tree stub, which fails
// cleanly at `PjRtClient::cpu()`; the `pjrt` feature rebinds it to the real
// vendored bindings with the identical surface.
#[cfg(not(feature = "pjrt"))]
use super::xla_stub as xla;

/// The process-wide PJRT runtime: CPU client + artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
}

impl Runtime {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, dir, manifest })
    }

    fn compile(&self, entry: &ModuleEntry) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", entry.file))
    }

    /// Compile the gradient module of `model`.
    pub fn grad_exec(&self, model: &str) -> Result<GradExec> {
        let entry = self.manifest.module(&format!("grad_{model}"))?;
        let minfo = self.manifest.model(model)?.clone();
        let exe = self.compile(entry)?;
        Ok(GradExec { exe: Mutex::new(exe), model: minfo })
    }

    /// Compile a palette compress module by manifest name
    /// (e.g. "compress_0p05").
    pub fn compress_exec(&self, name: &str) -> Result<CompressExec> {
        let entry = self.manifest.module(name)?;
        if entry.kind != "compress" {
            return Err(anyhow!("{name} is not a compress module"));
        }
        let exe = self.compile(entry)?;
        Ok(CompressExec {
            exe,
            dim: entry.dim.unwrap(),
            delta: entry.delta.unwrap(),
            k_per_block: entry.k_per_block.unwrap(),
        })
    }

    pub fn apply_exec(&self) -> Result<ApplyExec> {
        let entry = self.manifest.module("sgd_apply")?;
        let exe = self.compile(entry)?;
        Ok(ApplyExec { exe, dim: entry.dim.unwrap() })
    }
}

/// `(params f32[P], x, y) -> (loss f32[], grad f32[P])`.
pub struct GradExec {
    // PJRT executables are single-threaded-owned; the mutex makes GradExec
    // `Sync` for the `GradOracle: Send + Sync` bound. The coordinator pins
    // PJRT-backed runs to a serial pool, so the lock is uncontended.
    exe: Mutex<xla::PjRtLoadedExecutable>,
    pub model: ModelEntry,
}

/// Model input batch, matching the model's `x_dtype`.
pub enum BatchInput<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl GradExec {
    /// Execute one gradient step; writes the flat gradient into `grad_out`
    /// and returns the scalar loss.
    pub fn run(
        &self,
        params: &[f32],
        x: BatchInput,
        y: &[i32],
        grad_out: &mut [f32],
    ) -> Result<f32> {
        let p = self.model.param_count;
        assert_eq!(params.len(), p);
        assert_eq!(grad_out.len(), p);
        let dims_x: Vec<i64> =
            self.model.x_shape.iter().map(|&d| d as i64).collect();
        let dims_y: Vec<i64> =
            self.model.y_shape.iter().map(|&d| d as i64).collect();
        let lit_p = xla::Literal::vec1(params);
        let lit_x = match x {
            BatchInput::F32(v) => xla::Literal::vec1(v)
                .reshape(&dims_x)
                .map_err(|e| anyhow!("x reshape: {e:?}"))?,
            BatchInput::I32(v) => xla::Literal::vec1(v)
                .reshape(&dims_x)
                .map_err(|e| anyhow!("x reshape: {e:?}"))?,
        };
        let lit_y = xla::Literal::vec1(y)
            .reshape(&dims_y)
            .map_err(|e| anyhow!("y reshape: {e:?}"))?;
        let result = self
            .exe
            .lock()
            .expect("pjrt exec lock")
            .execute::<xla::Literal>(&[lit_p, lit_x, lit_y])
            .map_err(|e| anyhow!("grad execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let (loss, grad) = result
            .to_tuple2()
            .map_err(|e| anyhow!("grad output tuple: {e:?}"))?;
        grad.copy_raw_to(grad_out)
            .map_err(|e| anyhow!("grad copy: {e:?}"))?;
        let loss: f32 = loss
            .get_first_element()
            .map_err(|e| anyhow!("loss scalar: {e:?}"))?;
        Ok(loss)
    }
}

/// `(g f32[d], e f32[d]) -> (delta f32[d], e_new f32[d])` — the L1 Pallas
/// blockwise Top-k EF kernel, AOT-lowered. One executable per palette δ.
pub struct CompressExec {
    exe: xla::PjRtLoadedExecutable,
    pub dim: usize,
    pub delta: f64,
    pub k_per_block: usize,
}

impl CompressExec {
    pub fn run(&self, g: &[f32], e: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        assert_eq!(g.len(), self.dim);
        assert_eq!(e.len(), self.dim);
        let result = self
            .exe
            .execute::<xla::Literal>(&[
                xla::Literal::vec1(g),
                xla::Literal::vec1(e),
            ])
            .map_err(|e| anyhow!("compress execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let (delta, e_new) = result
            .to_tuple2()
            .map_err(|e| anyhow!("compress output tuple: {e:?}"))?;
        Ok((
            delta.to_vec().map_err(|e| anyhow!("delta vec: {e:?}"))?,
            e_new.to_vec().map_err(|e| anyhow!("e_new vec: {e:?}"))?,
        ))
    }
}

/// `(x f32[d], upd f32[d], lr f32[1]) -> x_new f32[d]` — fused SGD apply.
pub struct ApplyExec {
    exe: xla::PjRtLoadedExecutable,
    pub dim: usize,
}

impl ApplyExec {
    pub fn run(&self, x: &[f32], upd: &[f32], lr: f32) -> Result<Vec<f32>> {
        assert_eq!(x.len(), self.dim);
        let result = self
            .exe
            .execute::<xla::Literal>(&[
                xla::Literal::vec1(x),
                xla::Literal::vec1(upd),
                xla::Literal::vec1(&[lr]),
            ])
            .map_err(|e| anyhow!("apply execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let x_new = result
            .to_tuple1()
            .map_err(|e| anyhow!("apply output tuple: {e:?}"))?;
        x_new.to_vec().map_err(|e| anyhow!("x_new vec: {e:?}"))
    }
}
