//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) produced by `python/compile/aot.py` and executes them on
//! the CPU PJRT client via the `xla` crate. This is the only place the L3
//! coordinator touches compiled L2/L1 code; python never runs here.
//!
//! Interchange is HLO *text* (see aot.py / /opt/xla-example/README.md): the
//! text parser reassigns instruction ids, dodging the 64-bit-id protos that
//! xla_extension 0.5.1 rejects.

pub mod client;
pub mod manifest;
pub mod oracle;
/// Offline stand-in for the external `xla` crate; swapped out by the
/// `pjrt` feature (see client.rs).
#[cfg(not(feature = "pjrt"))]
pub(crate) mod xla_stub;

pub use client::{ApplyExec, CompressExec, GradExec, Runtime};
pub use manifest::{Manifest, ModelEntry, ModuleEntry, TensorEntry};
pub use oracle::{DataSource, PjrtOracle};

use std::path::PathBuf;

/// Default artifacts directory: `$REPO/artifacts` next to the binary's CWD,
/// overridable with `DECO_ARTIFACTS`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("DECO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
