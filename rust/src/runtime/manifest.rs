//! `artifacts/manifest.json` schema — the contract between aot.py and the
//! rust runtime (module table, tensor layout, init spec). Parsed with the
//! in-tree JSON codec (`util::json`).

use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct Manifest {
    /// compressor block size (must equal crate::BLOCK)
    pub block: usize,
    pub modules: HashMap<String, ModuleEntry>,
    pub models: HashMap<String, ModelEntry>,
}

#[derive(Clone, Debug)]
pub struct ModuleEntry {
    pub file: String,
    pub kind: String,
    pub model: Option<String>,
    pub dim: Option<usize>,
    pub delta: Option<f64>,
    pub k_per_block: Option<usize>,
    pub inputs: Vec<IoEntry>,
    pub outputs: Vec<IoEntry>,
}

#[derive(Clone, Debug)]
pub struct IoEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub task: String,
    pub param_count: usize,
    pub batch: usize,
    pub x_shape: Vec<usize>,
    pub x_dtype: String,
    pub y_shape: Vec<usize>,
    pub grad_bits: u64,
    pub meta: Json,
    pub tensors: Vec<TensorEntry>,
}

#[derive(Clone, Debug)]
pub struct TensorEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub init: String,
    pub std: f64,
}

fn err(msg: String) -> anyhow::Error {
    anyhow!(msg)
}

fn usizes(j: &Json, key: &str) -> Result<Vec<usize>> {
    Ok(j.req(key)
        .map_err(err)?
        .as_arr()
        .ok_or_else(|| anyhow!("'{key}' not an array"))?
        .iter()
        .filter_map(|v| v.as_usize())
        .collect())
}

fn parse_io(j: &Json) -> Result<IoEntry> {
    Ok(IoEntry {
        name: j.req_str("name").map_err(err)?.to_string(),
        shape: usizes(j, "shape")?,
        dtype: j.req_str("dtype").map_err(err)?.to_string(),
    })
}

fn parse_module(j: &Json) -> Result<ModuleEntry> {
    let ios = |key: &str| -> Result<Vec<IoEntry>> {
        j.req(key)
            .map_err(err)?
            .as_arr()
            .ok_or_else(|| anyhow!("'{key}' not an array"))?
            .iter()
            .map(parse_io)
            .collect()
    };
    Ok(ModuleEntry {
        file: j.req_str("file").map_err(err)?.to_string(),
        kind: j.req_str("kind").map_err(err)?.to_string(),
        model: j.get("model").and_then(|v| v.as_str()).map(String::from),
        dim: j.get("dim").and_then(|v| v.as_usize()),
        delta: j.get("delta").and_then(|v| v.as_f64()),
        k_per_block: j.get("k_per_block").and_then(|v| v.as_usize()),
        inputs: ios("inputs")?,
        outputs: ios("outputs")?,
    })
}

fn parse_model(j: &Json) -> Result<ModelEntry> {
    let tensors = j
        .req("tensors")
        .map_err(err)?
        .as_arr()
        .ok_or_else(|| anyhow!("'tensors' not an array"))?
        .iter()
        .map(|t| {
            Ok(TensorEntry {
                name: t.req_str("name").map_err(err)?.to_string(),
                shape: usizes(t, "shape")?,
                offset: t.req_usize("offset").map_err(err)?,
                size: t.req_usize("size").map_err(err)?,
                init: t.req_str("init").map_err(err)?.to_string(),
                std: t.req_f64("std").map_err(err)?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ModelEntry {
        task: j.req_str("task").map_err(err)?.to_string(),
        param_count: j.req_usize("param_count").map_err(err)?,
        batch: j.req_usize("batch").map_err(err)?,
        x_shape: usizes(j, "x_shape")?,
        x_dtype: j.req_str("x_dtype").map_err(err)?.to_string(),
        y_shape: usizes(j, "y_shape")?,
        grad_bits: j.req_f64("grad_bits").map_err(err)? as u64,
        meta: j.get("meta").cloned().unwrap_or(Json::Null),
        tensors,
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("parsing manifest.json: {e}"))?;
        let block = j.req_usize("block").map_err(err)?;
        if block != crate::BLOCK {
            return Err(anyhow!(
                "manifest block {block} != crate BLOCK {}",
                crate::BLOCK
            ));
        }
        let mut modules = HashMap::new();
        for (name, m) in j
            .req("modules")
            .map_err(err)?
            .as_obj()
            .ok_or_else(|| anyhow!("'modules' not an object"))?
        {
            modules.insert(
                name.clone(),
                parse_module(m).with_context(|| format!("module {name}"))?,
            );
        }
        let mut models = HashMap::new();
        for (name, m) in j
            .req("models")
            .map_err(err)?
            .as_obj()
            .ok_or_else(|| anyhow!("'models' not an object"))?
        {
            models.insert(
                name.clone(),
                parse_model(m).with_context(|| format!("model {name}"))?,
            );
        }
        Ok(Manifest { block, modules, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))
    }

    pub fn module(&self, name: &str) -> Result<&ModuleEntry> {
        self.modules
            .get(name)
            .ok_or_else(|| anyhow!("module '{name}' not in manifest"))
    }

    /// The compress-module palette: (delta, module name), ascending delta.
    pub fn compress_palette(&self) -> Vec<(f64, String)> {
        let mut out: Vec<(f64, String)> = self
            .modules
            .iter()
            .filter(|(_, m)| m.kind == "compress")
            .map(|(n, m)| (m.delta.unwrap_or(1.0), n.clone()))
            .collect();
        out.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        out
    }
}

impl ModelEntry {
    /// Initialize a flat parameter vector per the manifest tensor specs —
    /// the rust mirror of `python/compile/params.py::init_flat` (same
    /// *distributions*, independent stream; training starts from scratch so
    /// bit equality with python is not required).
    pub fn init_flat(&self, seed: u64) -> Vec<f32> {
        let mut out = vec![0.0f32; self.param_count];
        let mut rng = crate::util::Rng::new(seed ^ 0x1217);
        for t in &self.tensors {
            let dst = &mut out[t.offset..t.offset + t.size];
            match t.init.as_str() {
                "normal" => rng.fill_normal_f32(dst, t.std as f32),
                "ones" => dst.iter_mut().for_each(|v| *v = 1.0),
                _ => {} // zeros
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<std::path::PathBuf> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn parses_real_manifest() {
        let Some(dir) = artifacts() else {
            eprintln!("skipped: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.contains_key("gpt_mini"));
        assert!(m.modules.contains_key("grad_gpt_mini"));
        let gm = m.model("gpt_mini").unwrap();
        assert_eq!(gm.param_count % crate::BLOCK, 0);
        assert_eq!(gm.grad_bits, gm.param_count as u64 * 32);
        // tensor table covers the vector contiguously
        let mut off = 0;
        for t in &gm.tensors {
            assert_eq!(t.offset, off);
            off += t.size;
        }
        assert_eq!(off, gm.param_count);
        assert!(!m.compress_palette().is_empty());
        // compress entries carry their k
        for (delta, name) in m.compress_palette() {
            let e = m.module(&name).unwrap();
            assert_eq!(e.kind, "compress");
            assert!(delta > 0.0 && delta <= 1.0);
            assert!(e.k_per_block.unwrap() >= 1);
        }
    }

    #[test]
    fn init_flat_respects_spec() {
        let Some(dir) = artifacts() else {
            eprintln!("skipped: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let gm = m.model("gpt_mini").unwrap();
        let flat = gm.init_flat(3);
        assert_eq!(flat.len(), gm.param_count);
        for t in &gm.tensors {
            let sl = &flat[t.offset..t.offset + t.size];
            match t.init.as_str() {
                "zeros" => assert!(sl.iter().all(|&v| v == 0.0), "{}", t.name),
                "ones" => assert!(sl.iter().all(|&v| v == 1.0), "{}", t.name),
                "normal" => {
                    let std = crate::util::stats::l2_norm(sl)
                        / (sl.len() as f64).sqrt();
                    assert!(
                        std > 0.2 * t.std && std < 5.0 * t.std,
                        "{}: std {std} vs spec {}",
                        t.name,
                        t.std
                    );
                }
                other => panic!("unknown init {other}"),
            }
        }
    }
}
