//! `repro` — the DeCo-SGD experiment launcher (hand-rolled CLI; the offline
//! vendored crate set has no clap).
//!
//! ```text
//! repro exp <fig1|fig2|fig4|fig5|fig6|table1|thm3|phi|hetero|churn|topo|
//!            bonded|scale|lossy|all>
//!           [--scale F] [--tasks t1 t2] [--nodes 4 8] [--workers N]
//!           [--task NAME] [--t-comp F] [--mult F] [--seed N]
//!           [--fast] [--dir PATH] [--max-cells N]
//! repro train --config cfg.json [--out run.csv]
//! repro trace cfg.json [--out trace.json]
//! repro audit cfg.json [--out audit.csv] [--json audit.json]
//!             [--trace trace.json]
//! repro deco --a BPS --b S --t-comp S --s-g BITS
//! repro artifacts
//! ```

use anyhow::{anyhow, bail, ensure, Result};
use deco::config::ExperimentConfig;
use deco::deco::{solve, DecoInput};
use deco::exp;
use deco::obs::{
    audit_events, perfetto_audit_string, perfetto_string, Attribution,
    PlanAudit, TraceEvent, TraceSink,
};
use deco::util::Json;

/// Minimal flag parser: `--key value...` plus positional args.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, Vec<String>>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags: std::collections::HashMap<String, Vec<String>> =
            std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let mut vals = Vec::new();
                while i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    vals.push(argv[i + 1].clone());
                    i += 1;
                }
                flags.entry(key.replace('-', "_")).or_default().extend(vals);
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Self { positional, flags }
    }

    fn flag_f64(&self, key: &str) -> Option<f64> {
        self.flags.get(key)?.first()?.parse().ok()
    }

    fn flag_usize(&self, key: &str) -> Option<usize> {
        self.flags.get(key)?.first()?.parse().ok()
    }

    fn flag_str(&self, key: &str) -> Option<&str> {
        self.flags.get(key)?.first().map(|s| s.as_str())
    }

    /// Bare switches like `--fast` (present with no values).
    fn flag_present(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    fn flag_vec(&self, key: &str) -> Vec<String> {
        self.flags.get(key).cloned().unwrap_or_default()
    }

    fn req_f64(&self, key: &str) -> Result<f64> {
        self.flag_f64(key)
            .ok_or_else(|| anyhow!("missing required flag --{key}"))
    }
}

const USAGE: &str = "\
repro — DeCo-SGD paper reproduction CLI

USAGE:
  repro exp <id> [--scale F] [--tasks T..] [--nodes N..] [--workers N]
                 [--task NAME] [--t-comp F] [--mult F] [--seed N]
      ids: fig1 fig2 fig4 fig5 fig6 table1 thm3 phi ablation hetero churn
           topo bonded scale lossy all
      hetero: straggler severity x strategy sweep on a per-worker fabric
              (--workers N, --mult F = straggler latency multiplier)
      churn:  worker churn x link outages x strategy on the elastic fabric —
              event-triggered vs boundary-only DeCo re-planning
              (--workers N, --seed N drives the random-churn row)
      topo:   region count x WAN:LAN bandwidth ratio on the hierarchical
              multi-datacenter topology — two-tier DeCo vs the flat
              shared-egress star (--workers N, default 8)
      bonded: multi-path bonding vs single-homing under fast-path outages —
              water-filling failover degrades where a single path stalls
              (--workers N, --seed N)
      scale:  100k-worker clock-engine campaign, resumable via a manifest
              (--fast shrinks n for CI, --dir PATH overrides results/,
              --max-cells N pauses after N cells to demonstrate resume)
      lossy:  message loss x retransmission — deadline-bounded partial
              aggregation vs wait-for-all under i.i.d. and bursty
              Gilbert-Elliott drops (--workers N, --seed N, --fast
              shrinks the sweep for CI)
  repro --help | repro <cmd> --help
      print this usage and exit 0
  repro train --config cfg.json [--out run.csv]
  repro trace cfg.json [--out trace.json]
      run an analytic config with virtual-time tracing: writes a
      Chrome/Perfetto trace-event JSON (load in ui.perfetto.dev) and
      prints the stall-attribution report — per-phase totals summing to
      the run's makespan. Deterministic: byte-identical across reruns
      and pool sizes.
  repro audit cfg.json [--out audit.csv] [--json audit.json]
                       [--trace trace.json]
      run an analytic config traced, then audit the plans: per-window
      predicted-vs-realized round times, hindsight-oracle regret
      (re-solved on the realized bandwidth), and FabricMonitor
      calibration against the ground-truth traces. Prints aligned
      tables, writes a per-window CSV (and optionally canonical JSON /
      a Perfetto trace with predicted-vs-realized counter tracks).
      Deterministic: byte-identical across reruns and pool sizes.
  repro deco --a BPS --b SECONDS --t-comp SECONDS --s-g BITS
  repro artifacts
";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].as_str();
    let args = Args::parse(&argv[1..]);
    // `repro <cmd> --help` anywhere prints usage and exits 0 (the
    // top-level `repro --help` hits the match arm below)
    if args.flag_present("help") {
        print!("{USAGE}");
        return Ok(());
    }
    match cmd {
        "exp" => {
            let id = args
                .positional
                .first()
                .ok_or_else(|| anyhow!("exp needs an id\n{USAGE}"))?
                .clone();
            let scale = args.flag_f64("scale").unwrap_or(1.0);
            let tasks = args.flag_vec("tasks");
            let nodes: Vec<usize> = args
                .flag_vec("nodes")
                .iter()
                .filter_map(|s| s.parse().ok())
                .collect();
            let workers = args.flag_usize("workers").unwrap_or(4);
            let task =
                args.flag_str("task").unwrap_or("gpt_wikitext").to_string();
            let t_comp = args.flag_f64("t_comp").unwrap_or(2.0);
            match id.as_str() {
                "fig1" => exp::fig1::main(t_comp)?,
                "fig2" => exp::fig2::main()?,
                "fig4" => exp::fig4::main(&tasks, scale, workers)?,
                "fig5" => exp::fig5::main(scale, &nodes)?,
                "fig6" => exp::fig6::main(&task, scale)?,
                "table1" => exp::table1::main(scale, &tasks)?,
                "thm3" => exp::thm3::main()?,
                "phi" => exp::phi::main()?,
                "ablation" => {
                    let which =
                        args.flag_str("which").unwrap_or("all").to_string();
                    exp::ablation::main(&which)?;
                }
                "hetero" => {
                    let mult = args.flag_f64("mult").unwrap_or(6.0);
                    exp::hetero::main(scale, workers, mult)?;
                }
                "churn" => {
                    let seed = args.flag_usize("seed").unwrap_or(7) as u64;
                    exp::churn::main(scale, workers, seed)?;
                }
                "topo" => {
                    // the multi-datacenter sweep defaults to 8 workers so
                    // the 4-region rows keep 2 members per region
                    let workers = args.flag_usize("workers").unwrap_or(8);
                    exp::topo::main(scale, workers)?;
                }
                "bonded" => {
                    let seed = args.flag_usize("seed").unwrap_or(7) as u64;
                    exp::bonded::main(scale, workers, seed)?;
                }
                "lossy" => {
                    let seed = args.flag_usize("seed").unwrap_or(7) as u64;
                    exp::lossy::main(
                        scale,
                        workers,
                        seed,
                        args.flag_present("fast"),
                    )?;
                }
                "scale" => {
                    exp::scale::main(
                        args.flag_present("fast"),
                        args.flag_str("dir"),
                        args.flag_usize("max_cells"),
                    )?;
                }
                "all" => {
                    exp::fig1::main(t_comp)?;
                    exp::fig2::main()?;
                    exp::thm3::main()?;
                    exp::phi::main()?;
                    exp::fig4::main(&tasks, scale, workers)?;
                    exp::fig5::main(scale, &nodes)?;
                    exp::fig6::main(&task, scale)?;
                    exp::table1::main(scale, &tasks)?;
                }
                other => bail!("unknown experiment id '{other}'\n{USAGE}"),
            }
        }
        "train" => {
            let config = args
                .flag_str("config")
                .ok_or_else(|| anyhow!("train needs --config\n{USAGE}"))?;
            let cfg = ExperimentConfig::from_json_file(config)?;
            let mut env = exp::ExpEnv::new();
            let res = env.run(&cfg)?;
            println!(
                "{}: {} iters, {:.1}s virtual, final loss {:.5}",
                res.method,
                res.total_iters,
                res.total_time,
                res.final_loss()
            );
            if let Some(target) = cfg.stop.loss_target {
                match res.time_to_loss(target) {
                    Some(t) => println!("time-to-target({target}) = {t:.2}s"),
                    None => println!("target {target} not reached"),
                }
            }
            if let Some(path) = args.flag_str("out") {
                res.write_csv(path)?;
                println!("wrote {path}");
            }
        }
        "trace" => {
            let config = args
                .positional
                .first()
                .map(String::as_str)
                .or_else(|| args.flag_str("config"))
                .ok_or_else(|| anyhow!("trace needs a config path\n{USAGE}"))?;
            let cfg = ExperimentConfig::from_json_file(config)?;
            let (res, events) = exp::ExpEnv::run_traced(&cfg)?;
            let mut attr = Attribution::new();
            for ev in &events {
                if let TraceEvent::Tick(tt) = ev {
                    attr.record_tick(tt);
                }
            }
            // the report must account for the whole run: per-phase
            // totals sum to the makespan within 1e-6 relative
            let gap = (attr.attributed() - attr.makespan()).abs();
            ensure!(
                gap <= 1e-6 * attr.makespan().max(1e-12),
                "attribution lost {gap}s of the {}s makespan",
                attr.makespan()
            );
            let text = perfetto_string(&events);
            let parsed = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
            ensure!(
                parsed.to_string() == text,
                "perfetto JSON did not round-trip through util::Json"
            );
            let out = args.flag_str("out").unwrap_or("trace.json");
            std::fs::write(out, &text)?;
            println!(
                "{}: {} iters, {:.1}s virtual, final loss {:.5}",
                res.method,
                res.total_iters,
                res.total_time,
                res.final_loss()
            );
            println!("{}", attr.table());
            println!(
                "trace: {} events over {} ticks -> {out} ({} bytes)",
                events.len(),
                attr.ticks(),
                text.len()
            );
        }
        "audit" => {
            let config = args
                .positional
                .first()
                .map(String::as_str)
                .or_else(|| args.flag_str("config"))
                .ok_or_else(|| anyhow!("audit needs a config path\n{USAGE}"))?;
            let cfg = ExperimentConfig::from_json_file(config)?;
            let (res, events) = exp::ExpEnv::run_traced(&cfg)?;
            // ground truth: the same seeded fabric the run was priced on
            let fabric = cfg.network.build_fabric(cfg.workers)?;
            let report = audit_events(&events, &fabric);
            // contract check: the O(1) streaming fold must agree with the
            // buffered audit bit-for-bit
            let mut streaming = PlanAudit::streaming();
            for ev in &events {
                streaming.record(ev);
            }
            streaming.finish();
            ensure!(
                *streaming.summary() == report.summary,
                "streaming audit fold diverged from the buffered audit"
            );
            println!(
                "{}: {} iters, {:.1}s virtual, final loss {:.5}",
                res.method,
                res.total_iters,
                res.total_time,
                res.final_loss()
            );
            println!("{}", report.table());
            let out = args.flag_str("out").unwrap_or("audit.csv");
            std::fs::write(out, report.csv())?;
            println!(
                "audit: {} windows over {} iters -> {out}",
                report.summary.windows, report.summary.iters
            );
            if let Some(path) = args.flag_str("json") {
                let text = report.json().to_string();
                let parsed = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
                ensure!(
                    parsed.to_string() == text,
                    "audit JSON did not round-trip through util::Json"
                );
                std::fs::write(path, &text)?;
                println!("wrote {path}");
            }
            if let Some(path) = args.flag_str("trace") {
                let text = perfetto_audit_string(&events, &fabric);
                std::fs::write(path, &text)?;
                println!("wrote {path} ({} bytes)", text.len());
            }
        }
        "deco" => {
            let a = args.req_f64("a")?;
            let b = args.req_f64("b")?;
            let t_comp = args.req_f64("t_comp")?;
            let s_g = args.req_f64("s_g")?;
            let out = solve(&DecoInput { s_g, a, b, t_comp });
            println!(
                "tau* = {}, delta* = {:.4}  (ln phi = {:.3})",
                out.tau, out.delta, out.log_phi
            );
            println!(
                "T_avg at the optimum = T_comp = {t_comp}s  (bubble-free); \
                 transmission per iter = {:.3}s",
                out.delta * s_g / a
            );
        }
        "artifacts" => {
            let dir = deco::runtime::default_artifacts_dir();
            let m = deco::runtime::Manifest::load(&dir)?;
            println!("artifacts at {dir:?}: block={}", m.block);
            let mut names: Vec<_> = m.modules.keys().collect();
            names.sort();
            for name in names {
                let e = &m.modules[name];
                println!("  {name:<24} {} ({})", e.file, e.kind);
            }
            let mut mnames: Vec<_> = m.models.keys().collect();
            mnames.sort();
            for name in mnames {
                let e = &m.models[name];
                println!(
                    "  model {name:<18} P={} batch={} task={}",
                    e.param_count, e.batch, e.task
                );
            }
        }
        "--help" | "-h" | "help" => print!("{USAGE}"),
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::Args;

    fn parse(s: &str) -> Args {
        let v: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&v)
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("fig4 --scale 0.5 --tasks gpt_wikitext vit_imagenet");
        assert_eq!(a.positional, vec!["fig4"]);
        assert_eq!(a.flag_f64("scale"), Some(0.5));
        assert_eq!(
            a.flag_vec("tasks"),
            vec!["gpt_wikitext".to_string(), "vit_imagenet".to_string()]
        );
    }

    #[test]
    fn dashes_normalize_to_underscores() {
        let a = parse("deco --t-comp 0.35 --s-g 3.9e9");
        assert_eq!(a.flag_f64("t_comp"), Some(0.35));
        assert_eq!(a.flag_f64("s_g"), Some(3.9e9));
        assert!(a.req_f64("t_comp").is_ok());
        assert!(a.req_f64("missing").is_err());
    }

    #[test]
    fn help_is_a_bare_switch_on_any_command() {
        // `repro exp lossy --help` must short-circuit to USAGE: the
        // parser surfaces it as a present (valueless) flag
        let a = parse("exp lossy --help");
        assert!(a.flag_present("help"));
        assert_eq!(a.positional, vec!["exp", "lossy"]);
        assert!(!parse("exp lossy --fast").flag_present("help"));
    }

    #[test]
    fn empty_flag_and_numbers() {
        let a = parse("exp fig5 --nodes 4 8 16 --workers 2");
        assert_eq!(a.flag_usize("workers"), Some(2));
        assert_eq!(a.flag_vec("nodes").len(), 3);
        assert_eq!(a.flag_str("absent"), None);
    }
}
