//! Gradient compression substrate — the paper's `C_delta(·)` operators plus
//! the error-feedback bookkeeping (Sec. 2.2.2) and a sparse wire codec.
//!
//! The selection rule is a single *specification* shared by three
//! implementations that are cross-checked in tests:
//! 1. the pure-jnp oracle (`python/compile/kernels/ref.py`),
//! 2. the L1 Pallas kernel (`python/compile/kernels/topk_ef.py`), and
//! 3. the rust hot path here.
//!
//! Spec (deterministic, lower index wins ties): given magnitudes `|a|` and a
//! budget `k`, select every entry with `|a| > thr` (thr = k-th largest), then
//! the first `k − #gt` entries with `|a| == thr` in index order.

pub mod blockwise;
pub mod ef;
pub mod hybrid;
pub mod quantize;
pub mod randk;
pub mod sparse;
pub mod topk;

pub use blockwise::BlockTopK;
pub use ef::ErrorFeedback;
pub use hybrid::HybridRandKQ8;
pub use quantize::QuantizeQ8;
pub use randk::RandK;
pub use sparse::{SparseVec, COO_BITS_PER_ENTRY};
pub use topk::TopK;

use crate::util::Rng;

/// A gradient compressor with ratio `delta = (transmitted elements) / d`.
///
/// `compress` zeroes the dropped coordinates **in place** and returns the
/// number of elements kept (so the caller can account transmitted bits).
/// Implementations must be deterministic given `rng` state, and `Sync`: the
/// parallel worker phase shares one instance per worker across pool threads
/// (selection scratch, where present, hides behind an uncontended mutex).
pub trait Compressor: Send + Sync + std::fmt::Debug {
    /// Human-readable name for metrics/CSV.
    fn name(&self) -> &'static str;

    /// Nominal compression ratio in (0, 1].
    fn delta(&self) -> f64;

    /// Keep approximately `delta * a.len()` entries of `a`, zeroing the
    /// rest in place. Returns the exact number kept.
    fn compress(&self, a: &mut [f32], rng: &mut Rng) -> usize;

    /// Bits on the wire for `kept` entries of a length-`d` vector.
    /// Sparse methods pay index+value per entry; dense methods override.
    fn wire_bits(&self, kept: usize, _d: usize) -> u64 {
        (kept as u64) * COO_BITS_PER_ENTRY
    }
}

/// Identity compressor (`delta = 1`): D-SGD / DGA path. Wire format is the
/// dense vector — 32 bits per element, no index overhead.
#[derive(Clone, Debug, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> &'static str {
        "identity"
    }

    fn delta(&self) -> f64 {
        1.0
    }

    fn compress(&self, a: &mut [f32], _rng: &mut Rng) -> usize {
        a.len()
    }

    fn wire_bits(&self, _kept: usize, d: usize) -> u64 {
        (d as u64) * 32
    }
}

/// Budget for a ratio over a length: `ceil(delta * n)`, clamped to [1, n].
/// Matches `python/compile/kernels/topk_ef.py::k_for_delta`.
pub fn k_for_delta(delta: f64, n: usize) -> usize {
    ((delta * n as f64).ceil() as usize).clamp(1, n.max(1))
}

/// The training pipeline's compressor for a `(δ, blockwise)` choice:
/// `Identity` at δ ≥ 1 (D-SGD / DGA), otherwise Top-k (paper default) or
/// its blockwise Pallas-identical twin.
pub fn make_compressor(delta: f64, block_topk: bool) -> Box<dyn Compressor> {
    if delta >= 1.0 {
        Box::new(Identity)
    } else if block_topk {
        Box::new(BlockTopK::new(delta))
    } else {
        Box::new(TopK::new(delta))
    }
}

/// Per-(δ, blockwise) compressor cache. The training loop used to re-box a
/// fresh compressor every iteration, so Top-k's "warm scratch" never
/// actually warmed and the steady state allocated every step. Fixed-δ
/// strategies hit one entry forever (zero alloc); adaptive strategies
/// (DeCo re-solves against drifting monitor estimates, so δ is effectively
/// continuous) evict FIFO at [`CompressorCache::CAPACITY`], paying one
/// small allocation per re-solve instead of per iteration — and bounding
/// memory, since each Top-k instance lazily warms a dim-sized scratch
/// (§Perf in DESIGN.md). One cache lives in each
/// [`crate::coordinator::WorkerState`] (keeping scratch thread-local) and
/// one on the leader for wire accounting.
#[derive(Debug, Default)]
pub struct CompressorCache {
    entries: Vec<(u64, bool, Box<dyn Compressor>)>,
}

impl CompressorCache {
    /// Max cached entries; oldest is evicted first. Small on purpose: a
    /// run only ever interleaves a few δ values at once, and an evicted
    /// compressor frees its warm scratch.
    pub const CAPACITY: usize = 8;

    pub fn new() -> Self {
        Self { entries: Vec::new() }
    }

    /// Distinct `(δ, blockwise)` pairs cached so far.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The cached compressor for `(delta, block_topk)`, built on first use.
    pub fn get(&mut self, delta: f64, block_topk: bool) -> &dyn Compressor {
        let key = delta.to_bits();
        if let Some(i) = self
            .entries
            .iter()
            .position(|(k, b, _)| *k == key && *b == block_topk)
        {
            return self.entries[i].2.as_ref();
        }
        if self.entries.len() >= Self::CAPACITY {
            self.entries.remove(0); // FIFO eviction
        }
        self.entries
            .push((key, block_topk, make_compressor(delta, block_topk)));
        self.entries.last().unwrap().2.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_for_delta_matches_python() {
        assert_eq!(k_for_delta(1.0, 1024), 1024);
        assert_eq!(k_for_delta(0.5, 1024), 512);
        assert_eq!(k_for_delta(1e-9, 1024), 1);
        assert_eq!(k_for_delta(0.05, 1024), 52);
    }

    #[test]
    fn compressor_cache_reuses_instances() {
        let mut cache = CompressorCache::new();
        assert!(cache.is_empty());
        assert_eq!(cache.get(0.05, false).name(), "topk");
        assert_eq!(cache.get(1.0, false).name(), "identity");
        assert_eq!(cache.get(0.05, true).name(), "block_topk");
        assert_eq!(cache.len(), 3);
        // revisiting the same (δ, blockwise) pairs allocates nothing new
        for _ in 0..10 {
            cache.get(0.05, false);
            cache.get(1.0, false);
            cache.get(0.05, true);
        }
        assert_eq!(cache.len(), 3);
        assert!((cache.get(0.05, false).delta() - 0.05).abs() < 1e-12);
    }

    #[test]
    fn compressor_cache_is_bounded() {
        // a drifting δ (DeCo against a live monitor) must not grow the
        // cache — and with it a warm scratch per entry — without bound
        let mut cache = CompressorCache::new();
        for i in 0..100 {
            let delta = 0.01 + i as f64 * 1e-4;
            assert_eq!(cache.get(delta, false).name(), "topk");
            assert!(cache.len() <= CompressorCache::CAPACITY);
        }
        assert_eq!(cache.len(), CompressorCache::CAPACITY);
        // the most recent entry is still cached (no eviction on hit)
        let len = cache.len();
        cache.get(0.01 + 99.0 * 1e-4, false);
        assert_eq!(cache.len(), len);
    }

    #[test]
    fn identity_keeps_everything() {
        let mut a = vec![1.0f32, -2.0, 3.0];
        let mut rng = Rng::new(0);
        let kept = Identity.compress(&mut a, &mut rng);
        assert_eq!(kept, 3);
        assert_eq!(a, vec![1.0, -2.0, 3.0]);
        assert_eq!(Identity.wire_bits(3, 3), 96);
    }
}
