//! Gradient compression substrate — the paper's `C_delta(·)` operators plus
//! the error-feedback bookkeeping (Sec. 2.2.2) and a sparse wire codec.
//!
//! The selection rule is a single *specification* shared by three
//! implementations that are cross-checked in tests:
//! 1. the pure-jnp oracle (`python/compile/kernels/ref.py`),
//! 2. the L1 Pallas kernel (`python/compile/kernels/topk_ef.py`), and
//! 3. the rust hot path here.
//!
//! Spec (deterministic, lower index wins ties): given magnitudes `|a|` and a
//! budget `k`, select every entry with `|a| > thr` (thr = k-th largest), then
//! the first `k − #gt` entries with `|a| == thr` in index order.

pub mod blockwise;
pub mod ef;
pub mod hybrid;
pub mod quantize;
pub mod randk;
pub mod sparse;
pub mod topk;

pub use blockwise::BlockTopK;
pub use ef::ErrorFeedback;
pub use hybrid::HybridRandKQ8;
pub use quantize::QuantizeQ8;
pub use randk::RandK;
pub use sparse::{SparseVec, COO_BITS_PER_ENTRY};
pub use topk::TopK;

use crate::util::Rng;

/// A gradient compressor with ratio `delta = (transmitted elements) / d`.
///
/// `compress` zeroes the dropped coordinates **in place** and returns the
/// number of elements kept (so the caller can account transmitted bits).
/// Implementations must be deterministic given `rng` state.
pub trait Compressor: Send {
    /// Human-readable name for metrics/CSV.
    fn name(&self) -> &'static str;

    /// Nominal compression ratio in (0, 1].
    fn delta(&self) -> f64;

    /// Keep approximately `delta * a.len()` entries of `a`, zeroing the
    /// rest in place. Returns the exact number kept.
    fn compress(&self, a: &mut [f32], rng: &mut Rng) -> usize;

    /// Bits on the wire for `kept` entries of a length-`d` vector.
    /// Sparse methods pay index+value per entry; dense methods override.
    fn wire_bits(&self, kept: usize, _d: usize) -> u64 {
        (kept as u64) * COO_BITS_PER_ENTRY
    }
}

/// Identity compressor (`delta = 1`): D-SGD / DGA path. Wire format is the
/// dense vector — 32 bits per element, no index overhead.
#[derive(Clone, Debug, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> &'static str {
        "identity"
    }

    fn delta(&self) -> f64 {
        1.0
    }

    fn compress(&self, a: &mut [f32], _rng: &mut Rng) -> usize {
        a.len()
    }

    fn wire_bits(&self, _kept: usize, d: usize) -> u64 {
        (d as u64) * 32
    }
}

/// Budget for a ratio over a length: `ceil(delta * n)`, clamped to [1, n].
/// Matches `python/compile/kernels/topk_ef.py::k_for_delta`.
pub fn k_for_delta(delta: f64, n: usize) -> usize {
    ((delta * n as f64).ceil() as usize).clamp(1, n.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_for_delta_matches_python() {
        assert_eq!(k_for_delta(1.0, 1024), 1024);
        assert_eq!(k_for_delta(0.5, 1024), 512);
        assert_eq!(k_for_delta(1e-9, 1024), 1);
        assert_eq!(k_for_delta(0.05, 1024), 52);
    }

    #[test]
    fn identity_keeps_everything() {
        let mut a = vec![1.0f32, -2.0, 3.0];
        let mut rng = Rng::new(0);
        let kept = Identity.compress(&mut a, &mut rng);
        assert_eq!(kept, 3);
        assert_eq!(a, vec![1.0, -2.0, 3.0]);
        assert_eq!(Identity.wire_bits(3, 3), 96);
    }
}
