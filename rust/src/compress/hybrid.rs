//! Hybrid compressor — CocktailSGD-style [21] stacking of random
//! sparsification and 8-bit quantization under one EF loop.
//!
//! The paper's CocktailSGD baseline is modeled strategically (static (τ, δ)
//! from one DeCo solve) with Top-k, matching its appendix description; this
//! module provides the *operator* CocktailSGD actually ships — random-k
//! followed by stochastic Q8 on the survivors — for the compressor ablation
//! (`exp ablation --which compressor`). Wire size: 8 bits/value + 32-bit
//! index per kept entry + one scale per chunk.

use super::{k_for_delta, Compressor};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct HybridRandKQ8 {
    /// sparsification ratio (fraction of coordinates kept)
    delta: f64,
}

impl HybridRandKQ8 {
    pub fn new(delta: f64) -> Self {
        assert!(delta > 0.0 && delta <= 1.0);
        Self { delta }
    }

    /// Effective bit-ratio vs dense f32: delta × (8 + 32)/32 (value+index).
    pub fn effective_ratio(&self) -> f64 {
        self.delta * (8.0 + 32.0) / 32.0
    }
}

impl Compressor for HybridRandKQ8 {
    fn name(&self) -> &'static str {
        "hybrid_randk_q8"
    }

    fn delta(&self) -> f64 {
        self.delta
    }

    fn compress(&self, a: &mut [f32], rng: &mut Rng) -> usize {
        let n = a.len();
        let k = k_for_delta(self.delta, n);
        // 1. random-k mask
        if k < n {
            let keep = rng.sample_indices(n, k);
            let mut mask = vec![false; n];
            for &i in &keep {
                mask[i as usize] = true;
            }
            for (x, m) in a.iter_mut().zip(&mask) {
                if !*m {
                    *x = 0.0;
                }
            }
        }
        // 2. stochastic Q8 on survivors (per-call scale over the non-zeros)
        let maxabs = a.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        if maxabs > 0.0 {
            let scale = maxabs / 127.0;
            for x in a.iter_mut() {
                if *x != 0.0 {
                    let q = *x / scale;
                    let lo = q.floor();
                    let p = q - lo;
                    let q = if rng.next_f32() < p { lo + 1.0 } else { lo };
                    *x = q.clamp(-127.0, 127.0) * scale;
                }
            }
        }
        k.min(n)
    }

    fn wire_bits(&self, kept: usize, _d: usize) -> u64 {
        kept as u64 * (8 + 32) + 32 // values + indices + scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::ErrorFeedback;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn keeps_at_most_k_nonzeros() {
        let mut rng = Rng::new(1);
        let mut a = randvec(1000, 2);
        let c = HybridRandKQ8::new(0.1);
        let kept = c.compress(&mut a, &mut rng);
        assert_eq!(kept, 100);
        // quantization can round small survivors to exactly 0
        assert!(a.iter().filter(|&&x| x != 0.0).count() <= 100);
    }

    #[test]
    fn quantization_error_bounded_on_survivors() {
        let mut rng = Rng::new(3);
        let orig = randvec(512, 4);
        let mut a = orig.clone();
        let c = HybridRandKQ8::new(1.0); // no sparsification: pure Q8
        c.compress(&mut a, &mut rng);
        let maxabs = orig.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        let step = maxabs / 127.0;
        for (o, q) in orig.iter().zip(&a) {
            assert!((o - q).abs() <= step + 1e-6);
        }
    }

    #[test]
    fn ef_keeps_hybrid_stable() {
        // error stays bounded across many rounds despite the double lossy op
        let n = 4096;
        let mut ef = ErrorFeedback::new(n);
        let c = HybridRandKQ8::new(0.05);
        let mut rng = Rng::new(5);
        let mut worst = 0.0f64;
        for t in 0..200 {
            let mut g = randvec(n, 100 + t);
            ef.step(&mut g, &c, &mut rng);
            worst = worst.max(ef.error_norm_sq());
        }
        assert!(worst < 200.0 * n as f64, "EF diverged: {worst}");
    }

    #[test]
    fn wire_accounting() {
        let c = HybridRandKQ8::new(0.1);
        assert_eq!(c.wire_bits(100, 1000), 100 * 40 + 32);
        assert!((c.effective_ratio() - 0.125).abs() < 1e-12);
    }
}
