//! Blockwise Top-k — the rust twin of the L1 Pallas kernel
//! (`python/compile/kernels/topk_ef.py`).
//!
//! Selects `k = ceil(delta * BLOCK)` entries per BLOCK-sized tile with the
//! shared tie-break spec, so the output is bit-identical to the Pallas
//! kernel and to the jnp oracle (verified against
//! `artifacts/golden_compress.json`). Blockwise selection is what a TPU can
//! do without scatters — and on CPU it is also the cache-friendly variant:
//! each 4 KiB tile is touched exactly once.

use super::{Compressor, k_for_delta};
use crate::util::Rng;
use crate::BLOCK;
use std::sync::Mutex;

#[derive(Debug)]
pub struct BlockTopK {
    delta: f64,
    block: usize,
    k: usize,
    // uncontended (one instance cached per worker); exists to be `Sync`
    scratch: Mutex<Vec<u32>>,
}

impl Clone for BlockTopK {
    fn clone(&self) -> Self {
        Self::with_block(self.delta, self.block)
    }
}

impl BlockTopK {
    pub fn new(delta: f64) -> Self {
        Self::with_block(delta, BLOCK)
    }

    pub fn with_block(delta: f64, block: usize) -> Self {
        assert!(delta > 0.0 && delta <= 1.0, "delta must be in (0,1]");
        assert!(block > 0);
        let k = k_for_delta(delta, block);
        Self { delta, block, k, scratch: Mutex::new(Vec::new()) }
    }

    pub fn k_per_block(&self) -> usize {
        self.k
    }

    pub fn block(&self) -> usize {
        self.block
    }

    /// Compress one block in place; returns kept count. Same spec as
    /// `TopK::apply` restricted to the tile (integer-key selection, see
    /// `topk::abs_key`).
    fn apply_block(&self, a: &mut [f32]) -> usize {
        #[inline]
        fn abs_key(x: f32) -> u32 {
            x.to_bits() & 0x7FFF_FFFF
        }
        let n = a.len();
        let k = self.k.min(n);
        if k >= n {
            return n;
        }
        let (thr, n_gt) = {
            let mut keys = self.scratch.lock().expect("blocktopk scratch");
            keys.clear();
            keys.extend(a.iter().map(|x| abs_key(*x)));
            // ascending order statistic at n − k == the k-th largest; see
            // topk::threshold for why the strict count scans only `right`
            let (_, thr, right) = keys.select_nth_unstable(n - k);
            let thr = *thr;
            (thr, right.iter().filter(|&&x| x > thr).count())
        };
        let mut take_eq = k - n_gt;
        let mut kept = 0usize;
        for x in a.iter_mut() {
            let m = abs_key(*x);
            if m > thr {
                kept += 1;
            } else if m == thr && take_eq > 0 {
                take_eq -= 1;
                kept += 1;
            } else {
                *x = 0.0;
            }
        }
        kept
    }
}

impl Compressor for BlockTopK {
    fn name(&self) -> &'static str {
        "block_topk"
    }

    fn delta(&self) -> f64 {
        self.delta
    }

    fn compress(&self, a: &mut [f32], _rng: &mut Rng) -> usize {
        let mut kept = 0usize;
        let mut chunks = a.chunks_exact_mut(self.block);
        for chunk in &mut chunks {
            kept += self.apply_block(chunk);
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            kept += self.apply_block(rem);
        }
        kept
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn k_per_block_everywhere() {
        let c = BlockTopK::with_block(0.05, 256); // k = 13
        let mut rng = Rng::new(1);
        let mut a: Vec<f32> = (0..1024).map(|_| rng.normal_f32()).collect();
        let kept = c.compress(&mut a, &mut rng);
        assert_eq!(kept, 4 * 13);
        for blk in a.chunks(256) {
            assert_eq!(blk.iter().filter(|&&x| x != 0.0).count(), 13);
        }
    }

    #[test]
    fn remainder_block_handled() {
        let c = BlockTopK::with_block(0.5, 100);
        let mut rng = Rng::new(2);
        let mut a: Vec<f32> = (0..250).map(|_| rng.normal_f32()).collect();
        let kept = c.compress(&mut a, &mut rng);
        // blocks: 100,100,50 -> k=50,50,min(50,50)=25? k=ceil(.5*100)=50,
        // remainder block of 50 keeps min(50, 50)=50 -> all of it
        assert_eq!(kept, 50 + 50 + 50);
    }

    #[test]
    fn matches_global_topk_when_one_block() {
        let mut rng = Rng::new(3);
        let orig: Vec<f32> = (0..512).map(|_| rng.normal_f32()).collect();
        let mut a1 = orig.clone();
        let mut a2 = orig.clone();
        BlockTopK::with_block(0.1, 512).compress(&mut a1, &mut rng);
        super::super::TopK::new(0.1).compress(&mut a2, &mut rng);
        assert_eq!(a1, a2);
    }
}
