//! Sparse wire codec — COO (index u32, value f32) encoding of compressed
//! gradients, what actually crosses the simulated WAN link.
//!
//! `wire_bits` in the `Compressor` trait uses [`COO_BITS_PER_ENTRY`] so the
//! network simulator charges the real transmitted size (the paper's
//! `delta * S_g` accounting assumes value-only transmission; we expose both
//! and the experiments use the paper's convention via `payload_bits_paper`).

/// 32-bit index + 32-bit value.
pub const COO_BITS_PER_ENTRY: u64 = 64;

/// A sparse gradient message.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseVec {
    /// dense dimension
    pub dim: u32,
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
}

impl SparseVec {
    /// Encode the non-zeros of `a`.
    pub fn encode(a: &[f32]) -> Self {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (i, &x) in a.iter().enumerate() {
            if x != 0.0 {
                idx.push(i as u32);
                val.push(x);
            }
        }
        Self { dim: a.len() as u32, idx, val }
    }

    /// Encode with a pre-sized allocation (hot-path variant).
    pub fn encode_with_capacity(a: &[f32], cap: usize) -> Self {
        let mut idx = Vec::with_capacity(cap);
        let mut val = Vec::with_capacity(cap);
        for (i, &x) in a.iter().enumerate() {
            if x != 0.0 {
                idx.push(i as u32);
                val.push(x);
            }
        }
        Self { dim: a.len() as u32, idx, val }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Scatter into a fresh dense vector.
    pub fn decode(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim as usize];
        self.add_into_scaled(&mut out, 1.0);
        out
    }

    /// `out += scale * self` — the aggregation hot call on the leader.
    pub fn add_into_scaled(&self, out: &mut [f32], scale: f32) {
        debug_assert_eq!(out.len(), self.dim as usize);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] += scale * v;
        }
    }

    /// Bits on the wire: COO entries + 64-bit header (dim + nnz).
    pub fn wire_bits(&self) -> u64 {
        self.nnz() as u64 * COO_BITS_PER_ENTRY + 64
    }

    /// The paper's accounting (`delta * S_g`): values only.
    pub fn payload_bits_paper(&self) -> u64 {
        self.nnz() as u64 * 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, TopK};
    use crate::util::Rng;

    #[test]
    fn roundtrip_exact() {
        let mut rng = Rng::new(31);
        let mut a: Vec<f32> = (0..1000).map(|_| rng.normal_f32()).collect();
        TopK::new(0.1).compress(&mut a, &mut rng);
        let sv = SparseVec::encode(&a);
        assert_eq!(sv.nnz(), 100);
        assert_eq!(sv.decode(), a);
    }

    #[test]
    fn empty_and_dense_edges() {
        let z = SparseVec::encode(&[0.0; 16]);
        assert_eq!(z.nnz(), 0);
        assert_eq!(z.decode(), vec![0.0; 16]);
        let d = SparseVec::encode(&[1.0; 4]);
        assert_eq!(d.nnz(), 4);
    }

    #[test]
    fn aggregation_scaled_add() {
        let a = SparseVec { dim: 8, idx: vec![1, 3], val: vec![2.0, -4.0] };
        let b = SparseVec { dim: 8, idx: vec![3, 7], val: vec![1.0, 1.0] };
        let mut acc = vec![0.0f32; 8];
        a.add_into_scaled(&mut acc, 0.5);
        b.add_into_scaled(&mut acc, 0.5);
        assert_eq!(acc[1], 1.0);
        assert_eq!(acc[3], -1.5);
        assert_eq!(acc[7], 0.5);
    }

    #[test]
    fn wire_accounting() {
        let sv = SparseVec { dim: 100, idx: vec![0, 1, 2], val: vec![1.0; 3] };
        assert_eq!(sv.wire_bits(), 3 * 64 + 64);
        assert_eq!(sv.payload_bits_paper(), 96);
    }
}
