//! Sparse wire codec — COO (index u32, value f32) encoding of compressed
//! gradients, what actually crosses the simulated WAN link.
//!
//! `wire_bits` in the `Compressor` trait uses [`COO_BITS_PER_ENTRY`] so the
//! network simulator charges the real transmitted size (the paper's
//! `delta * S_g` accounting assumes value-only transmission; we expose both
//! and the experiments use the paper's convention via `payload_bits_paper`).

/// 32-bit index + 32-bit value.
pub const COO_BITS_PER_ENTRY: u64 = 64;

/// A sparse gradient message.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseVec {
    /// dense dimension
    pub dim: u32,
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
}

impl SparseVec {
    /// Encode the non-zeros of `a`.
    pub fn encode(a: &[f32]) -> Self {
        Self::encode_with_capacity(a, 0)
    }

    /// Encode with a pre-sized allocation (hot-path variant).
    pub fn encode_with_capacity(a: &[f32], cap: usize) -> Self {
        let mut sv = Self {
            dim: 0,
            idx: Vec::with_capacity(cap),
            val: Vec::with_capacity(cap),
        };
        sv.encode_into(a);
        sv
    }

    /// Re-encode `a` into this message's existing buffers. The steady-state
    /// hot path: per-worker messages are recycled across iterations, so
    /// after the capacity high-water mark is reached this allocates nothing.
    pub fn encode_into(&mut self, a: &[f32]) {
        self.dim = a.len() as u32;
        self.idx.clear();
        self.val.clear();
        for (i, &x) in a.iter().enumerate() {
            if x != 0.0 {
                self.idx.push(i as u32);
                self.val.push(x);
            }
        }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Scatter into a fresh dense vector.
    pub fn decode(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim as usize];
        self.add_into_scaled(&mut out, 1.0);
        out
    }

    /// `out += scale * self` — the aggregation hot call on the leader.
    pub fn add_into_scaled(&self, out: &mut [f32], scale: f32) {
        debug_assert_eq!(out.len(), self.dim as usize);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] += scale * v;
        }
    }

    /// Entry range `[i0, i1)` holding indices in `[lo, hi)`. Encoding emits
    /// indices in ascending order, so shard boundaries are two binary
    /// searches — this is what makes the leader's sharded reduction O(log k)
    /// per (worker, shard) pair plus the actual adds.
    pub fn index_range(&self, lo: u32, hi: u32) -> (usize, usize) {
        let i0 = self.idx.partition_point(|&i| i < lo);
        let i1 = self.idx.partition_point(|&i| i < hi);
        (i0, i1)
    }

    /// Sharded `add_into_scaled`: `out` is the contiguous shard of the
    /// dense target starting at global index `lo`; only entries landing in
    /// the shard are added. Reducing every worker's message shard-by-shard
    /// in fixed worker order performs the *same additions in the same order
    /// per coordinate* as the serial path, so results are bit-identical.
    pub fn add_shard_into_scaled(&self, lo: u32, out: &mut [f32], scale: f32) {
        let hi = lo + out.len() as u32;
        let (i0, i1) = self.index_range(lo, hi);
        for e in i0..i1 {
            out[(self.idx[e] - lo) as usize] += scale * self.val[e];
        }
    }

    /// Bits on the wire: COO entries + 64-bit header (dim + nnz).
    pub fn wire_bits(&self) -> u64 {
        self.nnz() as u64 * COO_BITS_PER_ENTRY + 64
    }

    /// The paper's accounting (`delta * S_g`): values only.
    pub fn payload_bits_paper(&self) -> u64 {
        self.nnz() as u64 * 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, TopK};
    use crate::util::Rng;

    #[test]
    fn roundtrip_exact() {
        let mut rng = Rng::new(31);
        let mut a: Vec<f32> = (0..1000).map(|_| rng.normal_f32()).collect();
        TopK::new(0.1).compress(&mut a, &mut rng);
        let sv = SparseVec::encode(&a);
        assert_eq!(sv.nnz(), 100);
        assert_eq!(sv.decode(), a);
    }

    #[test]
    fn empty_and_dense_edges() {
        let z = SparseVec::encode(&[0.0; 16]);
        assert_eq!(z.nnz(), 0);
        assert_eq!(z.decode(), vec![0.0; 16]);
        let d = SparseVec::encode(&[1.0; 4]);
        assert_eq!(d.nnz(), 4);
    }

    #[test]
    fn aggregation_scaled_add() {
        let a = SparseVec { dim: 8, idx: vec![1, 3], val: vec![2.0, -4.0] };
        let b = SparseVec { dim: 8, idx: vec![3, 7], val: vec![1.0, 1.0] };
        let mut acc = vec![0.0f32; 8];
        a.add_into_scaled(&mut acc, 0.5);
        b.add_into_scaled(&mut acc, 0.5);
        assert_eq!(acc[1], 1.0);
        assert_eq!(acc[3], -1.5);
        assert_eq!(acc[7], 0.5);
    }

    #[test]
    fn encode_into_recycles_buffers() {
        let mut sv = SparseVec::default();
        sv.encode_into(&[0.0, 1.0, 0.0, -2.0]);
        assert_eq!(sv.dim, 4);
        assert_eq!(sv.idx, vec![1, 3]);
        assert_eq!(sv.val, vec![1.0, -2.0]);
        let cap = sv.idx.capacity();
        // re-encode a same-or-smaller message: no reallocation
        sv.encode_into(&[3.0, 0.0, 0.0, 0.0]);
        assert_eq!(sv.idx, vec![0]);
        assert_eq!(sv.val, vec![3.0]);
        assert_eq!(sv.idx.capacity(), cap);
        assert_eq!(sv.decode(), vec![3.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn shard_add_matches_dense_add() {
        let sv = SparseVec {
            dim: 10,
            idx: vec![0, 3, 4, 9],
            val: vec![1.0, 2.0, 3.0, 4.0],
        };
        let mut dense = vec![0.0f32; 10];
        sv.add_into_scaled(&mut dense, 0.5);
        // shard at every chunk size, including ones that don't divide dim
        for chunk in 1..=11usize {
            let mut sharded = vec![0.0f32; 10];
            for (i, out) in sharded.chunks_mut(chunk).enumerate() {
                sv.add_shard_into_scaled((i * chunk) as u32, out, 0.5);
            }
            assert_eq!(sharded, dense, "chunk={chunk}");
        }
    }

    #[test]
    fn shard_edges_empty_and_all_mass_in_one() {
        // all mass in the middle shard; flanking shards must stay untouched
        let sv = SparseVec { dim: 12, idx: vec![4, 5, 6], val: vec![1.0; 3] };
        let mut lo = vec![0.0f32; 4];
        let mut mid = vec![0.0f32; 4];
        let mut hi = vec![0.0f32; 4];
        sv.add_shard_into_scaled(0, &mut lo, 1.0);
        sv.add_shard_into_scaled(4, &mut mid, 1.0);
        sv.add_shard_into_scaled(8, &mut hi, 1.0);
        assert_eq!(lo, vec![0.0; 4]);
        assert_eq!(mid, vec![1.0, 1.0, 1.0, 0.0]);
        assert_eq!(hi, vec![0.0; 4]);
        // empty message: any shard is a no-op
        let empty = SparseVec::encode(&[0.0; 12]);
        let mut out = vec![7.0f32; 6];
        empty.add_shard_into_scaled(6, &mut out, 1.0);
        assert_eq!(out, vec![7.0; 6]);
        assert_eq!(empty.index_range(0, 12), (0, 0));
    }

    #[test]
    fn index_range_boundaries() {
        let sv = SparseVec {
            dim: 8,
            idx: vec![1, 2, 5, 7],
            val: vec![1.0; 4],
        };
        assert_eq!(sv.index_range(0, 8), (0, 4));
        assert_eq!(sv.index_range(2, 6), (1, 3));
        assert_eq!(sv.index_range(3, 5), (2, 2)); // empty shard
        assert_eq!(sv.index_range(7, 8), (3, 4));
    }

    #[test]
    fn wire_accounting() {
        let sv = SparseVec { dim: 100, idx: vec![0, 1, 2], val: vec![1.0; 3] };
        assert_eq!(sv.wire_bits(), 3 * 64 + 64);
        assert_eq!(sv.payload_bits_paper(), 96);
    }
}
