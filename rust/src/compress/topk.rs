//! Global Top-k sparsification — the paper's default compressor (Sec. 2.2.2,
//! footnote 5) on the production hot path.
//!
//! Selection is O(d): one ascending `select_nth_unstable` pass over a
//! scratch copy of the magnitudes to find the k-th largest (the order
//! statistic at index `d − k`), then one linear pass applying the shared
//! tie-break spec (see `compress::mod`). No sort of the full vector, no
//! comparator callbacks, no allocation after the scratch buffer warms up.

use super::Compressor;
use crate::util::Rng;
use std::sync::Mutex;

/// Magnitude as a totally-ordered integer key: for finite f32, the bit
/// pattern of `|x|` is monotone in `|x|` (sign bit cleared), so integer
/// `select_nth_unstable` — no comparator callbacks, branch-predictable —
/// replaces float comparisons on the hot path (§Perf: ~2.5x on selection).
#[inline]
fn abs_key(x: f32) -> u32 {
    x.to_bits() & 0x7FFF_FFFF
}

/// Global (whole-vector) top-k by magnitude.
#[derive(Debug)]
pub struct TopK {
    delta: f64,
    // scratch reused across calls behind `compress(&self)`; one TopK
    // instance is cached per worker, so the mutex is uncontended — it only
    // exists to make the instance `Sync` for the parallel worker phase.
    scratch: Mutex<Vec<u32>>,
}

impl Clone for TopK {
    fn clone(&self) -> Self {
        Self::new(self.delta)
    }
}

impl TopK {
    pub fn new(delta: f64) -> Self {
        assert!(delta > 0.0 && delta <= 1.0, "delta must be in (0,1]");
        Self { delta, scratch: Mutex::new(Vec::new()) }
    }

    /// The k-th largest magnitude of `a` (as an integer key) plus the count
    /// of entries STRICTLY greater. Ascending `select_nth_unstable` at
    /// index `n − k` — the pure integer-key selection the module docs
    /// promise — leaves every entry ≥ thr in the right partition, so the
    /// strict count is O(k) instead of O(n).
    fn threshold(&self, a: &[f32], k: usize) -> (u32, usize) {
        let mut keys = self.scratch.lock().expect("topk scratch");
        keys.clear();
        keys.extend(a.iter().map(|x| abs_key(*x)));
        let n = keys.len();
        let (_, thr, right) = keys.select_nth_unstable(n - k);
        let thr = *thr;
        let n_gt = right.iter().filter(|&&x| x > thr).count();
        (thr, n_gt)
    }

    /// Apply the shared selection spec in place; returns entries kept.
    pub fn apply(&self, a: &mut [f32], k: usize) -> usize {
        let n = a.len();
        if k >= n {
            return n;
        }
        let (thr, n_gt) = self.threshold(a, k);
        let mut take_eq = k - n_gt;
        // single pass: zero everything not selected (ties: first kept)
        let mut kept = 0usize;
        for x in a.iter_mut() {
            let m = abs_key(*x);
            if m > thr {
                kept += 1;
            } else if m == thr && take_eq > 0 {
                take_eq -= 1;
                kept += 1;
            } else {
                *x = 0.0;
            }
        }
        kept
    }
}

impl Compressor for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn delta(&self) -> f64 {
        self.delta
    }

    fn compress(&self, a: &mut [f32], _rng: &mut Rng) -> usize {
        let k = super::k_for_delta(self.delta, a.len());
        self.apply(a, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn keeps_exactly_k() {
        let c = TopK::new(0.1);
        for n in [10, 100, 1000, 4096] {
            let mut a = randvec(n, n as u64);
            let mut rng = Rng::new(0);
            let kept = c.compress(&mut a, &mut rng);
            let k = super::super::k_for_delta(0.1, n);
            assert_eq!(kept, k);
            assert_eq!(a.iter().filter(|&&x| x != 0.0).count(), k);
        }
    }

    #[test]
    fn kept_are_largest() {
        let orig = randvec(512, 3);
        let mut a = orig.clone();
        let c = TopK::new(0.25);
        let mut rng = Rng::new(0);
        c.compress(&mut a, &mut rng);
        let kept_min = a
            .iter()
            .filter(|&&x| x != 0.0)
            .map(|x| x.abs())
            .fold(f32::INFINITY, f32::min);
        let dropped_max = orig
            .iter()
            .zip(&a)
            .filter(|(_, &kept)| kept == 0.0)
            .map(|(o, _)| o.abs())
            .fold(0.0f32, f32::max);
        assert!(kept_min >= dropped_max);
    }

    #[test]
    fn tie_break_lower_index() {
        let mut a = vec![1.0f32; 16];
        let c = TopK::new(0.25); // k = 4
        let mut rng = Rng::new(0);
        let kept = c.compress(&mut a, &mut rng);
        assert_eq!(kept, 4);
        assert_eq!(&a[..4], &[1.0; 4]);
        assert!(a[4..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn values_pass_through_unchanged() {
        let orig = randvec(256, 5);
        let mut a = orig.clone();
        let c = TopK::new(0.5);
        let mut rng = Rng::new(0);
        c.compress(&mut a, &mut rng);
        for (o, v) in orig.iter().zip(&a) {
            assert!(*v == 0.0 || v == o);
        }
    }

    #[test]
    fn delta_one_is_identity() {
        let orig = randvec(128, 6);
        let mut a = orig.clone();
        let c = TopK::new(1.0);
        let mut rng = Rng::new(0);
        assert_eq!(c.compress(&mut a, &mut rng), 128);
        assert_eq!(a, orig);
    }

    #[test]
    fn lemma2_contract() {
        // ||C(a) - a||^2 <= (1 - delta) ||a||^2  (Lemma 2, deterministic
        // for top-k)
        for seed in 0..20 {
            let orig = randvec(1000, seed);
            for delta in [0.01, 0.1, 0.5, 0.9] {
                let mut a = orig.clone();
                let c = TopK::new(delta);
                let mut rng = Rng::new(0);
                c.compress(&mut a, &mut rng);
                let err: f64 = orig
                    .iter()
                    .zip(&a)
                    .map(|(o, v)| ((o - v) as f64).powi(2))
                    .sum();
                let norm: f64 = orig.iter().map(|x| (*x as f64).powi(2)).sum();
                assert!(
                    err <= (1.0 - delta) * norm + 1e-9,
                    "seed={seed} delta={delta}: {err} > {}",
                    (1.0 - delta) * norm
                );
            }
        }
    }
}
