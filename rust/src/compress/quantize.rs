//! Stochastic 8-bit quantization (QSGD-style, per-chunk scale).
//!
//! An ablation compressor: hybrid schemes (CocktailSGD [21]) stack random
//! sparsification with quantization. Quantization keeps every coordinate but
//! shrinks each to 8 bits, so `delta()` reports the *bit* ratio 8/32 = 0.25
//! and `wire_bits` accounts 8 bits/element + one f32 scale per chunk.

use super::Compressor;
use crate::util::Rng;

const CHUNK: usize = 1024;

#[derive(Clone, Debug, Default)]
pub struct QuantizeQ8;

impl QuantizeQ8 {
    pub fn new() -> Self {
        Self
    }

    /// Quantize one chunk to int8 levels stochastically, dequantize back.
    fn roundtrip_chunk(a: &mut [f32], rng: &mut Rng) {
        let maxabs = a.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        if maxabs == 0.0 {
            return;
        }
        let scale = maxabs / 127.0;
        for x in a.iter_mut() {
            let q = *x / scale; // in [-127, 127]
            let lo = q.floor();
            let p = q - lo; // prob of rounding up
            let q = if (rng.next_f32()) < p { lo + 1.0 } else { lo };
            *x = q.clamp(-127.0, 127.0) * scale;
        }
    }
}

impl Compressor for QuantizeQ8 {
    fn name(&self) -> &'static str {
        "quantize_q8"
    }

    fn delta(&self) -> f64 {
        8.0 / 32.0
    }

    fn compress(&self, a: &mut [f32], rng: &mut Rng) -> usize {
        for chunk in a.chunks_mut(CHUNK) {
            Self::roundtrip_chunk(chunk, rng);
        }
        a.len()
    }

    fn wire_bits(&self, _kept: usize, d: usize) -> u64 {
        let chunks = d.div_ceil(CHUNK) as u64;
        (d as u64) * 8 + chunks * 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantization_error_bounded() {
        let mut rng = Rng::new(21);
        let orig: Vec<f32> = (0..2048).map(|_| rng.normal_f32() * 3.0).collect();
        let mut a = orig.clone();
        QuantizeQ8.compress(&mut a, &mut rng);
        let maxabs = orig.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        let step = maxabs / 127.0;
        for (o, q) in orig.iter().zip(&a) {
            assert!((o - q).abs() <= step + 1e-6, "o={o} q={q} step={step}");
        }
    }

    #[test]
    fn unbiased_rounding() {
        let mut rng = Rng::new(22);
        let orig = vec![0.333f32; 512];
        let mut acc = 0.0f64;
        let trials = 2000;
        for _ in 0..trials {
            let mut a = orig.clone();
            QuantizeQ8::roundtrip_chunk(&mut a, &mut rng);
            acc += a.iter().map(|&x| x as f64).sum::<f64>() / a.len() as f64;
        }
        let mean = acc / trials as f64;
        assert!((mean - 0.333).abs() < 1e-3, "mean={mean}");
    }

    #[test]
    fn wire_bits_quarter_rate() {
        let q = QuantizeQ8;
        assert_eq!(q.wire_bits(4096, 4096), 4096 * 8 + 4 * 32);
    }

    #[test]
    fn zero_vector_passthrough() {
        let mut a = vec![0.0f32; 64];
        let mut rng = Rng::new(23);
        QuantizeQ8.compress(&mut a, &mut rng);
        assert!(a.iter().all(|&x| x == 0.0));
    }
}
