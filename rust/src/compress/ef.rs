//! Error feedback (EF) — the paper's Sec. 2.2.2 update rules:
//!
//! ```text
//! Delta_t^i = C_delta(g_t^i + e_t^i)
//! e_{t+1}^i = g_t^i + e_t^i - Delta_t^i
//! ```
//!
//! One `ErrorFeedback` instance per worker. `step` is the gradient-path hot
//! call: it adds the carried error into the (mutable) gradient buffer, runs
//! the compressor in place, and recovers the new error without any extra
//! allocation (the caller's buffer becomes Delta; e is updated from the
//! difference). The invariant `Delta + e_new == g + e_old` holds *bitwise*
//! because e_new is computed as exactly `a - Delta` with Delta ∈ {a_i, 0}.

use super::Compressor;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct ErrorFeedback {
    e: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new(dim: usize) -> Self {
        Self { e: vec![0.0; dim] }
    }

    pub fn dim(&self) -> usize {
        self.e.len()
    }

    pub fn error(&self) -> &[f32] {
        &self.e
    }

    /// Squared norm of the carried error (the `||e_t||^2` the theory bounds).
    pub fn error_norm_sq(&self) -> f64 {
        crate::util::stats::l2_norm_sq(&self.e)
    }

    /// Reset carried error (used when delta/tau switch discontinuously would
    /// invalidate stale error — DeCo keeps it by default, matching Algo 2).
    pub fn reset(&mut self) {
        self.e.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Fold a whole gradient into the carried error. This is how the delay
    /// queue drains an extra in-flight gradient after a τ decrease: instead
    /// of dropping the gradient (losing its mass) or sending a second
    /// message (violating one-message-per-iteration), its mass re-emits
    /// through later compressed messages via the EF loop.
    pub fn absorb(&mut self, g: &[f32]) {
        assert_eq!(g.len(), self.e.len(), "gradient/eF dim mismatch");
        for (ei, gi) in self.e.iter_mut().zip(g.iter()) {
            *ei += *gi;
        }
    }

    /// Hot call: `g` enters as the raw gradient, leaves as `Delta`.
    /// Returns the number of transmitted (non-zero budget) entries.
    pub fn step(
        &mut self,
        g: &mut [f32],
        comp: &dyn Compressor,
        rng: &mut Rng,
    ) -> usize {
        assert_eq!(g.len(), self.e.len(), "gradient/eF dim mismatch");
        // a = g + e  (into the gradient buffer)
        for (gi, ei) in g.iter_mut().zip(self.e.iter()) {
            *gi += *ei;
        }
        // stash a into e (so after in-place compression we can recover it)
        self.e.copy_from_slice(g);
        let kept = comp.compress(g, rng);
        // e_new = a - Delta ; for selection compressors this is exact:
        // kept coords -> 0, dropped coords -> a_i
        for (ei, di) in self.e.iter_mut().zip(g.iter()) {
            *ei -= *di;
        }
        kept
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{BlockTopK, Identity, RandK, TopK};

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn ef_invariant_bitwise() {
        // Delta + e_new == g + e_old exactly, across iterations
        let n = 2048;
        let mut ef = ErrorFeedback::new(n);
        let comp = TopK::new(0.05);
        let mut rng = Rng::new(1);
        for t in 0..10 {
            let g = randvec(n, 100 + t);
            let e_old = ef.error().to_vec();
            let mut buf = g.clone();
            ef.step(&mut buf, &comp, &mut rng);
            for i in 0..n {
                let a = g[i] + e_old[i];
                assert_eq!(buf[i] + ef.error()[i], a, "i={i} t={t}");
            }
        }
    }

    #[test]
    fn identity_never_accumulates_error() {
        let n = 256;
        let mut ef = ErrorFeedback::new(n);
        let mut rng = Rng::new(2);
        for t in 0..5 {
            let mut g = randvec(n, t);
            ef.step(&mut g, &Identity, &mut rng);
            assert_eq!(ef.error_norm_sq(), 0.0);
        }
    }

    #[test]
    fn error_bounded_under_repeated_compression() {
        // Lemma 7's premise: with top-k EF the error stays bounded
        // (geometric contraction), it must not blow up over many steps.
        let n = 4096;
        let mut ef = ErrorFeedback::new(n);
        let comp = BlockTopK::new(0.05);
        let mut rng = Rng::new(3);
        let mut max_norm: f64 = 0.0;
        for t in 0..300 {
            let mut g = randvec(n, 7000 + t);
            ef.step(&mut g, &comp, &mut rng);
            max_norm = max_norm.max(ef.error_norm_sq());
        }
        // ||g||^2 ~ n; the EF bound is ~ (2/delta)*(1-delta)/(1-(1-d/2)) * n
        // with delta=0.05 that's O(40n); assert we stay well inside 100n.
        assert!(
            max_norm < 100.0 * n as f64,
            "error diverged: {max_norm} vs n={n}"
        );
    }

    #[test]
    fn randk_ef_invariant() {
        let n = 512;
        let mut ef = ErrorFeedback::new(n);
        let comp = RandK::new(0.1);
        let mut rng = Rng::new(4);
        let g = randvec(n, 9);
        let mut buf = g.clone();
        ef.step(&mut buf, &comp, &mut rng);
        for i in 0..n {
            assert_eq!(buf[i] + ef.error()[i], g[i]);
        }
    }

    #[test]
    fn absorb_adds_to_error_and_reemits() {
        let n = 64;
        let mut ef = ErrorFeedback::new(n);
        let g = randvec(n, 11);
        ef.absorb(&g);
        for i in 0..n {
            assert_eq!(ef.error()[i], g[i]);
        }
        // an Identity step flushes the absorbed mass into the next message
        let mut rng = Rng::new(6);
        let mut zero = vec![0.0f32; n];
        ef.step(&mut zero, &Identity, &mut rng);
        for i in 0..n {
            assert_eq!(zero[i], g[i], "absorbed mass must re-emit");
        }
        assert_eq!(ef.error_norm_sq(), 0.0);
    }

    #[test]
    fn reset_clears() {
        let mut ef = ErrorFeedback::new(64);
        let mut g = randvec(64, 10);
        let mut rng = Rng::new(5);
        ef.step(&mut g, &TopK::new(0.1), &mut rng);
        assert!(ef.error_norm_sq() > 0.0);
        ef.reset();
        assert_eq!(ef.error_norm_sq(), 0.0);
    }
}
