//! Rand-k sparsification — unbiased-when-scaled random coordinate selection.
//!
//! Kept for baseline ablations (CocktailSGD's sparsifier is random-k); the
//! paper's default is Top-k. `scale` controls whether the kept entries are
//! rescaled by d/k (the unbiased estimator) or passed through (the EF
//! convention, default — error feedback already compensates bias).

use super::Compressor;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct RandK {
    delta: f64,
    scale: bool,
}

impl RandK {
    pub fn new(delta: f64) -> Self {
        assert!(delta > 0.0 && delta <= 1.0);
        Self { delta, scale: false }
    }

    pub fn unbiased(delta: f64) -> Self {
        assert!(delta > 0.0 && delta <= 1.0);
        Self { delta, scale: true }
    }
}

impl Compressor for RandK {
    fn name(&self) -> &'static str {
        if self.scale { "randk_unbiased" } else { "randk" }
    }

    fn delta(&self) -> f64 {
        self.delta
    }

    fn compress(&self, a: &mut [f32], rng: &mut Rng) -> usize {
        let n = a.len();
        let k = super::k_for_delta(self.delta, n);
        if k >= n {
            return n;
        }
        // keep-mask via partial Fisher-Yates over indices
        let keep = rng.sample_indices(n, k);
        let mut mask = vec![false; n];
        for &i in &keep {
            mask[i as usize] = true;
        }
        let factor = if self.scale { n as f32 / k as f32 } else { 1.0 };
        for (x, m) in a.iter_mut().zip(&mask) {
            if *m {
                *x *= factor;
            } else {
                *x = 0.0;
            }
        }
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_k_random_entries() {
        let mut rng = Rng::new(11);
        let mut a: Vec<f32> = (1..=1000).map(|i| i as f32).collect();
        let c = RandK::new(0.1);
        let kept = c.compress(&mut a, &mut rng);
        assert_eq!(kept, 100);
        assert_eq!(a.iter().filter(|&&x| x != 0.0).count(), 100);
    }

    #[test]
    fn unbiased_in_expectation() {
        // E[C(a)] == a for the scaled variant: average many draws
        let n = 64;
        let a: Vec<f32> = (0..n).map(|i| (i as f32) - 31.5).collect();
        let c = RandK::unbiased(0.25);
        let mut rng = Rng::new(12);
        let trials = 4000;
        let mut acc = vec![0.0f64; n];
        for _ in 0..trials {
            let mut b = a.clone();
            c.compress(&mut b, &mut rng);
            for (s, v) in acc.iter_mut().zip(&b) {
                *s += *v as f64;
            }
        }
        for (s, orig) in acc.iter().zip(&a) {
            let mean = s / trials as f64;
            // estimator variance: Var = (1/delta - 1) * orig^2; allow 5 sigma
            let sigma =
                ((3.0 * (*orig as f64).powi(2)) / trials as f64).sqrt();
            assert!(
                (mean - *orig as f64).abs() < 5.0 * sigma + 0.05,
                "mean={mean} orig={orig} sigma={sigma}"
            );
        }
    }

    #[test]
    fn deterministic_given_rng_state() {
        let a0: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let c = RandK::new(0.2);
        let mut r1 = Rng::new(99);
        let mut r2 = Rng::new(99);
        let mut a1 = a0.clone();
        let mut a2 = a0.clone();
        c.compress(&mut a1, &mut r1);
        c.compress(&mut a2, &mut r2);
        assert_eq!(a1, a2);
    }
}
