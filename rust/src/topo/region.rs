//! Region structure and aggregator election.
//!
//! A [`Topology`] describes how the workers of a run are wired into the
//! aggregation tree. [`Topology::Flat`] is the historical star: every
//! worker pushes straight to the leader over its own fabric link.
//! [`Topology::TwoTier`] groups workers into regions, each with an elected
//! local aggregator and a per-region WAN link (a [`Fabric`] with one link
//! per *region*), so only region partials cross the WAN.

use std::sync::Arc;

use crate::netsim::Fabric;

/// One region of a two-tier topology: its member worker indices and the
/// member currently acting as local aggregator.
///
/// `members` is `Arc`-shared: a topology clone (one per sweep cell, one
/// inside every `VirtualClock`) bumps a refcount instead of copying the
/// member list — the PR-5 grid-sharing pattern applied to topology shapes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RegionTopo {
    /// worker indices belonging to this region (ascending, non-empty)
    pub members: Arc<[usize]>,
    /// the member reducing this region's gradients; its own gradient is
    /// local (no intra-region hop), and re-election replaces it when it
    /// departs (DESIGN.md §Topology)
    pub aggregator: usize,
}

impl RegionTopo {
    /// Build from any member container (`Vec<usize>`, boxed slice, …).
    pub fn new(members: impl Into<Arc<[usize]>>, aggregator: usize) -> Self {
        Self { members: members.into(), aggregator }
    }

    pub fn contains(&self, worker: usize) -> bool {
        self.members.contains(&worker)
    }
}

/// The aggregation tree of a run.
#[derive(Clone, Debug)]
pub enum Topology {
    /// every worker pushes straight to the leader over its own fabric link
    /// (bit-identical to the pre-topology path — `tests/topo.rs`)
    Flat,
    /// two-tier: intra-region reduction at elected aggregators, then one
    /// WAN transfer per region. `wan` has exactly one link per region.
    TwoTier { regions: Vec<RegionTopo>, wan: Fabric },
}

impl Topology {
    pub fn is_two_tier(&self) -> bool {
        matches!(self, Topology::TwoTier { .. })
    }

    /// Number of regions (0 for flat).
    pub fn region_count(&self) -> usize {
        match self {
            Topology::Flat => 0,
            Topology::TwoTier { regions, .. } => regions.len(),
        }
    }

    /// Check structural invariants against an `n`-worker run: regions
    /// partition `0..n` (every worker in exactly one region), every
    /// aggregator is a member of its region, and the WAN fabric carries
    /// exactly one link per region.
    pub fn validate(&self, n: usize) -> anyhow::Result<()> {
        let Topology::TwoTier { regions, wan } = self else {
            return Ok(());
        };
        if regions.is_empty() {
            anyhow::bail!("two-tier topology needs at least one region");
        }
        if wan.workers() != regions.len() {
            anyhow::bail!(
                "WAN fabric has {} links but the topology has {} regions",
                wan.workers(),
                regions.len()
            );
        }
        let mut seen = vec![false; n];
        for (r, region) in regions.iter().enumerate() {
            if region.members.is_empty() {
                anyhow::bail!("region {r} has no members");
            }
            if !region.contains(region.aggregator) {
                anyhow::bail!(
                    "region {r} aggregator {} is not one of its members",
                    region.aggregator
                );
            }
            for &w in &region.members {
                if w >= n {
                    anyhow::bail!(
                        "region {r} member {w} out of range (n = {n})"
                    );
                }
                if seen[w] {
                    anyhow::bail!("worker {w} appears in two regions");
                }
                seen[w] = true;
            }
        }
        if let Some(w) = seen.iter().position(|&s| !s) {
            anyhow::bail!("worker {w} belongs to no region");
        }
        Ok(())
    }

    /// The region index of `worker` (None for flat topologies).
    pub fn region_of(&self, worker: usize) -> Option<usize> {
        match self {
            Topology::Flat => None,
            Topology::TwoTier { regions, .. } => {
                regions.iter().position(|r| r.contains(worker))
            }
        }
    }

}

/// Elect a region's aggregator: the member with the highest intra-region
/// bandwidth at t = 0 — it sinks every member's message, so the
/// best-connected node hurts least — breaking ties by lowest latency, then
/// lowest index. Deterministic by construction.
pub fn elect(fabric: &Fabric, members: &[usize]) -> usize {
    elect_among(fabric, members, |_| true)
        .expect("elect requires a non-empty member list")
}

/// [`elect`] restricted to members marked `true` in `eligible` (indexed by
/// worker id) — the re-election form churn drives. `None` when no member
/// is eligible.
pub fn elect_eligible(
    fabric: &Fabric,
    members: &[usize],
    eligible: &[bool],
) -> Option<usize> {
    elect_among(fabric, members, |w| eligible[w])
}

fn elect_among(
    fabric: &Fabric,
    members: &[usize],
    eligible: impl Fn(usize) -> bool,
) -> Option<usize> {
    let mut best: Option<(usize, f64, f64)> = None;
    for &w in members {
        if !eligible(w) {
            continue;
        }
        let link = fabric.link(w);
        let (bw, lat) = (link.bandwidth_at(0.0), link.latency());
        let better = match best {
            None => true,
            Some((bw_b, lat_b, _)) => {
                bw > bw_b || (bw == bw_b && lat < lat_b)
            }
        };
        // ascending member order: ties keep the lowest index
        if better {
            best = Some((bw, lat, w));
        }
    }
    best.map(|(_, _, w)| w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{BandwidthTrace, Link};

    fn fabric(links: &[(f64, f64)]) -> Fabric {
        Fabric::new(
            links
                .iter()
                .map(|&(bps, lat)| {
                    Link::new(BandwidthTrace::constant(bps), lat)
                })
                .collect(),
        )
    }

    fn two_tier(regions: Vec<RegionTopo>, n_regions: usize) -> Topology {
        Topology::TwoTier {
            regions,
            wan: Fabric::homogeneous(
                n_regions,
                BandwidthTrace::constant(1e7),
                0.3,
            ),
        }
    }

    #[test]
    fn election_prefers_bandwidth_then_latency_then_index() {
        let f = fabric(&[
            (1e8, 0.1),
            (2e8, 0.2), // fastest link wins despite higher latency
            (2e8, 0.1),
            (1e8, 0.1),
        ]);
        assert_eq!(elect(&f, &[0, 3]), 0, "tie resolves to lowest index");
        assert_eq!(elect(&f, &[0, 1]), 1, "bandwidth dominates");
        assert_eq!(elect(&f, &[1, 2]), 2, "latency breaks the bw tie");
    }

    #[test]
    fn validate_catches_bad_partitions() {
        let ok = two_tier(
            vec![
                RegionTopo::new(vec![0, 1], 0),
                RegionTopo::new(vec![2, 3], 3),
            ],
            2,
        );
        assert!(ok.validate(4).is_ok());
        assert!(Topology::Flat.validate(4).is_ok());

        let overlap = two_tier(
            vec![
                RegionTopo::new(vec![0, 1], 0),
                RegionTopo::new(vec![1, 2, 3], 2),
            ],
            2,
        );
        assert!(overlap.validate(4).is_err(), "worker in two regions");

        let uncovered = two_tier(
            vec![RegionTopo::new(vec![0, 1], 0)],
            1,
        );
        assert!(uncovered.validate(3).is_err(), "worker 2 unassigned");

        let foreign_agg = two_tier(
            vec![
                RegionTopo::new(vec![0, 1], 2),
                RegionTopo::new(vec![2, 3], 2),
            ],
            2,
        );
        assert!(foreign_agg.validate(4).is_err());

        let wan_mismatch = Topology::TwoTier {
            regions: vec![
                RegionTopo::new(vec![0, 1], 0),
                RegionTopo::new(vec![2, 3], 2),
            ],
            wan: Fabric::homogeneous(3, BandwidthTrace::constant(1e7), 0.3),
        };
        assert!(wan_mismatch.validate(4).is_err());
    }

    #[test]
    fn region_lookup_and_eligible_election() {
        let f = fabric(&[(2e8, 0.1), (1e8, 0.1), (5e7, 0.1), (1e8, 0.1)]);
        let topo = two_tier(
            vec![
                RegionTopo::new(vec![0, 1], 0),
                RegionTopo::new(vec![2, 3], 3),
            ],
            2,
        );
        assert_eq!(topo.region_of(1), Some(0));
        assert_eq!(topo.region_of(2), Some(1));
        assert_eq!(Topology::Flat.region_of(1), None);
        // the re-election primitive (what VirtualClock::reelect_aggregator
        // drives): aggregator 0 departs -> worker 1 takes over region 0
        let mut eligible = vec![true; 4];
        eligible[0] = false;
        assert_eq!(elect_eligible(&f, &[0, 1], &eligible), Some(1));
        // an empty eligible set elects nobody (the region idles)
        eligible[1] = false;
        assert_eq!(elect_eligible(&f, &[0, 1], &eligible), None);
        // unrestricted election agrees with an all-true mask
        assert_eq!(
            elect_eligible(&f, &[2, 3], &[true; 4]),
            Some(elect(&f, &[2, 3]))
        );
    }
}
