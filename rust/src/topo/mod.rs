//! Hierarchical multi-datacenter topology (DESIGN.md §Topology) — the
//! two-tier aggregation layer over the per-worker [`crate::netsim::Fabric`].
//!
//! The paper's premise is training *across data centers*: cheap, fast links
//! inside a region and scarce, high-latency WAN links between them. The
//! flat star every run priced until now sends all n worker messages
//! straight across the worker links; this module turns a region-structured
//! fabric into a **two-tier aggregation plan**:
//!
//! * each region elects a local **aggregator** ([`elect`]) that reduces its
//!   members' (δ_lan-compressed) gradients over intra-region links;
//! * only the **per-region partials** cross the WAN, re-compressed at their
//!   own ratio δ_wan with their own staleness share τ_wan and a second,
//!   per-region error-feedback state at the boundary;
//! * the virtual clock prices the hierarchy exactly
//!   ([`crate::coordinator::VirtualClock::tick_topo`]): a region's partial
//!   is ready at the **slowest member's** intra-region arrival, the global
//!   aggregation completes at the **slowest region partial's** WAN arrival.
//!
//! [`Topology::Flat`] is the degenerate case and stays bit-identical to the
//! fabric-only path (`tests/topo.rs`); [`plan`] holds the per-tier DeCo
//! decomposition the `DecoTwoTier` strategy solves.

pub mod plan;
pub mod region;

pub use plan::{lan_input, wan_input, TwoTierPlan};
pub use region::{elect, elect_eligible, RegionTopo, Topology};
