//! Per-tier DeCo decomposition (DESIGN.md §Topology).
//!
//! The two-tier pipeline runs the DeCo problem **once per tier**: the LAN
//! tier ships each member's δ_lan-compressed gradient to its region
//! aggregator; the WAN tier ships each region's δ_wan-compressed partial to
//! the leader. Partials emerge every `T_comp` once the LAN tier is
//! bubble-free, so both tiers share the same `T_comp` cadence and each
//! solves the standard bubble-free problem against its own `(a, b)`:
//!
//! ```text
//! (τ_lan, δ_lan) = DeCo(S_g, a_lan, b_lan, T_comp)
//! (τ_wan, δ_wan) = DeCo(S_g, a_wan, b_wan, T_comp)
//! ```
//!
//! with the end-to-end staleness the delay queue realizes being the sum
//! `τ = τ_lan + τ_wan` (each tier's delay share covers its own hop). The
//! region partial is the aggregate of a whole region's gradients — still a
//! length-d vector, hence `s_g` (not `n_r · s_g`) prices the WAN message:
//! fan-in across the WAN is `n_effective = #regions`, one flow per region.

use crate::deco::{solve, DecoInput, DecoOutput};
use crate::netsim::Fabric;

use super::Topology;

/// The per-tier solution the `DecoTwoTier` strategy executes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TwoTierPlan {
    /// worker → region aggregator (intra-region links)
    pub lan: DecoOutput,
    /// region partial → leader (WAN links)
    pub wan: DecoOutput,
}

impl TwoTierPlan {
    /// Solve both tiers from their inputs.
    pub fn solve(lan: &DecoInput, wan: &DecoInput) -> Self {
        Self { lan: solve(lan), wan: solve(wan) }
    }

    /// End-to-end staleness the worker delay queues realize.
    pub fn total_tau(&self) -> usize {
        self.lan.tau + self.wan.tau
    }
}

/// Ground-truth LAN-tier DeCo input: the bottleneck over every member link
/// of every region at time `t` (on a two-tier fabric all worker links are
/// intra-region links). Monitored planning uses the per-link estimators
/// instead; this is the fabric-side view for analysis and priors.
pub fn lan_input(
    s_g: f64,
    t_comp: f64,
    fabric: &Fabric,
    t: f64,
) -> DecoInput {
    let (a, b) = fabric.bottleneck(t);
    DecoInput { s_g, a, b, t_comp }
}

/// Ground-truth WAN-tier DeCo input: the bottleneck over the topology's
/// per-region WAN links at time `t`. Panics on a flat topology — there is
/// no WAN tier to price.
pub fn wan_input(
    s_g: f64,
    t_comp: f64,
    topo: &Topology,
    t: f64,
) -> DecoInput {
    let Topology::TwoTier { wan, .. } = topo else {
        panic!("wan_input on a flat topology");
    };
    let (a, b) = wan.bottleneck(t);
    DecoInput { s_g, a, b, t_comp }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::BandwidthTrace;
    use crate::topo::RegionTopo;

    fn topo(wan_bps: f64, wan_lat: f64) -> Topology {
        Topology::TwoTier {
            regions: vec![
                RegionTopo::new(vec![0, 1], 0),
                RegionTopo::new(vec![2, 3], 2),
            ],
            wan: Fabric::homogeneous(
                2,
                BandwidthTrace::constant(wan_bps),
                wan_lat,
            ),
        }
    }

    #[test]
    fn tiers_price_their_own_links() {
        let lan_fabric =
            Fabric::homogeneous(4, BandwidthTrace::constant(1e9), 0.005);
        let s_g = 2e8;
        let t_comp = 0.2;
        let topo = topo(2e7, 0.3);
        let lan = lan_input(s_g, t_comp, &lan_fabric, 0.0);
        let wan = wan_input(s_g, t_comp, &topo, 0.0);
        assert_eq!(lan.a, 1e9);
        assert_eq!(wan.a, 2e7);
        let plan = TwoTierPlan::solve(&lan, &wan);
        // the fast LAN barely compresses; the scarce WAN compresses hard
        // and hides its latency behind a deeper delay share
        assert!(plan.lan.delta > plan.wan.delta);
        assert!(plan.wan.tau >= plan.lan.tau);
        assert_eq!(plan.total_tau(), plan.lan.tau + plan.wan.tau);
    }

    #[test]
    #[should_panic]
    fn wan_input_rejects_flat() {
        wan_input(1e8, 0.2, &Topology::Flat, 0.0);
    }
}
