//! L3 coordinator — the DD-EF-SGD training pipeline (Algorithm 2) over n
//! data-parallel workers, with delayed aggregation, error-feedback Top-k
//! compression, the DeCo controller, a trace-driven virtual clock, and
//! metrics. This is the paper's *system* contribution.
//!
//! Execution model (see DESIGN.md): the n workers are simulated inside one
//! process — each owns a data shard, an EF error vector and a delay queue;
//! gradients come from a [`crate::optim::GradOracle`] (PJRT-backed for the
//! real models, analytic for the theory experiments). Time is *virtual*:
//! computation cost is measured (or pinned) per iteration and communication
//! cost is priced on a per-worker [`crate::netsim::Fabric`] by the
//! fabric-driven Eq. 19 recurrence (each worker transmits over its own
//! link; the aggregation completes at the slowest arrival — DESIGN.md
//! §Network-Fabric) — exactly the quantity the paper's tables report —
//! while the training mathematics (losses, gradients, EF states) is
//! executed for real.
//!
//! Real wall-clock execution is parallel (DESIGN.md §Parallel-Execution):
//! the per-worker phase (gradient + clip + enqueue + EF/Top-k) fans out
//! over a [`crate::util::WorkerPool`], and leader aggregation shards the
//! model dimension across the same pool — with a fixed worker-order
//! reduction per shard so every pool size produces bit-identical runs.

pub mod arrival;
pub mod clock;
pub mod pipeline;
pub mod worker;

pub use clock::{ClassView, RegionTick, Tick, VirtualClock, WorkerTick};
pub use pipeline::{TrainLoop, TrainParams};
pub use worker::WorkerState;
