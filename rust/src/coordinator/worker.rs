//! Per-worker state: data-shard identity, the EF error vector, and the
//! delay queue that realizes staleness τ (Sec. 2.2.3).
//!
//! The queue discipline handles *dynamic* τ (DeCo changes it mid-run):
//! each iteration pushes the fresh gradient and pops the front gradient
//! whenever the queue holds more than the current τ entries — so after a τ
//! increase the pipeline silently stretches (a few iterations without
//! updates), and after a decrease it drains one extra gradient per step
//! until the new depth is reached. Both transients match what a real
//! asynchronous sender would do.

use crate::compress::{Compressor, ErrorFeedback, SparseVec};
use crate::util::Rng;
use std::collections::VecDeque;

#[derive(Debug)]
pub struct WorkerState {
    pub id: usize,
    ef: ErrorFeedback,
    queue: VecDeque<Vec<f32>>,
    /// recycled gradient buffers — the delay queue reaches steady state
    /// after τ iterations and then churns zero allocations (§Perf)
    free: Vec<Vec<f32>>,
    rng: Rng,
    /// scratch buffer reused across iterations (hot-path, no allocs)
    scratch: Vec<f32>,
}

impl WorkerState {
    pub fn new(id: usize, dim: usize, seed: u64) -> Self {
        Self {
            id,
            ef: ErrorFeedback::new(dim),
            queue: VecDeque::new(),
            free: Vec::new(),
            rng: Rng::new(seed ^ (id as u64).wrapping_mul(0x2545F4914F6CDD1D)),
            scratch: vec![0.0; dim],
        }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn error_norm_sq(&self) -> f64 {
        self.ef.error_norm_sq()
    }

    /// Mutable view of the scratch gradient buffer the oracle writes into.
    pub fn grad_buffer(&mut self) -> &mut [f32] {
        &mut self.scratch
    }

    /// Push the freshly-computed gradient (copies out of the scratch into
    /// a recycled buffer — steady state allocates nothing).
    pub fn push_gradient(&mut self) {
        let mut g = self.free.pop().unwrap_or_else(|| {
            Vec::with_capacity(self.scratch.len())
        });
        g.clear();
        g.extend_from_slice(&self.scratch);
        self.queue.push_back(g);
    }

    /// If the queue is deeper than `tau`, pop the oldest gradient, run the
    /// EF + compression step, and return the sparse message (plus kept
    /// count). Returns `None` while the pipeline is still filling.
    pub fn pop_compress(
        &mut self,
        tau: usize,
        comp: &dyn Compressor,
    ) -> Option<(SparseVec, usize)> {
        if self.queue.len() <= tau {
            return None;
        }
        let mut g = self.queue.pop_front().expect("non-empty");
        let kept = self.ef.step(&mut g, comp, &mut self.rng);
        let sv = SparseVec::encode_with_capacity(&g, kept);
        self.free.push(g); // recycle for future pushes
        Some((sv, kept))
    }

    /// Drop all queued gradients and carried error (full restart).
    pub fn reset(&mut self) {
        self.queue.clear();
        self.ef.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, TopK};

    #[test]
    fn staleness_is_exactly_tau() {
        // with constant τ, the gradient popped at iteration t was pushed at
        // t − τ
        let dim = 8;
        let mut w = WorkerState::new(0, dim, 1);
        let tau = 3usize;
        let comp = Identity;
        for t in 0..20usize {
            // stamp the gradient with its iteration index
            w.grad_buffer().iter_mut().for_each(|v| *v = t as f32);
            w.push_gradient();
            match w.pop_compress(tau, &comp) {
                None => assert!(t < tau, "pipeline should emit from t=τ"),
                Some((sv, _)) => {
                    let dense = sv.decode();
                    assert_eq!(dense[0] as usize, t - tau, "wrong staleness");
                }
            }
        }
    }

    #[test]
    fn tau_increase_stalls_then_resumes() {
        let dim = 4;
        let mut w = WorkerState::new(0, dim, 2);
        let comp = Identity;
        for t in 0..5usize {
            w.grad_buffer().iter_mut().for_each(|v| *v = t as f32);
            w.push_gradient();
            w.pop_compress(1, &comp);
        }
        // queue now holds 1 entry; raising τ to 4 stalls pops
        for t in 5..8usize {
            w.grad_buffer().iter_mut().for_each(|v| *v = t as f32);
            w.push_gradient();
            assert!(w.pop_compress(4, &comp).is_none() || t == 7);
        }
    }

    #[test]
    fn tau_decrease_drains() {
        let dim = 4;
        let mut w = WorkerState::new(0, dim, 3);
        let comp = Identity;
        for t in 0..6usize {
            w.grad_buffer().iter_mut().for_each(|v| *v = t as f32);
            w.push_gradient();
            w.pop_compress(5, &comp); // deep queue: pops only once len > 5
        }
        // 6 pushes, one pop at t=5 (len hit 6 > τ=5)
        assert_eq!(w.queue_len(), 5);
        // τ drops to 0: each call pops one, so repeated calls drain
        let mut drained = 0;
        while w.pop_compress(0, &comp).is_some() {
            drained += 1;
        }
        assert_eq!(drained, 5);
    }

    #[test]
    fn compression_applies_ef() {
        let dim = 1024;
        let mut w = WorkerState::new(0, dim, 4);
        let comp = TopK::new(0.1);
        let mut rng = Rng::new(9);
        for _ in 0..3 {
            let buf = w.grad_buffer();
            for v in buf.iter_mut() {
                *v = rng.normal_f32();
            }
            w.push_gradient();
            let (sv, kept) = w.pop_compress(0, &comp).unwrap();
            assert_eq!(kept, 103); // ceil(0.1 * 1024)
            assert_eq!(sv.nnz(), kept);
        }
        assert!(w.error_norm_sq() > 0.0, "EF must carry error");
    }
}
