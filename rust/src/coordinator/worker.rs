//! Per-worker state: data-shard identity, the EF error vector, and the
//! delay queue that realizes staleness τ (Sec. 2.2.3).
//!
//! The queue discipline handles *dynamic* τ (DeCo changes it mid-run):
//! each iteration pushes the fresh gradient and pops the front gradient
//! whenever the queue holds more than the current τ entries — so after a τ
//! increase the pipeline silently stretches (a few iterations without
//! updates), and after a decrease it drains exactly one extra gradient per
//! step until the new depth is reached (the extra in-flight gradient is
//! folded into the EF error via [`ErrorFeedback::absorb`], so its mass
//! re-emits through later compressed messages instead of being lost). Both
//! transients match what a real asynchronous sender would do;
//! `tests/properties.rs::prop_delay_queue_transients` checks them against
//! an explicit queue model.
//!
//! Elasticity (DESIGN.md §Elasticity): a departed worker's `WorkerState` is
//! *retained* — EF vector, delay queue, RNG — so a `Rejoin` resumes warm.
//! While departing under the `Drain` policy the worker stops computing but
//! keeps emitting its in-flight gradients one per iteration
//! ([`Self::drain_compress_cached`]); under `Drop` it merely clears its
//! pending message ([`Self::suspend`]) and the queue freezes in place.
//!
//! Parallel-execution contract (DESIGN.md §Parallel-Execution): a
//! `WorkerState` owns *everything* its per-iteration phase touches — EF
//! vector, delay queue, RNG, gradient scratch, compressor cache, and the
//! outgoing message buffer — so the pool may run one worker per thread with
//! no sharing and no locks. The leader reads the phase outputs
//! (`last_loss`, `last_grad_norm`, `message()`) only after the phase joins.

use crate::compress::{Compressor, CompressorCache, ErrorFeedback, SparseVec};
use crate::util::Rng;
use std::collections::VecDeque;

#[derive(Debug)]
pub struct WorkerState {
    pub id: usize,
    ef: ErrorFeedback,
    queue: VecDeque<Vec<f32>>,
    /// recycled gradient buffers — the delay queue reaches steady state
    /// after τ iterations and then churns zero allocations (§Perf)
    free: Vec<Vec<f32>>,
    rng: Rng,
    /// scratch buffer reused across iterations (hot-path, no allocs)
    scratch: Vec<f32>,
    /// outgoing sparse message, recycled across iterations (§Perf)
    msg: SparseVec,
    /// entries kept in `msg` this iteration; `None` while the pipeline fills
    msg_kept: Option<usize>,
    /// per-(δ, blockwise) compressor instances — cached so Top-k's scratch
    /// actually warms instead of being re-boxed every iteration
    comps: CompressorCache,
    /// worker-phase outputs, read by the leader between phases
    pub last_loss: f64,
    pub last_grad_norm: f64,
    /// wall-clock seconds this worker spent in the gradient oracle
    pub comp_secs: f64,
}

impl WorkerState {
    pub fn new(id: usize, dim: usize, seed: u64) -> Self {
        Self {
            id,
            ef: ErrorFeedback::new(dim),
            queue: VecDeque::new(),
            free: Vec::new(),
            rng: Rng::new(seed ^ (id as u64).wrapping_mul(0x2545F4914F6CDD1D)),
            scratch: vec![0.0; dim],
            msg: SparseVec::default(),
            msg_kept: None,
            comps: CompressorCache::new(),
            last_loss: 0.0,
            last_grad_norm: 0.0,
            comp_secs: 0.0,
        }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn error_norm_sq(&self) -> f64 {
        self.ef.error_norm_sq()
    }

    /// Mutable view of the scratch gradient buffer the oracle writes into.
    pub fn grad_buffer(&mut self) -> &mut [f32] {
        &mut self.scratch
    }

    /// Push the freshly-computed gradient (copies out of the scratch into
    /// a recycled buffer — steady state allocates nothing).
    pub fn push_gradient(&mut self) {
        let mut g = self.free.pop().unwrap_or_else(|| {
            Vec::with_capacity(self.scratch.len())
        });
        g.clear();
        g.extend_from_slice(&self.scratch);
        self.queue.push_back(g);
    }

    /// Hot-path pop: if the queue is deeper than `tau`, pop the oldest
    /// gradient, run the EF step through the *cached* compressor for
    /// `(delta, block_topk)`, and encode the result into the recycled
    /// message buffer (readable via [`Self::message`] until the next call).
    /// Returns the kept count, `None` while the pipeline is still filling.
    pub fn pop_compress_cached(
        &mut self,
        tau: usize,
        delta: f64,
        block_topk: bool,
    ) -> Option<usize> {
        self.msg_kept = None;
        if self.queue.len() <= tau {
            return None;
        }
        let mut g = self.queue.pop_front().expect("non-empty");
        self.drain_extra(tau);
        let comp = self.comps.get(delta, block_topk);
        let kept = self.ef.step(&mut g, comp, &mut self.rng);
        self.msg.encode_into(&g);
        self.free.push(g); // recycle for future pushes
        self.msg_kept = Some(kept);
        Some(kept)
    }

    /// τ decreased below the realized pipeline depth: drain exactly ONE
    /// extra in-flight gradient this step, folding it into the EF error so
    /// its mass re-emits through later compressed messages (module docs).
    fn drain_extra(&mut self, tau: usize) {
        if self.queue.len() > tau {
            let extra = self.queue.pop_front().expect("non-empty");
            self.ef.absorb(&extra);
            self.free.push(extra);
        }
    }

    /// The message produced by the last [`Self::pop_compress_cached`], if
    /// one was emitted this iteration.
    pub fn message(&self) -> Option<&SparseVec> {
        self.msg_kept.map(|_| &self.msg)
    }

    /// Kept-entry count of the current message, if one was emitted.
    pub fn message_kept(&self) -> Option<usize> {
        self.msg_kept
    }

    /// Distinct compressors cached so far (steady state: one per δ value
    /// the strategy has visited — the zero-alloc invariant benches check).
    pub fn compressor_cache_len(&self) -> usize {
        self.comps.len()
    }

    /// Allocating variant with a caller-supplied compressor — the
    /// single-message path tests and property checks drive directly.
    /// Returns the sparse message (plus kept count) or `None` while the
    /// pipeline is still filling.
    pub fn pop_compress(
        &mut self,
        tau: usize,
        comp: &dyn Compressor,
    ) -> Option<(SparseVec, usize)> {
        if self.queue.len() <= tau {
            return None;
        }
        let mut g = self.queue.pop_front().expect("non-empty");
        self.drain_extra(tau);
        let kept = self.ef.step(&mut g, comp, &mut self.rng);
        let sv = SparseVec::encode_with_capacity(&g, kept);
        self.free.push(g); // recycle for future pushes
        Some((sv, kept))
    }

    /// Departure drain (elastic `Drain` policy): pop the oldest in-flight
    /// gradient regardless of τ and emit it as this iteration's message —
    /// the worker has stopped computing, its pipeline is flushing. Returns
    /// `None` once the queue is empty (the worker is fully departed).
    pub fn drain_compress_cached(
        &mut self,
        delta: f64,
        block_topk: bool,
    ) -> Option<usize> {
        self.msg_kept = None;
        let mut g = self.queue.pop_front()?;
        let comp = self.comps.get(delta, block_topk);
        let kept = self.ef.step(&mut g, comp, &mut self.rng);
        self.msg.encode_into(&g);
        self.free.push(g);
        self.msg_kept = Some(kept);
        Some(kept)
    }

    /// Clear any pending outgoing message (the worker departed — `Drop`
    /// policy — or finished draining). EF vector and delay queue stay put:
    /// the warm-rejoin contract (module docs).
    pub fn suspend(&mut self) {
        self.msg_kept = None;
    }

    /// Drop all queued gradients, carried error, and any pending message
    /// (full restart).
    pub fn reset(&mut self) {
        self.queue.clear();
        self.ef.reset();
        self.msg_kept = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, TopK};

    #[test]
    fn staleness_is_exactly_tau() {
        // with constant τ, the gradient popped at iteration t was pushed at
        // t − τ
        let dim = 8;
        let mut w = WorkerState::new(0, dim, 1);
        let tau = 3usize;
        let comp = Identity;
        for t in 0..20usize {
            // stamp the gradient with its iteration index
            w.grad_buffer().iter_mut().for_each(|v| *v = t as f32);
            w.push_gradient();
            match w.pop_compress(tau, &comp) {
                None => assert!(t < tau, "pipeline should emit from t=τ"),
                Some((sv, _)) => {
                    let dense = sv.decode();
                    assert_eq!(dense[0] as usize, t - tau, "wrong staleness");
                }
            }
        }
    }

    #[test]
    fn cached_pop_matches_explicit_compressor() {
        // the zero-alloc cached path and the allocating compat path produce
        // identical messages given identical state
        let dim = 512;
        let delta = 0.1;
        let mut a = WorkerState::new(0, dim, 7);
        let mut b = WorkerState::new(0, dim, 7);
        let comp = TopK::new(delta);
        let mut rng = Rng::new(3);
        for t in 0..6usize {
            let g: Vec<f32> = (0..dim)
                .map(|i| rng.normal_f32() + (t + i) as f32 * 1e-6)
                .collect();
            a.grad_buffer().copy_from_slice(&g);
            a.push_gradient();
            b.grad_buffer().copy_from_slice(&g);
            b.push_gradient();
            let ka = a.pop_compress_cached(1, delta, false);
            let kb = b.pop_compress(1, &comp).map(|(sv, k)| {
                assert_eq!(Some(&sv), a.message());
                k
            });
            assert_eq!(ka, kb, "t={t}");
        }
        assert_eq!(a.compressor_cache_len(), 1);
    }

    #[test]
    fn message_cleared_while_pipeline_fills() {
        let mut w = WorkerState::new(0, 16, 2);
        w.grad_buffer().iter_mut().for_each(|v| *v = 1.0);
        w.push_gradient();
        assert_eq!(w.pop_compress_cached(0, 1.0, false), Some(16));
        assert!(w.message().is_some());
        // deepening τ stalls the pipeline: the stale message must vanish
        w.grad_buffer().iter_mut().for_each(|v| *v = 2.0);
        w.push_gradient();
        assert_eq!(w.pop_compress_cached(5, 1.0, false), None);
        assert!(w.message().is_none());
        assert_eq!(w.message_kept(), None);
    }

    #[test]
    fn tau_increase_stalls_then_resumes() {
        let dim = 4;
        let mut w = WorkerState::new(0, dim, 2);
        let comp = Identity;
        for t in 0..5usize {
            w.grad_buffer().iter_mut().for_each(|v| *v = t as f32);
            w.push_gradient();
            w.pop_compress(1, &comp);
        }
        // queue now holds 1 entry; raising τ to 4 stalls pops
        for t in 5..8usize {
            w.grad_buffer().iter_mut().for_each(|v| *v = t as f32);
            w.push_gradient();
            assert!(w.pop_compress(4, &comp).is_none() || t == 7);
        }
    }

    #[test]
    fn tau_decrease_drains_one_extra_per_step() {
        let dim = 4;
        let mut w = WorkerState::new(0, dim, 3);
        let comp = Identity;
        for t in 0..6usize {
            w.grad_buffer().iter_mut().for_each(|v| *v = t as f32);
            w.push_gradient();
            w.pop_compress(5, &comp); // deep queue: pops only once len > 5
        }
        // 6 pushes, one pop at t=5 (len hit 6 > τ=5)
        assert_eq!(w.queue_len(), 5);
        // τ drops to 2 mid-run: each step (push + pop) shrinks the queue by
        // exactly one — the drained extra is absorbed into EF, not lost
        for (step, want_len) in [(0usize, 4usize), (1, 3), (2, 2)] {
            w.grad_buffer().iter_mut().for_each(|v| *v = 10.0 + step as f32);
            w.push_gradient();
            assert!(w.pop_compress(2, &comp).is_some(), "step {step}");
            assert_eq!(w.queue_len(), want_len, "step {step}");
        }
        assert!(w.error_norm_sq() > 0.0, "drained mass parks in EF");
        // at the new depth the queue holds steady again
        w.grad_buffer().iter_mut().for_each(|v| *v = 20.0);
        w.push_gradient();
        assert!(w.pop_compress(2, &comp).is_some());
        assert_eq!(w.queue_len(), 2);
    }

    #[test]
    fn drained_gradient_mass_reemits_via_ef() {
        // total emitted mass over a τ decrease equals total pushed mass:
        // nothing is dropped, the extra pops come back through EF
        let dim = 4;
        let mut w = WorkerState::new(0, dim, 5);
        let comp = Identity;
        let mut pushed = 0.0f64;
        let mut emitted = 0.0f64;
        let mut step = |w: &mut WorkerState, tau: usize, val: f32| {
            w.grad_buffer().iter_mut().for_each(|v| *v = val);
            pushed += val as f64 * dim as f64;
            w.push_gradient();
            if let Some((sv, _)) = w.pop_compress(tau, &comp) {
                emitted += sv.decode().iter().map(|&v| v as f64).sum::<f64>();
            }
        };
        for t in 0..8 {
            step(&mut w, 4, 1.0 + t as f32);
        }
        for t in 8..20 {
            step(&mut w, 0, 1.0 + t as f32); // τ collapse: drains kick in
        }
        assert_eq!(w.queue_len(), 0);
        assert!(
            (pushed - emitted).abs() < 1e-3,
            "pushed {pushed} != emitted {emitted}"
        );
    }

    #[test]
    fn departure_drain_flushes_then_suspend_clears() {
        let dim = 8;
        let mut w = WorkerState::new(0, dim, 4);
        for t in 0..4usize {
            w.grad_buffer().iter_mut().for_each(|v| *v = t as f32);
            w.push_gradient();
            w.pop_compress_cached(3, 1.0, false);
        }
        assert_eq!(w.queue_len(), 3);
        // Drain policy: one in-flight gradient per call, FIFO order
        for want in 1..=3usize {
            let kept = w.drain_compress_cached(1.0, false);
            assert_eq!(kept, Some(dim));
            let msg = w.message().expect("drain emits");
            assert_eq!(msg.decode()[0], want as f32);
        }
        assert_eq!(w.queue_len(), 0);
        assert_eq!(w.drain_compress_cached(1.0, false), None);
        assert!(w.message().is_none(), "empty drain leaves no message");
        // Drop policy / departure: suspend clears the message, keeps EF
        w.grad_buffer().iter_mut().for_each(|v| *v = 9.0);
        w.push_gradient();
        w.pop_compress_cached(0, 0.5, false);
        assert!(w.message().is_some());
        let err = w.error_norm_sq();
        w.suspend();
        assert!(w.message().is_none());
        assert_eq!(w.error_norm_sq(), err, "EF retained for warm rejoin");
        assert_eq!(w.queue_len(), 0);
    }

    #[test]
    fn compression_applies_ef() {
        let dim = 1024;
        let mut w = WorkerState::new(0, dim, 4);
        let comp = TopK::new(0.1);
        let mut rng = Rng::new(9);
        for _ in 0..3 {
            let buf = w.grad_buffer();
            for v in buf.iter_mut() {
                *v = rng.normal_f32();
            }
            w.push_gradient();
            let (sv, kept) = w.pop_compress(0, &comp).unwrap();
            assert_eq!(kept, 103); // ceil(0.1 * 1024)
            assert_eq!(sv.nnz(), kept);
        }
        assert!(w.error_norm_sq() > 0.0, "EF must carry error");
    }
}
