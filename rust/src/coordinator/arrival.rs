//! Tournament tree over per-class sync arrivals.
//!
//! The virtual clock needs `argmax` over the arrivals of its timeline
//! classes every tick, with exactly the tie-breaking the historical O(n)
//! scan had: the *first strict maximum* in worker-index order. Keys are
//! `(tc, min_member)` — higher `tc` wins, ties go to the smaller minimum
//! member id — so the tree's winner is bit-for-bit the worker the old
//! per-worker loop would have picked. Updating one slot costs
//! O(log slots); the clock refreshes only the classes that transmitted,
//! which is what makes a 100k-worker tick O(changed classes · log C)
//! instead of O(n).

/// Slot key: (sync arrival TC, minimum member worker id of the class).
pub type ArrivalKey = (f64, u32);

/// The key of an empty / inactive slot: loses against every real arrival.
pub const EMPTY_KEY: ArrivalKey = (f64::NEG_INFINITY, u32::MAX);

/// `true` when `a` beats `b`: strictly later arrival, or the same arrival
/// from an earlier worker index.
fn beats(a: ArrivalKey, b: ArrivalKey) -> bool {
    a.0 > b.0 || (a.0 == b.0 && a.1 < b.1)
}

/// A flat segment tree (winner tree) over `slots` arrival keys.
#[derive(Clone, Debug)]
pub struct ArrivalTree {
    /// number of live slots (tree capacity is the next power of two)
    slots: usize,
    /// power-of-two leaf capacity
    cap: usize,
    /// per-slot keys, `EMPTY_KEY` beyond `slots`
    key: Vec<ArrivalKey>,
    /// internal nodes 1..cap: the winning *slot index* of each subtree
    /// (leaf `i` lives at tree position `cap + i`)
    win: Vec<u32>,
}

impl ArrivalTree {
    pub fn new(slots: usize) -> Self {
        let cap = slots.max(1).next_power_of_two();
        let mut t = Self {
            slots,
            cap,
            key: vec![EMPTY_KEY; cap],
            win: vec![0; cap],
        };
        t.rebuild();
        t
    }

    pub fn len(&self) -> usize {
        self.slots
    }

    pub fn is_empty(&self) -> bool {
        self.slots == 0
    }

    /// The winning slot of tree position `x` (internal node or leaf).
    fn slot_at(&self, x: usize) -> u32 {
        if x >= self.cap {
            (x - self.cap) as u32
        } else {
            self.win[x]
        }
    }

    fn rebuild(&mut self) {
        // bottom-up: internal nodes in decreasing index order see their
        // children (leaves or already-computed internals)
        for x in (1..self.cap).rev() {
            let (l, r) = (self.slot_at(2 * x), self.slot_at(2 * x + 1));
            self.win[x] = if beats(self.key[r as usize], self.key[l as usize])
            {
                r
            } else {
                l
            };
        }
    }

    /// Append one slot with `EMPTY_KEY` (a class split created a new
    /// class). Doubles the leaf capacity when full.
    pub fn push_slot(&mut self) {
        self.slots += 1;
        if self.slots > self.cap {
            self.cap *= 2;
            self.key.resize(self.cap, EMPTY_KEY);
            self.win = vec![0; self.cap];
            self.rebuild();
        }
    }

    /// Set `slot`'s key and repair the winner path in O(log cap).
    pub fn set(&mut self, slot: usize, key: ArrivalKey) {
        debug_assert!(slot < self.slots, "slot {slot} >= {}", self.slots);
        self.key[slot] = key;
        let mut x = (self.cap + slot) / 2;
        while x >= 1 {
            let (l, r) = (self.slot_at(2 * x), self.slot_at(2 * x + 1));
            self.win[x] = if beats(self.key[r as usize], self.key[l as usize])
            {
                r
            } else {
                l
            };
            x /= 2;
        }
    }

    /// The winning slot index (first strict max in min-member order).
    pub fn winner(&self) -> usize {
        self.slot_at(1) as usize
    }

    /// The winning slot's key.
    pub fn winner_key(&self) -> ArrivalKey {
        self.key[self.winner()]
    }

    /// `slot`'s current key.
    pub fn get(&self, slot: usize) -> ArrivalKey {
        self.key[slot]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The reference the tree must agree with: linear first-strict-max.
    fn scan(keys: &[ArrivalKey]) -> usize {
        let mut best = 0;
        for (i, &k) in keys.iter().enumerate() {
            if beats(k, keys[best]) {
                best = i;
            }
        }
        best
    }

    #[test]
    fn winner_matches_linear_scan_under_updates() {
        // deterministic pseudo-random walk over keys (no RNG dependency)
        let mut state = 0x9E3779B97F4A7C15u64;
        let mut step = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for slots in [1usize, 2, 3, 5, 8, 17, 64] {
            let mut tree = ArrivalTree::new(slots);
            let mut keys = vec![EMPTY_KEY; slots];
            for _ in 0..500 {
                let s = (step() % slots as u64) as usize;
                let tc = (step() % 1000) as f64 / 10.0;
                let m = (step() % 5) as u32;
                keys[s] = (tc, m);
                tree.set(s, (tc, m));
                assert_eq!(tree.winner(), scan(&keys));
            }
        }
    }

    #[test]
    fn ties_resolve_to_the_smallest_member() {
        let mut tree = ArrivalTree::new(4);
        tree.set(0, (5.0, 9));
        tree.set(1, (5.0, 2));
        tree.set(2, (5.0, 4));
        tree.set(3, (1.0, 0));
        assert_eq!(tree.winner(), 1);
        assert_eq!(tree.winner_key(), (5.0, 2));
        // a strictly later arrival beats any tie
        tree.set(3, (5.0000001, 99));
        assert_eq!(tree.winner(), 3);
    }

    #[test]
    fn push_slot_grows_past_the_initial_capacity() {
        let mut tree = ArrivalTree::new(2);
        tree.set(0, (1.0, 0));
        tree.set(1, (2.0, 1));
        for i in 0..10 {
            tree.push_slot();
            tree.set(2 + i, (3.0 + i as f64, (2 + i) as u32));
        }
        assert_eq!(tree.len(), 12);
        assert_eq!(tree.winner(), 11);
        // earlier keys survive the capacity doublings
        assert_eq!(tree.get(0), (1.0, 0));
        assert_eq!(tree.get(1), (2.0, 1));
    }

    #[test]
    fn empty_slots_never_win_against_real_arrivals() {
        let mut tree = ArrivalTree::new(8);
        tree.set(5, (0.0, 3));
        assert_eq!(tree.winner(), 5, "a zero arrival beats EMPTY_KEY");
        tree.set(5, EMPTY_KEY);
        // all empty again: the winner is just some empty slot
        assert_eq!(tree.winner_key(), EMPTY_KEY);
    }
}
