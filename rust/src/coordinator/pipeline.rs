//! The training loop — DD-EF-SGD (Algorithm 2) with pluggable strategy,
//! gradient oracle, and network. One instance = one training run.
//!
//! Per iteration t (1-based):
//! 1. ask the [`Strategy`] for (τ_t, δ_t) — DeCo-SGD refreshes from the
//!    monitor every E iterations here;
//! 2. every worker computes g_t at the *current* x_t (the paper's Fig. 2
//!    overlap: computation of step t runs while older messages are in
//!    flight), enqueues it, and
//! 3. pops g_{t−τ} and runs the fused EF + Top-k step, yielding the sparse
//!    Δ_t^i — steps 2+3 execute as ONE parallel phase over the worker pool,
//!    one worker per thread, since each [`WorkerState`] owns all the state
//!    its phase touches;
//! 4. the leader aggregates `x_{t+1} = x_t − γ/n Σ_i Δ_t^i` — sharded over
//!    the model dimension across the pool for large models, reducing every
//!    worker's message in fixed worker order per shard so the result is
//!    bit-identical to the serial reduction (DESIGN.md
//!    §Parallel-Execution);
//! 5. the virtual clock prices the iteration via the fabric-driven Eq. 19
//!    recurrence — every worker transmits over its own [`Fabric`] link and
//!    the aggregation completes at the slowest arrival — and each link's
//!    monitor observes its own transfer, feeding the next DeCo solve with
//!    the monitored bottleneck (or mean-link) view (DESIGN.md
//!    §Network-Fabric).
//!
//! Losses/gradients are *real* (PJRT or analytic oracle); only time is
//! virtual — see DESIGN.md §Hardware-Adaptation. The steady state is
//! allocation-free: compressors are cached per δ, and gradient + sparse
//! message buffers are recycled per worker (§Perf).

use super::{VirtualClock, WorkerState};
use crate::compress::{Compressor, CompressorCache};
use crate::deco::DecoInput;
use crate::elastic::{
    ChurnEvent, ChurnSpec, ChurnTimeline, DrainPolicy, MemberState, Membership,
};
use crate::metrics::{Record, RunResult};
use crate::netsim::{Fabric, FabricMonitor, Link};
use crate::optim::GradOracle;
use crate::strategy::{PlanBasis, Strategy, StrategyCtx};
use crate::util::stats::l2_norm;
use crate::util::WorkerPool;

/// Below this many total gradient elements (workers × dim) the worker phase
/// runs inline: spawning scoped threads costs more than the phase itself.
const PAR_MIN_WORK: usize = 1 << 14;

/// Minimum model dimension for sharded leader aggregation; smaller models
/// reduce serially (the reduction is a single memory-bound pass).
const SHARD_MIN_DIM: usize = 1 << 16;

/// Knobs for one training run.
#[derive(Clone, Debug)]
pub struct TrainParams {
    /// stepsize γ
    pub gamma: f32,
    pub max_iters: usize,
    /// full-loss evaluation cadence (iterations)
    pub log_every: usize,
    /// stop once the logged loss reaches this value
    pub loss_target: Option<f64>,
    /// stop once the virtual clock passes this (s)
    pub max_virtual_time: Option<f64>,
    /// pin the per-iteration compute time instead of measuring wall time
    pub t_comp_override: Option<f64>,
    /// pin the gradient size (bits) — lets small proxy models be priced at
    /// paper scale (e.g. GPT-2's 124M × 32 bits)
    pub s_g_override: Option<f64>,
    /// paper's wire accounting (δ·S_g bits) instead of the COO codec size
    pub paper_wire: bool,
    /// use the blockwise (L1-kernel-identical) compressor instead of global
    /// top-k
    pub block_topk: bool,
    /// global-norm gradient clipping applied per worker before EF (standard
    /// transformer practice; keeps aggressive (δ, τ) inside the stable
    /// region at practical stepsizes)
    pub clip_norm: Option<f64>,
    pub seed: u64,
    /// network priors used before the monitor has samples
    pub fallback: DecoInput,
    pub monitor_alpha: f64,
    /// which aggregate of the per-link monitors the strategy plans on:
    /// the bottleneck `(min a, max b)` (default — the pair that gates the
    /// synchronous aggregation) or the heterogeneity-blind mean link (the
    /// `exp hetero` control arm). Identical on a homogeneous fabric.
    pub plan: PlanBasis,
    /// worker-pool size; `None` = machine default
    /// ([`WorkerPool::default_threads`]), `Some(1)` = fully serial. With
    /// `t_comp_override` pinned, results are bit-identical at every
    /// setting; with measured compute time they differ exactly as much as
    /// wall-clock timing does (DESIGN.md §Parallel-Execution).
    pub threads: Option<usize>,
    /// churn schedule (elastic subsystem, DESIGN.md §Elasticity).
    /// `ChurnSpec::None` — the default — keeps the run bit-identical to a
    /// fabric-only run, serial and pooled (`tests/elastic.rs`).
    pub churn: ChurnSpec,
    /// what happens to a leaving worker's in-flight delayed gradients
    pub drain: DrainPolicy,
}

impl Default for TrainParams {
    fn default() -> Self {
        Self {
            gamma: 0.05,
            max_iters: 500,
            log_every: 10,
            loss_target: None,
            max_virtual_time: None,
            t_comp_override: None,
            s_g_override: None,
            paper_wire: true,
            block_topk: false,
            clip_norm: None,
            seed: 0,
            fallback: DecoInput { s_g: 1e9, a: 1e8, b: 0.1, t_comp: 0.1 },
            monitor_alpha: 0.3,
            plan: PlanBasis::Bottleneck,
            threads: None,
            churn: ChurnSpec::None,
            drain: DrainPolicy::Drop,
        }
    }
}

pub struct TrainLoop<O: GradOracle> {
    oracle: O,
    strategy: Box<dyn Strategy>,
    clock: VirtualClock,
    monitor: FabricMonitor,
    workers: Vec<WorkerState>,
    /// the global model (flat, padded)
    x: Vec<f32>,
    agg: Vec<f32>,
    pool: WorkerPool,
    /// leader-side compressor cache, used only for honest wire accounting
    wire_comps: CompressorCache,
    params: TrainParams,
    /// gradient bits at δ=1
    s_g: f64,
    /// elastic membership state machine (all-active forever on a static run)
    membership: Membership,
    /// Active|Draining mask — the workers the clock prices and the per-link
    /// monitors observe; kept in lockstep with `membership`
    member_mask: Vec<bool>,
    /// compiled churn schedule (fault windows are already baked into the
    /// clock's fabric; membership events fire as the clock passes them)
    churn: ChurnTimeline,
    churn_cursor: usize,
    /// fault-window close times, each an epoch bump for re-planning
    window_ends: Vec<f64>,
    window_cursor: usize,
}

impl<O: GradOracle> TrainLoop<O> {
    /// Single shared link for all workers — the homogeneous compatibility
    /// constructor: builds an n-way replicated [`Fabric`], which prices
    /// bit-identically to the former single-link path.
    pub fn new(
        oracle: O,
        strategy: Box<dyn Strategy>,
        link: Link,
        params: TrainParams,
    ) -> Self {
        let n = oracle.workers();
        Self::with_fabric(oracle, strategy, Fabric::replicate(link, n), params)
    }

    /// One [`Fabric`] link per worker — the general heterogeneous form.
    /// Panics on an invalid churn spec (programmatic misuse, like the
    /// fabric/worker-count asserts); config-driven callers should use
    /// [`Self::try_with_fabric`] to surface the error instead.
    pub fn with_fabric(
        oracle: O,
        strategy: Box<dyn Strategy>,
        fabric: Fabric,
        params: TrainParams,
    ) -> Self {
        Self::try_with_fabric(oracle, strategy, fabric, params)
            .expect("invalid churn spec")
    }

    /// [`Self::with_fabric`] that surfaces an invalid `params.churn` as an
    /// error — the path for specs that came from user configs.
    pub fn try_with_fabric(
        oracle: O,
        strategy: Box<dyn Strategy>,
        mut fabric: Fabric,
        params: TrainParams,
    ) -> anyhow::Result<Self> {
        let dim = oracle.dim();
        let n = oracle.workers();
        assert_eq!(
            fabric.workers(),
            n,
            "fabric must have exactly one link per worker"
        );
        let x = oracle.init();
        assert_eq!(x.len(), dim);
        let workers = (0..n)
            .map(|i| WorkerState::new(i, dim, params.seed ^ 0x77))
            .collect();
        let s_g = params.s_g_override.unwrap_or(dim as f64 * 32.0);
        let monitor = FabricMonitor::new(n, params.monitor_alpha, params.seed);
        let pool = match params.threads {
            Some(t) => WorkerPool::new(t),
            None => WorkerPool::with_default_parallelism(),
        };
        let churn = params.churn.compile(n)?;
        churn.bake_windows(&mut fabric);
        let window_ends = churn.window_ends();
        Ok(Self {
            oracle,
            strategy,
            clock: VirtualClock::new(fabric),
            monitor,
            workers,
            x,
            agg: vec![0.0; dim],
            pool,
            wire_comps: CompressorCache::new(),
            params,
            s_g,
            membership: Membership::new(n),
            member_mask: vec![true; n],
            churn,
            churn_cursor: 0,
            window_ends,
            window_cursor: 0,
        })
    }

    pub fn model(&self) -> &[f32] {
        &self.x
    }

    pub fn monitor(&self) -> &FabricMonitor {
        &self.monitor
    }

    /// The virtual clock (per-worker timelines, sync arrivals).
    pub fn clock(&self) -> &VirtualClock {
        &self.clock
    }

    /// Pool size this loop runs its phases on.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Elastic membership state (all-active forever on a static run).
    pub fn membership(&self) -> &Membership {
        &self.membership
    }

    /// Depart `worker` immediately under `policy`. Apply BEFORE pricing —
    /// the churn driver calls this; exposed for tests and external drivers.
    fn depart(&mut self, worker: usize, drain: DrainPolicy) {
        let flush =
            drain == DrainPolicy::Drain && self.workers[worker].queue_len() > 0;
        self.membership.leave(worker, flush);
        if !flush {
            // Drop policy (or nothing in flight): fully departed now
            self.workers[worker].suspend();
            self.member_mask[worker] = false;
            self.monitor.set_active(worker, false);
        }
    }

    /// Fire every churn event whose virtual time the clock has passed.
    /// Membership transitions apply here; fault windows were baked into
    /// the fabric at construction, so their start/end only bump the epoch
    /// (event-triggered strategies re-plan on it).
    fn apply_churn_events(&mut self) {
        if self.churn.is_empty() {
            return;
        }
        let now = self.clock.now();
        loop {
            let Some(ev) = self.churn.events().get(self.churn_cursor) else {
                break;
            };
            if ev.t > now {
                break;
            }
            let event = ev.event.clone();
            self.churn_cursor += 1;
            match event {
                ChurnEvent::Leave { worker } => {
                    self.depart(worker, self.params.drain);
                }
                ChurnEvent::Rejoin { worker } => {
                    self.membership.rejoin(worker);
                    self.member_mask[worker] = true;
                    self.monitor.set_active(worker, true);
                }
                ChurnEvent::LinkOutage { .. }
                | ChurnEvent::LinkDegrade { .. } => {
                    self.membership.bump();
                }
            }
        }
        while self.window_cursor < self.window_ends.len()
            && self.window_ends[self.window_cursor] <= now
        {
            self.membership.bump();
            self.window_cursor += 1;
        }
    }

    /// Run to completion. `task` labels the result.
    pub fn run(&mut self, task: &str) -> RunResult {
        let n = self.workers.len();
        let dim = self.x.len();
        let mut records = Vec::new();
        let mut last_grad_norm: Option<f64> = None;
        let method = self.strategy.name().to_string();
        let serial = WorkerPool::serial();
        let par_workers = self.pool.threads() > 1 && n * dim >= PAR_MIN_WORK;
        let par_shards = self.pool.threads() > 1 && dim >= SHARD_MIN_DIM;

        for t in 1..=self.params.max_iters {
            // 0. elastic: fire churn events the virtual clock has passed,
            // so the strategy already sees the new membership epoch
            self.apply_churn_events();

            // 1. strategy decides (τ_t, δ_t)
            let ctx = StrategyCtx {
                iter: t,
                monitor: &self.monitor,
                s_g: self.s_g,
                grad_norm: last_grad_norm,
                fallback: self.params.fallback,
                plan: self.params.plan,
                membership_epoch: self.membership.epoch(),
                active_workers: self.membership.active_count(),
            };
            let (tau, delta) = self.strategy.params(&ctx);

            // 2+3. worker phase, fanned out over the pool: gradient at x_t,
            // clip, enqueue; pop g_{t−τ}, EF + compress into the recycled
            // per-worker message. Safe to parallelize: each WorkerState
            // owns its EF vector, queue, RNG, scratch, and compressor cache.
            // Draining workers flush one in-flight gradient instead of
            // computing; departed workers sit out (their state is retained
            // for a warm rejoin — DESIGN.md §Elasticity).
            {
                let oracle = &self.oracle;
                let x = &self.x[..];
                let clip = self.params.clip_norm;
                let block_topk = self.params.block_topk;
                let membership = &self.membership;
                let pool = if par_workers { &self.pool } else { &serial };
                pool.for_each_chunk_mut(&mut self.workers, |_, chunk| {
                    for ws in chunk.iter_mut() {
                        let state = membership.state(ws.id);
                        if state == MemberState::Departed {
                            continue;
                        }
                        if state == MemberState::Draining {
                            ws.comp_secs = 0.0;
                            let _ = ws.drain_compress_cached(delta, block_topk);
                            continue;
                        }
                        let wall = std::time::Instant::now();
                        let loss = oracle.grad(ws.id, t, x, ws.grad_buffer());
                        ws.comp_secs = wall.elapsed().as_secs_f64();
                        let norm = l2_norm(ws.grad_buffer());
                        ws.last_loss = loss;
                        ws.last_grad_norm = norm;
                        if let Some(clip) = clip {
                            if norm > clip {
                                let s = (clip / norm) as f32;
                                ws.grad_buffer()
                                    .iter_mut()
                                    .for_each(|v| *v *= s);
                            }
                        }
                        ws.push_gradient();
                        let _ = ws.pop_compress_cached(tau, delta, block_topk);
                    }
                });
            }

            // leader reduction of the phase outputs, in fixed worker order
            // so the f64 sums are bit-identical at any pool size; loss /
            // norm / compute averages run over the *active* set, messages
            // (incl. draining flushes) aggregate over the member set
            let mut loss_acc = 0.0f64;
            let mut norm_acc = 0.0f64;
            let mut comp_acc = 0.0f64;
            let mut kept_total = 0usize;
            let mut any = false;
            for ws in &self.workers {
                if self.membership.is_active(ws.id) {
                    loss_acc += ws.last_loss;
                    norm_acc += ws.last_grad_norm;
                    comp_acc += ws.comp_secs;
                }
                if let Some(kept) = ws.message_kept() {
                    kept_total += kept;
                    any = true;
                }
            }
            let n_active = self.membership.active_count();
            let n_members = self.membership.member_count();
            let t_comp = self
                .params
                .t_comp_override
                .unwrap_or(comp_acc / n_active as f64);
            last_grad_norm = Some(norm_acc / n_active as f64);
            let train_loss = loss_acc / n_active as f64;

            // 4. aggregate + apply: sharded across the pool for large
            // models (ascending COO indices make shard boundaries two
            // binary searches), serial otherwise — identical arithmetic.
            // The γ/n average runs over the members whose gradient shares
            // this iteration carries (= n on a static run).
            if any {
                let gamma = self.params.gamma;
                let scale = 1.0 / n_members as f32;
                let workers = &self.workers;
                let pool = if par_shards { &self.pool } else { &serial };
                pool.zip_chunk_mut(
                    &mut self.agg,
                    &mut self.x,
                    |start, agg_s, x_s| {
                        agg_s.iter_mut().for_each(|v| *v = 0.0);
                        for ws in workers {
                            if let Some(sv) = ws.message() {
                                sv.add_shard_into_scaled(
                                    start as u32,
                                    agg_s,
                                    scale,
                                );
                            }
                        }
                        for (xi, ai) in x_s.iter_mut().zip(agg_s.iter()) {
                            *xi -= gamma * *ai;
                        }
                    },
                );
            }

            // 5. price the iteration over the member set and feed the
            // monitor (departed workers neither transmit nor observe)
            let bits = if self.params.paper_wire {
                (delta.min(1.0) * self.s_g) as u64
            } else {
                // honest wire accounting (COO indices, quantized payloads,
                // headers), averaged over members and scaled from the proxy
                // model's dimension up to the pinned paper-scale S_g
                let comp: &dyn Compressor =
                    self.wire_comps.get(delta, self.params.block_topk);
                let proxy_bits =
                    comp.wire_bits(kept_total / n_members.max(1), dim);
                let scale = self.s_g / (dim as f64 * 32.0);
                (proxy_bits as f64 * scale) as u64
            };
            let tick = self.clock.tick_members(
                t_comp,
                tau,
                bits,
                Some(&self.member_mask),
            );
            // each member's link monitor observes its own transfer and
            // latency — on a static homogeneous fabric every estimator sees
            // the same stream the former single monitor did
            if bits > 0 {
                for (i, wt) in self.clock.worker_ticks().iter().enumerate() {
                    if self.member_mask[i] && wt.tx_secs > 0.0 {
                        self.monitor.observe_transfer(i, bits, wt.tx_secs);
                    }
                }
            }
            for (i, link) in self.clock.fabric().links().iter().enumerate() {
                if self.member_mask[i] {
                    self.monitor.observe_latency_for(i, link.latency());
                }
            }
            self.monitor.observe_compute(t_comp);

            // a draining worker whose pipeline just emptied departs fully —
            // after the tick that priced its final message
            for w in 0..n {
                if self.membership.state(w) == MemberState::Draining
                    && self.workers[w].queue_len() == 0
                {
                    self.membership.finish_drain(w);
                    self.workers[w].suspend();
                    self.member_mask[w] = false;
                    self.monitor.set_active(w, false);
                }
            }

            // 6. metrics + stopping. The average training loss doubles as a
            // divergence guard: a strategy whose (δ, τ) violates the
            // stepsize condition blows up, and the per-iteration train loss
            // catches it *between* log_every boundaries instead of pricing
            // garbage iterations until the next full evaluation.
            let diverged = !train_loss.is_finite();
            if t % self.params.log_every == 0
                || t == self.params.max_iters
                || diverged
            {
                let loss = self.oracle.loss(&self.x);
                records.push(Record {
                    iter: t,
                    time: tick.tc,
                    loss,
                    train_loss,
                    tau,
                    delta,
                    grad_norm: last_grad_norm.unwrap_or(0.0),
                    bandwidth: self.monitor.bandwidth().unwrap_or(0.0),
                });
                if let Some(target) = self.params.loss_target {
                    if loss <= target {
                        break;
                    }
                }
                if diverged || !loss.is_finite() {
                    break;
                }
            }
            if let Some(tmax) = self.params.max_virtual_time {
                if self.clock.now() >= tmax {
                    break;
                }
            }
        }

        RunResult {
            method,
            task: task.to_string(),
            workers: n,
            total_time: self.clock.now(),
            total_iters: self.clock.iters(),
            records,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::BandwidthTrace;
    use crate::optim::Quadratic;
    use crate::strategy::StrategyKind;

    // Stability note: Theorem 1's stepsize condition γ ≤ 1/(4L√(φ/δ))
    // genuinely binds — aggressive (δ, τ) with a large γ diverges on the
    // quadratic. Tests therefore run in the stable regime (small L, small γ)
    // and the experiments pick per-task γ the same way the paper tunes lr.
    const S_G: f64 = 1e8; // bits
    const T_COMP: f64 = 0.2;

    fn quad() -> Quadratic {
        Quadratic::new(256, 4, 1.0, 0.2, 0.3, 0.3, 11)
    }

    fn link(bps: f64, lat: f64) -> Link {
        Link::new(BandwidthTrace::constant(bps), lat)
    }

    fn params() -> TrainParams {
        TrainParams {
            gamma: 0.005,
            max_iters: 4000,
            log_every: 25,
            t_comp_override: Some(T_COMP),
            s_g_override: Some(S_G),
            fallback: DecoInput { s_g: S_G, a: 2e7, b: 0.2, t_comp: T_COMP },
            ..Default::default()
        }
    }

    #[test]
    fn all_strategies_converge_on_quadratic() {
        let l0 = {
            let q = quad();
            let x = q.init();
            q.loss(&x)
        };
        for kind in StrategyKind::paper_baselines() {
            let mut tl =
                TrainLoop::new(quad(), kind.build(), link(2e7, 0.2), params());
            let res = tl.run("quad");
            assert!(
                res.final_loss() < 0.7 * l0,
                "{}: {} -> {}",
                kind.label(),
                l0,
                res.final_loss()
            );
        }
    }

    #[test]
    fn dsgd_time_matches_serial_model() {
        // D-SGD: every iteration costs T_comp + S_g/a + b on the virtual
        // clock
        let mut tl = TrainLoop::new(
            quad(),
            StrategyKind::DSgd.build(),
            link(2e7, 0.2),
            TrainParams { max_iters: 50, ..params() },
        );
        let res = tl.run("quad");
        let per_iter = T_COMP + S_G / 2e7 + 0.2;
        assert!(
            (res.total_time - 50.0 * per_iter).abs() / (50.0 * per_iter)
                < 1e-6,
            "{} vs {}",
            res.total_time,
            50.0 * per_iter
        );
    }

    #[test]
    fn deco_is_faster_than_dsgd_to_same_loss() {
        // the paper's headline, miniature: same loss target, DeCo-SGD needs
        // less virtual time than D-SGD under WAN conditions
        let l0 = {
            let q = quad();
            let x = q.init();
            q.loss(&x)
        };
        let target = 0.6 * l0;
        let run = |kind: StrategyKind| {
            let mut tl = TrainLoop::new(
                quad(),
                kind.build(),
                link(2e7, 0.2),
                TrainParams { loss_target: Some(target), ..params() },
            );
            tl.run("quad")
        };
        let dsgd = run(StrategyKind::DSgd);
        let deco = run(StrategyKind::DecoSgd { update_every: 20 });
        let t_dsgd = dsgd.time_to_loss(target).expect("dsgd reaches");
        let t_deco = deco.time_to_loss(target).expect("deco reaches");
        assert!(
            t_deco < t_dsgd,
            "deco {t_deco} should beat dsgd {t_dsgd}"
        );
    }

    #[test]
    fn records_are_monotone_in_time() {
        let mut tl = TrainLoop::new(
            quad(),
            StrategyKind::DecoSgd { update_every: 10 }.build(),
            link(5e6, 0.3),
            TrainParams { max_iters: 100, ..params() },
        );
        let res = tl.run("quad");
        for w in res.records.windows(2) {
            assert!(w[1].time > w[0].time);
            assert!(w[1].iter > w[0].iter);
        }
        assert!(res.total_iters <= 100);
    }

    #[test]
    fn records_carry_finite_train_loss() {
        let mut tl = TrainLoop::new(
            quad(),
            StrategyKind::DecoSgd { update_every: 10 }.build(),
            link(2e7, 0.2),
            TrainParams { max_iters: 100, ..params() },
        );
        let res = tl.run("quad");
        assert!(!res.records.is_empty());
        for r in &res.records {
            assert!(r.train_loss.is_finite());
            assert!(r.train_loss > 0.0, "quadratic losses are positive");
        }
    }

    #[test]
    fn divergence_guard_trips_between_log_boundaries() {
        // γ far above the Theorem 1 bound with aggressive (δ, τ): the run
        // must stop at the first non-finite train loss even though
        // log_every would only evaluate at iteration 4000
        let mut tl = TrainLoop::new(
            Quadratic::new(256, 4, 8.0, 0.2, 0.3, 0.3, 11),
            StrategyKind::DEfSgd { delta: 0.01 }.build(),
            link(2e7, 0.2),
            TrainParams {
                gamma: 5.0,
                max_iters: 4000,
                log_every: 4000,
                ..params()
            },
        );
        let res = tl.run("quad");
        assert!(
            res.total_iters < 4000,
            "guard never tripped: ran {} iters",
            res.total_iters
        );
        let last = res.records.last().expect("divergence record");
        assert!(!last.train_loss.is_finite() || !last.loss.is_finite());
    }
}
