//! The training loop — DD-EF-SGD (Algorithm 2) with pluggable strategy,
//! gradient oracle, and network. One instance = one training run.
//!
//! Per iteration t (1-based):
//! 1. ask the [`Strategy`] for (τ_t, δ_t) — DeCo-SGD refreshes from the
//!    monitor every E iterations here;
//! 2. every worker computes g_t at the *current* x_t (the paper's Fig. 2
//!    overlap: computation of step t runs while older messages are in
//!    flight) and enqueues it;
//! 3. every worker pops g_{t−τ}, runs the fused EF + Top-k step, yielding
//!    the sparse Δ_t^i;
//! 4. the leader aggregates `x_{t+1} = x_t − γ/n Σ_i Δ_t^i`;
//! 5. the virtual clock prices the iteration via the Eq. 19 recurrence over
//!    the bandwidth trace; the monitor observes the transfer and feeds the
//!    next DeCo solve.
//!
//! Losses/gradients are *real* (PJRT or analytic oracle); only time is
//! virtual — see DESIGN.md §Hardware-Adaptation.

use super::{VirtualClock, WorkerState};
use crate::compress::{BlockTopK, Compressor, Identity, TopK};
use crate::deco::DecoInput;
use crate::metrics::{Record, RunResult};
use crate::netsim::{Link, NetworkMonitor};
use crate::optim::GradOracle;
use crate::strategy::{Strategy, StrategyCtx};
use crate::util::stats::l2_norm;

/// Knobs for one training run.
#[derive(Clone, Debug)]
pub struct TrainParams {
    /// stepsize γ
    pub gamma: f32,
    pub max_iters: usize,
    /// full-loss evaluation cadence (iterations)
    pub log_every: usize,
    /// stop once the logged loss reaches this value
    pub loss_target: Option<f64>,
    /// stop once the virtual clock passes this (s)
    pub max_virtual_time: Option<f64>,
    /// pin the per-iteration compute time instead of measuring wall time
    pub t_comp_override: Option<f64>,
    /// pin the gradient size (bits) — lets small proxy models be priced at
    /// paper scale (e.g. GPT-2's 124M × 32 bits)
    pub s_g_override: Option<f64>,
    /// paper's wire accounting (δ·S_g bits) instead of the COO codec size
    pub paper_wire: bool,
    /// use the blockwise (L1-kernel-identical) compressor instead of global
    /// top-k
    pub block_topk: bool,
    /// global-norm gradient clipping applied per worker before EF (standard
    /// transformer practice; keeps aggressive (δ, τ) inside the stable
    /// region at practical stepsizes)
    pub clip_norm: Option<f64>,
    pub seed: u64,
    /// network priors used before the monitor has samples
    pub fallback: DecoInput,
    pub monitor_alpha: f64,
}

impl Default for TrainParams {
    fn default() -> Self {
        Self {
            gamma: 0.05,
            max_iters: 500,
            log_every: 10,
            loss_target: None,
            max_virtual_time: None,
            t_comp_override: None,
            s_g_override: None,
            paper_wire: true,
            block_topk: false,
            clip_norm: None,
            seed: 0,
            fallback: DecoInput { s_g: 1e9, a: 1e8, b: 0.1, t_comp: 0.1 },
            monitor_alpha: 0.3,
        }
    }
}

pub struct TrainLoop<O: GradOracle> {
    oracle: O,
    strategy: Box<dyn Strategy>,
    clock: VirtualClock,
    monitor: NetworkMonitor,
    workers: Vec<WorkerState>,
    /// the global model (flat, padded)
    x: Vec<f32>,
    agg: Vec<f32>,
    params: TrainParams,
    /// gradient bits at δ=1
    s_g: f64,
}

impl<O: GradOracle> TrainLoop<O> {
    pub fn new(
        oracle: O,
        strategy: Box<dyn Strategy>,
        link: Link,
        params: TrainParams,
    ) -> Self {
        let dim = oracle.dim();
        let n = oracle.workers();
        let x = oracle.init();
        assert_eq!(x.len(), dim);
        let workers = (0..n)
            .map(|i| WorkerState::new(i, dim, params.seed ^ 0x77))
            .collect();
        let s_g = params.s_g_override.unwrap_or(dim as f64 * 32.0);
        let monitor = NetworkMonitor::new(params.monitor_alpha);
        Self {
            oracle,
            strategy,
            clock: VirtualClock::new(link),
            monitor,
            workers,
            x,
            agg: vec![0.0; dim],
            params,
            s_g,
        }
    }

    pub fn model(&self) -> &[f32] {
        &self.x
    }

    pub fn monitor(&self) -> &NetworkMonitor {
        &self.monitor
    }

    fn make_compressor(&self, delta: f64) -> Box<dyn Compressor> {
        if delta >= 1.0 {
            Box::new(Identity)
        } else if self.params.block_topk {
            Box::new(BlockTopK::new(delta))
        } else {
            Box::new(TopK::new(delta))
        }
    }

    /// Run to completion. `task`/`method` label the result.
    pub fn run(&mut self, task: &str) -> RunResult {
        let n = self.workers.len();
        let mut records = Vec::new();
        let mut last_grad_norm: Option<f64> = None;
        let method = self.strategy.name().to_string();

        for t in 1..=self.params.max_iters {
            // 1. strategy decides (τ_t, δ_t)
            let ctx = StrategyCtx {
                iter: t,
                monitor: &self.monitor,
                s_g: self.s_g,
                grad_norm: last_grad_norm,
                fallback: self.params.fallback,
            };
            let (tau, delta) = self.strategy.params(&ctx);
            let comp = self.make_compressor(delta);

            // 2. compute gradients at x_t on every worker
            let wall0 = std::time::Instant::now();
            let mut norm_acc = 0.0f64;
            let mut loss_acc = 0.0f64;
            for w in 0..n {
                let ws = &mut self.workers[w];
                let loss =
                    self.oracle.grad(w, t, &self.x, ws.grad_buffer());
                loss_acc += loss;
                let norm = l2_norm(ws.grad_buffer());
                norm_acc += norm;
                if let Some(clip) = self.params.clip_norm {
                    if norm > clip {
                        let s = (clip / norm) as f32;
                        ws.grad_buffer().iter_mut().for_each(|v| *v *= s);
                    }
                }
                ws.push_gradient();
            }
            let measured =
                wall0.elapsed().as_secs_f64() / n as f64; // per-worker
            let t_comp = self.params.t_comp_override.unwrap_or(measured);
            last_grad_norm = Some(norm_acc / n as f64);
            let _ = loss_acc;

            // 3. pop + EF-compress; 4. aggregate
            self.agg.iter_mut().for_each(|v| *v = 0.0);
            let mut any = false;
            let mut kept_total = 0usize;
            for ws in self.workers.iter_mut() {
                if let Some((sv, kept)) = ws.pop_compress(tau, comp.as_ref())
                {
                    sv.add_into_scaled(&mut self.agg, 1.0 / n as f32);
                    kept_total += kept;
                    any = true;
                }
            }
            if any {
                let gamma = self.params.gamma;
                for (xi, ai) in self.x.iter_mut().zip(&self.agg) {
                    *xi -= gamma * ai;
                }
            }

            // 5. price the iteration and feed the monitor
            let bits = if self.params.paper_wire {
                (delta.min(1.0) * self.s_g) as u64
            } else {
                // honest wire accounting (COO indices, quantized payloads,
                // headers), averaged over workers and scaled from the proxy
                // model's dimension up to the pinned paper-scale S_g
                let proxy_bits =
                    comp.wire_bits(kept_total / n.max(1), self.x.len());
                let scale = self.s_g / (self.x.len() as f64 * 32.0);
                (proxy_bits as f64 * scale) as u64
            };
            let tick = self.clock.tick(t_comp, tau, bits);
            if bits > 0 && tick.tx_secs > 0.0 {
                self.monitor.observe_transfer(bits, tick.tx_secs);
            }
            self.monitor.observe_latency(self.clock.link().latency());
            self.monitor.observe_compute(t_comp);

            // 6. metrics + stopping
            if t % self.params.log_every == 0 || t == self.params.max_iters {
                let loss = self.oracle.loss(&self.x);
                records.push(Record {
                    iter: t,
                    time: tick.tc,
                    loss,
                    tau,
                    delta,
                    grad_norm: last_grad_norm.unwrap_or(0.0),
                    bandwidth: self.monitor.bandwidth().unwrap_or(0.0),
                });
                if let Some(target) = self.params.loss_target {
                    if loss <= target {
                        break;
                    }
                }
                // divergence guard: a strategy whose (δ, τ) violates the
                // stepsize condition can blow up — stop pricing iterations
                // once the loss is no longer finite
                if !loss.is_finite() {
                    break;
                }
            }
            if let Some(tmax) = self.params.max_virtual_time {
                if self.clock.now() >= tmax {
                    break;
                }
            }
        }

        RunResult {
            method,
            task: task.to_string(),
            workers: n,
            total_time: self.clock.now(),
            total_iters: self.clock.iters(),
            records,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::BandwidthTrace;
    use crate::optim::Quadratic;
    use crate::strategy::StrategyKind;

    // Stability note: Theorem 1's stepsize condition γ ≤ 1/(4L√(φ/δ))
    // genuinely binds — aggressive (δ, τ) with a large γ diverges on the
    // quadratic. Tests therefore run in the stable regime (small L, small γ)
    // and the experiments pick per-task γ the same way the paper tunes lr.
    const S_G: f64 = 1e8; // bits
    const T_COMP: f64 = 0.2;

    fn quad() -> Quadratic {
        Quadratic::new(256, 4, 1.0, 0.2, 0.3, 0.3, 11)
    }

    fn link(bps: f64, lat: f64) -> Link {
        Link::new(BandwidthTrace::constant(bps), lat)
    }

    fn params() -> TrainParams {
        TrainParams {
            gamma: 0.005,
            max_iters: 4000,
            log_every: 25,
            t_comp_override: Some(T_COMP),
            s_g_override: Some(S_G),
            fallback: DecoInput { s_g: S_G, a: 2e7, b: 0.2, t_comp: T_COMP },
            ..Default::default()
        }
    }

    #[test]
    fn all_strategies_converge_on_quadratic() {
        let l0 = {
            let mut q = quad();
            let x = q.init();
            q.loss(&x)
        };
        for kind in StrategyKind::paper_baselines() {
            let mut tl =
                TrainLoop::new(quad(), kind.build(), link(2e7, 0.2), params());
            let res = tl.run("quad");
            assert!(
                res.final_loss() < 0.7 * l0,
                "{}: {} -> {}",
                kind.label(),
                l0,
                res.final_loss()
            );
        }
    }

    #[test]
    fn dsgd_time_matches_serial_model() {
        // D-SGD: every iteration costs T_comp + S_g/a + b on the virtual
        // clock
        let mut tl = TrainLoop::new(
            quad(),
            StrategyKind::DSgd.build(),
            link(2e7, 0.2),
            TrainParams { max_iters: 50, ..params() },
        );
        let res = tl.run("quad");
        let per_iter = T_COMP + S_G / 2e7 + 0.2;
        assert!(
            (res.total_time - 50.0 * per_iter).abs() / (50.0 * per_iter)
                < 1e-6,
            "{} vs {}",
            res.total_time,
            50.0 * per_iter
        );
    }

    #[test]
    fn deco_is_faster_than_dsgd_to_same_loss() {
        // the paper's headline, miniature: same loss target, DeCo-SGD needs
        // less virtual time than D-SGD under WAN conditions
        let l0 = {
            let mut q = quad();
            let x = q.init();
            q.loss(&x)
        };
        let target = 0.6 * l0;
        let run = |kind: StrategyKind| {
            let mut tl = TrainLoop::new(
                quad(),
                kind.build(),
                link(2e7, 0.2),
                TrainParams { loss_target: Some(target), ..params() },
            );
            tl.run("quad")
        };
        let dsgd = run(StrategyKind::DSgd);
        let deco = run(StrategyKind::DecoSgd { update_every: 20 });
        let t_dsgd = dsgd.time_to_loss(target).expect("dsgd reaches");
        let t_deco = deco.time_to_loss(target).expect("deco reaches");
        assert!(
            t_deco < t_dsgd,
            "deco {t_deco} should beat dsgd {t_dsgd}"
        );
    }

    #[test]
    fn records_are_monotone_in_time() {
        let mut tl = TrainLoop::new(
            quad(),
            StrategyKind::DecoSgd { update_every: 10 }.build(),
            link(5e6, 0.3),
            TrainParams { max_iters: 100, ..params() },
        );
        let res = tl.run("quad");
        for w in res.records.windows(2) {
            assert!(w[1].time > w[0].time);
            assert!(w[1].iter > w[0].iter);
        }
        assert!(res.total_iters <= 100);
    }
}
