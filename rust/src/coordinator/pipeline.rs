//! The training loop — DD-EF-SGD (Algorithm 2) with pluggable strategy,
//! gradient oracle, and network. One instance = one training run.
//!
//! Per iteration t (1-based):
//! 1. ask the [`Strategy`] for (τ_t, δ_t) — DeCo-SGD refreshes from the
//!    monitor every E iterations here;
//! 2. every worker computes g_t at the *current* x_t (the paper's Fig. 2
//!    overlap: computation of step t runs while older messages are in
//!    flight), enqueues it, and
//! 3. pops g_{t−τ} and runs the fused EF + Top-k step, yielding the sparse
//!    Δ_t^i — steps 2+3 execute as ONE parallel phase over the worker pool,
//!    one worker per thread, since each [`WorkerState`] owns all the state
//!    its phase touches;
//! 4. the leader aggregates `x_{t+1} = x_t − γ/n Σ_i Δ_t^i` — sharded over
//!    the model dimension across the pool for large models, reducing every
//!    worker's message in fixed worker order per shard so the result is
//!    bit-identical to the serial reduction (DESIGN.md
//!    §Parallel-Execution);
//! 5. the virtual clock prices the iteration via the fabric-driven Eq. 19
//!    recurrence — every worker transmits over its own [`Fabric`] link and
//!    the aggregation completes at the slowest arrival — and each link's
//!    monitor observes its own transfer, feeding the next DeCo solve with
//!    the monitored bottleneck (or mean-link) view (DESIGN.md
//!    §Network-Fabric).
//!
//! Losses/gradients are *real* (PJRT or analytic oracle); only time is
//! virtual — see DESIGN.md §Hardware-Adaptation. The steady state is
//! allocation-free: compressors are cached per δ, and gradient + sparse
//! message buffers are recycled per worker (§Perf).

use super::{Tick, VirtualClock, WorkerState};
use crate::compress::{
    Compressor, CompressorCache, ErrorFeedback, SparseVec,
};
use crate::deco::DecoInput;
use crate::elastic::{
    ChurnEvent, ChurnSpec, ChurnTimeline, DrainPolicy, MemberState, Membership,
};
use crate::metrics::sink::{BufferSink, MetricsSink};
use crate::metrics::{Record, RegionRecord, RunResult};
use crate::netsim::{Fabric, FabricMonitor, Link};
use crate::obs::{
    worker_spans, ClockEvent, NullSink, PathSpanRec, RegionTrace, TickTrace,
    TraceEvent, TraceSink, WorkerTrace,
};
use crate::optim::GradOracle;
use crate::strategy::{PlanBasis, Strategy, StrategyCtx, WanCtx};
use crate::topo::Topology;
use crate::util::stats::l2_norm;
use crate::util::{Rng, WorkerPool};

/// Below this many total gradient elements (workers × dim) the worker phase
/// runs inline: spawning scoped threads costs more than the phase itself.
const PAR_MIN_WORK: usize = 1 << 14;

/// Minimum model dimension for sharded leader aggregation; smaller models
/// reduce serially (the reduction is a single memory-bound pass).
const SHARD_MIN_DIM: usize = 1 << 16;

/// Knobs for one training run.
#[derive(Clone, Debug)]
pub struct TrainParams {
    /// stepsize γ
    pub gamma: f32,
    pub max_iters: usize,
    /// full-loss evaluation cadence (iterations)
    pub log_every: usize,
    /// stop once the logged loss reaches this value
    pub loss_target: Option<f64>,
    /// stop once the virtual clock passes this (s)
    pub max_virtual_time: Option<f64>,
    /// pin the per-iteration compute time instead of measuring wall time
    pub t_comp_override: Option<f64>,
    /// pin the gradient size (bits) — lets small proxy models be priced at
    /// paper scale (e.g. GPT-2's 124M × 32 bits)
    pub s_g_override: Option<f64>,
    /// paper's wire accounting (δ·S_g bits) instead of the COO codec size
    pub paper_wire: bool,
    /// use the blockwise (L1-kernel-identical) compressor instead of global
    /// top-k
    pub block_topk: bool,
    /// global-norm gradient clipping applied per worker before EF (standard
    /// transformer practice; keeps aggressive (δ, τ) inside the stable
    /// region at practical stepsizes)
    pub clip_norm: Option<f64>,
    pub seed: u64,
    /// network priors used before the monitor has samples
    pub fallback: DecoInput,
    pub monitor_alpha: f64,
    /// which aggregate of the per-link monitors the strategy plans on:
    /// the bottleneck `(min a, max b)` (default — the pair that gates the
    /// synchronous aggregation) or the heterogeneity-blind mean link (the
    /// `exp hetero` control arm). Identical on a homogeneous fabric.
    pub plan: PlanBasis,
    /// worker-pool size; `None` = machine default
    /// ([`WorkerPool::default_threads`]), `Some(1)` = fully serial. With
    /// `t_comp_override` pinned, results are bit-identical at every
    /// setting; with measured compute time they differ exactly as much as
    /// wall-clock timing does (DESIGN.md §Parallel-Execution).
    pub threads: Option<usize>,
    /// churn schedule (elastic subsystem, DESIGN.md §Elasticity).
    /// `ChurnSpec::None` — the default — keeps the run bit-identical to a
    /// fabric-only run, serial and pooled (`tests/elastic.rs`).
    pub churn: ChurnSpec,
    /// what happens to a leaving worker's in-flight delayed gradients
    pub drain: DrainPolicy,
}

impl Default for TrainParams {
    fn default() -> Self {
        Self {
            gamma: 0.05,
            max_iters: 500,
            log_every: 10,
            loss_target: None,
            max_virtual_time: None,
            t_comp_override: None,
            s_g_override: None,
            paper_wire: true,
            block_topk: false,
            clip_norm: None,
            seed: 0,
            fallback: DecoInput { s_g: 1e9, a: 1e8, b: 0.1, t_comp: 0.1 },
            monitor_alpha: 0.3,
            plan: PlanBasis::Bottleneck,
            threads: None,
            churn: ChurnSpec::None,
            drain: DrainPolicy::Drop,
        }
    }
}

/// The leader's sharded apply: zero the reduction buffer, sum every
/// message (in the fixed order `msgs` yields — bit-identical at any pool
/// size), and step the model `x -= γ · scale · Σ msgs`. One copy of the
/// apply arithmetic serves the flat path (per-worker messages) and the
/// two-tier path (per-region messages); the iterator factory keeps the
/// steady state allocation-free (§Perf).
fn apply_messages<'a, F, I>(
    pool: &WorkerPool,
    agg: &mut [f32],
    x: &mut [f32],
    gamma: f32,
    scale: f32,
    msgs: F,
) where
    F: Fn() -> I + Sync,
    I: Iterator<Item = &'a SparseVec>,
{
    pool.zip_chunk_mut(agg, x, |start, agg_s, x_s| {
        agg_s.iter_mut().for_each(|v| *v = 0.0);
        for sv in msgs() {
            sv.add_shard_into_scaled(start as u32, agg_s, scale);
        }
        for (xi, ai) in x_s.iter_mut().zip(agg_s.iter()) {
            *xi -= gamma * *ai;
        }
    });
}

/// Leader-side per-region state of a two-tier run (DESIGN.md §Topology):
/// the WAN boundary's *second* compression stage with its own
/// error-feedback loop — the LAN tier's EF lives in each `WorkerState`,
/// this one absorbs what δ_wan drops from the region partial.
struct RegionState {
    /// dense partial: the sum of the region members' LAN messages
    partial: Vec<f32>,
    ef: ErrorFeedback,
    /// outgoing sparse WAN message, recycled across iterations
    msg: SparseVec,
    /// entries kept this iteration; `None` when the region emitted nothing
    msg_kept: Option<usize>,
    comps: CompressorCache,
    rng: Rng,
}

impl RegionState {
    fn new(dim: usize, seed: u64, region: usize) -> Self {
        Self {
            partial: vec![0.0; dim],
            ef: ErrorFeedback::new(dim),
            msg: SparseVec::default(),
            msg_kept: None,
            comps: CompressorCache::new(),
            rng: Rng::new(
                seed ^ (region as u64 + 1).wrapping_mul(0xD6E8FEB86659FD93),
            ),
        }
    }
}

/// WAN-tier monitoring state of a two-tier run: one estimator per region
/// WAN link plus the planning prior used before it warms.
struct WanState {
    monitor: FabricMonitor,
    fallback: DecoInput,
}

pub struct TrainLoop<O: GradOracle> {
    oracle: O,
    strategy: Box<dyn Strategy>,
    clock: VirtualClock,
    monitor: FabricMonitor,
    workers: Vec<WorkerState>,
    /// per-region WAN EF/compression state (empty on a flat topology)
    region_states: Vec<RegionState>,
    /// per-region WAN monitor + prior (None on a flat topology)
    wan: Option<WanState>,
    /// the global model (flat, padded)
    x: Vec<f32>,
    agg: Vec<f32>,
    pool: WorkerPool,
    /// leader-side compressor cache, used only for honest wire accounting
    wire_comps: CompressorCache,
    params: TrainParams,
    /// gradient bits at δ=1
    s_g: f64,
    /// elastic membership state machine (all-active forever on a static run)
    membership: Membership,
    /// Active|Draining mask — the workers the clock prices and the per-link
    /// monitors observe; kept in lockstep with `membership`
    member_mask: Vec<bool>,
    /// compiled churn schedule (fault windows are already baked into the
    /// clock's fabric; membership events fire as the clock passes them)
    churn: ChurnTimeline,
    churn_cursor: usize,
    /// fault-window close times, each an epoch bump for re-planning
    window_ends: Vec<f64>,
    window_cursor: usize,
    /// deadline-bounded aggregation (DESIGN.md §Robustness): a worker
    /// whose arrival the clock cut past the deadline has its message held
    /// here — NOT dropped — and folded into the next round's apply, so the
    /// late gradient lands with +1 effective staleness. All-`None` forever
    /// on a wait-for-all run (the bit-identity path).
    pending: Vec<Option<SparseVec>>,
    pending_count: usize,
}

impl<O: GradOracle> TrainLoop<O> {
    /// Single shared link for all workers — the homogeneous compatibility
    /// constructor: builds an n-way replicated [`Fabric`], which prices
    /// bit-identically to the former single-link path.
    pub fn new(
        oracle: O,
        strategy: Box<dyn Strategy>,
        link: Link,
        params: TrainParams,
    ) -> Self {
        let n = oracle.workers();
        Self::with_fabric(oracle, strategy, Fabric::replicate(link, n), params)
    }

    /// One [`Fabric`] link per worker — the general heterogeneous form.
    /// Panics on an invalid churn spec (programmatic misuse, like the
    /// fabric/worker-count asserts); config-driven callers should use
    /// [`Self::try_with_fabric`] to surface the error instead.
    pub fn with_fabric(
        oracle: O,
        strategy: Box<dyn Strategy>,
        fabric: Fabric,
        params: TrainParams,
    ) -> Self {
        Self::try_with_fabric(oracle, strategy, fabric, params)
            .expect("invalid churn spec")
    }

    /// [`Self::with_fabric`] that surfaces an invalid `params.churn` as an
    /// error — the path for specs that came from user configs.
    pub fn try_with_fabric(
        oracle: O,
        strategy: Box<dyn Strategy>,
        fabric: Fabric,
        params: TrainParams,
    ) -> anyhow::Result<Self> {
        Self::try_with_topology(
            oracle,
            strategy,
            fabric,
            Topology::Flat,
            params,
        )
    }

    /// The topology-aware constructor (DESIGN.md §Topology):
    /// [`Topology::Flat`] is exactly [`Self::try_with_fabric`] and stays
    /// bit-identical to it (`tests/topo.rs`); a [`Topology::TwoTier`]
    /// prices intra-region links per member and WAN links per region, and
    /// compresses twice (δ_lan at the workers, δ_wan at the region
    /// boundary with its own EF state). Errors on an invalid churn spec or
    /// a topology that doesn't partition the fabric's workers.
    pub fn try_with_topology(
        oracle: O,
        strategy: Box<dyn Strategy>,
        mut fabric: Fabric,
        topology: Topology,
        params: TrainParams,
    ) -> anyhow::Result<Self> {
        let dim = oracle.dim();
        let n = oracle.workers();
        assert_eq!(
            fabric.workers(),
            n,
            "fabric must have exactly one link per worker"
        );
        let x = oracle.init();
        assert_eq!(x.len(), dim);
        let workers = (0..n)
            .map(|i| WorkerState::new(i, dim, params.seed ^ 0x77))
            .collect();
        let s_g = params.s_g_override.unwrap_or(dim as f64 * 32.0);
        // one estimator per worker *path* (single-path workers get exactly
        // the estimator layout the pre-bonding monitor had — bit-compat)
        let monitor =
            FabricMonitor::for_fabric(&fabric, params.monitor_alpha, params.seed);
        let pool = match params.threads {
            Some(t) => WorkerPool::new(t),
            None => WorkerPool::with_default_parallelism(),
        };
        let churn = params.churn.compile_for(n, &fabric.paths_per_worker())?;
        churn.bake_windows(&mut fabric);
        let window_ends = churn.window_ends();
        let (region_states, wan) = match &topology {
            Topology::Flat => (Vec::new(), None),
            Topology::TwoTier { regions, wan } => {
                let states: Vec<RegionState> = (0..regions.len())
                    .map(|r| RegionState::new(dim, params.seed ^ 0x7070, r))
                    .collect();
                let (a, b) = wan.bottleneck(0.0);
                let wan_state = WanState {
                    monitor: FabricMonitor::new(
                        regions.len(),
                        params.monitor_alpha,
                        params.seed ^ 0x7A9,
                    ),
                    fallback: DecoInput {
                        s_g,
                        a,
                        b,
                        t_comp: params.fallback.t_comp,
                    },
                };
                (states, Some(wan_state))
            }
        };
        let mut tl = Self {
            oracle,
            strategy,
            clock: VirtualClock::with_topology(fabric, topology)?,
            monitor,
            workers,
            region_states,
            wan,
            x,
            agg: vec![0.0; dim],
            pool,
            wire_comps: CompressorCache::new(),
            params,
            s_g,
            membership: Membership::new(n),
            member_mask: vec![true; n],
            churn,
            churn_cursor: 0,
            window_ends,
            window_cursor: 0,
            pending: (0..n).map(|_| None).collect(),
            pending_count: 0,
        };
        if tl.clock.is_two_tier() {
            tl.mask_aggregator_monitors();
        }
        Ok(tl)
    }

    pub fn model(&self) -> &[f32] {
        &self.x
    }

    pub fn monitor(&self) -> &FabricMonitor {
        &self.monitor
    }

    /// The virtual clock (per-worker timelines, sync arrivals).
    pub fn clock(&self) -> &VirtualClock {
        &self.clock
    }

    /// The ground-truth fabric the run is priced on — what the audit
    /// layer scores monitor estimates and plan predictions against.
    pub fn fabric(&self) -> &Fabric {
        self.clock.fabric()
    }

    /// Pool size this loop runs its phases on.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Elastic membership state (all-active forever on a static run).
    pub fn membership(&self) -> &Membership {
        &self.membership
    }

    /// Depart `worker` immediately under `policy`. Apply BEFORE pricing —
    /// the churn driver calls this; exposed for tests and external drivers.
    fn depart(&mut self, worker: usize, drain: DrainPolicy) {
        let flush =
            drain == DrainPolicy::Drain && self.workers[worker].queue_len() > 0;
        self.membership.leave(worker, flush);
        if !flush {
            // Drop policy (or nothing in flight): fully departed now
            self.workers[worker].suspend();
            self.member_mask[worker] = false;
            self.monitor.set_active(worker, false);
        }
    }

    /// Fire every churn event whose virtual time the clock has passed.
    /// Membership transitions apply here; fault windows were baked into
    /// the fabric at construction, so their start/end only bump the epoch
    /// (event-triggered strategies re-plan on it).
    fn apply_churn_events(&mut self) {
        if self.churn.is_empty() {
            return;
        }
        let now = self.clock.now();
        loop {
            let Some(ev) = self.churn.events().get(self.churn_cursor) else {
                break;
            };
            if ev.t > now {
                break;
            }
            let event = ev.event.clone();
            self.churn_cursor += 1;
            match event {
                ChurnEvent::Leave { worker } => {
                    self.depart(worker, self.params.drain);
                }
                ChurnEvent::Rejoin { worker } => {
                    self.membership.rejoin(worker);
                    self.member_mask[worker] = true;
                    self.monitor.set_active(worker, true);
                }
                ChurnEvent::LinkOutage { .. }
                | ChurnEvent::LinkDegrade { .. }
                | ChurnEvent::PathOutage { .. }
                | ChurnEvent::PathDegrade { .. }
                | ChurnEvent::LossBurst { .. } => {
                    self.membership.bump();
                }
            }
        }
        while self.window_cursor < self.window_ends.len()
            && self.window_ends[self.window_cursor] <= now
        {
            self.membership.bump();
            self.window_cursor += 1;
        }
        self.ensure_aggregators();
    }

    /// Elastic × topology composition (DESIGN.md §Topology): after any
    /// membership movement, every region whose aggregator is no longer
    /// *active* hands the role to its best-connected active member
    /// ([`crate::topo::elect`] order); if the incumbent is fully departed
    /// and only *draining* members remain, the role falls back to the
    /// best-connected draining member — their in-flight flushes still flow
    /// through the region, so pricing must never route a partial through a
    /// node that no longer exists. A successful re-election bumps the
    /// membership epoch so event-triggered strategies re-plan at once; a
    /// region with nobody left keeps its stale aggregator and simply
    /// prices as inactive until a rejoin (which re-elects here again).
    /// Finally the LAN monitor masking is restored: aggregator links carry
    /// no intra-region traffic, so they must sit outside the LAN-tier
    /// aggregates (see [`Self::mask_aggregator_monitors`]).
    fn ensure_aggregators(&mut self) {
        if !self.clock.is_two_tier() {
            return;
        }
        let n = self.member_mask.len();
        let active: Vec<bool> =
            (0..n).map(|w| self.membership.is_active(w)).collect();
        for r in 0..self.clock.regions().len() {
            let agg = self.clock.regions()[r].aggregator;
            if active[agg] {
                continue;
            }
            if self.clock.reelect_aggregator(r, &active) {
                self.membership.bump();
            } else if !self.member_mask[agg]
                && self.clock.reelect_aggregator(r, &self.member_mask)
            {
                // no active member and the incumbent is gone: a draining
                // member takes the role so the region's flushes keep a
                // present aggregator
                self.membership.bump();
            }
        }
        self.mask_aggregator_monitors();
    }

    /// Restore the LAN monitor's active mask to the roles: a member link
    /// is in the LAN-tier aggregates iff it is masked in AND not currently
    /// an aggregator — an aggregator's link carries no LAN traffic (its
    /// gradient is local), so neither its latency nor its possibly-stale
    /// bandwidth estimate may shape the LAN bottleneck view the per-tier
    /// planner consumes. Idempotent; called at construction and after
    /// every membership movement.
    fn mask_aggregator_monitors(&mut self) {
        for w in 0..self.member_mask.len() {
            let is_agg =
                self.clock.regions().iter().any(|r| r.aggregator == w);
            self.monitor.set_active(w, self.member_mask[w] && !is_agg);
        }
    }

    /// Run to completion, buffering every logged record. `task` labels
    /// the result. Convenience over [`Self::run_streamed`] for runs whose
    /// record volume is analysis-sized.
    pub fn run(&mut self, task: &str) -> RunResult {
        let mut sink = BufferSink::new();
        let mut result = self
            .run_streamed(task, &mut sink)
            .expect("the buffering sink cannot fail");
        result.records = sink.into_records();
        result
    }

    /// Run to completion, handing each logged [`Record`] to `sink` the
    /// moment it exists instead of buffering (DESIGN.md §Perf) — the
    /// bounded-memory path for 100k-worker campaigns. The returned
    /// [`RunResult`] carries the run totals with an empty `records`; the
    /// sink owns the rows (and, for `CsvSink`, the incremental folds).
    /// A sink error aborts the run.
    pub fn run_streamed(
        &mut self,
        task: &str,
        sink: &mut dyn MetricsSink,
    ) -> anyhow::Result<RunResult> {
        self.run_traced(task, sink, &mut NullSink)
    }

    /// [`Self::run_streamed`] with a [`TraceSink`] receiving the typed
    /// observability events (DESIGN.md §Observability): per-worker phase
    /// spans and per-path windows each tick, churn / class-split /
    /// aggregator-election instants, and the strategy's re-plan decisions.
    /// Every emission is guarded by [`TraceSink::enabled`], so the
    /// [`NullSink`] path is byte-identical to (and as fast as) an
    /// untraced run; timestamps are virtual, so traced output is
    /// deterministic across pool sizes and reruns.
    pub fn run_traced(
        &mut self,
        task: &str,
        sink: &mut dyn MetricsSink,
        tracer: &mut dyn TraceSink,
    ) -> anyhow::Result<RunResult> {
        let n = self.workers.len();
        let dim = self.x.len();
        let mut last_grad_norm: Option<f64> = None;
        let method = self.strategy.name().to_string();
        let serial = WorkerPool::serial();
        let par_workers = self.pool.threads() > 1 && n * dim >= PAR_MIN_WORK;
        let par_shards = self.pool.threads() > 1 && dim >= SHARD_MIN_DIM;
        let tracing = tracer.enabled();
        if tracing {
            self.clock.set_event_log(true);
        }
        // per-worker region labels for the trace (region membership is
        // static; only the aggregator role moves)
        let region_of: Vec<Option<u32>> = if tracing {
            let mut map = vec![None; n];
            for (r, reg) in self.clock.regions().iter().enumerate() {
                for &m in &reg.members {
                    map[m] = Some(r as u32);
                }
            }
            map
        } else {
            Vec::new()
        };
        // flat fabrics without bonds or monitor noise observe transfers /
        // latencies per *timeline class* instead of per worker — O(live
        // classes) per tick, bit-identical to the per-worker stream
        // (every member of a class shares one link and one tick report)
        let class_monitor = !self.clock.is_two_tier()
            && self.monitor.noiseless()
            && (0..n).all(|i| self.clock.fabric().bond(i).is_none());

        for t in 1..=self.params.max_iters {
            // 0. elastic: fire churn events the virtual clock has passed,
            // so the strategy already sees the new membership epoch
            let churn_fired = self.churn_cursor;
            self.apply_churn_events();
            if tracing {
                for ev in
                    &self.churn.events()[churn_fired..self.churn_cursor]
                {
                    tracer.record(&TraceEvent::Churn {
                        t: ev.t,
                        iter: t,
                        event: ev.event.clone(),
                    });
                }
            }

            // 1. strategy decides the per-tier (τ_t, δ_t): tier-blind
            // strategies emit a flat plan (WAN uncompressed), DecoTwoTier
            // solves each tier against its own monitored links. The worker
            // pipeline realizes the *total* staleness; δ_lan compresses at
            // the workers, δ_wan at the region boundary.
            let ctx = StrategyCtx {
                iter: t,
                monitor: &self.monitor,
                s_g: self.s_g,
                grad_norm: last_grad_norm,
                fallback: self.params.fallback,
                plan: self.params.plan,
                membership_epoch: self.membership.epoch(),
                active_workers: self.membership.active_count(),
                wan: self.wan.as_ref().map(|w| WanCtx {
                    regions: w.monitor.links(),
                    monitor: &w.monitor,
                    fallback: w.fallback,
                }),
            };
            let tiers = self.strategy.params_tiered(&ctx);
            if tracing {
                if let Some(rec) = self.strategy.take_replan() {
                    tracer.record(&TraceEvent::Replan {
                        t: self.clock.now(),
                        iter: t,
                        rec,
                    });
                }
            }
            let (tau, delta) = (tiers.total_tau(), tiers.delta);
            let wan_delta = tiers.wan_delta();
            let two_tier = self.clock.is_two_tier();

            // 2+3. worker phase, fanned out over the pool: gradient at x_t,
            // clip, enqueue; pop g_{t−τ}, EF + compress into the recycled
            // per-worker message. Safe to parallelize: each WorkerState
            // owns its EF vector, queue, RNG, scratch, and compressor cache.
            // Draining workers flush one in-flight gradient instead of
            // computing; departed workers sit out (their state is retained
            // for a warm rejoin — DESIGN.md §Elasticity).
            {
                let oracle = &self.oracle;
                let x = &self.x[..];
                let clip = self.params.clip_norm;
                let block_topk = self.params.block_topk;
                let membership = &self.membership;
                let pool = if par_workers { &self.pool } else { &serial };
                pool.for_each_chunk_mut(&mut self.workers, |_, chunk| {
                    for ws in chunk.iter_mut() {
                        let state = membership.state(ws.id);
                        if state == MemberState::Departed {
                            continue;
                        }
                        if state == MemberState::Draining {
                            ws.comp_secs = 0.0;
                            let _ = ws.drain_compress_cached(delta, block_topk);
                            continue;
                        }
                        let wall = std::time::Instant::now();
                        let loss = oracle.grad(ws.id, t, x, ws.grad_buffer());
                        ws.comp_secs = wall.elapsed().as_secs_f64();
                        let norm = l2_norm(ws.grad_buffer());
                        ws.last_loss = loss;
                        ws.last_grad_norm = norm;
                        if let Some(clip) = clip {
                            if norm > clip {
                                let s = (clip / norm) as f32;
                                ws.grad_buffer()
                                    .iter_mut()
                                    .for_each(|v| *v *= s);
                            }
                        }
                        ws.push_gradient();
                        let _ = ws.pop_compress_cached(tau, delta, block_topk);
                    }
                });
            }

            // leader reduction of the phase outputs, in fixed worker order
            // so the f64 sums are bit-identical at any pool size; loss /
            // norm / compute averages run over the *active* set, messages
            // (incl. draining flushes) aggregate over the member set
            let mut loss_acc = 0.0f64;
            let mut norm_acc = 0.0f64;
            let mut comp_acc = 0.0f64;
            let mut kept_total = 0usize;
            let mut any = false;
            for ws in &self.workers {
                if self.membership.is_active(ws.id) {
                    loss_acc += ws.last_loss;
                    norm_acc += ws.last_grad_norm;
                    comp_acc += ws.comp_secs;
                }
                if let Some(kept) = ws.message_kept() {
                    kept_total += kept;
                    any = true;
                }
            }
            let n_active = self.membership.active_count();
            let n_members = self.membership.member_count();
            let t_comp = self
                .params
                .t_comp_override
                .unwrap_or(comp_acc / n_active as f64);
            last_grad_norm = Some(norm_acc / n_active as f64);
            let train_loss = loss_acc / n_active as f64;

            // 4. aggregate + apply: sharded across the pool for large
            // models (ascending COO indices make shard boundaries two
            // binary searches), serial otherwise — identical arithmetic.
            // The γ/n average runs over the members whose gradient shares
            // this iteration carries (= n on a static run). On a two-tier
            // topology the reduction is hierarchical: each region sums its
            // members' LAN messages into a dense partial and re-compresses
            // it at δ_wan through the region's own EF state (the second
            // compression stage — DESIGN.md §Topology), and the leader
            // applies the region messages.
            // The flat-topology apply runs AFTER the clock tick below: the
            // deadline cut decides which arrivals made this round, and the
            // cut-off workers' messages are stashed for the next one. The
            // two-tier reduction stays here — its WAN message sizes feed
            // the tick, and its deadline is pricing-only (see `tick_topo`).
            let mut wan_kept_total = 0usize;
            let mut wan_msgs = 0usize;
            let gamma = self.params.gamma;
            let scale = 1.0 / n_members as f32;
            let apool = if par_shards { &self.pool } else { &serial };
            if any && two_tier {
                    // region reduce + WAN-boundary EF/compress, one region
                    // per pool thread (each RegionState owns everything its
                    // phase touches; outputs land in per-region state, so
                    // any pool size is bit-identical). Serial for small
                    // models where the fan-out costs more than the work.
                    let workers = &self.workers;
                    let regions = self.clock.regions();
                    let block_topk = self.params.block_topk;
                    let rpool = if self.pool.threads() > 1
                        && regions.len() > 1
                        && regions.len() * dim >= PAR_MIN_WORK
                    {
                        &self.pool
                    } else {
                        &serial
                    };
                    rpool.for_each_chunk_mut(
                        &mut self.region_states,
                        |start, chunk| {
                            for (off, rs) in chunk.iter_mut().enumerate() {
                                let region = &regions[start + off];
                                rs.msg_kept = None;
                                let mut any_msg = false;
                                rs.partial.iter_mut().for_each(|v| *v = 0.0);
                                for &i in &region.members {
                                    if let Some(sv) = workers[i].message() {
                                        sv.add_into_scaled(
                                            &mut rs.partial,
                                            1.0,
                                        );
                                        any_msg = true;
                                    }
                                }
                                if !any_msg {
                                    continue;
                                }
                                let comp =
                                    rs.comps.get(wan_delta, block_topk);
                                let kept = rs.ef.step(
                                    &mut rs.partial,
                                    comp,
                                    &mut rs.rng,
                                );
                                rs.msg.encode_into(&rs.partial);
                                rs.msg_kept = Some(kept);
                            }
                        },
                    );
                    for rs in &self.region_states {
                        if let Some(kept) = rs.msg_kept {
                            wan_kept_total += kept;
                            wan_msgs += 1;
                        }
                    }
                    let region_states = &self.region_states;
                    apply_messages(
                        apool,
                        &mut self.agg,
                        &mut self.x,
                        gamma,
                        scale,
                        || {
                            region_states
                                .iter()
                                .filter(|rs| rs.msg_kept.is_some())
                                .map(|rs| &rs.msg)
                        },
                    );
            }

            // 5. price the iteration over the member set and feed the
            // monitor (departed workers neither transmit nor observe). On
            // a two-tier topology the LAN bits price the member →
            // aggregator hop and the WAN bits the partial's hop.
            let bits = if self.params.paper_wire {
                (delta.min(1.0) * self.s_g) as u64
            } else {
                // honest wire accounting (COO indices, quantized payloads,
                // headers), averaged over members and scaled from the proxy
                // model's dimension up to the pinned paper-scale S_g
                let comp: &dyn Compressor =
                    self.wire_comps.get(delta, self.params.block_topk);
                let proxy_bits =
                    comp.wire_bits(kept_total / n_members.max(1), dim);
                let scale = self.s_g / (dim as f64 * 32.0);
                (proxy_bits as f64 * scale) as u64
            };
            let wan_bits = if !two_tier {
                0
            } else if self.params.paper_wire {
                (wan_delta.min(1.0) * self.s_g) as u64
            } else {
                let comp: &dyn Compressor =
                    self.wire_comps.get(wan_delta, self.params.block_topk);
                let proxy_bits =
                    comp.wire_bits(wan_kept_total / wan_msgs.max(1), dim);
                let scale = self.s_g / (dim as f64 * 32.0);
                (proxy_bits as f64 * scale) as u64
            };
            // the strategy's aggregation deadline (None = wait for all);
            // must be armed before the tick so the cut prices this round
            self.clock.set_deadline(tiers.deadline);
            let tick = if two_tier {
                self.clock.tick_topo(
                    t_comp,
                    tau,
                    bits,
                    wan_bits,
                    Some(&self.member_mask),
                )
            } else {
                self.clock.tick_members(
                    t_comp,
                    tau,
                    bits,
                    Some(&self.member_mask),
                )
            };
            // flat apply, deadline-aware: fold in last round's held-back
            // messages, skip workers the cut left late (their messages are
            // stashed below and land next round — +1 staleness, never
            // dropped). With no deadline the iterator degenerates to
            // exactly the historical per-worker message stream.
            if !two_tier {
                if any || self.pending_count > 0 {
                    let workers = &self.workers;
                    let pending = &self.pending;
                    let late = self.clock.late_workers();
                    apply_messages(
                        apool,
                        &mut self.agg,
                        &mut self.x,
                        gamma,
                        scale,
                        || {
                            workers.iter().flat_map(move |ws| {
                                let held = pending[ws.id].as_ref();
                                let cur = ws.message().filter(|_| {
                                    late.binary_search(&(ws.id as u32))
                                        .is_err()
                                });
                                held.into_iter().chain(cur)
                            })
                        },
                    );
                }
                if self.pending_count > 0 {
                    self.pending_count = 0;
                    for p in self.pending.iter_mut() {
                        *p = None;
                    }
                }
                for &w in self.clock.late_workers() {
                    let w = w as usize;
                    if let Some(msg) = self.workers[w].message() {
                        self.pending[w] = Some(msg.clone());
                        self.pending_count += 1;
                        if tracing {
                            tracer.record(&TraceEvent::Clock {
                                t: tick.tc,
                                iter: t,
                                event: ClockEvent::LateAbsorb {
                                    worker: w as u32,
                                },
                            });
                        }
                    }
                }
            }
            if tracing {
                let tt = self.tick_trace(t, t_comp, &tick, &region_of);
                tracer.record(&TraceEvent::Tick(tt));
                for event in self.clock.drain_events() {
                    tracer.record(&TraceEvent::Clock {
                        t: tick.tc,
                        iter: t,
                        event,
                    });
                }
            }
            // each member's link monitor observes its own transfer and
            // latency — on a static homogeneous fabric every estimator sees
            // the same stream the former single monitor did. Bonded workers
            // observe per *path*: each path's water-filling share and busy
            // seconds feed that path's estimator, so the worker-level
            // (Σ bandwidth, min latency) view tracks the real aggregate
            // (DESIGN.md §Bonding).
            if bits > 0 {
                if class_monitor {
                    // one estimator update per live class — every member
                    // shares the class's link and tick report, so this is
                    // the per-worker stream, deduplicated
                    for cv in self.clock.class_views() {
                        if cv.active
                            && cv.sent_last
                            && cv.last.tx_secs > 0.0
                        {
                            self.monitor.observe_class_transfer(
                                cv.members,
                                bits,
                                cv.last.tx_secs,
                            );
                        }
                        // lossy workers (always singleton classes) report
                        // their delivery attempt count — the loss-rate
                        // estimator loss-aware DeCo plans on
                        if cv.active
                            && cv.sent_last
                            && self
                                .clock
                                .fabric()
                                .loss(cv.members[0] as usize)
                                .is_some()
                        {
                            self.monitor.observe_attempts(
                                cv.members[0] as usize,
                                f64::from(cv.last.attempts),
                            );
                        }
                    }
                } else {
                    for i in 0..n {
                        if !self.member_mask[i] {
                            continue;
                        }
                        if self.clock.fabric().bond(i).is_some() {
                            let ticks = self.clock.path_ticks(i);
                            for (p, pt) in ticks.iter().enumerate() {
                                if pt.tx_secs > 0.0 {
                                    self.monitor.observe_path_transfer(
                                        i, p, pt.bits, pt.tx_secs,
                                    );
                                }
                            }
                        } else {
                            // copied out: the lazily materialized view is
                            // O(1) after the first post-tick access
                            let wt = self.clock.worker_ticks()[i];
                            if wt.tx_secs > 0.0 {
                                self.monitor
                                    .observe_transfer(i, bits, wt.tx_secs);
                            }
                        }
                        if self.clock.fabric().loss(i).is_some() {
                            let wt = self.clock.worker_ticks()[i];
                            self.monitor
                                .observe_attempts(i, f64::from(wt.attempts));
                        }
                    }
                }
            }
            if class_monitor {
                for cv in self.clock.class_views() {
                    if cv.active {
                        let lat = self
                            .clock
                            .fabric()
                            .link(cv.members[0] as usize)
                            .latency();
                        self.monitor.observe_class_latency(cv.members, lat);
                    }
                }
            } else {
                for i in 0..n {
                    if !self.member_mask[i] {
                        continue;
                    }
                    if let Some(bond) = self.clock.fabric().bond(i) {
                        for (p, path) in bond.paths().iter().enumerate() {
                            self.monitor
                                .observe_path_latency(i, p, path.latency());
                        }
                    } else {
                        let lat = self.clock.fabric().link(i).latency();
                        self.monitor.observe_latency_for(i, lat);
                    }
                }
            }
            self.monitor.observe_compute(t_comp);
            // the WAN tier has its own per-region estimators: each active
            // region's link observes its partial's transfer, and inactive
            // regions leave the aggregate views (warm for reactivation)
            if let Some(w) = self.wan.as_mut() {
                let wan_fabric =
                    self.clock.wan_fabric().expect("two-tier clock");
                for (r, rt) in self.clock.region_ticks().iter().enumerate() {
                    w.monitor.set_active(r, rt.active);
                    if rt.active {
                        if wan_bits > 0 && rt.wan_tx_secs > 0.0 {
                            w.monitor.observe_transfer(
                                r,
                                wan_bits,
                                rt.wan_tx_secs,
                            );
                        }
                        w.monitor.observe_latency_for(
                            r,
                            wan_fabric.link(r).latency(),
                        );
                    }
                }
            }

            // a draining worker whose pipeline just emptied departs fully —
            // after the tick that priced its final message
            for w in 0..n {
                if self.membership.state(w) == MemberState::Draining
                    && self.workers[w].queue_len() == 0
                {
                    self.membership.finish_drain(w);
                    self.workers[w].suspend();
                    self.member_mask[w] = false;
                    self.monitor.set_active(w, false);
                }
            }

            // 6. metrics + stopping. The average training loss doubles as a
            // divergence guard: a strategy whose (δ, τ) violates the
            // stepsize condition blows up, and the per-iteration train loss
            // catches it *between* log_every boundaries instead of pricing
            // garbage iterations until the next full evaluation.
            let diverged = !train_loss.is_finite();
            if t % self.params.log_every == 0
                || t == self.params.max_iters
                || diverged
            {
                let loss = self.oracle.loss(&self.x);
                sink.record(&Record {
                    iter: t,
                    time: tick.tc,
                    loss,
                    train_loss,
                    tau,
                    delta,
                    grad_norm: last_grad_norm.unwrap_or(0.0),
                    bandwidth: self.monitor.bandwidth().unwrap_or(0.0),
                    wan_delta,
                    regions: self
                        .clock
                        .region_ticks()
                        .iter()
                        .zip(self.clock.wan_bits_totals())
                        .map(|(rt, &wb)| RegionRecord {
                            sync: rt.sync,
                            wan_bits: wb,
                        })
                        .collect(),
                })?;
                if let Some(target) = self.params.loss_target {
                    if loss <= target {
                        break;
                    }
                }
                if diverged || !loss.is_finite() {
                    break;
                }
            }
            if let Some(tmax) = self.params.max_virtual_time {
                if self.clock.now() >= tmax {
                    break;
                }
            }
        }

        Ok(RunResult {
            method,
            task: task.to_string(),
            workers: n,
            total_time: self.clock.now(),
            total_iters: self.clock.iters(),
            records: Vec::new(),
        })
    }

    /// Assemble the [`TickTrace`] for the tick just priced: every member
    /// worker's five phase spans (plus per-path windows on bonded links)
    /// and, on a two-tier topology, every active region's WAN boundaries.
    fn tick_trace(
        &mut self,
        iter: usize,
        t_comp: f64,
        tick: &Tick,
        region_of: &[Option<u32>],
    ) -> TickTrace {
        let ts = tick.ts;
        let tc = tick.tc;
        let n = self.member_mask.len();
        let mut workers = Vec::new();
        for w in 0..n {
            if !self.member_mask[w] {
                continue;
            }
            let aggregator =
                self.clock.regions().iter().any(|r| r.aggregator == w);
            let wt = self.clock.worker_ticks()[w];
            let start = (wt.tm - wt.tx_secs).max(ts).min(wt.tm);
            let paths: Vec<PathSpanRec> = self
                .clock
                .path_ticks(w)
                .iter()
                .enumerate()
                .filter(|(_, pt)| pt.tx_secs > 0.0)
                .map(|(p, pt)| PathSpanRec {
                    path: p as u32,
                    bits: pt.bits,
                    t0: pt.tm - pt.tx_secs,
                    t1: pt.tm,
                })
                .collect();
            workers.push(WorkerTrace {
                worker: w as u32,
                region: region_of.get(w).copied().flatten(),
                aggregator,
                spans: worker_spans(
                    ts - t_comp,
                    ts,
                    start,
                    wt.tm,
                    wt.tc,
                    tc,
                ),
                retx_secs: wt.retx_secs,
                paths,
            });
        }
        let regions: Vec<RegionTrace> = self
            .clock
            .region_ticks()
            .iter()
            .enumerate()
            .filter(|(_, rt)| rt.active)
            .map(|(r, rt)| RegionTrace {
                region: r as u32,
                sync: rt.sync,
                wan_start: (rt.wan_tm - rt.wan_tx_secs)
                    .max(rt.sync)
                    .min(rt.wan_tm),
                wan_tm: rt.wan_tm,
                wan_tc: rt.wan_tc,
                senders: rt.senders,
            })
            .collect();
        TickTrace { iter, ts, t_comp, tc, workers, regions }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::BandwidthTrace;
    use crate::optim::Quadratic;
    use crate::strategy::StrategyKind;

    // Stability note: Theorem 1's stepsize condition γ ≤ 1/(4L√(φ/δ))
    // genuinely binds — aggressive (δ, τ) with a large γ diverges on the
    // quadratic. Tests therefore run in the stable regime (small L, small γ)
    // and the experiments pick per-task γ the same way the paper tunes lr.
    const S_G: f64 = 1e8; // bits
    const T_COMP: f64 = 0.2;

    fn quad() -> Quadratic {
        Quadratic::new(256, 4, 1.0, 0.2, 0.3, 0.3, 11)
    }

    fn link(bps: f64, lat: f64) -> Link {
        Link::new(BandwidthTrace::constant(bps), lat)
    }

    fn params() -> TrainParams {
        TrainParams {
            gamma: 0.005,
            max_iters: 4000,
            log_every: 25,
            t_comp_override: Some(T_COMP),
            s_g_override: Some(S_G),
            fallback: DecoInput { s_g: S_G, a: 2e7, b: 0.2, t_comp: T_COMP },
            ..Default::default()
        }
    }

    #[test]
    fn all_strategies_converge_on_quadratic() {
        let l0 = {
            let q = quad();
            let x = q.init();
            q.loss(&x)
        };
        for kind in StrategyKind::paper_baselines() {
            let mut tl =
                TrainLoop::new(quad(), kind.build(), link(2e7, 0.2), params());
            let res = tl.run("quad");
            assert!(
                res.final_loss() < 0.7 * l0,
                "{}: {} -> {}",
                kind.label(),
                l0,
                res.final_loss()
            );
        }
    }

    #[test]
    fn dsgd_time_matches_serial_model() {
        // D-SGD: every iteration costs T_comp + S_g/a + b on the virtual
        // clock
        let mut tl = TrainLoop::new(
            quad(),
            StrategyKind::DSgd.build(),
            link(2e7, 0.2),
            TrainParams { max_iters: 50, ..params() },
        );
        let res = tl.run("quad");
        let per_iter = T_COMP + S_G / 2e7 + 0.2;
        assert!(
            (res.total_time - 50.0 * per_iter).abs() / (50.0 * per_iter)
                < 1e-6,
            "{} vs {}",
            res.total_time,
            50.0 * per_iter
        );
    }

    #[test]
    fn deco_is_faster_than_dsgd_to_same_loss() {
        // the paper's headline, miniature: same loss target, DeCo-SGD needs
        // less virtual time than D-SGD under WAN conditions
        let l0 = {
            let q = quad();
            let x = q.init();
            q.loss(&x)
        };
        let target = 0.6 * l0;
        let run = |kind: StrategyKind| {
            let mut tl = TrainLoop::new(
                quad(),
                kind.build(),
                link(2e7, 0.2),
                TrainParams { loss_target: Some(target), ..params() },
            );
            tl.run("quad")
        };
        let dsgd = run(StrategyKind::DSgd);
        let deco = run(StrategyKind::DecoSgd { update_every: 20 });
        let t_dsgd = dsgd.time_to_loss(target).expect("dsgd reaches");
        let t_deco = deco.time_to_loss(target).expect("deco reaches");
        assert!(
            t_deco < t_dsgd,
            "deco {t_deco} should beat dsgd {t_dsgd}"
        );
    }

    #[test]
    fn records_are_monotone_in_time() {
        let mut tl = TrainLoop::new(
            quad(),
            StrategyKind::DecoSgd { update_every: 10 }.build(),
            link(5e6, 0.3),
            TrainParams { max_iters: 100, ..params() },
        );
        let res = tl.run("quad");
        for w in res.records.windows(2) {
            assert!(w[1].time > w[0].time);
            assert!(w[1].iter > w[0].iter);
        }
        assert!(res.total_iters <= 100);
    }

    #[test]
    fn records_carry_finite_train_loss() {
        let mut tl = TrainLoop::new(
            quad(),
            StrategyKind::DecoSgd { update_every: 10 }.build(),
            link(2e7, 0.2),
            TrainParams { max_iters: 100, ..params() },
        );
        let res = tl.run("quad");
        assert!(!res.records.is_empty());
        for r in &res.records {
            assert!(r.train_loss.is_finite());
            assert!(r.train_loss > 0.0, "quadratic losses are positive");
        }
    }

    #[test]
    fn two_tier_run_converges_and_logs_region_columns() {
        use crate::topo::{RegionTopo, Topology};
        let lan = Fabric::homogeneous(4, BandwidthTrace::constant(1e9), 0.005);
        let topo = Topology::TwoTier {
            regions: vec![
                RegionTopo::new(vec![0, 1], 0),
                RegionTopo::new(vec![2, 3], 2),
            ],
            wan: Fabric::homogeneous(2, BandwidthTrace::constant(2e7), 0.3),
        };
        let mut tl = TrainLoop::try_with_topology(
            quad(),
            StrategyKind::DecoTwoTier { update_every: 20 }.build(),
            lan,
            topo,
            TrainParams { max_iters: 4000, ..params() },
        )
        .unwrap();
        let l0 = {
            let q = quad();
            let x = q.init();
            q.loss(&x)
        };
        let res = tl.run("quad");
        assert!(res.final_loss() < 0.7 * l0, "{l0} -> {}", res.final_loss());
        for r in &res.records {
            assert_eq!(r.regions.len(), 2, "two region columns per record");
            assert!(r.wan_delta > 0.0 && r.wan_delta <= 1.0);
            for reg in &r.regions {
                assert!(reg.sync > 0.0, "static run: regions always active");
                assert!(reg.sync <= r.time, "partials precede the global sync");
            }
        }
        // WAN bits accumulate monotonically per region
        let first = &res.records[0];
        let last = res.records.last().unwrap();
        for (a, b) in first.regions.iter().zip(&last.regions) {
            assert!(b.wan_bits > a.wan_bits);
        }
        // the CSV writer emits the per-region header (hard-error checked)
        let csv = res.to_csv();
        assert!(csv.lines().next().unwrap().contains("region1_wan_bits"));
    }

    #[test]
    fn deadline_bounded_rounds_absorb_the_straggler_and_finish_sooner() {
        use crate::strategy::TierParams;
        // τ=0, δ=1 with a pinned aggregation deadline: D-SGD whose round
        // closes at min(slowest arrival, TS + D)
        struct DeadlineSgd(Option<f64>);
        impl Strategy for DeadlineSgd {
            fn name(&self) -> &'static str {
                "deadline-sgd"
            }
            fn params(&mut self, _ctx: &StrategyCtx) -> (usize, f64) {
                (0, 1.0)
            }
            fn params_tiered(&mut self, _ctx: &StrategyCtx) -> TierParams {
                TierParams { tau: 0, delta: 1.0, wan: None, deadline: self.0 }
            }
        }
        let fabric = || {
            // worker 0 is a 4x straggler: fast arrivals at ~5.2 s past the
            // sync start, the straggler at ~20.2 s
            Fabric::with_straggler(
                4,
                BandwidthTrace::constant(2e7),
                0.2,
                0.25,
                4.0,
            )
        };
        let run = |deadline: Option<f64>| {
            let mut tl = TrainLoop::with_fabric(
                quad(),
                Box::new(DeadlineSgd(deadline)),
                fabric(),
                TrainParams { max_iters: 4000, ..params() },
            );
            tl.run("quad")
        };
        let l0 = {
            let q = quad();
            let x = q.init();
            q.loss(&x)
        };
        let wfa = run(None);
        let cut = run(Some(6.0));
        // the binding deadline caps every round at T_comp + 6.0 while
        // wait-for-all pays the straggler's full 20.2 s arrival
        assert!(
            cut.total_time < 0.5 * wfa.total_time,
            "cut {} vs wait-for-all {}",
            cut.total_time,
            wfa.total_time
        );
        // the straggler's gradients are absorbed (+1 staleness), not
        // dropped: the run still converges
        assert!(
            cut.final_loss() < 0.7 * l0,
            "{l0} -> {}",
            cut.final_loss()
        );
        // a deadline no arrival ever crosses is bit-identical to
        // wait-for-all — pricing AND model trajectory
        let slack = run(Some(1e9));
        assert_eq!(slack.total_time.to_bits(), wfa.total_time.to_bits());
        assert_eq!(
            slack.final_loss().to_bits(),
            wfa.final_loss().to_bits()
        );
        assert_eq!(slack.records.len(), wfa.records.len());
    }

    #[test]
    fn lossy_fabric_run_monitors_the_loss_rate() {
        use crate::netsim::LossProcess;
        let mut fabric =
            Fabric::homogeneous(4, BandwidthTrace::constant(2e7), 0.2);
        fabric.set_loss(1, LossProcess::iid(0.4, 7));
        let mut tl = TrainLoop::with_fabric(
            quad(),
            StrategyKind::DecoLossy { update_every: 10, quantile: 0.9 }
                .build(),
            fabric,
            TrainParams { max_iters: 300, ..params() },
        );
        let res = tl.run("quad");
        assert_eq!(res.total_iters, 300, "no divergence under loss");
        // worker 1 retries ~1/(1-0.4) times per message; the attempt
        // stream inverts back to the loss rate the planner consumes
        let p = tl.monitor().loss_rate().expect("attempt samples observed");
        assert!(p > 0.1 && p < 0.7, "estimated loss rate {p}");
    }

    #[test]
    fn divergence_guard_trips_between_log_boundaries() {
        // γ far above the Theorem 1 bound with aggressive (δ, τ): the run
        // must stop at the first non-finite train loss even though
        // log_every would only evaluate at iteration 4000
        let mut tl = TrainLoop::new(
            Quadratic::new(256, 4, 8.0, 0.2, 0.3, 0.3, 11),
            StrategyKind::DEfSgd { delta: 0.01 }.build(),
            link(2e7, 0.2),
            TrainParams {
                gamma: 5.0,
                max_iters: 4000,
                log_every: 4000,
                ..params()
            },
        );
        let res = tl.run("quad");
        assert!(
            res.total_iters < 4000,
            "guard never tripped: ran {} iters",
            res.total_iters
        );
        let last = res.records.last().expect("divergence record");
        assert!(!last.train_loss.is_finite() || !last.loss.is_finite());
    }
}
