//! Virtual wall clock — the incremental, trace-driven, **fabric**-driven
//! form of the Eq. 19 recurrence. The training loop advances it one
//! iteration at a time with whatever (T_comp, τ, wire bits) that iteration
//! actually used, which is how DeCo's *dynamic* (τ_t, δ_t) trajectory gets
//! faithfully priced.
//!
//! Per-worker semantics (DESIGN.md §Network-Fabric): every worker i sends
//! its message over its own [`Link`], so each keeps its own transmission
//! timeline `TM_k^i`; the synchronous aggregation of iteration k completes
//! at the **slowest** worker's arrival `TC_k = max_i (TM_k^i + b_i)`, and
//! that sync arrival is what the delayed-gradient wait `TC_{k−1−τ}` sees.
//! This is THE Eq. 19 implementation: `timesim::EventSim::run_on_fabric` /
//! `run_on_link` delegate here.
//!
//! ## Timeline classes (DESIGN.md §Perf)
//!
//! Workers whose links are identical ([`Fabric::link_class`]) and whose
//! activity histories agree have — by induction from the all-zero start —
//! bit-identical timelines, so the clock keeps **one** [`ClassState`] per
//! group and prices one transfer per class per tick instead of one per
//! worker. A homogeneous 100k-worker fabric is a single class; a straggler
//! fabric is two. Whenever histories could diverge (a churn mask that
//! splits a class, a bonded worker, an elected aggregator) the class is
//! split — splits never re-merge, so sharing only ever shrinks, which is
//! always correct. The slowest arrival is tracked by a tournament tree
//! ([`super::arrival::ArrivalTree`]) keyed `(tc, min member)`, reproducing
//! the historical O(n) scan's first-strict-max tie-breaking exactly; a
//! debug build re-runs the linear scan over classes each tick and asserts
//! agreement. [`Self::with_reference_scan`] forces one class per worker —
//! the O(n) reference engine the property tests compare against.

use std::sync::Arc;

use super::arrival::{ArrivalTree, EMPTY_KEY};
use crate::netsim::{Bond, Fabric, Link, LossProcess};
use crate::obs::ClockEvent;
use crate::topo::{elect_eligible, RegionTopo, Topology};

/// Retained sync-arrival history TC_k. The τ-delayed wait looks back
/// τ+1 iterations and DeCo's τ* is single-digit, so the clock keeps a
/// bounded ring instead of growing O(iterations) state; reaching past the
/// window is a bug (an absurd τ) and asserts.
const TC_HISTORY: usize = 4096;

#[derive(Clone, Debug)]
struct TcRing {
    buf: Vec<f64>,
    pushed: usize,
}

impl TcRing {
    fn new() -> Self {
        Self { buf: vec![0.0; TC_HISTORY], pushed: 0 }
    }

    fn push(&mut self, v: f64) {
        self.buf[self.pushed % TC_HISTORY] = v;
        self.pushed += 1;
    }

    fn len(&self) -> usize {
        self.pushed
    }

    /// TC at 0-based iteration index `idx` (the old `tc[idx]`).
    fn get(&self, idx: usize) -> f64 {
        assert!(
            idx < self.pushed && self.pushed - idx <= TC_HISTORY,
            "tau looks back past the retained clock history \
             (idx {idx}, pushed {}, window {TC_HISTORY})",
            self.pushed
        );
        self.buf[idx % TC_HISTORY]
    }

    fn last(&self) -> f64 {
        if self.pushed == 0 {
            0.0
        } else {
            self.buf[(self.pushed - 1) % TC_HISTORY]
        }
    }
}

/// One timeline class: a set of workers with identical links and identical
/// activity histories, sharing one timeline. Bonded workers and two-tier
/// aggregators are always singletons.
#[derive(Clone, Debug)]
struct ClassState {
    link: Link,
    /// multi-path bond (forces a singleton class)
    bond: Option<Arc<Bond>>,
    /// message-loss process (forces a singleton class — loss draws key on
    /// the worker id, so lossy timelines are genuinely per-worker)
    loss: Option<Arc<LossProcess>>,
    /// ascending member worker ids; never empty
    members: Vec<u32>,
    /// members transmit this tick (classes split on mixed masks, so the
    /// bit is always class-wide)
    active: bool,
    /// whether the class transmitted on the most recent tick (false while
    /// masked out: members report zeroed [`WorkerTick`]s)
    sent_last: bool,
    /// the current aggregator of a two-tier region (singleton; advances by
    /// local hand-off instead of a LAN transfer)
    aggregator: bool,
    /// TM_k of the previous iteration
    tm_prev: f64,
    /// per-path TM_k of the previous iteration (bonded classes only)
    path_tm_prev: Vec<f64>,
    /// per-path times of the last tick (bonded classes only)
    path_last: Vec<PathTick>,
    /// the last tick's report
    last: WorkerTick,
    /// transmission seconds accumulated along this class's timeline. A
    /// split clones the accumulator into the new class unchanged — every
    /// member's total stays the same left-to-right fold of per-tick
    /// `tx_secs` the singleton reference engine computes, so `tx_totals`
    /// is *bit*-identical across any split history (float addition does
    /// not reassociate, so a base+remainder scheme would drift by ulps)
    tx_total: f64,
}

impl ClassState {
    fn new(
        link: Link,
        bond: Option<Arc<Bond>>,
        loss: Option<Arc<LossProcess>>,
        worker: u32,
    ) -> Self {
        let k = bond.as_ref().map_or(0, |b| b.k());
        Self {
            link,
            bond,
            loss,
            members: vec![worker],
            active: true,
            sent_last: false,
            aggregator: false,
            tm_prev: 0.0,
            path_tm_prev: vec![0.0; k],
            path_last: vec![PathTick::default(); k],
            last: WorkerTick::default(),
            tx_total: 0.0,
        }
    }

    fn min_member(&self) -> u32 {
        self.members[0]
    }
}

#[derive(Debug)]
pub struct VirtualClock {
    fabric: Fabric,
    /// two-tier topology state; `None` prices the flat star exactly as the
    /// pre-topology clock did (DESIGN.md §Topology)
    two_tier: Option<TwoTierState>,
    /// timeline classes (see module docs); every worker belongs to exactly
    /// one via `class_of`
    classes: Vec<ClassState>,
    class_of: Vec<u32>,
    /// the previous tick's active mask (diffed to find classes to split)
    mask: Vec<bool>,
    all_active: bool,
    /// tournament tree over class arrivals, keyed `(tc, min member)`
    tree: ArrivalTree,
    /// TS_k of the previous iteration (computation is in lockstep)
    ts_prev: f64,
    /// bounded ring over the sync-arrival history TC_k
    tc: TcRing,
    /// aggregation deadline D (DESIGN.md §Robustness): the sync of
    /// iteration k completes at `max(fastest, min(slowest, TS_k + D))`
    /// instead of waiting for the slowest arrival; `None` = wait-for-all
    /// (bit-identical — the cut logic never runs)
    deadline: Option<f64>,
    /// workers whose arrival missed the last tick's deadline cut (their
    /// gradients are absorbed next round by the pipeline); always empty
    /// while `deadline` is `None`
    late_buf: Vec<u32>,
    /// lazily materialized per-worker views (`worker_ticks`/`tx_totals`)
    worker_last: Vec<WorkerTick>,
    tx_cache: Vec<f64>,
    views_dirty: bool,
    /// opt-in structural event log (class splits, elections) for the
    /// tracing layer (DESIGN.md §Observability); empty while disabled
    events: Vec<ClockEvent>,
    log_events: bool,
}

/// What one tick reports back to the trainer (the slowest worker's view —
/// the pair that gates the aggregation).
#[derive(Clone, Copy, Debug)]
pub struct Tick {
    /// computation end of iteration k
    pub ts: f64,
    /// transmission end of the slowest-arriving worker
    pub tm: f64,
    /// sync arrival — the iteration's contribution to total training time
    pub tc: f64,
    /// pure transmission duration of the slowest-arriving worker's message
    pub tx_secs: f64,
    /// retransmission seconds (failed attempts + backoff gaps) of the
    /// gating worker's message; 0 on lossless runs
    pub retx_secs: f64,
}

/// One worker's timeline entry for the last tick.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerTick {
    /// transmission end TM_k^i
    pub tm: f64,
    /// arrival TC_k^i = TM_k^i + b_i
    pub tc: f64,
    /// pure transmission duration of this worker's message (the *final*
    /// attempt's wire time under loss, so `bits / tx_secs` stays the
    /// link's true rate for the bandwidth estimators)
    pub tx_secs: f64,
    /// seconds lost to failed attempts + backoff gaps before the final
    /// attempt started (0 on lossless transfers)
    pub retx_secs: f64,
    /// transmission attempts (1 = first try landed; 0 = no transfer
    /// this tick, e.g. masked out)
    pub attempts: u32,
}

/// One path's timeline entry for a bonded worker's last tick
/// (DESIGN.md §Bonding).
#[derive(Clone, Copy, Debug, Default)]
pub struct PathTick {
    /// transmission end of this path's share
    pub tm: f64,
    /// water-filling bit share this path carried (fractional — the
    /// scheduler splits at the exact covering time, not on bit boundaries)
    pub bits: f64,
    /// pure transmission duration of this path's share (0 when idle)
    pub tx_secs: f64,
}

/// Read-only view of one timeline class (see
/// [`VirtualClock::class_views`]).
#[derive(Clone, Copy, Debug)]
pub struct ClassView<'a> {
    /// ascending member worker ids; never empty
    pub members: &'a [u32],
    /// the class's last-tick report (zeroed semantics apply only via
    /// `sent_last`, exactly like [`VirtualClock::worker_ticks`])
    pub last: WorkerTick,
    pub active: bool,
    /// whether the class transmitted on the most recent tick
    pub sent_last: bool,
    /// multi-path bond (always a singleton class)
    pub bonded: bool,
    /// two-tier aggregator (always a singleton class)
    pub aggregator: bool,
}

/// One bonded tick: water-fill `bits` across the bond's paths starting no
/// earlier than `ts` on each, record per-path timelines, and report the
/// worker-level [`WorkerTick`] (tm = last path to stop transmitting,
/// tc = the bonded sync arrival, tx = summed per-path wire seconds).
fn tick_bonded(
    bond: &Bond,
    path_tm_prev: &mut [f64],
    path_last: &mut [PathTick],
    ts: f64,
    bits: u64,
) -> WorkerTick {
    let starts: Vec<f64> =
        path_tm_prev.iter().map(|&tm| tm.max(ts)).collect();
    let sched = bond.schedule(&starts, bits);
    let mut tm = f64::NEG_INFINITY;
    let mut tx_secs = 0.0;
    for p in 0..bond.k() {
        path_tm_prev[p] = sched.tx_end[p];
        path_last[p] = PathTick {
            tm: sched.tx_end[p],
            bits: sched.bits[p],
            tx_secs: sched.tx_secs[p],
        };
        tm = tm.max(sched.tx_end[p]);
        tx_secs += sched.tx_secs[p];
    }
    WorkerTick { tm, tc: sched.arrival, tx_secs, retx_secs: 0.0, attempts: 1 }
}

/// The lossy counterpart of [`tick_bonded`]: the whole payload is
/// retransmitted on loss (DESIGN.md §Robustness), so the final attempt's
/// water-filling schedule is what lands in the per-path timelines.
fn tick_bonded_lossy(
    bond: &Bond,
    loss: &LossProcess,
    worker: u32,
    msg: u64,
    path_tm_prev: &mut [f64],
    path_last: &mut [PathTick],
    ts: f64,
    bits: u64,
) -> WorkerTick {
    let starts: Vec<f64> =
        path_tm_prev.iter().map(|&tm| tm.max(ts)).collect();
    let (sched, attempts, retx_secs) =
        loss.price_bonded(bond, worker, msg, &starts, bits);
    let mut tm = f64::NEG_INFINITY;
    let mut tx_secs = 0.0;
    for p in 0..bond.k() {
        path_tm_prev[p] = sched.tx_end[p];
        path_last[p] = PathTick {
            tm: sched.tx_end[p],
            bits: sched.bits[p],
            tx_secs: sched.tx_secs[p],
        };
        tm = tm.max(sched.tx_end[p]);
        tx_secs += sched.tx_secs[p];
    }
    WorkerTick { tm, tc: sched.arrival, tx_secs, retx_secs, attempts }
}

/// One region's timeline entry for the last two-tier tick
/// (DESIGN.md §Topology).
#[derive(Clone, Copy, Debug, Default)]
pub struct RegionTick {
    /// region sync: the partial is ready at the aggregator — the slowest
    /// active member's intra-region arrival (≥ TS_k; TS_k itself when only
    /// the aggregator is active)
    pub sync: f64,
    /// WAN transmission end of the region partial
    pub wan_tm: f64,
    /// WAN arrival of the region partial at the leader
    pub wan_tc: f64,
    /// pure WAN transmission duration of the partial
    pub wan_tx_secs: f64,
    /// members that transmitted over intra-region links this tick
    /// (the aggregator's own gradient is local and never counted)
    pub senders: usize,
    /// false when no member of the region was active this tick — the
    /// region emitted nothing and its WAN timeline stayed frozen
    pub active: bool,
}

/// Per-region WAN timelines + last-tick reports of a two-tier topology.
#[derive(Debug)]
struct TwoTierState {
    regions: Vec<RegionTopo>,
    /// one link per *region* — the scarce cross-datacenter tier
    wan: Fabric,
    /// WAN-transmission end of the previous iteration, per region
    wan_tm_prev: Vec<f64>,
    region_last: Vec<RegionTick>,
    /// cumulative WAN transmission seconds per region
    wan_tx_total: Vec<f64>,
    /// cumulative bits shipped across each region's WAN link — the
    /// headline savings metric of hierarchical aggregation
    wan_bits_total: Vec<u64>,
    /// per-region `(class, member count)` groups — the class-level view of
    /// `regions[r].members`, rebuilt only when the class structure changes
    groups: Vec<Vec<(u32, u32)>>,
    groups_dirty: bool,
}

impl VirtualClock {
    pub fn new(fabric: Fabric) -> Self {
        let n = fabric.workers();
        let mut classes: Vec<ClassState> = Vec::new();
        let mut class_of = vec![0u32; n];
        // fabric link-class -> clock class; bonded workers stay singleton
        let mut map: Vec<Option<u32>> =
            vec![None; fabric.link_class_count()];
        for w in 0..n {
            let loss = fabric.loss_arc(w).cloned();
            if fabric.bond_arc(w).is_some() || loss.is_some() {
                // bonded and lossy workers price per-worker: singleton
                class_of[w] = classes.len() as u32;
                classes.push(ClassState::new(
                    fabric.link(w).clone(),
                    fabric.bond_arc(w).cloned(),
                    loss,
                    w as u32,
                ));
                continue;
            }
            let fc = fabric.link_class(w);
            match map[fc] {
                Some(c) => {
                    classes[c as usize].members.push(w as u32);
                    class_of[w] = c;
                }
                None => {
                    let c = classes.len() as u32;
                    map[fc] = Some(c);
                    class_of[w] = c;
                    classes.push(ClassState::new(
                        fabric.link(w).clone(),
                        None,
                        None,
                        w as u32,
                    ));
                }
            }
        }
        let tree = ArrivalTree::new(classes.len());
        Self {
            fabric,
            two_tier: None,
            classes,
            class_of,
            mask: vec![true; n],
            all_active: true,
            tree,
            ts_prev: 0.0,
            tc: TcRing::new(),
            deadline: None,
            late_buf: Vec::new(),
            worker_last: vec![WorkerTick::default(); n],
            tx_cache: vec![0.0; n],
            views_dirty: false,
            events: Vec::new(),
            log_events: false,
        }
    }

    /// Topology-aware constructor (DESIGN.md §Topology).
    /// [`Topology::Flat`] is exactly [`Self::new`] — the flat clock stays
    /// bit-identical to the fabric-only recurrence (`tests/topo.rs`); a
    /// [`Topology::TwoTier`] is validated against the fabric's worker
    /// count and priced by [`Self::tick_topo`].
    pub fn with_topology(
        fabric: Fabric,
        topo: Topology,
    ) -> anyhow::Result<Self> {
        topo.validate(fabric.workers())?;
        let mut clock = Self::new(fabric);
        if let Topology::TwoTier { regions, wan } = topo {
            let r = regions.len();
            let aggs: Vec<usize> =
                regions.iter().map(|x| x.aggregator).collect();
            clock.two_tier = Some(TwoTierState {
                regions,
                wan,
                wan_tm_prev: vec![0.0; r],
                region_last: vec![RegionTick::default(); r],
                wan_tx_total: vec![0.0; r],
                wan_bits_total: vec![0; r],
                groups: vec![Vec::new(); r],
                groups_dirty: true,
            });
            // aggregators advance by local hand-off: their timelines
            // diverge from plain members immediately, so carve them out
            for a in aggs {
                let c = clock.ensure_singleton(a);
                clock.classes[c].aggregator = true;
            }
        }
        Ok(clock)
    }

    /// Single-link compatibility constructor (a 1-worker fabric).
    pub fn single_link(link: Link) -> Self {
        Self::new(Fabric::new(vec![link]))
    }

    /// Split every class into singletons: the O(n) per-worker reference
    /// engine (exactly the pre-SoA recurrence), which the property tests
    /// and `bench_scale` compare the shared-class engine against.
    pub fn with_reference_scan(mut self) -> Self {
        for w in 0..self.class_of.len() {
            self.ensure_singleton(w);
        }
        self
    }

    pub fn fabric(&self) -> &Fabric {
        &self.fabric
    }

    pub fn workers(&self) -> usize {
        self.class_of.len()
    }

    /// Number of timeline classes currently tracked: 1 on a homogeneous
    /// fabric, n in reference mode; splits only ever grow it.
    pub fn timeline_classes(&self) -> usize {
        self.classes.len()
    }

    /// Per-worker (TM, TC, tx) of the last tick. Materialized lazily from
    /// the class states (O(n) on the first call after a tick, O(1) after).
    pub fn worker_ticks(&mut self) -> &[WorkerTick] {
        self.materialize_views();
        &self.worker_last
    }

    /// Per-path (tx end, bit share, tx secs) of worker `worker`'s last
    /// tick — empty on single-path workers (DESIGN.md §Bonding).
    pub fn path_ticks(&self, worker: usize) -> &[PathTick] {
        &self.classes[self.class_of[worker] as usize].path_last
    }

    /// Cumulative transmission seconds per worker (lazily materialized).
    pub fn tx_totals(&mut self) -> &[f64] {
        self.materialize_views();
        &self.tx_cache
    }

    fn materialize_views(&mut self) {
        if !self.views_dirty {
            return;
        }
        for cls in &self.classes {
            let wt = if cls.sent_last {
                cls.last
            } else {
                WorkerTick::default()
            };
            for &w in &cls.members {
                self.worker_last[w as usize] = wt;
                self.tx_cache[w as usize] = cls.tx_total;
            }
        }
        self.views_dirty = false;
    }

    /// Whether this clock prices a two-tier topology.
    pub fn is_two_tier(&self) -> bool {
        self.two_tier.is_some()
    }

    /// The two-tier regions (empty slice on a flat topology).
    pub fn regions(&self) -> &[RegionTopo] {
        self.two_tier.as_ref().map_or(&[], |tt| &tt.regions)
    }

    /// The per-region WAN fabric (None on a flat topology).
    pub fn wan_fabric(&self) -> Option<&Fabric> {
        self.two_tier.as_ref().map(|tt| &tt.wan)
    }

    /// Per-region (sync, WAN tm/tc/tx) of the last two-tier tick (empty
    /// slice on a flat topology).
    pub fn region_ticks(&self) -> &[RegionTick] {
        self.two_tier.as_ref().map_or(&[], |tt| &tt.region_last)
    }

    /// Cumulative bits shipped over each region's WAN link.
    pub fn wan_bits_totals(&self) -> &[u64] {
        self.two_tier.as_ref().map_or(&[], |tt| &tt.wan_bits_total)
    }

    /// Cumulative WAN transmission seconds per region (the WAN-tier
    /// counterpart of [`Self::tx_totals`]).
    pub fn wan_tx_totals(&self) -> &[f64] {
        self.two_tier.as_ref().map_or(&[], |tt| &tt.wan_tx_total)
    }

    /// Set the aggregation deadline D (DESIGN.md §Robustness): each sync
    /// completes at `max(fastest, min(slowest, TS_k + D))` — the clamp to
    /// the fastest arrival guarantees at least one gradient lands, so an
    /// absurdly tight D degrades to "take whatever arrived first", never
    /// to an empty aggregation. `None` (the default) is wait-for-all,
    /// bit-identical to the pre-deadline clock. Infinite or non-positive
    /// deadlines are rejected to keep `None` the one spelling of
    /// wait-for-all.
    pub fn set_deadline(&mut self, deadline: Option<f64>) {
        if let Some(d) = deadline {
            assert!(d > 0.0 && d.is_finite(), "deadline {d} must be finite > 0");
        }
        self.deadline = deadline;
        if deadline.is_none() {
            self.late_buf.clear();
        }
    }

    pub fn deadline(&self) -> Option<f64> {
        self.deadline
    }

    /// Workers whose arrival missed the last tick's deadline cut, in
    /// ascending worker order. Their gradients were *not* aggregated this
    /// round; the pipeline absorbs them next round at +1 staleness
    /// (DESIGN.md §Robustness). Empty on wait-for-all runs.
    pub fn late_workers(&self) -> &[u32] {
        &self.late_buf
    }

    /// Enable/disable the structural event log (class splits, aggregator
    /// elections). Off by default — pushes cost nothing while disabled.
    pub fn set_event_log(&mut self, on: bool) {
        self.log_events = on;
        if !on {
            self.events.clear();
        }
    }

    /// Take the structural events accumulated since the last drain.
    pub fn drain_events(&mut self) -> Vec<ClockEvent> {
        std::mem::take(&mut self.events)
    }

    /// The *fastest* arrival of the last tick — min `(tc, min member)`
    /// over classes that transmitted — the O(classes) input to streaming
    /// stall attribution ([`crate::obs::Attribution::record_flat`]).
    /// `None` before the first tick.
    pub fn fastest_last(&self) -> Option<WorkerTick> {
        let mut best: Option<(f64, u32, WorkerTick)> = None;
        for cls in &self.classes {
            if cls.active && cls.sent_last {
                let key = (cls.last.tc, cls.min_member());
                let better = match best {
                    None => true,
                    Some((t, m, _)) => key < (t, m),
                };
                if better {
                    best = Some((key.0, key.1, cls.last));
                }
            }
        }
        best.map(|(_, _, wt)| wt)
    }

    /// Read-only per-class views — the class-level observation path: one
    /// entry per timeline class instead of one per worker, so monitor
    /// updates cost O(live classes) (DESIGN.md §Observability).
    pub fn class_views(&self) -> impl Iterator<Item = ClassView<'_>> {
        self.classes.iter().map(|c| ClassView {
            members: &c.members,
            last: c.last,
            active: c.active,
            sent_last: c.sent_last,
            bonded: c.bond.is_some(),
            aggregator: c.aggregator,
        })
    }

    /// Split `worker` out of a shared class into its own singleton,
    /// preserving the (identical) timeline. No-op if already singleton.
    fn ensure_singleton(&mut self, worker: usize) -> usize {
        let c = self.class_of[worker] as usize;
        if self.classes[c].members.len() == 1 {
            return c;
        }
        // the clone keeps the shared timeline *and* the tx accumulator:
        // members of one class have bitwise-equal histories, so carrying
        // the fold forward (rather than a base + remainder split) keeps
        // `tx_totals` bit-identical to the singleton reference engine
        let mut newc = self.classes[c].clone();
        newc.members = vec![worker as u32];
        self.classes[c].members.retain(|&w| w != worker as u32);
        self.class_of[worker] = self.classes.len() as u32;
        let key = if newc.active && newc.sent_last {
            (newc.last.tc, worker as u32)
        } else {
            EMPTY_KEY
        };
        self.classes.push(newc);
        self.tree.push_slot();
        self.tree.set(self.classes.len() - 1, key);
        // the donor class may have lost its min member: refresh its key
        let donor = &self.classes[c];
        let donor_key = if donor.active && donor.sent_last {
            (donor.last.tc, donor.min_member())
        } else {
            EMPTY_KEY
        };
        self.tree.set(c, donor_key);
        if let Some(tt) = self.two_tier.as_mut() {
            tt.groups_dirty = true;
        }
        self.views_dirty = true;
        let id = self.classes.len() - 1;
        if self.log_events {
            self.events.push(ClockEvent::ClassSplit {
                from_class: c,
                new_class: id,
                members: 1,
                active: self.classes[id].active,
            });
        }
        id
    }

    /// Re-elect region `region`'s aggregator among its members marked
    /// `true` in `eligible` — the churn hook: a departing aggregator hands
    /// the role to the best-connected surviving member (`topo::elect`
    /// order). Returns `true` if the aggregator changed; a region with no
    /// eligible member keeps its stale aggregator and simply prices as
    /// inactive until a rejoin. No-op on a flat topology.
    pub fn reelect_aggregator(
        &mut self,
        region: usize,
        eligible: &[bool],
    ) -> bool {
        let new = {
            let Some(tt) = self.two_tier.as_ref() else {
                return false;
            };
            let members = &tt.regions[region].members;
            match elect_eligible(&self.fabric, members, eligible) {
                Some(n) => n,
                None => return false,
            }
        };
        let tt = self.two_tier.as_mut().expect("checked above");
        let old = tt.regions[region].aggregator;
        tt.regions[region].aggregator = new;
        if new == old {
            return false;
        }
        // the demoted aggregator keeps its (already singleton) class but
        // becomes a plain sender; the new one is carved out
        let oldc = self.class_of[old] as usize;
        self.classes[oldc].aggregator = false;
        let nc = self.ensure_singleton(new);
        self.classes[nc].aggregator = true;
        if let Some(tt) = self.two_tier.as_mut() {
            tt.groups_dirty = true;
        }
        if self.log_events {
            self.events.push(ClockEvent::AggregatorElected {
                region: region as u32,
                old: Some(old as u32),
                new: new as u32,
            });
        }
        true
    }

    /// Computation end of the next iteration:
    /// `TS_k = T_comp + max(TC_{k−1−τ}, TS_{k−1})`.
    fn next_ts(&self, t_comp: f64, tau: usize) -> f64 {
        let k = self.tc.len() + 1;
        let tc_delayed = if k as i64 - 1 - tau as i64 >= 1 {
            self.tc.get(k - 2 - tau)
        } else {
            0.0
        };
        t_comp + tc_delayed.max(self.ts_prev)
    }

    /// Bring class `active` bits in line with the mask, splitting classes
    /// whose members disagree (active members keep the class, the rest
    /// form a new frozen one). Returns `true` if any class split.
    fn reconcile_mask(&mut self, active: Option<&[bool]>) -> bool {
        let n = self.class_of.len();
        match active {
            None => {
                if self.all_active {
                    return false;
                }
                // everyone transmits again: a frozen class's stale tm_prev
                // is dominated by max(·, TS) exactly like a rejoin
                for cls in &mut self.classes {
                    cls.active = true;
                }
                self.mask.fill(true);
                self.all_active = true;
                false
            }
            Some(m) => {
                assert_eq!(m.len(), n, "mask/worker mismatch");
                assert!(m.iter().any(|&a| a), "active set must be non-empty");
                if m == &self.mask[..] {
                    return false;
                }
                let mut touched: Vec<u32> = Vec::new();
                for i in 0..n {
                    if m[i] != self.mask[i] {
                        let c = self.class_of[i];
                        if !touched.contains(&c) {
                            touched.push(c);
                        }
                    }
                }
                let mut split = false;
                for c in touched {
                    split |= self.apply_mask_to_class(c as usize, m);
                }
                self.mask.copy_from_slice(m);
                self.all_active = m.iter().all(|&a| a);
                split
            }
        }
    }

    /// Apply the mask to one class; splits it when members disagree.
    /// Returns `true` on a split.
    fn apply_mask_to_class(&mut self, c: usize, m: &[bool]) -> bool {
        let want = m[self.classes[c].members[0] as usize];
        let (keep, moved): (Vec<u32>, Vec<u32>) = self.classes[c]
            .members
            .iter()
            .copied()
            .partition(|&w| m[w as usize] == want);
        let did_split = !moved.is_empty();
        if did_split {
            // mixed mask: the disagreeing members get their own class with
            // the same (shared) timeline and tx fold — the split preserves
            // every value bit-for-bit
            let mut newc = self.classes[c].clone();
            newc.members = moved;
            newc.active = !want;
            if !newc.active {
                newc.sent_last = false;
                for p in newc.path_last.iter_mut() {
                    *p = PathTick::default();
                }
            }
            let id = self.classes.len() as u32;
            for &w in &newc.members {
                self.class_of[w as usize] = id;
            }
            let key = if newc.active && newc.sent_last {
                (newc.last.tc, newc.min_member())
            } else {
                EMPTY_KEY
            };
            self.classes.push(newc);
            self.tree.push_slot();
            self.tree.set(id as usize, key);
            self.classes[c].members = keep;
            if self.log_events {
                let nc = &self.classes[id as usize];
                self.events.push(ClockEvent::ClassSplit {
                    from_class: c,
                    new_class: id as usize,
                    members: nc.members.len(),
                    active: nc.active,
                });
            }
        }
        let cls = &mut self.classes[c];
        cls.active = want;
        if !want {
            // masked out: timeline frozen, members report zeroed ticks so
            // per-link monitors see no phantom transfers
            cls.sent_last = false;
            for p in cls.path_last.iter_mut() {
                *p = PathTick::default();
            }
            self.tree.set(c, EMPTY_KEY);
        } else if did_split {
            // the donor kept only active members; refresh its (possibly
            // changed) min-member key
            let key = if cls.sent_last {
                (cls.last.tc, cls.members[0])
            } else {
                EMPTY_KEY
            };
            self.tree.set(c, key);
        }
        if did_split {
            if let Some(tt) = self.two_tier.as_mut() {
                tt.groups_dirty = true;
            }
        }
        did_split
    }

    /// Advance one iteration (k, 1-based) with every worker transmitting.
    pub fn tick(&mut self, t_comp: f64, tau: usize, bits: u64) -> Tick {
        self.tick_members(t_comp, tau, bits, None)
    }

    /// Advance one iteration over the *active* worker set (elastic
    /// membership, DESIGN.md §Elasticity). `active = None` means all
    /// workers and is exactly [`Self::tick`]. With a mask, only masked-in
    /// workers transmit: a departed worker's timeline freezes (its
    /// `tm_prev` goes stale, harmlessly dominated by `max(·, TS_k)` on
    /// rejoin) and the sync arrival is the max over active arrivals only.
    /// Masked-out workers report a zeroed [`WorkerTick`] so per-link
    /// monitors see no phantom transfers. A mask that splits a class
    /// splits the timeline sharing permanently — an all-true-forever run
    /// (`ChurnSpec::none()`) never splits and stays bit-identical to
    /// [`Self::tick`].
    pub fn tick_members(
        &mut self,
        t_comp: f64,
        tau: usize,
        bits: u64,
        active: Option<&[bool]>,
    ) -> Tick {
        self.reconcile_mask(active);
        let ts = self.next_ts(t_comp, tau);
        // 0-based message id of this iteration: the loss draws key on it,
        // so pricing is identical across engines and evaluation orders
        let msg = self.tc.len() as u64;
        for c in 0..self.classes.len() {
            let cls = &mut self.classes[c];
            if !cls.active {
                continue;
            }
            let wt = if let Some(bond) = cls.bond.clone() {
                match cls.loss.clone() {
                    Some(lp) => tick_bonded_lossy(
                        &bond,
                        &lp,
                        cls.members[0],
                        msg,
                        &mut cls.path_tm_prev,
                        &mut cls.path_last,
                        ts,
                        bits,
                    ),
                    None => tick_bonded(
                        &bond,
                        &mut cls.path_tm_prev,
                        &mut cls.path_last,
                        ts,
                        bits,
                    ),
                }
            } else {
                let start = cls.tm_prev.max(ts);
                match &cls.loss {
                    Some(lp) => {
                        let out =
                            lp.price(&cls.link, cls.members[0], msg, start, bits);
                        WorkerTick {
                            tm: out.tm,
                            tc: out.tm + cls.link.latency(),
                            tx_secs: out.tx_secs,
                            retx_secs: out.retx_secs,
                            attempts: out.attempts,
                        }
                    }
                    None => {
                        let tm = cls.link.transfer_end(start, bits);
                        WorkerTick {
                            tm,
                            tc: tm + cls.link.latency(),
                            tx_secs: tm - start,
                            retx_secs: 0.0,
                            attempts: 1,
                        }
                    }
                }
            };
            cls.tm_prev = wt.tm;
            cls.tx_total += wt.tx_secs;
            cls.last = wt;
            cls.sent_last = true;
            self.tree.set(c, (wt.tc, cls.members[0]));
            if self.log_events && wt.attempts > 1 {
                self.events.push(ClockEvent::Retransmit {
                    worker: cls.members[0],
                    attempts: wt.attempts,
                    retx_secs: wt.retx_secs,
                });
            }
        }
        let w = self.tree.winner();
        debug_assert!(
            self.classes[w].active && self.classes[w].sent_last,
            "active set must be non-empty"
        );
        #[cfg(debug_assertions)]
        self.assert_winner_matches_scan(w);
        let slowest = self.classes[w].last;
        self.late_buf.clear();
        let (tc_k, gate) = match self.deadline {
            Some(d) if ts + d < slowest.tc => self.deadline_cut(ts + d),
            _ => (slowest.tc, slowest),
        };
        self.ts_prev = ts;
        self.tc.push(tc_k);
        self.views_dirty = true;
        Tick {
            ts,
            tm: gate.tm,
            tc: tc_k,
            tx_secs: gate.tx_secs,
            retx_secs: gate.retx_secs,
        }
    }

    /// Apply a binding deadline cut at `cut < slowest arrival`: the sync
    /// completes at `max(fastest arrival, cut)`, classes that arrive later
    /// are reported late (their gradients get absorbed next round), and
    /// the *gating* on-time class — last arrival ≤ the cut, ties to the
    /// smaller min member, mirroring the wait-for-all tie-break — supplies
    /// the tick's (tm, tx, retx) view. The fastest clamp guarantees the
    /// gate exists. Links are NOT preempted: every in-flight transfer keeps
    /// its `tm_prev`, so late workers' links stay busy into the next round
    /// exactly as the queueing recurrence demands.
    fn deadline_cut(&mut self, cut: f64) -> (f64, WorkerTick) {
        let mut fastest = f64::INFINITY;
        for cls in &self.classes {
            if cls.active && cls.sent_last {
                fastest = fastest.min(cls.last.tc);
            }
        }
        let tc_k = cut.max(fastest);
        let mut gate: Option<(f64, u32, WorkerTick)> = None;
        for cls in &self.classes {
            if !(cls.active && cls.sent_last) {
                continue;
            }
            if cls.last.tc <= tc_k {
                let (t, m) = (cls.last.tc, cls.min_member());
                let better = match gate {
                    None => true,
                    Some((bt, bm, _)) => t > bt || (t == bt && m < bm),
                };
                if better {
                    gate = Some((t, m, cls.last));
                }
            } else {
                self.late_buf.extend_from_slice(&cls.members);
            }
        }
        self.late_buf.sort_unstable();
        if self.log_events && !self.late_buf.is_empty() {
            self.events.push(ClockEvent::DeadlineCut {
                cut: tc_k,
                late: self.late_buf.len(),
            });
        }
        let (_, _, wt) = gate.expect("fastest clamp guarantees a gate");
        (tc_k, wt)
    }

    /// The retired O(n) scan, kept as the debug-build reference for the
    /// tournament tree: first strict max over classes in min-member order.
    #[cfg(debug_assertions)]
    fn assert_winner_matches_scan(&self, winner: usize) {
        let mut best_tc = f64::NEG_INFINITY;
        let mut best_m = u32::MAX;
        for cls in &self.classes {
            if cls.active && cls.sent_last {
                let (t, m) = (cls.last.tc, cls.min_member());
                if t > best_tc || (t == best_tc && m < best_m) {
                    best_tc = t;
                    best_m = m;
                }
            }
        }
        let win = &self.classes[winner];
        debug_assert_eq!(
            best_tc.to_bits(),
            win.last.tc.to_bits(),
            "tournament tree disagrees with the reference scan"
        );
        debug_assert_eq!(best_m, win.min_member());
    }

    /// Advance one iteration on a two-tier topology (DESIGN.md §Topology):
    /// each active member ships its δ_lan-compressed gradient (`lan_bits`)
    /// over its own intra-region link; region r's partial is ready at the
    /// slowest member arrival (the region sync), then crosses the WAN as
    /// `wan_bits` over the region's own WAN link; the global aggregation
    /// completes at the slowest region partial's arrival, and that arrival
    /// is what the τ-delayed wait `TC_{k−1−τ}` sees. On a flat topology
    /// this delegates to [`Self::tick_members`] with `lan_bits`
    /// (bit-identical — `tests/topo.rs`) and `wan_bits` is ignored.
    pub fn tick_topo(
        &mut self,
        t_comp: f64,
        tau: usize,
        lan_bits: u64,
        wan_bits: u64,
        active: Option<&[bool]>,
    ) -> Tick {
        if self.two_tier.is_none() {
            return self.tick_members(t_comp, tau, lan_bits, active);
        }
        self.reconcile_mask(active);
        self.rebuild_region_groups();
        let ts = self.next_ts(t_comp, tau);
        let msg = self.tc.len() as u64;
        // class pass: active aggregators hand off locally (timeline
        // advances with TS, no wire), every other active class ships
        // lan_bits over its link/bond
        for cls in &mut self.classes {
            if !cls.active {
                continue;
            }
            if cls.aggregator {
                cls.tm_prev = ts;
                for p in cls.path_tm_prev.iter_mut() {
                    *p = ts;
                }
                for p in cls.path_last.iter_mut() {
                    *p = PathTick::default();
                }
                cls.last = WorkerTick {
                    tm: ts,
                    tc: ts,
                    tx_secs: 0.0,
                    retx_secs: 0.0,
                    attempts: 1,
                };
                cls.sent_last = true;
                continue;
            }
            let wt = if let Some(bond) = cls.bond.clone() {
                match cls.loss.clone() {
                    Some(lp) => tick_bonded_lossy(
                        &bond,
                        &lp,
                        cls.members[0],
                        msg,
                        &mut cls.path_tm_prev,
                        &mut cls.path_last,
                        ts,
                        lan_bits,
                    ),
                    None => tick_bonded(
                        &bond,
                        &mut cls.path_tm_prev,
                        &mut cls.path_last,
                        ts,
                        lan_bits,
                    ),
                }
            } else {
                let start = cls.tm_prev.max(ts);
                match &cls.loss {
                    Some(lp) => {
                        let out = lp.price(
                            &cls.link,
                            cls.members[0],
                            msg,
                            start,
                            lan_bits,
                        );
                        WorkerTick {
                            tm: out.tm,
                            tc: out.tm + cls.link.latency(),
                            tx_secs: out.tx_secs,
                            retx_secs: out.retx_secs,
                            attempts: out.attempts,
                        }
                    }
                    None => {
                        let tm = cls.link.transfer_end(start, lan_bits);
                        WorkerTick {
                            tm,
                            tc: tm + cls.link.latency(),
                            tx_secs: tm - start,
                            retx_secs: 0.0,
                            attempts: 1,
                        }
                    }
                }
            };
            cls.tm_prev = wt.tm;
            cls.tx_total += wt.tx_secs;
            cls.last = wt;
            cls.sent_last = true;
        }
        // region pass: O(regions + classes) via the precomputed groups
        let tt = self.two_tier.as_mut().expect("two-tier");
        let mut slowest = RegionTick::default();
        let mut any_region = false;
        for r in 0..tt.regions.len() {
            let mut sync = ts;
            let mut senders = 0usize;
            let mut any_member = false;
            for &(c, count) in &tt.groups[r] {
                let cls = &self.classes[c as usize];
                if !cls.active {
                    continue;
                }
                any_member = true;
                if cls.aggregator {
                    continue;
                }
                senders += count as usize;
                sync = sync.max(cls.last.tc);
            }
            if !any_member {
                // no active member: nothing to aggregate, WAN frozen
                tt.region_last[r] = RegionTick::default();
                continue;
            }
            // WAN tier: the partial crosses the region's own WAN link
            let wan_link = tt.wan.link(r);
            let start = tt.wan_tm_prev[r].max(sync);
            let wan_tm = wan_link.transfer_end(start, wan_bits);
            let rt = RegionTick {
                sync,
                wan_tm,
                wan_tc: wan_tm + wan_link.latency(),
                wan_tx_secs: wan_tm - start,
                senders,
                active: true,
            };
            tt.wan_tm_prev[r] = wan_tm;
            tt.wan_tx_total[r] += rt.wan_tx_secs;
            tt.wan_bits_total[r] += wan_bits;
            tt.region_last[r] = rt;
            if !any_region || rt.wan_tc > slowest.wan_tc {
                slowest = rt;
            }
            any_region = true;
        }
        assert!(any_region, "no region had an active member");
        self.late_buf.clear();
        // two-tier deadline: the *global* aggregation is cut at TS_k + D
        // over region partials (clamped to the fastest partial). Late
        // partials are a pricing-level approximation here — region-level
        // EF absorption would need per-region optimizer state, so the
        // late set is not reported for absorb on two-tier runs
        // (DESIGN.md §Robustness)
        let (tc_k, gate) = match self.deadline {
            Some(d) if ts + d < slowest.wan_tc => {
                let cut = ts + d;
                let mut fastest = f64::INFINITY;
                for rt in &tt.region_last {
                    if rt.active {
                        fastest = fastest.min(rt.wan_tc);
                    }
                }
                let tc_k = cut.max(fastest);
                let mut gate = RegionTick::default();
                let mut found = false;
                let mut late = 0usize;
                for rt in &tt.region_last {
                    if !rt.active {
                        continue;
                    }
                    if rt.wan_tc <= tc_k {
                        if !found || rt.wan_tc > gate.wan_tc {
                            gate = *rt;
                            found = true;
                        }
                    } else {
                        late += 1;
                    }
                }
                debug_assert!(found, "fastest clamp guarantees a gate");
                if self.log_events && late > 0 {
                    self.events
                        .push(ClockEvent::DeadlineCut { cut: tc_k, late });
                }
                (tc_k, gate)
            }
            _ => (slowest.wan_tc, slowest),
        };
        self.ts_prev = ts;
        self.tc.push(tc_k);
        self.views_dirty = true;
        Tick {
            ts,
            tm: gate.wan_tm,
            tc: tc_k,
            tx_secs: gate.wan_tx_secs,
            retx_secs: 0.0,
        }
    }

    /// Recompute the per-region class groups after a class-structure
    /// change (split, re-election). O(workers + regions · classes); runs
    /// only when `groups_dirty`.
    fn rebuild_region_groups(&mut self) {
        let Some(tt) = self.two_tier.as_mut() else {
            return;
        };
        if !tt.groups_dirty {
            return;
        }
        let ncls = self.classes.len();
        let mut pos: Vec<u32> = vec![u32::MAX; ncls];
        for (r, region) in tt.regions.iter().enumerate() {
            let counts = &mut tt.groups[r];
            counts.clear();
            for &wkr in region.members.iter() {
                let c = self.class_of[wkr];
                let p = pos[c as usize];
                if p == u32::MAX {
                    pos[c as usize] = counts.len() as u32;
                    counts.push((c, 1));
                } else {
                    counts[p as usize].1 += 1;
                }
            }
            // reset the scratch for the next region
            for &(c, _) in counts.iter() {
                pos[c as usize] = u32::MAX;
            }
        }
        tt.groups_dirty = false;
    }

    pub fn iters(&self) -> usize {
        self.tc.len()
    }

    /// Total elapsed virtual time (sync TC of the last iteration).
    pub fn now(&self) -> f64 {
        self.tc.last()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::BandwidthTrace;
    use crate::timesim::{EventSim, PipelineParams};

    #[test]
    fn matches_event_sim_with_constant_params() {
        let p = PipelineParams {
            a: 1e8,
            b: 0.2,
            delta: 0.1,
            tau: 2,
            t_comp: 0.05,
            s_g: 1e9,
        };
        let mut clock = VirtualClock::single_link(Link::new(
            BandwidthTrace::constant(p.a),
            p.b,
        ));
        let bits = (p.delta * p.s_g) as u64;
        for _ in 0..300 {
            clock.tick(p.t_comp, p.tau, bits);
        }
        let sim = EventSim::run(&p, 300);
        assert!(
            (clock.now() - sim.total_time()).abs() < 1e-6,
            "{} vs {}",
            clock.now(),
            sim.total_time()
        );
    }

    #[test]
    fn time_is_monotone_under_dynamic_params() {
        let mut clock = VirtualClock::single_link(Link::new(
            BandwidthTrace::constant(5e7),
            0.1,
        ));
        let mut prev = 0.0;
        for k in 1..100usize {
            let tau = k % 4;
            let bits = 1_000_000 + (k as u64 % 7) * 500_000;
            let t = clock.tick(0.02 + 0.001 * (k % 3) as f64, tau, bits);
            assert!(t.tc >= prev);
            assert!(t.tm >= t.ts);
            prev = t.tc;
        }
    }

    #[test]
    fn homogeneous_fabric_bit_identical_to_single_link() {
        let trace = BandwidthTrace::constant(2e7);
        let link = Link::new(trace.clone(), 0.15);
        let mut single = VirtualClock::single_link(link.clone());
        let mut fab = VirtualClock::new(Fabric::replicate(link, 5));
        // semantically identical fabric that defeats class sharing for one
        // link (a no-op Scaled(1.0) wrapper forms a second class) — both
        // classes must price bit-for-bit like the single link
        let mut mixed = VirtualClock::new(Fabric::new(vec![
            Link::new(trace.clone(), 0.15),
            Link::new(trace.clone(), 0.15),
            Link::new(trace.clone(), 0.15),
            Link::new(trace.clone(), 0.15),
            Link::new(trace.scaled(1.0), 0.15),
        ]));
        assert_eq!(fab.timeline_classes(), 1);
        assert_eq!(mixed.timeline_classes(), 2);
        for k in 1..=400usize {
            let tau = k % 3;
            let bits = 500_000 + (k as u64 % 11) * 250_000;
            let a = single.tick(0.07, tau, bits);
            let b = fab.tick(0.07, tau, bits);
            let c = mixed.tick(0.07, tau, bits);
            assert_eq!(a.ts.to_bits(), b.ts.to_bits(), "k={k}");
            assert_eq!(a.tm.to_bits(), b.tm.to_bits(), "k={k}");
            assert_eq!(a.tc.to_bits(), b.tc.to_bits(), "k={k}");
            assert_eq!(a.tx_secs.to_bits(), b.tx_secs.to_bits(), "k={k}");
            assert_eq!(a.tc.to_bits(), c.tc.to_bits(), "k={k} (two classes)");
            assert_eq!(a.tm.to_bits(), c.tm.to_bits(), "k={k} (two classes)");
        }
        assert_eq!(single.now().to_bits(), fab.now().to_bits());
        assert_eq!(single.now().to_bits(), mixed.now().to_bits());
    }

    #[test]
    fn reference_scan_mode_is_bit_identical_to_class_sharing() {
        let fabric = || {
            Fabric::with_straggler(
                6,
                BandwidthTrace::constant(1e8),
                0.1,
                0.5,
                2.0,
            )
        };
        let mut shared = VirtualClock::new(fabric());
        let mut reference = VirtualClock::new(fabric()).with_reference_scan();
        assert_eq!(shared.timeline_classes(), 2);
        assert_eq!(reference.timeline_classes(), 6);
        let mut mask = vec![true; 6];
        for k in 1..=300usize {
            if k % 37 == 0 {
                mask[k % 6] = !mask[k % 6];
                if !mask.iter().any(|&a| a) {
                    mask[0] = true;
                }
            }
            let bits = 600_000 + (k as u64 % 9) * 150_000;
            let a = shared.tick_members(0.05, k % 4, bits, Some(&mask));
            let b = reference.tick_members(0.05, k % 4, bits, Some(&mask));
            assert_eq!(a.tc.to_bits(), b.tc.to_bits(), "k={k}");
            assert_eq!(a.tm.to_bits(), b.tm.to_bits(), "k={k}");
            assert_eq!(a.tx_secs.to_bits(), b.tx_secs.to_bits(), "k={k}");
        }
        // per-worker views agree too
        let sw = shared.worker_ticks().to_vec();
        let rw = reference.worker_ticks().to_vec();
        for i in 0..6 {
            assert_eq!(sw[i].tc.to_bits(), rw[i].tc.to_bits(), "worker {i}");
        }
        let st = shared.tx_totals().to_vec();
        let rt = reference.tx_totals().to_vec();
        for i in 0..6 {
            assert_eq!(st[i].to_bits(), rt[i].to_bits(), "worker {i}");
        }
    }

    #[test]
    fn all_true_mask_is_bit_identical_to_tick() {
        // the determinism contract at the clock level: a mask that never
        // masks anyone out must not perturb a single bit (no splits)
        let fabric = || {
            Fabric::with_straggler(
                4,
                BandwidthTrace::constant(1e8),
                0.1,
                0.5,
                2.0,
            )
        };
        let mut plain = VirtualClock::new(fabric());
        let mut masked = VirtualClock::new(fabric());
        let mask = vec![true; 4];
        for k in 1..=200usize {
            let bits = 1_000_000 + (k as u64 % 5) * 300_000;
            let a = plain.tick(0.05, k % 3, bits);
            let b = masked.tick_members(0.05, k % 3, bits, Some(&mask));
            assert_eq!(a.tc.to_bits(), b.tc.to_bits(), "k={k}");
            assert_eq!(a.tm.to_bits(), b.tm.to_bits(), "k={k}");
        }
        assert_eq!(masked.timeline_classes(), 2, "no splits on all-true");
    }

    #[test]
    fn masked_straggler_stops_gating_and_rejoins_stale_free() {
        let fabric = Fabric::with_straggler(
            4,
            BandwidthTrace::constant(1e8),
            0.1,
            0.25,
            2.0,
        );
        let mut clock = VirtualClock::new(fabric);
        let bits = 4_000_000u64;
        // straggler present: it gates the sync arrival
        let mut mask = vec![true; 4];
        let t0 = clock.tick_members(0.05, 1, bits, Some(&mask));
        assert_eq!(t0.tc.to_bits(), clock.worker_ticks()[0].tc.to_bits());
        // straggler departs: sync snaps to the healthy links' pace and its
        // WorkerTick zeroes (no phantom transfer for the monitors)
        mask[0] = false;
        let t1 = clock.tick_members(0.05, 1, bits, Some(&mask));
        let healthy = clock.worker_ticks()[1];
        assert_eq!(t1.tc.to_bits(), healthy.tc.to_bits());
        assert_eq!(clock.worker_ticks()[0].tx_secs, 0.0);
        let tx0_frozen = clock.tx_totals()[0];
        for _ in 0..20 {
            clock.tick_members(0.05, 1, bits, Some(&mask));
        }
        assert_eq!(clock.tx_totals()[0], tx0_frozen, "timeline frozen");
        // rejoin: the stale tm_prev is dominated by TS, so the straggler
        // resumes gating immediately without time travel
        mask[0] = true;
        let t2 = clock.tick_members(0.05, 1, bits, Some(&mask));
        assert_eq!(t2.tc.to_bits(), clock.worker_ticks()[0].tc.to_bits());
        assert!(t2.tc > t1.tc);
        assert!(clock.tx_totals()[0] > tx0_frozen);
    }

    fn two_tier_clock(
        n: usize,
        per_region: usize,
        lan_bps: f64,
        lan_lat: f64,
        wan_bps: f64,
        wan_lat: f64,
    ) -> VirtualClock {
        assert_eq!(n % per_region, 0);
        let regions: Vec<RegionTopo> = (0..n / per_region)
            .map(|r| RegionTopo {
                members: (r * per_region..(r + 1) * per_region).collect(),
                aggregator: r * per_region,
            })
            .collect();
        let wan = Fabric::homogeneous(
            regions.len(),
            BandwidthTrace::constant(wan_bps),
            wan_lat,
        );
        VirtualClock::with_topology(
            Fabric::homogeneous(n, BandwidthTrace::constant(lan_bps), lan_lat),
            Topology::TwoTier { regions, wan },
        )
        .unwrap()
    }

    #[test]
    fn flat_topology_tick_topo_is_bit_identical() {
        let fabric = || {
            Fabric::with_straggler(
                4,
                BandwidthTrace::constant(1e8),
                0.1,
                0.5,
                2.0,
            )
        };
        let mut plain = VirtualClock::new(fabric());
        let mut topo =
            VirtualClock::with_topology(fabric(), Topology::Flat).unwrap();
        assert!(!topo.is_two_tier());
        assert!(topo.regions().is_empty() && topo.region_ticks().is_empty());
        for k in 1..=300usize {
            let bits = 800_000 + (k as u64 % 7) * 300_000;
            let a = plain.tick(0.05, k % 3, bits);
            // wan_bits must be entirely ignored on a flat topology
            let b = topo.tick_topo(0.05, k % 3, bits, 123_456_789, None);
            assert_eq!(a.ts.to_bits(), b.ts.to_bits(), "k={k}");
            assert_eq!(a.tm.to_bits(), b.tm.to_bits(), "k={k}");
            assert_eq!(a.tc.to_bits(), b.tc.to_bits(), "k={k}");
            assert_eq!(a.tx_secs.to_bits(), b.tx_secs.to_bits(), "k={k}");
        }
        assert_eq!(plain.now().to_bits(), topo.now().to_bits());
    }

    #[test]
    fn two_tier_tick_prices_both_hops() {
        let mut clock = two_tier_clock(4, 2, 1e8, 0.01, 1e7, 0.3);
        // aggregators are carved into singleton classes at construction:
        // 1 shared member class + 2 aggregator singletons
        assert_eq!(clock.timeline_classes(), 3);
        let t = clock.tick_topo(0.1, 0, 1_000_000, 1_000_000, None);
        // region sync: worker 1's LAN arrival = 0.1 + 0.01s tx + 0.01 lat
        let rts = clock.region_ticks();
        assert_eq!(rts.len(), 2);
        for rt in rts {
            assert!(rt.active);
            assert_eq!(rt.senders, 1, "aggregator never sends over LAN");
            assert!((rt.sync - 0.12).abs() < 1e-12, "sync={}", rt.sync);
            // WAN: 0.1s transfer at 1e7 bps + 0.3s latency
            assert!((rt.wan_tc - 0.52).abs() < 1e-12, "{}", rt.wan_tc);
            assert!(rt.sync >= t.ts);
            assert!(rt.wan_tc >= rt.sync);
        }
        // global sync = the slowest region's WAN arrival
        let max_wan =
            rts.iter().map(|r| r.wan_tc).fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(t.tc.to_bits(), max_wan.to_bits());
        // aggregators never transmit over the LAN tier
        assert_eq!(clock.worker_ticks()[0].tx_secs, 0.0);
        assert_eq!(clock.worker_ticks()[2].tx_secs, 0.0);
        assert!(clock.worker_ticks()[1].tx_secs > 0.0);
        assert_eq!(clock.wan_bits_totals(), &[1_000_000, 1_000_000]);
        assert!(clock.wan_tx_totals().iter().all(|&s| s > 0.0));
    }

    #[test]
    fn two_tier_masked_region_freezes_and_lone_aggregator_syncs_at_ts() {
        let mut clock = two_tier_clock(4, 2, 1e8, 0.01, 1e7, 0.3);
        let mut mask = vec![true; 4];
        // region 1 fully departs: it emits nothing, its WAN stays frozen
        mask[2] = false;
        mask[3] = false;
        let t = clock.tick_topo(0.1, 0, 1_000_000, 1_000_000, Some(&mask));
        let rts = clock.region_ticks();
        assert!(rts[0].active && !rts[1].active);
        assert_eq!(t.tc.to_bits(), rts[0].wan_tc.to_bits());
        assert_eq!(clock.wan_bits_totals()[1], 0);
        // region 0 loses its non-aggregator member: sync collapses to TS
        mask[1] = false;
        let t2 = clock.tick_topo(0.1, 0, 1_000_000, 1_000_000, Some(&mask));
        let rt = clock.region_ticks()[0];
        assert_eq!(rt.senders, 0);
        assert_eq!(rt.sync.to_bits(), t2.ts.to_bits());
        assert!(t2.tc > t.tc);
    }

    #[test]
    fn reelection_moves_the_aggregator_role() {
        let mut clock = two_tier_clock(4, 2, 1e8, 0.01, 1e7, 0.3);
        assert_eq!(clock.regions()[0].aggregator, 0);
        let mut eligible = vec![true; 4];
        eligible[0] = false;
        assert!(clock.reelect_aggregator(0, &eligible));
        assert_eq!(clock.regions()[0].aggregator, 1);
        // with nobody eligible the stale aggregator stays put
        eligible[1] = false;
        assert!(!clock.reelect_aggregator(0, &eligible));
        assert_eq!(clock.regions()[0].aggregator, 1);
    }

    #[test]
    fn straggler_gates_sync_arrival() {
        let fabric = Fabric::with_straggler(
            4,
            BandwidthTrace::constant(1e8),
            0.1,
            0.25,
            2.0,
        );
        let mut clock = VirtualClock::new(fabric);
        for _ in 0..50 {
            let tick = clock.tick(0.05, 1, 4_000_000);
            let wts = clock.worker_ticks();
            // the sync arrival is exactly the slowest worker's arrival
            let max_tc =
                wts.iter().map(|w| w.tc).fold(f64::NEG_INFINITY, f64::max);
            assert_eq!(tick.tc.to_bits(), max_tc.to_bits());
            // worker 0 (quarter bandwidth, double latency) is the straggler
            assert_eq!(tick.tc.to_bits(), wts[0].tc.to_bits());
            let straggler_tx = wts[0].tx_secs;
            for w in &wts[1..] {
                assert!(w.tc <= tick.tc);
                assert!(w.tx_secs < straggler_tx);
            }
        }
        // the straggler accumulated 4x the healthy transmission time
        let tx = clock.tx_totals();
        assert!((tx[0] / tx[1] - 4.0).abs() < 1e-6, "{tx:?}");
    }

    #[test]
    fn k1_bonded_clock_is_bit_identical_to_the_plain_fabric() {
        // the bond determinism contract at the clock level: wrapping every
        // link in a 1-path bond must not perturb a single bit, even though
        // it forces singleton classes
        let link = Link::new(
            BandwidthTrace::new(crate::netsim::TraceKind::Sine {
                mean_bps: 8e7,
                amp_bps: 3e7,
                period_s: 40.0,
            }),
            0.12,
        );
        let plain_fabric = Fabric::replicate(link.clone(), 3);
        let mut bonded_fabric = Fabric::replicate(link.clone(), 3);
        for i in 0..3 {
            bonded_fabric.set_bond(i, Bond::single(link.clone()));
        }
        let mut plain = VirtualClock::new(plain_fabric);
        let mut bonded = VirtualClock::new(bonded_fabric);
        assert_eq!(plain.timeline_classes(), 1);
        assert_eq!(bonded.timeline_classes(), 3, "bonds stay singleton");
        for k in 1..=300usize {
            let bits = if k % 13 == 0 {
                0
            } else {
                700_000 + (k as u64 % 9) * 400_000
            };
            let a = plain.tick(0.06, k % 3, bits);
            let b = bonded.tick(0.06, k % 3, bits);
            assert_eq!(a.ts.to_bits(), b.ts.to_bits(), "k={k}");
            assert_eq!(a.tm.to_bits(), b.tm.to_bits(), "k={k}");
            assert_eq!(a.tc.to_bits(), b.tc.to_bits(), "k={k}");
            assert_eq!(a.tx_secs.to_bits(), b.tx_secs.to_bits(), "k={k}");
        }
        assert_eq!(plain.now().to_bits(), bonded.now().to_bits());
        assert_eq!(bonded.path_ticks(0).len(), 1);
        assert_eq!(plain.path_ticks(0).len(), 0);
    }

    #[test]
    fn bonded_worker_splits_bits_and_arrives_no_later() {
        let fast = Link::new(BandwidthTrace::constant(1e8), 0.1);
        let slow = Link::new(BandwidthTrace::constant(5e7), 0.1);
        let mut solo_fabric = Fabric::replicate(fast.clone(), 2);
        solo_fabric.set_link(1, fast.clone()); // keep it non-trivial
        let mut bonded_fabric = solo_fabric.clone();
        bonded_fabric
            .set_bond(0, Bond::new(vec![fast.clone(), slow.clone()]));
        let mut solo = VirtualClock::new(solo_fabric);
        let mut bonded = VirtualClock::new(bonded_fabric);
        let bits = 6_000_000u64;
        for _ in 0..30 {
            let a = solo.tick(0.05, 1, bits);
            let b = bonded.tick(0.05, 1, bits);
            // an extra path can only help: bonded sync arrival <= solo's
            assert!(b.tc <= a.tc + 1e-9, "{} vs {}", b.tc, a.tc);
            // the water-filling shares add up to the payload
            let pts = bonded.path_ticks(0);
            assert_eq!(pts.len(), 2);
            let total: f64 = pts.iter().map(|p| p.bits).sum();
            assert!((total - bits as f64).abs() < 1e-6 * bits as f64 + 1.0);
            // both paths pulled their weight (2:1 bandwidth ratio)
            assert!(pts[0].bits > pts[1].bits);
            assert!(pts[1].bits > 0.0);
        }
        // worker tx_secs sums the per-path wire time: with both paths busy
        // it exceeds any single path's share duration
        let wt = bonded.worker_ticks()[0];
        let pts = bonded.path_ticks(0);
        assert!((wt.tx_secs - (pts[0].tx_secs + pts[1].tx_secs)).abs() < 1e-12);
    }

    #[test]
    fn rate_zero_loss_is_structurally_lossless() {
        use crate::netsim::LossProcess;
        // a rate-0 process is dropped at the fabric layer, so the clock
        // keeps its shared classes and every bit matches the plain run
        let link = Link::new(BandwidthTrace::constant(5e7), 0.1);
        let mut lossy_fabric = Fabric::replicate(link.clone(), 4);
        lossy_fabric.set_loss(1, LossProcess::iid(0.0, 42));
        assert!(!lossy_fabric.has_loss());
        let mut plain = VirtualClock::new(Fabric::replicate(link, 4));
        let mut lossy = VirtualClock::new(lossy_fabric);
        assert_eq!(plain.timeline_classes(), lossy.timeline_classes());
        for k in 1..=200usize {
            let bits = 900_000 + (k as u64 % 5) * 200_000;
            let a = plain.tick(0.05, k % 3, bits);
            let b = lossy.tick(0.05, k % 3, bits);
            assert_eq!(a.tc.to_bits(), b.tc.to_bits(), "k={k}");
            assert_eq!(a.tm.to_bits(), b.tm.to_bits(), "k={k}");
            assert_eq!(b.retx_secs, 0.0);
        }
    }

    #[test]
    fn lossy_worker_delays_sync_and_reports_retransmits() {
        use crate::netsim::LossProcess;
        let link = Link::new(BandwidthTrace::constant(5e7), 0.1);
        let mut fabric = Fabric::replicate(link.clone(), 3);
        fabric.set_loss(0, LossProcess::iid(0.6, 11).with_rto(0.3));
        let mut plain = VirtualClock::new(Fabric::replicate(link, 3));
        let mut lossy = VirtualClock::new(fabric.clone());
        let mut reference = VirtualClock::new(fabric).with_reference_scan();
        lossy.set_event_log(true);
        let mut any_retx = false;
        for k in 1..=100usize {
            let bits = 2_000_000u64;
            let a = plain.tick(0.05, 1, bits);
            let b = lossy.tick(0.05, 1, bits);
            let c = reference.tick(0.05, 1, bits);
            // loss never speeds a sync up, and the engines agree exactly
            assert!(b.tc >= a.tc, "k={k}");
            assert_eq!(b.tc.to_bits(), c.tc.to_bits(), "k={k}");
            assert_eq!(b.tm.to_bits(), c.tm.to_bits(), "k={k}");
            let wt = lossy.worker_ticks()[0];
            assert_eq!(
                wt.retx_secs.to_bits(),
                reference.worker_ticks()[0].retx_secs.to_bits()
            );
            if wt.attempts > 1 {
                any_retx = true;
                assert!(wt.retx_secs > 0.0);
            }
        }
        assert!(any_retx, "p=0.6 over 100 ticks must retransmit");
        let events = lossy.drain_events();
        assert!(events
            .iter()
            .any(|e| matches!(e, ClockEvent::Retransmit { worker: 0, .. })));
    }

    #[test]
    fn slack_deadline_is_bit_identical_to_wait_for_all() {
        let fabric = || {
            Fabric::with_straggler(
                4,
                BandwidthTrace::constant(1e8),
                0.1,
                0.25,
                2.0,
            )
        };
        let mut plain = VirtualClock::new(fabric());
        let mut dl = VirtualClock::new(fabric());
        let mut dl_ref = VirtualClock::new(fabric()).with_reference_scan();
        dl.set_deadline(Some(1e9)); // never binds
        dl_ref.set_deadline(Some(1e9));
        for k in 1..=200usize {
            let bits = 3_000_000 + (k as u64 % 4) * 500_000;
            let a = plain.tick(0.05, k % 3, bits);
            let b = dl.tick(0.05, k % 3, bits);
            let c = dl_ref.tick(0.05, k % 3, bits);
            assert_eq!(a.tc.to_bits(), b.tc.to_bits(), "k={k}");
            assert_eq!(a.tm.to_bits(), b.tm.to_bits(), "k={k}");
            assert_eq!(a.tx_secs.to_bits(), b.tx_secs.to_bits(), "k={k}");
            assert_eq!(a.tc.to_bits(), c.tc.to_bits(), "k={k} (reference)");
            assert!(dl.late_workers().is_empty());
        }
    }

    #[test]
    fn binding_deadline_cuts_at_ts_plus_d_and_reports_late_workers() {
        // straggler: ~4x transfer time + 2x latency; healthy workers land
        // well before it, so a deadline between the two cuts every round
        let bits = 4_000_000u64;
        let fabric = Fabric::with_straggler(
            4,
            BandwidthTrace::constant(1e8),
            0.1,
            0.25,
            2.0,
        );
        let mut wait = VirtualClock::new(fabric.clone());
        let mut dl = VirtualClock::new(fabric.clone());
        let mut dl_ref = VirtualClock::new(fabric).with_reference_scan();
        // healthy: 0.04s tx + 0.1 lat = 0.14 after TS; straggler: 0.16 + 0.2
        let d = 0.2;
        dl.set_deadline(Some(d));
        dl_ref.set_deadline(Some(d));
        dl.set_event_log(true);
        for k in 1..=50usize {
            let a = wait.tick(0.05, 1, bits);
            let b = dl.tick(0.05, 1, bits);
            let c = dl_ref.tick(0.05, 1, bits);
            // the cut binds: sync at TS + D, strictly before wait-for-all
            assert!(b.tc < a.tc, "k={k}");
            assert_eq!(b.tc.to_bits(), (b.ts + d).to_bits(), "k={k}");
            assert_eq!(dl.late_workers(), &[0], "straggler is late");
            // engines agree bit-for-bit under the cut
            assert_eq!(b.tc.to_bits(), c.tc.to_bits(), "k={k}");
            assert_eq!(b.tm.to_bits(), c.tm.to_bits(), "k={k}");
            assert_eq!(dl_ref.late_workers(), &[0]);
            // the gate is an on-time arrival: tm ≤ tc
            assert!(b.tm <= b.tc);
        }
        // deadline runs strictly ahead in virtual time
        assert!(dl.now() < wait.now());
        let events = dl.drain_events();
        assert!(events
            .iter()
            .any(|e| matches!(e, ClockEvent::DeadlineCut { late: 1, .. })));
        // a cut so tight nothing could land clamps to the fastest arrival
        let fabric = Fabric::with_straggler(
            2,
            BandwidthTrace::constant(1e8),
            0.1,
            0.25,
            2.0,
        );
        let mut tight = VirtualClock::new(fabric);
        tight.set_deadline(Some(1e-6));
        let t = tight.tick(0.05, 0, bits);
        let fastest = tight.worker_ticks()[1].tc;
        assert_eq!(t.tc.to_bits(), fastest.to_bits(), "clamped to fastest");
        assert_eq!(tight.late_workers(), &[0]);
    }
}
