//! Virtual wall clock — the incremental, trace-driven, **fabric**-driven
//! form of the Eq. 19 recurrence. The training loop advances it one
//! iteration at a time with whatever (T_comp, τ, wire bits) that iteration
//! actually used, which is how DeCo's *dynamic* (τ_t, δ_t) trajectory gets
//! faithfully priced.
//!
//! Per-worker semantics (DESIGN.md §Network-Fabric): every worker i sends
//! its message over its own [`Link`], so each keeps its own transmission
//! timeline `TM_k^i`; the synchronous aggregation of iteration k completes
//! at the **slowest** worker's arrival `TC_k = max_i (TM_k^i + b_i)`, and
//! that sync arrival is what the delayed-gradient wait `TC_{k−1−τ}` sees.
//! With a homogeneous fabric every per-worker timeline is identical, so the
//! recurrence is bit-identical to the former single-link clock (enforced by
//! `tests/fabric.rs`). This is THE Eq. 19 implementation:
//! `timesim::EventSim::run_on_fabric` / `run_on_link` delegate here.

use crate::netsim::{Bond, Fabric, Link};
use crate::topo::{elect_eligible, RegionTopo, Topology};

#[derive(Debug)]
pub struct VirtualClock {
    fabric: Fabric,
    /// two-tier topology state; `None` prices the flat star exactly as the
    /// pre-topology clock did (DESIGN.md §Topology)
    two_tier: Option<TwoTierState>,
    /// all links share one trace config + latency
    /// ([`Fabric::is_uniform`]): every per-worker timeline is provably
    /// identical, so one exact transfer inversion per tick suffices — the
    /// hot-path fast path that keeps per-worker pricing free for the
    /// paper's default scenarios
    uniform: bool,
    /// TS_k of the previous iteration (computation is in lockstep)
    ts_prev: f64,
    /// per-worker TM_k of the previous iteration
    tm_prev: Vec<f64>,
    /// per-path TM_k of the previous iteration for bonded workers
    /// (DESIGN.md §Bonding); empty vec on single-path workers
    path_tm_prev: Vec<Vec<f64>>,
    /// per-path times of the last tick for bonded workers (per-path
    /// monitoring); empty vec on single-path workers
    path_last: Vec<Vec<PathTick>>,
    /// full sync-arrival history TC_k (indexed k-1) for the τ-delayed max
    tc: Vec<f64>,
    /// per-worker times of the last tick (metrics / per-link monitoring)
    worker_last: Vec<WorkerTick>,
    /// cumulative per-worker transmission seconds (straggler accounting)
    tx_total: Vec<f64>,
}

/// What one tick reports back to the trainer (the slowest worker's view —
/// the pair that gates the aggregation).
#[derive(Clone, Copy, Debug)]
pub struct Tick {
    /// computation end of iteration k
    pub ts: f64,
    /// transmission end of the slowest-arriving worker
    pub tm: f64,
    /// sync arrival — the iteration's contribution to total training time
    pub tc: f64,
    /// pure transmission duration of the slowest-arriving worker's message
    pub tx_secs: f64,
}

/// One worker's timeline entry for the last tick.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerTick {
    /// transmission end TM_k^i
    pub tm: f64,
    /// arrival TC_k^i = TM_k^i + b_i
    pub tc: f64,
    /// pure transmission duration of this worker's message
    pub tx_secs: f64,
}

/// One path's timeline entry for a bonded worker's last tick
/// (DESIGN.md §Bonding).
#[derive(Clone, Copy, Debug, Default)]
pub struct PathTick {
    /// transmission end of this path's share
    pub tm: f64,
    /// water-filling bit share this path carried (fractional — the
    /// scheduler splits at the exact covering time, not on bit boundaries)
    pub bits: f64,
    /// pure transmission duration of this path's share (0 when idle)
    pub tx_secs: f64,
}

/// One bonded tick: water-fill `bits` across the bond's paths starting no
/// earlier than `ts` on each, record per-path timelines, and report the
/// worker-level [`WorkerTick`] (tm = last path to stop transmitting,
/// tc = the bonded sync arrival, tx = summed per-path wire seconds).
fn tick_bonded(
    bond: &Bond,
    path_tm_prev: &mut [f64],
    path_last: &mut [PathTick],
    ts: f64,
    bits: u64,
) -> WorkerTick {
    let starts: Vec<f64> =
        path_tm_prev.iter().map(|&tm| tm.max(ts)).collect();
    let sched = bond.schedule(&starts, bits);
    let mut tm = f64::NEG_INFINITY;
    let mut tx_secs = 0.0;
    for p in 0..bond.k() {
        path_tm_prev[p] = sched.tx_end[p];
        path_last[p] = PathTick {
            tm: sched.tx_end[p],
            bits: sched.bits[p],
            tx_secs: sched.tx_secs[p],
        };
        tm = tm.max(sched.tx_end[p]);
        tx_secs += sched.tx_secs[p];
    }
    WorkerTick { tm, tc: sched.arrival, tx_secs }
}

/// One region's timeline entry for the last two-tier tick
/// (DESIGN.md §Topology).
#[derive(Clone, Copy, Debug, Default)]
pub struct RegionTick {
    /// region sync: the partial is ready at the aggregator — the slowest
    /// active member's intra-region arrival (≥ TS_k; TS_k itself when only
    /// the aggregator is active)
    pub sync: f64,
    /// WAN transmission end of the region partial
    pub wan_tm: f64,
    /// WAN arrival of the region partial at the leader
    pub wan_tc: f64,
    /// pure WAN transmission duration of the partial
    pub wan_tx_secs: f64,
    /// members that transmitted over intra-region links this tick
    /// (the aggregator's own gradient is local and never counted)
    pub senders: usize,
    /// false when no member of the region was active this tick — the
    /// region emitted nothing and its WAN timeline stayed frozen
    pub active: bool,
}

/// Per-region WAN timelines + last-tick reports of a two-tier topology.
#[derive(Debug)]
struct TwoTierState {
    regions: Vec<RegionTopo>,
    /// one link per *region* — the scarce cross-datacenter tier
    wan: Fabric,
    /// WAN-transmission end of the previous iteration, per region
    wan_tm_prev: Vec<f64>,
    region_last: Vec<RegionTick>,
    /// cumulative WAN transmission seconds per region
    wan_tx_total: Vec<f64>,
    /// cumulative bits shipped across each region's WAN link — the
    /// headline savings metric of hierarchical aggregation
    wan_bits_total: Vec<u64>,
}

impl VirtualClock {
    pub fn new(fabric: Fabric) -> Self {
        let n = fabric.workers();
        let uniform = fabric.is_uniform();
        let paths: Vec<usize> =
            (0..n).map(|i| fabric.bond(i).map_or(0, Bond::k)).collect();
        Self {
            fabric,
            two_tier: None,
            uniform,
            ts_prev: 0.0,
            tm_prev: vec![0.0; n],
            path_tm_prev: paths.iter().map(|&k| vec![0.0; k]).collect(),
            path_last: paths
                .iter()
                .map(|&k| vec![PathTick::default(); k])
                .collect(),
            tc: Vec::new(),
            worker_last: vec![WorkerTick::default(); n],
            tx_total: vec![0.0; n],
        }
    }

    /// Topology-aware constructor (DESIGN.md §Topology).
    /// [`Topology::Flat`] is exactly [`Self::new`] — the flat clock stays
    /// bit-identical to the fabric-only recurrence (`tests/topo.rs`); a
    /// [`Topology::TwoTier`] is validated against the fabric's worker
    /// count and priced by [`Self::tick_topo`].
    pub fn with_topology(
        fabric: Fabric,
        topo: Topology,
    ) -> anyhow::Result<Self> {
        topo.validate(fabric.workers())?;
        let mut clock = Self::new(fabric);
        if let Topology::TwoTier { regions, wan } = topo {
            let r = regions.len();
            clock.two_tier = Some(TwoTierState {
                regions,
                wan,
                wan_tm_prev: vec![0.0; r],
                region_last: vec![RegionTick::default(); r],
                wan_tx_total: vec![0.0; r],
                wan_bits_total: vec![0; r],
            });
        }
        Ok(clock)
    }

    /// Single-link compatibility constructor (a 1-worker fabric).
    pub fn single_link(link: Link) -> Self {
        Self::new(Fabric::new(vec![link]))
    }

    pub fn fabric(&self) -> &Fabric {
        &self.fabric
    }

    pub fn workers(&self) -> usize {
        self.tm_prev.len()
    }

    /// Per-worker (TM, TC, tx) of the last tick.
    pub fn worker_ticks(&self) -> &[WorkerTick] {
        &self.worker_last
    }

    /// Per-path (tx end, bit share, tx secs) of worker `worker`'s last
    /// tick — empty on single-path workers (DESIGN.md §Bonding).
    pub fn path_ticks(&self, worker: usize) -> &[PathTick] {
        &self.path_last[worker]
    }

    /// Cumulative transmission seconds per worker.
    pub fn tx_totals(&self) -> &[f64] {
        &self.tx_total
    }

    /// Whether this clock prices a two-tier topology.
    pub fn is_two_tier(&self) -> bool {
        self.two_tier.is_some()
    }

    /// The two-tier regions (empty slice on a flat topology).
    pub fn regions(&self) -> &[RegionTopo] {
        self.two_tier.as_ref().map_or(&[], |tt| &tt.regions)
    }

    /// The per-region WAN fabric (None on a flat topology).
    pub fn wan_fabric(&self) -> Option<&Fabric> {
        self.two_tier.as_ref().map(|tt| &tt.wan)
    }

    /// Per-region (sync, WAN tm/tc/tx) of the last two-tier tick (empty
    /// slice on a flat topology).
    pub fn region_ticks(&self) -> &[RegionTick] {
        self.two_tier.as_ref().map_or(&[], |tt| &tt.region_last)
    }

    /// Cumulative bits shipped over each region's WAN link.
    pub fn wan_bits_totals(&self) -> &[u64] {
        self.two_tier.as_ref().map_or(&[], |tt| &tt.wan_bits_total)
    }

    /// Cumulative WAN transmission seconds per region (the WAN-tier
    /// counterpart of [`Self::tx_totals`]).
    pub fn wan_tx_totals(&self) -> &[f64] {
        self.two_tier.as_ref().map_or(&[], |tt| &tt.wan_tx_total)
    }

    /// Re-elect region `region`'s aggregator among its members marked
    /// `true` in `eligible` — the churn hook: a departing aggregator hands
    /// the role to the best-connected surviving member (`topo::elect`
    /// order). Returns `true` if the aggregator changed; a region with no
    /// eligible member keeps its stale aggregator and simply prices as
    /// inactive until a rejoin. No-op on a flat topology.
    pub fn reelect_aggregator(
        &mut self,
        region: usize,
        eligible: &[bool],
    ) -> bool {
        let Some(tt) = self.two_tier.as_mut() else {
            return false;
        };
        let members = &tt.regions[region].members;
        let Some(new) = elect_eligible(&self.fabric, members, eligible)
        else {
            return false;
        };
        let changed = new != tt.regions[region].aggregator;
        tt.regions[region].aggregator = new;
        changed
    }

    /// Advance one iteration (k = self.tc.len() + 1, 1-based) with every
    /// worker transmitting.
    pub fn tick(&mut self, t_comp: f64, tau: usize, bits: u64) -> Tick {
        self.tick_members(t_comp, tau, bits, None)
    }

    /// Advance one iteration over the *active* worker set (elastic
    /// membership, DESIGN.md §Elasticity). `active = None` means all
    /// workers and is exactly [`Self::tick`]. With a mask, only masked-in
    /// workers transmit: a departed worker's timeline freezes (its
    /// `tm_prev` goes stale, harmlessly dominated by `max(·, TS_k)` on
    /// rejoin) and the sync arrival is the max over active arrivals only.
    /// Masked-out workers report a zeroed [`WorkerTick`] so per-link
    /// monitors see no phantom transfers. The first masked tick latches the
    /// clock off the uniform fast path permanently — per-worker histories
    /// may diverge from then on — which is why an all-true-forever run
    /// (`ChurnSpec::none()`) stays bit-identical to [`Self::tick`].
    pub fn tick_members(
        &mut self,
        t_comp: f64,
        tau: usize,
        bits: u64,
        active: Option<&[bool]>,
    ) -> Tick {
        let all_active = match active {
            None => true,
            Some(m) => {
                assert_eq!(m.len(), self.tm_prev.len(), "mask/worker mismatch");
                assert!(m.iter().any(|&a| a), "active set must be non-empty");
                m.iter().all(|&a| a)
            }
        };
        if !all_active {
            self.uniform = false;
        }
        let k = self.tc.len() + 1;
        let tc_delayed = if k as i64 - 1 - tau as i64 >= 1 {
            self.tc[k - 2 - tau]
        } else {
            0.0
        };
        let ts = t_comp + tc_delayed.max(self.ts_prev);
        let slowest = if self.uniform {
            // identical links + identical histories (by induction from the
            // all-zero start): worker 0's times ARE every worker's times —
            // one transfer integration instead of n, bit-identical result
            let link = self.fabric.link(0);
            let start = self.tm_prev[0].max(ts);
            let tm = link.transfer_end(start, bits);
            let wt =
                WorkerTick { tm, tc: tm + link.latency(), tx_secs: tm - start };
            self.tm_prev.fill(tm);
            for (total, last) in
                self.tx_total.iter_mut().zip(self.worker_last.iter_mut())
            {
                *total += wt.tx_secs;
                *last = wt;
            }
            wt
        } else {
            let mut slowest = WorkerTick {
                tm: f64::NEG_INFINITY,
                tc: f64::NEG_INFINITY,
                tx_secs: 0.0,
            };
            for i in 0..self.tm_prev.len() {
                if let Some(m) = active {
                    if !m[i] {
                        // departed: timeline frozen, no phantom transfer
                        self.worker_last[i] = WorkerTick::default();
                        self.path_last[i].fill(PathTick::default());
                        continue;
                    }
                }
                let wt = if let Some(bond) = self.fabric.bond(i) {
                    tick_bonded(
                        bond,
                        &mut self.path_tm_prev[i],
                        &mut self.path_last[i],
                        ts,
                        bits,
                    )
                } else {
                    let link = self.fabric.link(i);
                    let start = self.tm_prev[i].max(ts);
                    let tm = link.transfer_end(start, bits);
                    WorkerTick {
                        tm,
                        tc: tm + link.latency(),
                        tx_secs: tm - start,
                    }
                };
                self.tm_prev[i] = wt.tm;
                self.tx_total[i] += wt.tx_secs;
                self.worker_last[i] = wt;
                if wt.tc > slowest.tc {
                    slowest = wt;
                }
            }
            slowest
        };
        self.ts_prev = ts;
        self.tc.push(slowest.tc);
        Tick { ts, tm: slowest.tm, tc: slowest.tc, tx_secs: slowest.tx_secs }
    }

    /// Advance one iteration on a two-tier topology (DESIGN.md §Topology):
    /// each active member ships its δ_lan-compressed gradient (`lan_bits`)
    /// over its own intra-region link; region r's partial is ready at the
    /// slowest member arrival (the region sync), then crosses the WAN as
    /// `wan_bits` over the region's own WAN link; the global aggregation
    /// completes at the slowest region partial's arrival, and that arrival
    /// is what the τ-delayed wait `TC_{k−1−τ}` sees. On a flat topology
    /// this delegates to [`Self::tick_members`] with `lan_bits`
    /// (bit-identical — `tests/topo.rs`) and `wan_bits` is ignored.
    pub fn tick_topo(
        &mut self,
        t_comp: f64,
        tau: usize,
        lan_bits: u64,
        wan_bits: u64,
        active: Option<&[bool]>,
    ) -> Tick {
        if self.two_tier.is_none() {
            return self.tick_members(t_comp, tau, lan_bits, active);
        }
        if let Some(m) = active {
            assert_eq!(m.len(), self.tm_prev.len(), "mask/worker mismatch");
            assert!(m.iter().any(|&a| a), "active set must be non-empty");
        }
        let k = self.tc.len() + 1;
        let tc_delayed = if k as i64 - 1 - tau as i64 >= 1 {
            self.tc[k - 2 - tau]
        } else {
            0.0
        };
        let ts = t_comp + tc_delayed.max(self.ts_prev);
        let tt = self.two_tier.as_mut().expect("checked above");
        let mut slowest = RegionTick::default();
        let mut any_region = false;
        for (r, region) in tt.regions.iter().enumerate() {
            // LAN tier: every active non-aggregator member sends its
            // compressed gradient to the aggregator; the partial is ready
            // at the slowest arrival (the aggregator's own gradient is
            // local, so a lone-aggregator region syncs at TS_k)
            let mut sync = ts;
            let mut senders = 0usize;
            let mut any_member = false;
            for &i in &region.members {
                if let Some(m) = active {
                    if !m[i] {
                        self.worker_last[i] = WorkerTick::default();
                        self.path_last[i].fill(PathTick::default());
                        continue;
                    }
                }
                any_member = true;
                if i == region.aggregator {
                    // local hand-off: timeline advances with TS, no wire
                    self.tm_prev[i] = ts;
                    self.path_tm_prev[i].fill(ts);
                    self.path_last[i].fill(PathTick::default());
                    self.worker_last[i] =
                        WorkerTick { tm: ts, tc: ts, tx_secs: 0.0 };
                    continue;
                }
                let wt = if let Some(bond) = self.fabric.bond(i) {
                    tick_bonded(
                        bond,
                        &mut self.path_tm_prev[i],
                        &mut self.path_last[i],
                        ts,
                        lan_bits,
                    )
                } else {
                    let link = self.fabric.link(i);
                    let start = self.tm_prev[i].max(ts);
                    let tm = link.transfer_end(start, lan_bits);
                    WorkerTick {
                        tm,
                        tc: tm + link.latency(),
                        tx_secs: tm - start,
                    }
                };
                self.tm_prev[i] = wt.tm;
                self.tx_total[i] += wt.tx_secs;
                self.worker_last[i] = wt;
                senders += 1;
                sync = sync.max(wt.tc);
            }
            if !any_member {
                // no active member: nothing to aggregate, WAN frozen
                tt.region_last[r] = RegionTick::default();
                continue;
            }
            // WAN tier: the partial crosses the region's own WAN link
            let wan_link = tt.wan.link(r);
            let start = tt.wan_tm_prev[r].max(sync);
            let wan_tm = wan_link.transfer_end(start, wan_bits);
            let rt = RegionTick {
                sync,
                wan_tm,
                wan_tc: wan_tm + wan_link.latency(),
                wan_tx_secs: wan_tm - start,
                senders,
                active: true,
            };
            tt.wan_tm_prev[r] = wan_tm;
            tt.wan_tx_total[r] += rt.wan_tx_secs;
            tt.wan_bits_total[r] += wan_bits;
            tt.region_last[r] = rt;
            if !any_region || rt.wan_tc > slowest.wan_tc {
                slowest = rt;
            }
            any_region = true;
        }
        assert!(any_region, "no region had an active member");
        self.ts_prev = ts;
        self.tc.push(slowest.wan_tc);
        Tick {
            ts,
            tm: slowest.wan_tm,
            tc: slowest.wan_tc,
            tx_secs: slowest.wan_tx_secs,
        }
    }

    pub fn iters(&self) -> usize {
        self.tc.len()
    }

    /// Total elapsed virtual time (sync TC of the last iteration).
    pub fn now(&self) -> f64 {
        *self.tc.last().unwrap_or(&0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::BandwidthTrace;
    use crate::timesim::{EventSim, PipelineParams};

    #[test]
    fn matches_event_sim_with_constant_params() {
        let p = PipelineParams {
            a: 1e8,
            b: 0.2,
            delta: 0.1,
            tau: 2,
            t_comp: 0.05,
            s_g: 1e9,
        };
        let mut clock = VirtualClock::single_link(Link::new(
            BandwidthTrace::constant(p.a),
            p.b,
        ));
        let bits = (p.delta * p.s_g) as u64;
        for _ in 0..300 {
            clock.tick(p.t_comp, p.tau, bits);
        }
        let sim = EventSim::run(&p, 300);
        assert!(
            (clock.now() - sim.total_time()).abs() < 1e-6,
            "{} vs {}",
            clock.now(),
            sim.total_time()
        );
    }

    #[test]
    fn time_is_monotone_under_dynamic_params() {
        let mut clock = VirtualClock::single_link(Link::new(
            BandwidthTrace::constant(5e7),
            0.1,
        ));
        let mut prev = 0.0;
        for k in 1..100usize {
            let tau = k % 4;
            let bits = 1_000_000 + (k as u64 % 7) * 500_000;
            let t = clock.tick(0.02 + 0.001 * (k % 3) as f64, tau, bits);
            assert!(t.tc >= prev);
            assert!(t.tm >= t.ts);
            prev = t.tc;
        }
    }

    #[test]
    fn homogeneous_fabric_bit_identical_to_single_link() {
        let trace = BandwidthTrace::constant(2e7);
        let link = Link::new(trace.clone(), 0.15);
        let mut single = VirtualClock::single_link(link.clone());
        let mut fab = VirtualClock::new(Fabric::replicate(link, 5));
        // semantically identical fabric that defeats the uniform detector
        // (one link wears a no-op Scaled(1.0) wrapper), forcing the general
        // per-link loop — it must match the fast path bit-for-bit
        let mut mixed = VirtualClock::new(Fabric::new(vec![
            Link::new(trace.clone(), 0.15),
            Link::new(trace.clone(), 0.15),
            Link::new(trace.clone(), 0.15),
            Link::new(trace.clone(), 0.15),
            Link::new(trace.scaled(1.0), 0.15),
        ]));
        for k in 1..=400usize {
            let tau = k % 3;
            let bits = 500_000 + (k as u64 % 11) * 250_000;
            let a = single.tick(0.07, tau, bits);
            let b = fab.tick(0.07, tau, bits);
            let c = mixed.tick(0.07, tau, bits);
            assert_eq!(a.ts.to_bits(), b.ts.to_bits(), "k={k}");
            assert_eq!(a.tm.to_bits(), b.tm.to_bits(), "k={k}");
            assert_eq!(a.tc.to_bits(), b.tc.to_bits(), "k={k}");
            assert_eq!(a.tx_secs.to_bits(), b.tx_secs.to_bits(), "k={k}");
            assert_eq!(a.tc.to_bits(), c.tc.to_bits(), "k={k} (general loop)");
            assert_eq!(a.tm.to_bits(), c.tm.to_bits(), "k={k} (general loop)");
        }
        assert_eq!(single.now().to_bits(), fab.now().to_bits());
        assert_eq!(single.now().to_bits(), mixed.now().to_bits());
    }

    #[test]
    fn all_true_mask_is_bit_identical_to_tick() {
        // the determinism contract at the clock level: a mask that never
        // masks anyone out must not perturb a single bit (fast path intact)
        let fabric = || {
            Fabric::with_straggler(
                4,
                BandwidthTrace::constant(1e8),
                0.1,
                0.5,
                2.0,
            )
        };
        let mut plain = VirtualClock::new(fabric());
        let mut masked = VirtualClock::new(fabric());
        let mask = vec![true; 4];
        for k in 1..=200usize {
            let bits = 1_000_000 + (k as u64 % 5) * 300_000;
            let a = plain.tick(0.05, k % 3, bits);
            let b = masked.tick_members(0.05, k % 3, bits, Some(&mask));
            assert_eq!(a.tc.to_bits(), b.tc.to_bits(), "k={k}");
            assert_eq!(a.tm.to_bits(), b.tm.to_bits(), "k={k}");
        }
    }

    #[test]
    fn masked_straggler_stops_gating_and_rejoins_stale_free() {
        let fabric = Fabric::with_straggler(
            4,
            BandwidthTrace::constant(1e8),
            0.1,
            0.25,
            2.0,
        );
        let mut clock = VirtualClock::new(fabric);
        let bits = 4_000_000u64;
        // straggler present: it gates the sync arrival
        let mut mask = vec![true; 4];
        let t0 = clock.tick_members(0.05, 1, bits, Some(&mask));
        assert_eq!(t0.tc.to_bits(), clock.worker_ticks()[0].tc.to_bits());
        // straggler departs: sync snaps to the healthy links' pace and its
        // WorkerTick zeroes (no phantom transfer for the monitors)
        mask[0] = false;
        let t1 = clock.tick_members(0.05, 1, bits, Some(&mask));
        let healthy = clock.worker_ticks()[1];
        assert_eq!(t1.tc.to_bits(), healthy.tc.to_bits());
        assert_eq!(clock.worker_ticks()[0].tx_secs, 0.0);
        let tx0_frozen = clock.tx_totals()[0];
        for _ in 0..20 {
            clock.tick_members(0.05, 1, bits, Some(&mask));
        }
        assert_eq!(clock.tx_totals()[0], tx0_frozen, "timeline frozen");
        // rejoin: the stale tm_prev is dominated by TS, so the straggler
        // resumes gating immediately without time travel
        mask[0] = true;
        let t2 = clock.tick_members(0.05, 1, bits, Some(&mask));
        assert_eq!(t2.tc.to_bits(), clock.worker_ticks()[0].tc.to_bits());
        assert!(t2.tc > t1.tc);
        assert!(clock.tx_totals()[0] > tx0_frozen);
    }

    fn two_tier_clock(
        n: usize,
        per_region: usize,
        lan_bps: f64,
        lan_lat: f64,
        wan_bps: f64,
        wan_lat: f64,
    ) -> VirtualClock {
        use crate::topo::RegionTopo;
        assert_eq!(n % per_region, 0);
        let regions: Vec<RegionTopo> = (0..n / per_region)
            .map(|r| RegionTopo {
                members: (r * per_region..(r + 1) * per_region).collect(),
                aggregator: r * per_region,
            })
            .collect();
        let wan = Fabric::homogeneous(
            regions.len(),
            BandwidthTrace::constant(wan_bps),
            wan_lat,
        );
        VirtualClock::with_topology(
            Fabric::homogeneous(n, BandwidthTrace::constant(lan_bps), lan_lat),
            Topology::TwoTier { regions, wan },
        )
        .unwrap()
    }

    #[test]
    fn flat_topology_tick_topo_is_bit_identical() {
        let fabric = || {
            Fabric::with_straggler(
                4,
                BandwidthTrace::constant(1e8),
                0.1,
                0.5,
                2.0,
            )
        };
        let mut plain = VirtualClock::new(fabric());
        let mut topo =
            VirtualClock::with_topology(fabric(), Topology::Flat).unwrap();
        assert!(!topo.is_two_tier());
        assert!(topo.regions().is_empty() && topo.region_ticks().is_empty());
        for k in 1..=300usize {
            let bits = 800_000 + (k as u64 % 7) * 300_000;
            let a = plain.tick(0.05, k % 3, bits);
            // wan_bits must be entirely ignored on a flat topology
            let b = topo.tick_topo(0.05, k % 3, bits, 123_456_789, None);
            assert_eq!(a.ts.to_bits(), b.ts.to_bits(), "k={k}");
            assert_eq!(a.tm.to_bits(), b.tm.to_bits(), "k={k}");
            assert_eq!(a.tc.to_bits(), b.tc.to_bits(), "k={k}");
            assert_eq!(a.tx_secs.to_bits(), b.tx_secs.to_bits(), "k={k}");
        }
        assert_eq!(plain.now().to_bits(), topo.now().to_bits());
    }

    #[test]
    fn two_tier_tick_prices_both_hops() {
        let mut clock = two_tier_clock(4, 2, 1e8, 0.01, 1e7, 0.3);
        let t = clock.tick_topo(0.1, 0, 1_000_000, 1_000_000, None);
        // region sync: worker 1's LAN arrival = 0.1 + 0.01s tx + 0.01 lat
        let rts = clock.region_ticks();
        assert_eq!(rts.len(), 2);
        for rt in rts {
            assert!(rt.active);
            assert_eq!(rt.senders, 1, "aggregator never sends over LAN");
            assert!((rt.sync - 0.12).abs() < 1e-12, "sync={}", rt.sync);
            // WAN: 0.1s transfer at 1e7 bps + 0.3s latency
            assert!((rt.wan_tc - 0.52).abs() < 1e-12, "{}", rt.wan_tc);
            assert!(rt.sync >= t.ts);
            assert!(rt.wan_tc >= rt.sync);
        }
        // global sync = the slowest region's WAN arrival
        let max_wan =
            rts.iter().map(|r| r.wan_tc).fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(t.tc.to_bits(), max_wan.to_bits());
        // aggregators never transmit over the LAN tier
        assert_eq!(clock.worker_ticks()[0].tx_secs, 0.0);
        assert_eq!(clock.worker_ticks()[2].tx_secs, 0.0);
        assert!(clock.worker_ticks()[1].tx_secs > 0.0);
        assert_eq!(clock.wan_bits_totals(), &[1_000_000, 1_000_000]);
        assert!(clock.wan_tx_totals().iter().all(|&s| s > 0.0));
    }

    #[test]
    fn two_tier_masked_region_freezes_and_lone_aggregator_syncs_at_ts() {
        let mut clock = two_tier_clock(4, 2, 1e8, 0.01, 1e7, 0.3);
        let mut mask = vec![true; 4];
        // region 1 fully departs: it emits nothing, its WAN stays frozen
        mask[2] = false;
        mask[3] = false;
        let t = clock.tick_topo(0.1, 0, 1_000_000, 1_000_000, Some(&mask));
        let rts = clock.region_ticks();
        assert!(rts[0].active && !rts[1].active);
        assert_eq!(t.tc.to_bits(), rts[0].wan_tc.to_bits());
        assert_eq!(clock.wan_bits_totals()[1], 0);
        // region 0 loses its non-aggregator member: sync collapses to TS
        mask[1] = false;
        let t2 = clock.tick_topo(0.1, 0, 1_000_000, 1_000_000, Some(&mask));
        let rt = clock.region_ticks()[0];
        assert_eq!(rt.senders, 0);
        assert_eq!(rt.sync.to_bits(), t2.ts.to_bits());
        assert!(t2.tc > t.tc);
    }

    #[test]
    fn reelection_moves_the_aggregator_role() {
        let mut clock = two_tier_clock(4, 2, 1e8, 0.01, 1e7, 0.3);
        assert_eq!(clock.regions()[0].aggregator, 0);
        let mut eligible = vec![true; 4];
        eligible[0] = false;
        assert!(clock.reelect_aggregator(0, &eligible));
        assert_eq!(clock.regions()[0].aggregator, 1);
        // with nobody eligible the stale aggregator stays put
        eligible[1] = false;
        assert!(!clock.reelect_aggregator(0, &eligible));
        assert_eq!(clock.regions()[0].aggregator, 1);
    }

    #[test]
    fn straggler_gates_sync_arrival() {
        let fabric = Fabric::with_straggler(
            4,
            BandwidthTrace::constant(1e8),
            0.1,
            0.25,
            2.0,
        );
        let mut clock = VirtualClock::new(fabric);
        for _ in 0..50 {
            let tick = clock.tick(0.05, 1, 4_000_000);
            let wts = clock.worker_ticks();
            // the sync arrival is exactly the slowest worker's arrival
            let max_tc =
                wts.iter().map(|w| w.tc).fold(f64::NEG_INFINITY, f64::max);
            assert_eq!(tick.tc.to_bits(), max_tc.to_bits());
            // worker 0 (quarter bandwidth, double latency) is the straggler
            assert_eq!(tick.tc.to_bits(), wts[0].tc.to_bits());
            for w in &wts[1..] {
                assert!(w.tc <= tick.tc);
                assert!(w.tx_secs < wts[0].tx_secs);
            }
        }
        // the straggler accumulated 4x the healthy transmission time
        let tx = clock.tx_totals();
        assert!((tx[0] / tx[1] - 4.0).abs() < 1e-6, "{tx:?}");
    }

    #[test]
    fn k1_bonded_clock_is_bit_identical_to_the_plain_fabric() {
        // the bond determinism contract at the clock level: wrapping every
        // link in a 1-path bond must not perturb a single bit, even though
        // it forces the general (non-uniform) loop
        let link = Link::new(
            BandwidthTrace::new(crate::netsim::TraceKind::Sine {
                mean_bps: 8e7,
                amp_bps: 3e7,
                period_s: 40.0,
            }),
            0.12,
        );
        let plain_fabric = Fabric::replicate(link.clone(), 3);
        let mut bonded_fabric = Fabric::replicate(link.clone(), 3);
        for i in 0..3 {
            bonded_fabric.set_bond(i, Bond::single(link.clone()));
        }
        let mut plain = VirtualClock::new(plain_fabric);
        let mut bonded = VirtualClock::new(bonded_fabric);
        for k in 1..=300usize {
            let bits = if k % 13 == 0 {
                0
            } else {
                700_000 + (k as u64 % 9) * 400_000
            };
            let a = plain.tick(0.06, k % 3, bits);
            let b = bonded.tick(0.06, k % 3, bits);
            assert_eq!(a.ts.to_bits(), b.ts.to_bits(), "k={k}");
            assert_eq!(a.tm.to_bits(), b.tm.to_bits(), "k={k}");
            assert_eq!(a.tc.to_bits(), b.tc.to_bits(), "k={k}");
            assert_eq!(a.tx_secs.to_bits(), b.tx_secs.to_bits(), "k={k}");
        }
        assert_eq!(plain.now().to_bits(), bonded.now().to_bits());
        assert_eq!(bonded.path_ticks(0).len(), 1);
        assert_eq!(plain.path_ticks(0).len(), 0);
    }

    #[test]
    fn bonded_worker_splits_bits_and_arrives_no_later() {
        let fast = Link::new(BandwidthTrace::constant(1e8), 0.1);
        let slow = Link::new(BandwidthTrace::constant(5e7), 0.1);
        let mut solo_fabric = Fabric::replicate(fast.clone(), 2);
        solo_fabric.set_link(1, fast.clone()); // keep it non-trivial
        let mut bonded_fabric = solo_fabric.clone();
        bonded_fabric
            .set_bond(0, Bond::new(vec![fast.clone(), slow.clone()]));
        let mut solo = VirtualClock::new(solo_fabric);
        let mut bonded = VirtualClock::new(bonded_fabric);
        let bits = 6_000_000u64;
        for _ in 0..30 {
            let a = solo.tick(0.05, 1, bits);
            let b = bonded.tick(0.05, 1, bits);
            // an extra path can only help: bonded sync arrival <= solo's
            assert!(b.tc <= a.tc + 1e-9, "{} vs {}", b.tc, a.tc);
            // the water-filling shares add up to the payload
            let pts = bonded.path_ticks(0);
            assert_eq!(pts.len(), 2);
            let total: f64 = pts.iter().map(|p| p.bits).sum();
            assert!((total - bits as f64).abs() < 1e-6 * bits as f64 + 1.0);
            // both paths pulled their weight (2:1 bandwidth ratio)
            assert!(pts[0].bits > pts[1].bits);
            assert!(pts[1].bits > 0.0);
        }
        // worker tx_secs sums the per-path wire time: with both paths busy
        // it exceeds any single path's share duration
        let wt = bonded.worker_ticks()[0];
        let pts = bonded.path_ticks(0);
        assert!((wt.tx_secs - (pts[0].tx_secs + pts[1].tx_secs)).abs() < 1e-12);
    }
}
