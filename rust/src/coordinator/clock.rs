//! Virtual wall clock — the incremental, trace-driven form of the Eq. 19
//! recurrence. The training loop advances it one iteration at a time with
//! whatever (T_comp, τ, wire bits) that iteration actually used, which is
//! how DeCo's *dynamic* (τ_t, δ_t) trajectory gets faithfully priced.

use crate::netsim::Link;

#[derive(Debug)]
pub struct VirtualClock {
    link: Link,
    /// TS_k, TM_k of the previous iteration
    ts_prev: f64,
    tm_prev: f64,
    /// full TC history (indexed k-1) for the τ-delayed max
    tc: Vec<f64>,
}

/// What one tick reports back to the trainer.
#[derive(Clone, Copy, Debug)]
pub struct Tick {
    /// computation end of iteration k
    pub ts: f64,
    /// transmission end (what the monitor samples bandwidth from)
    pub tm: f64,
    /// arrival — the iteration's contribution to total training time
    pub tc: f64,
    /// pure transmission duration of this iteration's message
    pub tx_secs: f64,
}

impl VirtualClock {
    pub fn new(link: Link) -> Self {
        Self { link, ts_prev: 0.0, tm_prev: 0.0, tc: Vec::new() }
    }

    pub fn link(&self) -> &Link {
        &self.link
    }

    /// Advance one iteration (k = self.tc.len() + 1, 1-based).
    pub fn tick(&mut self, t_comp: f64, tau: usize, bits: u64) -> Tick {
        let k = self.tc.len() + 1;
        let tc_delayed = if k as i64 - 1 - tau as i64 >= 1 {
            self.tc[k - 2 - tau]
        } else {
            0.0
        };
        let ts = t_comp + tc_delayed.max(self.ts_prev);
        let start = self.tm_prev.max(ts);
        let tm = self.link.transfer_end(start, bits);
        let tc = tm + self.link.latency();
        self.ts_prev = ts;
        self.tm_prev = tm;
        self.tc.push(tc);
        Tick { ts, tm, tc, tx_secs: tm - start }
    }

    pub fn iters(&self) -> usize {
        self.tc.len()
    }

    /// Total elapsed virtual time (TC of the last iteration).
    pub fn now(&self) -> f64 {
        *self.tc.last().unwrap_or(&0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::BandwidthTrace;
    use crate::timesim::{EventSim, PipelineParams};

    #[test]
    fn matches_event_sim_with_constant_params() {
        let p = PipelineParams {
            a: 1e8,
            b: 0.2,
            delta: 0.1,
            tau: 2,
            t_comp: 0.05,
            s_g: 1e9,
        };
        let mut clock = VirtualClock::new(Link::new(
            BandwidthTrace::constant(p.a),
            p.b,
        ));
        let bits = (p.delta * p.s_g) as u64;
        for _ in 0..300 {
            clock.tick(p.t_comp, p.tau, bits);
        }
        let sim = EventSim::run(&p, 300);
        assert!(
            (clock.now() - sim.total_time()).abs() < 1e-6,
            "{} vs {}",
            clock.now(),
            sim.total_time()
        );
    }

    #[test]
    fn time_is_monotone_under_dynamic_params() {
        let mut clock = VirtualClock::new(Link::new(
            BandwidthTrace::constant(5e7),
            0.1,
        ));
        let mut prev = 0.0;
        for k in 1..100usize {
            let tau = k % 4;
            let bits = 1_000_000 + (k as u64 % 7) * 500_000;
            let t = clock.tick(0.02 + 0.001 * (k % 3) as f64, tau, bits);
            assert!(t.tc >= prev);
            assert!(t.tm >= t.ts);
            prev = t.tc;
        }
    }
}
