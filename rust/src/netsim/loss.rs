//! Message-loss processes with timeout → exponential-backoff
//! retransmission (DESIGN.md §Robustness).
//!
//! A [`LossProcess`] decides, per (worker, message, attempt), whether a
//! gradient message is lost in flight. Pricing wraps the exact
//! prefix-integral engine: attempt `k` is priced by
//! [`crate::netsim::Link::transfer_end`] from its start instant; a lost
//! attempt's retry restarts `transfer_end` at the *backoff instant*
//! `tm_k + rto·2^k`, so every failed attempt occupies the link for its
//! full (exactly integrated) wire time and the payload re-enters the
//! queue after the timeout. The final (successful) attempt defines the
//! message's `tm`; everything before it — failed wire time plus backoff
//! gaps — is reported separately as `retx_secs` so the stall-attribution
//! report can carve a Retransmit phase out of the round without
//! disturbing the makespan tiling, and so the monitors keep estimating
//! the *link's* true rate from the final attempt's `bits / tx_secs`.
//!
//! Determinism: loss draws are pure seeded hashes of
//! `(seed, worker, message, attempt)` — no sequential RNG state — so
//! pricing is a pure function of its inputs, identical across the class
//! engine, the reference scan, and any evaluation order. The
//! Gilbert–Elliott variant discretizes the two-state chain onto fixed
//! dwell cells: cell `⌊t/dwell_s⌋` of each worker is independently `Bad`
//! with the stationary probability `pi_bad` (a pure hash of the cell
//! index), and the loss rate within a cell is `p_bad` or `p_good`. That
//! keeps the process bursty at the dwell timescale while staying O(1)
//! per query and exactly replayable.
//!
//! Degenerate contract: a rate-0 process never rejects a draw, so every
//! message succeeds on attempt 1 with `tm` equal to the lossless
//! `transfer_end` bit-for-bit — and the simulator only ever *consults* a
//! loss process where one is attached, so "no process" ≡ "rate 0" ≡
//! today's lossless path.

use super::bond::{Bond, BondSchedule};
use super::link::Link;

/// Default retransmission timeout base (s): attempt `k`'s retry starts
/// `rto·2^k` after the failed attempt's wire time ends.
pub const DEFAULT_RTO_S: f64 = 0.2;
/// Backoff exponent cap: backoff never exceeds `rto·2^MAX_BACKOFF_EXP`.
pub const MAX_BACKOFF_EXP: u32 = 6;
/// Attempt cap — a termination guarantee under rate-1.0 bursts (models
/// an eventual out-of-band recovery path). With exponential backoff the
/// capped worst case is minutes, not forever.
pub const MAX_ATTEMPTS: u32 = 12;

const SALT_DRAW: u64 = 0x9E3779B97F4A7C15;
const SALT_MSG: u64 = 0xD1B54A32D192ED03;
const SALT_ATTEMPT: u64 = 0xA0761D6478BD642F;
const SALT_STATE: u64 = 0xE7037ED1A0B428DB;

/// SplitMix64 finalizer — the pure mixing step behind every draw.
fn mix(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Uniform in [0, 1) from four mixed words.
fn hash01(seed: u64, a: u64, b: u64, c: u64) -> f64 {
    let x = mix(
        seed ^ a.wrapping_mul(SALT_DRAW)
            ^ b.wrapping_mul(SALT_MSG)
            ^ c.wrapping_mul(SALT_ATTEMPT),
    );
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// The loss model one worker's transport runs under.
#[derive(Clone, Debug, PartialEq)]
pub enum LossKind {
    /// Every attempt is lost independently with probability `p`.
    Iid { p: f64 },
    /// Discretized Gilbert–Elliott: each dwell cell of `dwell_s` seconds
    /// is independently `Bad` with the stationary probability `pi_bad`;
    /// attempts sent during a bad cell are lost with `p_bad`, otherwise
    /// `p_good`. Bursty at the dwell timescale, O(1) per query.
    GilbertElliott { p_good: f64, p_bad: f64, pi_bad: f64, dwell_s: f64 },
}

/// A scripted loss-rate spike (from `ChurnEvent::LossBurst`): while
/// `t ∈ [start_s, end_s)` the worker's loss rate is at least `rate`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LossBurstWindow {
    pub start_s: f64,
    pub end_s: f64,
    pub rate: f64,
}

/// A per-worker message-loss process with retransmission parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct LossProcess {
    kind: LossKind,
    seed: u64,
    rto_s: f64,
    bursts: Vec<LossBurstWindow>,
}

/// One fully priced lossy transfer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LossyOutcome {
    /// final (successful) attempt's transmission end — the link's next
    /// busy-from time
    pub tm: f64,
    /// final attempt's wire seconds (`tm − last attempt start`)
    pub tx_secs: f64,
    /// seconds lost to failed attempts + backoff gaps before the final
    /// attempt started (0 when attempt 1 succeeded)
    pub retx_secs: f64,
    /// total attempts (1 = no loss)
    pub attempts: u32,
}

impl LossProcess {
    /// i.i.d. loss with probability `p` per attempt.
    pub fn iid(p: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&p), "loss rate {p} out of [0, 1]");
        Self { kind: LossKind::Iid { p }, seed, rto_s: DEFAULT_RTO_S, bursts: Vec::new() }
    }

    /// Discretized Gilbert–Elliott bursty loss (see the module docs).
    pub fn gilbert_elliott(
        p_good: f64,
        p_bad: f64,
        pi_bad: f64,
        dwell_s: f64,
        seed: u64,
    ) -> Self {
        for (name, v) in
            [("p_good", p_good), ("p_bad", p_bad), ("pi_bad", pi_bad)]
        {
            assert!((0.0..=1.0).contains(&v), "{name} = {v} out of [0, 1]");
        }
        assert!(dwell_s > 0.0 && dwell_s.is_finite(), "dwell_s {dwell_s}");
        Self {
            kind: LossKind::GilbertElliott { p_good, p_bad, pi_bad, dwell_s },
            seed,
            rto_s: DEFAULT_RTO_S,
            bursts: Vec::new(),
        }
    }

    /// Override the retransmission timeout base.
    pub fn with_rto(mut self, rto_s: f64) -> Self {
        assert!(rto_s > 0.0 && rto_s.is_finite());
        self.rto_s = rto_s;
        self
    }

    /// Attach scripted loss-burst windows (how `elastic` bakes
    /// `ChurnEvent::LossBurst` in).
    pub fn with_bursts(mut self, mut bursts: Vec<LossBurstWindow>) -> Self {
        bursts.sort_by(|a, b| a.start_s.total_cmp(&b.start_s));
        self.bursts = bursts;
        self
    }

    pub fn kind(&self) -> &LossKind {
        &self.kind
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn rto_s(&self) -> f64 {
        self.rto_s
    }

    pub fn bursts(&self) -> &[LossBurstWindow] {
        &self.bursts
    }

    /// Whether every draw trivially succeeds — the degenerate process
    /// that is bit-identical to no process at all.
    pub fn is_lossless(&self) -> bool {
        let base = match self.kind {
            LossKind::Iid { p } => p == 0.0,
            LossKind::GilbertElliott { p_good, p_bad, pi_bad, .. } => {
                p_good == 0.0 && (p_bad == 0.0 || pi_bad == 0.0)
            }
        };
        base && self.bursts.iter().all(|b| b.rate == 0.0)
    }

    /// Backoff before retry `attempt + 1` (exponential, capped).
    pub fn backoff(&self, attempt: u32) -> f64 {
        self.rto_s * f64::from(1u32 << attempt.min(MAX_BACKOFF_EXP))
    }

    /// The loss rate worker `worker` sees at time `t` (base process,
    /// spiked by any covering burst window).
    pub fn rate_at(&self, worker: u32, t: f64) -> f64 {
        let mut p = match self.kind {
            LossKind::Iid { p } => p,
            LossKind::GilbertElliott { p_good, p_bad, pi_bad, dwell_s } => {
                let cell = (t.max(0.0) / dwell_s) as u64;
                let bad = hash01(
                    self.seed ^ SALT_STATE,
                    u64::from(worker),
                    cell,
                    0,
                ) < pi_bad;
                if bad {
                    p_bad
                } else {
                    p_good
                }
            }
        };
        for b in &self.bursts {
            if b.start_s <= t && t < b.end_s {
                p = p.max(b.rate);
            }
        }
        p
    }

    /// Pure seeded draw: is attempt `attempt` of message `msg` from
    /// `worker`, sent at `t`, lost?
    pub fn lost(&self, worker: u32, msg: u64, attempt: u32, t: f64) -> bool {
        let p = self.rate_at(worker, t);
        if p <= 0.0 {
            return false;
        }
        if p >= 1.0 {
            return true;
        }
        hash01(self.seed, u64::from(worker), msg, u64::from(attempt)) < p
    }

    /// Price one lossy transfer on a single-path link: attempt-by-attempt
    /// `transfer_end` with backoff restarts. `bits = 0` messages carry no
    /// payload and cannot be lost (they price exactly as today).
    pub fn price(
        &self,
        link: &Link,
        worker: u32,
        msg: u64,
        start: f64,
        bits: u64,
    ) -> LossyOutcome {
        let mut attempt = 0u32;
        let mut s = start;
        loop {
            let tm = link.transfer_end(s, bits);
            if bits == 0
                || attempt + 1 >= MAX_ATTEMPTS
                || !self.lost(worker, msg, attempt, s)
            {
                return LossyOutcome {
                    tm,
                    tx_secs: tm - s,
                    retx_secs: s - start,
                    attempts: attempt + 1,
                };
            }
            s = tm + self.backoff(attempt);
            attempt += 1;
        }
    }

    /// Bonded form: the *whole payload* is retransmitted on loss (the
    /// water-filling split is per attempt). Each path becomes free at its
    /// attempt `tx_end`, and the retry starts `backoff` later on every
    /// path. Returns the final attempt's schedule plus the attempt count
    /// and the earliest-path delay accumulated before it.
    pub fn price_bonded(
        &self,
        bond: &Bond,
        worker: u32,
        msg: u64,
        starts: &[f64],
        bits: u64,
    ) -> (BondSchedule, u32, f64) {
        let first_min =
            starts.iter().copied().fold(f64::INFINITY, f64::min);
        let mut attempt = 0u32;
        let mut cur: Vec<f64> = starts.to_vec();
        loop {
            let sched = bond.schedule(&cur, bits);
            let sent_at =
                cur.iter().copied().fold(f64::INFINITY, f64::min);
            if bits == 0
                || attempt + 1 >= MAX_ATTEMPTS
                || !self.lost(worker, msg, attempt, sent_at)
            {
                let retx = sent_at - first_min;
                return (sched, attempt + 1, retx);
            }
            let back = self.backoff(attempt);
            for (c, &e) in cur.iter_mut().zip(&sched.tx_end) {
                *c = e + back;
            }
            attempt += 1;
        }
    }

    /// Realized mean loss rate over `[t0, t1)` — the audit layer's ground
    /// truth (exact for the piecewise-constant rate process: integrates
    /// over dwell-cell and burst-window breakpoints).
    pub fn mean_rate_over(&self, worker: u32, t0: f64, t1: f64) -> f64 {
        if !(t1 > t0) {
            return self.rate_at(worker, t0);
        }
        let mut cuts = vec![t0, t1];
        if let LossKind::GilbertElliott { dwell_s, .. } = self.kind {
            let mut c = (t0 / dwell_s).floor() * dwell_s + dwell_s;
            // dwell cells shorter than 1e-6 of the span would blow up the
            // breakpoint list; the grid is fine enough below that
            let max_cuts = 4_000_000usize;
            let mut n = 0;
            while c < t1 && n < max_cuts {
                cuts.push(c);
                c += dwell_s;
                n += 1;
            }
        }
        for b in &self.bursts {
            if b.start_s > t0 && b.start_s < t1 {
                cuts.push(b.start_s);
            }
            if b.end_s > t0 && b.end_s < t1 {
                cuts.push(b.end_s);
            }
        }
        cuts.sort_by(f64::total_cmp);
        let mut acc = 0.0;
        for w in cuts.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            if hi > lo {
                acc += self.rate_at(worker, 0.5 * (lo + hi)) * (hi - lo);
            }
        }
        acc / (t1 - t0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{BandwidthTrace, TraceKind};

    fn link(bps: f64, lat: f64) -> Link {
        Link::new(BandwidthTrace::constant(bps), lat)
    }

    #[test]
    fn rate_zero_prices_bit_identical_to_lossless() {
        let lp = LossProcess::iid(0.0, 7);
        assert!(lp.is_lossless());
        let links = [
            link(1e8, 0.1),
            Link::new(
                BandwidthTrace::new(TraceKind::Sine {
                    mean_bps: 5e7,
                    amp_bps: 2e7,
                    period_s: 3.0,
                }),
                0.25,
            ),
        ];
        for l in &links {
            for bits in [0u64, 1, 4_000_000, 900_000_000] {
                for start in [0.0, 1.75, 42.0] {
                    let out = lp.price(l, 3, 11, start, bits);
                    assert_eq!(
                        out.tm.to_bits(),
                        l.transfer_end(start, bits).to_bits()
                    );
                    assert_eq!(out.attempts, 1);
                    assert_eq!(out.retx_secs, 0.0);
                }
            }
        }
    }

    #[test]
    fn draws_are_pure_and_seeded() {
        let lp = LossProcess::iid(0.4, 99);
        for (w, m, a) in [(0u32, 0u64, 0u32), (1, 5, 2), (7, 1000, 3)] {
            assert_eq!(lp.lost(w, m, a, 1.0), lp.lost(w, m, a, 1.0));
        }
        // a different seed flips at least one of many draws
        let other = LossProcess::iid(0.4, 100);
        let diff = (0..200u64)
            .any(|m| lp.lost(0, m, 0, 0.0) != other.lost(0, m, 0, 0.0));
        assert!(diff, "seeds must drive the draws");
        // the empirical rate tracks p
        let hits = (0..10_000u64)
            .filter(|&m| lp.lost(0, m, 0, 0.0))
            .count();
        let frac = hits as f64 / 10_000.0;
        assert!((frac - 0.4).abs() < 0.02, "empirical rate {frac}");
    }

    #[test]
    fn retransmission_never_makes_an_arrival_earlier() {
        let l = link(1e8, 0.05);
        for seed in 0..20u64 {
            let lp = LossProcess::iid(0.5, seed).with_rto(0.1);
            for msg in 0..50u64 {
                let out = lp.price(&l, 0, msg, 1.0, 10_000_000);
                let lossless = l.transfer_end(1.0, 10_000_000);
                assert!(
                    out.tm >= lossless,
                    "lossy tm {} < lossless {lossless}",
                    out.tm
                );
                if out.attempts == 1 {
                    assert_eq!(out.tm.to_bits(), lossless.to_bits());
                    assert_eq!(out.retx_secs, 0.0);
                } else {
                    assert!(out.retx_secs > 0.0);
                }
                // tx_secs is the FINAL attempt's wire time only
                assert!((out.tx_secs - 0.1).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        let lp = LossProcess::iid(0.5, 0).with_rto(0.25);
        assert_eq!(lp.backoff(0), 0.25);
        assert_eq!(lp.backoff(1), 0.5);
        assert_eq!(lp.backoff(3), 2.0);
        assert_eq!(lp.backoff(MAX_BACKOFF_EXP + 5), lp.backoff(MAX_BACKOFF_EXP));
    }

    #[test]
    fn rate_one_terminates_at_the_attempt_cap() {
        let lp = LossProcess::iid(1.0, 0).with_rto(0.01);
        let out = lp.price(&link(1e8, 0.0), 0, 0, 0.0, 1_000_000);
        assert_eq!(out.attempts, MAX_ATTEMPTS);
        assert!(out.retx_secs > 0.0);
        // and each failed attempt occupied the link for its full wire time
        assert!(out.tm > (MAX_ATTEMPTS as f64) * 0.01);
    }

    #[test]
    fn gilbert_elliott_is_bursty_at_the_dwell_scale() {
        let lp = LossProcess::gilbert_elliott(0.0, 0.9, 0.3, 10.0, 5);
        // rate is constant within a dwell cell, varies across cells
        let mut bad_cells = 0;
        for c in 0..200u64 {
            let t = c as f64 * 10.0 + 5.0;
            let r = lp.rate_at(0, t);
            assert_eq!(r, lp.rate_at(0, t + 3.0), "constant within a cell");
            assert!(r == 0.0 || r == 0.9);
            bad_cells += usize::from(r > 0.0);
        }
        let frac = bad_cells as f64 / 200.0;
        assert!((frac - 0.3).abs() < 0.1, "bad-cell fraction {frac}");
        // independent per worker
        let differs = (0..50u64).any(|c| {
            let t = c as f64 * 10.0 + 5.0;
            lp.rate_at(0, t) != lp.rate_at(1, t)
        });
        assert!(differs, "workers must draw independent state streams");
    }

    #[test]
    fn burst_windows_spike_the_rate() {
        let lp = LossProcess::iid(0.05, 0).with_bursts(vec![LossBurstWindow {
            start_s: 10.0,
            end_s: 20.0,
            rate: 0.8,
        }]);
        assert_eq!(lp.rate_at(0, 5.0), 0.05);
        assert_eq!(lp.rate_at(0, 10.0), 0.8);
        assert_eq!(lp.rate_at(0, 19.99), 0.8);
        assert_eq!(lp.rate_at(0, 20.0), 0.05, "[start, end) like DegradeWindow");
        assert!(!lp.is_lossless());
        // mean over a covering span mixes the two rates exactly
        let m = lp.mean_rate_over(0, 0.0, 40.0);
        assert!((m - (0.05 * 30.0 + 0.8 * 10.0) / 40.0).abs() < 1e-12);
    }

    #[test]
    fn mean_rate_over_matches_iid_and_ge_cells() {
        let iid = LossProcess::iid(0.2, 0);
        assert!((iid.mean_rate_over(0, 3.0, 50.0) - 0.2).abs() < 1e-12);
        let ge = LossProcess::gilbert_elliott(0.01, 0.9, 0.25, 5.0, 9);
        // integrate by hand over the dwell grid
        let (t0, t1) = (2.5, 102.5);
        let mut acc = 0.0;
        let mut t = t0;
        while t < t1 {
            let end = ((t / 5.0).floor() * 5.0 + 5.0).min(t1);
            acc += ge.rate_at(2, 0.5 * (t + end)) * (end - t);
            t = end;
        }
        let want = acc / (t1 - t0);
        assert!((ge.mean_rate_over(2, t0, t1) - want).abs() < 1e-9);
    }

    #[test]
    fn bonded_pricing_retransmits_the_whole_payload() {
        use crate::netsim::Bond;
        let bond = Bond::new(vec![link(1e8, 0.05), link(2e7, 0.3)]);
        let lossless = bond.schedule(&[0.0, 0.0], 50_000_000);
        let lp0 = LossProcess::iid(0.0, 3);
        let (s0, a0, r0) = lp0.price_bonded(&bond, 0, 0, &[0.0, 0.0], 50_000_000);
        assert_eq!(s0.arrival.to_bits(), lossless.arrival.to_bits());
        assert_eq!((a0, r0), (1, 0.0));
        // force losses: the final schedule starts later, never earlier
        let lp = LossProcess::iid(0.97, 3).with_rto(0.1);
        let (s, attempts, retx) =
            lp.price_bonded(&bond, 0, 0, &[0.0, 0.0], 50_000_000);
        assert!(attempts > 1);
        assert!(retx > 0.0);
        assert!(s.arrival > lossless.arrival);
    }
}
