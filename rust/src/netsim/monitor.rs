//! Network monitor — the "Get a, b from the network" step of Algorithm 2.
//!
//! Workers observe completed transfers (bits, duration) and iteration
//! compute times; the monitor maintains EWMA estimates that the DeCo
//! controller polls every `E` iterations. In a real deployment this is an
//! RTT probe + throughput sampling; in the simulator the observations come
//! from the event timeline, optionally with multiplicative measurement
//! noise to exercise DeCo's robustness (ablation `exp phi --noise`).

use crate::util::{Ewma, Rng};

#[derive(Clone, Debug)]
pub struct NetworkMonitor {
    bw: Ewma,
    lat: Ewma,
    comp: Ewma,
    /// multiplicative measurement noise (0 = exact)
    noise: f64,
    rng: Rng,
}

impl NetworkMonitor {
    pub fn new(alpha: f64) -> Self {
        Self {
            bw: Ewma::new(alpha),
            lat: Ewma::new(alpha),
            comp: Ewma::new(alpha),
            noise: 0.0,
            rng: Rng::new(0xC0FFEE),
        }
    }

    pub fn with_noise(mut self, noise: f64, seed: u64) -> Self {
        self.noise = noise;
        self.rng = Rng::new(seed);
        self
    }

    fn jitter(&mut self, x: f64) -> f64 {
        if self.noise == 0.0 {
            x
        } else {
            x * (1.0 + self.noise * self.rng.normal()).max(0.05)
        }
    }

    /// A transfer of `bits` took `secs` of pure transmission time.
    pub fn observe_transfer(&mut self, bits: u64, secs: f64) {
        if secs > 0.0 && bits > 0 {
            let sample = bits as f64 / secs;
            let sample = self.jitter(sample);
            self.bw.update(sample);
        }
    }

    /// Direct bandwidth sample (bits/s), e.g. from an active probe.
    pub fn observe_bandwidth(&mut self, bps: f64) {
        let s = self.jitter(bps);
        self.bw.update(s);
    }

    pub fn observe_latency(&mut self, secs: f64) {
        let s = self.jitter(secs);
        self.lat.update(s);
    }

    pub fn observe_compute(&mut self, secs: f64) {
        self.comp.update(secs);
    }

    /// Estimated bandwidth `a` (bits/s).
    pub fn bandwidth(&self) -> Option<f64> {
        self.bw.get()
    }

    /// Estimated end-to-end latency `b` (s).
    pub fn latency(&self) -> Option<f64> {
        self.lat.get()
    }

    /// Estimated per-iteration compute time `T_comp` (s).
    pub fn compute_time(&self) -> Option<f64> {
        self.comp.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimates_converge_to_truth() {
        let mut m = NetworkMonitor::new(0.3);
        for _ in 0..100 {
            m.observe_transfer(100_000_000, 1.0); // 1e8 bps
            m.observe_latency(0.2);
            m.observe_compute(0.05);
        }
        assert!((m.bandwidth().unwrap() - 1e8).abs() < 1e3);
        assert!((m.latency().unwrap() - 0.2).abs() < 1e-9);
        assert!((m.compute_time().unwrap() - 0.05).abs() < 1e-9);
    }

    #[test]
    fn tracks_bandwidth_shift() {
        let mut m = NetworkMonitor::new(0.5);
        for _ in 0..20 {
            m.observe_bandwidth(1e8);
        }
        for _ in 0..20 {
            m.observe_bandwidth(2e7);
        }
        let est = m.bandwidth().unwrap();
        assert!((est - 2e7).abs() / 2e7 < 0.01, "est={est}");
    }

    #[test]
    fn noise_does_not_bias_much() {
        let mut m = NetworkMonitor::new(0.05).with_noise(0.2, 9);
        for _ in 0..2000 {
            m.observe_bandwidth(1e8);
        }
        let est = m.bandwidth().unwrap();
        assert!((est - 1e8).abs() / 1e8 < 0.15, "est={est}");
    }

    #[test]
    fn ignores_degenerate_observations() {
        let mut m = NetworkMonitor::new(0.3);
        m.observe_transfer(0, 1.0);
        m.observe_transfer(100, 0.0);
        assert!(m.bandwidth().is_none());
    }
}
