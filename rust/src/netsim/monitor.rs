//! Network monitors — the "Get a, b from the network" step of Algorithm 2.
//!
//! Workers observe completed transfers (bits, duration) and iteration
//! compute times; the monitors maintain EWMA estimates that the DeCo
//! controller polls every `E` iterations. In a real deployment this is an
//! RTT probe + throughput sampling; in the simulator the observations come
//! from the event timeline — since the clock prices transfers by the exact
//! prefix-integral engine (DESIGN.md §Perf), an observed `bits / tx_secs`
//! sample is the true average rate of the transfer window, not a 10 ms
//! Euler approximation of it — optionally with multiplicative measurement
//! noise to exercise DeCo's robustness (ablation `exp phi --noise`).
//!
//! [`NetworkMonitor`] estimates ONE link. [`FabricMonitor`] holds one
//! estimator per worker *path* — single-path workers have exactly one,
//! bonded workers one per path (DESIGN.md §Bonding) — plus the aggregate
//! views a strategy plans on: each worker's effective pair is its
//! bandwidth **sum** across paths and its **min** path latency, and the
//! fabric-level aggregates are the monitored **bottleneck**
//! `(min bandwidth, max latency)` over workers — the pair that actually
//! gates the synchronous aggregation on a [`super::Fabric`] — and the
//! heterogeneity-blind **mean-link** view kept as the `exp hetero` control
//! arm. With identical single-path links every per-worker estimator
//! carries identical state, so the bottleneck aggregates are bit-identical
//! to the former single-monitor path (DESIGN.md §Network-Fabric).

use super::fabric::Fabric;
use crate::util::{Ewma, Rng};

#[derive(Clone, Debug)]
pub struct NetworkMonitor {
    bw: Ewma,
    lat: Ewma,
    comp: Ewma,
    /// multiplicative measurement noise (0 = exact)
    pub(crate) noise: f64,
    rng: Rng,
}

impl NetworkMonitor {
    /// `seed` drives the measurement-noise RNG — derive it from the run
    /// seed so noisy-monitor ablations vary across seeds.
    pub fn new(alpha: f64, seed: u64) -> Self {
        Self {
            bw: Ewma::new(alpha),
            lat: Ewma::new(alpha),
            comp: Ewma::new(alpha),
            noise: 0.0,
            rng: Rng::new(seed),
        }
    }

    pub fn with_noise(mut self, noise: f64) -> Self {
        self.noise = noise;
        self
    }

    fn jitter(&mut self, x: f64) -> f64 {
        if self.noise == 0.0 {
            x
        } else {
            x * (1.0 + self.noise * self.rng.normal()).max(0.05)
        }
    }

    /// A transfer of `bits` took `secs` of pure transmission time.
    pub fn observe_transfer(&mut self, bits: u64, secs: f64) {
        if secs > 0.0 && bits > 0 {
            let sample = bits as f64 / secs;
            let sample = self.jitter(sample);
            self.bw.update(sample);
        }
    }

    /// Direct bandwidth sample (bits/s), e.g. from an active probe.
    pub fn observe_bandwidth(&mut self, bps: f64) {
        let s = self.jitter(bps);
        self.bw.update(s);
    }

    pub fn observe_latency(&mut self, secs: f64) {
        let s = self.jitter(secs);
        self.lat.update(s);
    }

    pub fn observe_compute(&mut self, secs: f64) {
        self.comp.update(secs);
    }

    /// Estimated bandwidth `a` (bits/s).
    pub fn bandwidth(&self) -> Option<f64> {
        self.bw.get()
    }

    /// Estimated end-to-end latency `b` (s).
    pub fn latency(&self) -> Option<f64> {
        self.lat.get()
    }

    /// Estimated per-iteration compute time `T_comp` (s).
    pub fn compute_time(&self) -> Option<f64> {
        self.comp.get()
    }
}

/// Per-path estimators plus the aggregate views DeCo plans on.
#[derive(Clone, Debug)]
pub struct FabricMonitor {
    /// one estimator per worker path; single-path workers hold exactly one
    workers: Vec<Vec<NetworkMonitor>>,
    /// compute time is a property of the iteration, not of any link
    comp: Ewma,
    /// membership mask (elastic subsystem, DESIGN.md §Elasticity): departed
    /// workers keep their estimator state — a `Rejoin` resumes the warm
    /// EWMAs — but are excluded from every aggregate view, so a strategy
    /// always plans on the *active-set* fabric.
    active: Vec<bool>,
}

/// Per-path noise RNG stream: path 0 reduces exactly to the historical
/// per-link formula, so single-path runs replay bit-identically.
fn path_seed(seed: u64, worker: usize, path: usize) -> u64 {
    seed ^ (worker as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)
        ^ (path as u64).wrapping_mul(0xD1B54A32D192ED03)
}

impl FabricMonitor {
    /// One single-path estimator per worker; each path's noise RNG stream
    /// is derived from the run `seed` and the (worker, path) index.
    pub fn new(n: usize, alpha: f64, seed: u64) -> Self {
        Self::with_paths(&vec![1; n], alpha, seed)
    }

    /// Estimators matching a fabric's path geometry: one per worker path.
    pub fn for_fabric(fabric: &Fabric, alpha: f64, seed: u64) -> Self {
        Self::with_paths(&fabric.paths_per_worker(), alpha, seed)
    }

    /// One estimator per worker path, `paths[w]` paths for worker `w`.
    pub fn with_paths(paths: &[usize], alpha: f64, seed: u64) -> Self {
        assert!(!paths.is_empty());
        assert!(paths.iter().all(|&k| k > 0), "every worker has >= 1 path");
        Self {
            workers: paths
                .iter()
                .enumerate()
                .map(|(i, &k)| {
                    (0..k)
                        .map(|p| {
                            NetworkMonitor::new(alpha, path_seed(seed, i, p))
                        })
                        .collect()
                })
                .collect(),
            comp: Ewma::new(alpha),
            active: vec![true; paths.len()],
        }
    }

    /// Membership change: `false` freezes the worker's estimator out of the
    /// aggregates (its state is retained for a warm rejoin), `true` folds
    /// it back in.
    pub fn set_active(&mut self, worker: usize, active: bool) {
        self.active[worker] = active;
    }

    /// Workers currently folded into the aggregate views.
    pub fn active_links(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Apply multiplicative measurement noise to every path estimator.
    pub fn with_noise(mut self, noise: f64) -> Self {
        for w in &mut self.workers {
            for m in w {
                m.noise = noise;
            }
        }
        self
    }

    /// Worker count (one estimated "link" per worker, however many paths).
    pub fn links(&self) -> usize {
        self.workers.len()
    }

    /// Worker `worker`'s path-0 estimator — the whole link on single-path
    /// workers.
    pub fn link(&self, worker: usize) -> &NetworkMonitor {
        &self.workers[worker][0]
    }

    /// Path count for one worker.
    pub fn paths(&self, worker: usize) -> usize {
        self.workers[worker].len()
    }

    /// One specific path estimator of a (possibly bonded) worker.
    pub fn path(&self, worker: usize, path: usize) -> &NetworkMonitor {
        &self.workers[worker][path]
    }

    /// Worker `worker` finished a transfer of `bits` in `secs` of pure
    /// transmission time (path 0 — the single-path observation).
    pub fn observe_transfer(&mut self, worker: usize, bits: u64, secs: f64) {
        self.workers[worker][0].observe_transfer(bits, secs);
    }

    /// One path of a bonded worker carried `bits` (its water-filling
    /// share, fractional) in `secs` of pure transmission time.
    pub fn observe_path_transfer(
        &mut self,
        worker: usize,
        path: usize,
        bits: f64,
        secs: f64,
    ) {
        if secs > 0.0 && bits > 0.0 {
            self.workers[worker][path].observe_bandwidth(bits / secs);
        }
    }

    /// Latency sample for one worker's link (path 0).
    pub fn observe_latency_for(&mut self, worker: usize, secs: f64) {
        self.workers[worker][0].observe_latency(secs);
    }

    /// Latency sample for one path of a bonded worker.
    pub fn observe_path_latency(
        &mut self,
        worker: usize,
        path: usize,
        secs: f64,
    ) {
        self.workers[worker][path].observe_latency(secs);
    }

    pub fn observe_compute(&mut self, secs: f64) {
        self.comp.update(secs);
    }

    /// Broadcast a bandwidth probe to every path (tests / active probing).
    pub fn observe_bandwidth(&mut self, bps: f64) {
        for w in &mut self.workers {
            for m in w {
                m.observe_bandwidth(bps);
            }
        }
    }

    /// Broadcast a latency probe to every path (tests / active probing).
    pub fn observe_latency(&mut self, secs: f64) {
        for w in &mut self.workers {
            for m in w {
                m.observe_latency(secs);
            }
        }
    }

    /// One worker's effective bandwidth estimate: the path estimate on
    /// single-path workers, the **sum** of available path estimates on a
    /// bonded worker (the water-filling scheduler really does extract the
    /// aggregate rate, so DeCo should plan on it).
    pub fn worker_bandwidth(&self, worker: usize) -> Option<f64> {
        let paths = &self.workers[worker];
        if paths.len() == 1 {
            return paths[0].bandwidth();
        }
        let mut sum = 0.0;
        let mut seen = false;
        for m in paths {
            if let Some(a) = m.bandwidth() {
                sum += a;
                seen = true;
            }
        }
        seen.then_some(sum)
    }

    /// One worker's effective latency estimate: the path estimate on
    /// single-path workers, the **bandwidth-weighted** mean over available
    /// path estimates on a bonded worker — the water-filling scheduler
    /// routes bits in proportion to path bandwidth, so a bond with one
    /// fast-but-thin and one slow-but-fat path mostly pays the slow path's
    /// latency. (The bare min would under-price it and mislead DeCo's `b`
    /// input.) Paths with a latency estimate but no bandwidth estimate yet
    /// carry zero weight; if no path has both, fall back to the min over
    /// latency estimates.
    pub fn worker_latency(&self, worker: usize) -> Option<f64> {
        let paths = &self.workers[worker];
        if paths.len() == 1 {
            return paths[0].latency();
        }
        let (mut num, mut den) = (0.0, 0.0);
        let mut min = f64::INFINITY;
        let mut seen = false;
        for m in paths {
            if let Some(b) = m.latency() {
                seen = true;
                min = min.min(b);
                if let Some(a) = m.bandwidth() {
                    num += a * b;
                    den += a;
                }
            }
        }
        if !seen {
            return None;
        }
        Some(if den > 0.0 { num / den } else { min })
    }

    /// Active workers' effective views in worker order — the stream every
    /// aggregate draws from.
    fn active_views<'a, F: Fn(usize) -> Option<f64> + 'a>(
        &'a self,
        view: F,
    ) -> impl Iterator<Item = f64> + 'a {
        self.active
            .iter()
            .enumerate()
            .filter(|(_, &a)| a)
            .filter_map(move |(i, _)| view(i))
    }

    /// Aggregate bandwidth `a`: the monitored **bottleneck** (min over
    /// active workers with an estimate).
    pub fn bandwidth(&self) -> Option<f64> {
        self.active_views(|i| self.worker_bandwidth(i)).reduce(f64::min)
    }

    /// Aggregate latency `b`: the monitored **bottleneck** (max over active
    /// workers with an estimate).
    pub fn latency(&self) -> Option<f64> {
        self.active_views(|i| self.worker_latency(i)).reduce(f64::max)
    }

    /// Mean-link bandwidth — the heterogeneity-blind control view.
    pub fn mean_bandwidth(&self) -> Option<f64> {
        Self::mean(self.active_views(|i| self.worker_bandwidth(i)))
    }

    /// Mean-link latency — the heterogeneity-blind control view.
    pub fn mean_latency(&self) -> Option<f64> {
        Self::mean(self.active_views(|i| self.worker_latency(i)))
    }

    fn mean(vals: impl Iterator<Item = f64>) -> Option<f64> {
        let (mut sum, mut n) = (0.0, 0usize);
        for v in vals {
            sum += v;
            n += 1;
        }
        if n > 0 {
            Some(sum / n as f64)
        } else {
            None
        }
    }

    pub fn compute_time(&self) -> Option<f64> {
        self.comp.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimates_converge_to_truth() {
        let mut m = NetworkMonitor::new(0.3, 0);
        for _ in 0..100 {
            m.observe_transfer(100_000_000, 1.0); // 1e8 bps
            m.observe_latency(0.2);
            m.observe_compute(0.05);
        }
        assert!((m.bandwidth().unwrap() - 1e8).abs() < 1e3);
        assert!((m.latency().unwrap() - 0.2).abs() < 1e-9);
        assert!((m.compute_time().unwrap() - 0.05).abs() < 1e-9);
    }

    #[test]
    fn tracks_bandwidth_shift() {
        let mut m = NetworkMonitor::new(0.5, 0);
        for _ in 0..20 {
            m.observe_bandwidth(1e8);
        }
        for _ in 0..20 {
            m.observe_bandwidth(2e7);
        }
        let est = m.bandwidth().unwrap();
        assert!((est - 2e7).abs() / 2e7 < 0.01, "est={est}");
    }

    #[test]
    fn noise_does_not_bias_much() {
        let mut m = NetworkMonitor::new(0.05, 9).with_noise(0.2);
        for _ in 0..2000 {
            m.observe_bandwidth(1e8);
        }
        let est = m.bandwidth().unwrap();
        assert!((est - 1e8).abs() / 1e8 < 0.15, "est={est}");
    }

    #[test]
    fn noise_stream_follows_seed() {
        // same observations, different seeds => different noisy estimates
        let run = |seed: u64| {
            let mut m = NetworkMonitor::new(0.3, seed).with_noise(0.3);
            for _ in 0..10 {
                m.observe_bandwidth(1e8);
            }
            m.bandwidth().unwrap()
        };
        assert_ne!(run(1), run(2));
        assert_eq!(run(7), run(7), "same seed must replay exactly");
    }

    #[test]
    fn ignores_degenerate_observations() {
        let mut m = NetworkMonitor::new(0.3, 0);
        m.observe_transfer(0, 1.0);
        m.observe_transfer(100, 0.0);
        assert!(m.bandwidth().is_none());
    }

    #[test]
    fn fabric_monitor_bottleneck_and_mean() {
        let mut fm = FabricMonitor::new(3, 0.5, 0);
        assert_eq!(fm.links(), 3);
        assert!(fm.bandwidth().is_none() && fm.latency().is_none());
        for _ in 0..30 {
            fm.observe_transfer(0, 10_000_000, 1.0); // 1e7 bps straggler
            fm.observe_transfer(1, 100_000_000, 1.0); // 1e8
            fm.observe_transfer(2, 100_000_000, 1.0); // 1e8
            fm.observe_latency_for(0, 0.6);
            fm.observe_latency_for(1, 0.1);
            fm.observe_latency_for(2, 0.1);
            fm.observe_compute(0.2);
        }
        let a = fm.bandwidth().unwrap();
        let b = fm.latency().unwrap();
        assert!((a - 1e7).abs() < 1.0, "bottleneck bw {a}");
        assert!((b - 0.6).abs() < 1e-9, "bottleneck lat {b}");
        let am = fm.mean_bandwidth().unwrap();
        let bm = fm.mean_latency().unwrap();
        assert!((am - 7e7).abs() < 1.0, "mean bw {am}");
        assert!((bm - 0.8 / 3.0).abs() < 1e-9, "mean lat {bm}");
        assert!((fm.compute_time().unwrap() - 0.2).abs() < 1e-9);
    }

    #[test]
    fn departed_worker_leaves_the_aggregates_and_rejoins_warm() {
        let mut fm = FabricMonitor::new(3, 0.5, 0);
        for _ in 0..30 {
            fm.observe_transfer(0, 10_000_000, 1.0); // 1e7 bps straggler
            fm.observe_transfer(1, 100_000_000, 1.0);
            fm.observe_transfer(2, 100_000_000, 1.0);
            fm.observe_latency_for(0, 0.6);
            fm.observe_latency_for(1, 0.1);
            fm.observe_latency_for(2, 0.1);
        }
        assert!((fm.bandwidth().unwrap() - 1e7).abs() < 1.0);
        // the straggler departs: bottleneck snaps to the healthy links
        fm.set_active(0, false);
        assert_eq!(fm.active_links(), 2);
        assert!((fm.bandwidth().unwrap() - 1e8).abs() < 1.0);
        assert!((fm.latency().unwrap() - 0.1).abs() < 1e-9);
        assert!((fm.mean_bandwidth().unwrap() - 1e8).abs() < 1.0);
        // rejoin: the warm estimator folds straight back in, no re-warmup
        fm.set_active(0, true);
        assert!((fm.bandwidth().unwrap() - 1e7).abs() < 1.0);
        assert!((fm.latency().unwrap() - 0.6).abs() < 1e-9);
    }

    #[test]
    fn fabric_monitor_homogeneous_matches_single() {
        // identical links => aggregates bit-identical to one NetworkMonitor
        let mut single = NetworkMonitor::new(0.3, 0);
        let mut fm = FabricMonitor::new(4, 0.3, 0);
        for k in 0..50u64 {
            let bits = 1_000_000 + k * 31_337;
            let secs = 0.01 + (k as f64) * 1e-4;
            single.observe_transfer(bits, secs);
            single.observe_latency(0.2);
            single.observe_compute(0.05);
            for w in 0..4 {
                fm.observe_transfer(w, bits, secs);
                fm.observe_latency_for(w, 0.2);
            }
            fm.observe_compute(0.05);
        }
        assert_eq!(
            fm.bandwidth().unwrap().to_bits(),
            single.bandwidth().unwrap().to_bits()
        );
        assert_eq!(
            fm.latency().unwrap().to_bits(),
            single.latency().unwrap().to_bits()
        );
        assert_eq!(
            fm.compute_time().unwrap().to_bits(),
            single.compute_time().unwrap().to_bits()
        );
    }

    #[test]
    fn path_zero_seed_matches_the_historical_per_link_stream() {
        // a 2-path monitor's path 0 must carry the exact noise stream the
        // single-path monitor had, so legacy estimates replay bitwise
        let mut legacy = FabricMonitor::new(2, 0.3, 42).with_noise(0.25);
        let mut bonded =
            FabricMonitor::with_paths(&[2, 1], 0.3, 42).with_noise(0.25);
        for _ in 0..20 {
            legacy.observe_transfer(0, 5_000_000, 0.5);
            bonded.observe_transfer(0, 5_000_000, 0.5);
        }
        assert_eq!(
            legacy.link(0).bandwidth().unwrap().to_bits(),
            bonded.link(0).bandwidth().unwrap().to_bits()
        );
    }

    #[test]
    fn bonded_worker_sums_bandwidth_and_weights_latency() {
        let mut fm = FabricMonitor::with_paths(&[2, 1], 0.5, 0);
        for _ in 0..30 {
            fm.observe_path_transfer(0, 0, 100_000_000.0, 1.0); // 1e8
            fm.observe_path_transfer(0, 1, 20_000_000.0, 1.0); // 2e7
            fm.observe_path_latency(0, 0, 0.05);
            fm.observe_path_latency(0, 1, 0.3);
            fm.observe_transfer(1, 100_000_000, 1.0);
            fm.observe_latency_for(1, 0.1);
        }
        let w0 = fm.worker_bandwidth(0).unwrap();
        assert!((w0 - 1.2e8).abs() < 1.0, "sum over paths, got {w0}");
        // bandwidth-weighted across paths: (1e8·0.05 + 2e7·0.3) / 1.2e8 —
        // most bits ride the fat path, so its latency dominates
        assert!((fm.worker_latency(0).unwrap() - 11e6 / 1.2e8).abs() < 1e-12);
        // bottleneck over workers: worker 1's 1e8 < worker 0's 1.2e8
        assert!((fm.bandwidth().unwrap() - 1e8).abs() < 1.0);
        assert!((fm.latency().unwrap() - 0.1).abs() < 1e-12);
        // one path collapsing drags the bonded aggregate below worker 1
        for _ in 0..60 {
            fm.observe_path_transfer(0, 0, 1_000.0, 1.0); // outage floor
        }
        assert!(fm.worker_bandwidth(0).unwrap() < 3e7);
        assert!(fm.bandwidth().unwrap() < 3e7);
    }

    #[test]
    fn partial_path_estimates_still_aggregate() {
        // only one path of a bond has samples: the worker view uses what
        // it has instead of reporting nothing
        let mut fm = FabricMonitor::with_paths(&[2], 0.5, 0);
        assert!(fm.worker_bandwidth(0).is_none());
        fm.observe_path_transfer(0, 1, 20_000_000.0, 1.0);
        assert!((fm.worker_bandwidth(0).unwrap() - 2e7).abs() < 1.0);
        fm.observe_path_latency(0, 1, 0.3);
        assert!((fm.worker_latency(0).unwrap() - 0.3).abs() < 1e-12);
    }
}
