//! Network monitors — the "Get a, b from the network" step of Algorithm 2.
//!
//! Workers observe completed transfers (bits, duration) and iteration
//! compute times; the monitors maintain EWMA estimates that the DeCo
//! controller polls every `E` iterations. In a real deployment this is an
//! RTT probe + throughput sampling; in the simulator the observations come
//! from the event timeline — since the clock prices transfers by the exact
//! prefix-integral engine (DESIGN.md §Perf), an observed `bits / tx_secs`
//! sample is the true average rate of the transfer window, not a 10 ms
//! Euler approximation of it — optionally with multiplicative measurement
//! noise to exercise DeCo's robustness (ablation `exp phi --noise`).
//!
//! [`NetworkMonitor`] estimates ONE link. [`FabricMonitor`] holds one
//! estimator per worker *path* — single-path workers have exactly one,
//! bonded workers one per path (DESIGN.md §Bonding) — plus the aggregate
//! views a strategy plans on: each worker's effective pair is its
//! bandwidth **sum** across paths and its **min** path latency, and the
//! fabric-level aggregates are the monitored **bottleneck**
//! `(min bandwidth, max latency)` over workers — the pair that actually
//! gates the synchronous aggregation on a [`super::Fabric`] — and the
//! heterogeneity-blind **mean-link** view kept as the `exp hetero` control
//! arm. With identical single-path links every per-worker estimator
//! carries identical state, so the bottleneck aggregates are bit-identical
//! to the former single-monitor path (DESIGN.md §Network-Fabric).

use super::fabric::Fabric;
use crate::util::{Ewma, Rng};

#[derive(Clone, Debug)]
pub struct NetworkMonitor {
    bw: Ewma,
    lat: Ewma,
    comp: Ewma,
    /// mean transmission attempts per delivered message (lossy transport,
    /// DESIGN.md §Robustness): 1.0 on a clean link, `1/(1-p)` in
    /// expectation under i.i.d. loss rate `p`
    att: Ewma,
    /// multiplicative measurement noise (0 = exact)
    pub(crate) noise: f64,
    rng: Rng,
}

impl NetworkMonitor {
    /// `seed` drives the measurement-noise RNG — derive it from the run
    /// seed so noisy-monitor ablations vary across seeds.
    pub fn new(alpha: f64, seed: u64) -> Self {
        Self {
            bw: Ewma::new(alpha),
            lat: Ewma::new(alpha),
            comp: Ewma::new(alpha),
            att: Ewma::new(alpha),
            noise: 0.0,
            rng: Rng::new(seed),
        }
    }

    pub fn with_noise(mut self, noise: f64) -> Self {
        self.noise = noise;
        self
    }

    fn jitter(&mut self, x: f64) -> f64 {
        if self.noise == 0.0 {
            x
        } else {
            x * (1.0 + self.noise * self.rng.normal()).max(0.05)
        }
    }

    /// A transfer of `bits` took `secs` of pure transmission time.
    pub fn observe_transfer(&mut self, bits: u64, secs: f64) {
        if secs > 0.0 && bits > 0 {
            let sample = bits as f64 / secs;
            let sample = self.jitter(sample);
            self.bw.update(sample);
        }
    }

    /// Direct bandwidth sample (bits/s), e.g. from an active probe.
    pub fn observe_bandwidth(&mut self, bps: f64) {
        let s = self.jitter(bps);
        self.bw.update(s);
    }

    pub fn observe_latency(&mut self, secs: f64) {
        let s = self.jitter(secs);
        self.lat.update(s);
    }

    pub fn observe_compute(&mut self, secs: f64) {
        self.comp.update(secs);
    }

    /// A delivered message took `attempts` transmissions (1 = no loss).
    /// Deliberately noise-free: attempt counts are exact in any transport.
    pub fn observe_attempts(&mut self, attempts: f64) {
        if attempts >= 1.0 {
            self.att.update(attempts);
        }
    }

    /// Estimated bandwidth `a` (bits/s).
    pub fn bandwidth(&self) -> Option<f64> {
        self.bw.get()
    }

    /// Estimated end-to-end latency `b` (s).
    pub fn latency(&self) -> Option<f64> {
        self.lat.get()
    }

    /// Estimated per-iteration compute time `T_comp` (s).
    pub fn compute_time(&self) -> Option<f64> {
        self.comp.get()
    }

    /// Mean attempts per delivered message (`None` before any sample).
    pub fn attempts(&self) -> Option<f64> {
        self.att.get()
    }

    /// Estimated message-loss rate, inverted from the attempt EWMA: a
    /// geometric attempt count with mean `m` implies `p = 1 - 1/m`.
    pub fn loss_rate(&self) -> Option<f64> {
        self.att.get().map(|m| (1.0 - 1.0 / m.max(1.0)).clamp(0.0, 1.0))
    }
}

/// Snapshot of one estimator slot's effective views at a re-plan instant
/// (recorded into [`crate::obs::ReplanRecord`] for the audit layer): the
/// optimistic worker views DeCo plans on plus the pessimistic band
/// (min path bandwidth / max path latency) that brackets a bonded
/// worker's true effective pair. Single-path workers carry a degenerate
/// band (`bw == bw_pess`, `lat == lat_pess`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SlotEstimate {
    /// representative worker: the slot's lowest-indexed active member
    pub worker: u32,
    /// active workers sharing this estimator slot
    pub members: u32,
    /// optimistic effective bandwidth (Σ over bonded paths), bits/s
    pub bw: f64,
    /// optimistic effective latency (bandwidth-weighted over paths), s
    pub lat: f64,
    /// pessimistic bandwidth (min over bonded paths), bits/s
    pub bw_pess: f64,
    /// pessimistic latency (max over bonded paths), s
    pub lat_pess: f64,
}

/// Per-path estimators plus the aggregate views DeCo plans on.
///
/// Storage is slot-indirected (DESIGN.md §Observability): workers whose
/// estimators are bitwise identical may share one *slot* (one per-path
/// estimator set), which makes the class-level observation path
/// ([`Self::observe_class_transfer`]) O(live classes) per tick instead of
/// O(workers). Per-worker writes copy a shared slot out first
/// (copy-on-write), so mixed per-worker / per-class use stays sound;
/// reads are one indirection.
#[derive(Clone, Debug)]
pub struct FabricMonitor {
    /// estimator slots; `slots[slot_of[w]]` is worker `w`'s per-path set
    /// (single-path workers hold exactly one estimator)
    slots: Vec<Vec<NetworkMonitor>>,
    /// worker → slot index
    slot_of: Vec<usize>,
    /// live pointer count per slot (0 = orphaned by a split / regroup)
    slot_members: Vec<usize>,
    /// compute time is a property of the iteration, not of any link
    comp: Ewma,
    /// membership mask (elastic subsystem, DESIGN.md §Elasticity): departed
    /// workers keep their estimator state — a `Rejoin` resumes the warm
    /// EWMAs — but are excluded from every aggregate view, so a strategy
    /// always plans on the *active-set* fabric.
    active: Vec<bool>,
    /// whether any estimator carries measurement noise — the noise RNG
    /// streams are per worker, so noisy estimators never share slots
    noisy: bool,
}

/// Per-path noise RNG stream: path 0 reduces exactly to the historical
/// per-link formula, so single-path runs replay bit-identically.
fn path_seed(seed: u64, worker: usize, path: usize) -> u64 {
    seed ^ (worker as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)
        ^ (path as u64).wrapping_mul(0xD1B54A32D192ED03)
}

impl FabricMonitor {
    /// One single-path estimator per worker; each path's noise RNG stream
    /// is derived from the run `seed` and the (worker, path) index.
    pub fn new(n: usize, alpha: f64, seed: u64) -> Self {
        Self::with_paths(&vec![1; n], alpha, seed)
    }

    /// Estimators matching a fabric's path geometry: one per worker path.
    pub fn for_fabric(fabric: &Fabric, alpha: f64, seed: u64) -> Self {
        Self::with_paths(&fabric.paths_per_worker(), alpha, seed)
    }

    /// One estimator per worker path, `paths[w]` paths for worker `w`.
    pub fn with_paths(paths: &[usize], alpha: f64, seed: u64) -> Self {
        assert!(!paths.is_empty());
        assert!(paths.iter().all(|&k| k > 0), "every worker has >= 1 path");
        Self {
            slots: paths
                .iter()
                .enumerate()
                .map(|(i, &k)| {
                    (0..k)
                        .map(|p| {
                            NetworkMonitor::new(alpha, path_seed(seed, i, p))
                        })
                        .collect()
                })
                .collect(),
            slot_of: (0..paths.len()).collect(),
            slot_members: vec![1; paths.len()],
            comp: Ewma::new(alpha),
            active: vec![true; paths.len()],
            noisy: false,
        }
    }

    /// Membership change: `false` freezes the worker's estimator out of the
    /// aggregates (its state is retained for a warm rejoin), `true` folds
    /// it back in.
    pub fn set_active(&mut self, worker: usize, active: bool) {
        self.active[worker] = active;
    }

    /// Workers currently folded into the aggregate views.
    pub fn active_links(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Apply multiplicative measurement noise to every path estimator.
    /// Intended at construction time (all current callers), before any
    /// slots have been shared by class-level observations.
    pub fn with_noise(mut self, noise: f64) -> Self {
        for slot in &mut self.slots {
            for m in slot {
                m.noise = noise;
            }
        }
        self.noisy = noise != 0.0;
        self
    }

    /// True when no estimator carries measurement noise — the condition
    /// under which workers with identical observation histories hold
    /// bitwise-identical estimator state (and may share slots).
    pub fn noiseless(&self) -> bool {
        !self.noisy
    }

    /// Worker count (one estimated "link" per worker, however many paths).
    pub fn links(&self) -> usize {
        self.slot_of.len()
    }

    /// Worker `worker`'s path-0 estimator — the whole link on single-path
    /// workers.
    pub fn link(&self, worker: usize) -> &NetworkMonitor {
        &self.slots[self.slot_of[worker]][0]
    }

    /// Path count for one worker.
    pub fn paths(&self, worker: usize) -> usize {
        self.slots[self.slot_of[worker]].len()
    }

    /// One specific path estimator of a (possibly bonded) worker.
    pub fn path(&self, worker: usize, path: usize) -> &NetworkMonitor {
        &self.slots[self.slot_of[worker]][path]
    }

    /// Exclusive slot for one worker, splitting a shared slot out
    /// copy-on-write first.
    fn own_slot(&mut self, worker: usize) -> usize {
        let s = self.slot_of[worker];
        if self.slot_members[s] == 1 {
            return s;
        }
        self.slot_members[s] -= 1;
        let split = self.slots[s].clone();
        self.slots.push(split);
        self.slot_members.push(1);
        self.slot_of[worker] = self.slots.len() - 1;
        self.slots.len() - 1
    }

    /// Slot shared by exactly `members`. O(1) in the steady state (the
    /// class already shares a slot — pointer count equals the member
    /// count, which under split-only class evolution implies set
    /// equality); otherwise the first member's state is cloned into a
    /// fresh slot and every member repointed at it.
    fn class_slot(&mut self, members: &[u32]) -> usize {
        let s = self.slot_of[members[0] as usize];
        if self.slot_members[s] == members.len() {
            return s;
        }
        let shared = self.slots[s].clone();
        self.slots.push(shared);
        self.slot_members.push(members.len());
        let ns = self.slots.len() - 1;
        for &w in members {
            let old = self.slot_of[w as usize];
            self.slot_members[old] -= 1;
            self.slot_of[w as usize] = ns;
        }
        ns
    }

    /// Worker `worker` finished a transfer of `bits` in `secs` of pure
    /// transmission time (path 0 — the single-path observation).
    pub fn observe_transfer(&mut self, worker: usize, bits: u64, secs: f64) {
        let s = self.own_slot(worker);
        self.slots[s][0].observe_transfer(bits, secs);
    }

    /// One estimator update for a whole timeline class: every worker in
    /// `members` observed the same `(bits, secs)` transfer. Requires that
    /// the members' estimators have seen identical observation histories
    /// — exactly what the clock's shared-timeline classes guarantee (they
    /// only ever split); grouping divergent workers would collapse their
    /// state onto the first member's. With measurement noise the
    /// per-worker RNG streams differ, so the update falls back to
    /// per-member writes.
    pub fn observe_class_transfer(
        &mut self,
        members: &[u32],
        bits: u64,
        secs: f64,
    ) {
        assert!(!members.is_empty());
        if self.noisy {
            for &w in members {
                self.observe_transfer(w as usize, bits, secs);
            }
            return;
        }
        let s = self.class_slot(members);
        self.slots[s][0].observe_transfer(bits, secs);
    }

    /// One path of a bonded worker carried `bits` (its water-filling
    /// share, fractional) in `secs` of pure transmission time.
    pub fn observe_path_transfer(
        &mut self,
        worker: usize,
        path: usize,
        bits: f64,
        secs: f64,
    ) {
        if secs > 0.0 && bits > 0.0 {
            let s = self.own_slot(worker);
            self.slots[s][path].observe_bandwidth(bits / secs);
        }
    }

    /// Latency sample for one worker's link (path 0).
    pub fn observe_latency_for(&mut self, worker: usize, secs: f64) {
        let s = self.own_slot(worker);
        self.slots[s][0].observe_latency(secs);
    }

    /// Class-level form of [`Self::observe_latency_for`] — same contract
    /// as [`Self::observe_class_transfer`].
    pub fn observe_class_latency(&mut self, members: &[u32], secs: f64) {
        assert!(!members.is_empty());
        if self.noisy {
            for &w in members {
                self.observe_latency_for(w as usize, secs);
            }
            return;
        }
        let s = self.class_slot(members);
        self.slots[s][0].observe_latency(secs);
    }

    /// Latency sample for one path of a bonded worker.
    pub fn observe_path_latency(
        &mut self,
        worker: usize,
        path: usize,
        secs: f64,
    ) {
        let s = self.own_slot(worker);
        self.slots[s][path].observe_latency(secs);
    }

    pub fn observe_compute(&mut self, secs: f64) {
        self.comp.update(secs);
    }

    /// Worker `worker` delivered its gradient in `attempts` transmissions
    /// (path 0 — the retransmission loop rides the whole bond, so bonded
    /// workers record on their first path too). Lossy workers are always
    /// singleton timeline classes, so there is no class-level form.
    pub fn observe_attempts(&mut self, worker: usize, attempts: f64) {
        let s = self.own_slot(worker);
        self.slots[s][0].observe_attempts(attempts);
    }

    /// Broadcast a bandwidth probe to every path (tests / active probing).
    pub fn observe_bandwidth(&mut self, bps: f64) {
        if self.noisy {
            for w in 0..self.slot_of.len() {
                let s = self.own_slot(w);
                for m in &mut self.slots[s] {
                    m.observe_bandwidth(bps);
                }
            }
        } else {
            for (s, slot) in self.slots.iter_mut().enumerate() {
                if self.slot_members[s] > 0 {
                    for m in slot {
                        m.observe_bandwidth(bps);
                    }
                }
            }
        }
    }

    /// Broadcast a latency probe to every path (tests / active probing).
    pub fn observe_latency(&mut self, secs: f64) {
        if self.noisy {
            for w in 0..self.slot_of.len() {
                let s = self.own_slot(w);
                for m in &mut self.slots[s] {
                    m.observe_latency(secs);
                }
            }
        } else {
            for (s, slot) in self.slots.iter_mut().enumerate() {
                if self.slot_members[s] > 0 {
                    for m in slot {
                        m.observe_latency(secs);
                    }
                }
            }
        }
    }

    /// One worker's effective bandwidth estimate: the path estimate on
    /// single-path workers, the **sum** of available path estimates on a
    /// bonded worker (the water-filling scheduler really does extract the
    /// aggregate rate, so DeCo should plan on it).
    pub fn worker_bandwidth(&self, worker: usize) -> Option<f64> {
        let paths = &self.slots[self.slot_of[worker]];
        if paths.len() == 1 {
            return paths[0].bandwidth();
        }
        let mut sum = 0.0;
        let mut seen = false;
        for m in paths {
            if let Some(a) = m.bandwidth() {
                sum += a;
                seen = true;
            }
        }
        seen.then_some(sum)
    }

    /// One worker's effective latency estimate: the path estimate on
    /// single-path workers, the **bandwidth-weighted** mean over available
    /// path estimates on a bonded worker — the water-filling scheduler
    /// routes bits in proportion to path bandwidth, so a bond with one
    /// fast-but-thin and one slow-but-fat path mostly pays the slow path's
    /// latency. (The bare min would under-price it and mislead DeCo's `b`
    /// input.) Paths with a latency estimate but no bandwidth estimate yet
    /// carry zero weight; if no path has both, fall back to the min over
    /// latency estimates.
    pub fn worker_latency(&self, worker: usize) -> Option<f64> {
        let paths = &self.slots[self.slot_of[worker]];
        if paths.len() == 1 {
            return paths[0].latency();
        }
        let (mut num, mut den) = (0.0, 0.0);
        let mut min = f64::INFINITY;
        let mut seen = false;
        for m in paths {
            if let Some(b) = m.latency() {
                seen = true;
                min = min.min(b);
                if let Some(a) = m.bandwidth() {
                    num += a * b;
                    den += a;
                }
            }
        }
        if !seen {
            return None;
        }
        Some(if den > 0.0 { num / den } else { min })
    }

    /// One worker's **pessimistic** bandwidth estimate: identical to
    /// [`Self::worker_bandwidth`] on single-path workers; on a bonded
    /// worker the **min** over available path estimates — the floor the
    /// bond delivers if every path but the weakest goes dark. Together
    /// with the optimistic Σ view this brackets the band the audit layer
    /// scores the planner's inputs against (DESIGN.md §Observability).
    pub fn worker_bandwidth_pessimistic(&self, worker: usize) -> Option<f64> {
        let paths = &self.slots[self.slot_of[worker]];
        if paths.len() == 1 {
            return paths[0].bandwidth();
        }
        paths.iter().filter_map(|m| m.bandwidth()).reduce(f64::min)
    }

    /// One worker's **pessimistic** latency estimate: identical to
    /// [`Self::worker_latency`] on single-path workers; on a bonded
    /// worker the **max** over available path latency estimates — what
    /// the bond pays when the slowest path carries the tail bits.
    pub fn worker_latency_pessimistic(&self, worker: usize) -> Option<f64> {
        let paths = &self.slots[self.slot_of[worker]];
        if paths.len() == 1 {
            return paths[0].latency();
        }
        paths.iter().filter_map(|m| m.latency()).reduce(f64::max)
    }

    /// Pessimistic aggregate bandwidth: the bottleneck (min over active
    /// workers) of the per-worker pessimistic views. Equals
    /// [`Self::bandwidth`] bit-for-bit when no worker is bonded.
    pub fn bandwidth_pessimistic(&self) -> Option<f64> {
        self.active_views(|i| self.worker_bandwidth_pessimistic(i))
            .reduce(f64::min)
    }

    /// Pessimistic aggregate latency: the bottleneck (max over active
    /// workers) of the per-worker pessimistic views. Equals
    /// [`Self::latency`] bit-for-bit when no worker is bonded.
    pub fn latency_pessimistic(&self) -> Option<f64> {
        self.active_views(|i| self.worker_latency_pessimistic(i))
            .reduce(f64::max)
    }

    /// Per-slot snapshot of the effective worker views at this instant —
    /// one entry per estimator slot with at least one active member and
    /// both a bandwidth and a latency estimate, ordered by each slot's
    /// lowest-indexed active member (deterministic). Shared slots emit
    /// one entry carrying their member count, so the snapshot is O(live
    /// classes) entries on class-sharing runs.
    pub fn slot_estimates(&self) -> Vec<SlotEstimate> {
        let mut members = vec![0u32; self.slots.len()];
        for w in 0..self.slot_of.len() {
            if self.active[w] {
                members[self.slot_of[w]] += 1;
            }
        }
        let mut seen = vec![false; self.slots.len()];
        let mut out = Vec::new();
        for w in 0..self.slot_of.len() {
            let s = self.slot_of[w];
            if !self.active[w] || seen[s] {
                continue;
            }
            seen[s] = true;
            let (Some(bw), Some(lat)) =
                (self.worker_bandwidth(w), self.worker_latency(w))
            else {
                continue;
            };
            out.push(SlotEstimate {
                worker: w as u32,
                members: members[s],
                bw,
                lat,
                bw_pess: self.worker_bandwidth_pessimistic(w).unwrap_or(bw),
                lat_pess: self.worker_latency_pessimistic(w).unwrap_or(lat),
            });
        }
        out
    }

    /// Active workers' effective views in worker order — the stream every
    /// aggregate draws from.
    fn active_views<'a, F: Fn(usize) -> Option<f64> + 'a>(
        &'a self,
        view: F,
    ) -> impl Iterator<Item = f64> + 'a {
        self.active
            .iter()
            .enumerate()
            .filter(|(_, &a)| a)
            .filter_map(move |(i, _)| view(i))
    }

    /// Aggregate bandwidth `a`: the monitored **bottleneck** (min over
    /// active workers with an estimate).
    pub fn bandwidth(&self) -> Option<f64> {
        self.active_views(|i| self.worker_bandwidth(i)).reduce(f64::min)
    }

    /// Aggregate latency `b`: the monitored **bottleneck** (max over active
    /// workers with an estimate).
    pub fn latency(&self) -> Option<f64> {
        self.active_views(|i| self.worker_latency(i)).reduce(f64::max)
    }

    /// Mean-link bandwidth — the heterogeneity-blind control view.
    pub fn mean_bandwidth(&self) -> Option<f64> {
        Self::mean(self.active_views(|i| self.worker_bandwidth(i)))
    }

    /// Mean-link latency — the heterogeneity-blind control view.
    pub fn mean_latency(&self) -> Option<f64> {
        Self::mean(self.active_views(|i| self.worker_latency(i)))
    }

    fn mean(vals: impl Iterator<Item = f64>) -> Option<f64> {
        let (mut sum, mut n) = (0.0, 0usize);
        for v in vals {
            sum += v;
            n += 1;
        }
        if n > 0 {
            Some(sum / n as f64)
        } else {
            None
        }
    }

    pub fn compute_time(&self) -> Option<f64> {
        self.comp.get()
    }

    /// Aggregate message-loss estimate: the **worst** (max) per-worker
    /// loss rate over active workers with an attempt sample — the rate
    /// that gates the synchronous aggregation, mirroring the bottleneck
    /// `(a, b)` views. `None` until some worker has retried or delivered
    /// first-try (clean workers that have reported attempts pull the
    /// aggregate toward 0 only for themselves; max keeps the planner
    /// honest about the lossiest link).
    pub fn loss_rate(&self) -> Option<f64> {
        self.active_views(|i| self.link(i).loss_rate()).reduce(f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimates_converge_to_truth() {
        let mut m = NetworkMonitor::new(0.3, 0);
        for _ in 0..100 {
            m.observe_transfer(100_000_000, 1.0); // 1e8 bps
            m.observe_latency(0.2);
            m.observe_compute(0.05);
        }
        assert!((m.bandwidth().unwrap() - 1e8).abs() < 1e3);
        assert!((m.latency().unwrap() - 0.2).abs() < 1e-9);
        assert!((m.compute_time().unwrap() - 0.05).abs() < 1e-9);
    }

    #[test]
    fn tracks_bandwidth_shift() {
        let mut m = NetworkMonitor::new(0.5, 0);
        for _ in 0..20 {
            m.observe_bandwidth(1e8);
        }
        for _ in 0..20 {
            m.observe_bandwidth(2e7);
        }
        let est = m.bandwidth().unwrap();
        assert!((est - 2e7).abs() / 2e7 < 0.01, "est={est}");
    }

    #[test]
    fn noise_does_not_bias_much() {
        let mut m = NetworkMonitor::new(0.05, 9).with_noise(0.2);
        for _ in 0..2000 {
            m.observe_bandwidth(1e8);
        }
        let est = m.bandwidth().unwrap();
        assert!((est - 1e8).abs() / 1e8 < 0.15, "est={est}");
    }

    #[test]
    fn noise_stream_follows_seed() {
        // same observations, different seeds => different noisy estimates
        let run = |seed: u64| {
            let mut m = NetworkMonitor::new(0.3, seed).with_noise(0.3);
            for _ in 0..10 {
                m.observe_bandwidth(1e8);
            }
            m.bandwidth().unwrap()
        };
        assert_ne!(run(1), run(2));
        assert_eq!(run(7), run(7), "same seed must replay exactly");
    }

    #[test]
    fn ignores_degenerate_observations() {
        let mut m = NetworkMonitor::new(0.3, 0);
        m.observe_transfer(0, 1.0);
        m.observe_transfer(100, 0.0);
        assert!(m.bandwidth().is_none());
    }

    #[test]
    fn fabric_monitor_bottleneck_and_mean() {
        let mut fm = FabricMonitor::new(3, 0.5, 0);
        assert_eq!(fm.links(), 3);
        assert!(fm.bandwidth().is_none() && fm.latency().is_none());
        for _ in 0..30 {
            fm.observe_transfer(0, 10_000_000, 1.0); // 1e7 bps straggler
            fm.observe_transfer(1, 100_000_000, 1.0); // 1e8
            fm.observe_transfer(2, 100_000_000, 1.0); // 1e8
            fm.observe_latency_for(0, 0.6);
            fm.observe_latency_for(1, 0.1);
            fm.observe_latency_for(2, 0.1);
            fm.observe_compute(0.2);
        }
        let a = fm.bandwidth().unwrap();
        let b = fm.latency().unwrap();
        assert!((a - 1e7).abs() < 1.0, "bottleneck bw {a}");
        assert!((b - 0.6).abs() < 1e-9, "bottleneck lat {b}");
        let am = fm.mean_bandwidth().unwrap();
        let bm = fm.mean_latency().unwrap();
        assert!((am - 7e7).abs() < 1.0, "mean bw {am}");
        assert!((bm - 0.8 / 3.0).abs() < 1e-9, "mean lat {bm}");
        assert!((fm.compute_time().unwrap() - 0.2).abs() < 1e-9);
    }

    #[test]
    fn departed_worker_leaves_the_aggregates_and_rejoins_warm() {
        let mut fm = FabricMonitor::new(3, 0.5, 0);
        for _ in 0..30 {
            fm.observe_transfer(0, 10_000_000, 1.0); // 1e7 bps straggler
            fm.observe_transfer(1, 100_000_000, 1.0);
            fm.observe_transfer(2, 100_000_000, 1.0);
            fm.observe_latency_for(0, 0.6);
            fm.observe_latency_for(1, 0.1);
            fm.observe_latency_for(2, 0.1);
        }
        assert!((fm.bandwidth().unwrap() - 1e7).abs() < 1.0);
        // the straggler departs: bottleneck snaps to the healthy links
        fm.set_active(0, false);
        assert_eq!(fm.active_links(), 2);
        assert!((fm.bandwidth().unwrap() - 1e8).abs() < 1.0);
        assert!((fm.latency().unwrap() - 0.1).abs() < 1e-9);
        assert!((fm.mean_bandwidth().unwrap() - 1e8).abs() < 1.0);
        // rejoin: the warm estimator folds straight back in, no re-warmup
        fm.set_active(0, true);
        assert!((fm.bandwidth().unwrap() - 1e7).abs() < 1.0);
        assert!((fm.latency().unwrap() - 0.6).abs() < 1e-9);
    }

    #[test]
    fn fabric_monitor_homogeneous_matches_single() {
        // identical links => aggregates bit-identical to one NetworkMonitor
        let mut single = NetworkMonitor::new(0.3, 0);
        let mut fm = FabricMonitor::new(4, 0.3, 0);
        for k in 0..50u64 {
            let bits = 1_000_000 + k * 31_337;
            let secs = 0.01 + (k as f64) * 1e-4;
            single.observe_transfer(bits, secs);
            single.observe_latency(0.2);
            single.observe_compute(0.05);
            for w in 0..4 {
                fm.observe_transfer(w, bits, secs);
                fm.observe_latency_for(w, 0.2);
            }
            fm.observe_compute(0.05);
        }
        assert_eq!(
            fm.bandwidth().unwrap().to_bits(),
            single.bandwidth().unwrap().to_bits()
        );
        assert_eq!(
            fm.latency().unwrap().to_bits(),
            single.latency().unwrap().to_bits()
        );
        assert_eq!(
            fm.compute_time().unwrap().to_bits(),
            single.compute_time().unwrap().to_bits()
        );
    }

    #[test]
    fn path_zero_seed_matches_the_historical_per_link_stream() {
        // a 2-path monitor's path 0 must carry the exact noise stream the
        // single-path monitor had, so legacy estimates replay bitwise
        let mut legacy = FabricMonitor::new(2, 0.3, 42).with_noise(0.25);
        let mut bonded =
            FabricMonitor::with_paths(&[2, 1], 0.3, 42).with_noise(0.25);
        for _ in 0..20 {
            legacy.observe_transfer(0, 5_000_000, 0.5);
            bonded.observe_transfer(0, 5_000_000, 0.5);
        }
        assert_eq!(
            legacy.link(0).bandwidth().unwrap().to_bits(),
            bonded.link(0).bandwidth().unwrap().to_bits()
        );
    }

    #[test]
    fn bonded_worker_sums_bandwidth_and_weights_latency() {
        let mut fm = FabricMonitor::with_paths(&[2, 1], 0.5, 0);
        for _ in 0..30 {
            fm.observe_path_transfer(0, 0, 100_000_000.0, 1.0); // 1e8
            fm.observe_path_transfer(0, 1, 20_000_000.0, 1.0); // 2e7
            fm.observe_path_latency(0, 0, 0.05);
            fm.observe_path_latency(0, 1, 0.3);
            fm.observe_transfer(1, 100_000_000, 1.0);
            fm.observe_latency_for(1, 0.1);
        }
        let w0 = fm.worker_bandwidth(0).unwrap();
        assert!((w0 - 1.2e8).abs() < 1.0, "sum over paths, got {w0}");
        // bandwidth-weighted across paths: (1e8·0.05 + 2e7·0.3) / 1.2e8 —
        // most bits ride the fat path, so its latency dominates
        assert!((fm.worker_latency(0).unwrap() - 11e6 / 1.2e8).abs() < 1e-12);
        // bottleneck over workers: worker 1's 1e8 < worker 0's 1.2e8
        assert!((fm.bandwidth().unwrap() - 1e8).abs() < 1.0);
        assert!((fm.latency().unwrap() - 0.1).abs() < 1e-12);
        // one path collapsing drags the bonded aggregate below worker 1
        for _ in 0..60 {
            fm.observe_path_transfer(0, 0, 1_000.0, 1.0); // outage floor
        }
        assert!(fm.worker_bandwidth(0).unwrap() < 3e7);
        assert!(fm.bandwidth().unwrap() < 3e7);
    }

    #[test]
    fn class_observation_matches_per_worker_bitwise() {
        // two timeline classes, then a split: the O(classes) observation
        // path must leave every estimator bitwise identical to the
        // per-worker stream
        let n = 6;
        let mut per = FabricMonitor::new(n, 0.3, 7);
        let mut cls = FabricMonitor::new(n, 0.3, 7);
        let observe = |per: &mut FabricMonitor,
                       cls: &mut FabricMonitor,
                       members: &[u32],
                       k: u64,
                       c: u64| {
            let bits = 1_000_000 + k * 10_007 + c * 331;
            let secs = 0.01 + k as f64 * 1e-4 + c as f64 * 1e-3;
            let lat = 0.1 + c as f64 * 0.05;
            for &w in members {
                per.observe_transfer(w as usize, bits, secs);
                per.observe_latency_for(w as usize, lat);
            }
            cls.observe_class_transfer(members, bits, secs);
            cls.observe_class_latency(members, lat);
        };
        for k in 0..20u64 {
            observe(&mut per, &mut cls, &[0, 2, 4], k, 0);
            observe(&mut per, &mut cls, &[1, 3, 5], k, 1);
        }
        // class {0, 2, 4} splits — {0, 4} and {2} diverge from here on
        for k in 20..40u64 {
            observe(&mut per, &mut cls, &[0, 4], k, 0);
            observe(&mut per, &mut cls, &[2], k, 2);
            observe(&mut per, &mut cls, &[1, 3, 5], k, 1);
        }
        for w in 0..n {
            assert_eq!(
                per.link(w).bandwidth().unwrap().to_bits(),
                cls.link(w).bandwidth().unwrap().to_bits(),
                "worker {w} bandwidth"
            );
            assert_eq!(
                per.link(w).latency().unwrap().to_bits(),
                cls.link(w).latency().unwrap().to_bits(),
                "worker {w} latency"
            );
        }
        assert_eq!(
            per.bandwidth().unwrap().to_bits(),
            cls.bandwidth().unwrap().to_bits()
        );
        assert_eq!(
            per.latency().unwrap().to_bits(),
            cls.latency().unwrap().to_bits()
        );
        assert_eq!(
            per.mean_bandwidth().unwrap().to_bits(),
            cls.mean_bandwidth().unwrap().to_bits()
        );
    }

    #[test]
    fn class_observation_matches_per_worker_at_1024() {
        // the scale point the sweeps care about: one class of 1024, split
        // into halves mid-stream, still bitwise against per-worker
        let n = 1024usize;
        let all: Vec<u32> = (0..n as u32).collect();
        let (lo, hi) = all.split_at(n / 2);
        let mut per = FabricMonitor::new(n, 0.3, 3);
        let mut cls = FabricMonitor::new(n, 0.3, 3);
        for k in 0..10u64 {
            let bits = 2_000_000 + k * 77_003;
            let secs = 0.02 + k as f64 * 1e-4;
            for w in 0..n {
                per.observe_transfer(w, bits, secs);
                per.observe_latency_for(w, 0.2);
            }
            cls.observe_class_transfer(&all, bits, secs);
            cls.observe_class_latency(&all, 0.2);
        }
        for k in 0..10u64 {
            let bits = 3_000_000 + k * 13_007;
            let secs = 0.03 + k as f64 * 2e-4;
            for (part, shift) in [(lo, 0.0), (hi, 0.1)] {
                for &w in part {
                    per.observe_transfer(w as usize, bits, secs + shift);
                    per.observe_latency_for(w as usize, 0.2 + shift);
                }
                cls.observe_class_transfer(part, bits, secs + shift);
                cls.observe_class_latency(part, 0.2 + shift);
            }
        }
        for w in 0..n {
            assert_eq!(
                per.link(w).bandwidth().unwrap().to_bits(),
                cls.link(w).bandwidth().unwrap().to_bits()
            );
            assert_eq!(
                per.link(w).latency().unwrap().to_bits(),
                cls.link(w).latency().unwrap().to_bits()
            );
        }
        assert_eq!(
            per.bandwidth().unwrap().to_bits(),
            cls.bandwidth().unwrap().to_bits()
        );
    }

    #[test]
    fn noisy_class_observation_preserves_per_worker_streams() {
        // with measurement noise the class path must fall back to
        // per-member updates so every worker keeps its own RNG stream
        let mut per = FabricMonitor::new(2, 0.3, 5).with_noise(0.2);
        let mut cls = FabricMonitor::new(2, 0.3, 5).with_noise(0.2);
        assert!(!cls.noiseless());
        for _ in 0..10 {
            per.observe_transfer(0, 5_000_000, 0.5);
            per.observe_transfer(1, 5_000_000, 0.5);
            cls.observe_class_transfer(&[0, 1], 5_000_000, 0.5);
        }
        for w in 0..2 {
            assert_eq!(
                per.link(w).bandwidth().unwrap().to_bits(),
                cls.link(w).bandwidth().unwrap().to_bits()
            );
        }
        // different seeds really do produce different per-worker values
        assert_ne!(
            cls.link(0).bandwidth().unwrap().to_bits(),
            cls.link(1).bandwidth().unwrap().to_bits()
        );
    }

    #[test]
    fn pessimistic_views_match_optimistic_on_single_path() {
        // no bonds anywhere: the pessimistic band is degenerate and
        // bitwise equal to the optimistic aggregates
        let mut fm = FabricMonitor::new(3, 0.5, 0);
        for _ in 0..20 {
            fm.observe_transfer(0, 10_000_000, 1.0);
            fm.observe_transfer(1, 100_000_000, 1.0);
            fm.observe_transfer(2, 100_000_000, 1.0);
            fm.observe_latency_for(0, 0.6);
            fm.observe_latency_for(1, 0.1);
            fm.observe_latency_for(2, 0.1);
        }
        assert_eq!(
            fm.bandwidth().unwrap().to_bits(),
            fm.bandwidth_pessimistic().unwrap().to_bits()
        );
        assert_eq!(
            fm.latency().unwrap().to_bits(),
            fm.latency_pessimistic().unwrap().to_bits()
        );
        for w in 0..3 {
            assert_eq!(
                fm.worker_bandwidth(w).unwrap().to_bits(),
                fm.worker_bandwidth_pessimistic(w).unwrap().to_bits()
            );
        }
    }

    #[test]
    fn bonded_pessimistic_band_brackets_the_optimistic_view() {
        let mut fm = FabricMonitor::with_paths(&[2, 1], 0.5, 0);
        for _ in 0..30 {
            fm.observe_path_transfer(0, 0, 100_000_000.0, 1.0); // 1e8
            fm.observe_path_transfer(0, 1, 20_000_000.0, 1.0); // 2e7
            fm.observe_path_latency(0, 0, 0.05);
            fm.observe_path_latency(0, 1, 0.3);
            fm.observe_transfer(1, 50_000_000, 1.0);
            fm.observe_latency_for(1, 0.1);
        }
        // worker 0's band: [min path, Σ paths] for bandwidth, and
        // latency's pessimistic max above the weighted mean
        let bw_opt = fm.worker_bandwidth(0).unwrap();
        let bw_pess = fm.worker_bandwidth_pessimistic(0).unwrap();
        assert!((bw_pess - 2e7).abs() < 1.0, "min path, got {bw_pess}");
        assert!(bw_pess < bw_opt);
        let lat_pess = fm.worker_latency_pessimistic(0).unwrap();
        assert!((lat_pess - 0.3).abs() < 1e-12, "max path, got {lat_pess}");
        assert!(lat_pess > fm.worker_latency(0).unwrap());
        // aggregates: optimistic bottleneck is worker 1 (5e7 < 1.2e8) but
        // the pessimistic bottleneck is worker 0's thin path (2e7)
        assert!((fm.bandwidth().unwrap() - 5e7).abs() < 1.0);
        assert!((fm.bandwidth_pessimistic().unwrap() - 2e7).abs() < 1.0);
        assert!((fm.latency_pessimistic().unwrap() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn slot_estimates_snapshot_is_deduplicated_and_ordered() {
        let n = 4;
        let mut fm = FabricMonitor::new(n, 0.3, 0);
        // workers {0,2} share one observation history via the class path,
        // {1,3} another; both collapse to one slot each
        for k in 0..10u64 {
            let bits = 1_000_000 + k * 331;
            fm.observe_class_transfer(&[0, 2], bits, 0.01);
            fm.observe_class_latency(&[0, 2], 0.1);
            fm.observe_class_transfer(&[1, 3], bits * 2, 0.01);
            fm.observe_class_latency(&[1, 3], 0.2);
        }
        let snap = fm.slot_estimates();
        assert_eq!(snap.len(), 2, "one entry per shared slot");
        assert_eq!((snap[0].worker, snap[0].members), (0, 2));
        assert_eq!((snap[1].worker, snap[1].members), (1, 2));
        assert!(snap[0].bw < snap[1].bw);
        // degenerate band on single-path workers
        assert_eq!(snap[0].bw.to_bits(), snap[0].bw_pess.to_bits());
        assert_eq!(snap[0].lat.to_bits(), snap[0].lat_pess.to_bits());
        // deactivating one member shrinks the count; a whole slot out
        // drops the entry
        fm.set_active(2, false);
        let snap = fm.slot_estimates();
        assert_eq!(snap.len(), 2);
        assert_eq!((snap[0].worker, snap[0].members), (0, 1));
        fm.set_active(0, false);
        let snap = fm.slot_estimates();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].worker, 1);
    }

    #[test]
    fn attempt_samples_invert_to_a_loss_rate() {
        let mut m = NetworkMonitor::new(0.3, 0);
        assert!(m.loss_rate().is_none());
        // mean attempts 2.0 under i.i.d. loss p = 0.5
        for _ in 0..200 {
            m.observe_attempts(2.0);
        }
        let p = m.loss_rate().unwrap();
        assert!((p - 0.5).abs() < 1e-9, "p = {p}");
        // clean link: attempts 1.0 -> p = 0 exactly
        let mut clean = NetworkMonitor::new(0.3, 0);
        clean.observe_attempts(1.0);
        assert_eq!(clean.loss_rate().unwrap(), 0.0);
        // degenerate samples ignored
        clean.observe_attempts(0.0);
        assert_eq!(clean.loss_rate().unwrap(), 0.0);
    }

    #[test]
    fn fabric_loss_rate_is_the_worst_active_worker() {
        let mut fm = FabricMonitor::new(3, 0.5, 0);
        assert!(fm.loss_rate().is_none());
        for _ in 0..100 {
            fm.observe_attempts(0, 1.0); // clean
            fm.observe_attempts(1, 4.0); // p = 0.75
        }
        let p = fm.loss_rate().unwrap();
        assert!((p - 0.75).abs() < 1e-6, "p = {p}");
        // the lossy worker departs: aggregate snaps to the clean links
        fm.set_active(1, false);
        assert_eq!(fm.loss_rate().unwrap(), 0.0);
    }

    #[test]
    fn partial_path_estimates_still_aggregate() {
        // only one path of a bond has samples: the worker view uses what
        // it has instead of reporting nothing
        let mut fm = FabricMonitor::with_paths(&[2], 0.5, 0);
        assert!(fm.worker_bandwidth(0).is_none());
        fm.observe_path_transfer(0, 1, 20_000_000.0, 1.0);
        assert!((fm.worker_bandwidth(0).unwrap() - 2e7).abs() < 1.0);
        fm.observe_path_latency(0, 1, 0.3);
        assert!((fm.worker_latency(0).unwrap() - 0.3).abs() < 1e-12);
    }
}
