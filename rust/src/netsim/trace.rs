//! Bandwidth traces `a(t)` in bits/s.
//!
//! The paper's experiments run under "dynamic low bandwidth, average
//! bandwidth <= 1 Gbps" (Sec. C.2, Fig. 6). We provide:
//! * `Constant` — the Table-1 grid points;
//! * `Sine` — smooth periodic variation (Fig. 6's visual shape);
//! * `Ou` — mean-reverting Ornstein-Uhlenbeck, the standard stochastic
//!   model for measured WAN throughput;
//! * `Markov` — regime-switching (congestion episodes), heavier tails;
//! * `File`-style piecewise-linear samples for replaying external traces.
//!
//! All traces are deterministic functions of (seed, t) — OU and Markov
//! pre-generate samples on a fixed grid and interpolate, so `at()` is pure
//! and the event simulator can integrate over them reproducibly.

use crate::util::Rng;


/// One degrade/outage window on a link: bandwidth is multiplied by `frac`
/// on `[start_s, end_s)`. `frac = 0` models a full outage — the trace floor
/// keeps the link barely alive, so an in-flight transfer stalls for the
/// window instead of dividing by zero, and completes once the window ends
/// (DESIGN.md §Elasticity).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DegradeWindow {
    pub start_s: f64,
    pub end_s: f64,
    pub frac: f64,
}

impl DegradeWindow {
    pub fn contains(&self, t: f64) -> bool {
        t >= self.start_s && t < self.end_s
    }
}

/// Trace configuration (serde-friendly, lives in experiment TOML).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceKind {
    Constant { bps: f64 },
    Sine { mean_bps: f64, amp_bps: f64, period_s: f64 },
    Ou { mean_bps: f64, sigma_bps: f64, theta: f64, seed: u64 },
    Markov { levels_bps: Vec<f64>, dwell_s: f64, seed: u64 },
    Samples { times_s: Vec<f64>, bps: Vec<f64> },
    /// Lazy multiplicative scaling: `at(t) = frac · inner.at(t)`. Keeps the
    /// inner trace's full resolution and horizon (no resampling grid) —
    /// this is how straggler fabrics derive a slow link from the base
    /// trace without flattening sub-grid dynamics.
    Scaled { inner: Box<TraceKind>, frac: f64 },
    /// Lazy time-windowed degradation: `at(t) = inner.at(t) · Π frac` over
    /// the windows containing `t`, full resolution like [`Self::Scaled`].
    /// This is how churn schedules bake link outages/degrades into the
    /// fabric (elastic subsystem, DESIGN.md §Elasticity).
    Windowed { inner: Box<TraceKind>, windows: Vec<DegradeWindow> },
}

/// A realized bandwidth trace.
#[derive(Clone, Debug)]
pub struct BandwidthTrace {
    kind: TraceKind,
    /// `kind` with all `Scaled`/`Windowed` wrappers peeled off — populated
    /// only when `kind` actually carries a wrapper, so plain traces don't
    /// duplicate their payload vectors
    base: Option<TraceKind>,
    /// product of the peeled `Scaled` fractions (1.0 for unwrapped kinds)
    scale: f64,
    /// all peeled `Windowed` windows (empty for unwrapped kinds)
    windows: Vec<DegradeWindow>,
    /// pre-generated grid for stochastic kinds: (dt, samples)
    grid: Option<(f64, Vec<f64>)>,
    floor: f64,
}

/// Grid resolution for stochastic traces (s).
const GRID_DT: f64 = 0.05;
/// Pre-generated horizon (s); beyond it the trace wraps around, keeping
/// long experiments stationary without unbounded memory.
const GRID_HORIZON: f64 = 4096.0;

impl BandwidthTrace {
    pub fn new(kind: TraceKind) -> Self {
        let (base, scale, windows) = match &kind {
            TraceKind::Scaled { .. } | TraceKind::Windowed { .. } => {
                let (b, s, w) = Self::flatten(&kind);
                (Some(b), s, w)
            }
            _ => (None, 1.0, Vec::new()),
        };
        let grid = match base.as_ref().unwrap_or(&kind) {
            TraceKind::Ou { mean_bps, sigma_bps, theta, seed } => {
                Some((GRID_DT, Self::gen_ou(*mean_bps, *sigma_bps, *theta, *seed)))
            }
            TraceKind::Markov { levels_bps, dwell_s, seed } => {
                Some((GRID_DT, Self::gen_markov(levels_bps, *dwell_s, *seed)))
            }
            _ => None,
        };
        // never allow a dead link: floor at 1 kbps
        Self { kind, base, scale, windows, grid, floor: 1e3 }
    }

    /// Peel nested `Scaled`/`Windowed` wrappers into
    /// (base kind, accumulated factor, accumulated windows).
    fn flatten(kind: &TraceKind) -> (TraceKind, f64, Vec<DegradeWindow>) {
        match kind {
            TraceKind::Scaled { inner, frac } => {
                let (base, f, w) = Self::flatten(inner);
                (base, f * frac, w)
            }
            TraceKind::Windowed { inner, windows } => {
                let (base, f, mut w) = Self::flatten(inner);
                w.extend(windows.iter().copied());
                (base, f, w)
            }
            other => (other.clone(), 1.0, Vec::new()),
        }
    }

    pub fn constant(bps: f64) -> Self {
        Self::new(TraceKind::Constant { bps })
    }

    /// This trace scaled by `frac`, lazily: full resolution, no resampling.
    pub fn scaled(&self, frac: f64) -> Self {
        Self::new(TraceKind::Scaled {
            inner: Box::new(self.kind.clone()),
            frac,
        })
    }

    /// This trace with degrade/outage `windows` applied, lazily: full
    /// resolution, no resampling. Empty windows return the trace unchanged.
    pub fn windowed(&self, windows: Vec<DegradeWindow>) -> Self {
        if windows.is_empty() {
            return self.clone();
        }
        Self::new(TraceKind::Windowed {
            inner: Box::new(self.kind.clone()),
            windows,
        })
    }

    /// The degrade/outage windows carried by this trace (empty unless a
    /// churn schedule baked some in).
    pub fn windows(&self) -> &[DegradeWindow] {
        &self.windows
    }

    pub fn kind(&self) -> &TraceKind {
        &self.kind
    }

    /// The evaluated kind: `kind` with any `Scaled` wrappers peeled off.
    fn base(&self) -> &TraceKind {
        self.base.as_ref().unwrap_or(&self.kind)
    }

    /// `Some(effective bps)` when the trace is constant in time (possibly
    /// through `Scaled` wrappers) — the closed-form transfer fast path.
    /// Windowed traces are never constant: the windows vary in time.
    pub fn as_constant(&self) -> Option<f64> {
        if self.windows.is_empty() {
            self.constant_base()
        } else {
            None
        }
    }

    /// `Some(healthy bps)` when the trace is constant *outside* its fault
    /// windows (constant base through `Scaled`/`Windowed` wrappers). A
    /// transfer whose interval touches no window still solves in closed
    /// form at this rate — the fast path that keeps churn runs from
    /// integrating every healthy-period transfer
    /// ([`super::Link::transfer_end`]).
    pub fn constant_base(&self) -> Option<f64> {
        if let TraceKind::Constant { bps } = self.base() {
            Some((bps * self.scale).max(self.floor))
        } else {
            None
        }
    }

    fn gen_ou(mean: f64, sigma: f64, theta: f64, seed: u64) -> Vec<f64> {
        let n = (GRID_HORIZON / GRID_DT) as usize;
        let mut rng = Rng::new(seed);
        let mut x = mean;
        let mut out = Vec::with_capacity(n);
        let sq = sigma * (2.0 * theta * GRID_DT).sqrt();
        for _ in 0..n {
            out.push(x);
            x += theta * (mean - x) * GRID_DT + sq * rng.normal();
            x = x.max(0.02 * mean); // reflect at 2% of mean
        }
        out
    }

    fn gen_markov(levels: &[f64], dwell_s: f64, seed: u64) -> Vec<f64> {
        assert!(!levels.is_empty());
        let n = (GRID_HORIZON / GRID_DT) as usize;
        let mut rng = Rng::new(seed);
        let mut state = rng.below(levels.len());
        let p_switch = (GRID_DT / dwell_s).min(1.0);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(levels[state]);
            if rng.next_f64() < p_switch {
                state = rng.below(levels.len());
            }
        }
        out
    }

    /// Bandwidth at absolute time `t` (bits/s). Pure function.
    pub fn at(&self, t: f64) -> f64 {
        let v = match self.base() {
            TraceKind::Constant { bps } => *bps,
            TraceKind::Sine { mean_bps, amp_bps, period_s } => {
                mean_bps + amp_bps * (std::f64::consts::TAU * t / period_s).sin()
            }
            TraceKind::Samples { times_s, bps } => {
                Self::interp(times_s, bps, t)
            }
            _ => {
                let (dt, samples) = self.grid.as_ref().unwrap();
                let i = ((t / dt) as usize) % samples.len();
                samples[i]
            }
        };
        let mut v = v * self.scale;
        for w in &self.windows {
            if w.contains(t) {
                v *= w.frac;
            }
        }
        v.max(self.floor)
    }

    fn interp(ts: &[f64], vs: &[f64], t: f64) -> f64 {
        if ts.is_empty() {
            return 0.0;
        }
        if t <= ts[0] {
            return vs[0];
        }
        if t >= *ts.last().unwrap() {
            return *vs.last().unwrap();
        }
        let i = ts.partition_point(|&x| x <= t) - 1;
        let w = (t - ts[i]) / (ts[i + 1] - ts[i]);
        vs[i] * (1.0 - w) + vs[i + 1] * w
    }

    /// Mean bandwidth over [t0, t1] (trapezoid on a fine grid).
    pub fn mean_over(&self, t0: f64, t1: f64) -> f64 {
        let n = 200;
        let dt = (t1 - t0) / n as f64;
        let sum: f64 = (0..=n).map(|i| self.at(t0 + i as f64 * dt)).sum();
        sum / (n + 1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_everywhere() {
        let t = BandwidthTrace::constant(1e8);
        assert_eq!(t.at(0.0), 1e8);
        assert_eq!(t.at(1e6), 1e8);
    }

    #[test]
    fn sine_bounds_and_mean() {
        let t = BandwidthTrace::new(TraceKind::Sine {
            mean_bps: 1e8,
            amp_bps: 5e7,
            period_s: 10.0,
        });
        for i in 0..1000 {
            let v = t.at(i as f64 * 0.037);
            assert!((5e7 - 1.0..=1.5e8 + 1.0).contains(&v));
        }
        let m = t.mean_over(0.0, 10.0);
        assert!((m - 1e8).abs() < 2e6, "mean={m}");
    }

    #[test]
    fn ou_stationary_stats() {
        let t = BandwidthTrace::new(TraceKind::Ou {
            mean_bps: 1e8,
            sigma_bps: 2e7,
            theta: 0.5,
            seed: 5,
        });
        let m = t.mean_over(0.0, 2000.0);
        assert!((m - 1e8).abs() < 1e7, "mean={m}");
        // never below floor, never absurd
        for i in 0..10_000 {
            let v = t.at(i as f64 * 0.21);
            assert!(v > 0.0 && v < 1e9);
        }
    }

    #[test]
    fn markov_visits_levels() {
        let levels = vec![5e7, 1e8, 2e8];
        let t = BandwidthTrace::new(TraceKind::Markov {
            levels_bps: levels.clone(),
            dwell_s: 1.0,
            seed: 6,
        });
        let mut seen = [false; 3];
        for i in 0..20_000 {
            let v = t.at(i as f64 * 0.05);
            for (j, &l) in levels.iter().enumerate() {
                if (v - l).abs() < 1.0 {
                    seen[j] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "levels visited: {seen:?}");
    }

    #[test]
    fn samples_interpolate() {
        let t = BandwidthTrace::new(TraceKind::Samples {
            times_s: vec![0.0, 10.0],
            bps: vec![1e8, 2e8],
        });
        assert_eq!(t.at(-1.0), 1e8);
        assert!((t.at(5.0) - 1.5e8).abs() < 1.0);
        assert_eq!(t.at(11.0), 2e8);
    }

    #[test]
    fn scaled_preserves_full_resolution() {
        // a fast sine (period 0.2 s) scaled by 0.25: every sample is exactly
        // frac × the inner value — no 0.5 s resampling grid, no horizon cap
        let inner = BandwidthTrace::new(TraceKind::Sine {
            mean_bps: 1e8,
            amp_bps: 5e7,
            period_s: 0.2,
        });
        let scaled = inner.scaled(0.25);
        for i in 0..500 {
            // probe sub-grid offsets and times far past the old 1024 s wrap
            let t = i as f64 * 0.013 + if i % 2 == 0 { 0.0 } else { 2000.0 };
            let want = (inner.at(t) * 0.25).max(1e3);
            assert_eq!(scaled.at(t), want, "t={t}");
        }
    }

    #[test]
    fn scaled_nests_multiplicatively() {
        let t = BandwidthTrace::constant(1e8).scaled(0.5).scaled(0.5);
        assert_eq!(t.at(3.0), 0.25 * 1e8);
        assert_eq!(t.as_constant(), Some(0.25 * 1e8));
    }

    #[test]
    fn scaled_stochastic_shares_inner_stream() {
        // scaling an OU trace must not change the realized sample path —
        // only its amplitude (the old resampling grid broke this)
        let kind = TraceKind::Ou {
            mean_bps: 1e8,
            sigma_bps: 2e7,
            theta: 0.4,
            seed: 12,
        };
        let inner = BandwidthTrace::new(kind.clone());
        let scaled = BandwidthTrace::new(TraceKind::Scaled {
            inner: Box::new(kind),
            frac: 0.1,
        });
        for i in 0..1000 {
            let t = i as f64 * 0.037;
            let want = (inner.at(t) * 0.1).max(1e3);
            assert_eq!(scaled.at(t), want);
        }
    }

    #[test]
    fn unscaled_constant_fast_path() {
        let t = BandwidthTrace::constant(2e8);
        assert_eq!(t.as_constant(), Some(2e8));
        let s = BandwidthTrace::new(TraceKind::Sine {
            mean_bps: 1e8,
            amp_bps: 1e7,
            period_s: 3.0,
        });
        assert_eq!(s.as_constant(), None);
        assert_eq!(s.scaled(0.5).as_constant(), None);
    }

    #[test]
    fn windowed_degrades_inside_window_only() {
        let t = BandwidthTrace::constant(1e8).windowed(vec![
            DegradeWindow { start_s: 10.0, end_s: 20.0, frac: 0.5 },
            DegradeWindow { start_s: 30.0, end_s: 40.0, frac: 0.0 },
        ]);
        assert_eq!(t.at(5.0), 1e8);
        assert_eq!(t.at(10.0), 5e7); // window start is inclusive
        assert_eq!(t.at(19.99), 5e7);
        assert_eq!(t.at(20.0), 1e8); // window end is exclusive
        // full outage: clamped to the 1 kbps floor, never zero
        assert_eq!(t.at(35.0), 1e3);
        assert_eq!(t.at(45.0), 1e8);
        // windowed traces lose the constant fast path
        assert_eq!(t.as_constant(), None);
        assert_eq!(t.windows().len(), 2);
    }

    #[test]
    fn windowed_composes_with_scaled() {
        // scale and windows commute: both are lazy multiplicative wrappers
        let inner = BandwidthTrace::new(TraceKind::Sine {
            mean_bps: 1e8,
            amp_bps: 4e7,
            period_s: 0.3,
        });
        let wrapped = inner
            .scaled(0.5)
            .windowed(vec![DegradeWindow { start_s: 2.0, end_s: 4.0, frac: 0.25 }]);
        for i in 0..300 {
            let t = i as f64 * 0.021;
            let base = inner.at(t) * 0.5;
            let want = if (2.0..4.0).contains(&t) { base * 0.25 } else { base };
            assert_eq!(wrapped.at(t), want.max(1e3), "t={t}");
        }
    }

    #[test]
    fn empty_windows_are_identity() {
        let t = BandwidthTrace::constant(2e8);
        let w = t.windowed(Vec::new());
        assert_eq!(w.as_constant(), Some(2e8));
        assert_eq!(w.kind(), t.kind());
    }

    #[test]
    fn deterministic_across_instances() {
        let k = TraceKind::Ou { mean_bps: 1e8, sigma_bps: 1e7, theta: 0.3, seed: 77 };
        let a = BandwidthTrace::new(k.clone());
        let b = BandwidthTrace::new(k);
        for i in 0..100 {
            assert_eq!(a.at(i as f64 * 1.3), b.at(i as f64 * 1.3));
        }
    }
}
