//! Bandwidth traces `a(t)` in bits/s, plus the exact prefix-integral
//! transfer engine built on them.
//!
//! The paper's experiments run under "dynamic low bandwidth, average
//! bandwidth <= 1 Gbps" (Sec. C.2, Fig. 6). We provide:
//! * `Constant` — the Table-1 grid points;
//! * `Sine` — smooth periodic variation (Fig. 6's visual shape);
//! * `Ou` — mean-reverting Ornstein-Uhlenbeck, the standard stochastic
//!   model for measured WAN throughput;
//! * `Markov` — regime-switching (congestion episodes), heavier tails;
//! * `File`-style piecewise-linear samples for replaying external traces.
//!
//! All traces are deterministic functions of (seed, t) — OU and Markov
//! pre-generate samples on a fixed grid and interpolate, so `at()` is pure
//! and the event simulator can integrate over them reproducibly.
//!
//! **Prefix-integral engine (DESIGN.md §Perf).** Every trace also exposes
//! its exact cumulative-bits integral `B(t) = ∫₀ᵗ at(s) ds` and its
//! inverse: [`BandwidthTrace::bits_over`] is a prefix *difference* and
//! [`BandwidthTrace::end_of_transfer`] solves `B(end) − B(start) = bits`
//! in closed form per piece — the fluid-flow trick that replaces the old
//! 10 ms forward-Euler stepping of `Link::transfer_end`. The effective
//! rate `max(m · base(t), floor)` is piecewise in `t`: the multiplier
//! `m = scale · Π window fracs` is constant between window boundaries
//! (the private `CumTrace` segment spine), and within a segment the base
//! kind is closed-form (constant, sine, piecewise-linear samples) or
//! piecewise-constant on the pre-generated grid, where prefix sums give
//! O(log n) lookups and inversions. The stochastic grid wraps
//! periodically past `GRID_HORIZON` exactly as `at()` does (cell index
//! mod n), so the prefix extends periodically and a transfer straddling
//! the wrap prices precisely the bits `at()` reports.

use crate::util::Rng;
use std::sync::Arc;

/// One degrade/outage window on a link: bandwidth is multiplied by `frac`
/// on `[start_s, end_s)`. `frac = 0` models a full outage — the trace floor
/// keeps the link barely alive, so an in-flight transfer stalls for the
/// window instead of dividing by zero, and completes once the window ends
/// (DESIGN.md §Elasticity).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DegradeWindow {
    pub start_s: f64,
    pub end_s: f64,
    pub frac: f64,
}

impl DegradeWindow {
    pub fn contains(&self, t: f64) -> bool {
        t >= self.start_s && t < self.end_s
    }
}

/// Trace configuration (serde-friendly, lives in experiment TOML).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceKind {
    Constant { bps: f64 },
    Sine { mean_bps: f64, amp_bps: f64, period_s: f64 },
    Ou { mean_bps: f64, sigma_bps: f64, theta: f64, seed: u64 },
    Markov { levels_bps: Vec<f64>, dwell_s: f64, seed: u64 },
    Samples { times_s: Vec<f64>, bps: Vec<f64> },
    /// Lazy multiplicative scaling: `at(t) = frac · inner.at(t)`. Keeps the
    /// inner trace's full resolution and horizon (no resampling grid) —
    /// this is how straggler fabrics derive a slow link from the base
    /// trace without flattening sub-grid dynamics.
    Scaled { inner: Box<TraceKind>, frac: f64 },
    /// Lazy time-windowed degradation: `at(t) = inner.at(t) · Π frac` over
    /// the windows containing `t`, full resolution like [`Self::Scaled`].
    /// This is how churn schedules bake link outages/degrades into the
    /// fabric (elastic subsystem, DESIGN.md §Elasticity).
    Windowed { inner: Box<TraceKind>, windows: Vec<DegradeWindow> },
}

/// Pre-generated stochastic grid plus its prefix integral. `Arc`-shared
/// across trace clones, so cloning a fabric (one clone per sweep cell)
/// never regenerates or copies an OU/Markov sample path.
#[derive(Debug)]
struct Grid {
    dt: f64,
    samples: Vec<f64>,
    /// `prefix[i] = Σ_{j<i} samples[j] · dt` — base-trace bits over
    /// `[0, i·dt)`; length `samples.len() + 1`
    prefix: Vec<f64>,
    min: f64,
    max: f64,
}

impl Grid {
    fn new(dt: f64, samples: Vec<f64>) -> Self {
        let mut prefix = Vec::with_capacity(samples.len() + 1);
        let mut acc = 0.0;
        prefix.push(0.0);
        for &s in &samples {
            acc += s * dt;
            prefix.push(acc);
        }
        let min = samples.iter().copied().fold(f64::INFINITY, f64::min);
        let max = samples.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        Self { dt, samples, prefix, min, max }
    }

    fn len(&self) -> usize {
        self.samples.len()
    }

    /// Base bits over one full horizon (`len · dt` seconds).
    fn total(&self) -> f64 {
        *self.prefix.last().unwrap()
    }
}

/// Knot prefix integral of a `Samples` base: `cum[i]` is the exact
/// trapezoid integral of the piecewise-linear rate from the first knot to
/// knot `i`. `Arc`-shared across clones like [`Grid`].
#[derive(Debug)]
struct Knots {
    cum: Vec<f64>,
    min: f64,
}

impl Knots {
    fn new(ts: &[f64], vs: &[f64]) -> Self {
        let mut cum = Vec::with_capacity(ts.len());
        cum.push(0.0);
        for i in 1..ts.len() {
            let prev = cum[i - 1];
            cum.push(prev + 0.5 * (vs[i - 1] + vs[i]) * (ts[i] - ts[i - 1]));
        }
        let min = vs.iter().copied().fold(f64::INFINITY, f64::min);
        Self { cum, min }
    }
}

/// The spine of the prefix-integral engine: time-sorted segments on which
/// the effective multiplier `scale · Π window fracs` is constant. The
/// first segment starts at −∞ (multiplier = bare `scale`), each window
/// edge starts a new one, and the last extends to +∞, so cumulative bits
/// over any interval decompose into per-segment closed forms and the
/// windowed fast paths fall out as the trivial single-segment case.
#[derive(Clone, Debug)]
struct CumTrace {
    /// `(segment start, multiplier)`; starts ascending, `segs[0].0 = −∞`
    segs: Vec<(f64, f64)>,
}

impl CumTrace {
    fn build(scale: f64, windows: &[DegradeWindow]) -> Self {
        if windows.is_empty() {
            return Self { segs: vec![(f64::NEG_INFINITY, scale)] };
        }
        let mut edges: Vec<f64> = windows
            .iter()
            .flat_map(|w| [w.start_s, w.end_s])
            .collect();
        edges.sort_by(f64::total_cmp);
        edges.dedup();
        let mut segs = vec![(f64::NEG_INFINITY, scale)];
        for &e in &edges {
            // windows are [start, end): a window either covers a whole
            // segment or none of it, so probing the segment start decides.
            // Fracs multiply onto `scale` in declaration order, the same
            // order `at()` historically applied them.
            let mut m = scale;
            for w in windows {
                if w.contains(e) {
                    m *= w.frac;
                }
            }
            segs.push((e, m));
        }
        Self { segs }
    }

    /// Index of the segment containing `t`.
    fn index(&self, t: f64) -> usize {
        self.segs.partition_point(|s| s.0 <= t) - 1
    }
}

/// `∫ₐᵇ max(m·v(t), floor) dt` for a linear `v` with value `va` at `a` and
/// slope `sl` — the one-crossing closed form shared by the `Samples`
/// pieces. `b` must be finite.
fn clamped_linear(m: f64, floor: f64, a: f64, b: f64, va: f64, sl: f64) -> f64 {
    if b <= a {
        return 0.0;
    }
    let vb = va + sl * (b - a);
    let (ra, rb) = (va * m, vb * m);
    if ra >= floor && rb >= floor {
        return 0.5 * (ra + rb) * (b - a);
    }
    if ra <= floor && rb <= floor {
        return floor * (b - a);
    }
    // exactly one crossing strictly inside (ra, rb straddle the floor)
    let tc = (a + (floor / m - va) / sl).clamp(a, b);
    if ra > floor {
        0.5 * (ra + floor) * (tc - a) + floor * (b - tc)
    } else {
        floor * (tc - a) + 0.5 * (floor + rb) * (b - tc)
    }
}

/// Inverse of [`clamped_linear`]: the time at which `rem` bits complete
/// within `[a, b]`, given the span holds at least `rem`. The quadratic is
/// solved in the cancellation-free form `2·rem / (r + √(r² + 2·s·rem))`,
/// which degrades gracefully to `rem / r` as the slope vanishes.
fn clamped_linear_end(
    m: f64,
    floor: f64,
    a: f64,
    b: f64,
    va: f64,
    sl: f64,
    rem: f64,
) -> f64 {
    if rem <= 0.0 {
        return a;
    }
    let se = sl * m;
    let ramp = |start: f64, r0: f64, need: f64| {
        let disc = (r0 * r0 + 2.0 * se * need).max(0.0).sqrt();
        start + 2.0 * need / (r0 + disc)
    };
    let vb = va + sl * (b - a);
    let (ra, rb) = (va * m, vb * m);
    if ra >= floor && rb >= floor {
        return ramp(a, ra, rem);
    }
    if ra <= floor && rb <= floor {
        return a + rem / floor;
    }
    let tc = (a + (floor / m - va) / sl).clamp(a, b);
    if ra > floor {
        let head = 0.5 * (ra + floor) * (tc - a);
        if rem <= head {
            ramp(a, ra, rem)
        } else {
            tc + (rem - head) / floor
        }
    } else {
        let head = floor * (tc - a);
        if rem <= head {
            a + rem / floor
        } else {
            ramp(tc, floor, rem - head)
        }
    }
}

/// A realized bandwidth trace.
#[derive(Clone, Debug)]
pub struct BandwidthTrace {
    kind: TraceKind,
    /// `kind` with all `Scaled`/`Windowed` wrappers peeled off — populated
    /// only when `kind` actually carries a wrapper, so plain traces don't
    /// duplicate their payload vectors
    base: Option<TraceKind>,
    /// product of the peeled `Scaled` fractions (1.0 for unwrapped kinds)
    scale: f64,
    /// all peeled `Windowed` windows (empty for unwrapped kinds)
    windows: Vec<DegradeWindow>,
    /// pre-generated grid + prefix integral for stochastic kinds
    grid: Option<Arc<Grid>>,
    /// knot prefix integral for `Samples` bases
    knots: Option<Arc<Knots>>,
    /// constant-multiplier segments (window boundaries)
    cum: CumTrace,
    floor: f64,
}

/// Grid resolution for stochastic traces (s).
const GRID_DT: f64 = 0.05;
/// Pre-generated horizon (s); beyond it the trace wraps around, keeping
/// long experiments stationary without unbounded memory. The wrap is by
/// **cell index** (`(t/dt) as usize % n`, see `at()`), and the prefix
/// integral extends periodically with the same cell mapping, so transfers
/// straddling the wrap price exactly the bits `at()` reports
/// (`grid_prefix_extends_periodically_past_the_horizon` below).
const GRID_HORIZON: f64 = 4096.0;

impl BandwidthTrace {
    pub fn new(kind: TraceKind) -> Self {
        let (base, scale, windows) = match &kind {
            TraceKind::Scaled { .. } | TraceKind::Windowed { .. } => {
                let (b, s, w) = Self::flatten(&kind);
                (Some(b), s, w)
            }
            _ => (None, 1.0, Vec::new()),
        };
        let realized = base.as_ref().unwrap_or(&kind);
        let grid = match realized {
            TraceKind::Ou { mean_bps, sigma_bps, theta, seed } => {
                Some(Arc::new(Grid::new(
                    GRID_DT,
                    Self::gen_ou(*mean_bps, *sigma_bps, *theta, *seed),
                )))
            }
            TraceKind::Markov { levels_bps, dwell_s, seed } => {
                Some(Arc::new(Grid::new(
                    GRID_DT,
                    Self::gen_markov(levels_bps, *dwell_s, *seed),
                )))
            }
            _ => None,
        };
        let knots = match realized {
            TraceKind::Samples { times_s, bps } if !times_s.is_empty() => {
                Some(Arc::new(Knots::new(times_s, bps)))
            }
            _ => None,
        };
        let cum = CumTrace::build(scale, &windows);
        // never allow a dead link: floor at 1 kbps
        Self { kind, base, scale, windows, grid, knots, cum, floor: 1e3 }
    }

    /// Peel nested `Scaled`/`Windowed` wrappers into
    /// (base kind, accumulated factor, accumulated windows).
    fn flatten(kind: &TraceKind) -> (TraceKind, f64, Vec<DegradeWindow>) {
        match kind {
            TraceKind::Scaled { inner, frac } => {
                let (base, f, w) = Self::flatten(inner);
                (base, f * frac, w)
            }
            TraceKind::Windowed { inner, windows } => {
                let (base, f, mut w) = Self::flatten(inner);
                w.extend(windows.iter().copied());
                (base, f, w)
            }
            other => (other.clone(), 1.0, Vec::new()),
        }
    }

    pub fn constant(bps: f64) -> Self {
        Self::new(TraceKind::Constant { bps })
    }

    /// This trace scaled by `frac`, lazily: full resolution, no resampling.
    pub fn scaled(&self, frac: f64) -> Self {
        Self::new(TraceKind::Scaled {
            inner: Box::new(self.kind.clone()),
            frac,
        })
    }

    /// This trace with degrade/outage `windows` applied, lazily: full
    /// resolution, no resampling. Empty windows return the trace unchanged.
    pub fn windowed(&self, windows: Vec<DegradeWindow>) -> Self {
        if windows.is_empty() {
            return self.clone();
        }
        Self::new(TraceKind::Windowed {
            inner: Box::new(self.kind.clone()),
            windows,
        })
    }

    /// The degrade/outage windows carried by this trace (empty unless a
    /// churn schedule baked some in).
    pub fn windows(&self) -> &[DegradeWindow] {
        &self.windows
    }

    pub fn kind(&self) -> &TraceKind {
        &self.kind
    }

    /// The evaluated kind: `kind` with any `Scaled` wrappers peeled off.
    fn base(&self) -> &TraceKind {
        self.base.as_ref().unwrap_or(&self.kind)
    }

    /// `Some(effective bps)` when the trace is constant in time (possibly
    /// through `Scaled` wrappers) — the closed-form transfer fast path.
    /// Windowed traces are never constant: the windows vary in time.
    pub fn as_constant(&self) -> Option<f64> {
        if self.windows.is_empty() {
            self.constant_base()
        } else {
            None
        }
    }

    /// `Some(healthy bps)` when the trace is constant *outside* its fault
    /// windows (constant base through `Scaled`/`Windowed` wrappers). A
    /// transfer whose interval touches no window still solves in closed
    /// form at this rate — the fast path that keeps churn runs from
    /// pricing every healthy-period transfer through the segment walk
    /// ([`super::Link::transfer_end`]).
    pub fn constant_base(&self) -> Option<f64> {
        if let TraceKind::Constant { bps } = self.base() {
            Some((bps * self.scale).max(self.floor))
        } else {
            None
        }
    }

    fn gen_ou(mean: f64, sigma: f64, theta: f64, seed: u64) -> Vec<f64> {
        let n = (GRID_HORIZON / GRID_DT) as usize;
        let mut rng = Rng::new(seed);
        let mut x = mean;
        let mut out = Vec::with_capacity(n);
        let sq = sigma * (2.0 * theta * GRID_DT).sqrt();
        for _ in 0..n {
            out.push(x);
            x += theta * (mean - x) * GRID_DT + sq * rng.normal();
            x = x.max(0.02 * mean); // reflect at 2% of mean
        }
        out
    }

    fn gen_markov(levels: &[f64], dwell_s: f64, seed: u64) -> Vec<f64> {
        assert!(!levels.is_empty());
        let n = (GRID_HORIZON / GRID_DT) as usize;
        let mut rng = Rng::new(seed);
        let mut state = rng.below(levels.len());
        let p_switch = (GRID_DT / dwell_s).min(1.0);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(levels[state]);
            if rng.next_f64() < p_switch {
                state = rng.below(levels.len());
            }
        }
        out
    }

    /// The effective multiplier (scale · window fracs) at time `t`.
    fn mult_at(&self, t: f64) -> f64 {
        if self.windows.is_empty() {
            return self.scale;
        }
        self.cum.segs[self.cum.index(t)].1
    }

    /// Bandwidth at absolute time `t` (bits/s). Pure function.
    pub fn at(&self, t: f64) -> f64 {
        let v = match self.base() {
            TraceKind::Constant { bps } => *bps,
            TraceKind::Sine { mean_bps, amp_bps, period_s } => {
                mean_bps + amp_bps * (std::f64::consts::TAU * t / period_s).sin()
            }
            TraceKind::Samples { times_s, bps } => {
                Self::interp(times_s, bps, t)
            }
            _ => {
                let g = self.grid.as_ref().unwrap();
                let i = ((t / g.dt) as usize) % g.len();
                g.samples[i]
            }
        };
        (v * self.mult_at(t)).max(self.floor)
    }

    fn interp(ts: &[f64], vs: &[f64], t: f64) -> f64 {
        if ts.is_empty() {
            return 0.0;
        }
        if t <= ts[0] {
            return vs[0];
        }
        if t >= *ts.last().unwrap() {
            return *vs.last().unwrap();
        }
        let i = ts.partition_point(|&x| x <= t) - 1;
        let w = (t - ts[i]) / (ts[i + 1] - ts[i]);
        vs[i] * (1.0 - w) + vs[i + 1] * w
    }

    /// Mean bandwidth over `[t0, t1]` — an exact prefix difference, no
    /// sampling grid. A degenerate interval (`t1 <= t0`) reports the
    /// instantaneous rate `at(t0)` instead of dividing by a non-positive
    /// width.
    pub fn mean_over(&self, t0: f64, t1: f64) -> f64 {
        if t1 <= t0 {
            return self.at(t0);
        }
        self.bits_over(t0, t1) / (t1 - t0)
    }

    /// Exact cumulative bits `∫_{t0}^{t1} at(s) ds` — the prefix-integral
    /// difference `B(t1) − B(t0)`. Returns 0 for a degenerate interval.
    pub fn bits_over(&self, t0: f64, t1: f64) -> f64 {
        if t1 <= t0 {
            return 0.0;
        }
        let segs = &self.cum.segs;
        let mut i = self.cum.index(t0);
        let mut t = t0;
        let mut acc = 0.0;
        loop {
            let m = segs[i].1;
            let end = if i + 1 < segs.len() {
                segs[i + 1].0
            } else {
                f64::INFINITY
            };
            if t1 <= end {
                return acc + self.seg_bits(m, t, t1);
            }
            acc += self.seg_bits(m, t, end);
            t = end;
            i += 1;
        }
    }

    /// Exact transfer end: the time `t` at which `bits_over(start, t)`
    /// reaches `bits` — the inverse of the cumulative integral, solved in
    /// closed form per piece (binary search over grid prefix sums /
    /// bracketed bisection for clamped sines). The effective rate is
    /// floored at 1 kbps, so every transfer terminates.
    pub fn end_of_transfer(&self, start: f64, bits: f64) -> f64 {
        if bits <= 0.0 {
            return start;
        }
        let segs = &self.cum.segs;
        let mut i = self.cum.index(start);
        let mut t = start;
        let mut remaining = bits;
        loop {
            let m = segs[i].1;
            if i + 1 < segs.len() {
                let end = segs[i + 1].0;
                let avail = self.seg_bits(m, t, end);
                if avail < remaining {
                    remaining -= avail;
                    t = end;
                    i += 1;
                    continue;
                }
            }
            return self.seg_end(m, t, remaining);
        }
    }

    /// The pre-engine integrator, kept verbatim as the comparison oracle
    /// shared by `tests/properties.rs` and `benches/bench_trace.rs`:
    /// forward Euler over `at()` at the historical 10 ms grid. **Frozen**
    /// — it defines the legacy semantics the exact engine is measured
    /// against; never "fix" it.
    pub fn euler_end_reference(&self, start: f64, bits: f64) -> f64 {
        const INT_DT: f64 = 0.01;
        let mut remaining = bits;
        let mut t = start;
        loop {
            let rate = self.at(t);
            let sent = rate * INT_DT;
            if sent >= remaining {
                return t + remaining / rate;
            }
            remaining -= sent;
            t += INT_DT;
        }
    }

    /// `∫_{t0}^{t1} max(m · base(s), floor) ds` within one multiplier
    /// segment.
    fn seg_bits(&self, m: f64, t0: f64, t1: f64) -> f64 {
        if t1 <= t0 {
            return 0.0;
        }
        if m <= 0.0 {
            // outage windows (frac = 0): the floor carries the transfer
            return self.floor * (t1 - t0);
        }
        match self.base() {
            TraceKind::Constant { bps } => {
                (bps * m).max(self.floor) * (t1 - t0)
            }
            TraceKind::Sine { mean_bps, amp_bps, period_s } => {
                self.sine_bits(*mean_bps, *amp_bps, *period_s, m, t0, t1)
            }
            TraceKind::Samples { times_s, bps } => {
                self.samples_bits(times_s, bps, m, t0, t1)
            }
            _ => self.grid_bits(m, t0, t1),
        }
    }

    /// End time of `bits` starting at `t0` within one multiplier segment
    /// (the caller guarantees the segment holds at least `bits`, or is the
    /// last, unbounded one).
    fn seg_end(&self, m: f64, t0: f64, bits: f64) -> f64 {
        if bits <= 0.0 {
            return t0;
        }
        if m <= 0.0 {
            return t0 + bits / self.floor;
        }
        match self.base() {
            TraceKind::Constant { bps } => {
                t0 + bits / (bps * m).max(self.floor)
            }
            TraceKind::Sine { mean_bps, amp_bps, period_s } => {
                self.sine_end(*mean_bps, *amp_bps, *period_s, m, t0, bits)
            }
            TraceKind::Samples { times_s, bps } => {
                self.samples_end(times_s, bps, m, t0, bits)
            }
            _ => self.grid_end(m, t0, bits),
        }
    }

    // ---- sine base: closed forms with floor-crossing splits ----

    fn sine_bits(
        &self,
        mean: f64,
        amp: f64,
        period: f64,
        m: f64,
        t0: f64,
        t1: f64,
    ) -> f64 {
        if amp == 0.0 {
            return (mean * m).max(self.floor) * (t1 - t0);
        }
        let om = std::f64::consts::TAU / period;
        if (mean - amp.abs()) * m >= self.floor {
            // the clamp never binds: one antiderivative difference
            let cosdiff = (om * t1).cos() - (om * t0).cos();
            return m * (mean * (t1 - t0) - (amp / om) * cosdiff);
        }
        if (mean + amp.abs()) * m <= self.floor {
            return self.floor * (t1 - t0);
        }
        // whole periods contribute a phase-invariant closed form; the
        // remainder splits at the floor crossings (sub-period spans skip
        // the per-period integral entirely — the inversion hot path)
        let q = ((t1 - t0) / period).floor();
        let whole = if q > 0.0 {
            q * self.sine_span(mean, amp, period, m, 0.0, period)
        } else {
            0.0
        };
        whole + self.sine_span(mean, amp, period, m, t0 + q * period, t1)
    }

    /// Clamped sine integral over a span of at most ~one period: split at
    /// the floor crossings `sin(ωt) = s0`, decide each piece by its
    /// midpoint, and use the pure-sine antiderivative above the floor.
    fn sine_span(
        &self,
        mean: f64,
        amp: f64,
        period: f64,
        m: f64,
        a: f64,
        b: f64,
    ) -> f64 {
        if b <= a {
            return 0.0;
        }
        let tau = std::f64::consts::TAU;
        let om = tau / period;
        let s0 = ((self.floor / m - mean) / amp).clamp(-1.0, 1.0);
        let x1 = s0.asin();
        let x2 = std::f64::consts::PI - x1;
        let mut cuts = vec![a, b];
        for x in [x1, x2] {
            let mut k = ((om * a - x) / tau).floor() - 1.0;
            let kmax = ((om * b - x) / tau).ceil() + 1.0;
            while k <= kmax {
                let t = (x + k * tau) / om;
                if t > a && t < b {
                    cuts.push(t);
                }
                k += 1.0;
            }
        }
        cuts.sort_by(f64::total_cmp);
        let mut acc = 0.0;
        for w in cuts.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            if hi <= lo {
                continue;
            }
            let mid = 0.5 * (lo + hi);
            let r = (mean + amp * (om * mid).sin()) * m;
            acc += if r >= self.floor {
                let cosdiff = (om * hi).cos() - (om * lo).cos();
                m * (mean * (hi - lo) - (amp / om) * cosdiff)
            } else {
                self.floor * (hi - lo)
            };
        }
        acc
    }

    fn sine_end(
        &self,
        mean: f64,
        amp: f64,
        period: f64,
        m: f64,
        t0: f64,
        bits: f64,
    ) -> f64 {
        if amp == 0.0 {
            return t0 + bits / (mean * m).max(self.floor);
        }
        if (mean + amp.abs()) * m <= self.floor {
            return t0 + bits / self.floor;
        }
        // skip whole periods arithmetically, then solve within one period
        // by guarded Newton on the closed-form cumulative (the rate is
        // positive, so it is strictly increasing; the bracket keeps every
        // step safe and the loop converges to ulp precision)
        let om = std::f64::consts::TAU / period;
        let pfull = self.sine_bits(mean, amp, period, m, 0.0, period);
        let q = (bits / pfull).floor().max(0.0);
        let mut lo = t0 + q * period;
        if self.sine_bits(mean, amp, period, m, t0, lo) > bits {
            lo = t0 + (q - 1.0).max(0.0) * period;
        }
        let mut hi = lo + period;
        while self.sine_bits(mean, amp, period, m, t0, hi) < bits {
            hi += period;
        }
        // anchor the cumulative at the bracket base so every Newton
        // evaluation integrates at most one (sub-)period
        let base = self.sine_bits(mean, amp, period, m, t0, lo);
        let anchor = lo;
        let mut x = 0.5 * (lo + hi);
        for _ in 0..200 {
            if x <= lo || x >= hi {
                break;
            }
            let f = base + self.sine_bits(mean, amp, period, m, anchor, x)
                - bits;
            if f < 0.0 {
                lo = x;
            } else {
                hi = x;
            }
            let rate = ((mean + amp * (om * x).sin()) * m).max(self.floor);
            let nx = x - f / rate;
            x = if nx > lo && nx < hi { nx } else { 0.5 * (lo + hi) };
        }
        hi
    }

    // ---- samples base: knot prefix sums + linear-piece closed forms ----

    /// Raw (unscaled, unclamped) cumulative of the piecewise-linear base,
    /// anchored at the first knot; the constant extensions before the
    /// first and after the last knot continue linearly, matching
    /// [`Self::interp`].
    fn knots_raw(&self, ts: &[f64], vs: &[f64], t: f64) -> f64 {
        let kn = self.knots.as_ref().unwrap();
        if t <= ts[0] {
            return vs[0] * (t - ts[0]);
        }
        let last = ts.len() - 1;
        if t >= ts[last] {
            return kn.cum[last] + vs[last] * (t - ts[last]);
        }
        let i = ts.partition_point(|&x| x <= t) - 1;
        let w = (t - ts[i]) / (ts[i + 1] - ts[i]);
        let vt = vs[i] * (1.0 - w) + vs[i + 1] * w;
        kn.cum[i] + 0.5 * (vs[i] + vt) * (t - ts[i])
    }

    fn samples_bits(
        &self,
        ts: &[f64],
        vs: &[f64],
        m: f64,
        t0: f64,
        t1: f64,
    ) -> f64 {
        if ts.is_empty() {
            // interp reports 0 bps: the floor is all there is
            return self.floor * (t1 - t0);
        }
        let kn = self.knots.as_ref().unwrap();
        if kn.min * m >= self.floor {
            let raw1 = self.knots_raw(ts, vs, t1);
            let raw0 = self.knots_raw(ts, vs, t0);
            return m * (raw1 - raw0);
        }
        self.samples_clamped_bits(ts, vs, m, t0, t1)
    }

    fn samples_clamped_bits(
        &self,
        ts: &[f64],
        vs: &[f64],
        m: f64,
        t0: f64,
        t1: f64,
    ) -> f64 {
        let floor = self.floor;
        let last = ts.len() - 1;
        let mut acc = 0.0;
        if t0 < ts[0] {
            acc += clamped_linear(m, floor, t0, t1.min(ts[0]), vs[0], 0.0);
        }
        if t1 > ts[last] {
            let a = t0.max(ts[last]);
            acc += clamped_linear(m, floor, a, t1, vs[last], 0.0);
        }
        if t1 <= ts[0] || t0 >= ts[last] {
            return acc;
        }
        let lo = t0.max(ts[0]);
        let hi = t1.min(ts[last]);
        let i0 = if lo <= ts[0] {
            0
        } else {
            ts.partition_point(|&x| x <= lo) - 1
        };
        for i in i0..last {
            let (pa, pb) = (ts[i], ts[i + 1]);
            if pa >= hi {
                break;
            }
            if pb <= pa {
                continue;
            }
            let a = lo.max(pa);
            let b = hi.min(pb);
            if b <= a {
                continue;
            }
            let sl = (vs[i + 1] - vs[i]) / (pb - pa);
            let va = vs[i] + sl * (a - pa);
            acc += clamped_linear(m, floor, a, b, va, sl);
        }
        acc
    }

    fn samples_end(
        &self,
        ts: &[f64],
        vs: &[f64],
        m: f64,
        t0: f64,
        bits: f64,
    ) -> f64 {
        if ts.is_empty() {
            return t0 + bits / self.floor;
        }
        let kn = self.knots.as_ref().unwrap();
        if kn.min * m >= self.floor {
            // unclamped: locate by knot prefix, solve the linear ramp with
            // the same cancellation-free quadratic as the clamped pieces
            let target = self.knots_raw(ts, vs, t0) + bits / m;
            let last = ts.len() - 1;
            if target <= 0.0 {
                return ts[0] + target / vs[0];
            }
            if target >= kn.cum[last] {
                return ts[last] + (target - kn.cum[last]) / vs[last];
            }
            let i = kn.cum.partition_point(|&c| c <= target) - 1;
            let rem = target - kn.cum[i];
            let sl = (vs[i + 1] - vs[i]) / (ts[i + 1] - ts[i]);
            let disc = (vs[i] * vs[i] + 2.0 * sl * rem).max(0.0).sqrt();
            return ts[i] + 2.0 * rem / (vs[i] + disc);
        }
        self.samples_clamped_end(ts, vs, m, t0, bits)
    }

    fn samples_clamped_end(
        &self,
        ts: &[f64],
        vs: &[f64],
        m: f64,
        t0: f64,
        bits: f64,
    ) -> f64 {
        let floor = self.floor;
        let last = ts.len() - 1;
        let mut t = t0;
        let mut rem = bits;
        if t < ts[0] {
            let avail = clamped_linear(m, floor, t, ts[0], vs[0], 0.0);
            if avail >= rem {
                return clamped_linear_end(m, floor, t, ts[0], vs[0], 0.0, rem);
            }
            rem -= avail;
            t = ts[0];
        }
        if t < ts[last] {
            let i0 = if t <= ts[0] {
                0
            } else {
                ts.partition_point(|&x| x <= t) - 1
            };
            for i in i0..last {
                let (pa, pb) = (ts[i], ts[i + 1]);
                if pb <= pa {
                    continue;
                }
                let a = t.max(pa);
                if a >= pb {
                    continue;
                }
                let sl = (vs[i + 1] - vs[i]) / (pb - pa);
                let va = vs[i] + sl * (a - pa);
                let avail = clamped_linear(m, floor, a, pb, va, sl);
                if avail >= rem {
                    return clamped_linear_end(m, floor, a, pb, va, sl, rem);
                }
                rem -= avail;
                t = pb;
            }
        }
        // constant extension past the last knot
        let rate = (vs[last] * m).max(floor);
        t.max(ts[last]) + rem / rate
    }

    // ---- stochastic grid base: prefix sums with periodic extension ----

    /// Raw (unscaled, unclamped) cumulative of the grid base. Uses the
    /// same cell mapping as `at()` — `cell = (t/dt) as usize`, value
    /// `samples[cell % n]` — so the periodic extension past
    /// [`GRID_HORIZON`] integrates exactly what `at()` reports, wrap
    /// discontinuity included. Negative times extend at `samples[0]`
    /// (the saturating cast `at()` performs).
    fn grid_raw(&self, g: &Grid, t: f64) -> f64 {
        if t <= 0.0 {
            return g.samples[0] * t;
        }
        let n = g.len();
        let cell = (t / g.dt) as usize;
        let (q, i) = (cell / n, cell % n);
        let frac = t - cell as f64 * g.dt;
        q as f64 * g.total() + g.prefix[i] + g.samples[i] * frac
    }

    fn grid_bits(&self, m: f64, t0: f64, t1: f64) -> f64 {
        let g = self.grid.as_ref().unwrap();
        if g.min * m >= self.floor {
            return m * (self.grid_raw(g, t1) - self.grid_raw(g, t0));
        }
        if g.max * m <= self.floor {
            return self.floor * (t1 - t0);
        }
        self.grid_clamped_bits(g, m, t0, t1)
    }

    /// Mid-clamp case (a deep `Scaled`/degrade pushes part of the sample
    /// range under the floor): walk cells — still exact, each cell is
    /// constant — skipping whole horizons via the per-horizon clamped
    /// total.
    fn grid_clamped_bits(&self, g: &Grid, m: f64, t0: f64, t1: f64) -> f64 {
        let n = g.len();
        let horizon = n as f64 * g.dt;
        let mut acc = 0.0;
        let mut t = t0;
        // the skip relies on horizon-periodicity, which only holds at
        // t >= 0 (negative times saturate to samples[0], see grid_raw)
        if t0 >= 0.0 && t1 - t0 > 2.0 * horizon {
            let per = self.grid_clamped_horizon(g, m);
            let q = ((t1 - t0) / horizon).floor() - 1.0;
            acc += q * per;
            t = t0 + q * horizon;
        }
        let mut cell = (t / g.dt) as usize;
        loop {
            let rate = (g.samples[cell % n] * m).max(self.floor);
            let b = (cell as f64 + 1.0) * g.dt;
            if b >= t1 {
                return acc + rate * (t1 - t).max(0.0);
            }
            acc += rate * (b - t).max(0.0);
            t = b;
            cell += 1;
        }
    }

    /// Clamped bits over one full horizon at multiplier `m`.
    fn grid_clamped_horizon(&self, g: &Grid, m: f64) -> f64 {
        g.samples.iter().map(|&s| (s * m).max(self.floor)).sum::<f64>() * g.dt
    }

    fn grid_end(&self, m: f64, t0: f64, bits: f64) -> f64 {
        let g = self.grid.as_ref().unwrap();
        if g.max * m <= self.floor {
            return t0 + bits / self.floor;
        }
        if g.min * m >= self.floor {
            // unclamped: O(log n) — skip whole horizons, binary-search the
            // prefix array, divide within the landing cell
            let total = g.total();
            let target = self.grid_raw(g, t0) + bits / m;
            if target <= 0.0 {
                return target / g.samples[0];
            }
            let n = g.len();
            let mut q = (target / total).floor();
            let mut rem = target - q * total;
            if rem < 0.0 {
                q -= 1.0;
                rem += total;
            }
            if rem >= total {
                q += 1.0;
                rem -= total;
            }
            let i = (g.prefix.partition_point(|&p| p <= rem) - 1).min(n - 1);
            let within = (rem - g.prefix[i]) / g.samples[i];
            return (q * n as f64 + i as f64) * g.dt + within;
        }
        // mid-clamp: skip whole horizons via the clamped total, then walk
        // (the skip needs horizon-periodicity, so only from t0 >= 0 —
        // negative times saturate to samples[0], see grid_raw)
        let n = g.len();
        let horizon = n as f64 * g.dt;
        let per = self.grid_clamped_horizon(g, m);
        let mut t = t0;
        let mut rem = bits;
        if t0 >= 0.0 && rem > 2.0 * per {
            let q = (rem / per).floor() - 1.0;
            rem -= q * per;
            t += q * horizon;
        }
        let mut cell = (t / g.dt) as usize;
        loop {
            let rate = (g.samples[cell % n] * m).max(self.floor);
            let b = (cell as f64 + 1.0) * g.dt;
            let avail = rate * (b - t).max(0.0);
            if avail >= rem {
                return t + rem / rate;
            }
            rem -= avail;
            t = b;
            cell += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_everywhere() {
        let t = BandwidthTrace::constant(1e8);
        assert_eq!(t.at(0.0), 1e8);
        assert_eq!(t.at(1e6), 1e8);
    }

    #[test]
    fn sine_bounds_and_mean() {
        let t = BandwidthTrace::new(TraceKind::Sine {
            mean_bps: 1e8,
            amp_bps: 5e7,
            period_s: 10.0,
        });
        for i in 0..1000 {
            let v = t.at(i as f64 * 0.037);
            assert!((5e7 - 1.0..=1.5e8 + 1.0).contains(&v));
        }
        // the prefix difference is exact: a full period averages to the
        // mean to fp precision, not just sampler precision
        let m = t.mean_over(0.0, 10.0);
        assert!((m - 1e8).abs() < 1.0, "mean={m}");
    }

    #[test]
    fn ou_stationary_stats() {
        let t = BandwidthTrace::new(TraceKind::Ou {
            mean_bps: 1e8,
            sigma_bps: 2e7,
            theta: 0.5,
            seed: 5,
        });
        let m = t.mean_over(0.0, 2000.0);
        assert!((m - 1e8).abs() < 1e7, "mean={m}");
        // never below floor, never absurd
        for i in 0..10_000 {
            let v = t.at(i as f64 * 0.21);
            assert!(v > 0.0 && v < 1e9);
        }
    }

    #[test]
    fn markov_visits_levels() {
        let levels = vec![5e7, 1e8, 2e8];
        let t = BandwidthTrace::new(TraceKind::Markov {
            levels_bps: levels.clone(),
            dwell_s: 1.0,
            seed: 6,
        });
        let mut seen = [false; 3];
        for i in 0..20_000 {
            let v = t.at(i as f64 * 0.05);
            for (j, &l) in levels.iter().enumerate() {
                if (v - l).abs() < 1.0 {
                    seen[j] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "levels visited: {seen:?}");
    }

    #[test]
    fn samples_interpolate() {
        let t = BandwidthTrace::new(TraceKind::Samples {
            times_s: vec![0.0, 10.0],
            bps: vec![1e8, 2e8],
        });
        assert_eq!(t.at(-1.0), 1e8);
        assert!((t.at(5.0) - 1.5e8).abs() < 1.0);
        assert_eq!(t.at(11.0), 2e8);
    }

    #[test]
    fn scaled_preserves_full_resolution() {
        // a fast sine (period 0.2 s) scaled by 0.25: every sample is exactly
        // frac × the inner value — no 0.5 s resampling grid, no horizon cap
        let inner = BandwidthTrace::new(TraceKind::Sine {
            mean_bps: 1e8,
            amp_bps: 5e7,
            period_s: 0.2,
        });
        let scaled = inner.scaled(0.25);
        for i in 0..500 {
            // probe sub-grid offsets and times far past the old 1024 s wrap
            let t = i as f64 * 0.013 + if i % 2 == 0 { 0.0 } else { 2000.0 };
            let want = (inner.at(t) * 0.25).max(1e3);
            assert_eq!(scaled.at(t), want, "t={t}");
        }
    }

    #[test]
    fn scaled_nests_multiplicatively() {
        let t = BandwidthTrace::constant(1e8).scaled(0.5).scaled(0.5);
        assert_eq!(t.at(3.0), 0.25 * 1e8);
        assert_eq!(t.as_constant(), Some(0.25 * 1e8));
    }

    #[test]
    fn scaled_stochastic_shares_inner_stream() {
        // scaling an OU trace must not change the realized sample path —
        // only its amplitude (the old resampling grid broke this)
        let kind = TraceKind::Ou {
            mean_bps: 1e8,
            sigma_bps: 2e7,
            theta: 0.4,
            seed: 12,
        };
        let inner = BandwidthTrace::new(kind.clone());
        let scaled = BandwidthTrace::new(TraceKind::Scaled {
            inner: Box::new(kind),
            frac: 0.1,
        });
        for i in 0..1000 {
            let t = i as f64 * 0.037;
            let want = (inner.at(t) * 0.1).max(1e3);
            assert_eq!(scaled.at(t), want);
        }
    }

    #[test]
    fn unscaled_constant_fast_path() {
        let t = BandwidthTrace::constant(2e8);
        assert_eq!(t.as_constant(), Some(2e8));
        let s = BandwidthTrace::new(TraceKind::Sine {
            mean_bps: 1e8,
            amp_bps: 1e7,
            period_s: 3.0,
        });
        assert_eq!(s.as_constant(), None);
        assert_eq!(s.scaled(0.5).as_constant(), None);
    }

    #[test]
    fn windowed_degrades_inside_window_only() {
        let t = BandwidthTrace::constant(1e8).windowed(vec![
            DegradeWindow { start_s: 10.0, end_s: 20.0, frac: 0.5 },
            DegradeWindow { start_s: 30.0, end_s: 40.0, frac: 0.0 },
        ]);
        assert_eq!(t.at(5.0), 1e8);
        assert_eq!(t.at(10.0), 5e7); // window start is inclusive
        assert_eq!(t.at(19.99), 5e7);
        assert_eq!(t.at(20.0), 1e8); // window end is exclusive
        // full outage: clamped to the 1 kbps floor, never zero
        assert_eq!(t.at(35.0), 1e3);
        assert_eq!(t.at(45.0), 1e8);
        // windowed traces lose the constant fast path
        assert_eq!(t.as_constant(), None);
        assert_eq!(t.windows().len(), 2);
    }

    #[test]
    fn windowed_composes_with_scaled() {
        // scale and windows commute: both are lazy multiplicative wrappers
        let inner = BandwidthTrace::new(TraceKind::Sine {
            mean_bps: 1e8,
            amp_bps: 4e7,
            period_s: 0.3,
        });
        let wrapped = inner
            .scaled(0.5)
            .windowed(vec![DegradeWindow { start_s: 2.0, end_s: 4.0, frac: 0.25 }]);
        for i in 0..300 {
            let t = i as f64 * 0.021;
            let base = inner.at(t) * 0.5;
            let want = if (2.0..4.0).contains(&t) { base * 0.25 } else { base };
            assert_eq!(wrapped.at(t), want.max(1e3), "t={t}");
        }
    }

    #[test]
    fn empty_windows_are_identity() {
        let t = BandwidthTrace::constant(2e8);
        let w = t.windowed(Vec::new());
        assert_eq!(w.as_constant(), Some(2e8));
        assert_eq!(w.kind(), t.kind());
    }

    #[test]
    fn deterministic_across_instances() {
        let k = TraceKind::Ou { mean_bps: 1e8, sigma_bps: 1e7, theta: 0.3, seed: 77 };
        let a = BandwidthTrace::new(k.clone());
        let b = BandwidthTrace::new(k);
        for i in 0..100 {
            assert_eq!(a.at(i as f64 * 1.3), b.at(i as f64 * 1.3));
        }
    }

    #[test]
    fn mean_over_degenerate_interval_returns_at() {
        let t = BandwidthTrace::new(TraceKind::Sine {
            mean_bps: 1e8,
            amp_bps: 3e7,
            period_s: 4.0,
        });
        // t1 == t0 and t1 < t0 both report the instantaneous rate instead
        // of a negative/zero-width quotient
        assert_eq!(t.mean_over(3.0, 3.0).to_bits(), t.at(3.0).to_bits());
        assert_eq!(t.mean_over(5.0, 2.0).to_bits(), t.at(5.0).to_bits());
    }

    #[test]
    fn cum_constant_paths_are_closed_form_exact() {
        let t = BandwidthTrace::constant(1e8).scaled(0.5);
        assert_eq!(t.bits_over(2.0, 5.0), 5e7 * 3.0);
        assert_eq!(t.end_of_transfer(2.0, 1.5e8), 5.0);
        // degenerate inputs
        assert_eq!(t.bits_over(5.0, 5.0), 0.0);
        assert_eq!(t.end_of_transfer(7.0, 0.0), 7.0);
    }

    #[test]
    fn cum_windowed_constant_prices_outages_exactly() {
        let t = BandwidthTrace::constant(1e8).windowed(vec![DegradeWindow {
            start_s: 10.0,
            end_s: 20.0,
            frac: 0.0,
        }]);
        // 0.05 s healthy + 10 s at the 1 kbps floor + the remainder healthy
        let bits = 1e7;
        let end = t.end_of_transfer(9.95, bits);
        let want = 20.0 + (bits - 5e6 - 1e4) / 1e8;
        assert!((end - want).abs() < 1e-9, "end={end} want={want}");
        // and the forward direction agrees bit-for-bit with the pieces
        let b = t.bits_over(9.95, end);
        assert!((b - bits).abs() < 1.0, "bits_over={b}");
    }

    #[test]
    fn cum_sine_inverts_and_prices_full_periods() {
        let t = BandwidthTrace::new(TraceKind::Sine {
            mean_bps: 1e8,
            amp_bps: 9e7,
            period_s: 2.0,
        });
        // one period's worth of bits at the mean takes exactly one period
        let end = t.end_of_transfer(0.0, 2e8);
        assert!((end - 2.0).abs() < 1e-9, "end={end}");
        // round trip from an arbitrary phase
        let bits = 3.7e8;
        let end = t.end_of_transfer(1.23, bits);
        assert!((t.bits_over(1.23, end) - bits).abs() < 1.0);
    }

    #[test]
    fn cum_sine_respects_the_floor_clamp() {
        // a sine dipping below zero spends part of each period at the
        // 1 kbps floor; the clamped integral must match a fine Riemann sum
        let t = BandwidthTrace::new(TraceKind::Sine {
            mean_bps: 1e6,
            amp_bps: 2e6,
            period_s: 3.0,
        });
        let (t0, t1) = (0.7, 9.1);
        let exact = t.bits_over(t0, t1);
        let n = 200_000;
        let dt = (t1 - t0) / n as f64;
        let riemann: f64 = (0..n)
            .map(|i| t.at(t0 + (i as f64 + 0.5) * dt) * dt)
            .sum();
        let rel = (exact - riemann).abs() / riemann;
        assert!(rel < 1e-6, "exact={exact} riemann={riemann}");
        // inversion round-trips through the clamped region
        let bits = exact * 0.6;
        let end = t.end_of_transfer(t0, bits);
        assert!((t.bits_over(t0, end) - bits).abs() <= bits * 1e-9 + 1.0);
    }

    #[test]
    fn cum_samples_inverts_across_knots() {
        let t = BandwidthTrace::new(TraceKind::Samples {
            times_s: vec![0.0, 10.0, 15.0],
            bps: vec![1e8, 2e8, 5e7],
        });
        // trapezoid over [0, 10] = 1.5e9; over [10, 15] = 6.25e8
        assert!((t.bits_over(0.0, 10.0) - 1.5e9).abs() < 1.0);
        assert!((t.bits_over(0.0, 15.0) - 2.125e9).abs() < 1.0);
        // past the last knot the rate is constant
        assert!((t.bits_over(15.0, 17.0) - 1e8).abs() < 1.0);
        for bits in [1e8, 1.5e9, 2.0e9, 2.5e9] {
            let end = t.end_of_transfer(0.0, bits);
            assert!(
                (t.bits_over(0.0, end) - bits).abs() <= bits * 1e-9 + 1.0,
                "bits={bits}"
            );
        }
    }

    #[test]
    fn cum_samples_floor_crossings_match_riemann() {
        // a degrade window so deep that the linear ramp crosses the floor
        // inside it: effective in-window rates span [650, 2000] around the
        // 1 kbps floor, so both clamped sub-pieces and the crossing split
        // run (the tolerance absorbs the Riemann sum's own error at the
        // two window-edge jump cells)
        let t = BandwidthTrace::new(TraceKind::Samples {
            times_s: vec![0.0, 20.0, 40.0],
            bps: vec![2e7, 2e8, 5e7],
        })
        .windowed(vec![DegradeWindow {
            start_s: 5.0,
            end_s: 35.0,
            frac: 1e-5,
        }]);
        let (t0, t1) = (1.0, 44.0);
        let exact = t.bits_over(t0, t1);
        let n = 400_000;
        let dt = (t1 - t0) / n as f64;
        let riemann: f64 = (0..n)
            .map(|i| t.at(t0 + (i as f64 + 0.5) * dt) * dt)
            .sum();
        let rel = (exact - riemann).abs() / riemann;
        assert!(rel < 1e-4, "exact={exact} riemann={riemann}");
        for frac in [0.2, 0.5, 0.9] {
            let bits = exact * frac;
            let end = t.end_of_transfer(t0, bits);
            assert!(
                (t.bits_over(t0, end) - bits).abs() <= bits * 1e-9 + 1e-3,
                "frac={frac}"
            );
        }
    }

    #[test]
    fn grid_prefix_extends_periodically_past_the_horizon() {
        let t = BandwidthTrace::new(TraceKind::Ou {
            mean_bps: 1e8,
            sigma_bps: 2e7,
            theta: 0.5,
            seed: 11,
        });
        // the wrap is by cell index (`(t/dt) as usize % n`), so bits over
        // any two whole horizons agree to fp noise
        let h = GRID_HORIZON;
        let b0 = t.bits_over(0.0, h);
        let b1 = t.bits_over(h, 2.0 * h);
        assert!((b0 - b1).abs() / b0 < 1e-9, "b0={b0} b1={b1}");
        // a span straddling the wrap prices exactly the bits at() reports:
        // compare against a cell-aligned midpoint Riemann sum (cells are
        // constant, so the sum is the exact integral)
        let (t0, t1) = (h - 6.3, h + 7.7);
        let exact = t.bits_over(t0, t1);
        let mut acc = 0.0;
        let mut cell = (t0 / GRID_DT) as usize;
        loop {
            let a = cell as f64 * GRID_DT;
            let b = (cell as f64 + 1.0) * GRID_DT;
            let (lo, hi) = (t0.max(a), t1.min(b));
            if hi > lo {
                acc += t.at(0.5 * (lo + hi)) * (hi - lo);
            }
            if b >= t1 {
                break;
            }
            cell += 1;
        }
        assert!(
            (exact - acc).abs() <= exact * 1e-9 + 1.0,
            "exact={exact} riemann={acc}"
        );
        // a transfer straddling the wrap inverts those same bits
        let bits = exact * 0.9;
        let end = t.end_of_transfer(t0, bits);
        assert!(end > h && end < t1, "end={end}");
        assert!((t.bits_over(t0, end) - bits).abs() <= bits * 1e-9 + 1.0);
    }

    #[test]
    fn cum_deep_scaled_grid_hits_the_floor_exactly() {
        // scale an OU trace so far down that part of the sample range
        // clamps at the floor: the cell walk must agree with at()
        let t = BandwidthTrace::new(TraceKind::Ou {
            mean_bps: 1e8,
            sigma_bps: 2e7,
            theta: 0.5,
            seed: 3,
        })
        .scaled(2e-5); // mean ≈ 2 kbps, floor at 1 kbps binds sometimes
        let (t0, t1) = (12.3, 61.7);
        let exact = t.bits_over(t0, t1);
        let mut acc = 0.0;
        let mut cell = (t0 / GRID_DT) as usize;
        loop {
            let a = cell as f64 * GRID_DT;
            let b = (cell as f64 + 1.0) * GRID_DT;
            let (lo, hi) = (t0.max(a), t1.min(b));
            if hi > lo {
                acc += t.at(0.5 * (lo + hi)) * (hi - lo);
            }
            if b >= t1 {
                break;
            }
            cell += 1;
        }
        assert!(
            (exact - acc).abs() <= exact * 1e-9 + 1e-3,
            "exact={exact} riemann={acc}"
        );
        let bits = exact * 0.5;
        let end = t.end_of_transfer(t0, bits);
        assert!((t.bits_over(t0, end) - bits).abs() <= bits * 1e-9 + 1e-3);
    }
}
