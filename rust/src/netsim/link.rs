//! A WAN link with end-to-end latency `b` and time-varying bandwidth `a(t)`.
//!
//! `transfer_end` solves `∫ a(t) dt = bits` over the trace **exactly** so
//! that transmissions started during a bandwidth dip genuinely take
//! longer — the effect DeCo-SGD's adaptivity exploits. Pricing goes
//! through the trace's prefix-integral engine
//! ([`BandwidthTrace::end_of_transfer`], DESIGN.md §Perf): O(log n) per
//! transfer on the stochastic grids instead of the former 10 ms
//! forward-Euler stepping, with no discretization error. The paper's
//! model (`delta·S_g/a + b`) is the constant-trace special case, kept as
//! an explicit closed-form fast path (bit-identical to the pre-engine
//! code) and asserted in tests.

use super::trace::{BandwidthTrace, DegradeWindow};

#[derive(Clone, Debug)]
pub struct Link {
    trace: BandwidthTrace,
    latency_s: f64,
}

impl Link {
    pub fn new(trace: BandwidthTrace, latency_s: f64) -> Self {
        assert!(latency_s >= 0.0);
        Self { trace, latency_s }
    }

    pub fn latency(&self) -> f64 {
        self.latency_s
    }

    pub fn trace(&self) -> &BandwidthTrace {
        &self.trace
    }

    /// This link with degrade/outage `windows` baked into its trace (same
    /// latency). How churn schedules realize `LinkOutage`/`LinkDegrade`
    /// events — see `elastic::ChurnTimeline::bake_windows`.
    pub fn with_windows(&self, windows: Vec<DegradeWindow>) -> Link {
        Link::new(self.trace.windowed(windows), self.latency_s)
    }

    /// Instantaneous bandwidth (bits/s).
    pub fn bandwidth_at(&self, t: f64) -> f64 {
        self.trace.at(t)
    }

    /// Time when a transfer of `bits` *finishes leaving the sender* if it
    /// starts at `start` (transmission time only, no latency).
    pub fn transfer_end(&self, start: f64, bits: u64) -> f64 {
        if bits == 0 {
            return start;
        }
        let bits_f = bits as f64;
        // fast path: constant traces (possibly `Scaled`) solve in closed form
        if let Some(bps) = self.trace.as_constant() {
            return start + bits_f / bps;
        }
        // constant base with fault windows: the closed form still holds
        // whenever the transfer interval touches no window (the rate is the
        // healthy constant throughout, so the end time is exact and nothing
        // after it matters)
        if let Some(bps) = self.trace.constant_base() {
            let end = start + bits_f / bps;
            let clear = self
                .trace
                .windows()
                .iter()
                .all(|w| w.start_s >= end || w.end_s <= start);
            if clear {
                return end;
            }
        }
        // everything else inverts the exact cumulative integral B(t)
        self.trace.end_of_transfer(start, bits_f)
    }

    /// Arrival time at the receiver: transmission end + latency.
    pub fn arrival(&self, start: f64, bits: u64) -> f64 {
        self.transfer_end(start, bits) + self.latency_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::trace::TraceKind;

    #[test]
    fn constant_matches_closed_form() {
        let link = Link::new(BandwidthTrace::constant(1e8), 0.1);
        // 1e8 bits over 1e8 bps = 1 s
        let end = link.transfer_end(5.0, 100_000_000);
        assert!((end - 6.0).abs() < 1e-9);
        assert!((link.arrival(5.0, 100_000_000) - 6.1).abs() < 1e-9);
    }

    #[test]
    fn zero_bits_instant() {
        let link = Link::new(BandwidthTrace::constant(1e8), 0.25);
        assert_eq!(link.transfer_end(3.0, 0), 3.0);
        assert!((link.arrival(3.0, 0) - 3.25).abs() < 1e-12);
    }

    #[test]
    fn windowed_constant_fast_path_outside_windows_only() {
        use crate::netsim::DegradeWindow;
        let link = Link::new(
            BandwidthTrace::constant(1e8)
                .windowed(vec![DegradeWindow {
                    start_s: 10.0,
                    end_s: 20.0,
                    frac: 0.0,
                }]),
            0.1,
        );
        // clear of the window: exact closed form (1e7 bits at 1e8 = 0.1 s)
        let end = link.transfer_end(5.0, 10_000_000);
        assert_eq!(end, 5.1);
        // ends exactly at the window start: still closed form
        assert_eq!(link.transfer_end(9.9, 10_000_000), 10.0);
        // overlapping the outage: stalls through it, now priced exactly —
        // 0.05 s healthy + 10 s at the 1 kbps floor + the remainder
        let stalled = link.transfer_end(9.95, 10_000_000);
        let want = 20.0 + (1e7 - 5e6 - 1e4) / 1e8;
        assert!(
            (stalled - want).abs() < 1e-9,
            "exact stall pricing: got {stalled}, want {want}"
        );
    }

    #[test]
    fn varying_bandwidth_integrates() {
        // square-ish sine: mean 1e8; sending exactly one period's worth of
        // bits takes exactly one period under the exact integral
        let link = Link::new(
            BandwidthTrace::new(TraceKind::Sine {
                mean_bps: 1e8,
                amp_bps: 9e7,
                period_s: 2.0,
            }),
            0.0,
        );
        let end = link.transfer_end(0.0, 200_000_000); // one period at mean
        assert!((end - 2.0).abs() < 1e-9, "end={end}");
    }

    #[test]
    fn slower_trace_takes_longer() {
        let fast = Link::new(BandwidthTrace::constant(2e8), 0.0);
        let slow = Link::new(BandwidthTrace::constant(5e7), 0.0);
        let bits = 50_000_000;
        assert!(slow.transfer_end(0.0, bits) > fast.transfer_end(0.0, bits));
    }

    #[test]
    fn monotone_in_start_time() {
        let link = Link::new(
            BandwidthTrace::new(TraceKind::Ou {
                mean_bps: 1e8,
                sigma_bps: 3e7,
                theta: 0.5,
                seed: 42,
            }),
            0.05,
        );
        let mut prev = 0.0;
        for i in 0..50 {
            let s = i as f64 * 0.3;
            let e = link.arrival(s, 10_000_000);
            assert!(e >= s + 0.05);
            assert!(e >= prev - 1e-9 || e >= s, "arrivals should not regress");
            prev = e;
        }
    }

    #[test]
    fn transfer_end_inverts_the_cumulative_integral() {
        // B(end) − B(start) == bits on every base kind the clock prices
        let traces = vec![
            BandwidthTrace::new(TraceKind::Sine {
                mean_bps: 1e8,
                amp_bps: 6e7,
                period_s: 3.0,
            }),
            BandwidthTrace::new(TraceKind::Ou {
                mean_bps: 8e7,
                sigma_bps: 2e7,
                theta: 0.4,
                seed: 21,
            }),
            BandwidthTrace::new(TraceKind::Markov {
                levels_bps: vec![2e7, 1e8, 2e8],
                dwell_s: 1.5,
                seed: 4,
            }),
        ];
        for trace in traces {
            let link = Link::new(trace.clone(), 0.1);
            for k in 0..40u64 {
                let start = k as f64 * 17.3;
                let bits = 1_000_000 + k * 77_000_000;
                let end = link.transfer_end(start, bits);
                let got = trace.bits_over(start, end);
                let want = bits as f64;
                assert!(
                    (got - want).abs() <= want * 1e-9 + 1.0,
                    "k={k}: B(end)-B(start)={got} != bits={want}"
                );
            }
        }
    }
}
