//! Heterogeneous network fabric — one link per worker, stored as
//! link-equivalence classes.
//!
//! The paper's Limitations section explicitly defers "device heterogeneity
//! (different bandwidth/latency per node)". The fabric is the pricing
//! substrate for every training run (DESIGN.md §Network-Fabric): the
//! synchronous aggregation of iteration k completes when the **slowest**
//! worker's message arrives (`sync_arrival` / the fabric-driven Eq. 19
//! recurrence in `coordinator::VirtualClock`), so the effective (a, b) the
//! DeCo controller should plan with are the bottleneck worker's. A
//! homogeneous fabric collapses bit-identically to the former single-link
//! path (enforced by `tests/fabric.rs`); `exp hetero` quantifies how much
//! bottleneck-aware planning recovers under a straggler.
//!
//! ## SoA layout
//!
//! Workers sharing an identical link (same latency bits, same trace config)
//! are grouped into an **equivalence class**: `class_links` holds one
//! [`Link`] per class and `class_of[worker]` maps each worker to its class.
//! A 100k-worker homogeneous fabric stores one link, not 100k, and every
//! consumer that prices "one transfer per distinct link" (the virtual
//! clock's class engine, `sync_arrival`'s uniform fast path) gets its
//! sharing structure from here. `link(worker)` still hands out a per-worker
//! `&Link` view, so heterogeneity-aware call sites are unchanged. Bonds are
//! `Arc`-shared so cloning a fabric per sweep cell never deep-copies path
//! sets.

use std::sync::Arc;

use super::bond::Bond;
use super::link::Link;
use super::loss::LossProcess;
use super::trace::BandwidthTrace;

#[derive(Clone, Debug)]
pub struct Fabric {
    /// one link per equivalence class (same latency bits + trace config)
    class_links: Vec<Link>,
    /// per-worker class index into `class_links`
    class_of: Vec<u32>,
    /// per-worker multi-path bonds (DESIGN.md §Bonding); `None` everywhere
    /// on a classic single-path fabric. A bonded worker's link class
    /// mirrors its path 0, so legacy single-link views stay meaningful.
    bonds: Vec<Option<Arc<Bond>>>,
    /// per-worker message-loss processes (DESIGN.md §Robustness); `None`
    /// everywhere on a lossless fabric. Loss pricing is per-worker (the
    /// draws key on the worker id), so a lossy worker leaves the uniform
    /// fast path just like a bonded one.
    losses: Vec<Option<Arc<LossProcess>>>,
    /// every link shares one trace config and latency — cached at
    /// construction so hot paths (`sync_arrival`, the virtual clock) can
    /// price one transfer instead of n when the answer is provably shared
    uniform: bool,
}

impl Fabric {
    pub fn new(links: Vec<Link>) -> Self {
        assert!(!links.is_empty());
        let n = links.len();
        let mut class_links: Vec<Link> = Vec::new();
        let mut class_of = Vec::with_capacity(n);
        for l in links {
            match class_links.iter().position(|rep| Self::same_class(rep, &l))
            {
                Some(c) => class_of.push(c as u32),
                None => {
                    class_of.push(class_links.len() as u32);
                    class_links.push(l);
                }
            }
        }
        let uniform = class_links.len() == 1;
        Self {
            class_links,
            class_of,
            bonds: vec![None; n],
            losses: vec![None; n],
            uniform,
        }
    }

    /// Class predicate: identical latency (bit equality) and identical
    /// trace configuration. Two links in one class price any transfer
    /// bit-identically, by construction of the exact integral engine.
    fn same_class(a: &Link, b: &Link) -> bool {
        a.latency().to_bits() == b.latency().to_bits()
            && a.trace().kind() == b.trace().kind()
    }

    /// Whether every link is identical (same trace config, same latency).
    /// Uniform fabrics price every worker's transfer identically, which is
    /// what lets [`Self::sync_arrival`] and the clock's fast path run one
    /// exact integral instead of n.
    pub fn is_uniform(&self) -> bool {
        self.uniform
    }

    /// Homogeneous fabric: `n` copies of the same trace/latency.
    pub fn homogeneous(n: usize, trace: BandwidthTrace, latency_s: f64) -> Self {
        Self::new(
            (0..n)
                .map(|_| Link::new(trace.clone(), latency_s))
                .collect(),
        )
    }

    /// `n` copies of an existing link — the compatibility bridge for the
    /// single-`Link` constructors.
    pub fn replicate(link: Link, n: usize) -> Self {
        Self::new(vec![link; n])
    }

    /// One straggler: worker 0 gets `frac` of the bandwidth and `mult`× the
    /// latency of everyone else. The straggler's trace is the *lazily
    /// scaled* base trace ([`super::trace::TraceKind::Scaled`]), so it keeps
    /// the base trace's full temporal resolution and horizon.
    pub fn with_straggler(
        n: usize,
        trace: BandwidthTrace,
        latency_s: f64,
        frac: f64,
        mult: f64,
    ) -> Self {
        assert!(frac > 0.0 && mult > 0.0);
        let mut links = Vec::with_capacity(n);
        for i in 0..n {
            if i == 0 {
                links.push(Link::new(trace.scaled(frac), latency_s * mult));
            } else {
                links.push(Link::new(trace.clone(), latency_s));
            }
        }
        Self::new(links)
    }

    pub fn workers(&self) -> usize {
        self.class_of.len()
    }

    pub fn link(&self, worker: usize) -> &Link {
        &self.class_links[self.class_of[worker] as usize]
    }

    /// Number of link-equivalence classes (1 on a uniform fabric).
    pub fn link_class_count(&self) -> usize {
        self.class_links.len()
    }

    /// The equivalence class `worker`'s link belongs to. Workers in one
    /// class price any transfer bit-identically — the virtual clock's
    /// class engine builds its sharing structure from this map.
    pub fn link_class(&self, worker: usize) -> usize {
        self.class_of[worker] as usize
    }

    /// Replace one worker's link — how churn schedules bake outage/degrade
    /// windows into the fabric before a run (elastic subsystem). The
    /// O(workers) class rebucketing runs once per call; this is a
    /// setup-path operation (window baking, re-wiring), never per-tick.
    pub fn set_link(&mut self, worker: usize, link: Link) {
        let old = self.class_of[worker] as usize;
        let c = match self
            .class_links
            .iter()
            .position(|rep| Self::same_class(rep, &link))
        {
            Some(c) => c,
            None => {
                self.class_links.push(link);
                self.class_links.len() - 1
            }
        };
        self.class_of[worker] = c as u32;
        if c != old && !self.class_of.iter().any(|&x| x as usize == old) {
            // the old class lost its last member: drop it and remap
            self.class_links.remove(old);
            for x in &mut self.class_of {
                if *x as usize > old {
                    *x -= 1;
                }
            }
        }
        self.uniform = !self.has_bonds()
            && !self.has_loss()
            && self.class_links.len() == 1;
    }

    /// Attach a multi-path [`Bond`] to one worker. The worker's link class
    /// is re-pointed at the bond's path 0 so single-link views keep
    /// working; any bond takes the fabric off the uniform fast path (its
    /// pricing is genuinely per-worker).
    pub fn set_bond(&mut self, worker: usize, bond: Bond) {
        self.set_link(worker, bond.path(0).clone());
        self.bonds[worker] = Some(Arc::new(bond));
        self.uniform = false;
    }

    pub fn bond(&self, worker: usize) -> Option<&Bond> {
        self.bonds[worker].as_deref()
    }

    /// The `Arc` handle behind [`Self::bond`] — what the clock's class
    /// engine stores so per-cell fabric clones share path sets.
    pub fn bond_arc(&self, worker: usize) -> Option<&Arc<Bond>> {
        self.bonds[worker].as_ref()
    }

    pub fn has_bonds(&self) -> bool {
        self.bonds.iter().any(Option::is_some)
    }

    /// Attach a message-loss process to one worker's transport
    /// (DESIGN.md §Robustness). A trivially lossless process (rate 0,
    /// no bursts) is not stored at all, so "loss rate 0" is *structurally*
    /// identical to today's lossless fabric — not merely numerically.
    pub fn set_loss(&mut self, worker: usize, loss: LossProcess) {
        if loss.is_lossless() {
            self.losses[worker] = None;
        } else {
            self.losses[worker] = Some(Arc::new(loss));
            self.uniform = false;
        }
    }

    pub fn loss(&self, worker: usize) -> Option<&LossProcess> {
        self.losses[worker].as_deref()
    }

    /// The `Arc` handle behind [`Self::loss`] — what the clock's class
    /// engine stores so per-cell fabric clones share loss processes.
    pub fn loss_arc(&self, worker: usize) -> Option<&Arc<LossProcess>> {
        self.losses[worker].as_ref()
    }

    pub fn has_loss(&self) -> bool {
        self.losses.iter().any(Option::is_some)
    }

    /// Path count per worker: 1 for classic single-link workers, the
    /// bond's k otherwise — the geometry churn validation and the monitor
    /// are built against.
    pub fn paths_per_worker(&self) -> Vec<usize> {
        (0..self.workers())
            .map(|i| self.bonds[i].as_deref().map_or(1, Bond::k))
            .collect()
    }

    /// One worker's effective `(bandwidth, latency)` view at time `t`:
    /// the bare link for single-path workers, the bonded aggregate
    /// (Σ path bandwidth, water-filling-weighted effective latency)
    /// otherwise.
    fn worker_view(&self, worker: usize, t: f64) -> (f64, f64) {
        match &self.bonds[worker] {
            Some(b) => (b.bandwidth_at(t), b.effective_latency(t)),
            None => {
                let l = self.link(worker);
                (l.bandwidth_at(t), l.latency())
            }
        }
    }

    /// Arrival time of the synchronous aggregation: max over per-worker
    /// arrivals of a message of `bits` started at `start`. On a uniform
    /// fabric every arrival is identical, so one exact transfer integral
    /// suffices (bit-identical to the max over n copies).
    pub fn sync_arrival(&self, start: f64, bits: u64) -> f64 {
        if self.uniform {
            return self.class_links[0].arrival(start, bits);
        }
        (0..self.workers())
            .map(|i| match &self.bonds[i] {
                Some(b) => b.arrival(start, bits),
                None => self.link(i).arrival(start, bits),
            })
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// The bottleneck link's parameters at time `t` — what DeCo should plan
    /// with under heterogeneity (min bandwidth, max latency). A bonded
    /// worker contributes its aggregate view (Σ path bandwidth, weighted
    /// effective latency).
    pub fn bottleneck(&self, t: f64) -> (f64, f64) {
        let a = (0..self.workers())
            .map(|i| self.worker_view(i, t).0)
            .fold(f64::INFINITY, f64::min);
        let b = (0..self.workers())
            .map(|i| self.worker_view(i, t).1)
            .fold(f64::NEG_INFINITY, f64::max);
        (a, b)
    }

    /// Mean-link parameters at time `t` — what a heterogeneity-blind
    /// controller would plan with (the `exp hetero` control arm). Summed in
    /// worker index order: the float fold must stay bit-stable across the
    /// SoA refactor.
    pub fn mean(&self, t: f64) -> (f64, f64) {
        let n = self.workers() as f64;
        let a = (0..self.workers())
            .map(|i| self.worker_view(i, t).0)
            .sum::<f64>()
            / n;
        let b = (0..self.workers())
            .map(|i| self.worker_view(i, t).1)
            .sum::<f64>()
            / n;
        (a, b)
    }

    /// The bottleneck over the *active* subset of workers — the
    /// membership-aware planning view under churn (DESIGN.md §Elasticity).
    /// Panics if the mask is empty or all-false: an empty active set has no
    /// bottleneck (the elastic layer never lets membership empty).
    pub fn bottleneck_active(&self, t: f64, active: &[bool]) -> (f64, f64) {
        assert_eq!(active.len(), self.workers());
        let (mut a, mut b) = (f64::INFINITY, f64::NEG_INFINITY);
        for (i, &on) in active.iter().enumerate() {
            if on {
                let (wa, wb) = self.worker_view(i, t);
                a = a.min(wa);
                b = b.max(wb);
            }
        }
        assert!(a.is_finite(), "active set must be non-empty");
        (a, b)
    }

    /// Mean-link parameters over the *active* subset — the
    /// heterogeneity-blind control view under churn.
    pub fn mean_active(&self, t: f64, active: &[bool]) -> (f64, f64) {
        assert_eq!(active.len(), self.workers());
        let (mut sa, mut sb, mut n) = (0.0, 0.0, 0usize);
        for (i, &on) in active.iter().enumerate() {
            if on {
                let (wa, wb) = self.worker_view(i, t);
                sa += wa;
                sb += wb;
                n += 1;
            }
        }
        assert!(n > 0, "active set must be non-empty");
        (sa / n as f64, sb / n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::TraceKind;

    #[test]
    fn homogeneous_sync_equals_single_link() {
        let f = Fabric::homogeneous(4, BandwidthTrace::constant(1e8), 0.1);
        let single = Link::new(BandwidthTrace::constant(1e8), 0.1);
        assert_eq!(
            f.sync_arrival(2.0, 10_000_000),
            single.arrival(2.0, 10_000_000)
        );
        assert_eq!(f.workers(), 4);
    }

    #[test]
    fn replicate_matches_homogeneous() {
        let link = Link::new(BandwidthTrace::constant(5e7), 0.2);
        let f = Fabric::replicate(link.clone(), 3);
        assert_eq!(f.workers(), 3);
        assert_eq!(f.sync_arrival(1.0, 1_000_000), link.arrival(1.0, 1_000_000));
    }

    #[test]
    fn straggler_dominates_sync() {
        let f = Fabric::with_straggler(
            4,
            BandwidthTrace::constant(1e8),
            0.1,
            0.25, // quarter bandwidth
            2.0,  // double latency
        );
        let healthy = Link::new(BandwidthTrace::constant(1e8), 0.1);
        let bits = 50_000_000;
        let sync = f.sync_arrival(0.0, bits);
        assert!(sync > healthy.arrival(0.0, bits));
        // straggler transfer: 4x time + 0.2 latency
        assert!((sync - (bits as f64 / 2.5e7 + 0.2)).abs() < 0.05, "{sync}");
    }

    #[test]
    fn straggler_keeps_trace_dynamics() {
        // a sine faster than the old 0.5 s resampling grid, probed past the
        // old 1024 s horizon: the scaled link must track frac × base exactly
        let base = BandwidthTrace::new(TraceKind::Sine {
            mean_bps: 1e8,
            amp_bps: 4e7,
            period_s: 0.3,
        });
        let f = Fabric::with_straggler(2, base.clone(), 0.1, 0.5, 1.0);
        for i in 0..400 {
            let t = 0.07 * i as f64 + if i % 3 == 0 { 1500.0 } else { 0.0 };
            assert_eq!(f.link(0).bandwidth_at(t), (base.at(t) * 0.5).max(1e3));
            assert_eq!(f.link(1).bandwidth_at(t), base.at(t));
        }
    }

    #[test]
    fn link_classes_group_identical_workers() {
        let f = Fabric::homogeneous(1000, BandwidthTrace::constant(1e8), 0.1);
        assert_eq!(f.link_class_count(), 1, "homogeneous fabric = 1 class");
        assert!((0..1000).all(|i| f.link_class(i) == 0));
        let s = Fabric::with_straggler(
            1000,
            BandwidthTrace::constant(1e8),
            0.1,
            0.25,
            2.0,
        );
        assert_eq!(s.link_class_count(), 2, "straggler forms its own class");
        assert_eq!(s.link_class(0), 0);
        assert!((1..1000).all(|i| s.link_class(i) == 1));
        // per-worker views still resolve through the class table
        assert_eq!(s.link(0).latency(), 0.2);
        assert_eq!(s.link(999).latency(), 0.1);
    }

    #[test]
    fn active_views_skip_departed_workers() {
        let f = Fabric::with_straggler(
            4,
            BandwidthTrace::constant(1e8),
            0.1,
            0.25,
            2.0,
        );
        let all = vec![true; 4];
        assert_eq!(f.bottleneck_active(0.0, &all), f.bottleneck(0.0));
        assert_eq!(f.mean_active(0.0, &all), f.mean(0.0));
        // straggler (worker 0) departed: the active bottleneck is healthy
        let mask = vec![false, true, true, true];
        assert_eq!(f.bottleneck_active(0.0, &mask), (1e8, 0.1));
        let (am, bm) = f.mean_active(0.0, &mask);
        assert_eq!(am, 1e8);
        assert!((bm - 0.1).abs() < 1e-12, "bm={bm}");
    }

    #[test]
    fn set_link_replaces_one_worker() {
        let mut f = Fabric::homogeneous(3, BandwidthTrace::constant(1e8), 0.1);
        f.set_link(1, Link::new(BandwidthTrace::constant(1e7), 0.4));
        assert_eq!(f.bottleneck(0.0), (1e7, 0.4));
        assert_eq!(f.link(0).latency(), 0.1);
        assert_eq!(f.link_class_count(), 2);
    }

    #[test]
    fn uniformity_tracks_construction_and_set_link() {
        let mut f = Fabric::homogeneous(3, BandwidthTrace::constant(1e8), 0.1);
        assert!(f.is_uniform());
        // the uniform fast path must agree with the general max loop
        let general: f64 = (0..f.workers())
            .map(|i| f.link(i).arrival(2.0, 5_000_000))
            .fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(f.sync_arrival(2.0, 5_000_000).to_bits(), general.to_bits());
        // replacing a link breaks uniformity; restoring it re-establishes
        // (and the orphaned one-member class is garbage-collected)
        f.set_link(1, Link::new(BandwidthTrace::constant(1e7), 0.1));
        assert!(!f.is_uniform());
        assert_eq!(f.link_class_count(), 2);
        f.set_link(1, Link::new(BandwidthTrace::constant(1e8), 0.1));
        assert!(f.is_uniform());
        assert_eq!(f.link_class_count(), 1);
        assert!(!Fabric::with_straggler(
            3,
            BandwidthTrace::constant(1e8),
            0.1,
            0.5,
            1.0
        )
        .is_uniform());
    }

    #[test]
    fn bottleneck_reports_worst_case() {
        let f = Fabric::with_straggler(
            3,
            BandwidthTrace::constant(2e8),
            0.05,
            0.5,
            3.0,
        );
        let (a, b) = f.bottleneck(1.0);
        assert!((a - 1e8).abs() / 1e8 < 0.01, "a={a}");
        assert!((b - 0.15).abs() < 1e-9, "b={b}");
        let (am, bm) = f.mean(1.0);
        assert!(am > a && am < 2e8, "mean bw between bottleneck and best");
        assert!(bm > 0.05 && bm < b, "mean latency between best and worst");
    }

    #[test]
    fn bonds_leave_the_uniform_fast_path_and_aggregate_views() {
        use crate::netsim::Bond;
        let mut f = Fabric::homogeneous(3, BandwidthTrace::constant(1e8), 0.1);
        assert!(!f.has_bonds());
        assert_eq!(f.paths_per_worker(), vec![1, 1, 1]);
        f.set_bond(
            0,
            Bond::new(vec![
                Link::new(BandwidthTrace::constant(1e8), 0.1),
                Link::new(BandwidthTrace::constant(5e7), 0.02),
            ]),
        );
        assert!(f.has_bonds());
        assert!(!f.is_uniform(), "bonded pricing is per-worker");
        assert_eq!(f.paths_per_worker(), vec![2, 1, 1]);
        assert_eq!(f.bond(0).unwrap().k(), 2);
        assert!(f.bond(1).is_none());
        // worker 0's aggregate: 150 Mbps, weighted latency ≈ 73 ms — so the
        // bottleneck view stays at the unbonded workers' 100 Mbps / 100 ms
        assert_eq!(f.bottleneck(0.0), (1e8, 0.1));
        let (am, bm) = f.mean(0.0);
        assert!((am - (1.5e8 + 2e8) / 3.0).abs() < 1.0, "am={am}");
        // worker 0 latency is bandwidth-weighted across paths, not min:
        // (1e8·0.1 + 5e7·0.02) / 1.5e8
        let w0 = 11e6 / 1.5e8;
        assert!((bm - (w0 + 0.2) / 3.0).abs() < 1e-12, "bm={bm}");
        // a bonded sync arrival beats the mirrored path-0 link alone
        let solo = Fabric::homogeneous(3, BandwidthTrace::constant(1e8), 0.1);
        let bits = 200_000_000;
        assert!(f.sync_arrival(0.0, bits) <= solo.sync_arrival(0.0, bits));
        // set_link elsewhere must not resurrect the uniform fast path
        f.set_link(1, Link::new(BandwidthTrace::constant(1e8), 0.1));
        assert!(!f.is_uniform());
    }
}
