//! Heterogeneous network fabric — one link per worker.
//!
//! The paper's Limitations section explicitly defers "device heterogeneity
//! (different bandwidth/latency per node)". This extension implements the
//! substrate and the natural semantics for the synchronous DD-EF-SGD
//! pipeline: the aggregation of iteration k completes when the **slowest**
//! worker's message arrives, so the effective (a, b) the DeCo controller
//! should plan with are the bottleneck worker's. `exp ablation --which
//! heterogeneity` quantifies how much a straggler erodes DeCo's gains.

use super::link::Link;
use super::trace::BandwidthTrace;

pub struct Fabric {
    links: Vec<Link>,
}

impl Fabric {
    pub fn new(links: Vec<Link>) -> Self {
        assert!(!links.is_empty());
        Self { links }
    }

    /// Homogeneous fabric: `n` copies of the same trace/latency.
    pub fn homogeneous(n: usize, trace: BandwidthTrace, latency_s: f64) -> Self {
        Self::new(
            (0..n)
                .map(|_| Link::new(trace.clone(), latency_s))
                .collect(),
        )
    }

    /// One straggler: worker 0 gets `frac` of the bandwidth and `mult`× the
    /// latency of everyone else.
    pub fn with_straggler(
        n: usize,
        trace: BandwidthTrace,
        latency_s: f64,
        frac: f64,
        mult: f64,
    ) -> Self {
        let mut links = Vec::with_capacity(n);
        for i in 0..n {
            if i == 0 {
                // scale the trace by sampling: wrap as Samples over a grid
                let times: Vec<f64> = (0..2048).map(|k| k as f64 * 0.5).collect();
                let bps: Vec<f64> =
                    times.iter().map(|&t| trace.at(t) * frac).collect();
                links.push(Link::new(
                    BandwidthTrace::new(super::trace::TraceKind::Samples {
                        times_s: times,
                        bps,
                    }),
                    latency_s * mult,
                ));
            } else {
                links.push(Link::new(trace.clone(), latency_s));
            }
        }
        Self::new(links)
    }

    pub fn workers(&self) -> usize {
        self.links.len()
    }

    pub fn link(&self, worker: usize) -> &Link {
        &self.links[worker]
    }

    /// Arrival time of the synchronous aggregation: max over per-worker
    /// arrivals of a message of `bits` started at `start`.
    pub fn sync_arrival(&self, start: f64, bits: u64) -> f64 {
        self.links
            .iter()
            .map(|l| l.arrival(start, bits))
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// The bottleneck link's parameters at time `t` — what DeCo should plan
    /// with under heterogeneity (min bandwidth, max latency).
    pub fn bottleneck(&self, t: f64) -> (f64, f64) {
        let a = self
            .links
            .iter()
            .map(|l| l.bandwidth_at(t))
            .fold(f64::INFINITY, f64::min);
        let b = self
            .links
            .iter()
            .map(|l| l.latency())
            .fold(f64::NEG_INFINITY, f64::max);
        (a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_sync_equals_single_link() {
        let f = Fabric::homogeneous(4, BandwidthTrace::constant(1e8), 0.1);
        let single = Link::new(BandwidthTrace::constant(1e8), 0.1);
        assert_eq!(
            f.sync_arrival(2.0, 10_000_000),
            single.arrival(2.0, 10_000_000)
        );
        assert_eq!(f.workers(), 4);
    }

    #[test]
    fn straggler_dominates_sync() {
        let f = Fabric::with_straggler(
            4,
            BandwidthTrace::constant(1e8),
            0.1,
            0.25, // quarter bandwidth
            2.0,  // double latency
        );
        let healthy = Link::new(BandwidthTrace::constant(1e8), 0.1);
        let bits = 50_000_000;
        let sync = f.sync_arrival(0.0, bits);
        assert!(sync > healthy.arrival(0.0, bits));
        // straggler transfer: 4x time + 0.2 latency
        assert!((sync - (bits as f64 / 2.5e7 + 0.2)).abs() < 0.05, "{sync}");
    }

    #[test]
    fn bottleneck_reports_worst_case() {
        let f = Fabric::with_straggler(
            3,
            BandwidthTrace::constant(2e8),
            0.05,
            0.5,
            3.0,
        );
        let (a, b) = f.bottleneck(1.0);
        assert!((a - 1e8).abs() / 1e8 < 0.01, "a={a}");
        assert!((b - 0.15).abs() < 1e-9, "b={b}");
    }
}
