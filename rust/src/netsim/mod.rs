//! WAN network simulator — the substitute for the paper's docker-tc testbed
//! (Sec. C.1): dynamic bandwidth traces, a varying-rate link that integrates
//! transfer time, and the monitor whose (a, b) estimates feed DeCo.

pub mod fabric;
pub mod link;
pub mod monitor;
pub mod trace;

pub use fabric::Fabric;
pub use link::Link;
pub use monitor::NetworkMonitor;
pub use trace::{BandwidthTrace, TraceKind};
