//! WAN network simulator — the substitute for the paper's docker-tc testbed
//! (Sec. C.1): dynamic bandwidth traces, varying-rate links that integrate
//! transfer time, the per-worker [`Fabric`] every training run is priced
//! on, and the per-link monitors whose aggregate (a, b) estimates feed
//! DeCo (DESIGN.md §Network-Fabric).

pub mod bond;
pub mod fabric;
pub mod link;
pub mod loss;
pub mod monitor;
pub mod trace;

pub use bond::{Bond, BondSchedule};
pub use fabric::Fabric;
pub use link::Link;
pub use loss::{LossBurstWindow, LossKind, LossProcess, LossyOutcome};
pub use monitor::{FabricMonitor, NetworkMonitor, SlotEstimate};
pub use trace::{BandwidthTrace, DegradeWindow, TraceKind};
