//! Bonded multi-path transport: one worker, k WAN paths, one payload
//! (DESIGN.md §Bonding).
//!
//! A `Bond` aggregates several unreliable per-worker links (e.g. a
//! cellular-like OU path plus a stable low-bandwidth path) into a single
//! resilient transport. The water-filling scheduler splits a gradient's
//! bits across the paths so every path's share *arrives* at the same
//! moment: the bonded arrival is the earliest `T` where
//! `Σ_p B_p(start_p, max(start_p, T − b_p)) ≥ bits`, with `B_p` the exact
//! cumulative-bandwidth integral from `netsim::trace` and `b_p` the path
//! latency. The sum is monotone nondecreasing in `T`, so `T` is found by
//! bracketed bisection run to full f64 resolution — O(k log n) per
//! schedule on the stochastic grids, closed-form integrals elsewhere.
//!
//! Degenerate contracts: a k = 1 bond delegates straight to
//! `Link::transfer_end` (no bisection), so it is bit-identical to the
//! single-link path the rest of the simulator prices; `bits = 0` arrives
//! after the smallest `start + latency` with every path idle. A path
//! under a full outage window still "carries" its 1 kbps floor trickle —
//! the same stall-not-die clamp single links use — so the schedule
//! degrades to the surviving paths' capacity instead of freezing.

use crate::netsim::{DegradeWindow, Link};

/// k per-worker WAN paths priced as one transport.
#[derive(Clone, Debug)]
pub struct Bond {
    paths: Vec<Link>,
}

/// One bonded transfer, fully resolved: the common arrival plus the
/// per-path split the water-filling scheduler chose.
#[derive(Clone, Debug)]
pub struct BondSchedule {
    /// When the receiver holds the full payload (all shares land here).
    pub arrival: f64,
    /// Per-path transmission end times (the path's next busy-from time).
    pub tx_end: Vec<f64>,
    /// Per-path bit shares; Σ equals the payload (±1e-6 relative).
    pub bits: Vec<f64>,
    /// Per-path busy seconds (0 for a path that carried nothing).
    pub tx_secs: Vec<f64>,
}

impl Bond {
    pub fn new(paths: Vec<Link>) -> Self {
        assert!(!paths.is_empty(), "a bond needs at least one path");
        Self { paths }
    }

    /// The degenerate one-path bond (bit-identical to the bare link).
    pub fn single(link: Link) -> Self {
        Self::new(vec![link])
    }

    pub fn k(&self) -> usize {
        self.paths.len()
    }

    pub fn paths(&self) -> &[Link] {
        &self.paths
    }

    pub fn path(&self, p: usize) -> &Link {
        &self.paths[p]
    }

    /// A copy with fault windows baked into path `p` only — the failover
    /// primitive `elastic` uses for path-scoped churn events.
    pub fn with_path_windows(
        &self,
        p: usize,
        windows: Vec<DegradeWindow>,
    ) -> Bond {
        let mut paths = self.paths.clone();
        paths[p] = paths[p].with_windows(windows);
        Bond::new(paths)
    }

    /// The lowest path latency — the bonded latency view DeCo plans on
    /// (the first share can arrive this soon after its send).
    pub fn min_latency(&self) -> f64 {
        self.paths
            .iter()
            .map(Link::latency)
            .fold(f64::INFINITY, f64::min)
    }

    /// Aggregate instantaneous bandwidth `Σ_p a_p(t)`.
    pub fn bandwidth_at(&self, t: f64) -> f64 {
        self.paths.iter().map(|p| p.bandwidth_at(t)).sum()
    }

    /// Water-filling-weighted effective latency at time `t`:
    /// `Σ_p a_p(t)·b_p / Σ_p a_p(t)` — each path's latency weighted by the
    /// bandwidth share the water-filling scheduler hands it. The bare min
    /// across paths (the pre-weighting view) under-prices a bond with one
    /// fast-but-thin and one slow-but-fat path, because most bits ride the
    /// slow path. Falls back to [`Self::min_latency`] when every path is
    /// at zero bandwidth (all-paths-out floor trickle).
    pub fn effective_latency(&self, t: f64) -> f64 {
        if self.paths.len() == 1 {
            // bit-identity contract: a k=1 bond is the bare link
            return self.paths[0].latency();
        }
        let (mut num, mut den) = (0.0, 0.0);
        for p in &self.paths {
            let a = p.bandwidth_at(t);
            num += a * p.latency();
            den += a;
        }
        if den > 0.0 {
            num / den
        } else {
            self.min_latency()
        }
    }

    /// Water-fill `bits` across the paths, path `p` free from
    /// `starts[p]`: every share arrives at the common `arrival`.
    pub fn schedule(&self, starts: &[f64], bits: u64) -> BondSchedule {
        let k = self.paths.len();
        assert_eq!(starts.len(), k, "one start per path");
        if k == 1 {
            // bit-identity contract: no bisection, the bare link's answer
            let link = &self.paths[0];
            let tm = link.transfer_end(starts[0], bits);
            return BondSchedule {
                arrival: tm + link.latency(),
                tx_end: vec![tm],
                bits: vec![bits as f64],
                tx_secs: vec![if bits > 0 { tm - starts[0] } else { 0.0 }],
            };
        }
        let first_arrival = starts
            .iter()
            .zip(&self.paths)
            .map(|(&s, p)| s + p.latency())
            .fold(f64::INFINITY, f64::min);
        if bits == 0 {
            return BondSchedule {
                arrival: first_arrival,
                tx_end: starts.to_vec(),
                bits: vec![0.0; k],
                tx_secs: vec![0.0; k],
            };
        }
        let bits_f = bits as f64;
        let covered = |t: f64| -> f64 {
            let mut sum = 0.0;
            for (p, link) in self.paths.iter().enumerate() {
                let end = (t - link.latency()).max(starts[p]);
                sum += link.trace().bits_over(starts[p], end);
            }
            sum
        };
        // Bracket: no path has sent anything at the first possible
        // arrival (lo), and the best path ALONE covers the payload by its
        // own single-path arrival (hi) — so the earliest covering T lies
        // in [lo, hi]. Bisect to full f64 resolution: a coarser tolerance
        // would leave a k·rate·ε bits-conservation error behind.
        let mut lo = first_arrival;
        let mut hi = self
            .paths
            .iter()
            .enumerate()
            .map(|(p, l)| l.transfer_end(starts[p], bits) + l.latency())
            .fold(f64::INFINITY, f64::min)
            .max(lo);
        while hi > lo {
            let mid = 0.5 * (lo + hi);
            if mid <= lo || mid >= hi {
                break;
            }
            if covered(mid) >= bits_f {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        let arrival = hi;
        let mut tx_end = Vec::with_capacity(k);
        let mut shares = Vec::with_capacity(k);
        let mut tx_secs = Vec::with_capacity(k);
        for (p, link) in self.paths.iter().enumerate() {
            let end = (arrival - link.latency()).max(starts[p]);
            let share = link.trace().bits_over(starts[p], end);
            tx_end.push(end);
            tx_secs.push(if share > 0.0 { end - starts[p] } else { 0.0 });
            shares.push(share);
        }
        BondSchedule { arrival, tx_end, bits: shares, tx_secs }
    }

    /// `schedule` with every path free from the same `start`; returns the
    /// common arrival.
    pub fn arrival(&self, start: f64, bits: u64) -> f64 {
        let starts = vec![start; self.paths.len()];
        self.schedule(&starts, bits).arrival
    }

    /// `schedule` with a common `start`; returns the last transmission
    /// end across the paths (the bonded analogue of
    /// `Link::transfer_end`).
    pub fn transfer_end(&self, start: f64, bits: u64) -> f64 {
        let starts = vec![start; self.paths.len()];
        self.schedule(&starts, bits)
            .tx_end
            .into_iter()
            .fold(start, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{BandwidthTrace, TraceKind};

    fn sine(mean: f64, amp: f64, period: f64, lat: f64) -> Link {
        Link::new(
            BandwidthTrace::new(TraceKind::Sine {
                mean_bps: mean,
                amp_bps: amp,
                period_s: period,
            }),
            lat,
        )
    }

    #[test]
    fn k1_bond_is_bit_identical_to_the_bare_link() {
        for link in [
            Link::new(BandwidthTrace::constant(1e8), 0.1),
            sine(5e7, 2e7, 3.0, 0.25),
        ] {
            let bond = Bond::single(link.clone());
            for bits in [0u64, 1, 4_000_000, 900_000_000] {
                for start in [0.0, 1.75, 42.0] {
                    let s = bond.schedule(&[start], bits);
                    assert_eq!(
                        s.tx_end[0].to_bits(),
                        link.transfer_end(start, bits).to_bits()
                    );
                    assert_eq!(
                        s.arrival.to_bits(),
                        link.arrival(start, bits).to_bits()
                    );
                }
            }
        }
    }

    #[test]
    fn identical_paths_split_evenly_and_halve_the_transfer() {
        let link = Link::new(BandwidthTrace::constant(1e8), 0.1);
        let bond = Bond::new(vec![link.clone(), link.clone()]);
        let bits = 200_000_000u64;
        let s = bond.schedule(&[0.0, 0.0], bits);
        // each path carries half the payload in half the solo time
        let solo = link.arrival(0.0, bits);
        let tol = 1e-6 * bits as f64 + 1.0;
        assert!((s.bits[0] - s.bits[1]).abs() < tol);
        assert!((s.bits[0] + s.bits[1] - bits as f64).abs() < tol);
        let expect = 0.5 * (solo - 0.1) + 0.1;
        assert!(
            (s.arrival - expect).abs() < 1e-6,
            "arrival {} != halved {expect}",
            s.arrival
        );
    }

    #[test]
    fn slow_path_carries_its_bandwidth_share() {
        let fast = Link::new(BandwidthTrace::constant(8e7), 0.1);
        let slow = Link::new(BandwidthTrace::constant(2e7), 0.1);
        let bits = 100_000_000u64;
        let s = Bond::new(vec![fast, slow]).schedule(&[0.0, 0.0], bits);
        // equal latencies, constant rates: shares follow the rate ratio
        // and the bonded pipe behaves like one 100 Mbps link
        assert!((s.bits[0] / s.bits[1] - 4.0).abs() < 1e-6);
        assert!((s.arrival - (1.0 + 0.1)).abs() < 1e-6);
    }

    #[test]
    fn outage_window_shifts_the_payload_to_the_survivor() {
        let flaky = Link::new(
            BandwidthTrace::constant(1e8).windowed(vec![DegradeWindow {
                start_s: 0.0,
                end_s: 1e4,
                frac: 0.0,
            }]),
            0.05,
        );
        let stable = Link::new(BandwidthTrace::constant(2e7), 0.3);
        let bits = 40_000_000u64;
        let bond = Bond::new(vec![flaky.clone(), stable.clone()]);
        let s = bond.schedule(&[0.0, 0.0], bits);
        // the flaky path contributes only its 1 kbps floor trickle
        assert!(s.bits[0] < 1e4, "outaged path carried {}", s.bits[0]);
        assert!(s.bits[1] > bits as f64 - 1e4);
        let solo = stable.arrival(0.0, bits);
        assert!(
            s.arrival <= solo + 1e-9,
            "failover arrival {} worse than survivor alone {solo}",
            s.arrival
        );
        // and the all-paths-out bond stalls at k x floor, not forever
        let both = Bond::new(vec![
            flaky.clone(),
            Link::new(
                BandwidthTrace::constant(2e7).windowed(vec![DegradeWindow {
                    start_s: 0.0,
                    end_s: 1e4,
                    frac: 0.0,
                }]),
                0.3,
            ),
        ]);
        let stalled = both.schedule(&[0.0, 0.0], 10_000u64);
        assert!(stalled.arrival > 4.0, "2 kbps floor must gate the stall");
    }

    #[test]
    fn zero_bits_arrive_on_the_fastest_latency() {
        let bond = Bond::new(vec![
            Link::new(BandwidthTrace::constant(1e8), 0.4),
            Link::new(BandwidthTrace::constant(1e6), 0.07),
        ]);
        let s = bond.schedule(&[2.0, 3.0], 0);
        assert_eq!(s.arrival.to_bits(), (3.0 + 0.07f64).to_bits());
        assert_eq!(s.bits, vec![0.0, 0.0]);
        assert_eq!(s.tx_end, vec![2.0, 3.0]);
    }

    #[test]
    fn bits_conserved_on_varying_traces_and_staggered_starts() {
        let bond = Bond::new(vec![
            sine(9e7, 3e7, 4.0, 0.12),
            sine(3e7, 1e7, 11.0, 0.02),
            Link::new(BandwidthTrace::constant(1.5e7), 0.3),
        ]);
        for bits in [50_000u64, 7_000_000, 600_000_000] {
            let s = bond.schedule(&[1.0, 6.5, 2.25], bits);
            let total: f64 = s.bits.iter().sum();
            let tol = 1e-6 * bits as f64 + 1.0;
            assert!(
                (total - bits as f64).abs() < tol,
                "split sums to {total}, payload {bits}"
            );
            for p in 0..3 {
                // no share arrives after the common arrival
                let lat = bond.path(p).latency();
                assert!(s.tx_end[p] + lat <= s.arrival + 1e-9);
            }
        }
    }
}
