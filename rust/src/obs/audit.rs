//! Plan audit — predicted-vs-realized round times, hindsight-oracle
//! regret, and estimator calibration (DESIGN.md §Observability → Audit).
//!
//! DeCo's whole claim is that the closed-form round-time model picks
//! `(τ, δ)` well; PR 8's trace stream records each decision
//! ([`ReplanRecord`]) without ever checking it against what the virtual
//! clock delivered. This module closes the prediction → outcome loop:
//!
//! * **Plan audit** ([`PlanAudit`]): joins each re-plan with the realized
//!   virtual-time outcomes of the iterations it governed into per-window
//!   records ([`PlanWindow`]) — predicted vs realized seconds/iter,
//!   signed bias, relative error — plus a run-level calibration fold
//!   ([`AuditSummary`]). Window `i` spans `[t_replan_i, t_replan_{i+1})`
//!   (the last closes at its final tick's arrival); because the training
//!   loop emits `Replan` at `clock.now()` — the previous tick's arrival —
//!   the windows tile `[first_replan, makespan]` bitwise, and realized
//!   time sums exactly to the clock's total over that range. The fold is
//!   O(1) per tick (the [`PlanAudit::streaming`] form — same budget class
//!   as [`super::Attribution::record_flat`], so `exp scale` can afford
//!   it) and the buffered form replays the identical per-event updates,
//!   so the two agree bit-for-bit by construction.
//! * **Hindsight-oracle regret** ([`oracle_regret`]): re-solve each
//!   window against the *realized* bandwidth over it — the exact
//!   prefix-integral trace means, not estimates — to get the oracle
//!   `(τ, δ)` and its round time; report per-window and cumulative
//!   regret of the executed plan. At the solved point the closed form is
//!   bubble-free (`T_avg = T_comp`), so on a constant trace regret is
//!   ≈ 0 and any gap is exactly what adaptation lost.
//! * **Estimator calibration** ([`calibrate`]): score the
//!   [`crate::netsim::FabricMonitor`] estimates captured in each
//!   [`ReplanRecord`] against ground-truth trace means over the window
//!   they governed — signed bias, RMSE, ±10% coverage — per estimator
//!   slot and aggregated, plus the bonded `[pess, opt]` band coverage
//!   (how often the PR-6 optimistic Σ-bandwidth view bracketed reality).
//!
//! Conventions: bias is `realized − predicted` (positive = the plan was
//! optimistic / under-predicted). Bonded workers' ground truth follows
//! the planner's own optimistic convention — Σ path trace means, min
//! path latency — so the regret charges the *plan*, not the convention;
//! the pessimistic band shows when that convention itself misled. On a
//! two-tier topology the audit scores the LAN-tier solve only (the flat
//! view the worker pipeline realizes).

use super::{ReplanRecord, TraceEvent, TraceSink};
use crate::deco::{solve, DecoInput};
use crate::metrics::format_table;
use crate::netsim::Fabric;
use crate::timesim::{t_avg_closed_form, PipelineParams};
use crate::util::Json;
use std::collections::BTreeMap;

// ---------------------------------------------------------------------------
// Plan windows
// ---------------------------------------------------------------------------

/// One plan window: the iterations a single re-plan governed, joined with
/// their realized virtual-time span.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanWindow {
    /// ordinal among closed non-empty windows (0-based, re-plan order)
    pub index: usize,
    /// first governed iteration (the tick whose solve opened the window)
    pub iter_first: usize,
    /// governed iterations (ticks priced inside the window)
    pub iters: usize,
    /// re-plan instant — the previous tick's arrival
    pub t_start: f64,
    /// the window's last tick arrival (== the next re-plan's instant)
    pub t_end: f64,
    /// solver-predicted steady-state seconds per iteration
    pub predicted: f64,
    /// the decision record (`None` when the fold was fed raw predictions
    /// without records, as `exp scale` does)
    pub rec: Option<ReplanRecord>,
}

impl PlanWindow {
    /// Realized seconds per governed iteration.
    pub fn realized(&self) -> f64 {
        (self.t_end - self.t_start) / self.iters as f64
    }

    /// Signed bias (s/iter): realized − predicted. Positive = the plan
    /// under-predicted (was optimistic).
    pub fn bias(&self) -> f64 {
        self.realized() - self.predicted
    }

    /// Bias relative to the realized round time (0 when degenerate).
    pub fn rel_err(&self) -> f64 {
        let r = self.realized();
        if r > 0.0 {
            self.bias() / r
        } else {
            0.0
        }
    }
}

/// Run-level plan-calibration fold. Every field is updated by the same
/// O(1) per-window close whether the audit streams or buffers, so the
/// two paths agree bit-for-bit.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AuditSummary {
    /// closed windows that governed at least one tick
    pub windows: usize,
    /// total governed iterations
    pub iters: usize,
    /// first re-plan instant (start of the audited range)
    pub first_t: f64,
    /// last governed tick arrival (end of the audited range)
    pub last_t: f64,
    /// Σ predicted · iters (predicted seconds over the audited range)
    pub pred_time: f64,
    /// Σ (t_end − t_start) (== `last_t − first_t` up to float addition)
    pub real_time: f64,
    /// Σ per-window bias² — feeds [`Self::rmse`]
    pub bias_sq_sum: f64,
    /// windows that over-predicted (realized < predicted)
    pub over: usize,
    /// windows that under-predicted (realized > predicted)
    pub under: usize,
    /// largest-magnitude signed per-window bias (s/iter)
    pub worst_bias: f64,
    /// index of the worst window
    pub worst_index: usize,
}

impl AuditSummary {
    /// Iteration-weighted mean predicted round time (s/iter).
    pub fn mean_predicted(&self) -> f64 {
        if self.iters > 0 {
            self.pred_time / self.iters as f64
        } else {
            0.0
        }
    }

    /// Iteration-weighted mean realized round time (s/iter).
    pub fn mean_realized(&self) -> f64 {
        if self.iters > 0 {
            self.real_time / self.iters as f64
        } else {
            0.0
        }
    }

    /// Run-level signed bias (s/iter): mean realized − mean predicted.
    pub fn bias(&self) -> f64 {
        self.mean_realized() - self.mean_predicted()
    }

    /// Per-window RMSE of realized − predicted (s/iter).
    pub fn rmse(&self) -> f64 {
        if self.windows > 0 {
            (self.bias_sq_sum / self.windows as f64).sqrt()
        } else {
            0.0
        }
    }
}

/// The window currently accumulating ticks.
#[derive(Clone, Debug)]
struct OpenWindow {
    iter_first: usize,
    iters: usize,
    t_start: f64,
    t_end: f64,
    predicted: f64,
    rec: Option<ReplanRecord>,
}

/// The plan-audit fold: feed it re-plans and tick arrivals (directly via
/// [`Self::replan`] / [`Self::tick`], or as a [`TraceSink`]), then
/// [`Self::finish`]. The streaming form keeps only the [`AuditSummary`]
/// — O(1) memory, O(1) per tick; the buffered form retains every
/// [`PlanWindow`] for the regret and calibration passes.
#[derive(Clone, Debug, Default)]
pub struct PlanAudit {
    summary: AuditSummary,
    open: Option<OpenWindow>,
    retained: Option<Vec<PlanWindow>>,
}

impl PlanAudit {
    /// O(1)-memory streaming fold: summary only, records dropped.
    pub fn streaming() -> Self {
        Self::default()
    }

    /// Replay a buffered trace, retaining every closed window. The
    /// per-event updates are the exact calls a streaming fold makes, so
    /// `PlanAudit::buffered(events).summary()` equals the streaming
    /// summary bit-for-bit.
    pub fn buffered(events: &[TraceEvent]) -> Self {
        let mut a = Self { retained: Some(Vec::new()), ..Self::default() };
        for ev in events {
            a.record(ev);
        }
        a.finish();
        a
    }

    /// A re-plan fired at virtual time `t` before pricing iteration
    /// `iter`: close the open window at `t` and open the next one.
    /// `rec` is retained only by the buffered form.
    pub fn replan(
        &mut self,
        t: f64,
        iter: usize,
        predicted: f64,
        rec: Option<ReplanRecord>,
    ) {
        self.close(t);
        self.open = Some(OpenWindow {
            iter_first: iter,
            iters: 0,
            t_start: t,
            t_end: t,
            predicted,
            rec: if self.retained.is_some() { rec } else { None },
        });
    }

    /// A tick arrived at `tc`. Ticks before the first re-plan are outside
    /// every window and contribute nothing.
    pub fn tick(&mut self, tc: f64) {
        if let Some(o) = self.open.as_mut() {
            o.iters += 1;
            o.t_end = tc;
        }
    }

    /// Close the run: the open window ends at its last tick's arrival.
    /// Idempotent; a window that governed no tick is dropped.
    pub fn finish(&mut self) {
        if let Some(end) = self.open.as_ref().map(|o| o.t_end) {
            self.close(end);
        }
    }

    fn close(&mut self, t_end: f64) {
        let Some(o) = self.open.take() else { return };
        if o.iters == 0 {
            return;
        }
        let s = &mut self.summary;
        if s.windows == 0 {
            s.first_t = o.t_start;
        }
        s.last_t = t_end;
        let realized = (t_end - o.t_start) / o.iters as f64;
        let bias = realized - o.predicted;
        s.iters += o.iters;
        s.pred_time += o.predicted * o.iters as f64;
        s.real_time += t_end - o.t_start;
        s.bias_sq_sum += bias * bias;
        if bias < 0.0 {
            s.over += 1;
        } else if bias > 0.0 {
            s.under += 1;
        }
        if s.windows == 0 || bias.abs() > s.worst_bias.abs() {
            s.worst_bias = bias;
            s.worst_index = s.windows;
        }
        let index = s.windows;
        s.windows += 1;
        if let Some(ws) = self.retained.as_mut() {
            ws.push(PlanWindow {
                index,
                iter_first: o.iter_first,
                iters: o.iters,
                t_start: o.t_start,
                t_end,
                predicted: o.predicted,
                rec: o.rec,
            });
        }
    }

    pub fn summary(&self) -> &AuditSummary {
        &self.summary
    }

    /// Closed windows (empty in the streaming form).
    pub fn windows(&self) -> &[PlanWindow] {
        self.retained.as_deref().unwrap_or(&[])
    }
}

impl TraceSink for PlanAudit {
    fn record(&mut self, ev: &TraceEvent) {
        match ev {
            TraceEvent::Replan { t, iter, rec } => {
                let keep =
                    self.retained.is_some().then(|| rec.clone());
                self.replan(*t, *iter, rec.predicted_round, keep);
            }
            TraceEvent::Tick(tk) => self.tick(tk.tc),
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------------
// Ground truth + hindsight oracle
// ---------------------------------------------------------------------------

/// One worker's realized `(bandwidth, latency)` over `[t0, t1)`: the
/// exact trace mean (prefix-integral difference) on single-path links;
/// on bonded workers the planner's optimistic convention — Σ path means,
/// min path latency.
fn worker_realized(fabric: &Fabric, w: usize, t0: f64, t1: f64) -> (f64, f64) {
    match fabric.bond(w) {
        Some(bond) => {
            let bw: f64 =
                bond.paths().iter().map(|p| p.trace().mean_over(t0, t1)).sum();
            let lat = bond
                .paths()
                .iter()
                .map(|p| p.latency())
                .fold(f64::INFINITY, f64::min);
            (bw, lat)
        }
        None => {
            let l = fabric.link(w);
            (l.trace().mean_over(t0, t1), l.latency())
        }
    }
}

/// The realized LAN-tier bottleneck `(a, b)` over `[t0, t1)`: min worker
/// bandwidth, max worker latency — the pair that actually gated the
/// synchronous aggregation, from the exact prefix integrals.
pub fn realized_lan_bottleneck(
    fabric: &Fabric,
    t0: f64,
    t1: f64,
) -> (f64, f64) {
    let mut a = f64::INFINITY;
    let mut b: f64 = 0.0;
    for w in 0..fabric.workers() {
        let (bw, lat) = worker_realized(fabric, w, t0, t1);
        a = a.min(bw);
        b = b.max(lat);
    }
    (a, b)
}

/// Hindsight-oracle verdict for one plan window.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WindowRegret {
    pub index: usize,
    /// realized bottleneck bandwidth over the window (bits/s)
    pub realized_a: f64,
    /// realized bottleneck latency over the window (s)
    pub realized_b: f64,
    /// oracle `(τ, δ)` re-solved against the realized window
    pub oracle_tau: usize,
    pub oracle_delta: f64,
    /// the oracle plan's steady-state round time (s/iter)
    pub oracle_round: f64,
    /// realized − oracle seconds per iteration
    pub regret: f64,
    /// governed iterations (weights the cumulative sum)
    pub iters: usize,
}

/// Per-window and cumulative hindsight regret.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RegretReport {
    pub windows: Vec<WindowRegret>,
    /// Σ regret · iters — seconds of makespan lost to imperfect plans
    pub cumulative: f64,
}

/// Re-solve every window against the bandwidth the fabric *realized*
/// over it (exact prefix-integral means — the PR-5 engine) and report
/// the executed plan's regret versus that hindsight oracle. Windows
/// without a [`ReplanRecord`] (streaming-fed) or with a degenerate
/// realized bottleneck are skipped.
pub fn oracle_regret(windows: &[PlanWindow], fabric: &Fabric) -> RegretReport {
    let mut rep = RegretReport::default();
    for w in windows {
        let Some(rec) = &w.rec else { continue };
        let (a, b) = realized_lan_bottleneck(fabric, w.t_start, w.t_end);
        if !(a.is_finite() && a > 0.0) {
            continue;
        }
        let inp = DecoInput {
            s_g: rec.lan.input.s_g,
            a,
            b,
            t_comp: rec.lan.input.t_comp,
        };
        let out = solve(&inp);
        let oracle_round = t_avg_closed_form(&PipelineParams {
            a,
            b,
            delta: out.delta,
            tau: out.tau,
            t_comp: inp.t_comp,
            s_g: inp.s_g,
        });
        let regret = w.realized() - oracle_round;
        rep.cumulative += regret * w.iters as f64;
        rep.windows.push(WindowRegret {
            index: w.index,
            realized_a: a,
            realized_b: b,
            oracle_tau: out.tau,
            oracle_delta: out.delta,
            oracle_round,
            regret,
            iters: w.iters,
        });
    }
    rep
}

// ---------------------------------------------------------------------------
// Loss audit (predicted vs realized message-loss rate)
// ---------------------------------------------------------------------------

/// Predicted-vs-realized message-loss rate for one plan window — the
/// lossy-transport analogue of the bandwidth calibration: the planner's
/// attempt-count EWMA ([`crate::netsim::NetworkMonitor::loss_rate`])
/// snapshotted at the re-plan, against the seeded loss processes' exact
/// mean rate over the window it governed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WindowLoss {
    pub index: usize,
    /// the planner's loss estimate at the re-plan (`None` when the
    /// strategy carried no loss model)
    pub predicted: Option<f64>,
    /// realized loss rate over the window: max over lossy workers of the
    /// process mean — the same worst-link convention the planner's
    /// fabric-level estimate folds over
    pub realized: f64,
    /// the aggregation deadline the plan armed (`None` = wait-for-all)
    pub deadline: Option<f64>,
}

/// Realized fabric-level loss rate over `[t0, t1)`: max over lossy
/// workers of each process's exact mean rate (burst windows included).
fn realized_loss_rate(fabric: &Fabric, t0: f64, t1: f64) -> f64 {
    (0..fabric.workers())
        .filter_map(|w| fabric.loss(w).map(|l| l.mean_rate_over(w as u32, t0, t1)))
        .fold(0.0, f64::max)
}

/// Score each window's loss prediction against the ground-truth process
/// means. Empty on a lossless fabric and for streaming-fed windows
/// (no [`ReplanRecord`] to read the prediction from).
pub fn loss_audit(windows: &[PlanWindow], fabric: &Fabric) -> Vec<WindowLoss> {
    if !fabric.has_loss() {
        return Vec::new();
    }
    windows
        .iter()
        .filter_map(|w| {
            let rec = w.rec.as_ref()?;
            Some(WindowLoss {
                index: w.index,
                predicted: rec.predicted_loss,
                realized: realized_loss_rate(fabric, w.t_start, w.t_end),
                deadline: rec.deadline,
            })
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Estimator calibration
// ---------------------------------------------------------------------------

/// Calibration of one estimator slot against ground truth, accumulated
/// over every window whose [`ReplanRecord`] snapshotted it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CalibrationRow {
    /// the slot's representative worker; `u32::MAX` on the aggregate row
    pub worker: u32,
    /// (window, slot) scores folded into this row
    pub samples: usize,
    /// mean estimated bandwidth (bits/s)
    pub mean_est: f64,
    /// mean ground-truth bandwidth over the windows (bits/s)
    pub mean_true: f64,
    /// mean signed bias: estimate − truth (bits/s)
    pub bias: f64,
    /// RMSE of estimate − truth (bits/s)
    pub rmse: f64,
    /// fraction of windows with |est − truth| ≤ 10% of truth
    pub coverage: f64,
    /// fraction of windows whose truth lay inside the worker's
    /// `[pessimistic, optimistic]` bandwidth band (degenerate — and so
    /// rarely covering — on single-path workers under a moving trace)
    pub band_coverage: f64,
    /// mean signed latency bias: estimate − truth (s)
    pub lat_bias: f64,
}

/// Per-slot rows (ascending representative worker) plus the aggregate.
#[derive(Clone, Debug, PartialEq)]
pub struct CalibrationReport {
    pub links: Vec<CalibrationRow>,
    /// every (window, slot) score folded together (`worker == u32::MAX`)
    pub all: CalibrationRow,
}

#[derive(Clone, Copy, Default)]
struct CalAcc {
    n: usize,
    est_sum: f64,
    true_sum: f64,
    err_sum: f64,
    err_sq_sum: f64,
    covered: usize,
    in_band: usize,
    lat_err_sum: f64,
}

impl CalAcc {
    fn row(&self, worker: u32) -> CalibrationRow {
        let n = self.n.max(1) as f64;
        CalibrationRow {
            worker,
            samples: self.n,
            mean_est: self.est_sum / n,
            mean_true: self.true_sum / n,
            bias: self.err_sum / n,
            rmse: (self.err_sq_sum / n).sqrt(),
            coverage: self.covered as f64 / n,
            band_coverage: self.in_band as f64 / n,
            lat_bias: self.lat_err_sum / n,
        }
    }
}

/// Score every estimator snapshot in the windows' [`ReplanRecord`]s
/// against the ground-truth trace means over the window each governed —
/// the estimates were made *at* `t_start` for the window ahead, so this
/// measures exactly the error the planner acted on. Slot-shared
/// estimates (class granularity) score once per slot against the
/// representative worker's links.
pub fn calibrate(windows: &[PlanWindow], fabric: &Fabric) -> CalibrationReport {
    let mut per: BTreeMap<u32, CalAcc> = BTreeMap::new();
    let mut all = CalAcc::default();
    for w in windows {
        let Some(rec) = &w.rec else { continue };
        for l in &rec.links {
            let (truth, lat_truth) =
                worker_realized(fabric, l.worker as usize, w.t_start, w.t_end);
            if !(truth.is_finite() && truth > 0.0) {
                continue;
            }
            let err = l.bw - truth;
            let (lo, hi) = (l.bw_pess.min(l.bw), l.bw_pess.max(l.bw));
            // single-path bands are zero-width, and the EWMA's observed
            // bits/secs differs from the prefix-integral mean by float
            // rounding even on a constant trace — bracket with relative
            // slack so the degenerate band still covers exact agreement
            let eps = 1e-9 * truth;
            for acc in [per.entry(l.worker).or_default(), &mut all] {
                acc.n += 1;
                acc.est_sum += l.bw;
                acc.true_sum += truth;
                acc.err_sum += err;
                acc.err_sq_sum += err * err;
                acc.covered += usize::from(err.abs() <= 0.1 * truth);
                acc.in_band +=
                    usize::from(lo - eps <= truth && truth <= hi + eps);
                acc.lat_err_sum += l.lat - lat_truth;
            }
        }
    }
    CalibrationReport {
        links: per.iter().map(|(&w, acc)| acc.row(w)).collect(),
        all: all.row(u32::MAX),
    }
}

// ---------------------------------------------------------------------------
// The full report (what `repro audit` prints and writes)
// ---------------------------------------------------------------------------

/// Plan audit + hindsight regret + estimator calibration for one traced
/// run, with deterministic table / CSV / JSON renderings.
#[derive(Clone, Debug)]
pub struct AuditReport {
    pub summary: AuditSummary,
    pub windows: Vec<PlanWindow>,
    pub regret: RegretReport,
    pub calibration: CalibrationReport,
    /// per-window predicted-vs-realized loss rates (empty when lossless)
    pub loss: Vec<WindowLoss>,
}

/// Run the buffered audit over a trace and score it against `fabric`
/// (the ground truth the run was priced on — rebuild it from the same
/// config; traces are seeded, so the sample paths replay identically).
pub fn audit_events(events: &[TraceEvent], fabric: &Fabric) -> AuditReport {
    let plan = PlanAudit::buffered(events);
    let windows = plan.windows().to_vec();
    let regret = oracle_regret(&windows, fabric);
    let calibration = calibrate(&windows, fabric);
    let loss = loss_audit(&windows, fabric);
    AuditReport { summary: *plan.summary(), windows, regret, calibration, loss }
}

impl AuditReport {
    /// The aligned plan-audit + calibration tables.
    pub fn table(&self) -> String {
        let s = &self.summary;
        let plan_rows = vec![
            vec!["plan windows".into(), s.windows.to_string()],
            vec!["governed iters".into(), s.iters.to_string()],
            vec![
                "audited range (s)".into(),
                format!("{:.6} .. {:.6}", s.first_t, s.last_t),
            ],
            vec![
                "mean predicted (s/iter)".into(),
                format!("{:.6}", s.mean_predicted()),
            ],
            vec![
                "mean realized (s/iter)".into(),
                format!("{:.6}", s.mean_realized()),
            ],
            vec!["plan bias (s/iter)".into(), format!("{:.6}", s.bias())],
            vec!["window rmse (s/iter)".into(), format!("{:.6}", s.rmse())],
            vec![
                "over / under windows".into(),
                format!("{} / {}", s.over, s.under),
            ],
            vec![
                "worst window".into(),
                format!("#{} ({:+.6} s/iter)", s.worst_index, s.worst_bias),
            ],
            vec![
                "oracle regret (s)".into(),
                format!("{:.6}", self.regret.cumulative),
            ],
        ];
        let mut plan_rows = plan_rows;
        if !self.loss.is_empty() {
            let n = self.loss.len() as f64;
            let realized = self.loss.iter().map(|l| l.realized).sum::<f64>() / n;
            let preds: Vec<f64> =
                self.loss.iter().filter_map(|l| l.predicted).collect();
            let predicted = if preds.is_empty() {
                "-".into()
            } else {
                format!(
                    "{:.4}",
                    preds.iter().sum::<f64>() / preds.len() as f64
                )
            };
            plan_rows.push(vec![
                "mean loss pred / real".into(),
                format!("{predicted} / {realized:.4}"),
            ]);
        }
        let mut out = format_table(&["plan audit", "value"], &plan_rows);
        let cal_rows: Vec<Vec<String>> = self
            .calibration
            .links
            .iter()
            .chain(std::iter::once(&self.calibration.all))
            .map(|r| {
                vec![
                    if r.worker == u32::MAX {
                        "all".into()
                    } else {
                        format!("w{}", r.worker)
                    },
                    r.samples.to_string(),
                    format!("{:.3}", r.mean_est / 1e6),
                    format!("{:.3}", r.mean_true / 1e6),
                    format!("{:+.3}", r.bias / 1e6),
                    format!("{:.3}", r.rmse / 1e6),
                    format!("{:.2}", r.coverage),
                    format!("{:.2}", r.band_coverage),
                    format!("{:+.4}", r.lat_bias),
                ]
            })
            .collect();
        out.push('\n');
        out.push_str(&format_table(
            &[
                "link",
                "wins",
                "est Mbps",
                "true Mbps",
                "bias",
                "rmse",
                "cov10%",
                "band",
                "lat bias s",
            ],
            &cal_rows,
        ));
        out
    }

    /// Deterministic per-window CSV (regret and loss columns joined by
    /// index; the loss columns are empty on a lossless fabric).
    pub fn csv(&self) -> String {
        let regret: BTreeMap<usize, &WindowRegret> =
            self.regret.windows.iter().map(|r| (r.index, r)).collect();
        let loss: BTreeMap<usize, &WindowLoss> =
            self.loss.iter().map(|l| (l.index, l)).collect();
        let mut out = String::from(
            "window,iter_first,iters,t_start,t_end,predicted,realized,bias,\
             rel_err,realized_a,oracle_tau,oracle_delta,oracle_round,regret,\
             predicted_loss,realized_loss,deadline\n",
        );
        for w in &self.windows {
            let r = regret.get(&w.index);
            let l = loss.get(&w.index);
            out.push_str(&format!(
                "{},{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{},{},{},{},{},\
                 {},{},{}\n",
                w.index,
                w.iter_first,
                w.iters,
                w.t_start,
                w.t_end,
                w.predicted,
                w.realized(),
                w.bias(),
                w.rel_err(),
                r.map_or("".into(), |r| format!("{:.6}", r.realized_a)),
                r.map_or("".into(), |r| r.oracle_tau.to_string()),
                r.map_or("".into(), |r| format!("{:.6}", r.oracle_delta)),
                r.map_or("".into(), |r| format!("{:.6}", r.oracle_round)),
                r.map_or("".into(), |r| format!("{:.6}", r.regret)),
                l.and_then(|l| l.predicted)
                    .map_or(String::new(), |p| format!("{p:.6}")),
                l.map_or(String::new(), |l| format!("{:.6}", l.realized)),
                l.and_then(|l| l.deadline)
                    .map_or(String::new(), |d| format!("{d:.6}")),
            ));
        }
        out
    }

    /// Canonical JSON (BTreeMap-ordered keys — byte-deterministic).
    pub fn json(&self) -> Json {
        let s = &self.summary;
        let cal: Vec<Json> = self
            .calibration
            .links
            .iter()
            .chain(std::iter::once(&self.calibration.all))
            .map(|r| {
                Json::obj(vec![
                    ("band_coverage", Json::num(r.band_coverage)),
                    ("bias", Json::num(r.bias)),
                    ("coverage", Json::num(r.coverage)),
                    ("lat_bias", Json::num(r.lat_bias)),
                    ("mean_est", Json::num(r.mean_est)),
                    ("mean_true", Json::num(r.mean_true)),
                    ("rmse", Json::num(r.rmse)),
                    ("samples", Json::num(r.samples as f64)),
                    (
                        "worker",
                        if r.worker == u32::MAX {
                            Json::str("all")
                        } else {
                            Json::num(r.worker as f64)
                        },
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("calibration", Json::arr(cal)),
            ("cumulative_regret", Json::num(self.regret.cumulative)),
            ("governed_iters", Json::num(s.iters as f64)),
            ("loss_windows", Json::num(self.loss.len() as f64)),
            ("mean_predicted", Json::num(s.mean_predicted())),
            ("mean_realized", Json::num(s.mean_realized())),
            ("plan_bias", Json::num(s.bias())),
            ("window_rmse", Json::num(s.rmse())),
            ("windows", Json::num(s.windows as f64)),
            ("worst_bias", Json::num(s.worst_bias)),
            ("worst_index", Json::num(s.worst_index as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::BandwidthTrace;
    use crate::obs::{TickTrace, TierReplan};

    fn rec(a: f64, b: f64, predicted: f64) -> ReplanRecord {
        ReplanRecord {
            lan: TierReplan {
                input: DecoInput { s_g: 1e8, a, b, t_comp: 0.2 },
                tau: 1,
                delta: 0.5,
                log_phi: -1.0,
            },
            wan: None,
            predicted_round: predicted,
            pessimistic: None,
            links: Vec::new(),
            predicted_loss: None,
            deadline: None,
        }
    }

    fn tick_ev(iter: usize, tc: f64) -> TraceEvent {
        TraceEvent::Tick(TickTrace {
            iter,
            ts: tc - 0.1,
            t_comp: 0.1,
            tc,
            workers: Vec::new(),
            regions: Vec::new(),
        })
    }

    fn replan_ev(t: f64, iter: usize, predicted: f64) -> TraceEvent {
        TraceEvent::Replan { t, iter, rec: rec(2e7, 0.2, predicted) }
    }

    #[test]
    fn windows_tile_and_summary_folds() {
        // replan@0 (pred 0.5) -> ticks at 0.6, 1.2; replan@1.2 (pred
        // 0.58) -> ticks at 1.8, 2.4, 3.1
        let events = vec![
            replan_ev(0.0, 1, 0.5),
            tick_ev(1, 0.6),
            tick_ev(2, 1.2),
            replan_ev(1.2, 3, 0.58),
            tick_ev(3, 1.8),
            tick_ev(4, 2.4),
            tick_ev(5, 3.1),
        ];
        let a = PlanAudit::buffered(&events);
        let ws = a.windows();
        assert_eq!(ws.len(), 2);
        assert_eq!((ws[0].iter_first, ws[0].iters), (1, 2));
        assert_eq!((ws[1].iter_first, ws[1].iters), (3, 3));
        // exact tiling: window 0 ends where window 1 starts, bitwise
        assert_eq!(ws[0].t_end.to_bits(), ws[1].t_start.to_bits());
        let s = a.summary();
        assert_eq!((s.windows, s.iters), (2, 5));
        assert_eq!(s.first_t, 0.0);
        assert_eq!(s.last_t, 3.1);
        assert!((s.real_time - 3.1).abs() < 1e-12);
        // window 0 realized 0.6 vs pred 0.5 (under-predicted); window 1
        // realized 1.9/3 vs 0.58 (over-predicted)
        assert!((ws[0].bias() - 0.1).abs() < 1e-12);
        assert!(ws[1].bias() < 0.0);
        assert_eq!((s.over, s.under), (1, 1));
        assert_eq!(s.worst_index, 0);
        assert!((s.worst_bias - 0.1).abs() < 1e-12);
        assert!(s.rmse() > 0.0);
    }

    #[test]
    fn streaming_matches_buffered_bitwise() {
        let mut events = vec![replan_ev(0.0, 1, 0.31)];
        let mut t = 0.0;
        for k in 1..=40usize {
            t += 0.3 + 0.01 * (k % 5) as f64;
            if k % 10 == 1 && k > 1 {
                events.push(replan_ev(t - 0.3, k, 0.3 + 0.002 * k as f64));
            }
            events.push(tick_ev(k, t));
        }
        let buffered = PlanAudit::buffered(&events);
        let mut streaming = PlanAudit::streaming();
        for ev in &events {
            streaming.record(ev);
        }
        streaming.finish();
        assert!(streaming.windows().is_empty(), "streaming keeps no windows");
        assert_eq!(streaming.summary(), buffered.summary());
        // bitwise, not just PartialEq on the floats
        assert_eq!(
            streaming.summary().real_time.to_bits(),
            buffered.summary().real_time.to_bits()
        );
        assert_eq!(
            streaming.summary().bias_sq_sum.to_bits(),
            buffered.summary().bias_sq_sum.to_bits()
        );
    }

    #[test]
    fn ticks_before_first_replan_and_empty_windows_are_dropped() {
        let events = vec![
            tick_ev(1, 0.5), // pre-plan: outside every window
            replan_ev(0.5, 2, 0.4),
            tick_ev(2, 0.9),
            replan_ev(0.9, 3, 0.4), // governs nothing (run ends)
        ];
        let a = PlanAudit::buffered(&events);
        assert_eq!(a.windows().len(), 1);
        assert_eq!(a.summary().iters, 1);
        assert_eq!(a.summary().first_t, 0.5);
        assert_eq!(a.summary().last_t, 0.9);
    }

    #[test]
    fn no_replans_is_a_vacuous_audit() {
        let events = vec![tick_ev(1, 0.5), tick_ev(2, 1.0)];
        let a = PlanAudit::buffered(&events);
        assert_eq!(a.summary(), &AuditSummary::default());
        assert!(a.windows().is_empty());
    }

    #[test]
    fn oracle_regret_is_zero_when_the_plan_was_perfect() {
        // constant 2e7 fabric; the plan solved on the true (a, b) and the
        // realized rounds hit T_comp exactly -> regret == 0
        let fabric =
            Fabric::homogeneous(2, BandwidthTrace::constant(2e7), 0.2);
        let inp = DecoInput { s_g: 1e8, a: 2e7, b: 0.2, t_comp: 0.2 };
        let out = solve(&inp);
        let pred = t_avg_closed_form(&PipelineParams {
            a: inp.a,
            b: inp.b,
            delta: out.delta,
            tau: out.tau,
            t_comp: inp.t_comp,
            s_g: inp.s_g,
        });
        assert!((pred - 0.2).abs() < 1e-12, "bubble-free at the optimum");
        let windows = vec![PlanWindow {
            index: 0,
            iter_first: 1,
            iters: 10,
            t_start: 1.0,
            t_end: 1.0 + 10.0 * pred,
            predicted: pred,
            rec: Some(rec(2e7, 0.2, pred)),
        }];
        let rep = oracle_regret(&windows, &fabric);
        assert_eq!(rep.windows.len(), 1);
        let w = &rep.windows[0];
        assert!((w.realized_a - 2e7).abs() < 1e-6);
        assert!((w.oracle_round - 0.2).abs() < 1e-12);
        assert!(w.regret.abs() < 1e-12, "regret {}", w.regret);
        assert!(rep.cumulative.abs() < 1e-9);
    }

    #[test]
    fn oracle_regret_charges_slow_realized_rounds() {
        let fabric =
            Fabric::homogeneous(2, BandwidthTrace::constant(2e7), 0.2);
        // same plan, but the realized window ran 50% slower than the
        // oracle round
        let windows = vec![PlanWindow {
            index: 0,
            iter_first: 1,
            iters: 10,
            t_start: 1.0,
            t_end: 4.0, // 0.3 s/iter vs oracle 0.2
            predicted: 0.2,
            rec: Some(rec(2e7, 0.2, 0.2)),
        }];
        let rep = oracle_regret(&windows, &fabric);
        assert!((rep.windows[0].regret - 0.1).abs() < 1e-9);
        assert!((rep.cumulative - 1.0).abs() < 1e-9);
    }

    #[test]
    fn calibration_scores_estimates_against_trace_means() {
        use crate::netsim::SlotEstimate;
        let fabric =
            Fabric::homogeneous(2, BandwidthTrace::constant(2e7), 0.2);
        let slot = |w: u32, bw: f64| SlotEstimate {
            worker: w,
            members: 1,
            bw,
            lat: 0.2,
            bw_pess: bw,
            lat_pess: 0.2,
        };
        let mut r = rec(2e7, 0.2, 0.2);
        // worker 0 estimates truth exactly; worker 1 is 25% high
        r.links = vec![slot(0, 2e7), slot(1, 2.5e7)];
        let windows = vec![PlanWindow {
            index: 0,
            iter_first: 1,
            iters: 10,
            t_start: 1.0,
            t_end: 3.0,
            predicted: 0.2,
            rec: Some(r),
        }];
        let cal = calibrate(&windows, &fabric);
        assert_eq!(cal.links.len(), 2);
        let (w0, w1) = (&cal.links[0], &cal.links[1]);
        assert_eq!((w0.worker, w1.worker), (0, 1));
        assert!(w0.bias.abs() < 1.0 && w0.coverage == 1.0);
        assert!(w0.band_coverage == 1.0, "exact estimate is in the band");
        assert!((w1.bias - 5e6).abs() < 1.0);
        assert_eq!(w1.coverage, 0.0);
        assert_eq!(w1.band_coverage, 0.0);
        let all = &cal.all;
        assert_eq!(all.samples, 2);
        assert!((all.bias - 2.5e6).abs() < 1.0);
        assert!((all.coverage - 0.5).abs() < 1e-12);
    }

    #[test]
    fn loss_audit_joins_predictions_with_process_means() {
        use crate::netsim::LossProcess;
        let mut fabric =
            Fabric::homogeneous(2, BandwidthTrace::constant(2e7), 0.2);
        fabric.set_loss(0, LossProcess::iid(0.3, 42));
        let mut r = rec(2e7, 0.2, 0.2);
        r.predicted_loss = Some(0.25);
        r.deadline = Some(2.0);
        let windows = vec![
            PlanWindow {
                index: 0,
                iter_first: 1,
                iters: 10,
                t_start: 1.0,
                t_end: 3.0,
                predicted: 0.2,
                rec: Some(r),
            },
            PlanWindow {
                index: 1,
                iter_first: 11,
                iters: 5,
                t_start: 3.0,
                t_end: 4.0,
                predicted: 0.2,
                rec: None,
            },
        ];
        let audit = loss_audit(&windows, &fabric);
        assert_eq!(audit.len(), 1, "record-less windows are skipped");
        let l = &audit[0];
        assert_eq!(l.index, 0);
        assert_eq!(l.predicted, Some(0.25));
        assert!(
            (l.realized - 0.3).abs() < 1e-12,
            "i.i.d. mean rate is the base rate, got {}",
            l.realized
        );
        assert_eq!(l.deadline, Some(2.0));
        // lossless fabric -> vacuous loss audit
        let clean =
            Fabric::homogeneous(2, BandwidthTrace::constant(2e7), 0.2);
        assert!(loss_audit(&windows, &clean).is_empty());
    }

    #[test]
    fn report_renders_deterministically() {
        let fabric =
            Fabric::homogeneous(2, BandwidthTrace::constant(2e7), 0.2);
        let events = vec![
            replan_ev(0.0, 1, 0.5),
            tick_ev(1, 0.6),
            tick_ev(2, 1.2),
            replan_ev(1.2, 3, 0.58),
            tick_ev(3, 1.8),
        ];
        let a = audit_events(&events, &fabric);
        let b = audit_events(&events, &fabric);
        assert_eq!(a.csv(), b.csv());
        assert_eq!(a.json().to_string(), b.json().to_string());
        assert_eq!(a.table(), b.table());
        assert!(a.table().contains("plan bias"));
        assert!(a.csv().lines().count() == 3, "header + 2 windows");
        // the loss columns exist but stay empty on a lossless fabric
        let header = a.csv().lines().next().unwrap().to_string();
        assert!(header.ends_with("predicted_loss,realized_loss,deadline"));
        assert!(a.loss.is_empty());
        assert!(!a.table().contains("mean loss pred / real"));
        let parsed = Json::parse(&a.json().to_string()).unwrap();
        assert_eq!(parsed.to_string(), a.json().to_string());
    }
}
