//! Observability — deterministic, virtual-time-keyed structured tracing
//! (DESIGN.md §Observability).
//!
//! The paper's whole argument is about *where time goes* — compression
//! cost vs. transfer time vs. staleness-amplified error — yet a CSV of
//! `(iter, loss, time)` rows can't show how much of a run's makespan was
//! compute, LAN transfer, WAN transfer, or slowest-worker wait, nor why
//! DeCo picked a particular `(δ, τ)` at each re-plan. This module is that
//! missing layer:
//!
//! * a [`TraceSink`] trait with a zero-overhead [`NullSink`] default —
//!   every emission site in the training loop is guarded by
//!   [`TraceSink::enabled`], so disabled tracing never builds an event;
//! * typed events ([`TraceEvent`]): per-worker per-iteration phase spans
//!   ([`TickTrace`], derived from the exact per-link arrival times the
//!   clock already computes), per-path transfer spans on bonded links,
//!   churn events from `elastic`, class split / aggregator-election
//!   events from the shared-timeline class engine ([`ClockEvent`]), and a
//!   re-plan decision log from `strategy` ([`ReplanRecord`]);
//! * two exporters: Chrome/Perfetto trace-event JSON
//!   ([`perfetto_trace`] — spans on virtual time, one track per worker /
//!   region / path) and the streaming stall-[`Attribution`] report
//!   (per-phase totals whose sum equals the run's makespan exactly).
//!
//! Determinism contract: every timestamp is **virtual** (the clock's
//! Eq.-19 recurrence), never wall clock, so a traced run serializes
//! byte-identically across reruns and worker-pool sizes. The Perfetto
//! export goes through [`crate::util::Json`] (BTreeMap-ordered keys) to
//! keep the bytes canonical.

use crate::deco::DecoInput;
use crate::elastic::ChurnEvent;
use crate::metrics::format_table;
use crate::netsim::{Fabric, SlotEstimate};
use crate::util::Json;
use std::collections::BTreeSet;

pub mod audit;

pub use audit::{
    audit_events, calibrate, loss_audit, oracle_regret,
    realized_lan_bottleneck, AuditReport, AuditSummary, CalibrationReport,
    CalibrationRow, PlanAudit, PlanWindow, RegretReport, WindowLoss,
    WindowRegret,
};

// ---------------------------------------------------------------------------
// Span taxonomy
// ---------------------------------------------------------------------------

/// One phase of the per-iteration timeline (DESIGN.md §Observability).
///
/// A worker's iteration tiles into the first five phases; in the two-tier
/// topology the winning region's partial then rides the WAN phases. The
/// stall-attribution chain relabels terminal aggregation wait as
/// [`Phase::StragglerWait`] — time the *fastest* chain spent waiting on
/// everyone else.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// forward/backward + compress + error-feedback compute
    Compute,
    /// gradient ready but the (shared) uplink is still busy
    QueueWait,
    /// failed attempts + backoff gaps before the final (successful)
    /// transmission attempt started (lossy transport,
    /// DESIGN.md §Robustness); carved out of the tail of `QueueWait`
    Retransmit,
    /// bits on the LAN wire (bonded workers: the water-filled window)
    LanTransfer,
    /// end-to-end link latency `b`
    Propagation,
    /// arrived; waiting for the tick's slowest worker
    AggWait,
    /// region partial waits for its slowest member
    RegionSyncWait,
    /// region partial ready but the WAN uplink is still busy
    WanQueue,
    /// bits on the WAN wire
    WanTransfer,
    /// WAN end-to-end latency
    WanPropagation,
    /// region partial arrived; waiting for the slowest region
    WanAggWait,
    /// attribution only: the fastest chain waiting on stragglers
    StragglerWait,
}

impl Phase {
    /// Number of phases (sizes the attribution accumulator).
    pub const COUNT: usize = 12;

    /// All phases, in taxonomy order.
    pub const ALL: [Phase; Phase::COUNT] = [
        Phase::Compute,
        Phase::QueueWait,
        Phase::Retransmit,
        Phase::LanTransfer,
        Phase::Propagation,
        Phase::AggWait,
        Phase::RegionSyncWait,
        Phase::WanQueue,
        Phase::WanTransfer,
        Phase::WanPropagation,
        Phase::WanAggWait,
        Phase::StragglerWait,
    ];

    /// Stable display name (also the Perfetto event name).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            Phase::QueueWait => "queue_wait",
            Phase::Retransmit => "retransmit",
            Phase::LanTransfer => "lan_transfer",
            Phase::Propagation => "propagation",
            Phase::AggWait => "agg_wait",
            Phase::RegionSyncWait => "region_sync_wait",
            Phase::WanQueue => "wan_queue",
            Phase::WanTransfer => "wan_transfer",
            Phase::WanPropagation => "wan_propagation",
            Phase::WanAggWait => "wan_agg_wait",
            Phase::StragglerWait => "straggler_wait",
        }
    }

    fn index(self) -> usize {
        Phase::ALL.iter().position(|&p| p == self).unwrap()
    }
}

/// A half-open `[t0, t1)` phase interval on the virtual timeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Span {
    pub phase: Phase,
    pub t0: f64,
    pub t1: f64,
}

impl Span {
    pub fn dur(&self) -> f64 {
        self.t1 - self.t0
    }
}

/// Build the five worker-phase spans from raw tick boundaries, forcing
/// the boundary sequence monotone (float rounding can put `tm − tx` a
/// hair before `ts`; bonded workers legitimately report per-path busy
/// seconds that sum past the window, collapsing `QueueWait` to zero
/// width). The spans always tile `[compute_start, tc_k]` contiguously.
pub fn worker_spans(
    compute_start: f64,
    ts: f64,
    start: f64,
    tm: f64,
    tc_w: f64,
    tc_k: f64,
) -> [Span; 5] {
    let mut b = [compute_start, ts, start, tm, tc_w, tc_k];
    for i in 1..b.len() {
        b[i] = b[i].max(b[i - 1]);
    }
    let phases = [
        Phase::Compute,
        Phase::QueueWait,
        Phase::LanTransfer,
        Phase::Propagation,
        Phase::AggWait,
    ];
    std::array::from_fn(|i| Span { phase: phases[i], t0: b[i], t1: b[i + 1] })
}

/// One path of a bonded worker's transfer window (detail under the
/// worker's `LanTransfer` span; water-filling means paths overlap).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PathSpanRec {
    pub path: u32,
    /// fractional water-filling share carried by this path
    pub bits: f64,
    pub t0: f64,
    pub t1: f64,
}

/// One worker's fully-tiled iteration timeline.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerTrace {
    pub worker: u32,
    /// region id in the two-tier topology, `None` on a flat fabric
    pub region: Option<u32>,
    /// aggregators don't send on the LAN: their middle spans are empty
    pub aggregator: bool,
    pub spans: [Span; 5],
    /// seconds of the `QueueWait` span's tail spent on failed
    /// transmission attempts + backoff gaps (lossy transport); 0 on
    /// lossless links. Exporters carve this out as [`Phase::Retransmit`]
    /// via [`split_retransmit`], keeping the tiling exact.
    pub retx_secs: f64,
    /// per-path windows for bonded workers (empty on single-path links)
    pub paths: Vec<PathSpanRec>,
}

/// Split a `QueueWait` span into (queue proper, retransmit tail): the
/// final attempt started at `span.t1`, so the `retx` seconds of failed
/// attempts + backoff immediately precede it. Clamped so both halves stay
/// inside the original span — the tiling invariant is preserved exactly.
pub fn split_retransmit(span: Span, retx: f64) -> (Span, Span) {
    debug_assert_eq!(span.phase, Phase::QueueWait);
    let mid = (span.t1 - retx.max(0.0)).clamp(span.t0, span.t1);
    (
        Span { phase: Phase::QueueWait, t0: span.t0, t1: mid },
        Span { phase: Phase::Retransmit, t0: mid, t1: span.t1 },
    )
}

/// One region's WAN timeline boundaries for a tick (two-tier only).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RegionTrace {
    pub region: u32,
    /// slowest member arrival (`RegionSyncWait` ends here)
    pub sync: f64,
    /// WAN wire becomes free for this region's partial
    pub wan_start: f64,
    /// WAN transmission ends
    pub wan_tm: f64,
    /// WAN arrival (`wan_tm` + WAN latency)
    pub wan_tc: f64,
    /// active members whose gradients fed the partial
    pub senders: usize,
}

impl RegionTrace {
    /// The region's five WAN-phase spans, tiling `[ts, tc]`.
    pub fn spans(&self, ts: f64, tc: f64) -> [Span; 5] {
        let mut b =
            [ts, self.sync, self.wan_start, self.wan_tm, self.wan_tc, tc];
        for i in 1..b.len() {
            b[i] = b[i].max(b[i - 1]);
        }
        let phases = [
            Phase::RegionSyncWait,
            Phase::WanQueue,
            Phase::WanTransfer,
            Phase::WanPropagation,
            Phase::WanAggWait,
        ];
        std::array::from_fn(|i| Span {
            phase: phases[i],
            t0: b[i],
            t1: b[i + 1],
        })
    }
}

/// Everything the clock resolved for one training iteration.
#[derive(Clone, Debug, PartialEq)]
pub struct TickTrace {
    pub iter: usize,
    /// send-ready time `TS_k` (compute started at `ts − t_comp`)
    pub ts: f64,
    pub t_comp: f64,
    /// global arrival `TC_k` — the tournament winner
    pub tc: f64,
    /// active workers only, ascending by id
    pub workers: Vec<WorkerTrace>,
    /// active regions only (two-tier), ascending by id
    pub regions: Vec<RegionTrace>,
}

// ---------------------------------------------------------------------------
// Control-plane events
// ---------------------------------------------------------------------------

/// Structural events from the shared-timeline class engine
/// (DESIGN.md §Perf): class splits and aggregator elections.
#[derive(Clone, Debug, PartialEq)]
pub enum ClockEvent {
    /// `members` workers split out of `from_class` into `new_class`
    ClassSplit {
        from_class: usize,
        new_class: usize,
        members: usize,
        active: bool,
    },
    /// a region elected a new aggregator (churn-composed re-election)
    AggregatorElected { region: u32, old: Option<u32>, new: u32 },
    /// a worker's message needed `attempts` transmission attempts; the
    /// failed ones + backoff gaps cost `retx_secs` (DESIGN.md §Robustness)
    Retransmit { worker: u32, attempts: u32, retx_secs: f64 },
    /// the aggregation deadline cut this sync at `cut` with `late`
    /// arrivals still in flight
    DeadlineCut { cut: f64, late: usize },
    /// a gradient that missed an earlier deadline was absorbed into this
    /// round's aggregation at +1 staleness
    LateAbsorb { worker: u32 },
}

/// One tier of a DeCo re-plan: the monitor inputs the solver saw and the
/// `(τ, δ, ln φ)` it chose.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TierReplan {
    pub input: DecoInput,
    pub tau: usize,
    pub delta: f64,
    pub log_phi: f64,
}

/// A re-plan decision: per-tier solves plus the closed-form predicted
/// round time (`timesim::model::t_avg_closed_form` on the LAN tier).
#[derive(Clone, Debug, PartialEq)]
pub struct ReplanRecord {
    pub lan: TierReplan,
    /// WAN tier in the two-tier topology
    pub wan: Option<TierReplan>,
    /// solver-predicted steady-state seconds per iteration
    pub predicted_round: f64,
    /// pessimistic `(a, b)` aggregate at the solve instant — min path
    /// bandwidth / max path latency per bonded worker, bottlenecked over
    /// workers. Diverges from the optimistic `lan.input` view only when a
    /// worker is bonded; the audit layer reports when the optimistic bond
    /// view misled the plan (DESIGN.md §Observability).
    pub pessimistic: Option<(f64, f64)>,
    /// per-slot estimator snapshot at the solve instant — what the
    /// calibration layer scores against ground-truth trace means
    pub links: Vec<SlotEstimate>,
    /// the loss rate the planner assumed (loss-aware DeCo only; `None`
    /// for loss-blind strategies) — the audit layer scores it against the
    /// realized rate from the fabric's loss processes
    pub predicted_loss: Option<f64>,
    /// the aggregation deadline the plan set (`None` = wait-for-all)
    pub deadline: Option<f64>,
}

/// A typed trace event on the virtual timeline.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    Tick(TickTrace),
    Churn { t: f64, iter: usize, event: ChurnEvent },
    Clock { t: f64, iter: usize, event: ClockEvent },
    Replan { t: f64, iter: usize, rec: ReplanRecord },
}

// ---------------------------------------------------------------------------
// Sinks
// ---------------------------------------------------------------------------

/// Receives trace events from the training loop. Emission sites must
/// guard event *construction* behind [`TraceSink::enabled`] so the
/// [`NullSink`] keeps the hot path allocation- and branch-cheap.
pub trait TraceSink {
    /// `false` ⇒ the caller must skip building events entirely.
    fn enabled(&self) -> bool {
        true
    }
    fn record(&mut self, ev: &TraceEvent);
}

/// The zero-overhead default: reports disabled, drops everything.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn enabled(&self) -> bool {
        false
    }
    fn record(&mut self, _ev: &TraceEvent) {}
}

/// Buffers every event in memory (exporters consume the buffer).
#[derive(Clone, Debug, Default)]
pub struct BufferTracer {
    events: Vec<TraceEvent>,
}

impl BufferTracer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn into_events(self) -> Vec<TraceEvent> {
        self.events
    }
}

impl TraceSink for BufferTracer {
    fn record(&mut self, ev: &TraceEvent) {
        self.events.push(ev.clone());
    }
}

// ---------------------------------------------------------------------------
// Stall attribution
// ---------------------------------------------------------------------------

/// Streaming stall-attribution accumulator (DESIGN.md §Observability).
///
/// Decomposes the run's makespan into per-phase totals by walking, each
/// tick, the *fastest* chain — the fastest worker on a flat fabric; the
/// fastest member of the fastest region in the two-tier topology — and
/// relabeling its terminal aggregation wait [`Phase::StragglerWait`].
/// Because the chain's bottom (`ts − t_comp`) never exceeds the running
/// arrival horizon and its pieces tile contiguously up to `TC_k`, the
/// clipped per-phase totals sum *exactly* to the final horizon (the
/// makespan), even when churn makes `TC_k` non-monotone.
#[derive(Clone, Debug, Default)]
pub struct Attribution {
    totals: [f64; Phase::COUNT],
    /// running max of `TC_k` — equals the makespan after the last tick
    horizon: f64,
    ticks: usize,
}

impl Attribution {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `[t0, t1)` clipped against the horizon (pieces already swept
    /// by an earlier, slower tick contribute nothing).
    fn add(&mut self, phase: Phase, t0: f64, t1: f64) {
        let lo = t0.max(self.horizon);
        if t1 > lo {
            self.totals[phase.index()] += t1 - lo;
        }
    }

    /// Attribute one tick from its full [`TickTrace`].
    pub fn record_tick(&mut self, tk: &TickTrace) {
        if tk.regions.is_empty() {
            match fastest_worker(&tk.workers, None, false) {
                Some(w) => {
                    for s in &w.spans[..4] {
                        if s.phase == Phase::QueueWait && w.retx_secs > 0.0 {
                            let (q, r) = split_retransmit(*s, w.retx_secs);
                            self.add(q.phase, q.t0, q.t1);
                            self.add(r.phase, r.t0, r.t1);
                        } else {
                            self.add(s.phase, s.t0, s.t1);
                        }
                    }
                    let last = &w.spans[4];
                    self.add(Phase::StragglerWait, last.t0, last.t1);
                }
                // no active senders: the whole window is a stall
                None => self.add(Phase::StragglerWait, self.horizon, tk.tc),
            }
        } else {
            let r = tk
                .regions
                .iter()
                .min_by(|x, y| {
                    (x.wan_tc, x.region)
                        .partial_cmp(&(y.wan_tc, y.region))
                        .unwrap()
                })
                .unwrap();
            // fastest *sending* member of the fastest region; an
            // aggregator-only region contributes its aggregator's
            // compute span and chains from `ts`
            let m = fastest_worker(&tk.workers, Some(r.region), false)
                .or_else(|| fastest_worker(&tk.workers, Some(r.region), true));
            let tc_m = match m {
                Some(w) => {
                    for s in &w.spans[..4] {
                        if s.phase == Phase::QueueWait && w.retx_secs > 0.0 {
                            let (q, r) = split_retransmit(*s, w.retx_secs);
                            self.add(q.phase, q.t0, q.t1);
                            self.add(r.phase, r.t0, r.t1);
                        } else {
                            self.add(s.phase, s.t0, s.t1);
                        }
                    }
                    w.spans[3].t1
                }
                None => tk.ts,
            };
            self.add(Phase::RegionSyncWait, tc_m, r.sync.max(tc_m));
            let chain = [
                (Phase::WanQueue, r.sync.max(tc_m), r.wan_start),
                (Phase::WanTransfer, r.wan_start, r.wan_tm),
                (Phase::WanPropagation, r.wan_tm, r.wan_tc),
                (Phase::StragglerWait, r.wan_tc, tk.tc),
            ];
            let mut lo = r.sync.max(tc_m);
            for (phase, t0, t1) in chain {
                lo = lo.max(t0);
                let hi = t1.max(lo);
                self.add(phase, lo, hi);
                lo = hi;
            }
        }
        self.horizon = self.horizon.max(tk.tc);
        self.ticks += 1;
    }

    /// O(1) flat-fabric path for the 100k-worker sweeps: attribute one
    /// tick straight from the fastest worker's raw boundaries (as
    /// returned by the clock), skipping the [`TickTrace`] build.
    pub fn record_flat(
        &mut self,
        ts: f64,
        t_comp: f64,
        tm: f64,
        tc_w: f64,
        tx_secs: f64,
        retx_secs: f64,
        tc: f64,
    ) {
        let start = (tm - tx_secs).max(ts).min(tm);
        let spans = worker_spans(ts - t_comp, ts, start, tm, tc_w, tc);
        for s in &spans[..4] {
            if s.phase == Phase::QueueWait && retx_secs > 0.0 {
                let (q, r) = split_retransmit(*s, retx_secs);
                self.add(q.phase, q.t0, q.t1);
                self.add(r.phase, r.t0, r.t1);
            } else {
                self.add(s.phase, s.t0, s.t1);
            }
        }
        self.add(Phase::StragglerWait, spans[4].t0, spans[4].t1);
        self.horizon = self.horizon.max(tc);
        self.ticks += 1;
    }

    /// Seconds attributed to one phase.
    pub fn total(&self, phase: Phase) -> f64 {
        self.totals[phase.index()]
    }

    /// The run's makespan: the running max of tick arrivals.
    pub fn makespan(&self) -> f64 {
        self.horizon
    }

    pub fn ticks(&self) -> usize {
        self.ticks
    }

    /// Sum of all per-phase totals (equals [`Self::makespan`] up to
    /// float accumulation).
    pub fn attributed(&self) -> f64 {
        self.totals.iter().sum()
    }

    /// Fraction of the makespan spent in `phase` (0 on an empty run).
    pub fn fraction(&self, phase: Phase) -> f64 {
        if self.horizon > 0.0 {
            self.total(phase) / self.horizon
        } else {
            0.0
        }
    }

    /// Fraction waiting on stragglers (terminal wait + region sync).
    pub fn straggler_fraction(&self) -> f64 {
        self.fraction(Phase::StragglerWait)
            + self.fraction(Phase::RegionSyncWait)
    }

    /// Fraction on the wire (queue + transfer + propagation, both tiers).
    pub fn transfer_fraction(&self) -> f64 {
        [
            Phase::QueueWait,
            Phase::LanTransfer,
            Phase::Propagation,
            Phase::WanQueue,
            Phase::WanTransfer,
            Phase::WanPropagation,
        ]
        .iter()
        .map(|&p| self.fraction(p))
        .sum()
    }

    /// Fraction computing (forward/backward + compress + EF).
    pub fn compute_fraction(&self) -> f64 {
        self.fraction(Phase::Compute)
    }

    /// Fraction of the makespan the gating chain spent on failed
    /// transmission attempts + backoff (0 on lossless runs) —
    /// the headline robustness figure (DESIGN.md §Robustness).
    pub fn retransmit_fraction(&self) -> f64 {
        self.fraction(Phase::Retransmit)
    }

    /// The stall-attribution report as an aligned text table.
    pub fn table(&self) -> String {
        let mut rows: Vec<Vec<String>> = Phase::ALL
            .iter()
            .filter(|&&p| !matches!(p, Phase::AggWait | Phase::WanAggWait))
            .map(|&p| {
                vec![
                    p.name().to_string(),
                    format!("{:.6}", self.total(p)),
                    format!("{:.4}", self.fraction(p)),
                ]
            })
            .collect();
        rows.push(vec![
            "makespan".to_string(),
            format!("{:.6}", self.horizon),
            format!("{:.4}", if self.horizon > 0.0 { 1.0 } else { 0.0 }),
        ]);
        format_table(&["phase", "seconds", "fraction"], &rows)
    }
}

impl TraceSink for Attribution {
    fn record(&mut self, ev: &TraceEvent) {
        if let TraceEvent::Tick(tk) = ev {
            self.record_tick(tk);
        }
    }
}

/// Min by `(propagation-end, worker id)` over (optionally) one region's
/// senders or aggregators.
fn fastest_worker<'a>(
    workers: &'a [WorkerTrace],
    region: Option<u32>,
    aggregator: bool,
) -> Option<&'a WorkerTrace> {
    workers
        .iter()
        .filter(|w| region.is_none() || w.region == region)
        .filter(|w| w.aggregator == aggregator)
        .min_by(|x, y| {
            (x.spans[3].t1, x.worker)
                .partial_cmp(&(y.spans[3].t1, y.worker))
                .unwrap()
        })
}

// ---------------------------------------------------------------------------
// Perfetto export
// ---------------------------------------------------------------------------

const PID_WORKERS: f64 = 0.0;
const PID_REGIONS: f64 = 1.0;
const PID_CONTROL: f64 = 2.0;
const PID_PATHS: f64 = 3.0;

fn us(t: f64) -> Json {
    Json::num(t * 1e6)
}

fn meta(name: &str, pid: f64, tid: Option<f64>, label: &str) -> Json {
    let mut pairs = vec![
        ("args", Json::obj(vec![("name", Json::str(label))])),
        ("name", Json::str(name)),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid)),
    ];
    if let Some(tid) = tid {
        pairs.push(("tid", Json::num(tid)));
    }
    Json::obj(pairs)
}

fn complete(
    name: &str,
    cat: &str,
    pid: f64,
    tid: f64,
    t0: f64,
    t1: f64,
    args: Json,
) -> Json {
    Json::obj(vec![
        ("args", args),
        ("cat", Json::str(cat)),
        ("dur", us(t1 - t0)),
        ("name", Json::str(name)),
        ("ph", Json::str("X")),
        ("pid", Json::num(pid)),
        ("tid", Json::num(tid)),
        ("ts", us(t0)),
    ])
}

fn instant(name: &str, cat: &str, tid: f64, t: f64, args: Json) -> Json {
    Json::obj(vec![
        ("args", args),
        ("cat", Json::str(cat)),
        ("name", Json::str(name)),
        ("ph", Json::str("i")),
        ("pid", Json::num(PID_CONTROL)),
        ("s", Json::str("t")),
        ("tid", Json::num(tid)),
        ("ts", us(t)),
    ])
}

fn tier_args(prefix: &str, t: &TierReplan, pairs: &mut Vec<(String, Json)>) {
    pairs.push((format!("{prefix}a"), Json::num(t.input.a)));
    pairs.push((format!("{prefix}b"), Json::num(t.input.b)));
    pairs.push((format!("{prefix}delta"), Json::num(t.delta)));
    pairs.push((format!("{prefix}log_phi"), Json::num(t.log_phi)));
    pairs.push((format!("{prefix}s_g"), Json::num(t.input.s_g)));
    pairs.push((format!("{prefix}t_comp"), Json::num(t.input.t_comp)));
    pairs.push((format!("{prefix}tau"), Json::num(t.tau as f64)));
}

/// A `"ph":"C"` counter sample on the control process — Perfetto renders
/// each `name` as a counter track with one series per args key.
fn counter(name: &str, tid: f64, t: f64, args: Json) -> Json {
    Json::obj(vec![
        ("args", args),
        ("cat", Json::str("audit")),
        ("name", Json::str(name)),
        ("ph", Json::str("C")),
        ("pid", Json::num(PID_CONTROL)),
        ("tid", Json::num(tid)),
        ("ts", us(t)),
    ])
}

/// Export a trace as Chrome/Perfetto trace-event JSON: `"ph":"X"`
/// complete spans on virtual time (µs), one track per worker (pid 0),
/// region (pid 1), and bonded path (pid 3); churn / class / re-plan
/// instants plus the plan-audit counter tracks on the control process
/// (pid 2). Output bytes are canonical: fixed emission order + BTreeMap
/// key order.
pub fn perfetto_trace(events: &[TraceEvent]) -> Json {
    perfetto_events(events, None)
}

/// [`perfetto_trace`] plus a ground-truth series in the estimator
/// counter track: the realized bottleneck bandwidth over each plan
/// window, computed from the fabric's exact prefix integrals
/// ([`realized_lan_bottleneck`]). `fabric` must be (a rebuild of) the
/// fabric the traced run priced — traces are seeded, so rebuilding from
/// the same config replays the identical sample paths.
pub fn perfetto_audit_trace(events: &[TraceEvent], fabric: &Fabric) -> Json {
    perfetto_events(events, Some(fabric))
}

fn perfetto_events(events: &[TraceEvent], truth: Option<&Fabric>) -> Json {
    let mut workers: BTreeSet<u32> = BTreeSet::new();
    let mut regions: BTreeSet<u32> = BTreeSet::new();
    let mut bonded: BTreeSet<u32> = BTreeSet::new();
    for ev in events {
        if let TraceEvent::Tick(tk) = ev {
            for w in &tk.workers {
                workers.insert(w.worker);
                if !w.paths.is_empty() {
                    bonded.insert(w.worker);
                }
            }
            for r in &tk.regions {
                regions.insert(r.region);
            }
        }
    }

    let mut out: Vec<Json> = Vec::new();
    out.push(meta("process_name", PID_WORKERS, None, "workers"));
    for &w in &workers {
        out.push(meta(
            "thread_name",
            PID_WORKERS,
            Some(w as f64),
            &format!("worker {w}"),
        ));
    }
    if !regions.is_empty() {
        out.push(meta("process_name", PID_REGIONS, None, "regions"));
        for &r in &regions {
            out.push(meta(
                "thread_name",
                PID_REGIONS,
                Some(r as f64),
                &format!("region {r}"),
            ));
        }
    }
    out.push(meta("process_name", PID_CONTROL, None, "control"));
    for (tid, label) in [
        (0.0, "churn"),
        (1.0, "classes"),
        (2.0, "replan"),
        (3.0, "plan audit"),
        (4.0, "estimator"),
    ] {
        out.push(meta("thread_name", PID_CONTROL, Some(tid), label));
    }
    if !bonded.is_empty() {
        out.push(meta("process_name", PID_PATHS, None, "bond paths"));
        for &w in &bonded {
            out.push(meta(
                "thread_name",
                PID_PATHS,
                Some(w as f64),
                &format!("worker {w} paths"),
            ));
        }
    }

    for ev in events {
        match ev {
            TraceEvent::Tick(tk) => {
                let iter_args =
                    Json::obj(vec![("iter", Json::num(tk.iter as f64))]);
                for w in &tk.workers {
                    let mut emit: Vec<Span> = Vec::with_capacity(6);
                    for s in &w.spans {
                        if s.phase == Phase::QueueWait && w.retx_secs > 0.0 {
                            let (q, r) = split_retransmit(*s, w.retx_secs);
                            emit.push(q);
                            emit.push(r);
                        } else {
                            emit.push(*s);
                        }
                    }
                    for s in &emit {
                        if s.t1 > s.t0 {
                            out.push(complete(
                                s.phase.name(),
                                "worker",
                                PID_WORKERS,
                                w.worker as f64,
                                s.t0,
                                s.t1,
                                iter_args.clone(),
                            ));
                        }
                    }
                    for p in &w.paths {
                        if p.t1 > p.t0 {
                            out.push(complete(
                                &format!("path {}", p.path),
                                "path",
                                PID_PATHS,
                                w.worker as f64,
                                p.t0,
                                p.t1,
                                Json::obj(vec![
                                    ("bits", Json::num(p.bits)),
                                    ("iter", Json::num(tk.iter as f64)),
                                ]),
                            ));
                        }
                    }
                }
                for r in &tk.regions {
                    for s in &r.spans(tk.ts, tk.tc) {
                        if s.t1 > s.t0 {
                            out.push(complete(
                                s.phase.name(),
                                "region",
                                PID_REGIONS,
                                r.region as f64,
                                s.t0,
                                s.t1,
                                iter_args.clone(),
                            ));
                        }
                    }
                }
            }
            TraceEvent::Churn { t, iter, event } => {
                out.push(instant(
                    &format!("{event:?}"),
                    "churn",
                    0.0,
                    *t,
                    Json::obj(vec![("iter", Json::num(*iter as f64))]),
                ));
            }
            TraceEvent::Clock { t, iter, event } => {
                out.push(instant(
                    &format!("{event:?}"),
                    "clock",
                    1.0,
                    *t,
                    Json::obj(vec![("iter", Json::num(*iter as f64))]),
                ));
            }
            TraceEvent::Replan { t, iter, rec } => {
                let mut pairs: Vec<(String, Json)> = vec![
                    ("iter".to_string(), Json::num(*iter as f64)),
                    (
                        "predicted_round".to_string(),
                        Json::num(rec.predicted_round),
                    ),
                ];
                tier_args("lan_", &rec.lan, &mut pairs);
                if let Some(w) = &rec.wan {
                    tier_args("wan_", w, &mut pairs);
                }
                let args = Json::Obj(pairs.into_iter().collect());
                out.push(instant("replan", "replan", 2.0, *t, args));
            }
        }
    }

    // plan-audit counter tracks (pid 2, tids 3/4): one predicted-vs-
    // realized sample per closed plan window at the window's open
    // instant, and the estimate-vs-truth bandwidth band next to it. A
    // trace without re-plans (or whose re-plans governed no tick) emits
    // no counters.
    let plan = PlanAudit::buffered(events);
    for w in plan.windows() {
        out.push(counter(
            "round s/iter",
            3.0,
            w.t_start,
            Json::obj(vec![
                ("predicted", Json::num(w.predicted)),
                ("realized", Json::num(w.realized())),
            ]),
        ));
        let Some(rec) = &w.rec else { continue };
        let mut pairs: Vec<(String, Json)> =
            vec![("est".to_string(), Json::num(rec.lan.input.a / 1e6))];
        if let Some((bw, _)) = rec.pessimistic {
            pairs.push(("pess".to_string(), Json::num(bw / 1e6)));
        }
        if let Some(fabric) = truth {
            let (a, _) = realized_lan_bottleneck(fabric, w.t_start, w.t_end);
            pairs.push(("true".to_string(), Json::num(a / 1e6)));
        }
        let args = Json::Obj(pairs.into_iter().collect());
        out.push(counter("bandwidth Mbps", 4.0, w.t_start, args));
    }

    Json::obj(vec![("traceEvents", Json::arr(out))])
}

/// [`perfetto_trace`] serialized to canonical bytes.
pub fn perfetto_string(events: &[TraceEvent]) -> String {
    perfetto_trace(events).to_string()
}

/// [`perfetto_audit_trace`] serialized to canonical bytes.
pub fn perfetto_audit_string(events: &[TraceEvent], fabric: &Fabric) -> String {
    perfetto_audit_trace(events, fabric).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat_tick(
        iter: usize,
        ts: f64,
        t_comp: f64,
        ticks: &[(f64, f64, f64)],
        tc: f64,
    ) -> TickTrace {
        let workers = ticks
            .iter()
            .enumerate()
            .map(|(i, &(tm, tc_w, tx))| WorkerTrace {
                worker: i as u32,
                region: None,
                aggregator: false,
                spans: worker_spans(
                    ts - t_comp,
                    ts,
                    (tm - tx).max(ts).min(tm),
                    tm,
                    tc_w,
                    tc,
                ),
                retx_secs: 0.0,
                paths: Vec::new(),
            })
            .collect();
        TickTrace { iter, ts, t_comp, tc, workers, regions: Vec::new() }
    }

    #[test]
    fn worker_spans_tile_and_clamp() {
        let s = worker_spans(0.0, 0.2, 0.25, 0.5, 0.7, 1.0);
        assert_eq!(s[0].t0, 0.0);
        assert_eq!(s[4].t1, 1.0);
        for w in s.windows(2) {
            assert_eq!(w[0].t1, w[1].t0, "contiguous");
        }
        // bonded-style: start before ts collapses QueueWait to zero
        let s = worker_spans(0.0, 0.2, 0.1, 0.5, 0.7, 1.0);
        assert_eq!(s[1].dur(), 0.0);
        assert_eq!(s[2].t0, 0.2);
        assert_eq!(s[2].t1, 0.5);
    }

    #[test]
    fn flat_attribution_sums_to_makespan() {
        let mut a = Attribution::new();
        // tick 1: ts=0.2 (t_comp 0.2), fastest worker arrives 0.5, tc 0.8
        a.record_tick(&flat_tick(
            1,
            0.2,
            0.2,
            &[(0.3, 0.5, 0.1), (0.6, 0.8, 0.2)],
            0.8,
        ));
        // tick 2 overlaps tick 1's horizon
        a.record_tick(&flat_tick(
            2,
            0.6,
            0.2,
            &[(0.9, 1.1, 0.2), (1.0, 1.4, 0.3)],
            1.4,
        ));
        let sum = a.attributed();
        let span = a.makespan();
        assert!((sum - span).abs() < 1e-12, "{sum} vs {span}");
        assert!(a.total(Phase::Compute) > 0.0);
        assert!(a.total(Phase::StragglerWait) > 0.0);
    }

    #[test]
    fn non_monotone_tc_contributes_nothing_new() {
        let mut a = Attribution::new();
        a.record_tick(&flat_tick(1, 0.2, 0.2, &[(0.5, 0.9, 0.2)], 0.9));
        let before = a.attributed();
        // a later tick that finishes earlier (post-churn speedup) is
        // entirely below the horizon
        a.record_tick(&flat_tick(2, 0.3, 0.1, &[(0.4, 0.5, 0.1)], 0.5));
        assert_eq!(a.attributed(), before);
        assert_eq!(a.makespan(), 0.9);
    }

    #[test]
    fn record_flat_matches_record_tick() {
        let mut by_tick = Attribution::new();
        let mut by_flat = Attribution::new();
        let ticks = [
            (0.2, 0.2, 0.35, 0.55, 0.1, 0.8),
            (0.6, 0.2, 0.95, 1.15, 0.2, 1.3),
        ];
        for (i, &(ts, t_comp, tm, tc_w, tx, tc)) in ticks.iter().enumerate() {
            by_tick.record_tick(&flat_tick(
                i + 1,
                ts,
                t_comp,
                &[(tm, tc_w, tx), (tm + 0.1, tc, tx)],
                tc,
            ));
            by_flat.record_flat(ts, t_comp, tm, tc_w, tx, 0.0, tc);
        }
        for p in Phase::ALL {
            assert_eq!(
                by_tick.total(p).to_bits(),
                by_flat.total(p).to_bits(),
                "{p:?}"
            );
        }
    }

    #[test]
    fn two_tier_attribution_sums_to_makespan() {
        let ts = 0.2;
        let t_comp = 0.2;
        let tc = 2.0;
        let mk = |w: u32, region, aggregator, tm: f64, tc_w: f64| WorkerTrace {
            worker: w,
            region: Some(region),
            aggregator,
            spans: if aggregator {
                worker_spans(ts - t_comp, ts, ts, ts, ts, tc)
            } else {
                worker_spans(ts - t_comp, ts, ts, tm, tc_w, tc)
            },
            retx_secs: 0.0,
            paths: Vec::new(),
        };
        let tk = TickTrace {
            iter: 1,
            ts,
            t_comp,
            tc,
            workers: vec![
                mk(0, 0, true, 0.0, 0.0),
                mk(1, 0, false, 0.3, 0.4),
                mk(2, 1, true, 0.0, 0.0),
                mk(3, 1, false, 0.35, 0.5),
            ],
            regions: vec![
                RegionTrace {
                    region: 0,
                    sync: 0.5,
                    wan_start: 0.6,
                    wan_tm: 1.0,
                    wan_tc: 1.3,
                    senders: 1,
                },
                RegionTrace {
                    region: 1,
                    sync: 0.5,
                    wan_start: 0.6,
                    wan_tm: 1.6,
                    wan_tc: 2.0,
                    senders: 1,
                },
            ],
        };
        let mut a = Attribution::new();
        a.record_tick(&tk);
        assert!((a.attributed() - 2.0).abs() < 1e-12);
        // region 0 is the fastest chain; waiting for region 1 is stall
        assert!((a.total(Phase::StragglerWait) - 0.7).abs() < 1e-12);
        assert!((a.total(Phase::WanTransfer) - 0.4).abs() < 1e-12);
        assert!((a.total(Phase::RegionSyncWait) - 0.1).abs() < 1e-12);
        let fsum = a.straggler_fraction()
            + a.transfer_fraction()
            + a.compute_fraction();
        assert!((fsum - 1.0).abs() < 1e-12, "fractions partition: {fsum}");
    }

    #[test]
    fn table_lists_all_chain_phases() {
        let mut a = Attribution::new();
        a.record_flat(0.2, 0.2, 0.5, 0.7, 0.2, 0.0, 1.0);
        let t = a.table();
        for p in [
            "compute",
            "lan_transfer",
            "retransmit",
            "straggler_wait",
            "makespan",
        ] {
            assert!(t.contains(p), "missing {p} in:\n{t}");
        }
    }

    #[test]
    fn retransmit_split_preserves_the_tiling() {
        // queue span [0.2, 0.5]: 0.2 s of it was retransmission
        let (q, r) = split_retransmit(
            Span { phase: Phase::QueueWait, t0: 0.2, t1: 0.5 },
            0.2,
        );
        assert_eq!((q.t0, q.t1), (0.2, 0.3));
        assert_eq!((r.t0, r.t1), (0.3, 0.5));
        assert_eq!(r.phase, Phase::Retransmit);
        // retx larger than the span clamps, never inverts
        let (q, r) = split_retransmit(
            Span { phase: Phase::QueueWait, t0: 0.2, t1: 0.5 },
            5.0,
        );
        assert_eq!(q.dur(), 0.0);
        assert_eq!((r.t0, r.t1), (0.2, 0.5));
    }

    #[test]
    fn flat_attribution_with_retransmit_still_sums_to_makespan() {
        let mut a = Attribution::new();
        // ts=0.2, final attempt starts 0.6 (tm 0.8, tx 0.2), of the queue
        // window [0.2, 0.6] the last 0.3 s were failed attempts + backoff
        a.record_flat(0.2, 0.2, 0.8, 1.0, 0.2, 0.3, 1.2);
        assert!((a.attributed() - a.makespan()).abs() < 1e-12);
        assert!((a.total(Phase::Retransmit) - 0.3).abs() < 1e-12);
        assert!((a.total(Phase::QueueWait) - 0.1).abs() < 1e-12);
        assert!(a.retransmit_fraction() > 0.0);
        // zero retx attributes nothing to the retransmit phase
        let mut b = Attribution::new();
        b.record_flat(0.2, 0.2, 0.8, 1.0, 0.2, 0.0, 1.2);
        assert_eq!(b.total(Phase::Retransmit), 0.0);
        assert!((b.attributed() - b.makespan()).abs() < 1e-12);
    }

    #[test]
    fn null_sink_is_disabled_buffer_records() {
        let mut null = NullSink;
        assert!(!null.enabled());
        let ev = TraceEvent::Clock {
            t: 1.0,
            iter: 3,
            event: ClockEvent::AggregatorElected {
                region: 0,
                old: Some(1),
                new: 2,
            },
        };
        null.record(&ev);
        let mut buf = BufferTracer::new();
        assert!(buf.enabled());
        buf.record(&ev);
        assert_eq!(buf.events(), &[ev]);
    }

    #[test]
    fn perfetto_round_trips_and_is_deterministic() {
        let tk = flat_tick(1, 0.2, 0.2, &[(0.5, 0.7, 0.2)], 1.0);
        let events = vec![
            TraceEvent::Tick(tk),
            TraceEvent::Churn {
                t: 0.9,
                iter: 1,
                event: ChurnEvent::Leave { worker: 0 },
            },
            TraceEvent::Replan {
                t: 1.0,
                iter: 2,
                rec: ReplanRecord {
                    lan: TierReplan {
                        input: DecoInput {
                            s_g: 1e8,
                            a: 2e7,
                            b: 0.2,
                            t_comp: 0.2,
                        },
                        tau: 2,
                        delta: 0.25,
                        log_phi: -1.0,
                    },
                    wan: None,
                    predicted_round: 0.21,
                    pessimistic: None,
                    links: Vec::new(),
                    predicted_loss: None,
                    deadline: None,
                },
            },
        ];
        let s1 = perfetto_string(&events);
        let s2 = perfetto_string(&events);
        assert_eq!(s1, s2, "byte-identical across serializations");
        let parsed = Json::parse(&s1).expect("emitted JSON parses");
        assert_eq!(parsed, perfetto_trace(&events), "round-trip");
        let evs = parsed.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert!(evs.len() > 5);
    }
}
