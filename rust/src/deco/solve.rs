//! Algorithm 1 (DeCo) — traverse the feasible τ range, compute δ*(τ) from
//! Remark 4, and return the φ-minimal pair.

use super::phi::log_phi;
use crate::netsim::Fabric;


/// Network / workload state consumed by DeCo (Algorithm 1 inputs).
#[derive(Clone, Copy, Debug)]
pub struct DecoInput {
    /// gradient size, bits
    pub s_g: f64,
    /// bandwidth, bits/s
    pub a: f64,
    /// end-to-end latency, s
    pub b: f64,
    /// computation time per iteration, s
    pub t_comp: f64,
}

impl DecoInput {
    /// Plan on the **bottleneck** of per-link `(a, b)` pairs — min
    /// bandwidth, max latency: the link that gates the synchronous
    /// aggregation on a heterogeneous fabric (DESIGN.md §Network-Fabric).
    pub fn bottleneck(
        s_g: f64,
        t_comp: f64,
        links: impl IntoIterator<Item = (f64, f64)>,
    ) -> Self {
        let (mut a, mut b) = (f64::INFINITY, f64::NEG_INFINITY);
        for (ai, bi) in links {
            a = a.min(ai);
            b = b.max(bi);
        }
        assert!(a.is_finite() && b.is_finite(), "needs at least one link");
        Self { s_g, a, b, t_comp }
    }

    /// Plan on the **mean link** — what a heterogeneity-blind controller
    /// sees (the `exp hetero` control arm).
    pub fn mean_link(
        s_g: f64,
        t_comp: f64,
        links: impl IntoIterator<Item = (f64, f64)>,
    ) -> Self {
        let (mut sa, mut sb, mut n) = (0.0, 0.0, 0usize);
        for (ai, bi) in links {
            sa += ai;
            sb += bi;
            n += 1;
        }
        assert!(n > 0, "needs at least one link");
        Self { s_g, a: sa / n as f64, b: sb / n as f64, t_comp }
    }

    /// The bottleneck of the fabric's **active** links at time `t` — the
    /// membership-aware planning view under churn (DESIGN.md §Elasticity):
    /// a departed straggler stops constraining the plan, a rejoined one
    /// constrains it again.
    ///
    /// This is the *ground-truth* fabric view, for programmatic planning
    /// and analysis (like [`Self::bottleneck`]/[`Self::mean_link`]). The
    /// training loop itself plans on the *monitored* active-set view:
    /// `netsim::FabricMonitor` applies the same membership mask to its
    /// per-link EWMA estimators.
    pub fn bottleneck_fabric(
        s_g: f64,
        t_comp: f64,
        fabric: &Fabric,
        t: f64,
        active: &[bool],
    ) -> Self {
        let (a, b) = fabric.bottleneck_active(t, active);
        Self { s_g, a, b, t_comp }
    }

    /// The mean of the fabric's **active** links at time `t` — the
    /// heterogeneity-blind control view under churn.
    pub fn mean_link_fabric(
        s_g: f64,
        t_comp: f64,
        fabric: &Fabric,
        t: f64,
        active: &[bool],
    ) -> Self {
        let (a, b) = fabric.mean_active(t, active);
        Self { s_g, a, b, t_comp }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecoOutput {
    pub tau: usize,
    pub delta: f64,
    /// ln φ at the optimum (−∞ when δ*=1, i.e. no compression needed)
    pub log_phi: f64,
}

/// Remark 4: the largest δ that keeps the pipeline bubble-free at staleness
/// τ. Returns `None` when even δ→0 cannot (τ·T_comp ≤ b: the delay cannot
/// cover the latency alone).
pub fn delta_star(inp: &DecoInput, tau: usize) -> Option<f64> {
    let by_delay = (tau as f64 * inp.t_comp - inp.b) * inp.a / inp.s_g;
    let by_rate = inp.t_comp * inp.a / inp.s_g;
    let d = by_delay.min(by_rate).min(1.0);
    (d > 0.0).then_some(d)
}

/// The feasible τ range of Eq. 11: `[⌈b/T_comp⌉, ⌈(b + S_g/a)/T_comp⌉]`.
pub fn tau_range(inp: &DecoInput) -> (usize, usize) {
    let lo = (inp.b / inp.t_comp).ceil() as usize;
    let hi = ((inp.b + inp.s_g / inp.a) / inp.t_comp).ceil() as usize;
    (lo, hi.max(lo))
}

/// Algorithm 1. Iterates τ from high to low (like the paper's pseudo-code)
/// keeping `φ ≤ φ_min`, so ties resolve to the smallest τ. Always returns a
/// valid output: if no (τ, δ) in range is feasible (degenerate network), it
/// falls back to `τ = ⌈b/T_comp⌉ + 1, δ = δ*` or ultimately (τ_lo, 1.0).
pub fn solve(inp: &DecoInput) -> DecoOutput {
    assert!(inp.s_g > 0.0 && inp.a > 0.0 && inp.t_comp > 0.0 && inp.b >= 0.0);
    let (lo, hi) = tau_range(inp);
    let mut best: Option<DecoOutput> = None;
    // high -> low, keep on <=: ties prefer smaller τ (fresher gradients)
    for tau in (lo..=hi).rev() {
        let Some(delta) = delta_star(inp, tau) else { continue };
        let lp = log_phi(delta, tau);
        if best.map_or(true, |b| lp <= b.log_phi) {
            best = Some(DecoOutput { tau, delta, log_phi: lp });
        }
    }
    best.unwrap_or_else(|| {
        // degenerate: even the largest feasible τ gives δ*(τ) <= 0 — means
        // τ·T_comp ≤ b across the whole range (only possible at lo == hi
        // with extreme b). Push τ one beyond until positive.
        let mut tau = hi + 1;
        loop {
            if let Some(delta) = delta_star(inp, tau) {
                return DecoOutput { tau, delta, log_phi: log_phi(delta, tau) };
            }
            tau += 1;
            if tau > hi + 1_000_000 {
                return DecoOutput { tau: lo, delta: 1.0, log_phi: f64::NEG_INFINITY };
            }
        }
    })
}

/// EXTENSION (beyond the paper — see DESIGN.md): Remark 4 takes
/// δ = δ*(τ) as the per-τ optimum, implicitly assuming φ(·, τ) is
/// decreasing. That holds on the paper's operating range, but
/// `d ln φ/dδ = −1/(1−δ) − 1/δ + τ/(2−δ)` changes sign for large τ:
/// past the stationary point, *less* aggressive compression would
/// converge faster at zero time cost (any δ ≤ δ*(τ) keeps the pipeline
/// bubble-free). `solve_refined` minimizes φ over the full feasible
/// interval (0, δ*(τ)] per τ via ternary search on ln φ, and never does
/// worse than Algorithm 1.
pub fn solve_refined(inp: &DecoInput) -> DecoOutput {
    let (lo, hi) = tau_range(inp);
    let mut best: Option<DecoOutput> = None;
    for tau in (lo..=hi).rev() {
        let Some(dmax) = delta_star(inp, tau) else { continue };
        // ternary-search the unimodal-on-(0, dmax] region; log_phi is
        // decreasing then increasing on (0, min(dmax, stationary)], so a
        // bounded ternary search finds the interior min (or the edge).
        let (mut a, mut b) = (1e-6, dmax);
        for _ in 0..80 {
            let m1 = a + (b - a) / 3.0;
            let m2 = b - (b - a) / 3.0;
            if log_phi(m1, tau) <= log_phi(m2, tau) {
                b = m2;
            } else {
                a = m1;
            }
        }
        let delta = ((a + b) / 2.0).min(dmax);
        // candidates: interior optimum and the Remark-4 edge
        for d in [delta, dmax] {
            let lp = log_phi(d, tau);
            if best.map_or(true, |bst| lp <= bst.log_phi) {
                best = Some(DecoOutput { tau, delta: d, log_phi: lp });
            }
        }
    }
    best.unwrap_or_else(|| solve(inp))
}

/// Brute-force reference: grid-search δ on a fine grid for every τ in a wide
/// range, honoring the same bubble-free constraint. Used by tests to verify
/// `solve` is optimal among feasible pairs.
pub fn solve_brute_force(inp: &DecoInput, grid: usize) -> DecoOutput {
    let (lo, hi) = tau_range(inp);
    let mut best = DecoOutput { tau: lo, delta: 1.0, log_phi: f64::INFINITY };
    for tau in lo..=hi {
        let Some(dmax) = delta_star(inp, tau) else { continue };
        // φ is decreasing in δ, so the constrained optimum for this τ is at
        // δ = δ*(τ); the grid verifies that claim numerically.
        for i in 1..=grid {
            let d = dmax * i as f64 / grid as f64;
            let lp = log_phi(d, tau);
            if lp < best.log_phi
                || (lp == best.log_phi && tau < best.tau)
            {
                best = DecoOutput { tau, delta: d, log_phi: lp };
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inp(s_g: f64, a: f64, b: f64, t_comp: f64) -> DecoInput {
        DecoInput { s_g, a, b, t_comp }
    }

    #[test]
    fn matches_brute_force() {
        let cases = [
            inp(124e6 * 32.0, 1e8, 0.1, 0.5),  // GPT-2 on 100 Mbps / 100 ms
            inp(124e6 * 32.0, 5e8, 0.1, 0.5),
            inp(124e6 * 32.0, 1e8, 1.0, 0.5),
            inp(124e6 * 32.0, 5e8, 1.0, 0.5),
            inp(86e6 * 32.0, 1e8, 0.2, 0.3),   // ViT-Base
            inp(1e9, 1e9, 0.05, 0.01),
        ];
        for c in cases {
            let fast = solve(&c);
            let brute = solve_brute_force(&c, 400);
            assert_eq!(fast.tau, brute.tau, "{c:?}");
            assert!(
                (fast.delta - brute.delta).abs() / brute.delta < 0.01,
                "{c:?}: {} vs {}",
                fast.delta,
                brute.delta
            );
            assert!(fast.log_phi <= brute.log_phi + 1e-9);
        }
    }

    #[test]
    fn bubble_free_condition_holds() {
        // T_avg(τ*, δ*) == T_comp per Theorem 3's closed form
        use crate::timesim::model::{t_avg_closed_form, PipelineParams};
        let c = inp(124e6 * 32.0, 1e8, 0.1, 0.5);
        let out = solve(&c);
        let p = PipelineParams {
            a: c.a,
            b: c.b,
            delta: out.delta,
            tau: out.tau,
            t_comp: c.t_comp,
            s_g: c.s_g,
        };
        let tavg = t_avg_closed_form(&p);
        assert!(
            (tavg - c.t_comp).abs() / c.t_comp < 1e-6,
            "T_avg={tavg} != T_comp={}",
            c.t_comp
        );
    }

    #[test]
    fn bottleneck_and_mean_link_inputs() {
        let links = [(1e7, 0.9), (1e8, 0.1), (1e8, 0.1), (1e8, 0.1)];
        let bot = DecoInput::bottleneck(1e9, 0.2, links);
        assert_eq!(bot.a, 1e7);
        assert_eq!(bot.b, 0.9);
        let mean = DecoInput::mean_link(1e9, 0.2, links);
        assert!((mean.a - 7.75e7).abs() < 1.0);
        assert!((mean.b - 0.3).abs() < 1e-12);
        // blind planning is strictly more optimistic under a straggler: it
        // tolerates a larger delta than the gating link can afford
        let d_bot = solve(&bot).delta;
        let d_mean = solve(&mean).delta;
        assert!(d_mean > d_bot, "mean {d_mean} <= bottleneck {d_bot}");
        // identical links: the two views coincide
        let homo = [(1e8, 0.1); 4];
        let hb = DecoInput::bottleneck(1e9, 0.2, homo);
        let hm = DecoInput::mean_link(1e9, 0.2, homo);
        assert_eq!(hb.a, hm.a);
        assert_eq!(hb.b, hm.b);
    }

    #[test]
    fn fabric_constructors_follow_the_active_set() {
        use crate::netsim::{BandwidthTrace, Fabric};
        let fabric = Fabric::with_straggler(
            4,
            BandwidthTrace::constant(1e8),
            0.1,
            0.1, // tenth bandwidth
            9.0, // 9x latency
        );
        let all = vec![true; 4];
        let bot = DecoInput::bottleneck_fabric(1e9, 0.2, &fabric, 0.0, &all);
        assert_eq!(bot.a, 1e7);
        assert!((bot.b - 0.9).abs() < 1e-12);
        // straggler departs: the active-set plan relaxes to the healthy links
        let mask = vec![false, true, true, true];
        let gone = DecoInput::bottleneck_fabric(1e9, 0.2, &fabric, 0.0, &mask);
        assert_eq!(gone.a, 1e8);
        assert!((gone.b - 0.1).abs() < 1e-12);
        assert!(solve(&gone).delta > solve(&bot).delta);
        let mean = DecoInput::mean_link_fabric(1e9, 0.2, &fabric, 0.0, &mask);
        assert_eq!(mean.a, 1e8, "healthy links are identical");
    }

    #[test]
    fn good_network_needs_no_compression() {
        // LAN-like: δ* should hit 1.0 (or very near) and τ small
        let c = inp(1e6 * 32.0, 1e10, 0.001, 0.1);
        let out = solve(&c);
        assert!(out.delta > 0.99, "delta={}", out.delta);
        assert!(out.tau <= 1);
    }

    #[test]
    fn worse_bandwidth_smaller_delta() {
        let base = inp(124e6 * 32.0, 5e8, 0.1, 0.5);
        let slow = inp(124e6 * 32.0, 1e8, 0.1, 0.5);
        let d_base = solve(&base).delta;
        let d_slow = solve(&slow).delta;
        assert!(d_slow < d_base, "{d_slow} !< {d_base}");
    }

    #[test]
    fn higher_latency_larger_tau() {
        let low = inp(124e6 * 32.0, 1e8, 0.1, 0.5);
        let high = inp(124e6 * 32.0, 1e8, 1.0, 0.5);
        assert!(solve(&high).tau > solve(&low).tau);
    }

    #[test]
    fn paper_table3_orders_of_magnitude() {
        // Table 3 reports (τ*, δ*) = (2, 0.02) for GPT at a=0.1 Gbps,
        // b=0.1 s and (3, 0.02) at b=1.0 s. With T_comp ~= b/τ* scale
        // (paper's A40 testbed, GPT-2 124M, batch 5), our solver should land
        // in the same ballpark: τ in [1, 6], δ in [0.005, 0.1].
        let s_g = 124e6 * 32.0;
        let t_comp = 0.35; // ~paper-scale step time
        for (a, b) in [(1e8, 0.1), (5e8, 0.1), (1e8, 1.0), (5e8, 1.0)] {
            let out = solve(&inp(s_g, a, b, t_comp));
            assert!(out.tau >= 1 && out.tau <= 6, "tau={} at ({a},{b})", out.tau);
            assert!(
                out.delta >= 0.004 && out.delta <= 0.2,
                "delta={} at ({a},{b})",
                out.delta
            );
        }
    }

    #[test]
    fn refined_never_worse_and_beats_brute_force_region() {
        // refined == Algorithm 1 on the paper's operating range, and at
        // least as good everywhere (including large-τ regimes where
        // Remark 4's edge choice is suboptimal)
        let cases = [
            inp(124e6 * 32.0, 1e8, 0.1, 0.5),
            inp(124e6 * 32.0, 5e8, 1.0, 0.5),
            inp(86e6 * 32.0, 1e8, 0.2, 0.3),
            // latency-dominated: huge τ -> φ non-monotone in δ
            inp(1e8, 1e9, 5.0, 0.05),
            inp(1e7, 1e9, 2.0, 0.02),
        ];
        for c in cases {
            let alg1 = solve(&c);
            let refined = solve_refined(&c);
            assert!(
                refined.log_phi <= alg1.log_phi + 1e-9,
                "{c:?}: refined {} worse than alg1 {}",
                refined.log_phi,
                alg1.log_phi
            );
            let brute = solve_brute_force(&c, 800);
            assert!(
                refined.log_phi <= brute.log_phi + 1e-6,
                "{c:?}: refined {} vs brute {}",
                refined.log_phi,
                brute.log_phi
            );
        }
    }

    #[test]
    fn tau_range_sane() {
        let c = inp(1e9, 1e8, 0.5, 0.1);
        let (lo, hi) = tau_range(&c);
        assert_eq!(lo, 5); // ceil(0.5/0.1)
        assert_eq!(hi, 105); // ceil((0.5 + 10)/0.1)
    }

    #[test]
    fn degenerate_latency_dominated_still_returns() {
        // absurdly high latency: b >> everything
        let c = inp(1e6, 1e9, 100.0, 0.001);
        let out = solve(&c);
        assert!(out.delta > 0.0 && out.delta <= 1.0);
        assert!(out.tau >= (c.b / c.t_comp) as usize);
    }
}
