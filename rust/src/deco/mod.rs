//! DeCo (Algorithm 1) — joint selection of delay staleness `τ*` and
//! compression ratio `δ*` from the network state `(a, b)`, the gradient
//! size `S_g`, and the measured compute time `T_comp`.
//!
//! The objective is the convergence-governing factor from Theorem 1,
//!
//! ```text
//! φ(δ, τ) = (1 − δ) / ( δ · (1 − δ/2)^τ )
//! ```
//!
//! minimized subject to the bubble-free-pipeline condition
//! `T_avg = T_comp` (Eq. 10/11), which by Remark 4 pins
//! `δ*(τ) = min{ (τ·T_comp − b)·a/S_g, T_comp·a/S_g, 1 }` and restricts
//! `τ ∈ [⌈b/T_comp⌉, ⌈(b + S_g/a)/T_comp⌉]`. The traversal picks the φ-minimal
//! pair, ties going to the smallest τ (freshest gradients), exactly like the
//! paper's pseudo-code (which iterates τ downward and keeps `φ ≤ φ_min`).

pub mod phi;
pub mod solve;

pub use phi::{log_phi, phi, phi_prime};
pub use solve::{solve, DecoInput, DecoOutput};

/// Snap a continuous δ* to the AOT palette (the HLO compress modules are
/// compiled for fixed k — see python/compile/aot.py::DELTA_PALETTE). Picks
/// the smallest palette entry ≥ δ* (never undershoots the bubble-free
/// condition from above; falls back to the largest entry below if δ* exceeds
/// the whole palette, i.e. 1.0 handled by caller via `delta >= 1`).
pub fn snap_to_palette(delta: f64, palette: &[f64]) -> f64 {
    debug_assert!(!palette.is_empty());
    let mut sorted: Vec<f64> = palette.to_vec();
    sorted.sort_by(|x, y| x.partial_cmp(y).unwrap());
    for &p in &sorted {
        if p >= delta {
            return p;
        }
    }
    *sorted.last().unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn snap_picks_ceiling_entry() {
        let pal = [0.01, 0.02, 0.05, 0.1, 0.2, 0.5];
        assert_eq!(super::snap_to_palette(0.03, &pal), 0.05);
        assert_eq!(super::snap_to_palette(0.05, &pal), 0.05);
        assert_eq!(super::snap_to_palette(0.001, &pal), 0.01);
        assert_eq!(super::snap_to_palette(0.9, &pal), 0.5);
    }
}
