//! The φ factor of Theorem 1 — the quantity DeCo minimizes.
//!
//! φ(δ, τ) = (1−δ) / (δ·(1−δ/2)^τ) blows up double-exponentially fast in τ
//! for small δ (the paper's headline: *staleness exponentially amplifies
//! compression noise*). Comparisons therefore run in log space.

/// φ(δ, τ). Returns 0 for δ = 1 (degradation to DD-SGD, Remark 2) and +∞
/// for δ ≤ 0.
pub fn phi(delta: f64, tau: usize) -> f64 {
    if delta >= 1.0 {
        return 0.0;
    }
    if delta <= 0.0 {
        return f64::INFINITY;
    }
    log_phi(delta, tau).exp()
}

/// ln φ(δ, τ) — overflow-free ordering key.
pub fn log_phi(delta: f64, tau: usize) -> f64 {
    if delta >= 1.0 {
        return f64::NEG_INFINITY;
    }
    if delta <= 0.0 {
        return f64::INFINITY;
    }
    (1.0 - delta).ln() - delta.ln() - tau as f64 * (1.0 - delta / 2.0).ln()
}

/// The federated-learning / small-model variant from Remark 1:
/// φ'(δ, τ) = (1−δ) / (δ²·(1−δ/2)^τ).
pub fn phi_prime(delta: f64, tau: usize) -> f64 {
    if delta >= 1.0 {
        return 0.0;
    }
    if delta <= 0.0 {
        return f64::INFINITY;
    }
    ((1.0 - delta).ln() - 2.0 * delta.ln()
        - tau as f64 * (1.0 - delta / 2.0).ln())
    .exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degradation_cases() {
        // δ=1 → φ=0 (DD-SGD); τ=0 → φ=(1-δ)/δ (D-EF-SGD)
        assert_eq!(phi(1.0, 5), 0.0);
        for delta in [0.01, 0.1, 0.5] {
            let expect = (1.0 - delta) / delta;
            assert!((phi(delta, 0) - expect).abs() / expect < 1e-12);
        }
    }

    #[test]
    fn exponential_amplification_in_tau() {
        // φ(δ, τ+1)/φ(δ, τ) == 1/(1-δ/2) — the exponential factor the paper
        // is the first to expose
        let delta = 0.05;
        let ratio = 1.0 / (1.0 - delta / 2.0);
        for tau in [0usize, 1, 5, 20, 100] {
            let r = phi(delta, tau + 1) / phi(delta, tau);
            assert!((r - ratio).abs() < 1e-9, "tau={tau}: {r} vs {ratio}");
        }
    }

    #[test]
    fn delta_derivative_matches_analytic_sign() {
        // d ln φ / dδ = -1/(1-δ) - 1/δ + τ/(2-δ). For τ=0 this is always
        // negative (φ strictly decreasing); for large τ it changes sign
        // (down, up, then down again near δ→1) — the numeric values must
        // agree with the analytic derivative's sign everywhere.
        for tau in [0usize, 2, 10, 40] {
            for i in 1..199 {
                let d = i as f64 / 200.0;
                let analytic =
                    -1.0 / (1.0 - d) - 1.0 / d + tau as f64 / (2.0 - d);
                let h = 1e-6;
                let numeric = (log_phi(d + h, tau) - log_phi(d - h, tau))
                    / (2.0 * h);
                assert!(
                    (numeric - analytic).abs()
                        < 1e-3 * analytic.abs().max(1.0),
                    "tau={tau} delta={d}: {numeric} vs {analytic}"
                );
                if tau == 0 {
                    assert!(analytic < 0.0, "phi(·,0) must be decreasing");
                }
            }
        }
    }

    #[test]
    fn log_phi_consistent_with_phi() {
        for (d, t) in [(0.01, 3usize), (0.2, 7), (0.77, 0)] {
            assert!((log_phi(d, t).exp() - phi(d, t)).abs() / phi(d, t) < 1e-12);
        }
    }

    #[test]
    fn log_phi_handles_huge_tau_without_overflow() {
        let lp = log_phi(0.01, 400_000);
        assert!(lp.is_finite());
        assert!(phi(0.01, 400_000).is_infinite()); // exp overflows, log fine
    }

    #[test]
    fn phi_prime_dominates_phi() {
        // φ' = φ/δ ≥ φ for δ ≤ 1
        for (d, t) in [(0.05, 2usize), (0.3, 5), (0.9, 1)] {
            assert!(phi_prime(d, t) >= phi(d, t));
            let expect = phi(d, t) / d;
            assert!((phi_prime(d, t) - expect).abs() / expect < 1e-9);
        }
    }
}
