//! Minimal JSON codec — parser + writer for the artifact manifest, configs,
//! and result files. Built in-tree because this repo builds fully offline
//! from a vendored crate set that has no serde facade (see Cargo.toml).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null). Numbers are f64 (adequate: every integer in our
//! schemas fits in 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Typed helpers that thread an error message.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| format!("key '{key}' not a string"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, String> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| format!("key '{key}' not a number"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, String> {
        Ok(self.req_f64(key)? as usize)
    }

    // ----- builders -------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ----- parse ----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- write ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            if let Some(w) = indent {
                out.push('\n');
                for _ in 0..(w * d) {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected '{}'", c as char))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c => {
                    // copy raw UTF-8 bytes through
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("bad utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(b'0'..=b'9') | Some(b'.') | Some(b'e') | Some(b'E')
                | Some(b'+') | Some(b'-')
        ) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
            "block": 1024,
            "modules": {
                "grad_gpt": {"file": "g.hlo.txt", "delta": 0.05,
                             "inputs": [{"shape": [8, 64], "dtype": "i32"}]}
            },
            "flag": true, "none": null, "neg": -1.5e3
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.req_usize("block").unwrap(), 1024);
        let m = j.get("modules").unwrap().get("grad_gpt").unwrap();
        assert_eq!(m.req_str("file").unwrap(), "g.hlo.txt");
        assert_eq!(m.req_f64("delta").unwrap(), 0.05);
        let shape = m.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_usize(), Some(64));
        assert_eq!(j.get("flag").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("none"), Some(&Json::Null));
        assert_eq!(j.get("neg").unwrap().as_f64(), Some(-1500.0));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = Json::obj(vec![
            ("a", Json::arr([Json::num(1.0), Json::num(2.5)])),
            ("s", Json::str("hi \"there\"\n")),
            ("b", Json::Bool(false)),
        ]);
        for text in [v.to_string(), v.to_string_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::num(1024.0).to_string(), "1024");
        assert_eq!(Json::num(0.05).to_string(), "0.05");
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "tab\t nl\n quote\" back\\ unicode→";
        let j = Json::Str(s.to_string());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_escape_parses() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} trailing").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }
}
